"""granite-moe-3b-a800m [moe] — 40 routed experts, top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]

40 % 16 != 0 so EP over the 16-way model axis is off; expert FFN hidden dim is
sharded instead (TP-for-MoE; DESIGN.md §3).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    grad_accum=4,
    moe_group=1024,  # §Perf hillclimb: capacity state is O(k t^2)/group
    n_experts=40,
    n_shared_experts=0,
    top_k=8,
    moe_d_ff=512,
    rope_theta=10_000.0,
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)
