"""Admission control: shed overload instead of thrashing the far tier.

The paper's Fig. 4 point is that pushing DDR past its utilization knee
explodes latency — the serving analogue is a backlog so deep that decode
steps queue behind far-tier migration traffic. The controller models each
request as (prefill + decode) token-equivalents of work, estimates the
fleet's service rate from its slot capacity, and admits only while the
projected queueing delay stays inside the SLO. Shed requests are counted,
not errored: an overloaded fleet degrades by rejecting at the door.
"""
from __future__ import annotations

import dataclasses
from typing import List

from repro.data.requests import Request


@dataclasses.dataclass
class SLOModel:
    """Delay budget in engine steps + how request tokens map to steps.

    A decode token costs one slot-step; prefill is amortized (one batched
    pass) so it is discounted by ``prefill_weight``.
    """

    max_delay_steps: float = 64.0
    prefill_weight: float = 0.25

    def request_cost(self, req: Request) -> float:
        return self.prefill_weight * len(req.tokens) + req.decode_len


class AdmissionController:
    def __init__(self, slo: SLOModel):
        self.slo = slo
        self.offered = 0
        self.admitted = 0

    @property
    def shed(self) -> int:
        return self.offered - self.admitted

    @property
    def shed_rate(self) -> float:
        return self.shed / max(self.offered, 1)

    def backlog_steps(self, replicas: List) -> float:
        """Projected steps to drain the fleet's queued work at full rate.

        Queued prompts are discounted by the same ``prefill_weight`` as
        ``request_cost`` so admission and its SLO share one cost model.
        """
        work = sum(r.engine.backlog_tokens(self.slo.prefill_weight) for r in replicas)
        rate = sum(len(r.engine.slots) for r in replicas)  # tokens/step ideal
        return work / max(rate, 1)

    def admit(self, req: Request, replicas: List) -> bool:
        self.offered += 1
        rate = sum(len(r.engine.slots) for r in replicas)
        projected = self.backlog_steps(replicas) + self.slo.request_cost(req) / max(rate, 1)
        if projected > self.slo.max_delay_steps:
            return False
        self.admitted += 1
        return True
