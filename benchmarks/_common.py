"""Shared benchmark harness: run the serving engine under a paper-workload
profile and return measured access statistics (MemProf-in-the-loop)."""
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.workloads import PROFILES, get_profile
from repro.data.requests import RequestGenerator
from repro.models.api import get_model
from repro.runtime.serving import EngineConfig, ServingEngine

_MODEL_CACHE = {}  # arch -> (cfg, api, params): one jitted decode per arch


def engine_for(arch="smollm-360m", seed=0, **ekw):
    if arch not in _MODEL_CACHE:
        cfg = get_config(arch).reduced()
        api = get_model(cfg)
        _MODEL_CACHE[arch] = (cfg, api, api.init(jax.random.PRNGKey(0)))
    cfg, api, params = _MODEL_CACHE[arch]
    kw = dict(max_batch=4, max_len=64, n_pages=512)
    kw.update(ekw)
    return cfg, ServingEngine(api, params, EngineConfig(**kw), seed=seed)


def run_workload(name, n_requests=10, seed=0, arch="smollm-360m", prompt=24, decode=8, **ekw):
    cfg, eng = engine_for(arch, seed=seed, **ekw)
    prof = dataclasses.replace(get_profile(name), prompt_mean=prompt, decode_mean=decode)
    gen = RequestGenerator(prof, vocab_size=cfg.vocab_size, seed=seed)
    stats = eng.run(gen, n_requests=n_requests, max_steps=2000)
    return eng, stats


def stream_for(name, n=20_000, n_blocks=4096, seed=0):
    """Raw block-access stream for a workload profile (fast path)."""
    prof = get_profile(name)
    gen = RequestGenerator(prof, vocab_size=1024, seed=seed)
    return gen.block_stream(n, n_blocks=n_blocks), prof


def fmt_table(rows, headers):
    w = [max(len(str(r[i])) for r in rows + [headers]) for i in range(len(headers))]
    out = ["  ".join(str(h).ljust(w[i]) for i, h in enumerate(headers))]
    out.append("  ".join("-" * w[i] for i in range(len(headers))))
    for r in rows:
        out.append("  ".join(str(c).ljust(w[i]) for i, c in enumerate(r)))
    return "\n".join(out)


ALL_WORKLOADS = list(PROFILES)
