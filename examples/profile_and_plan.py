"""The paper's method, end to end: MEASURE the workload's memory behavior,
then let the measurements PICK the memory-subsystem design.

1. profile block accesses (MemProf.MemBW analogue) for a service,
2. compute the bandwidth distribution + stability (Fig. 9/18),
3. plan a two-tier split from the CDF and evaluate Baseline/Ideal/Tiered
   (Table 4/5), and
4. check the prefetchability of the stream (Fig. 21/22).

PYTHONPATH=src python examples/profile_and_plan.py [--workload Reader]
"""
import argparse

import numpy as np

from repro.configs.workloads import PROFILES
from repro.core import distribution as dist
from repro.core import hw
from repro.core.prefetch import PrefetchEngine
from repro.core.profiler import AccessProfiler
from repro.core.tiering import ThroughputModel, evaluate_configs
from repro.data.requests import RequestGenerator


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="Reader", choices=sorted(PROFILES))
    ap.add_argument("--samples", type=int, default=120_000)
    args = ap.parse_args()
    prof_spec = PROFILES[args.workload]

    # 1. measure
    gen = RequestGenerator(prof_spec, vocab_size=1024, seed=0)
    stream = gen.block_stream(args.samples)
    prof = AccessProfiler(n_blocks=prof_spec.n_blocks)
    prof.record("state", stream)
    counts = prof.counts("state")

    # 2. distribution
    cap90 = dist.capacity_for_traffic(counts, 0.90)
    alpha = dist.zipf_alpha(counts)
    thirds = [np.bincount(t, minlength=prof_spec.n_blocks) for t in np.array_split(stream, 3)]
    stab = dist.interval_stability(thirds, 0.10)
    print(f"[{args.workload}] measured behavior:")
    print(f"  90% of bandwidth comes from {cap90*100:.1f}% of capacity (zipf alpha ~ {alpha:.2f})")
    print(f"  hottest-10% traffic share stable at {stab['mean']:.3f} +- {stab['max_dev']:.3f} across windows")

    # 3. the measurements pick the design
    res = evaluate_configs(
        counts,
        {"Baseline": hw.BASELINE, "Ideal": hw.IDEAL, "Tiered": hw.TIERED},
        ThroughputModel(),
    )
    print("  tier evaluation (paper Table 5):")
    for name, r in res.items():
        print(
            f"    {name:9s} tput {r['relative_throughput']:.3f}x  "
            f"tput/cost {r['throughput_per_cost']:.3f}  bound {r['bound']}"
        )
    best = max(res, key=lambda k: res[k]["throughput_per_cost"])
    print(f"  -> measured behavior selects: {best}")

    # 4. prefetchability
    eng = PrefetchEngine("nextline", buffer_blocks=256, degree=1)
    for b in stream[:20_000]:
        eng.access(int(b), is_far=True)
    s = eng.stats
    print(f"  prefetcher on this stream: accuracy {s.accuracy:.2f}, coverage {s.coverage:.2f} "
          f"(paper Fig. 22: worth enabling only with bandwidth headroom)")
    print("profile_and_plan ok")


if __name__ == "__main__":
    main()
