from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update  # noqa: F401
from repro.optim.schedule import warmup_cosine  # noqa: F401
from repro.optim.compression import compress_int8, decompress_int8  # noqa: F401
