"""Flash attention Pallas TPU kernel.

Grid: (batch, q_heads, Lq/block_q, Lk/block_k) — the KV-block axis is the
innermost (sequential on TPU), so the online-softmax running state
(m, l, acc) lives in VMEM scratch and is carried across KV blocks.

VMEM working set per step: q (bq, D) + k/v (bk, D) + acc (bq, D) + scores
(bq, bk) — with bq=bk=512, D=128 in f32 that's ~2.8 MiB, comfortably under
the ~16 MiB/core VMEM budget of v5e while keeping the MXU matmul dims
(bq x D x bk) at multiples of 128.

GQA without KV expansion: the K/V index maps divide the query-head index by
the group size, so each KV head's blocks are fetched once per group.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._interpret import resolve_interpret

NEG_INF = -1e30
LANES = 128


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, causal, scale, lk_valid, q_offset):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)  # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)  # (bk, D)
    bq, d = q.shape
    bk = k.shape[0]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bk)

    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = kpos < lk_valid
    if causal:
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_offset
        valid = valid & (kpos <= qpos)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[:, 0]  # (bq,)
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_ref[:, 0] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[:, 0], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 512,
    block_k: int = 512,
    lk_valid: int | None = None,
    q_offset: int | None = None,
    interpret=None,
) -> jax.Array:
    """q: (B, Hq, Lq, D); k/v: (B, Hkv, Lk, D). Dims must divide the blocks.

    Returns (B, Hq, Lq, D) in q.dtype. ``lk_valid`` is the unpadded K length
    (ops.py pads K/V; rows at kpos >= lk_valid are masked). ``q_offset`` is
    the absolute position of q row 0 (for prefix alignment: lk_true - lq_true).
    """
    b, hq, lq, d = q.shape
    hkv, lk = k.shape[1], k.shape[2]
    group = hq // hkv
    block_q = min(block_q, lq)
    block_k = min(block_k, lk)
    assert lq % block_q == 0 and lk % block_k == 0, (lq, lk, block_q, block_k)
    grid = (b, hq, lq // block_q, lk // block_k)
    scale = 1.0 / math.sqrt(d)
    lk_valid = lk if lk_valid is None else lk_valid
    q_offset = (lk_valid - lq) if q_offset is None else q_offset

    kernel = functools.partial(
        _kernel, causal=causal, scale=scale, lk_valid=lk_valid, q_offset=q_offset
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bb, h, i, j: (bb, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda bb, h, i, j, g=group: (bb, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda bb, h, i, j, g=group: (bb, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda bb, h, i, j: (bb, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, LANES), jnp.float32),  # m (lane-replicated)
            pltpu.VMEM((block_q, LANES), jnp.float32),  # l
            pltpu.VMEM((block_q, d), jnp.float32),  # acc
        ],
        interpret=resolve_interpret(interpret),
    )(q, k, v)
