"""Paper Fig. 22: L2 prefetcher accuracy/coverage per workload.

The paper's finding — high accuracy (>75%) but LOW coverage (<50%) on
irregular cloud workloads, near-perfect on predictable streams (Ads1 /
CPU inference) — reproduced with the software far-tier prefetcher on each
workload profile's block stream.
"""
import numpy as np

from repro.core.prefetch import PrefetchEngine

from _common import ALL_WORKLOADS, fmt_table, stream_for


def main(predictor="nextline"):
    rows = []
    out = {}
    for name in ALL_WORKLOADS:
        stream, prof = stream_for(name, n=12_000)
        eng = PrefetchEngine(predictor=predictor, buffer_blocks=256, degree=1)
        for b in stream:
            eng.access(int(b), is_far=True)
        s = eng.stats
        rows.append((name, f"{s.accuracy*100:5.1f}%", f"{s.coverage*100:5.1f}%", f"{s.bw_overhead*100:5.1f}%"))
        out[name] = (s.accuracy, s.coverage)
    # the predictable sequential stream (Ads1-like CPU inference analogue)
    eng = PrefetchEngine(predictor="nextline", buffer_blocks=128, degree=4)
    for b in np.tile(np.arange(512), 8):
        eng.access(int(b), is_far=True)
    s = eng.stats
    rows.append(("sequential(KV walk)", f"{s.accuracy*100:5.1f}%", f"{s.coverage*100:5.1f}%", f"{s.bw_overhead*100:5.1f}%"))
    print(f"[fig22] far-tier prefetcher accuracy/coverage (predictor={predictor})")
    print(fmt_table(rows, ["workload", "accuracy", "coverage", "bw overhead"]))
    print("paper: accuracy >75%, coverage <50% for most services; regular streams prefetch well")
    return out


if __name__ == "__main__":
    main()
