"""Prefetch engine: per-stream predictors, trace training, batch contract.

Covers the two bugfixes this PR makes to core/prefetch.py —

* cross-stream contamination: predictor state (stride/last/markov training)
  is keyed per stream, so interleaved callers never teach each other
  transitions that no single request stream ever makes;
* end-of-run accounting drift: prefetches still resident at teardown are
  charged as waste by finalized_stats()/finalize(), so accuracy is not
  inflated by run-end residency —

plus the trace-trained successor path (train_successors gates, predict_chain
chasing, fleet pooling through train_fleet_successors / TierEpoch) and a
differential oracle pinning the vectorized ``access_many`` batch contract
against a plain-Python reimplementation.
"""
import collections

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core.memtrace import TraceWindow
from repro.core.prefetch import PrefetchEngine, PrefetchStats, train_successors
from repro.fleet import aggregator
from repro.fleet.replica import ReplicaProfile


def _window(blocks, streams=None, start=0):
    b = np.asarray(blocks, np.int64)
    s = None if streams is None else np.asarray(streams, np.int64)
    return TraceWindow(start, b, np.zeros(b.size, bool), s)


# ---------------------------------------------------------------------------
# satellite 1: per-stream predictor state (the contamination regression)


def test_interleaved_strided_streams_both_predict():
    """Two strided walks interleaved through one engine, tagged by stream:
    each keeps its own stride and both get covered. The pre-fix engine
    folded them into one global stream whose apparent stride was the
    inter-stream jump, covering neither."""
    eng = PrefetchEngine(predictor="stride", buffer_blocks=256, degree=2)
    a = [100 + 2 * i for i in range(64)]   # stride 2
    b = [9000 + 3 * i for i in range(64)]  # stride 3
    for x, y in zip(a, b):
        eng.access(x, is_far=True, stream="a")
        eng.access(y, is_far=True, stream="b")
    s = eng.finalized_stats()
    assert eng._streams["a"].stride == 2
    assert eng._streams["b"].stride == 3
    # after the stride locks (2 accesses) every subsequent access on each
    # stream is covered by the previous access's prefetch
    assert s.coverage > 0.9, s
    assert s.demand_fetches <= 4, s


def test_aggregate_stream_regression_guard():
    """The same interleaved traffic pushed through ONE stream id (the old
    broken behavior) must do strictly worse than the tagged run — this is
    the regression the per-stream fix exists to prevent coming back."""

    def run(tagged: bool) -> PrefetchStats:
        eng = PrefetchEngine(predictor="stride", buffer_blocks=256, degree=2)
        for i in range(64):
            eng.access(100 + 2 * i, is_far=True, stream="a" if tagged else 0)
            eng.access(9000 + 3 * i, is_far=True, stream="b" if tagged else 0)
        return eng.finalized_stats()

    good, bad = run(tagged=True), run(tagged=False)
    assert good.coverage > bad.coverage
    assert good.demand_fetches < bad.demand_fetches


def test_markov_trains_within_stream_only():
    """Interleaving A: x->y repeated with B: p->q repeated must not create
    cross-stream edges like y->p in the shared markov table."""
    eng = PrefetchEngine(predictor="markov", buffer_blocks=64, degree=1)
    for _ in range(8):
        eng.access(10, is_far=True, stream="A")
        eng.access(70, is_far=True, stream="B")
        eng.access(11, is_far=True, stream="A")
        eng.access(71, is_far=True, stream="B")
    assert set(eng._markov[10]) == {11}
    assert set(eng._markov[70]) == {71}
    assert 70 not in eng._markov[11]  # the interleave-order edge
    assert 10 not in eng._markov[71]


def test_drop_stream_forgets_training_tail():
    eng = PrefetchEngine(predictor="stride")
    eng.access(5, is_far=False, stream=3)
    assert 3 in eng._streams
    eng.drop_stream(3)
    assert 3 not in eng._streams
    eng.drop_stream(3)  # idempotent


# ---------------------------------------------------------------------------
# satellite 2: end-of-run accounting


def test_finalized_charges_resident_unused():
    eng = PrefetchEngine(predictor="nextline", buffer_blocks=64, degree=2)
    eng.access(10, is_far=True)  # issues 11, 12; neither consumed
    assert eng.resident_unused() == 2
    live = eng.stats
    fin = eng.finalized_stats()
    assert fin.unused_evicted == live.unused_evicted + 2
    assert fin.total_prefetched == live.total_prefetched
    # non-destructive: live books and buffer untouched, second call agrees
    assert eng.resident_unused() == 2
    assert eng.finalized_stats() == fin
    # finalized books balance: every prefetch is used or wasted
    assert fin.used_prefetches + fin.unused_evicted == fin.total_prefetched


def test_finalize_flushes_buffer():
    eng = PrefetchEngine(predictor="nextline", buffer_blocks=64, degree=2)
    eng.access(10, is_far=True)
    s = eng.finalize()
    assert eng.resident_unused() == 0
    assert s.unused_evicted == 2
    assert s is eng.stats  # finalize mutates the live books


def test_consume_on_use_one_prefetch_covers_one_miss():
    eng = PrefetchEngine(predictor="nextline", buffer_blocks=64, degree=1)
    eng.access(0, is_far=True)            # demand fetch; issues 1
    assert eng.access(1, is_far=True)     # covered, prefetch consumed
    eng2 = PrefetchEngine(predictor="off", buffer_blocks=64)
    eng2.mark_prefetched([7])
    assert eng2.access(7, is_far=True)
    assert not eng2.access(7, is_far=False)  # already spent
    assert eng2.stats.used_prefetches == 1


def test_evict_counts_as_waste():
    eng = PrefetchEngine(predictor="off", buffer_blocks=64)
    eng.mark_prefetched([1, 2, 3])
    assert eng.evict([2, 99]) == 1  # only pending entries count
    assert eng.stats.unused_evicted == 1
    assert eng.resident_unused() == 2


# ---------------------------------------------------------------------------
# property tests (hypothesis when available, deterministic replay otherwise)


@settings(max_examples=40)
@given(
    st.lists(st.integers(min_value=0, max_value=64), min_size=1, max_size=200),
    st.sampled_from(["nextline", "stride", "markov", "trace", "off"]),
)
def test_books_invariants(blocks, predictor):
    eng = PrefetchEngine(predictor=predictor, buffer_blocks=16, degree=2)
    eng.load_successors({i: (i + 3,) for i in range(0, 64, 2)})
    for i, b in enumerate(blocks):
        eng.access(int(b), is_far=bool(b % 2), stream=i % 3)
    live, fin = eng.stats, eng.finalized_stats()
    assert live.used_prefetches + live.unused_evicted <= live.total_prefetched
    assert fin.used_prefetches + fin.unused_evicted == fin.total_prefetched
    for s in (live, fin):
        assert 0.0 <= s.accuracy <= 1.0
        assert 0.0 <= s.coverage <= 1.0
        if s.total_prefetched + s.demand_fetches > 0:
            assert s.bw_overhead >= 0.0
    assert eng.resident_unused() <= eng.capacity


@settings(max_examples=40)
@given(st.lists(st.integers(min_value=0, max_value=40), min_size=2, max_size=120))
def test_access_many_books_match_scalar_totals(blocks):
    """Fresh (never re-read) batches through access_many keep the same
    invariants as the scalar path; totals stay balanced after finalize."""
    b = np.asarray(blocks, np.int64)
    far = (b % 3 == 0)
    eng = PrefetchEngine(predictor="nextline", buffer_blocks=16, degree=2)
    for i in range(0, b.size, 7):
        eng.access_many(b[i : i + 7], far[i : i + 7], stream=i % 2)
    s = eng.finalize()
    assert s.used_prefetches + s.unused_evicted == s.total_prefetched


# ---------------------------------------------------------------------------
# satellite 3/4: the vectorized batch contract, pinned by a plain oracle


def _oracle_access_many(eng, blocks, far_mask, stream):
    """Plain-Python reimplementation of the documented access_many
    contract: probe the whole batch first (unique hits consume), then train
    and issue only on the suffix past the stream's previous batch."""
    b = [int(x) for x in np.asarray(blocks).reshape(-1)]
    f = list(np.broadcast_to(np.asarray(far_mask, bool).reshape(-1), (len(b),)))
    hits = [blk in eng.buffer for blk in b]
    covered = sum(hits)
    eng.stats.demand_fetches += sum(1 for h, fl in zip(hits, f) if fl and not h)
    for blk in sorted({blk for blk, h in zip(b, hits) if h}):
        eng._consume(blk)
    stt = eng._stream(stream)
    prev = stt.tail
    k = 0
    if prev is not None and prev.size and len(b) >= prev.size and list(prev) == b[: prev.size]:
        k = int(prev.size)
    stt.tail = np.asarray(b, np.int64)
    if k == len(b):
        return covered
    new = b[k:]
    if k == 0 and stt.last is None:
        srcs, dsts = new[:-1], new[1:]
    else:
        last = stt.last if k == 0 else int(prev[-1])
        srcs, dsts = [last] + new[:-1], list(new)
    for a_, b_ in zip(srcs, dsts):
        if a_ != b_:
            eng._markov[a_][b_] += 1
    if srcs:
        stt.stride = (dsts[-1] - srcs[-1]) or stt.stride
    stt.last = new[-1]
    for blk in new:
        for p in eng._predict(blk, stt):
            if p >= 0:
                eng._insert(p)
    return covered


def _observable(eng):
    return (
        dataclasses_tuple(eng.stats),
        list(eng.buffer.keys()),
        {
            sid: (s.last, s.stride, None if s.tail is None else tuple(s.tail.tolist()))
            for sid, s in eng._streams.items()
        },
        {k: dict(v) for k, v in eng._markov.items()},
    )


def dataclasses_tuple(s):
    return (s.total_prefetched, s.unused_evicted, s.used_prefetches, s.demand_fetches)


@pytest.mark.parametrize("predictor", ["nextline", "stride", "markov", "trace"])
def test_access_many_differential_oracle(predictor):
    """Randomized decode-like traffic (growing re-read walks + fresh
    batches, several streams) through the vectorized path and the oracle:
    stats, buffer contents AND order (LRU state), and per-stream training
    state must agree after every single batch."""
    rng = np.random.default_rng(42)
    table = {i: (int(rng.integers(0, 256)),) for i in range(0, 256, 3)}
    vec = PrefetchEngine(predictor=predictor, buffer_blocks=32, degree=2)
    ref = PrefetchEngine(predictor=predictor, buffer_blocks=32, degree=2)
    vec.load_successors(table)
    ref.load_successors(table)
    walks = {s: list(rng.integers(0, 256, size=4)) for s in range(3)}
    for step in range(80):
        s = int(rng.integers(0, 3))
        kind = rng.random()
        if kind < 0.6:  # decode step: re-read the walk, grown by 0-2 pages
            walks[s] += [int(x) for x in rng.integers(0, 256, size=int(rng.integers(0, 3)))]
            batch = np.asarray(walks[s], np.int64)
        elif kind < 0.8:  # fresh walk (new request admitted to the slot)
            walks[s] = [int(x) for x in rng.integers(0, 256, size=int(rng.integers(1, 8)))]
            batch = np.asarray(walks[s], np.int64)
        else:  # arbitrary batch (no prefix relation)
            batch = rng.integers(0, 256, size=int(rng.integers(1, 12))).astype(np.int64)
        far = rng.random(batch.size) < 0.5
        got = vec.access_many(batch, far, stream=s)
        want = _oracle_access_many(ref, batch, far, stream=s)
        assert got == want, (step, got, want)
        assert _observable(vec) == _observable(ref), step
    assert vec.finalized_stats() == ref.finalized_stats()


def test_access_many_prefix_skip_trains_suffix_only():
    """A decode step re-reads its whole walk: only the new page may train
    or issue, and the unchanged prefix must not inflate markov counts."""
    eng = PrefetchEngine(predictor="markov", buffer_blocks=64, degree=1)
    walk = [5, 9, 2]
    eng.access_many(np.asarray(walk), np.zeros(3, bool), stream=0)
    for nxt in (17, 23, 31):
        walk.append(nxt)
        eng.access_many(np.asarray(walk), np.zeros(len(walk), bool), stream=0)
    # each edge trained exactly once despite the walk being re-read 4x
    for a, b in zip([5, 9, 2, 17, 23], [9, 2, 17, 23, 31]):
        assert eng._markov[a][b] == 1, (a, b, eng._markov[a])
    # pure re-read: nothing changes
    before = eng.stats.total_prefetched
    eng.access_many(np.asarray(walk), np.zeros(len(walk), bool), stream=0)
    assert eng.stats.total_prefetched == before


def test_access_many_probe_all_first():
    """A prefetch issued by a batch cannot cover a later element of the
    SAME batch — coverage is decided for the whole batch up front."""
    eng = PrefetchEngine(predictor="nextline", buffer_blocks=64, degree=1)
    covered = eng.access_many(np.asarray([10, 11, 12]), np.ones(3, bool), stream=0)
    assert covered == 0  # 10 issued 11, but 11's probe already happened
    assert eng.stats.demand_fetches == 3
    # the issued prefetches cover the NEXT batch
    covered = eng.access_many(np.asarray([10, 11, 12, 13]), np.ones(4, bool), stream=0)
    assert covered > 0


# ---------------------------------------------------------------------------
# trace training: gates, per-stream extraction, chain prediction


def test_train_successors_learns_chain_exactly():
    chain = [7, 301, 12, 988, 45]
    blocks = chain * 5
    table = train_successors([_window(blocks)])
    for a, b in zip(chain, chain[1:]):
        assert table[a][0] == b
    # scattered ids: nothing nextline-like invented
    assert 8 not in table.get(7, ())


def test_train_successors_per_stream_and_no_self():
    # A walks 1->2->1->2..., B walks 50->60; interleaved in one window
    blocks = [1, 50, 2, 60, 1, 50, 2, 60, 1, 50, 2, 60]
    streams = [0, 1, 0, 1, 0, 1, 0, 1, 0, 1, 0, 1]
    table = train_successors([_window(blocks, streams)])
    assert 2 in table[1] and 50 not in table.get(1, ())
    assert 60 in table[50] and 2 not in table.get(50, ())
    # self-transitions dropped
    t2 = train_successors([_window([4, 4, 4, 4, 4])])
    assert t2 == {}


def test_train_successors_confidence_gates():
    # seen once -> below min_count
    assert train_successors([_window([1, 2])]) == {}
    # 2 sightings of 1->2 but diluted below min_frac by other successors
    blocks = [1, 2, 1, 2]
    for x in range(100, 110):
        blocks += [1, x]
    table = train_successors([_window(blocks)], min_count=2, min_frac=0.3)
    assert 1 not in table  # 2/12 of the mass < 0.3
    # raise the share -> passes
    table = train_successors([_window([1, 2] * 6 + [1, 99])], min_frac=0.3)
    assert table[1] == (2,)


def test_train_successors_windows_do_not_chain():
    # window 1 ends at 7, window 2 starts at 8 (same stream id): the edge
    # 7->8 must not appear even across many window pairs
    ws = []
    for _ in range(4):
        ws.append(_window([3, 7]))
        ws.append(_window([8, 4]))
    table = train_successors(ws)
    assert 8 not in table.get(7, ())
    assert table[3] == (7,) and table[8] == (4,)


def test_predict_chain_chases_and_cuts_cycles():
    eng = PrefetchEngine(predictor="trace", degree=1)
    eng.load_successors({1: (5,), 5: (9,), 9: (3,)})
    assert eng.predict_chain(1, lookahead=3) == [5, 9, 3]
    assert eng.predict_chain(1, lookahead=2) == [5, 9]
    eng.load_successors({1: (5,), 5: (1,)})
    assert eng.predict_chain(1, lookahead=10) == [5]  # cycle cut, terminates
    assert eng.predict_chain(777, lookahead=4) == []  # untrained block


def test_trace_predictor_has_no_fallback():
    """An empty table must issue NOTHING — the no-heuristic property that
    keeps the trace predictor's wasted bandwidth at or below baselines."""
    eng = PrefetchEngine(predictor="trace", buffer_blocks=64, degree=2)
    for b in range(50):
        eng.access(b, is_far=True)
    assert eng.stats.total_prefetched == 0
    assert eng.stats.demand_fetches == 50


def test_load_successors_merge_semantics():
    eng = PrefetchEngine(predictor="trace")
    eng.load_successors({1: (2,), 3: (4,)})
    eng.load_successors({3: (9,), 5: (6,)}, merge=True)
    assert eng._successors == {1: (2,), 3: (9,), 5: (6,)}
    eng.load_successors({7: (8,)})  # wholesale replace
    assert eng._successors == {7: (8,)}


# ---------------------------------------------------------------------------
# fleet plumbing: pooled training and epoch shipping


def _profile(rid, windows):
    return ReplicaProfile(
        rid=rid, counts=np.zeros(16, np.int64), windows=windows,
        reads=0, writes=0, live_hit_ratio=0.0, live_accesses=0,
        live_capacity=4, near_hit_rate=0.0,
    )


def test_fleet_pooling_beats_per_host_tables():
    """Each host saw a transition ONCE — below min_count locally, but the
    fleet pool crosses the gate. This is why the aggregator retrains on
    pooled windows instead of merging per-host tables."""
    w0, w1 = _window([11, 12], streams=[0, 0]), _window([11, 12], streams=[0, 0])
    assert train_successors([w0]) == {}  # one sighting: below the gate
    table = aggregator.train_fleet_successors([_profile(0, [w0]), _profile(1, [w1])])
    # fleet tables are tenant-partitioned; untagged streams train ""
    assert table[""][11] == (12,)


def test_fleet_pooling_namespaces_streams_per_host():
    """Both hosts use engine stream id 0; without the rid namespace their
    windows' streams would collide. The logical BLOCK space stays shared
    (that is the point), but no spurious same-stream edges appear."""
    p0 = _profile(0, [_window([1, 2, 1, 2], streams=[0, 0, 0, 0])])
    p1 = _profile(1, [_window([7, 8, 7, 8], streams=[0, 0, 0, 0])])
    table = aggregator.train_fleet_successors([p0, p1])[""]
    assert table[1] == (2,) and table[7] == (8,)
    assert 7 not in table.get(2, ())


def test_tier_epoch_ships_prefetch_table():
    from repro.fleet.autotier import TierEpoch

    ep = TierEpoch(
        fleet_step=0, near_ids=np.zeros(0, np.int64), near_hit_frac=0.0,
        migrated_pages=0, overlap_prev=1.0,
        prefetch_table={"web": {3: (4,)}},
    )
    assert ep.prefetch_table["web"][3] == (4,)


# ---------------------------------------------------------------------------
# tenant-partitioned prefetch: table isolation + fair-share buffer


def test_train_tenant_successors_partitions_by_stream_tenant():
    from repro.core.prefetch import train_tenant_successors

    # tenant A (stream 0) walks 1->2, tenant B (stream 1) walks 7->8; both
    # twice so each crosses the min_count gate within its own partition
    w = _window([1, 7, 2, 8, 1, 7, 2, 8], streams=[0, 1, 0, 1, 0, 1, 0, 1])
    tables = train_tenant_successors([w], {0: "A", 1: "B"})
    assert tables["A"] == {1: (2,)}
    assert tables["B"] == {7: (8,)}
    # unmapped streams train the default "" partition, and empty
    # partitions are dropped rather than shipped
    tables = train_tenant_successors([w], {0: "A"})
    assert tables["A"] == {1: (2,)}
    assert tables[""] == {7: (8,)}
    assert set(tables) == {"A", ""}


def test_trace_predictions_come_from_own_tenant_table_only():
    eng = PrefetchEngine(predictor="trace", buffer_blocks=64, degree=2)
    eng.load_successors({"A": {1: (2,)}, "B": {1: (9,)}})
    eng.set_stream_partition(10, "A")
    eng.set_stream_partition(11, "B")
    assert eng.predict_chain(1, stream=10, lookahead=1) == [2]
    assert eng.predict_chain(1, stream=11, lookahead=1) == [9]
    # a stream with no partition reads the default table — empty here
    assert eng.predict_chain(1, stream=12, lookahead=1) == []
    # explicit partition override (queued requests with no stream yet)
    assert eng.predict_chain(1, stream=-1, lookahead=1, partition="B") == [9]


def test_fair_share_eviction_protects_under_share_tenant():
    """The interference fix: tenant B holds 2 pending prefetches (under its
    fair share of a 8-entry buffer); tenant A floods 20 more. Every
    overflow eviction must land on A's own entries — B's survive until B's
    demand accesses consume them."""
    eng = PrefetchEngine(predictor="trace", buffer_blocks=8)
    eng.mark_prefetched([100, 101], partitions="B")
    eng.mark_prefetched(list(range(20)), partitions="A")
    assert len(eng.buffer) == 8
    assert 100 in eng.buffer and 101 in eng.buffer
    assert eng._part_sizes == {"A": 6, "B": 2}
    # B's entries still cover B's demand accesses
    eng.set_stream_partition(1, "B")
    assert eng.access(100, is_far=True, stream=1)
    assert eng.access(101, is_far=True, stream=1)
    assert eng.stats.used_prefetches == 2


def test_over_share_inserter_pays_for_its_own_overflow():
    """When the inserting tenant is over its fair share, IT pays — oldest
    entry first — rather than pushing the cost onto its neighbor."""
    eng = PrefetchEngine(predictor="trace", buffer_blocks=4)
    eng.mark_prefetched([50], partitions="B")
    eng.mark_prefetched([0, 1, 2], partitions="A")  # full: A=3 > 4/2, B=1
    eng.mark_prefetched([3], partitions="A")
    assert 50 in eng.buffer  # B untouched
    assert 0 not in eng.buffer  # A's oldest evicted
    assert set(eng.buffer) == {50, 1, 2, 3}
    assert eng.stats.unused_evicted == 1


def test_partition_sizes_track_consume_evict_finalize():
    eng = PrefetchEngine(predictor="trace", buffer_blocks=8)
    eng.mark_prefetched([1, 2], partitions="A")
    eng.mark_prefetched([3], partitions="B")
    eng.set_stream_partition(0, "A")
    eng.access(1, is_far=True, stream=0)  # consume
    assert eng._part_sizes == {"A": 1, "B": 1}
    eng.evict([3])  # demotion eviction
    assert eng._part_sizes == {"A": 1}
    eng.finalize()
    assert eng._part_sizes == {}
    assert eng.stats.unused_evicted == 2  # evicted 3 + resident 2
