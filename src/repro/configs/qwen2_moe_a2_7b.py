"""qwen2-moe-a2.7b [moe] — 60 routed experts top-4 + 4 shared.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    grad_accum=4,
    moe_group=1024,  # §Perf hillclimb: capacity state is O(k t^2)/group
    pooling_cluster=4,
    qkv_bias=True,
    n_experts=60,
    n_shared_experts=4,
    top_k=4,
    moe_d_ff=1408,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B; hf",
)
