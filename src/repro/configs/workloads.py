"""The paper's nine production microservices as serving-workload profiles.

Each profile parameterizes a request stream for the serving engine: prompt
prefix sharing (Web services share page templates -> shared KV prefixes),
access skew over state blocks (Zipf alpha), request length distributions,
and read/write mix. Alphas are set so the measured bandwidth distributions
land where the paper's Fig. 9/18 put each service (e.g. Reader's near-tier
hit fraction ~0.81 at a 37.5% capacity split, Table 5).

These drive benchmarks/fig9, fig17, fig18, table5, fig21, fig22, table6.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class WorkloadProfile:
    name: str
    zipf_alpha: float  # skew of block accesses (embedding/KV/expert streams)
    prefix_share: float  # probability a request reuses a shared prompt prefix
    n_prefixes: int  # size of the shared-prefix pool
    prompt_mean: int  # prompt length (tokens)
    decode_mean: int  # decode length (tokens)
    rw_ratio: float  # target read:write ratio (paper Table 6 scale)
    frontend_bound: float  # fraction of stalls that are code-fetch (Fig. 7)
    n_blocks: int = 4096  # profiled state blocks
    seq_jump: float = 0.4  # P(break the sequential run) per access: low =
    # predictable stream (Ads1 inference), high = random KV lookups (Cache)


# values follow the qualitative placement of Fig. 7 + Table 2/6:
# Web1/Web2: highly frontend bound, huge shared templates;
# Cache1/2: Zipfian key-value skew, Cache1 splits workload/NIC cores;
# Ads: mixed, inference-like predictable streams (Ads1 prefetches well);
# Feed: balanced; Reader: most backend/bandwidth bound (the Table 5 subject).
PROFILES: dict[str, WorkloadProfile] = {
    "Web1": WorkloadProfile("Web1", 1.25, 0.85, 32, 512, 64, 1.72, 0.35, n_blocks=8192, seq_jump=0.5),
    "Web2": WorkloadProfile("Web2", 1.22, 0.80, 64, 384, 96, 1.70, 0.33, n_blocks=8192, seq_jump=0.5),
    "Ads1": WorkloadProfile("Ads1", 1.15, 0.30, 128, 256, 32, 1.90, 0.15, n_blocks=8192, seq_jump=0.08),
    "Ads2": WorkloadProfile("Ads2", 1.12, 0.35, 128, 256, 48, 1.85, 0.18, n_blocks=8192, seq_jump=0.4),
    "Ads3": WorkloadProfile("Ads3", 1.10, 0.25, 256, 192, 48, 1.80, 0.20, n_blocks=8192, seq_jump=0.45),
    "Cache1": WorkloadProfile("Cache1", 1.30, 0.10, 512, 64, 8, 1.84, 0.22, n_blocks=8192, seq_jump=0.85),
    "Cache2": WorkloadProfile("Cache2", 1.28, 0.10, 512, 64, 8, 1.95, 0.30, n_blocks=8192, seq_jump=0.8),
    "Feed": WorkloadProfile("Feed", 1.15, 0.45, 96, 320, 64, 2.14, 0.25, n_blocks=8192, seq_jump=0.55),
    # Reader's alpha is CALIBRATED: at the 37.5% near split it must serve
    # ~82% of traffic from the near tier (paper Table 5's measured 84.6 vs
    # 19.2 GiB/s split) — that is what lands Tiered at 1.46x.
    "Reader": WorkloadProfile("Reader", 0.86, 0.20, 256, 448, 96, 1.60, 0.08, n_blocks=4096, seq_jump=0.55),
}


def get_profile(name: str) -> WorkloadProfile:
    return PROFILES[name]
