"""Oracle: sequential WKV6 recurrence (same math as models/rwkv6.wkv6)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, lw, u, state=None):
    """r/k/v: (B, T, H, hd) f32; lw: log-decay (B, T, H, hd) (<= 0); u: (H, hd).

    Returns (y (B,T,H,hd), final_state (B,H,hd,hd)).
    S_t = diag(w_t) S_{t-1} + k_t v_t^T ;  y_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
    """
    b, t, h, hd = r.shape
    w = jnp.exp(lw.astype(jnp.float32))
    if state is None:
        state = jnp.zeros((b, h, hd, hd), jnp.float32)

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None, :, :, None] * kv)
        s = w_t[..., :, None] * s + kv
        return s, y

    xs = tuple(a.transpose(1, 0, 2, 3).astype(jnp.float32) for a in (r, k, v, w))
    state, ys = jax.lax.scan(step, state, xs)
    return ys.transpose(1, 0, 2, 3), state
