"""Multi-tenant fleet serving: per-tenant SLOs, per-tenant MemProf streams,
weighted-fair dispatch, and the co-location interference study.

Acceptance (ISSUE 2): two tenants through one fleet get independent shed
accounting; per-tenant aggregated histograms sum to the combined histogram;
the interference benchmark reports solo-vs-colocated near-hit degradation
deterministically under a fixed seed.
"""
import dataclasses
import pathlib
import sys
from collections import deque

import numpy as np
import pytest

from repro.configs.workloads import get_profile
from repro.data.requests import Request, RequestGenerator, interleave
from repro.fleet import (
    AdmissionController,
    SLOModel,
    aggregate_counts,
    aggregate_tenant_counts,
    build_fleet,
    export_all,
    fleet_report,
    fleet_vocab,
)

# the interference benchmark is importable the same way benchmarks/run.py
# loads it (benchmarks/ is a script dir, not a package)
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "benchmarks"))
import tenant_interference  # noqa: E402


def _profile(**kw):
    base = dict(prompt_mean=16, decode_mean=6, prefix_share=0.8, n_prefixes=3)
    base.update(kw)
    return dataclasses.replace(get_profile("Web1"), **base)


def _two_tenant_gens(seed=0):
    web = RequestGenerator(
        _profile(), vocab_size=fleet_vocab(), seed=seed, rate=8.0, tenant="web"
    )
    cache = RequestGenerator(
        _profile(prefix_share=0.0, prompt_mean=8, decode_mean=4),
        vocab_size=fleet_vocab(), seed=seed + 1, rate=32.0, tenant="cache",
    )
    return [cache, web]


# ---------------------------------------------------------------------------
# tenant identity plumbing


def test_request_generator_stamps_tenant():
    gen = RequestGenerator(_profile(), vocab_size=64, seed=0, tenant="web")
    assert next(gen).tenant == "web"
    assert next(RequestGenerator(_profile(), vocab_size=64, seed=0)).tenant == "default"


def test_interleave_merges_by_arrival_with_unique_ids():
    reqs = interleave(_two_tenant_gens(), 40)
    assert [r.rid for r in reqs] == list(range(40))
    assert [r.arrival for r in reqs] == sorted(r.arrival for r in reqs)
    tenants = {r.tenant for r in reqs}
    assert tenants == {"web", "cache"}
    # the 4x-rate cache tenant dominates the time-ordered merge
    n_cache = sum(r.tenant == "cache" for r in reqs)
    assert n_cache > 20
    # prefix ids are namespaced per tenant: no cross-tenant aliasing
    web_pids = {r.prefix_id for r in reqs if r.tenant == "web" and r.prefix_id >= 0}
    cache_pids = {r.prefix_id for r in reqs if r.tenant == "cache" and r.prefix_id >= 0}
    assert not (web_pids & cache_pids)


# ---------------------------------------------------------------------------
# weighted-fair dispatch


def _fleet(**kw):
    base = dict(n_pages=128, trace_window=16, trace_period=32)
    base.update(kw)
    return build_fleet(2, policy="round-robin", **base)


def test_weighted_fair_dispatch_order():
    fleet = _fleet(tenant_weights={"web": 3.0, "cache": 1.0})
    for i in range(4):
        fleet.tenant_queues.setdefault("web", deque()).append(
            Request(i, np.zeros(4, np.int32), 2, -1, 0.0, "web")
        )
        fleet.tenant_queues.setdefault("cache", deque()).append(
            Request(10 + i, np.zeros(4, np.int32), 2, -1, 0.0, "cache")
        )
    assert fleet.dispatch(4) == 4
    # weight 3 tenant gets 3 of the first 4 picks (cache wins the vtime tie
    # on name, then web runs until its virtual time catches up)
    assert fleet.routed_by == {"cache": 1, "web": 3}
    assert fleet.dispatch() == 4  # drain the rest
    assert fleet.routed_by == {"cache": 4, "web": 4}
    assert fleet.queued() == 0


def test_equal_weights_alternate():
    fleet = _fleet()
    for i in range(3):
        fleet.tenant_queues.setdefault("a", deque()).append(
            Request(i, np.zeros(4, np.int32), 2, -1, 0.0, "a")
        )
        fleet.tenant_queues.setdefault("b", deque()).append(
            Request(10 + i, np.zeros(4, np.int32), 2, -1, 0.0, "b")
        )
    fleet.dispatch(4)
    assert fleet.routed_by == {"a": 2, "b": 2}


# ---------------------------------------------------------------------------
# queue-wait latency percentiles (virtual-time wait per tenant)


@pytest.mark.slow
def test_bursty_tenant_p99_does_not_inflate_neighbor():
    """A burst tenant's overload queues behind its own weighted-fair share:
    its p99 wait blows up, the well-behaved tenant's stays near zero."""
    fleet = _fleet()
    web = RequestGenerator(
        _profile(), vocab_size=fleet_vocab(), seed=0, rate=4.0, tenant="web"
    )
    burst = RequestGenerator(
        _profile(prefix_share=0.0), vocab_size=fleet_vocab(),
        seed=1, rate=64.0, tenant="burst",
    )
    reqs = interleave([web, burst], 48)
    fleet.run(iter(reqs), n_requests=48, max_steps=800, submit_per_step=8)
    rep = fleet.tenant_report()
    for t in ("web", "burst"):
        assert 0.0 <= rep[t]["wait_p50"] <= rep[t]["wait_p99"], rep[t]
    # the burst tenant actually queued (the test means something)...
    assert rep["burst"]["wait_p99"] > 1.0, rep["burst"]
    # ...but its backlog stayed its own: the neighbor's tail is a fraction
    assert rep["web"]["wait_p99"] <= 0.5 * rep["burst"]["wait_p99"], rep
    assert rep["web"]["wait_p99"] <= 2.0, rep["web"]


@pytest.mark.slow
def test_two_tenants_independent_shed_accounting():
    adm = AdmissionController(
        SLOModel(max_delay_steps=64.0),
        tenant_slos={"cache": SLOModel(max_delay_steps=4.0),
                     "web": SLOModel(max_delay_steps=1e6)},
    )
    fleet = _fleet(admission=adm)
    reqs = interleave(_two_tenant_gens(), 40)
    stats = fleet.run(iter(reqs), n_requests=40, max_steps=800)
    ts = adm.tenant_stats()
    assert set(ts) == {"web", "cache"}
    # the bursty, latency-tight tenant sheds; its neighbor does not
    assert ts["cache"]["shed"] > 0
    assert ts["web"]["shed"] == 0
    # per-tenant books balance and sum to the fleet totals
    for t in ts:
        assert ts[t]["offered"] == ts[t]["admitted"] + ts[t]["shed"]
        assert stats["tenants"][t]["shed"] == ts[t]["shed"]
    assert adm.shed == sum(v["shed"] for v in ts.values()) == stats["shed"]
    assert adm.offered == 40
    # everything admitted was served
    assert stats["requests_finished"] == stats["routed"] == adm.admitted


# ---------------------------------------------------------------------------
# acceptance: per-tenant histograms partition the combined histogram


@pytest.mark.slow
def test_tenant_histograms_sum_to_combined():
    fleet = _fleet(autotier=dict(near_frac=0.3, epoch_steps=8))
    reqs = interleave(_two_tenant_gens(), 24)
    fleet.run(iter(reqs), n_requests=24, max_steps=800, submit_per_step=2)
    profiles = export_all(fleet.replicas)
    by_tenant = aggregate_tenant_counts(profiles)
    assert set(by_tenant) == {"web", "cache"}
    combined = aggregate_counts(profiles)
    np.testing.assert_array_equal(
        np.sum([c for c in by_tenant.values()], axis=0), combined
    )
    # and per host, too
    for p in profiles:
        np.testing.assert_array_equal(
            np.sum([c for c in p.tenant_counts.values()], axis=0), p.counts
        )
    # fleet report exposes both per-tenant hotness views
    rep = fleet_report(profiles)
    assert set(rep["tenants"]) == {"web", "cache"}
    for t in rep["tenants"]:
        assert 0.0 <= rep["tenants"][t]["near_hit_rate"] <= 1.0
        assert rep["tenants"][t]["total_accesses"] > 0


@pytest.mark.slow
def test_autotier_reports_per_tenant_near_fracs():
    fleet = _fleet(autotier=dict(near_frac=0.3, epoch_steps=8))
    reqs = interleave(_two_tenant_gens(), 24)
    fleet.run(iter(reqs), n_requests=24, max_steps=800, submit_per_step=2)
    hist = fleet.autotierer.history
    assert hist
    last = hist[-1]
    assert set(last.tenant_near_frac) == {"web", "cache"}
    for frac in last.tenant_near_frac.values():
        assert 0.0 <= frac <= 1.0


# ---------------------------------------------------------------------------
# acceptance: interference benchmark is deterministic under a fixed seed


@pytest.mark.slow
def test_interference_benchmark_deterministic():
    kw = dict(seed=0, n_requests_solo=8, n_requests_colo=16)
    r1 = tenant_interference.run_study(**kw)
    r2 = tenant_interference.run_study(**kw)
    assert r1 == r2
    assert set(r1["near_hit_degradation"]) == {"web", "cache"}
    for v in r1["near_hit_degradation"].values():
        assert np.isfinite(v)
    for t, m in r1["colocated"].items():
        assert 0.0 <= m["near_hit_rate"] <= 1.0
        assert 0.0 <= m["shed_rate"] <= 1.0
