"""Paper Table 4/5 + Fig. 20: Baseline / Ideal / Tiered memory-BW tiering.

Reproduces the headline result with the paper's own constants (near tier =
2x BW at 2x cost, 37.5/62.5 capacity split, DDR knee calibrated to the
measured 67.8 GB/s on a 100 GB/s part) driven by the MEASURED Reader-profile
access distribution. Paper: Tiered = 1.46x throughput, 1.13x tput/cost,
within 6.32% of Ideal.
"""
import numpy as np

from repro.core import hw
from repro.core.tiering import ThroughputModel, evaluate_configs

from _common import fmt_table, run_workload, stream_for

PAPER = {"Baseline": (1.0, 1.0), "Ideal": (1.55, 0.73), "Tiered": (1.46, 1.13)}


def main(live_engine=True):
    # The paper numbers need the CALIBRATED Reader distribution over the
    # full 4096-block space — a reduced-scale engine's working set is far
    # too small to reproduce it (its whole footprint fits the Tiered near
    # capacity, collapsing Tiered onto Ideal). So the table is always
    # computed from the profile stream, and the live engine contributes a
    # device-executed cross-check: the same Reader traffic served with the
    # near/far split executed by the fused tiered-gather kernel, hit
    # counters produced in-kernel at the access point.
    device = None
    if live_engine:
        _, stats = run_workload(
            "Reader", n_requests=12, prompt=48, decode=12, device_tiering=True,
            near_frac=0.02,
        )
        device = stats["device_tiering"]
    stream, _ = stream_for("Reader", n=200_000)
    counts = np.bincount(stream, minlength=4096).astype(float)
    src = "Reader profile stream"
    res = evaluate_configs(
        counts,
        {"Baseline": hw.BASELINE, "Ideal": hw.IDEAL, "Tiered": hw.TIERED},
        ThroughputModel(),
    )
    rows = []
    for name, r in res.items():
        pt, pc = PAPER[name]
        rows.append(
            (
                name,
                f"{r['relative_throughput']:.3f}",
                f"{pt:.2f}",
                f"{r['throughput_per_cost']:.3f}",
                f"{pc:.2f}",
                r["bound"],
                f"{r['plan'].hit_fracs[0]:.3f}",
            )
        )
    print(f"[table5] source: {src}")
    if device is not None:
        print(
            f"[table5] device-executed decode cross-check (2% near tier): "
            f"near-hit {device['near_hit_rate']:.3f} "
            f"({device['near_hits']}/{device['far_hits']} near/far counted in-kernel)"
        )
    print(fmt_table(rows, ["config", "tput(x)", "paper", "tput/cost", "paper", "bound", "near-hit"]))
    gap = abs(res["Tiered"]["relative_throughput"] - res["Ideal"]["relative_throughput"]) / res[
        "Ideal"
    ]["relative_throughput"]
    print(f"Tiered within {gap*100:.2f}% of Ideal (paper: 6.32%)")
    return {name: r["relative_throughput"] for name, r in res.items()}


if __name__ == "__main__":
    main()
