"""One strict parser for every repro boolean env toggle.

``REPRO_KERNEL_INTERPRET``, ``REPRO_DEVICE_TIERING`` and
``REPRO_FLEET_LOCKSTEP`` all route through :func:`env_flag`: accepted
spellings are shared, and anything else raises so a typo'd CI line fails
loudly instead of silently testing the wrong path.
"""
from __future__ import annotations

import os
from typing import Optional

_TRUE = ("1", "true", "yes", "on")
_FALSE = ("0", "false", "no", "off")


def env_flag(var: str, default: Optional[bool] = None) -> Optional[bool]:
    """Strictly parse a boolean env var; ``default`` when unset."""
    env = os.environ.get(var)
    if env is None:
        return default
    if env.lower() in _TRUE:
        return True
    if env.lower() in _FALSE:
        return False
    raise ValueError(f"{var}={env!r}: expected one of {_TRUE + _FALSE}")
