"""Chaos study: goodput + tail queue-wait + per-tenant shed isolation under
a seeded kill/recover schedule vs the fault-free baseline.

Two tenants (steady web + bursty cache, the tenant_interference pair) share
a fleet that is then put through three deterministic scenarios:

* **baseline**     — no chaos engine attached (the plain event path);
* **zero-fault**   — a ChaosEngine with an EMPTY scenario: watchdogs armed,
  timeouts posted and cancelled, but nothing fires. Must be bit-exact with
  baseline — the equivalence the chaos machinery is built on;
* **kill-recover** — one replica crashes mid-burst and a replacement host
  joins after a fixed outage window (ElasticFleet scale-up, near tier
  pre-warmed from the fleet plan), plus a transient hang on a survivor.

Reported per scenario: goodput (decoded tokens per unit virtual time —
lost/discarded decode work never counts), per-tenant p99 queue wait and
shed rate (one tenant's burst landing in its own shed book, not its
neighbor's, even while a host is down), failovers, retries and the
quantified ``lost_tokens``.

Self-checks (process-style return code, like fleet_bench):
1. zero-fault chaos is bit-identical to baseline on the merged books;
2. the kill-recover scenario is a pure function of its seed (two runs,
   identical normalized stats + fault log);
3. no silent drops: every admitted rid ends completed/shed/failed, and
   ``lost_tokens`` equals the sum over crash lost_windows;
4. the crash actually cost something (>= 1 failover) and the fleet still
   finished every non-shed request.

Emits ``BENCH_chaos.json`` next to this file.

PYTHONPATH=src python -m benchmarks.run chaos_bench
"""
import dataclasses
import json
import pathlib

from repro.configs.workloads import get_profile
from repro.data.requests import RequestGenerator, interleave
from repro.fleet import (
    AdmissionController,
    ChaosEngine,
    FaultEvent,
    SLOModel,
    build_fleet,
    fleet_vocab,
)

from _common import fmt_table

N_REPLICAS = 3
N_REQUESTS = 24
SEED = 0

TENANTS = {
    "web": dict(
        base="Web1",
        overrides=dict(prompt_mean=24, decode_mean=8, prefix_share=0.9, n_prefixes=3),
        rate=8.0,
        slo=SLOModel(max_delay_steps=96.0),
    ),
    "cache": dict(
        base="Cache1",
        overrides=dict(prompt_mean=8, decode_mean=6, prefix_share=0.0, n_prefixes=4),
        rate=32.0,
        slo=SLOModel(max_delay_steps=12.0),
    ),
}

# the kill/recover schedule: one hard crash with a replacement host after a
# 6-unit outage, plus a 3-unit stall on a survivor that recovers before the
# watchdog (transient — no failover charge)
SCENARIO = [
    FaultEvent(6.0, "crash", rid=1, duration=6.0),
    FaultEvent(10.0, "hang", rid=0, duration=3.0),
]


def _build():
    return build_fleet(
        N_REPLICAS,
        policy="least-loaded",
        trace_window=16,
        trace_period=32,
        admission=AdmissionController(
            SLOModel(max_delay_steps=64.0),
            tenant_slos={t: TENANTS[t]["slo"] for t in TENANTS},
        ),
        autotier=dict(near_frac=0.30, epoch_steps=8),
        elastic=dict(min_replicas=1, max_replicas=N_REPLICAS + 1),
        seed=SEED,
    )


def _traffic(seed: int):
    gens = []
    for i, t in enumerate(sorted(TENANTS)):
        spec = TENANTS[t]
        prof = dataclasses.replace(get_profile(spec["base"]), **spec["overrides"])
        gens.append(
            RequestGenerator(
                prof, vocab_size=fleet_vocab(), seed=seed + i, rate=spec["rate"], tenant=t
            )
        )
    return iter(interleave(gens, N_REQUESTS))


def _norm(stats: dict) -> str:
    """Stable comparison surface: everything but the per-host breakdowns."""
    keep = {k: v for k, v in stats.items() if k not in ("per_replica", "retired_replicas")}
    return json.dumps(keep, sort_keys=True, default=str)


def run_cell(scenario, seed: int = SEED):
    fleet = _build()
    if scenario is not None:
        ChaosEngine(fleet, scenario, dispatch_timeout=8.0, max_retries=3)
    stats = fleet.run(_traffic(seed), n_requests=N_REQUESTS, max_steps=600, submit_per_step=3)
    return fleet, stats


def _row(name: str, stats: dict):
    tens = stats["tenants"]
    return (
        name,
        f"{stats['simulated_throughput']:.3f}",
        stats["requests_finished"],
        stats["requests_failed"],
        stats["failovers"],
        stats["lost_tokens"],
        " ".join(f"{t}={ts['wait_p99']:.1f}" for t, ts in sorted(tens.items())),
        " ".join(f"{t}={ts['shed_rate']:.2f}" for t, ts in sorted(tens.items())),
    )


def main():
    base_fleet, base = run_cell(None)
    zero_fleet, zero = run_cell([])
    kill_fleet, kill = run_cell(SCENARIO)

    rows = [_row("baseline", base), _row("zero-fault chaos", zero), _row("kill-recover", kill)]
    print("chaos study: seeded kill/recover vs fault-free baseline")
    print(
        fmt_table(
            rows,
            ("scenario", "goodput", "done", "failed", "failovers", "lost-tok", "wait-p99", "shed-rate"),
        )
    )

    failures = []
    # 1. zero-fault chaos config is bit-exact with the plain event path
    if _norm(base) != _norm(zero):
        failures.append("zero-fault chaos diverged from baseline books")
    # 2. kill-recover is a pure function of the seed
    refleet, rekill = run_cell(SCENARIO)
    if _norm(kill) != _norm(rekill) or kill_fleet.chaos.log != refleet.chaos.log:
        failures.append("kill-recover scenario not deterministic under its seed")
    # 3. no silent drops + lost-token reconciliation
    rep = kill_fleet.outcome_report()
    if not rep["complete"]:
        failures.append(f"unresolved requests after recovery: {rep['pending']}")
    lw_lost = sum(w.get("lost_decode_tokens", 0) for w in kill["lost_windows"])
    if kill["lost_tokens"] != lw_lost:
        failures.append(
            f"lost_tokens {kill['lost_tokens']} != lost_window sum {lw_lost}"
        )
    # 4. the crash cost something and the fleet absorbed it
    if kill["failovers"] < 1:
        failures.append("kill scenario produced no failover")
    shed = rep["outcomes"].get("shed", 0)
    done = rep["outcomes"].get("completed", 0) + rep["outcomes"].get("failed", 0)
    if shed + done != rep["offered"]:
        failures.append("outcome ledger does not partition the offered set")

    out = {
        "baseline": json.loads(_norm(base)),
        "zero_fault": json.loads(_norm(zero)),
        "kill_recover": json.loads(_norm(kill)),
        "fault_log": [list(e) for e in kill_fleet.chaos.log],
        "self_check_failures": failures,
    }
    path = pathlib.Path(__file__).resolve().parent / "BENCH_chaos.json"
    path.write_text(json.dumps(out, indent=1, default=str))
    print(f"\nwrote {path}")

    if failures:
        for f in failures:
            print(f"chaos_bench: FAIL ({f})")
        return 1
    print("chaos_bench ok")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
