import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count on first
init, and the production meshes need 512 placeholder host devices. Nothing
here allocates real data — inputs are ShapeDtypeStructs and only
``.lower().compile()`` runs.

Per cell this records, to ``experiments/dryrun/<mesh>/<arch>__<shape>.json``:
  * ``memory_analysis()``  -> per-device argument/output/temp/peak bytes
                              (proves the cell fits 16 GiB HBM per chip);
  * ``cost_analysis()``    -> per-device HLO FLOPs and bytes accessed;
  * collective bytes       -> parsed from the post-SPMD HLO text
                              (``hlo_analysis``: trip-counted through scans);
  * the three roofline terms (``launch/roofline.py``).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --mesh single        # 40 cells
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi         # pod axis
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --list
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, applicable_shapes, get_config, list_archs, skipped_shapes
from repro.core import hw, pooling
from repro.launch import hlo_analysis, roofline as rl
from repro.launch.mesh import activate, make_production_mesh, spec as mk_spec
from repro.models.api import get_model, make_prefill_step, make_serve_step, make_train_step
from repro.optim import AdamWConfig, adamw_init

HBM_BUDGET = hw.HBM_BYTES


# ---------------------------------------------------------------------------
# sharding helpers


def _fit_spec(spec_tuple, aval, mesh) -> P:
    """PartitionSpec for one leaf: drop axes absent from the mesh or not
    dividing the dimension (e.g. batch=1 on a 16-way data axis)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for i, a in enumerate(tuple(spec_tuple)):
        if a is None or i >= len(aval.shape):
            out.append(None)
            continue
        parts = a if isinstance(a, tuple) else (a,)
        kept = tuple(n for n in parts if n in sizes)
        total = 1
        for n in kept:
            total *= sizes[n]
        if not kept or aval.shape[i] % total != 0:
            out.append(None)
        else:
            out.append(kept if isinstance(a, tuple) else kept[0])
    return P(*out)


def tree_shardings(mesh, specs, avals):
    """NamedShardings for a pytree of spec-tuples against abstract values."""
    return jax.tree.map(
        lambda s, a: NamedSharding(mesh, _fit_spec(s, a, mesh)),
        specs,
        avals,
        is_leaf=lambda s: isinstance(s, tuple) and all(
            x is None or isinstance(x, (str, tuple)) for x in s
        ),
    )


def _spec_like(avals, spec_fn):
    """Build a spec tree with the same structure as ``avals``."""
    return jax.tree.map(spec_fn, avals)


def _as_bf16(avals):
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, jnp.bfloat16)
        if jnp.issubdtype(a.dtype, jnp.floating)
        else a,
        avals,
    )


# ---------------------------------------------------------------------------
# one cell


@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    ok: bool
    seconds_lower: float = 0.0
    seconds_compile: float = 0.0
    memory: Optional[dict] = None
    cost: Optional[dict] = None
    collectives: Optional[dict] = None
    roofline: Optional[dict] = None
    error: Optional[str] = None
    pooled: int = 0

    def as_dict(self):
        return dataclasses.asdict(self)


def _attn_kernel_bytes(cfg, sh, *, model_axis: int = 16, dp: int = 16) -> float:
    """Per-device HBM bytes of the Pallas flash kernel for this cell.

    The kernel streams q/k/v once and writes o (fwd); the backward re-reads
    q/k/v/o/do and writes dq/dk/dv; under remat the forward runs twice. All
    score/prob traffic stays in VMEM — that is the kernel's entire point and
    the delta vs. the reference HLO's tagged traffic.
    """
    if cfg.family == "ssm" or sh.kind == "decode":
        return 0.0  # no chunked-attention region in these cells
    heads_shard = model_axis if (cfg.n_heads % model_axis == 0 and cfg.n_kv_heads % model_axis == 0) else 1
    b_dev = max(sh.global_batch // dp, 1)
    L = sh.seq_len
    hd = cfg.head_dim
    qb = b_dev * (cfg.n_heads // heads_shard) * L * hd * 2.0  # bf16
    kb = b_dev * (cfg.n_kv_heads // heads_shard) * L * hd * 2.0
    n_attn_layers = cfg.n_layers if cfg.family != "hybrid" else max(
        cfg.n_layers // max(cfg.shared_attn_every, 1), 1
    )
    fwd = qb + 2 * kb + qb  # q + k + v + o
    if sh.kind == "train":
        bwd = 2 * (qb + 2 * kb) + 2 * qb + (qb + 2 * kb)  # reads + do/o + grads
        per_layer = 2 * fwd + bwd  # remat: fwd twice
    else:
        per_layer = fwd
    return n_attn_layers * per_layer


def _collect_params_shardings(api, mesh, pool: int, serve: bool):
    """(abstract_params, shardings, storage_specs). Serve cells use bf16."""
    cfg = api.cfg
    aparams = api.abstract_params()
    if serve:
        aparams = _as_bf16(aparams)
    specs = api.param_specs()
    if pool > 1:
        specs = pooling.pooled_specs(specs, aparams, mesh)
    return aparams, tree_shardings(mesh, specs, aparams), specs


def run_cell(arch: str, shape_name: str, multi_pod: bool, *, interactive_log=print) -> CellResult:
    cfg = get_config(arch)
    sh = SHAPES[shape_name]
    if sh.kind != "train" and cfg.sp_activations:
        # SP residuals + SP-native attention are a TRAINING memory feature
        # (they shrink remat stacks); serving has no remat stacks and is
        # better off with plain TP attention (head-sharded KV compute).
        cfg = dataclasses.replace(cfg, sp_activations=False)
    api = get_model(cfg)
    # the paper's weight pooling (ZeRO over the pool axis, per-layer JIT
    # gather inside the scan) — on for archs whose param+optimizer state
    # exceeds per-chip HBM under pure TP (the shared-L2 "apparent capacity").
    pool = cfg.pooling_cluster if cfg.pooling_cluster > 1 else 0
    mesh = make_production_mesh(multi_pod=multi_pod, pool=pool)
    mesh_name = "pod2" if multi_pod else "pod1"
    res = CellResult(arch, shape_name, mesh_name, ok=False, pooled=pool)
    t0 = time.time()
    try:
        with activate(mesh):
            if sh.kind == "train":
                aparams, p_sh, p_specs = _collect_params_shardings(api, mesh, pool, serve=False)
                aopt = jax.eval_shape(adamw_init, aparams)
                o_sh = {
                    "m": p_sh,
                    "v": p_sh,
                    "step": NamedSharding(mesh, P()),
                }
                abatch = api.input_specs(shape_name)
                b_sh = tree_shardings(mesh, api.batch_specs(shape_name), abatch)
                step = make_train_step(api, AdamWConfig(), storage_specs=p_specs)
                jfn = jax.jit(
                    step,
                    in_shardings=(p_sh, o_sh, b_sh),
                    out_shardings=(p_sh, o_sh, None),
                    donate_argnums=(0, 1),
                )
                t0 = time.time()
                lowered = jfn.lower(aparams, aopt, abatch)
            elif sh.kind == "prefill":
                aparams, p_sh, _ = _collect_params_shardings(api, mesh, pool, serve=True)
                abatch = api.input_specs(shape_name)
                b_sh = tree_shardings(mesh, api.batch_specs(shape_name), abatch)
                step = make_prefill_step(api, max_len=sh.seq_len)
                jfn = jax.jit(step, in_shardings=(p_sh, b_sh))
                t0 = time.time()
                lowered = jfn.lower(aparams, abatch)
            else:  # decode
                aparams, p_sh, _ = _collect_params_shardings(api, mesh, pool, serve=True)
                specs = api.input_specs(shape_name)
                acache, atoks = specs["cache"], specs["tokens"]
                cache_sh = tree_shardings(mesh, api.cache_specs(), acache)
                tok_sh = tree_shardings(
                    mesh, api.batch_specs(shape_name)["tokens"], atoks
                )
                step = make_serve_step(api)
                jfn = jax.jit(
                    step,
                    in_shardings=(p_sh, cache_sh, tok_sh),
                    out_shardings=(None, cache_sh),
                    donate_argnums=(1,),
                )
                t0 = time.time()
                lowered = jfn.lower(aparams, acache, atoks)
            res.seconds_lower = time.time() - t0

            t1 = time.time()
            compiled = lowered.compile()
            res.seconds_compile = time.time() - t1

            ma = compiled.memory_analysis()
            res.memory = {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "alias_bytes": int(ma.alias_size_in_bytes),
                "peak_bytes": int(
                    ma.argument_size_in_bytes
                    + ma.output_size_in_bytes
                    + ma.temp_size_in_bytes
                    - ma.alias_size_in_bytes
                ),
                "hbm_budget": int(HBM_BUDGET),
            }
            res.memory["fits"] = res.memory["peak_bytes"] <= HBM_BUDGET
            ca = compiled.cost_analysis() or {}
            flops = float(ca.get("flops", 0.0))
            bytes_ = float(ca.get("bytes accessed", 0.0))
            res.cost = {"flops": flops, "bytes_accessed": bytes_}

            hlo = compiled.as_text()
            chips = mesh.devices.size
            cost = hlo_analysis.analyze(hlo, total_devices=chips)
            res.collectives = {
                "total_bytes": float(cost.total_collective_bytes),
                "by_kind_bytes": {k: float(v) for k, v in cost.collective_bytes.items()},
                "op_counts": {k: int(v) for k, v in cost.collective_ops.items()},
                "group_sizes": {k: float(v) for k, v in cost.group_sizes.items()},
                "hlo_flops_model": float(cost.flops),
                "hlo_bytes_model": float(cost.bytes),
            }

            n_tokens = sh.global_batch * (sh.seq_len if sh.kind in ("train", "prefill") else 1)
            # primary FLOP/byte source: the trip-counted HLO walk. XLA's own
            # cost_analysis() visits while bodies once, so an 80-layer scan
            # under-reports by 80x; both are recorded, the walk drives terms.
            terms = rl.roofline(
                flops=cost.flops or flops,
                bytes_=cost.bytes or bytes_,
                cost=cost,
                n_params=float(
                    cfg.n_active_params() if cfg.family == "moe" else cfg.n_params()
                ),
                n_tokens=float(n_tokens),
                chips=chips,
                kind="train" if sh.kind == "train" else "serve",
                attn_ref_bytes=float(cost.tagged_bytes.get("flash_attention_ref", 0.0)),
                attn_kernel_bytes=_attn_kernel_bytes(cfg, sh),
            )
            res.roofline = terms.as_dict()
            res.roofline["roofline_fraction"] = rl.roofline_fraction(terms)
            res.ok = True
            interactive_log(
                f"[{mesh_name}] {arch} x {shape_name}: "
                f"lower {res.seconds_lower:.1f}s compile {res.seconds_compile:.1f}s "
                f"peak {res.memory['peak_bytes']/2**30:.2f} GiB "
                f"({'fits' if res.memory['fits'] else 'OVER'}) | "
                + rl.format_row("", terms)
            )
    except Exception as e:  # noqa: BLE001 — recorded, the driver continues
        res.error = f"{type(e).__name__}: {e}\n{traceback.format_exc(limit=8)}"
        interactive_log(f"[{mesh_name}] {arch} x {shape_name}: FAILED {type(e).__name__}: {e}")
    return res


# ---------------------------------------------------------------------------
# driver


def all_cells():
    for arch in list_archs():
        cfg = get_config(arch)
        for shape in applicable_shapes(cfg):
            yield arch, shape


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", action="append", help="arch id (repeatable); default all")
    ap.add_argument("--shape", action="append", help="shape name (repeatable); default all applicable")
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="single")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true", help="recompute cells that already have a JSON")
    args = ap.parse_args(argv)

    cells = [
        (a, s)
        for a, s in all_cells()
        if (not args.arch or a in args.arch) and (not args.shape or s in args.shape)
    ]
    if args.list:
        for a, s in cells:
            print(f"{a:24s} {s}")
        skips = {
            a: skipped_shapes(get_config(a)) for a in list_archs() if skipped_shapes(get_config(a))
        }
        print(f"\n{len(cells)} cells; skips per assignment rules:")
        for a, sk in skips.items():
            for s, why in sk.items():
                print(f"  {a:24s} {s}: {why}")
        return 0

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    n_fail = 0
    for multi in meshes:
        mesh_dir = os.path.join(args.out, "pod2" if multi else "pod1")
        os.makedirs(mesh_dir, exist_ok=True)
        for arch, shape in cells:
            path = os.path.join(mesh_dir, f"{arch}__{shape}.json")
            if os.path.exists(path) and not args.force:
                print(f"[skip] {path} exists")
                continue
            res = run_cell(arch, shape, multi)
            with open(path, "w") as f:
                json.dump(res.as_dict(), f, indent=1)
            n_fail += 0 if res.ok else 1
    print(f"done; {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
