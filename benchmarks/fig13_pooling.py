"""Paper Fig. 13: IPC vs L2 code-cache allocation -> throughput-relevant
capacity vs pooling-cluster size k (the shared-L2 analogue).

For qwen1.5-110b (the pooling flagship): per-replica resident bytes, apparent
HBM capacity multiplier, and the gather traffic paid per step — the identical
capacity-for-interconnect trade the paper buys with a shared L2. When the
dry-run artifacts exist, the MEASURED all-gather bytes per step are shown
next to the analytic model.
"""
import json
import os

from repro.configs import get_config
from repro.core import hw, pooling

from _common import fmt_table

GIB = 2**30


def main(dryrun_dir="experiments/dryrun/pod1"):
    cfg = get_config("qwen1.5-110b")
    pbytes = cfg.n_params() * 4.0 / 16  # f32, TP16-sharded slice per chip row
    measured = None
    path = os.path.join(dryrun_dir, "qwen1.5-110b__train_4k.json")
    if os.path.exists(path):
        d = json.load(open(path))
        if d.get("collectives"):
            measured = d["collectives"]["by_kind_bytes"].get("all-gather")
    rows = []
    out = {}
    for k in (1, 2, 4, 8, 16):
        m = pooling.apparent_capacity_model(pbytes, hw.HBM_BYTES, k)
        fits = "yes" if 3 * m["resident_bytes"] < 0.8 * hw.HBM_BYTES else "NO"
        rows.append(
            (
                k,
                f"{m['resident_bytes']/GIB:7.2f}",
                f"{3*m['resident_bytes']/GIB:7.2f}",
                f"{m['apparent_capacity_x']:.1f}x",
                f"{m['gather_bytes']/GIB:7.2f}",
                fits,
            )
        )
        out[k] = m["resident_bytes"]
    print("[fig13] qwen1.5-110b per-chip weight residency vs pooling cluster k")
    print(
        fmt_table(
            rows,
            ["k", "params GiB", "p+m+v GiB", "apparent", "gather GiB/step", "fits HBM"],
        )
    )
    if measured is not None:
        print(f"measured all-gather bytes/step from dry-run (pool=16): {measured/GIB:.2f} GiB/device")
    print("paper: 9.1% IPC gain from 4x apparent code cache; here 16x apparent HBM makes the arch trainable at all")
    return out


if __name__ == "__main__":
    main()
