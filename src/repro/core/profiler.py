"""MemProf analogue: block-granular access profiling for framework state.

The paper samples I-TLB misses (MemProf.Code) and LLC demand misses
(MemProf.MemBW) and aggregates per page. Here the instrumented "pages" are
the framework's state blocks — KV-cache pages, MoE experts, embedding rows,
parameter shards — and the "cores" are streams (DP replicas, request lanes).

Three probes, mirroring Fig. 6:
  * Code  -> ``record`` on parameter-block reads per replica stream;
             ``correlation`` reproduces Table 2, ``bandwidth_cdf`` Fig. 9.
  * MemBW -> ``record`` on KV/expert/embedding accesses; windowed counts
             give Fig. 18's interval study and feed the tier planner.
  * MemLat-> prefetcher accounting lives in core/prefetch.py; the profiler
             only aggregates its counters into the report.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional

import numpy as np

from repro.core import distribution


@dataclasses.dataclass
class StreamStats:
    counts: np.ndarray  # (n_blocks,) total
    reads: int = 0
    writes: int = 0


class AccessProfiler:
    """Counts block accesses per stream, with measurement windows.

    ``window_len`` (in record-steps) splits time into windows so
    interval-stability (Fig. 18) can be evaluated; window boundaries advance
    via ``tick()`` (one tick == one engine step).
    """

    def __init__(self, n_blocks: int, block_bytes: int = 4096, window_len: int = 30):
        self.n_blocks = n_blocks
        self.block_bytes = block_bytes
        self.window_len = window_len
        self._streams: Dict[str, StreamStats] = {}
        self._windows: Dict[str, list] = {}
        self._cur_win: Dict[str, np.ndarray] = {}
        self.step = 0

    # ------------------------------------------------------------------
    def _stream(self, name: str) -> StreamStats:
        if name not in self._streams:
            self._streams[name] = StreamStats(np.zeros(self.n_blocks, np.int64))
            self._windows[name] = []
            self._cur_win[name] = np.zeros(self.n_blocks, np.int64)
        return self._streams[name]

    def record(self, stream: str, block_ids, weights=None, rw: str = "r"):
        st = self._stream(stream)
        ids = np.asarray(block_ids).reshape(-1)
        if weights is None:
            np.add.at(st.counts, ids, 1)
            np.add.at(self._cur_win[stream], ids, 1)
            n = ids.size
        else:
            w = np.asarray(weights).reshape(-1)
            np.add.at(st.counts, ids, w)
            np.add.at(self._cur_win[stream], ids, w)
            n = int(w.sum())
        if rw == "r":
            st.reads += n
        else:
            st.writes += n

    def tick(self, n: int = 1):
        """Advance time; closes measurement windows at window_len boundaries."""
        for _ in range(n):
            self.step += 1
            if self.step % self.window_len == 0:
                for name, cur in self._cur_win.items():
                    self._windows[name].append(cur.copy())
                    cur[:] = 0

    # ------------------------------------------------------------------
    def streams(self, prefix: str = "") -> list:
        """Registered stream names, optionally filtered by prefix.

        Tenant-scoped streams use dotted names ("kv.web"); the fleet export
        enumerates them here instead of reaching into private state.
        """
        return sorted(n for n in self._streams if n.startswith(prefix))

    def counts(self, stream: str) -> np.ndarray:
        return self._stream(stream).counts

    def windows(self, stream: str) -> list:
        return self._windows.get(stream, [])

    def bandwidth_cdf(self, stream: str):
        return distribution.bandwidth_cdf(self.counts(stream))

    def hot_fraction(self, stream: str, capacity_frac: float) -> float:
        return distribution.hot_fraction(self.counts(stream), capacity_frac)

    def correlation(self, s1: str, s2: str) -> float:
        return distribution.pearson(self.counts(s1), self.counts(s2))

    def rw_ratio(self, stream: str) -> float:
        st = self._stream(stream)
        return st.reads / max(st.writes, 1)

    def bytes_accessed(self, stream: str) -> int:
        return int(self.counts(stream).sum()) * self.block_bytes

    # ------------------------------------------------------------------
    def report(self, capacity_fracs: Iterable[float] = (0.05, 0.1, 0.25)) -> dict:
        """The MemProf report: per stream, the hotness profile + stability."""
        out = {}
        for name, st in self._streams.items():
            counts = st.counts
            out[name] = {
                "total_accesses": int(counts.sum()),
                "active_frac": float((counts > 0).mean()),
                "hot": {f: distribution.hot_fraction(counts, f) for f in capacity_fracs},
                "capacity_for_90pct": distribution.capacity_for_traffic(counts, 0.9),
                "zipf_alpha": distribution.zipf_alpha(counts),
                "rw_ratio": self.rw_ratio(name),
                "stability": distribution.interval_stability(self.windows(name)),
            }
        return out
