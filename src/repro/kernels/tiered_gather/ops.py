"""Public tiered-gather ops: lane padding + the two-tier composition."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.tiered_gather.kernel import gather_rows_kernel

LANE = 128


def _pad_lanes(x):
    pad = (-x.shape[-1]) % LANE
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    return x, pad


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_rows(src, ids, scales=None, *, interpret: bool = True):
    """src: (M, D); ids: (N,) -> (N, D) f32 (dequantized if scales given)."""
    d = src.shape[1]
    srcp, _ = _pad_lanes(src)
    sc = None if scales is None else scales.reshape(-1, 1).astype(jnp.float32)
    out = gather_rows_kernel(srcp, ids.astype(jnp.int32), sc, interpret=interpret)
    return out[:, :d]


@functools.partial(jax.jit, static_argnames=("interpret",))
def tiered_lookup(hot, cold_q, cold_scales, tier, slot, ids, *, interpret: bool = True):
    """Two-tier lookup: near rows from ``hot`` (bf16/f32), far rows from the
    int8 ``cold_q``+``cold_scales`` store, selected by ``tier``/``slot`` maps.

    On real hardware the two gathers run on separate streams (HBM vs host
    DMA); here both go through the kernel and are merged by tier mask.
    """
    s = slot[ids]
    t = tier[ids]
    hot_rows = gather_rows(hot, jnp.where(t == 0, s, 0), interpret=interpret)
    cold_rows = gather_rows(
        cold_q, jnp.where(t == 1, s, 0), cold_scales, interpret=interpret
    )
    return jnp.where((t == 0)[:, None], hot_rows, cold_rows)
