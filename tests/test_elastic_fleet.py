"""Elastic fleet: burst-driven scale-up with warm-tier handoff, drain-before-
retire with profile folding, and the stitched-trace validation surviving a
full scale cycle (ISSUE 3 acceptance).

All runs are event-driven (elasticity reacts per completion batch) and
seeded — scale events land on exact virtual times.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs.workloads import get_profile
from repro.data.requests import RequestGenerator
from repro.fleet import (
    AdmissionController,
    SLOModel,
    aggregate_counts,
    build_fleet,
    export_all,
    fleet_vocab,
    validate_fleet,
)


def _profile(**kw):
    base = dict(prompt_mean=24, decode_mean=6, prefix_share=0.9, n_prefixes=3)
    base.update(kw)
    return dataclasses.replace(get_profile("Web1"), **base)


def _elastic_fleet(**kw):
    base = dict(
        n_pages=256,
        trace_window=16,
        trace_period=32,
        admission=AdmissionController(SLOModel(max_delay_steps=16.0)),
        autotier=dict(near_frac=0.30, epoch_steps=4),
        elastic=dict(
            min_replicas=2, max_replicas=5, cooldown=3.0,
            up_shed_rate=0.05, up_backlog_frac=0.6, down_backlog_frac=0.15,
        ),
        seed=0,
    )
    base.update(kw)
    return build_fleet(2, policy="least-loaded", **base)


def _burst_run(fleet, n_requests=60, submit_per_step=6, seed=0):
    gen = RequestGenerator(_profile(), vocab_size=fleet_vocab(), seed=seed)
    return fleet.run(
        gen, n_requests=n_requests, max_steps=800, submit_per_step=submit_per_step
    )


# ---------------------------------------------------------------------------
# scale-up: warm-tier handoff


@pytest.mark.slow
def test_scale_up_warms_near_tier_from_fleet_plan():
    """Acceptance: a scaled-up replica's initial near set IS the
    AutoTierer's latest pushed plan (truncated to the host's capacity),
    and fleet planning owns its placement from birth."""
    fleet = _elastic_fleet()
    _burst_run(fleet, n_requests=16, submit_per_step=2)
    at = fleet.autotierer
    assert at.history  # plan exists before the handoff
    plan = at.warm_near_ids()
    r = fleet.elastic.scale_up(fleet._now, reason="test")
    expected = np.asarray(plan, np.int64).reshape(-1)
    expected = expected[(expected >= 0) & (expected < r.engine.ecfg.n_pages)]
    expected = np.sort(expected[: r.engine.placement.near_capacity])
    np.testing.assert_array_equal(
        np.flatnonzero(r.engine.placement.tier == 0), expected
    )
    assert r.engine.external_placement
    assert r in fleet.replicas and r in at.replicas  # one shared list


def test_scale_up_without_plan_cold_starts():
    fleet = _elastic_fleet(autotier=None, elastic=dict(min_replicas=1, max_replicas=3))
    r = fleet.elastic.scale_up(0.0, reason="test")
    assert not r.engine.external_placement  # local TPP loop stays in charge
    assert r.rid == 2  # rids continue past the initial set


# ---------------------------------------------------------------------------
# scale-down: drain, retire, fold the profile


# manual-drain tests: a huge cooldown disables automatic scale decisions
# (retire-on-drained still runs every batch), min_replicas=1 allows the
# manual scale_down of one of the two initial hosts
_MANUAL = dict(min_replicas=1, max_replicas=5, cooldown=1e9)


@pytest.mark.slow
def test_drained_replica_profile_folds_into_fleet_histogram():
    fleet = _elastic_fleet(elastic=dict(_MANUAL))
    _burst_run(fleet, n_requests=16, submit_per_step=2)
    victim = fleet.replicas[-1]
    before = victim.engine.profiler.counts("kv").copy()
    assert before.sum() > 0
    fleet.elastic.scale_down(fleet._now, reason="test")
    assert victim.draining
    # drain to empty: serve nothing new, let the victim finish its backlog
    _burst_run(fleet, n_requests=4, submit_per_step=1, seed=9)
    assert victim not in fleet.replicas  # retired
    retired = [p for p in fleet.elastic.retired_profiles if p.rid == victim.rid]
    assert len(retired) == 1
    # its counts only grew while draining, and the fleet aggregate keeps them
    assert (retired[0].counts[: before.size] >= before).all()
    combined = aggregate_counts(fleet.export_profiles())
    live_only = aggregate_counts(export_all(fleet.replicas))
    n = combined.size
    assert combined.sum() == live_only.sum() + sum(
        int(p.counts.sum()) for p in fleet.elastic.retired_profiles
    )
    assert (combined[: retired[0].counts.size] >= retired[0].counts[:n]).all()
    # the autotierer keeps planning on the retired host's history too
    assert retired[0] in fleet.autotierer.extra_profiles
    # ...and the fleet service books keep the retired host's work
    stats = fleet.fleet_stats()
    assert stats["requests_finished"] == stats["routed"]


@pytest.mark.slow
def test_drained_replica_never_receives_new_work():
    fleet = _elastic_fleet(elastic=dict(_MANUAL))
    _burst_run(fleet, n_requests=8, submit_per_step=2)
    victim = fleet.replicas[0]
    routed_before = victim.engine.prefill_tokens
    victim.start_drain()
    _burst_run(fleet, n_requests=8, submit_per_step=2, seed=5)
    assert victim.engine.prefill_tokens == routed_before


# ---------------------------------------------------------------------------
# acceptance: the full cycle


@pytest.mark.slow
def test_burst_triggers_scale_cycle_and_trace_stays_valid():
    """Acceptance: an arrival burst scales the fleet up; the post-burst
    quiet period drains + retires; the stitched fleet trace (including
    retired hosts) stays within <=5% of live counters across the cycle."""
    fleet = _elastic_fleet()
    stats = _burst_run(fleet)
    actions = [e.action for e in fleet.elastic.events]
    assert "up" in actions, fleet.elastic.events
    assert "retire" in actions, fleet.elastic.events
    assert stats["shed"] > 0  # the burst was a real overload
    assert stats["requests_finished"] == stats["routed"]  # drains served all
    # back to the floor after the burst
    assert len(fleet.replicas) == fleet.elastic.min_replicas
    val = validate_fleet(fleet.export_profiles())
    assert val["trace_len"] > 0
    assert val["hit_ratio_error"] <= 0.05, val
    assert abs(val["rw_ratio_error_pct"]) <= 5.0, val


@pytest.mark.slow
def test_scale_cycle_is_deterministic():
    events = []
    for _ in range(2):
        fleet = _elastic_fleet()
        _burst_run(fleet)
        events.append([(e.vtime, e.action, e.rid) for e in fleet.elastic.events])
    assert events[0] == events[1] and events[0]


def test_scale_down_respects_min_replicas():
    fleet = _elastic_fleet()
    assert fleet.elastic.scale_down(0.0) is None  # already at the floor
    assert all(not r.draining for r in fleet.replicas)


def test_stitch_orders_late_joiner_windows_by_join_time():
    """Regression: an elastically added host's engine step counter starts
    at 0 — its windows must stitch at join-time + step*cost, not at the
    trace's beginning."""
    from repro.core.memtrace import TraceWindow
    from repro.fleet import ReplicaProfile, stitch_fleet

    def prof(rid, blocks, clock_offset):
        w = TraceWindow(0, np.full(4, blocks, np.int64), np.zeros(4, bool))
        return ReplicaProfile(
            rid=rid, counts=np.bincount(w.blocks, minlength=8), windows=[w],
            reads=4, writes=0, live_hit_ratio=0.5, live_accesses=4,
            live_capacity=4, near_hit_rate=1.0, clock_offset=clock_offset,
        )

    founding, joiner = prof(0, 1, 0.0), prof(1, 2, 100.0)
    trace = stitch_fleet([joiner, founding], n_pages=8)
    # founding host's window (vtime 0) comes first despite list order and
    # both windows sharing start_step 0
    assert trace.blocks[0] == 1 and trace.blocks[-1] == 2 + 8  # namespaced


@pytest.mark.slow
def test_scaled_up_replica_records_join_time():
    fleet = _elastic_fleet()
    _burst_run(fleet, n_requests=12, submit_per_step=2)
    r = fleet.elastic.scale_up(fleet._now, reason="test")
    assert r.created_at == fleet._now > 0
    assert r.export_profile().clock_offset == r.created_at


# ---------------------------------------------------------------------------
# scheduler cancellation + faults racing scale events


def test_scheduler_cancel_skips_without_trace():
    """A cancelled event is swept without running, without advancing the
    clock, without forming a batch — the property that makes the armed-but-
    idle watchdog invisible in the event books."""
    from repro.fleet.scheduler import VirtualScheduler

    sched = VirtualScheduler()
    ran = []
    batches = []
    e1 = sched.post(1.0, lambda: ran.append("a"))
    e2 = sched.post(1.0, lambda: ran.append("b"))
    sched.post(2.0, lambda: ran.append("c"))
    assert sched.cancel(e2) is True
    assert sched.cancel(e2) is False  # idempotent
    assert sched.cancel(None) is False  # None-safe
    assert sched.live_pending == 2 and sched.pending == 3
    sched.run(quiescent=lambda t: batches.append(t))
    assert ran == ["a", "c"]
    assert batches == [1.0, 2.0]
    assert sched.events_cancelled == 1 and sched.events_run == 2


def test_scheduler_fully_cancelled_timestamp_advances_nothing():
    from repro.fleet.scheduler import VirtualScheduler

    sched = VirtualScheduler()
    ran = []
    batches = []
    ev = sched.post(5.0, lambda: ran.append("dead"))
    sched.post(9.0, lambda: ran.append("live"))
    sched.cancel(ev)
    sched.run(quiescent=lambda t: batches.append(t))
    # t=5.0 never happened: no batch, and the clock went straight to 9.0
    assert batches == [9.0] and ran == ["live"]
    assert sched.batches == 1


def test_scheduler_cancel_and_reschedule():
    """The watchdog reschedule pattern: cancel the pending event, post a
    replacement at a later time — exactly one of the two ever runs."""
    from repro.fleet.scheduler import VirtualScheduler

    sched = VirtualScheduler()
    fired = []
    ev = sched.post(3.0, lambda: fired.append("old"))

    def at_one():
        sched.cancel(ev)
        sched.post(6.0, lambda: fired.append("new"))

    sched.post(1.0, at_one)
    sched.run()
    assert fired == ["new"] and sched.now == 6.0
    # cancel is idempotent: a second cancel of the same event is a no-op
    assert sched.cancel(ev) is False
    # cancelling an ALREADY-RUN event is a harmless no-op (lazy removal
    # popped it from the heap): teardown paths cancel unconditionally
    done = sched.post(7.0, lambda: fired.append("late"))
    sched.run()
    assert fired == ["new", "late"]
    assert sched.cancel(done) is True  # marks it, but it will never be swept
    assert sched.live_pending == 0


@pytest.mark.slow
def test_crash_races_pending_scale_down():
    """A draining victim that crashes is retired exactly once, through the
    crash path: its books land in crashed_stats (not retired_stats), the
    elastic history shows drain -> crash with no drained-retire, and the
    run still terminates with every request accounted."""
    from repro.fleet import ChaosEngine, FaultEvent

    fleet = _elastic_fleet(elastic=dict(_MANUAL))
    _burst_run(fleet, n_requests=16, submit_per_step=2)
    victim = fleet.replicas[-1]
    fleet.elastic.scale_down(fleet._now, reason="test")
    assert victim.draining
    # crash the draining host at the very start of the next run: FAULT
    # priority sorts before that timestamp's completions and the per-batch
    # retire-on-drained check, so the crash deterministically wins the race
    ChaosEngine(
        fleet,
        [FaultEvent(fleet._now, "crash", rid=victim.rid)],
        dispatch_timeout=50.0,
    )
    _burst_run(fleet, n_requests=8, submit_per_step=2, seed=9)
    assert victim not in fleet.replicas
    actions = [(e.action, e.rid) for e in fleet.elastic.events]
    assert ("drain", victim.rid) in actions
    assert ("crash", victim.rid) in actions
    assert ("retire", victim.rid) not in actions  # crash won the race
    assert victim.rid in [s["rid"] for s in fleet.crashed_stats]
    assert victim.rid not in [s["rid"] for s in fleet.elastic.retired_stats]
    # its profile is folded exactly once into the fleet aggregate
    assert sum(1 for p in fleet.export_profiles() if p.rid == victim.rid) == 1
    rep = fleet.outcome_report()
    assert rep["complete"], rep


def test_admission_pressure_export():
    adm = AdmissionController(SLOModel(max_delay_steps=8.0), pressure_window=4)
    fleet = _elastic_fleet(admission=adm, elastic=None)
    p = adm.pressure(fleet.replicas)
    assert p["shed_rate"] == 0.0 and p["backlog_steps"] == 0.0
    gen = RequestGenerator(_profile(), vocab_size=fleet_vocab(), seed=0)
    for _ in range(12):
        fleet.offer(next(gen))
    p = adm.pressure(fleet.replicas)
    assert 0.0 <= p["shed_rate"] <= 1.0
    assert p["shed_rate"] == pytest.approx(adm.recent_shed_rate)
    # window is sliding: only the last 4 decisions count
    assert len(adm._recent) == 4
