"""Online fleet re-tiering: plan on the aggregate, push to every host.

The paper's tiering decision (§5, Table 5) is made from *fleet* behavior —
"few pages serve most bandwidth" is a property of the service, not of one
host's recent window. The AutoTierer periodically re-runs core/tiering.plan
on the aggregated fleet histogram and pushes the resulting near-tier page
set to every replica (which suppresses their local TPP loops), so placement
is driven by the representative profile instead of each engine's noisy
local view. Under a stationary workload the pushed plan converges: the
Jaccard overlap of successive near-sets approaches 1.

Epochs are keyed on *virtual time*, not fleet-step counts: the event-driven
fleet has no global tick, and an elastic fleet has no fixed replica set.
The hook receives the scheduler's clock and re-plans every ``epoch_steps``
units of virtual time (in lockstep mode with nominal speeds one unit == one
fleet step, so the legacy cadence is unchanged). Retired replicas keep
contributing through ``extra_profiles`` — a drained host's history is part
of the service's behavior even after the host is gone — and a freshly added
replica with no traffic yet contributes zeros, never NaNs.

Multi-tenant: the plan is still made from the COMBINED histogram — the near
tier is one physical resource — but each epoch also reports the fraction of
every tenant's accesses the pushed near set would serve. A skew-heavy
tenant crowding the top-k pushes its neighbors' planned near-hit down;
that per-tenant spread is the co-location interference signal the
tenant_interference benchmark measures.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import numpy as np

from repro.core import tiering
from repro.core.hw import HBM_BW, HOST_LINK_BW, TierSpec
from repro.fleet import aggregator
from repro.fleet.replica import Replica, ReplicaProfile


def _fleet_specs(near_frac: float) -> tuple:
    return (
        TierSpec("hbm", near_frac, HBM_BW, 1.0, 8.0),
        TierSpec("host-dram", 1.0 - near_frac, HOST_LINK_BW, 6.0, 1.0),
    )


@dataclasses.dataclass
class TierEpoch:
    fleet_step: int
    near_ids: np.ndarray
    near_hit_frac: float  # planned fraction of accesses served near
    migrated_pages: int  # placement changes this push cost, fleet-wide
    overlap_prev: float  # Jaccard vs previous epoch's near set
    # planned near-served fraction per tenant under the SAME shared near set
    tenant_near_frac: Dict[str, float] = dataclasses.field(default_factory=dict)
    vtime: float = 0.0  # virtual time this epoch was planned at
    n_replicas: int = 0  # live replica-set size at plan time (elasticity)
    # bytes the push actually moved through the hosts' device tier stores
    # (promote dequants + demote quants); 0 when hosts run host-accounted
    device_moved_bytes: int = 0
    # fleet-wide dispatch/sync budget at plan time: CUMULATIVE tiered-gather
    # kernel launches and counter-plane host syncs across the live replica
    # set (snapshots, not per-epoch deltas like device_moved_bytes — diff
    # consecutive epochs for a rate; retired hosts are excluded). Epochs
    # read DRAINED device counters — the profile export that feeds the
    # plan is a drain boundary — so these never lag the plan's inputs
    device_dispatches: int = 0
    device_host_syncs: int = 0
    # fleet-trained prefetch successor tables pushed alongside the near
    # set, TENANT-PARTITIONED ({tenant: {block: (succ, ...)}}): the
    # trace-driven prefetcher's fleet plane — sequences learned on any host
    # prefetch for all of them, but only within their own tenant's
    # partition, so one tenant's template chains cannot evict another
    # tenant's pending prefetches on the hosts the push lands on
    prefetch_table: Dict[str, Dict[int, tuple]] = dataclasses.field(
        default_factory=dict
    )
    # per-shard near-tier capacity of each sharded host at plan time
    # ({rid: (cap_shard0, cap_shard1, ...)}): a sharded replica's near tier
    # is the UNION of its shards' slices, and the planner's near set lands
    # on each shard restricted to the pages that shard owns — these are the
    # per-shard ceilings that restriction is guaranteed to fit under
    shard_near_capacity: Dict[int, tuple] = dataclasses.field(default_factory=dict)


class AutoTierer:
    def __init__(
        self,
        replicas: List[Replica],
        near_frac: float = 0.30,
        epoch_steps: int = 32,
        specs: Optional[tuple] = None,
    ):
        self.replicas = replicas
        self.near_frac = near_frac
        self.epoch_steps = epoch_steps
        self.specs = specs or _fleet_specs(near_frac)
        self.history: List[TierEpoch] = []
        # profiles of replicas retired by the elastic layer: their traffic
        # shaped the service's histogram, so the plan keeps seeing it
        self.extra_profiles: List[ReplicaProfile] = []
        self._last_epoch = 0.0
        # monotone plan sequence number, stamped on every push: engines
        # fence on it after a failover so a plan computed from pre-fault
        # profiles can never land on a host the fault machinery reset
        self.epoch_seq = 0

    # ------------------------------------------------------------------
    def __call__(self, now: float):
        """FleetRouter.on_step hook; ``now`` is fleet virtual time."""
        if now - self._last_epoch >= self.epoch_steps:
            # advance the boundary grid (even when there is no data yet) so
            # epochs stay aligned with the legacy fleet-step modulo cadence
            self._last_epoch += self.epoch_steps * math.floor(
                (now - self._last_epoch) / self.epoch_steps
            )
            self.step(now)

    def step(self, now: float = 0.0) -> Optional[TierEpoch]:
        profiles = aggregator.export_all(self.replicas) + list(self.extra_profiles)
        counts = aggregator.aggregate_counts(profiles)
        if counts.size == 0 or counts.sum() == 0:
            return None
        self.epoch_seq += 1
        p = tiering.plan(counts, self.specs)
        # the prefetch plane rides the placement epoch: one table trained
        # from every host's stream-tagged windows, pushed with the near set
        table = aggregator.train_fleet_successors(profiles)
        moved_before = sum(r.device_moved_bytes for r in self.replicas)
        migrated = sum(
            r.apply_placement(p.hot_blocks, epoch=self.epoch_seq)
            for r in self.replicas
        )
        if table:
            for r in self.replicas:
                r.load_successors(table)
        device_moved = sum(r.device_moved_bytes for r in self.replicas) - moved_before
        overlap = 0.0
        if self.history:
            prev = set(self.history[-1].near_ids.tolist())
            cur = set(p.hot_blocks.tolist())
            overlap = len(prev & cur) / max(len(prev | cur), 1)
        tenant_frac = {}
        for t, tc in aggregator.aggregate_tenant_counts(profiles).items():
            total = float(tc.sum())
            if tc.size == 0 or total <= 0.0:
                # a freshly added replica registers its tenant streams
                # before any traffic lands: report an explicit 0, never
                # divide into a zero histogram
                tenant_frac[t] = 0.0
                continue
            near = tc[p.hot_blocks[p.hot_blocks < tc.size]].sum()
            tenant_frac[t] = float(near / total)
        # live hosts only: extra_profiles are frozen snapshots of retired
        # hosts and would inflate the budget for the rest of the run
        live = profiles[: len(self.replicas)]
        dev = [pr.device_tiering for pr in live if pr.device_tiering]
        shard_caps = {
            pr.rid: tuple(pr.device_tiering["shard_near_capacity"])
            for pr in live
            if pr.device_tiering and "shard_near_capacity" in pr.device_tiering
        }
        epoch = TierEpoch(
            int(now),
            p.hot_blocks,
            p.hit_fracs[0],
            migrated,
            overlap,
            tenant_frac,
            vtime=float(now),
            n_replicas=len(self.replicas),
            device_moved_bytes=device_moved,
            device_dispatches=sum(d["dispatches"] for d in dev),
            device_host_syncs=sum(d["host_syncs"] for d in dev),
            prefetch_table=table,
            shard_near_capacity=shard_caps,
        )
        self.history.append(epoch)
        return epoch

    # ------------------------------------------------------------------
    def warm_near_ids(self) -> Optional[np.ndarray]:
        """Latest pushed near set — what a scaled-up replica warms from."""
        return self.history[-1].near_ids if self.history else None

    def warm_successors(self) -> Dict[str, Dict[int, tuple]]:
        """Latest fleet prefetch tables (tenant-partitioned) — a joining
        host predicts from its first step instead of cold-starting its own
        trace training."""
        return self.history[-1].prefetch_table if self.history else {}

    @property
    def converged(self) -> bool:
        """Plan is stable once consecutive near-sets mostly agree."""
        return len(self.history) >= 2 and self.history[-1].overlap_prev >= 0.8

    def convergence_trace(self) -> List[float]:
        return [e.overlap_prev for e in self.history]
