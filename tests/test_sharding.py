"""Mesh/sharding helpers + HLO cost-model unit tests (1-device safe)."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.launch import hlo_analysis as ha
from repro.launch import mesh as meshlib
from repro.launch.roofline import roofline, roofline_fraction


def test_shard_is_noop_without_mesh():
    x = jnp.ones((4, 8))
    y = meshlib.shard(x, "data", "model")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_spec_filters_missing_axes():
    mesh = meshlib.make_host_mesh(model=1)
    with meshlib.activate(mesh):
        s = meshlib.spec(("pod", "data"), "model", None)
        assert s == P(("data",), "model", None)


def test_shard_divisibility_drop():
    mesh = meshlib.make_host_mesh(model=1)  # data axis size = n devices (1)
    with meshlib.activate(mesh):
        x = jnp.ones((3, 5))
        y = meshlib.shard(x, "data", "model")  # 3 % 1 == 0 -> applies, harmless
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_host_mesh_rejects_non_dividing_model_axis():
    """An (n // model, model) mesh would silently drop n % model devices;
    make_host_mesh must refuse instead of quietly shrinking the fleet."""
    import pytest

    bad = 2 * len(jax.devices())  # guaranteed non-divisor of the device count
    with pytest.raises(ValueError, match="divide"):
        meshlib.make_host_mesh(model=bad)
    with pytest.raises(ValueError):
        meshlib.make_host_mesh(model=0)


def test_serving_mesh_shapes_and_bounds():
    import pytest

    mesh = meshlib.make_serving_mesh(model=1)
    assert mesh.shape["model"] == 1
    with pytest.raises(ValueError):
        meshlib.make_serving_mesh(model=len(jax.devices()) + 1)
    with pytest.raises(ValueError):
        meshlib.make_serving_mesh(model=0)


def test_shard_model_params_single_device_identity():
    """On a 1-device serving mesh the placement is a pure device_put: every
    leaf comes back bit-identical (the 1-shard bit-exactness anchor)."""
    mesh = meshlib.make_serving_mesh(model=1)
    tree = {
        "w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": jnp.arange(5, dtype=jnp.float32),
        "odd": jnp.ones((3,), jnp.float32),
    }
    out = meshlib.shard_model_params(tree, mesh)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(tree[k]), np.asarray(out[k]))


def test_production_mesh_shapes():
    # shape math only (no devices needed for the assertion of the spec)
    import inspect

    src = inspect.getsource(meshlib.make_production_mesh)
    assert "(2, 16, 16)" in src and "(16, 16)" in src


# ---------------------------------------------------------------------------
# HLO cost model


SAMPLE_HLO = """
HloModule test

%add.clone (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %add.9 = f32[] add(%x, %y)
}

%cond (p: (s32[], f32[8,128])) -> pred[] {
  %p = (s32[], f32[8,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %p = (s32[], f32[8,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,128] get-tuple-element(%p), index=1
  %w = f32[128,128] constant({...})
  %d = f32[8,128] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,128] all-reduce(%d), replica_groups=[16,16]<=[256], to_apply=%add.clone
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,128]) tuple(%ip, %ar)
}

ENTRY %main (a: f32[8,128]) -> f32[8,128] {
  %a = f32[8,128] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,128]) tuple(%zero, %a)
  %w = (s32[], f32[8,128]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8,128] get-tuple-element(%w), index=1
}
"""


def test_hlo_walk_trip_counts_collectives():
    cost = ha.analyze(SAMPLE_HLO, total_devices=256)
    # dot: 2*8*128*128 flops, 12 trips (+ scalar loop bookkeeping)
    want = 2 * 8 * 128 * 128 * 12
    assert want <= cost.flops <= want + 100
    # all-reduce operand: 8*128*4 bytes, 12 trips
    assert cost.collective_bytes["all-reduce"] == 8 * 128 * 4 * 12
    assert cost.collective_ops["all-reduce"] == 12
    assert cost.group_sizes["all-reduce"] == 16


def test_hlo_slice_aware_bytes():
    hlo = """
HloModule t

ENTRY %main (a: f32[32,1024], i: s32[]) -> f32[1,1024] {
  %a = f32[32,1024] parameter(0)
  %i = s32[] parameter(1)
  %z = s32[] constant(0)
  ROOT %ds = f32[1,1024] dynamic-slice(%a, %i, %z), dynamic_slice_sizes={1,1024}
}
"""
    cost = ha.analyze(hlo, total_devices=1)
    # 2 * slice bytes, NOT the whole 32x1024 buffer
    assert cost.bytes == 2 * 1024 * 4


def test_roofline_terms_and_bound():
    c = ha.Cost()
    c.collective_bytes["all-reduce"] = 1e9
    c.group_sizes["all-reduce"] = 16
    t = roofline(
        flops=1e12,
        bytes_=1e11,
        cost=c,
        n_params=1e9,
        n_tokens=1e6,
        chips=256,
        kind="train",
    )
    assert t.compute_s > 0 and t.memory_s > 0 and t.collective_s > 0
    assert t.bound in ("compute", "memory", "collective")
    assert 0.0 <= roofline_fraction(t) <= 1.0


def test_fused_slice_discount():
    hlo = """
HloModule t

%fused_dus (p0: f32[64,256], p1: f32[1,256], p2: s32[]) -> f32[64,256] {
  %p0 = f32[64,256] parameter(0)
  %p1 = f32[1,256] parameter(1)
  %p2 = s32[] parameter(2)
  %z = s32[] constant(0)
  ROOT %dus = f32[64,256] dynamic-update-slice(%p0, %p1, %p2, %z)
}

ENTRY %main (a: f32[64,256], u: f32[1,256], i: s32[]) -> f32[64,256] {
  %a = f32[64,256] parameter(0)
  %u = f32[1,256] parameter(1)
  %i = s32[] parameter(2)
  ROOT %f = f32[64,256] fusion(%a, %u, %i), kind=kLoop, calls=%fused_dus
}
"""
    cost = ha.analyze(hlo, total_devices=1)
    # boundary would be (in 64x256 + 1x256 + out 64x256)*4B; discounted to ~2*slice
    assert cost.bytes <= 3 * 256 * 4 + 16
