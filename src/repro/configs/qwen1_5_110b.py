"""qwen1.5-110b [dense] — QKV bias, GQA. [hf:Qwen/Qwen1.5-0.5B; hf]

The cluster-weight-pooling flagship: at TP=16, full f32 optimizer state does
not fit one replica's HBM without pooling (see core/pooling.py).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    pooling_cluster=16,
    sp_activations=True,  # seq-shard residuals: 80 layers of saved h fit HBM
    source="hf:Qwen/Qwen1.5-0.5B; hf",
)
