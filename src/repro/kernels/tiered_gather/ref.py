"""Oracles for the tiered row-gather kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_rows_ref(src, ids, scales=None):
    """src: (M, D); ids: (N,) int32; scales: optional (M,) row scales.

    Returns (N, D) f32: src[ids] (dequantized by scales if given).
    """
    rows = src[ids].astype(jnp.float32)
    if scales is not None:
        rows = rows * scales[ids].astype(jnp.float32)[:, None]
    return rows


def tiered_lookup_ref(hot, cold_q, cold_scales, tier, slot, ids):
    """Two-tier lookup oracle.

    hot: (Mh, D) bf16/f32 near-tier rows; cold_q: (Mc, D) int8 far-tier rows
    with per-row ``cold_scales`` (Mc,); ``tier[id]`` in {0=hot, 1=cold};
    ``slot[id]`` = row within its tier. Returns (N, D) f32.
    """
    s = slot[ids]
    t = tier[ids]
    h = hot[jnp.where(t == 0, s, 0)].astype(jnp.float32)
    c = cold_q[jnp.where(t == 1, s, 0)].astype(jnp.float32) * cold_scales[
        jnp.where(t == 1, s, 0)
    ].astype(jnp.float32)[:, None]
    return jnp.where((t == 0)[:, None], h, c)
