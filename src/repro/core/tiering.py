"""Memory-bandwidth tiering: planner + throughput model (paper §5, Table 4/5).

The throughput model is a three-term roofline calibrated on the paper's own
measurements:

  R(config) = min( R_cpu(avg_latency),            # compute bound
                   knee * BW_tier / traffic_tier  # per-tier bandwidth bound
                   ... for each tier )

* ``knee`` is the ~60-70% utilization ceiling beyond which DDR latency
  explodes (paper Fig. 4 discussion; calibrated to Baseline's measured
  67.8 GB/s on a 100 GB/s part -> knee = 0.68).
* R_cpu captures that Ideal only reached 1.55x despite 2x bandwidth —
  the workload becomes compute/latency bound. Latency sensitivity sigma
  degrades R_cpu as far-tier hits raise average memory latency
  (Tiered landed within 6.32% of Ideal).

``plan`` picks the near-tier capacity from a measured access CDF — the
paper's 37.5/62.5 split emerges from "few pages serve most bandwidth".
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core import distribution
from repro.core.hw import BW_KNEE, TierSpec


@dataclasses.dataclass(frozen=True)
class TierPlan:
    specs: tuple  # TierSpec per tier, hottest first
    hit_fracs: tuple  # fraction of accesses served per tier
    hot_blocks: np.ndarray  # ids placed in the near tier

    @property
    def cost(self) -> float:
        return sum(s.cost for s in self.specs)


def plan(counts: np.ndarray, specs: Sequence[TierSpec]) -> TierPlan:
    """Place the hottest blocks in the nearest tier, by measured counts."""
    counts = np.asarray(counts, np.float64)
    n = counts.size
    order = np.argsort(-counts)
    total = max(counts.sum(), 1.0)
    hit_fracs, start = [], 0
    hot_blocks = np.array([], np.int64)
    for i, s in enumerate(specs):
        k = int(np.ceil(s.capacity_frac * n)) if i < len(specs) - 1 else n - start
        ids = order[start : start + k]
        hit_fracs.append(float(counts[ids].sum() / total))
        if i == 0:
            hot_blocks = ids
        start += k
    return TierPlan(tuple(specs), tuple(hit_fracs), hot_blocks)


@dataclasses.dataclass(frozen=True)
class ThroughputModel:
    """Calibrated bandwidth/compute/latency roofline (see module docstring)."""

    bytes_per_access: float = 64.0
    knee: float = BW_KNEE
    cpu_headroom: float = 1.55  # R_cpu / R_baseline when latency is near-tier
    # calibrated so Tiered lands at the paper's 1.46-1.47x when the near tier
    # serves ~81.5% of traffic (Table 5's measured 84.6/103.8 split)
    latency_sigma: float = 0.42

    def baseline_rate(self, baseline: TierSpec) -> float:
        return self.knee * baseline.bw / self.bytes_per_access

    def throughput(self, plan: TierPlan, baseline: TierSpec) -> dict:
        r_base = self.baseline_rate(baseline)
        # per-tier bandwidth bound
        bw_bounds = []
        for spec, hit in zip(plan.specs, plan.hit_fracs):
            if hit <= 1e-9:
                continue
            bw_bounds.append(self.knee * spec.bw / (hit * self.bytes_per_access))
        # compute bound with latency degradation
        avg_lat = sum(s.latency_rel * h for s, h in zip(plan.specs, plan.hit_fracs))
        r_cpu = self.cpu_headroom * r_base / (1.0 + self.latency_sigma * max(avg_lat - 1.0, 0.0))
        rate = min([r_cpu] + bw_bounds)
        rel = rate / r_base
        tier_bw = [
            rate * h * self.bytes_per_access / 1e9 for h in plan.hit_fracs
        ]  # GB/s actually drawn per tier
        return {
            "rate": rate,
            "relative_throughput": rel,
            "bound": "cpu" if rate == r_cpu else "bandwidth",
            "tier_bw_gbps": tier_bw,
            "cost": plan.cost,
            "throughput_per_cost": rel / plan.cost,
            "avg_latency_rel": avg_lat,
        }


def evaluate_configs(counts: np.ndarray, configs: dict, model: ThroughputModel, baseline_key: str = "Baseline"):
    """Run the Table 5 comparison for {name: (TierSpec, ...)} configs."""
    base_spec = configs[baseline_key][0]
    out = {}
    for name, specs in configs.items():
        p = plan(counts, specs)
        out[name] = {"plan": p, **model.throughput(p, base_spec)}
    return out
