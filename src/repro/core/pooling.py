"""Cluster weight pooling — the paper's shared-L2 proposal, TPU-native.

Paper: four cores run identical code, so pool their four private L2s into one
shared L2 -> 4x apparent capacity, same silicon. Here: k data-parallel
replicas hold identical parameters, so store each parameter 1/k-sharded over
the ``pool`` mesh axis and all-gather it just-in-time inside the step ->
k x apparent HBM per replica, same chips. The gather is expressed as a
sharding constraint, so XLA SPMD schedules it (and overlaps it with the
previous layer's compute); its transpose in the backward pass is the
reduce-scatter that keeps gradients and optimizer state sharded (ZeRO-1/2/3
in one move).

``pooled_specs`` picks, per parameter, the largest dimension that is still
unsharded and divisible by the pool-axis size, and shards it. ``gather`` is
the in-step constraint back to the compute (TP-only) layout.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np

from repro.launch import mesh as meshlib
from repro.launch.mesh import POOL


def _is_spec(s) -> bool:
    return isinstance(s, tuple)


def pooled_specs(compute_specs, abstract_params, mesh) -> dict:
    """Storage specs: compute specs + POOL axis on the best available dim.

    ``abstract_params``: pytree of ShapeDtypeStruct (from jax.eval_shape).
    Leaves whose dims are all sharded/non-divisible stay at compute layout.
    """
    if POOL not in mesh.axis_names:
        return compute_specs
    k = dict(zip(mesh.axis_names, mesh.devices.shape))[POOL]

    def one(spec, aval):
        spec = tuple(spec)
        best, best_size = None, 0
        for i, (s, dim) in enumerate(zip(spec, aval.shape)):
            if s is None and dim % k == 0 and dim > best_size:
                best, best_size = i, dim
        if best is None:
            return spec
        out = list(spec)
        out[best] = POOL
        return tuple(out)

    return jax.tree.map(one, compute_specs, abstract_params, is_leaf=_is_spec)


def gather(params, compute_specs):
    """In-step all-gather: constrain pooled params back to compute layout.

    Under jax.grad, the transpose of this constraint reduce-scatters the
    gradients back to the pooled layout — no explicit collectives needed.
    """
    return jax.tree.map(
        lambda p, s: meshlib.shard(p, *s),
        params,
        compute_specs,
        is_leaf=lambda x: _is_spec(x) and not isinstance(x, jax.Array),
    )


def apparent_capacity_model(
    param_bytes: float, hbm_bytes: float, cluster: int, gather_bytes_per_step: Optional[float] = None
) -> dict:
    """Analytical model for benchmarks/fig13_pooling.py (IPC-vs-cache analogue).

    Returns per-replica HBM freed and the gather traffic paid, as the paper
    reports apparent-cache-size vs performance.
    """
    resident = param_bytes / cluster
    freed = param_bytes - resident
    return {
        "cluster": cluster,
        "resident_bytes": resident,
        "freed_bytes": freed,
        "apparent_capacity_x": min(cluster, hbm_bytes / max(resident, 1.0)),
        "gather_bytes": gather_bytes_per_step if gather_bytes_per_step is not None else param_bytes * (cluster - 1) / cluster,
    }
