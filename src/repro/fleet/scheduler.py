"""Virtual-time event scheduler: the fleet's clock without the barrier.

Lockstep stepping (``FleetRouter.step`` calling every replica once per
global tick) encodes a hidden assumption the paper's fleet data refutes:
that all hosts are equally fast. Per-host heterogeneity is first-order at
hyperscale — one 4x-slow host must cost the fleet one slow *replica*, not a
4x-slow *barrier*. This module provides the discrete-event core that makes
stragglers a scenario instead of a bug: each replica runs on its own clock,
posts a completion event when its step's virtual-time cost elapses, and the
router dispatches queued work the moment capacity frees.

Determinism is the design constraint: events execute in
``(time, priority, seq)`` order, where ``seq`` is posting order — there is
no wall clock, no thread, no hash-order anywhere, so a seeded run replays
exactly. With homogeneous step costs the event schedule degenerates to the
lockstep schedule (completions for all busy replicas land on the same
timestamp, in replica order), which is what lets the router guarantee
bit-exact equivalence with the legacy lockstep mode.

Cancellation: ``post`` returns the Event handle and ``cancel`` marks it
dead in place (lazy heap removal). A cancelled event is popped and skipped
without executing, without advancing ``now``, without counting toward
``events_run``, and without forming a quiescent batch — so a timeout event
that its completion races and cancels leaves NO trace in the event order,
which is what makes a zero-fault chaos config bit-exact with the plain
event-driven path.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, List, Optional

# Priorities order same-timestamp events the way one lockstep iteration
# orders its phases: fault injections strike first (a crash at t beats a
# completion at t — the adversarial and deterministic choice), then step
# completions retire work and free slots, then open-loop arrivals are
# offered to admission, then watchdog timeouts (a completion landing
# exactly on its deadline counts as on time). Dispatch is not an event —
# it runs in the quiescent hook after every batch.
FAULT = -1
COMPLETION = 0
ARRIVAL = 1
TIMEOUT = 2


@dataclasses.dataclass(order=True)
class Event:
    time: float
    prio: int
    seq: int
    action: Callable[[], None] = dataclasses.field(compare=False)
    cancelled: bool = dataclasses.field(default=False, compare=False)


class VirtualScheduler:
    """Ordered event heap over virtual time.

    ``run`` drains events in (time, prio, seq) order. All live events
    sharing a timestamp form one *batch*; after each batch the
    ``quiescent`` callback runs once — that is where the fleet router
    fires its hooks, dispatches from the weighted-fair tenant queues into
    freed slots, and starts new replica steps (posting their completion
    events). Actions may post further events, including at the current
    timestamp, and may cancel any not-yet-executed event.
    """

    def __init__(self):
        self._heap: List[Event] = []
        self._seq = itertools.count()
        self.now = 0.0
        self.events_run = 0
        self.events_cancelled = 0  # cancelled events swept past (never run)
        self.batches = 0  # quiescent batches (same-timestamp event groups)

    def post(
        self, time: float, action: Callable[[], None], prio: int = COMPLETION
    ) -> Event:
        if time < self.now:
            raise ValueError(f"event scheduled in the past: {time} < {self.now}")
        ev = Event(float(time), prio, next(self._seq), action)
        heapq.heappush(self._heap, ev)
        return ev

    def cancel(self, ev: Optional[Event]) -> bool:
        """Mark an event dead; it is swept (not executed) when reached.

        Returns True if this call transitioned the event to cancelled.
        Safe on None and on already-cancelled events (idempotent), so
        callers can cancel unconditionally on every teardown path.
        """
        if ev is None or ev.cancelled:
            return False
        ev.cancelled = True
        return True

    @property
    def pending(self) -> int:
        """Heap size, cancelled-but-unswept events included."""
        return len(self._heap)

    @property
    def live_pending(self) -> int:
        """Events that will actually execute if reached."""
        return sum(1 for ev in self._heap if not ev.cancelled)

    def run(
        self,
        until: float = float("inf"),
        quiescent: Optional[Callable[[float], None]] = None,
        max_events: int = 10_000_000,
    ) -> float:
        """Drain events with time <= ``until``; returns final virtual time.

        A timestamp whose events were ALL cancelled advances nothing: the
        clock stays put, no batch is counted, quiescent does not fire.
        """
        while self._heap and self._heap[0].time <= until:
            t = self._heap[0].time
            ran = 0
            while self._heap and self._heap[0].time == t:
                ev = heapq.heappop(self._heap)
                if ev.cancelled:
                    self.events_cancelled += 1
                    continue
                self.now = t
                ran += 1
                self.events_run += 1
                if self.events_run > max_events:
                    raise RuntimeError("VirtualScheduler runaway: max_events exceeded")
                ev.action()
            if ran == 0:
                continue
            self.batches += 1
            if quiescent is not None:
                quiescent(t)
        return self.now
