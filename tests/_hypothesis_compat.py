"""Degrade gracefully when ``hypothesis`` is not installed.

Property tests import ``given``/``settings``/``st`` from here instead of from
``hypothesis`` directly. With hypothesis present this module is a pure
re-export. On a bare environment the shim below replays each property test
over a small deterministic sample drawn from a miniature strategy
implementation — far weaker than real shrinking/search, but the invariants
still execute and the suite collects.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised implicitly when hypothesis exists
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect

    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def example(self, rng):
            return self._sample(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value=0, max_value=1 << 16):
            return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def lists(elements, min_size=0, max_size=16, **_kw):
            def sample(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.example(rng) for _ in range(n)]

            return _Strategy(sample)

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])

    st = _Strategies()

    def given(*strategies, **kw_strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper():
                rng = np.random.default_rng(0)
                for _ in range(getattr(wrapper, "_max_examples", 10)):
                    drawn = [s.example(rng) for s in strategies]
                    named = {k: s.example(rng) for k, s in kw_strategies.items()}
                    fn(*drawn, **named)

            # pytest must see a zero-arg signature (not the wrapped one) or it
            # will hunt for fixtures named after the property arguments
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper

        return deco

    def settings(max_examples=10, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco
