"""Software far-tier prefetch engine + the paper's accuracy/coverage accounting.

TPUs have no hardware prefetcher into HBM; the serving engine prefetches
far-tier blocks (KV pages, experts, embedding rows) ahead of the decode step
and overlaps the host->HBM copy with compute. The paper's §6 accounting maps
verbatim (CL -> block):

  Accuracy = 1 - unused_prefetched_evicted / total_prefetched
  Coverage = (total_prefetched - unused_evicted)
           / (total_blocks_brought_in - unused_evicted)

Predictors (selectable, mirroring the L2-prefetcher taxonomy):
  * nextline — block b -> b+1 (sequential KV walks: near-perfect)
  * stride   — per-stream stride detection
  * markov   — first-order successor table (router/embedding streams)

The paper's headline finding — high accuracy but LOW coverage on irregular
streams, with real bandwidth overhead — reproduces here: a markov table
covers only repeated transitions, and every wrong prefetch costs a far-tier
fetch (benchmarks/fig21/fig22).
"""
from __future__ import annotations

import collections
import dataclasses

import numpy as np


@dataclasses.dataclass
class PrefetchStats:
    total_prefetched: int = 0
    unused_evicted: int = 0
    used_prefetches: int = 0
    demand_fetches: int = 0  # far-tier fetches NOT covered by a prefetch

    @property
    def accuracy(self) -> float:
        if self.total_prefetched == 0:
            return 1.0
        return 1.0 - self.unused_evicted / self.total_prefetched

    @property
    def coverage(self) -> float:
        brought_in = self.total_prefetched + self.demand_fetches
        denom = brought_in - self.unused_evicted
        if denom <= 0:
            return 0.0
        return (self.total_prefetched - self.unused_evicted) / denom

    @property
    def bw_overhead(self) -> float:
        """Extra blocks moved vs. a perfect (demand-only) fetcher."""
        useful = self.used_prefetches + self.demand_fetches
        return (self.total_prefetched + self.demand_fetches) / max(useful, 1) - 1.0


class PrefetchEngine:
    def __init__(self, predictor: str = "nextline", buffer_blocks: int = 64, degree: int = 2):
        assert predictor in ("nextline", "stride", "markov", "off")
        self.predictor = predictor
        self.buffer = collections.OrderedDict()  # block_id -> used flag (LRU)
        self.capacity = buffer_blocks
        self.degree = degree
        self.stats = PrefetchStats()
        self._last: int | None = None
        self._stride: int = 1
        self._markov: dict[int, collections.Counter] = collections.defaultdict(
            collections.Counter
        )

    # ------------------------------------------------------------------
    def _predict(self, block: int) -> list[int]:
        if self.predictor == "off":
            return []
        if self.predictor == "nextline":
            return [block + i + 1 for i in range(self.degree)]
        if self.predictor == "stride":
            return [block + (i + 1) * self._stride for i in range(self.degree)]
        succ = self._markov.get(block)
        if not succ:
            return []
        # confidence gate: only prefetch successors seen repeatedly AND
        # dominating the transition mass — this is what makes real L2
        # prefetchers ACCURATE but LOW-COVERAGE on irregular streams
        # (paper Fig. 22): confident predictions are rare.
        total = sum(succ.values())
        return [
            b
            for b, c in succ.most_common(self.degree)
            if c >= 2 and c / total >= 0.5
        ]

    def _insert(self, block: int):
        if block in self.buffer:
            return
        self.stats.total_prefetched += 1
        self.buffer[block] = False
        if len(self.buffer) > self.capacity:
            _, used = self.buffer.popitem(last=False)
            if not used:
                self.stats.unused_evicted += 1

    # ------------------------------------------------------------------
    def access(self, block: int, *, is_far: bool) -> bool:
        """Demand access to ``block``. Returns True if a prefetch covered it.

        Call for every far-tier-eligible access; near-tier (is_far=False)
        accesses only train the predictor.
        """
        covered = False
        if is_far:
            if block in self.buffer:
                if not self.buffer[block]:
                    self.stats.used_prefetches += 1
                self.buffer[block] = True
                self.buffer.move_to_end(block)
                covered = True
            else:
                self.stats.demand_fetches += 1
        # train + issue
        if self._last is not None:
            self._stride = block - self._last or self._stride
            self._markov[self._last][block] += 1
        self._last = block
        for p in self._predict(block):
            if 0 <= p:
                self._insert(p)
        return covered

    def access_many(self, blocks, far_mask) -> int:
        hits = 0
        for b, f in zip(np.asarray(blocks).reshape(-1), np.asarray(far_mask).reshape(-1)):
            hits += bool(self.access(int(b), is_far=bool(f)))
        return hits
