"""Production memory tracing (paper §6.2-§6.3): windowed attach/detach block
traces + the cache-simulator validation of Table 6.

The paper's PIN tool attaches for microseconds, detaches, and stitches many
short windows from multiple hosts into one representative trace, validated by
replaying it through a cache simulator and comparing the L1D hit ratio and
R:W ratio against production counters (errors <= ~5%).

Here the tracer attaches to the serving/training engine's block-access
stream for ``window_len`` steps every ``period`` steps (overhead bound =
window_len / period), stitches windows, and ``CacheSim`` replays the stitched
trace through an LRU block cache to validate against live statistics.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class TraceWindow:
    start_step: int
    blocks: np.ndarray  # int64
    is_write: np.ndarray  # bool
    # per-access stream id (decode slot / request / trace lane), int64.
    # None on traces recorded before stream tagging; consumers must treat
    # that as "one unknown stream", never as "stream 0 of many" — training
    # per-stream predictors on an untagged interleaved trace is exactly the
    # aggregate-stream contamination core/prefetch.py exists to avoid.
    stream: Optional[np.ndarray] = None


class MemTracer:
    def __init__(self, window_len: int = 20, period: int = 100):
        assert window_len <= period
        self.window_len = window_len
        self.period = period
        self.step = 0
        self._open: Optional[list] = None
        self._open_start = 0
        self.windows: List[TraceWindow] = []

    @property
    def attached(self) -> bool:
        return self.step % self.period < self.window_len

    def tick(self):
        self.step += 1

    def record(self, blocks, is_write=False, stream=0):
        """Called by the engine for every batch of block accesses; cheap
        (appends) only while attached — the low-overhead property.

        ``stream`` tags every access in the batch with the logical stream
        it belongs to (decode slot / request id) so trace consumers — the
        prefetcher's successor training above all — can recover per-stream
        order from the interleaved window."""
        if not self.attached:
            if self._open is not None:
                self._flush()
            return
        if self._open is None:
            self._open = []
            self._open_start = self.step
        b = np.asarray(blocks).reshape(-1)
        w = np.broadcast_to(np.asarray(is_write), b.shape)
        s = np.broadcast_to(np.asarray(stream), b.shape)
        self._open.append((b.astype(np.int64), w.astype(bool), s.astype(np.int64)))

    def _flush(self):
        if self._open:
            bs = np.concatenate([x[0] for x in self._open])
            ws = np.concatenate([x[1] for x in self._open])
            ss = np.concatenate([x[2] for x in self._open])
            self.windows.append(TraceWindow(self._open_start, bs, ws, ss))
        self._open = None

    def stitch(self) -> TraceWindow:
        """Concatenate all windows into one representative trace."""
        if self._open is not None:
            self._flush()
        if not self.windows:
            return TraceWindow(
                0, np.zeros(0, np.int64), np.zeros(0, bool), np.zeros(0, np.int64)
            )
        streams = [
            w.stream
            if w.stream is not None
            else np.zeros(w.blocks.size, np.int64)
            for w in self.windows
        ]
        return TraceWindow(
            self.windows[0].start_step,
            np.concatenate([w.blocks for w in self.windows]),
            np.concatenate([w.is_write for w in self.windows]),
            np.concatenate(streams),
        )

    def overhead_frac(self) -> float:
        return self.window_len / self.period


class CacheSim:
    """LRU block cache (the paper's 'simple cache simulator')."""

    def __init__(self, capacity_blocks: int):
        self.capacity = capacity_blocks
        self.lru: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0

    def access(self, block: int):
        if block in self.lru:
            self.lru.move_to_end(block)
            self.hits += 1
        else:
            self.misses += 1
            self.lru[block] = True
            if len(self.lru) > self.capacity:
                self.lru.popitem(last=False)

    def run(self, trace: TraceWindow) -> dict:
        for b in trace.blocks:
            self.access(int(b))
        reads = int((~trace.is_write).sum())
        writes = int(trace.is_write.sum())
        return {
            "hit_ratio": self.hits / max(self.hits + self.misses, 1),
            "rw_ratio": reads / max(writes, 1),
        }


def validate_trace(trace: TraceWindow, live_hit_ratio: float, live_rw_ratio: float, capacity_blocks: int) -> dict:
    """Table 6: simulated-vs-live hit ratio and R:W errors."""
    sim = CacheSim(capacity_blocks).run(trace)
    return {
        "sim_hit_ratio": sim["hit_ratio"],
        "live_hit_ratio": live_hit_ratio,
        "hit_ratio_error": abs(sim["hit_ratio"] - live_hit_ratio),
        "sim_rw_ratio": sim["rw_ratio"],
        "live_rw_ratio": live_rw_ratio,
        "rw_ratio_error_pct": 100.0 * (sim["rw_ratio"] - live_rw_ratio) / max(live_rw_ratio, 1e-9),
    }
