"""HLO-text cost analysis with loop trip-count accounting.

XLA's HloCostAnalysis (what ``compiled.cost_analysis()`` reports) visits each
instruction ONCE — a lax.scan over 80 layers reports 1/80th of the real
FLOPs. This walker parses the post-SPMD optimized HLO text, recursing through
``while`` bodies (×trip count, recovered from the loop condition's compare
constant), ``fusion``/``call`` computations, and ``conditional`` branches
(max), to produce:

  * flops            — dot/convolution + elementwise, per device
  * bytes            — HBM traffic proxy: operand+output bytes at fusion
                       boundaries (fusion internals stay in registers/VMEM)
  * collective_bytes — per collective type, operand-size sum (assignment
                       convention) + replica-group sizes for effective-
                       traffic refinement in roofline.py

All values are PER DEVICE (post-SPMD HLO is the per-device program).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_ELEMENTWISE_FLOPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "cosine",
    "sine", "floor", "ceil", "round-nearest-afz", "expm1", "log1p", "logistic",
    "atan2", "remainder", "select", "clamp", "compare", "and", "or", "xor", "not",
}


def _shape_elems_bytes(type_str: str):
    """Total (elems, bytes) over possibly-tuple HLO type text."""
    elems = 0
    nbytes = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    out_type: str
    args_text: str
    attrs_text: str
    line: str


_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^=]*?\)|[\w\[\]\{\},\d]+?))\s+"
    r"([\w\-]+)\((.*?)\)(.*)$"
)


def parse_computations(hlo: str) -> Dict[str, List[Op]]:
    """computation name -> list of Ops."""
    comps: Dict[str, List[Op]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        # strip /*index=N*/-style comments: they appear inside tuple types and
        # long operand lists and would break _OP_RE (they contain '=')
        s = re.sub(r"/\*.*?\*/", "", line).strip()
        if not s:
            continue
        if (s.startswith("%") or s.startswith("ENTRY")) and s.endswith("{"):
            m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", s)
            if m:
                cur = m.group(1)
                comps[cur] = []
                if s.startswith("ENTRY"):
                    comps["__entry__"] = comps[cur]
            continue
        if s.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(s)
        if not m:
            continue
        name, out_type, kind, args, attrs = m.groups()
        comps[cur].append(Op(name, kind, out_type, args, attrs, s))
    return comps


def _called_comps(op: Op) -> List[str]:
    """Computations referenced by calls=/to_apply=/body=/condition=/branches."""
    out = []
    for key in ("calls=", "to_apply=", "body=", "condition="):
        m = re.search(key + r"%?([\w\.\-]+)", op.attrs_text)
        if m:
            out.append((key[:-1], m.group(1)))
    m = re.search(r"branch_computations=\{([^}]*)\}", op.attrs_text)
    if m:
        for name in m.group(1).split(","):
            out.append(("branch", name.strip().lstrip("%")))
    return out


_OPERAND_NAME_RE = re.compile(r"%([\w\.\-]+)")


def _operand_types(op: Op, symtab: Optional[Dict[str, str]] = None) -> List[str]:
    """Type strings of each operand.

    Unoptimized HLO prints operand types inline; optimized/compiled HLO
    prints bare ``%name`` references, resolved through ``symtab``
    (instruction name -> out_type within the computation).
    """
    inline = [m.group(0) for m in _SHAPE_RE.finditer(op.args_text)]
    if inline:
        return inline
    if symtab is None:
        return []
    out = []
    for m in _OPERAND_NAME_RE.finditer(op.args_text):
        t = symtab.get(m.group(1))
        if t:
            out.append(t)
    return out


def _dot_flops(op: Op, symtab: Optional[Dict[str, str]] = None) -> float:
    out_elems, _ = _shape_elems_bytes(op.out_type)
    types = _operand_types(op, symtab)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs_text)
    if not types or m is None:
        return 2.0 * out_elems  # fallback
    lhs = _SHAPE_RE.search(types[0])
    lhs_dims = [int(x) for x in lhs.group(2).split(",") if x] if lhs else []
    if not lhs_dims:
        lhs_dims = [1]
    cdim = 1.0
    for ci in (int(x) for x in m.group(1).split(",") if x):
        if ci < len(lhs_dims):
            cdim *= lhs_dims[ci]
    return 2.0 * out_elems * cdim


def _conv_flops(op: Op, symtab: Optional[Dict[str, str]] = None) -> float:
    # approx: 2 * output elems * (kernel spatial elems * in_features)
    ops_types = [
        (m.group(1), m.group(2))
        for t in _operand_types(op, symtab)
        for m in [_SHAPE_RE.search(t)]
        if m
    ]
    out_elems, _ = _shape_elems_bytes(op.out_type)
    if len(ops_types) < 2:
        return 2.0 * out_elems
    k_elems = 1
    for d in ops_types[1][1].split(","):
        if d:
            k_elems *= int(d)
    return 2.0 * out_elems * max(k_elems, 1) / max(out_elems ** 0, 1)


def _collect_cond_ops(
    name: str, comps: Dict[str, List[Op]], seen: Optional[set] = None
) -> List[Op]:
    """Ops of the loop condition, descending through fusions/calls (compiled
    HLO often hides the compare + constant inside a fused computation)."""
    if seen is None:
        seen = set()
    if name in seen or name not in comps:
        return []
    seen.add(name)
    out = []
    for op in comps[name]:
        out.append(op)
        if op.kind in ("fusion", "call"):
            for _, cname in _called_comps(op):
                out.extend(_collect_cond_ops(cname, comps, seen))
    return out


def _trip_count(cond_ops: List[Op]) -> int:
    """Recover scan trip count from the loop condition's compare constant."""
    consts = {}
    for op in cond_ops:
        if op.kind == "constant":
            m = re.search(r"constant\((-?\d+)\)", op.line)
            if m:
                consts[op.name] = int(m.group(1))
    for op in cond_ops:
        if op.kind == "compare":
            names = re.findall(r"%([\w\.\-]+)", op.args_text)
            for n in names:
                if n in consts and consts[n] > 0:
                    return consts[n]
    pos = [v for v in consts.values() if v > 0]
    return max(pos) if pos else 1


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float)
    )
    collective_ops: Dict[str, int] = dataclasses.field(default_factory=lambda: defaultdict(int))
    group_sizes: Dict[str, float] = dataclasses.field(default_factory=dict)
    # bytes attributed to named_scope tags (e.g. "flash_attention_ref"),
    # used for the kernel-adjusted memory term in roofline.py
    tagged_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float)
    )

    def add(self, other: "Cost", times: float = 1.0):
        self.flops += other.flops * times
        self.bytes += other.bytes * times
        self.transcendentals += other.transcendentals * times
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] += v * times
        for k, v in other.collective_ops.items():
            self.collective_ops[k] += int(v * times)
        for k, v in other.tagged_bytes.items():
            self.tagged_bytes[k] += v * times
        self.group_sizes.update(other.group_sizes)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def _group_size(op: Op, total_devices: int) -> int:
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", op.attrs_text)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", op.attrs_text)
    if m:
        return int(m.group(2))
    return total_devices


class HloCostModel:
    def __init__(self, hlo_text: str, total_devices: int = 1, tags: tuple = ("flash_attention_ref",)):
        self.comps = parse_computations(hlo_text)
        self.total_devices = total_devices
        self.tags = tags
        self._memo: Dict[str, Cost] = {}
        self._tag_memo: Dict[str, frozenset] = {}
        # per-computation symbol table: instruction name -> out_type, for
        # resolving bare %name operands in optimized HLO text
        self._symtabs: Dict[str, Dict[str, str]] = {
            cname: {op.name: op.out_type for op in ops}
            for cname, ops in self.comps.items()
        }

    def _fused_slice_discount(self, op: Op, symtab: Dict[str, str]) -> float:
        """Boundary-bytes discount for fusions that slice/update big buffers.

        A fused dynamic-update-slice writes one slice of an aliased scan
        stack; a fused dynamic-slice reads one. The boundary accounting
        charged the full stack on both sides — subtract it back, keep 2x the
        slice region.
        """
        discount = 0.0
        for _, cname in _called_comps(op):
            cops = self.comps.get(cname, [])
            csym = self._symtabs.get(cname, {})
            for cop in cops:
                base = cop.kind.split(".")[0]
                if base == "dynamic-update-slice":
                    types = _operand_types(cop, csym)
                    big = _shape_elems_bytes(cop.out_type)[1]
                    upd = _shape_elems_bytes(types[1])[1] if len(types) > 1 else 0.0
                    # full stack appeared as operand AND output; real traffic 2*upd
                    discount += max(2.0 * big - 2.0 * upd, 0.0)
                elif base in ("dynamic-slice", "gather"):
                    types = _operand_types(cop, csym)
                    big = _shape_elems_bytes(types[0])[1] if types else 0.0
                    out = _shape_elems_bytes(cop.out_type)[1]
                    # operand param was charged at the boundary; real read = out
                    discount += max(big - out, 0.0)
        return discount

    def _comp_tags(self, name: str) -> frozenset:
        """Tags appearing anywhere in a computation (for fusion attribution:
        the fusion boundary op often carries only the root op's metadata)."""
        if name in self._tag_memo:
            return self._tag_memo[name]
        self._tag_memo[name] = frozenset()  # cycle guard
        found = {t for t in self.tags for op in self.comps.get(name, []) if t in op.line}
        for op in self.comps.get(name, []):
            if op.kind in ("fusion", "call"):
                for _, cname in _called_comps(op):
                    found |= self._comp_tags(cname)
        self._tag_memo[name] = frozenset(found)
        return self._tag_memo[name]

    def computation_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Cost()  # cycle guard
        cost = Cost()
        symtab = self._symtabs.get(name, {})
        for op in self.comps.get(name, []):
            cost.add(self._op_cost(op, symtab))
        self._memo[name] = cost
        return cost

    def _op_cost(self, op: Op, symtab: Dict[str, str]) -> Cost:
        c = Cost()
        kind = op.kind
        if kind in ("parameter", "constant", "get-tuple-element", "tuple", "bitcast", "after-all", "iota"):
            return c
        if kind == "while":
            body = cond = None
            for role, cname in _called_comps(op):
                if role == "body":
                    body = cname
                elif role == "condition":
                    cond = cname
            trips = _trip_count(_collect_cond_ops(cond, self.comps)) if cond else 1
            if body:
                c.add(self.computation_cost(body), times=max(trips, 1))
            if cond:
                c.add(self.computation_cost(cond), times=max(trips, 1))
            return c
        if kind == "conditional":
            branches = [self.computation_cost(n) for _, n in _called_comps(op)]
            if branches:
                best = max(branches, key=lambda b: b.flops + b.bytes)
                c.add(best)
            return c
        if kind in ("fusion", "call", "async-start"):
            sub_tags = set()
            for _, cname in _called_comps(op):
                sub = self.computation_cost(cname)
                sub_tags |= self._comp_tags(cname)
                if kind == "fusion":
                    # fusion internals live in registers/VMEM: count their
                    # flops/transcendentals/collectives but NOT their bytes
                    # (nor tagged bytes) — HBM traffic is only the boundary
                    sub = dataclasses.replace(
                        sub,
                        bytes=0.0,
                        collective_bytes=dict(sub.collective_bytes),
                        collective_ops=dict(sub.collective_ops),
                        tagged_bytes={},
                    )
                c.add(sub)
            # fusion boundary traffic, slice-aware: a fused dynamic-(update-)
            # slice on a scan stack touches one slice, not the whole buffer
            _, ob = _shape_elems_bytes(op.out_type)
            ib = sum(_shape_elems_bytes(t)[1] for t in _operand_types(op, symtab))
            total = ob + ib
            if kind == "fusion":
                total -= self._fused_slice_discount(op, symtab)
                total = max(total, 0.0)
            c.bytes += total
            for t in self.tags:
                if t in op.line or t in sub_tags:
                    c.tagged_bytes[t] += total
            return c

        # leaf op
        out_elems, out_bytes = _shape_elems_bytes(op.out_type)
        in_bytes = sum(_shape_elems_bytes(t)[1] for t in _operand_types(op, symtab))
        base = kind.split(".")[0]
        # slice-aware traffic: these ops touch only the slice/rows they
        # address, not the whole (often scan-stack-sized) operand buffer
        if base in ("dynamic-slice", "slice", "gather"):
            sliced = 2.0 * out_bytes  # read region + write out
            c.bytes += sliced
            for t in self.tags:
                if t in op.line:
                    c.tagged_bytes[t] += sliced
            return c
        if base in ("dynamic-update-slice", "scatter"):
            types = _operand_types(op, symtab)
            upd_idx = 1 if base == "dynamic-update-slice" else 2
            upd = _shape_elems_bytes(types[upd_idx])[1] if len(types) > upd_idx else out_bytes
            sliced = 2.0 * upd  # read + write the updated region (in-place alias)
            c.bytes += sliced
            for t in self.tags:
                if t in op.line:
                    c.tagged_bytes[t] += sliced
            return c
        if base.endswith("-done") or base.endswith("-update"):
            return c  # async completion: traffic already charged at -start
        for coll in COLLECTIVES:
            if base.startswith(coll):
                c.collective_bytes[coll] += in_bytes
                c.collective_ops[coll] += 1
                c.group_sizes[coll] = _group_size(op, self.total_devices)
                c.bytes += in_bytes + out_bytes
                return c
        if base == "dot":
            c.flops += _dot_flops(op, symtab)
        elif base == "convolution":
            c.flops += _conv_flops(op, symtab)
        elif base in ("reduce", "reduce-window"):
            c.flops += sum(_shape_elems_bytes(t)[0] for t in _operand_types(op, symtab)) / 2
        elif base in _ELEMENTWISE_FLOPS:
            c.flops += out_elems
            if base in ("exponential", "log", "tanh", "rsqrt", "sqrt", "logistic", "expm1", "log1p", "cosine", "sine", "power"):
                c.transcendentals += out_elems
        c.bytes += in_bytes + out_bytes
        for t in self.tags:
            if t in op.line:
                c.tagged_bytes[t] += in_bytes + out_bytes
        return c

    # ------------------------------------------------------------------
    def entry_cost(self) -> Cost:
        name = "__entry__"
        if name not in self.comps:
            # fall back: the computation reached by no other (heuristic: first)
            name = next(iter(self.comps))
        # analyze via the entry list directly
        cost = Cost()
        symtab = self._symtabs.get(name, {})
        for op in self.comps[name]:
            cost.add(self._op_cost(op, symtab))
        return cost


def analyze(hlo_text: str, total_devices: int = 1) -> Cost:
    return HloCostModel(hlo_text, total_devices).entry_cost()
