"""Public tiered-gather ops: lane padding + the two-tier composition.

``tiered_lookup_segments`` is the serving decode path's entry point: ONE
fused kernel pass resolves a whole engine step — every active slot's page
ids concatenated, with a per-gather segment index — against the device
tier map, gathers each row from the near (bf16/f32) or far (int8 +
per-row scale) store with the dequant fused in, and accumulates a
per-segment (near, far) hit pair on device. The counters stay device
arrays: nothing here forces a host sync, which is the whole point — the
engine drains them once per profiler window.

``tiered_lookup_counted`` is the per-call variant (one segment, counters
returned as int32 scalars); ``tiered_lookup`` keeps the rows-only
signature for callers that don't consume counters.

Mixed prefill/decode steps (continuous batching) change NOTHING here: a
prefill-chunk segment is just another (slot, pages) run in the same ragged
pass. The per-segment role (decode vs prefill) lives entirely in the
counter plane — ``TieredKVCache.lookup_segments(role_idx=...)`` scatters
the same per-segment hit pairs into a role-indexed accumulator alongside
the slot/tenant rows — so the kernel signature and the 1-dispatch budget
are untouched by the prefill/decode mix.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels._interpret import resolve_interpret
from repro.kernels.tiered_gather.kernel import (
    gather_rows_kernel,
    tiered_gather_kernel,
    tiered_segmented_kernel,
)

LANE = 128


def _pad_lanes(x):
    pad = (-x.shape[-1]) % LANE
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    return x, pad


def _nonempty(x, dtype):
    """A (>=1, D) store: an empty tier still needs one DMA-able dummy row."""
    if x.shape[0] == 0:
        return jnp.zeros((1, x.shape[1]), dtype)
    return x.astype(dtype)


def gather_rows(src, ids, scales=None, *, interpret: Optional[bool] = None):
    """src: (M, D); ids: (N,) -> (N, D) f32 (dequantized if scales given)."""
    return _gather_rows(src, ids, scales, interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _gather_rows(src, ids, scales, *, interpret):
    d = src.shape[1]
    srcp, _ = _pad_lanes(src)
    sc = None if scales is None else scales.reshape(-1, 1).astype(jnp.float32)
    out = gather_rows_kernel(srcp, ids.astype(jnp.int32), sc, interpret=interpret)
    return out[:, :d]


def tiered_lookup_counted(hot, cold_q, cold_scales, tier, slot, ids,
                          *, interpret: Optional[bool] = None):
    """Two-tier lookup: near rows from ``hot`` (bf16/f32), far rows from the
    int8 ``cold_q``+``cold_scales`` store, selected by ``tier``/``slot`` maps.

    Returns (rows (N, D) f32, near_hits int32 scalar, far_hits int32 scalar):
    the hit split is counted inside the kernel, at the access point. On real
    hardware the two gathers run on separate streams (HBM vs host DMA); here
    both tiers are DMA'd through one fused pass and merged by the tier bit.
    """
    if ids.shape[0] == 0:
        z = jnp.zeros((), jnp.int32)
        return jnp.zeros((0, hot.shape[1]), jnp.float32), z, z
    rows, near = _tiered_lookup(
        hot, cold_q, cold_scales, tier, slot, ids, interpret=resolve_interpret(interpret)
    )
    return rows, near, jnp.int32(ids.shape[0]) - near


def tiered_lookup_segments(hot, cold_q, cold_scales, tier, slot, ids, seg_of,
                           n_segments: int, *, interpret: Optional[bool] = None):
    """Step-wide ragged lookup: one dispatch for any number of segments.

    ``ids`` (N,) is the concatenation of every segment's page ids and
    ``seg_of`` (N,) assigns each gather to a segment in [0, n_segments).
    Returns (rows (N, D) f32, seg_hits (n_segments, 2) int32) with
    seg_hits[:, 0] the near hits and seg_hits[:, 1] the far hits counted
    inside the kernel. Both results are DEVICE arrays — no host sync —
    so a caller batching a fixed segment count sees stable shapes and the
    counters can feed a device-resident accumulator plane.
    """
    n_segments = int(n_segments)
    if ids.shape[0] == 0:
        return (
            jnp.zeros((0, hot.shape[1]), jnp.float32),
            jnp.zeros((n_segments, 2), jnp.int32),
        )
    return _tiered_lookup_segments(
        hot, cold_q, cold_scales, tier, slot, ids, seg_of,
        n_segments=n_segments, interpret=resolve_interpret(interpret),
    )


@functools.partial(jax.jit, static_argnames=("n_segments", "interpret"))
def _tiered_lookup_segments(hot, cold_q, cold_scales, tier, slot, ids, seg_of,
                            *, n_segments, interpret):
    d = hot.shape[1]
    ids = ids.astype(jnp.int32)
    t = tier[ids].astype(jnp.int32)
    s = slot[ids].astype(jnp.int32)
    hotp, _ = _pad_lanes(_nonempty(hot, hot.dtype))
    coldp, _ = _pad_lanes(_nonempty(cold_q, jnp.int8))
    scales = cold_scales.reshape(-1).astype(jnp.float32)
    if scales.shape[0] == 0:
        scales = jnp.ones((1,), jnp.float32)
    rows, seg_hits = tiered_segmented_kernel(
        hotp,
        coldp,
        scales.reshape(-1, 1),
        t,
        jnp.where(t == 0, s, 0),
        jnp.where(t == 1, s, 0),
        seg_of.astype(jnp.int32),
        n_segments,
        interpret=interpret,
    )
    return rows[:, :d], seg_hits


def tiered_lookup(hot, cold_q, cold_scales, tier, slot, ids,
                  *, interpret: Optional[bool] = None):
    """Rows-only view of :func:`tiered_lookup_counted`."""
    return tiered_lookup_counted(
        hot, cold_q, cold_scales, tier, slot, ids, interpret=interpret
    )[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def _tiered_lookup(hot, cold_q, cold_scales, tier, slot, ids, *, interpret):
    d = hot.shape[1]
    ids = ids.astype(jnp.int32)
    t = tier[ids].astype(jnp.int32)
    s = slot[ids].astype(jnp.int32)
    hotp, _ = _pad_lanes(_nonempty(hot, hot.dtype))
    coldp, _ = _pad_lanes(_nonempty(cold_q, jnp.int8))
    scales = cold_scales.reshape(-1).astype(jnp.float32)
    if scales.shape[0] == 0:
        scales = jnp.ones((1,), jnp.float32)
    rows, hits = tiered_gather_kernel(
        hotp,
        coldp,
        scales.reshape(-1, 1),
        t,
        jnp.where(t == 0, s, 0),
        jnp.where(t == 1, s, 0),
        interpret=interpret,
    )
    return rows[:, :d], hits[0, 0]
