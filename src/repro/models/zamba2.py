"""Zamba2 hybrid: Mamba2 backbone + a SHARED attention block applied every
``shared_attn_every`` layers (arXiv:2411.15242).

The shared block is one parameter set reused at every application depth —
the model-level mirror of the paper's shared-L2 idea (identical content →
one shared structure). Input to the shared block is concat(hidden, original
embedding) (2*d), projected through attention (32 heads of 64) and a 2d->d_ff
MLP back into the residual stream. Each application keeps its own KV cache
(same params, different activations).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.mesh import BATCH, MODEL, shard
from repro.models import common, mamba2

Array = jax.Array


def n_attn_apps(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.shared_attn_every


def _is_attn_layer(cfg: ModelConfig, i) -> Array:
    return (i % cfg.shared_attn_every) == cfg.shared_attn_every - 1


# ---------------------------------------------------------------------------
# init


def _init_shared(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    u = 2 * d  # concat(hidden, embedding)
    hd = cfg.head_dim  # 64
    q_dim = cfg.n_heads * hd  # 2048
    kv_dim = cfg.n_kv_heads * hd
    ks = jax.random.split(key, 8)
    return {
        "ln1": jnp.ones((u,), dtype),
        "wq": common.dense_init(ks[0], (u, q_dim), dtype=dtype),
        "wk": common.dense_init(ks[1], (u, kv_dim), dtype=dtype),
        "wv": common.dense_init(ks[2], (u, kv_dim), dtype=dtype),
        "wo": common.dense_init(ks[3], (q_dim, d), scale=0.1, dtype=dtype),
        "ln2": jnp.ones((u,), dtype),
        "w_gate": common.dense_init(ks[4], (u, cfg.d_ff), dtype=dtype),
        "w_up": common.dense_init(ks[5], (u, cfg.d_ff), dtype=dtype),
        "w_down": common.dense_init(ks[6], (cfg.d_ff, d), scale=0.1, dtype=dtype),
    }


def init(key, cfg: ModelConfig) -> dict:
    dtype = common.dt(cfg.param_dtype)
    ke, kl, ksh, kh = jax.random.split(key, 4)
    layers = jax.vmap(lambda k: mamba2.init_block(k, cfg, dtype))(
        jax.random.split(kl, cfg.n_layers)
    )
    return {
        "embed": common.embed_init(ke, (cfg.padded_vocab, cfg.d_model), dtype),
        "layers": layers,
        "shared": _init_shared(ksh, cfg, dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "lm_head": common.dense_init(kh, (cfg.d_model, cfg.padded_vocab), dtype=dtype),
    }


def param_specs(cfg: ModelConfig) -> dict:
    lyr = jax.tree.map(
        lambda s: (None,) + tuple(s), mamba2.block_specs(cfg), is_leaf=lambda s: isinstance(s, tuple)
    )
    return {
        "embed": (MODEL, None),
        "layers": lyr,
        "shared": {
            "ln1": (None,),
            "wq": (None, MODEL),
            "wk": (None, MODEL),
            "wv": (None, MODEL),
            "wo": (MODEL, None),
            "ln2": (None,),
            "w_gate": (None, MODEL),
            "w_up": (None, MODEL),
            "w_down": (MODEL, None),
        },
        "final_norm": (None,),
        "lm_head": (None, MODEL),
    }


# ---------------------------------------------------------------------------
# shared attention block


def _shared_qkv(sh: dict, cfg: ModelConfig, u: Array, positions: Array):
    b, t, _ = u.shape
    hd = cfg.head_dim
    un = common.rms_norm(u, sh["ln1"], cfg.norm_eps)
    q = (un @ sh["wq"]).reshape(b, t, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = (un @ sh["wk"]).reshape(b, t, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = (un @ sh["wv"]).reshape(b, t, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, BATCH, MODEL, None, None)
    k = shard(k, BATCH, MODEL, None, None)
    return un, q, k, v


def shared_specs(cfg: ModelConfig) -> dict:
    return param_specs(cfg)["shared"]


def shared_block_train(sh: dict, cfg: ModelConfig, h: Array, emb0: Array, positions: Array):
    sh = common.constrain_tree(sh, shared_specs(cfg), common.dt(cfg.compute_dtype))
    u = jnp.concatenate([h, emb0], axis=-1)
    un, q, k, v = _shared_qkv(sh, cfg, u, positions)
    o = common.attention_chunked(q, k, v, causal=True, block_k=1024)
    b, hh, t, hd = o.shape
    attn_out = (o.transpose(0, 2, 1, 3).reshape(b, t, hh * hd) @ sh["wo"]).astype(h.dtype)
    h = h + attn_out
    un2 = common.rms_norm(jnp.concatenate([h, emb0], axis=-1), sh["ln2"], cfg.norm_eps)
    return h + common.swiglu(un2, sh["w_gate"], sh["w_up"], sh["w_down"])


def shared_block_prefill(sh, cfg, h, emb0, positions, max_len: int):
    u = jnp.concatenate([h, emb0], axis=-1)
    un, q, k, v = _shared_qkv(sh, cfg, u, positions)
    o = common.attention_chunked(q, k, v, causal=True, block_k=1024)
    b, hh, t, hd = o.shape
    h = h + (o.transpose(0, 2, 1, 3).reshape(b, t, hh * hd) @ sh["wo"]).astype(h.dtype)
    un2 = common.rms_norm(jnp.concatenate([h, emb0], axis=-1), sh["ln2"], cfg.norm_eps)
    h = h + common.swiglu(un2, sh["w_gate"], sh["w_up"], sh["w_down"])
    pad = max_len - t
    k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))) if pad > 0 else k
    v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))) if pad > 0 else v
    return h, (k.astype(jnp.bfloat16), v.astype(jnp.bfloat16))


def shared_block_decode(sh, cfg, h, emb0, k_cache, v_cache, lengths):
    """h, emb0: (B,1,D); caches (B,Hkv,S,hd). Returns (h', k', v')."""
    b = h.shape[0]
    positions = lengths[:, None].astype(jnp.int32)
    u = jnp.concatenate([h, emb0], axis=-1)
    un, q, k, v = _shared_qkv(sh, cfg, u, positions)
    idx = jnp.arange(b)
    k_cache = k_cache.at[idx, :, lengths, :].set(k[:, :, 0, :].astype(k_cache.dtype))
    v_cache = v_cache.at[idx, :, lengths, :].set(v[:, :, 0, :].astype(v_cache.dtype))
    o = common.attention_decode(q, k_cache.astype(q.dtype), v_cache.astype(q.dtype), lengths + 1)
    hh, hd = o.shape[1], o.shape[3]
    h = h + (o.transpose(0, 2, 1, 3).reshape(b, 1, hh * hd) @ sh["wo"]).astype(h.dtype)
    un2 = common.rms_norm(jnp.concatenate([h, emb0], axis=-1), sh["ln2"], cfg.norm_eps)
    h = h + common.swiglu(un2, sh["w_gate"], sh["w_up"], sh["w_down"])
    return h, k_cache, v_cache


# ---------------------------------------------------------------------------
# full model


def _embed(params, cfg, tokens):
    h = jnp.take(params["embed"], tokens, axis=0).astype(common.dt(cfg.compute_dtype))
    return shard(h, BATCH, None, None)


def _logits(params, cfg, h):
    h = common.rms_norm(h, params["final_norm"], cfg.norm_eps)
    return shard(
        jnp.einsum("bsd,dv->bsv", h, params["lm_head"].astype(h.dtype), preferred_element_type=jnp.float32),
        BATCH, None, MODEL,
    )


def _split_groups(cfg: ModelConfig, tree):
    """Stacked (L, ...) layer tree -> ((G, k, ...) grouped, (R, ...) tail)."""
    k = cfg.shared_attn_every
    g = cfg.n_layers // k
    grouped = jax.tree.map(lambda x: x[: g * k].reshape((g, k) + x.shape[1:]), tree)
    tail = jax.tree.map(lambda x: x[g * k :], tree)
    return grouped, tail


def forward(params, cfg: ModelConfig, tokens=None, embeds=None, positions=None, *, remat=None, **_):
    h = _embed(params, cfg, tokens) if embeds is None else embeds.astype(common.dt(cfg.compute_dtype))
    emb0 = h
    b, t, d = h.shape
    if positions is None:
        positions = common.causal_positions(b, t)
    sh = params["shared"]
    use_remat = cfg.remat if remat is None else remat

    def mamba_layer(h, lp):
        m, _ = mamba2.apply(lp, cfg, h)
        return shard(h + m, BATCH, None, None)

    mamba_blk = common.maybe_remat(mamba_layer, use_remat, cfg.remat_policy)

    def group(h, gp):
        # k mamba layers, then one application of the shared attention block
        h, _ = jax.lax.scan(lambda c, lp: (mamba_blk(c, lp), None), h, gp)
        h = shared_block_train(sh, cfg, h, emb0, positions)
        return shard(h, BATCH, None, None)

    grp = common.maybe_remat(group, use_remat, cfg.remat_policy)
    grouped, tail = _split_groups(cfg, params["layers"])
    h, _ = jax.lax.scan(lambda c, gp: (grp(c, gp), None), h, grouped)
    h, _ = jax.lax.scan(lambda c, lp: (mamba_blk(c, lp), None), h, tail)
    return _logits(params, cfg, h)


def features(params, cfg: ModelConfig, tokens=None, embeds=None, positions=None, *, remat=None, **_):
    """Trunk -> (post-norm h, lm_head weight) for the fused CE path."""
    h = _embed(params, cfg, tokens) if embeds is None else embeds.astype(common.dt(cfg.compute_dtype))
    emb0 = h
    b, t, d = h.shape
    if positions is None:
        positions = common.causal_positions(b, t)
    sh = params["shared"]
    use_remat = cfg.remat if remat is None else remat

    def mamba_layer(h, lp):
        m, _ = mamba2.apply(lp, cfg, h)
        return shard(h + m, BATCH, None, None)

    mamba_blk = common.maybe_remat(mamba_layer, use_remat, cfg.remat_policy)

    def group(h, gp):
        h, _ = jax.lax.scan(lambda c, lp: (mamba_blk(c, lp), None), h, gp)
        h = shared_block_train(sh, cfg, h, emb0, positions)
        return shard(h, BATCH, None, None)

    grp = common.maybe_remat(group, use_remat, cfg.remat_policy)
    grouped, tail = _split_groups(cfg, params["layers"])
    h, _ = jax.lax.scan(lambda c, gp: (grp(c, gp), None), h, grouped)
    h, _ = jax.lax.scan(lambda c, lp: (mamba_blk(c, lp), None), h, tail)
    h = common.rms_norm(h, params["final_norm"], cfg.norm_eps)
    return h, shard(params["lm_head"], None, MODEL)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    napp = n_attn_apps(cfg)
    hd = cfg.head_dim
    ms = mamba2.init_state(cfg, batch)
    return {
        "k": jnp.zeros((napp, batch, cfg.n_kv_heads, max_len, hd), dtype),
        "v": jnp.zeros((napp, batch, cfg.n_kv_heads, max_len, hd), dtype),
        "conv": jnp.zeros((cfg.n_layers,) + ms["conv"].shape, jnp.float32),
        "ssm": jnp.zeros((cfg.n_layers,) + ms["ssm"].shape, jnp.float32),
        "lengths": jnp.zeros((batch,), jnp.int32),
    }


def cache_specs(cfg: ModelConfig, model_axis: int = 16) -> dict:
    kv = (None, BATCH, MODEL, None, None) if cfg.n_kv_heads % model_axis == 0 else (None, BATCH, None, MODEL, None)
    return {
        "k": kv,
        "v": kv,
        "conv": (None, BATCH, None, None),
        "ssm": (None, BATCH, MODEL, None, None),
        "lengths": (BATCH,),
    }


def prefill(params, cfg: ModelConfig, tokens=None, embeds=None, *, max_len: int, **_):
    h = _embed(params, cfg, tokens) if embeds is None else embeds.astype(common.dt(cfg.compute_dtype))
    emb0 = h
    b, t, d = h.shape
    positions = common.causal_positions(b, t)
    sh = params["shared"]

    def mamba_layer(h, lp):
        m, st = mamba2.apply(lp, cfg, h)
        return shard(h + m, BATCH, None, None), st

    def group(h, gp):
        h, st = jax.lax.scan(mamba_layer, h, gp)
        h, (k, v) = shared_block_prefill(sh, cfg, h, emb0, positions, max_len)
        return shard(h, BATCH, None, None), (st, k, v)

    grouped, tail = _split_groups(cfg, params["layers"])
    h, (g_st, ks, vs) = jax.lax.scan(group, h, grouped)
    h, t_st = jax.lax.scan(mamba_layer, h, tail)
    # restack per-layer states: (G,k,...) + (R,...) -> (L,...)
    merge = lambda a, b_: jnp.concatenate([a.reshape((-1,) + a.shape[2:]), b_], axis=0)
    convs = merge(g_st["conv"], t_st["conv"])
    ssms = merge(g_st["ssm"], t_st["ssm"])
    cache = {
        "k": ks,
        "v": vs,
        "conv": convs,
        "ssm": ssms,
        "lengths": jnp.full((b,), t, jnp.int32),
    }
    return _logits(params, cfg, h), cache


def decode_step(params, cfg: ModelConfig, cache: dict, tokens: Array):
    h = _embed(params, cfg, tokens)  # (B,1,D)
    emb0 = h
    lengths = cache["lengths"]
    sh = params["shared"]
    k = cfg.shared_attn_every
    g = cfg.n_layers // k

    def mamba_layer(h, xs):
        lp, conv, ssm = xs
        m, st = mamba2.apply(lp, cfg, h, {"conv": conv, "ssm": ssm})
        return h + m, st

    grouped, tail = _split_groups(cfg, params["layers"])
    regroup = lambda x: x[: g * k].reshape((g, k) + x.shape[1:])
    conv_g, conv_t = regroup(cache["conv"]), cache["conv"][g * k :]
    ssm_g, ssm_t = regroup(cache["ssm"]), cache["ssm"][g * k :]

    def group(h, xs):
        gp, conv, ssm, kc, vc = xs
        h, st = jax.lax.scan(mamba_layer, h, (gp, conv, ssm))
        h, kc, vc = shared_block_decode(sh, cfg, h, emb0, kc, vc, lengths)
        return h, (st, kc, vc)

    h, (g_st, ks, vs) = jax.lax.scan(group, h, (grouped, conv_g, ssm_g, cache["k"], cache["v"]))
    h, t_st = jax.lax.scan(mamba_layer, h, (tail, conv_t, ssm_t))
    merge = lambda a, b_: jnp.concatenate([a.reshape((-1,) + a.shape[2:]), b_], axis=0)
    new_cache = {
        "k": ks,
        "v": vs,
        "conv": merge(g_st["conv"], t_st["conv"]),
        "ssm": merge(g_st["ssm"], t_st["ssm"]),
        "lengths": lengths + 1,
    }
    return _logits(params, cfg, h), new_cache
