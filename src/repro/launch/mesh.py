"""Mesh construction + sharding-constraint helpers.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state). Models call ``shard(x, ...)`` which is a no-op unless a
mesh has been activated — so the same model code runs on 1 CPU device in
tests and on the 512-chip production mesh in the dry-run/launcher.

Axis convention:
  single-pod : (data=16, model=16)            axes ("data", "model")
  multi-pod  : (pod=2, data=16, model=16)     axes ("pod", "data", "model")
``pod`` is the outer data-parallel axis (gradient all-reduce crosses DCI);
``BATCH`` below shards over ("pod", "data") when both exist.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisName = Union[str, tuple, None]

# canonical logical axes used throughout the model code
BATCH = ("pod", "data", "pool")  # batch / data-parallel (pool is inner DP)
MODEL = "model"  # tensor-parallel
POOL = "pool"  # weight-pooling cluster (shared-L2 analogue) — ZeRO shard axis


def make_production_mesh(*, multi_pod: bool = False, pool: int = 0) -> Mesh:
    """Production mesh: 256 chips/pod as (data=16, model=16); 2 pods = 512.

    ``pool=k`` factors the data axis into (data=16/k, pool=k): a k-device
    weight-pooling cluster (the paper's k-core shared-L2 cluster). Batch
    shards over (pod, data, pool) either way, so total DP is unchanged.
    """
    if pool:
        assert 16 % pool == 0, pool
        shape = (2, 16 // pool, pool, 16) if multi_pod else (16 // pool, pool, 16)
        axes = ("pod", "data", "pool", "model") if multi_pod else ("data", "pool", "model")
    else:
        shape = (2, 16, 16) if multi_pod else (16, 16)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1) -> Mesh:
    """Mesh over whatever devices exist (tests / CPU smoke).

    ``model`` must divide the host device count exactly: the old behavior
    (``n // model``) silently dropped the remainder devices from the mesh,
    which is never what a caller sizing a model axis wants.
    """
    n = len(jax.devices())
    if model < 1 or n % model != 0:
        dropped = n % model if model >= 1 else n
        raise ValueError(
            f"model={model} does not divide the {n} available devices; "
            f"an (n // model, model) mesh would silently drop {dropped} "
            "device(s). Pick a model-axis size that divides the device count."
        )
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_serving_mesh(model: int = 1) -> Mesh:
    """1-D ``("model",)`` mesh over the first ``model`` local devices.

    The sharded serving engine's mesh: unlike :func:`make_host_mesh` it
    does NOT require the model axis to divide the host device count — a
    2-shard replica on an 8-device host simply uses 2 devices (the other
    6 belong to other replicas). CPU-testable under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """
    devs = jax.devices()
    if model < 1 or model > len(devs):
        raise ValueError(
            f"model={model} shards need {model} devices; host has {len(devs)}"
        )
    return Mesh(np.asarray(devs[:model]), ("model",))


def shard_model_params(params, mesh: Mesh, axis: str = MODEL):
    """Place a parameter pytree on ``mesh`` with each leaf's LAST axis
    sharded over ``axis`` when divisible, replicated otherwise — the
    ``with_sharding_constraint``-style tensor-parallel layout, applied at
    placement time so every later jitted step computes on sharded operands
    without per-call constraint calls. On a 1-device mesh this is a pure
    device_put: values (and therefore decoded tokens) are bit-identical to
    the unsharded engine."""
    size = int(mesh.shape[axis])

    def put(x):
        if getattr(x, "ndim", 0) >= 1 and size > 1 and x.shape[-1] % size == 0:
            s = NamedSharding(mesh, P(*([None] * (x.ndim - 1) + [axis])))
        else:
            s = NamedSharding(mesh, P())
        return jax.device_put(x, s)

    return jax.tree.map(put, params)


# ---------------------------------------------------------------------------
# active-mesh context (thread-local; no global jax state)

_local = threading.local()


def active_mesh() -> Optional[Mesh]:
    return getattr(_local, "mesh", None)


@contextlib.contextmanager
def activate(mesh: Optional[Mesh]):
    prev = active_mesh()
    _local.mesh = mesh
    try:
        if mesh is not None:
            with mesh:
                yield mesh
        else:
            yield None
    finally:
        _local.mesh = prev


def _filter_spec(axes: Sequence[AxisName], mesh: Mesh) -> P:
    names = set(mesh.axis_names)
    out = []
    for a in axes:
        if a is None:
            out.append(None)
        elif isinstance(a, tuple):
            kept = tuple(n for n in a if n in names)
            out.append(kept if kept else None)
        else:
            out.append(a if a in names else None)
    return P(*out)


def spec(*axes: AxisName, mesh: Optional[Mesh] = None) -> P:
    """PartitionSpec with axes not present in the mesh dropped."""
    mesh = mesh or active_mesh()
    if mesh is None:
        return P(*axes)
    return _filter_spec(axes, mesh)


def shard(x: jax.Array, *axes: AxisName) -> jax.Array:
    """with_sharding_constraint if a mesh is active, else identity.

    Divisibility-aware: any requested axis whose size does not divide the
    corresponding array dimension is dropped (e.g. 15 query heads on a 16-way
    model axis stay replicated rather than erroring).
    """
    mesh = active_mesh()
    if mesh is None:
        return x
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    names = set(mesh.axis_names)
    out = []
    for i, a in enumerate(axes):
        if a is None or i >= x.ndim:
            out.append(None)
            continue
        parts = a if isinstance(a, tuple) else (a,)
        kept = tuple(n for n in parts if n in names)
        total = 1
        for n in kept:
            total *= sizes[n]
        if not kept or total == 0 or x.shape[i] % total != 0:
            out.append(None)
        else:
            out.append(kept if isinstance(a, tuple) else kept[0])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*out)))


def named(mesh: Mesh, *axes: AxisName) -> NamedSharding:
    return NamedSharding(mesh, _filter_spec(axes, mesh))


def tree_shardings(mesh: Mesh, specs) -> "jax.tree_util.PyTreeDef":
    """Map a pytree of PartitionSpecs to NamedShardings on ``mesh``."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, _filter_spec(tuple(s), mesh)),
        specs,
        is_leaf=lambda s: isinstance(s, P),
    )
