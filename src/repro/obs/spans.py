"""Request-lifecycle span recorder: the fleet's flight data, bounded.

MemProf's tracing tool exists because counters alone cannot explain *when*
and *why* a page got hot (paper §6.2); the serving analogue is that fleet
totals cannot explain where a request's latency went. Every request gets a
trace id (its rid) at admission and emits spans — ``admit``, ``queue``,
``dispatch``, ``prefill``, ``decode``, ``migrate``, ``shed``/``complete`` —
stamped with *virtual time* from the fleet scheduler, so one diurnal
scenario produces one causally-ordered trace (exported to Perfetto by
obs/export.py).

Memory is bounded: the recorder is a ring buffer of ``capacity`` finished
spans. Under a million-request scenario the oldest spans fall off the ring
and ``dropped`` counts them — the drop count is itself a metric (the
FlightRecorder exports it as ``spans_dropped``), because a trace that
silently truncates is exactly the production blindness the paper warns
about. Open spans (begun, not yet ended) live in a dict keyed by
``(trace, name)`` and do not consume ring slots until they finish.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, Optional, Tuple

INSTANT = "instant"
SPAN = "span"


@dataclasses.dataclass
class Span:
    name: str
    trace: int  # request rid, or -1 for host/fleet-level spans
    t0: float  # virtual time
    t1: float  # == t0 for instants
    tenant: str = ""
    replica: int = -1
    kind: str = SPAN
    args: dict = dataclasses.field(default_factory=dict)

    @property
    def dur(self) -> float:
        return self.t1 - self.t0


class SpanRecorder:
    def __init__(self, capacity: int = 65536):
        assert capacity > 0
        self.capacity = int(capacity)
        self.spans: Deque[Span] = deque()
        self.dropped = 0
        self.emitted = 0
        self.double_end = 0  # ends on an already-closed span (retry paths)
        self._open: Dict[Tuple[int, str], Span] = {}
        # recently-closed keys (bounded like the ring): lets ``end`` tell a
        # double-end apart from an end that never had a begin
        self._closed: set = set()
        self._closed_order: Deque[Tuple[int, str]] = deque()

    # ------------------------------------------------------------------
    def _push(self, span: Span):
        if len(self.spans) >= self.capacity:
            self.spans.popleft()
            self.dropped += 1
        self.spans.append(span)
        self.emitted += 1

    def begin(
        self,
        name: str,
        trace: int,
        t: float,
        tenant: str = "",
        replica: int = -1,
        **args,
    ):
        """Open a span; it enters the ring when ``end`` closes it. A repeated
        begin for the same (trace, name) replaces the open span (the older
        one is flushed as zero-length so it is never silently lost)."""
        key = (trace, name)
        prev = self._open.pop(key, None)
        if prev is not None:
            prev.t1 = prev.t0
            prev.args["truncated"] = True
            self._push(prev)
        self._open[key] = Span(name, trace, float(t), float(t), tenant, replica, SPAN, args)

    def _note_closed(self, key: Tuple[int, str]):
        if key in self._closed:
            return
        self._closed.add(key)
        self._closed_order.append(key)
        if len(self._closed_order) > self.capacity:
            self._closed.discard(self._closed_order.popleft())

    def end(self, name: str, trace: int, t: float, **args) -> Optional[Span]:
        """Close an open span at virtual time ``t``.

        Ending an already-closed span again — retry/re-dispatch paths do
        this when a failover and a late completion both try to close the
        same lifecycle span — records NOTHING and bumps the ``double_end``
        book: exactly one span per begin reaches the ring, and the open-
        span table is never corrupted by the second close. An end whose
        key was never begun (nor recently closed) is still recorded as an
        ``unmatched`` instant so a genuine lifecycle bug shows up in the
        trace instead of vanishing."""
        key = (trace, name)
        span = self._open.pop(key, None)
        if span is None:
            if key in self._closed:
                self.double_end += 1
                return None
            span = Span(name, trace, float(t), float(t), kind=INSTANT, args={"unmatched": True})
        span.t1 = float(t)
        span.args.update(args)
        self._note_closed(key)
        self._push(span)
        return span

    def instant(
        self,
        name: str,
        trace: int,
        t: float,
        tenant: str = "",
        replica: int = -1,
        **args,
    ):
        self._push(Span(name, trace, float(t), float(t), tenant, replica, INSTANT, args))

    def span(
        self,
        name: str,
        trace: int,
        t0: float,
        t1: float,
        tenant: str = "",
        replica: int = -1,
        **args,
    ):
        """Record an already-finished span in one call (engine-side use:
        the step that retires a request knows its whole decode range)."""
        self._push(Span(name, trace, float(t0), float(t1), tenant, replica, SPAN, args))

    # ------------------------------------------------------------------
    @property
    def open_count(self) -> int:
        return len(self._open)

    def finished(self) -> list:
        """Finished spans in emission order (ring contents)."""
        return list(self.spans)

    def drain_open(self, t: float):
        """Flush still-open spans at trace-export time (truncated runs):
        each closes at ``t`` and is tagged, so B/E events stay balanced."""
        for key in list(self._open):
            self.end(key[1], key[0], t, truncated=True)
