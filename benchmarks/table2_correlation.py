"""Paper Table 2: cross-core L1 I-TLB miss correlation -> cross-replica
parameter/state access correlation.

Every data-parallel replica reads byte-identical weight blocks in the same
order each step (rho ~ 1, the paper's "cores run similar code"); unrelated
streams (decode KV vs. router — the paper's workload-vs-NIC-core pair)
decorrelate.
"""
import numpy as np

from repro.core.profiler import AccessProfiler

from _common import fmt_table, stream_for


def _replica_param_stream(seed, n_steps=6, n_weight_blocks=1500, n_embed_rows=512, rng=None):
    """One DP replica's per-step block touches: full weight sweep (identical
    across replicas) + data-dependent embedding rows (also identical when the
    replicas see the same global batch order, as DP replicas do)."""
    rng = rng or np.random.default_rng(0)  # SAME data stream for all replicas
    out = []
    for _ in range(n_steps):
        out.append(np.arange(n_weight_blocks))  # the "code" sweep
        rows = rng.zipf(1.2, 256) % n_embed_rows + n_weight_blocks
        out.append(rows)
    return np.concatenate(out)


def main():
    nb = 1500 + 512
    prof = AccessProfiler(n_blocks=4096)
    shared_rng = np.random.default_rng(42)
    s0 = _replica_param_stream(0, rng=shared_rng)
    shared_rng = np.random.default_rng(42)
    s1 = _replica_param_stream(1, rng=shared_rng)
    prof.record("replica0", s0)
    prof.record("replica1", s1)
    kv, _ = stream_for("Web1", n=20_000)
    router, _ = stream_for("Cache2", n=20_000, seed=9)
    prof.record("kv_stream", kv)
    prof.record("router_stream", router)

    rows = [
        ("replica0 vs replica1 (params)", f"{prof.correlation('replica0', 'replica1'):.4f}", "0.98-0.9997"),
        ("kv vs router (unrelated)", f"{prof.correlation('kv_stream', 'router_stream'):.4f}", "~0.001 (workload vs NIC)"),
    ]
    print("[table2] cross-stream Pearson correlation (paper Table 2 analogue)")
    print(fmt_table(rows, ["pair", "rho", "paper band"]))
    assert prof.correlation("replica0", "replica1") > 0.99
    return {"replica_rho": prof.correlation("replica0", "replica1")}


if __name__ == "__main__":
    main()
