"""GQA attention layer: init + train/prefill/decode application.

Layout: projections are stored flat — wq: (D, Hq*hd), wk/wv: (D, Hkv*hd),
wo: (Hq*hd, D) — so TP sharding is a plain column/row split (Megatron style).
KV cache per layer: k/v (B, Hkv, S, hd) + per-sequence lengths (B,).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.mesh import BATCH, MODEL, shard
from repro.models import common

Array = jax.Array


def init(key, cfg: ModelConfig, dtype) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    q_dim, kv_dim = cfg.n_heads * hd, cfg.n_kv_heads * hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": common.dense_init(ks[0], (d, q_dim), dtype=dtype),
        "wk": common.dense_init(ks[1], (d, kv_dim), dtype=dtype),
        "wv": common.dense_init(ks[2], (d, kv_dim), dtype=dtype),
        "wo": common.dense_init(ks[3], (q_dim, d), scale=1.0 / (2 * cfg.n_layers) ** 0.5, dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((q_dim,), dtype)
        p["bk"] = jnp.zeros((kv_dim,), dtype)
        p["bv"] = jnp.zeros((kv_dim,), dtype)
    return p


def param_specs(cfg: ModelConfig) -> dict:
    if cfg.sp_activations:
        # sequence-parallel attention (see _project_qkv): weights replicated
        # over MODEL; the seq dim carries the parallelism end to end, so the
        # attention path has NO resharding at all. Storage still shards over
        # the pool axis (ZeRO), so residency is unchanged.
        p = {"wq": (None, None), "wk": (None, None), "wv": (None, None), "wo": (None, None)}
        if cfg.qkv_bias:
            p.update({"bq": (None,), "bk": (None,), "bv": (None,)})
        return p
    p = {"wq": (None, MODEL), "wk": (None, MODEL), "wv": (None, MODEL), "wo": (MODEL, None)}
    if cfg.qkv_bias:
        p.update({"bq": (MODEL,), "bk": (MODEL,), "bv": (MODEL,)})
    return p


def _project_qkv(p: dict, cfg: ModelConfig, x: Array):
    b, l, _ = x.shape
    hd = cfg.head_dim
    q = jnp.einsum("bld,de->ble", x, p["wq"], preferred_element_type=jnp.float32).astype(x.dtype)
    k = jnp.einsum("bld,de->ble", x, p["wk"], preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("bld,de->ble", x, p["wv"], preferred_element_type=jnp.float32).astype(x.dtype)
    if cfg.qkv_bias:
        q, k, v = q + p["bq"].astype(q.dtype), k + p["bk"].astype(k.dtype), v + p["bv"].astype(v.dtype)
    q = q.reshape(b, l, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, l, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, l, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    if cfg.sp_activations:
        # context/sequence parallelism: q stays seq-sharded (each shard owns
        # its causal rows), k/v are gathered — tiny for GQA (few kv heads)
        q = shard(q, BATCH, None, MODEL, None)
        k = shard(k, BATCH, None, None, None)
        v = shard(v, BATCH, None, None, None)
    else:
        q = shard(q, BATCH, MODEL, None, None)
        k = shard(k, BATCH, MODEL, None, None)
        v = shard(v, BATCH, MODEL, None, None)
    return q, k, v


def _rope(cfg: ModelConfig, q: Array, k: Array, positions, mrope_positions=None):
    if cfg.rope_theta <= 0:
        return q, k
    if mrope_positions is not None:
        q = common.apply_mrope(q, mrope_positions, cfg.rope_theta, cfg.mrope_sections)
        k = common.apply_mrope(k, mrope_positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = common.apply_rope(q, positions, cfg.rope_theta)
        k = common.apply_rope(k, positions, cfg.rope_theta)
    return q, k


def _out_proj(p: dict, x_dtype, o: Array) -> Array:
    b, h, l, hd = o.shape
    o = o.transpose(0, 2, 1, 3).reshape(b, l, h * hd)
    out = jnp.einsum("ble,ed->bld", o, p["wo"], preferred_element_type=jnp.float32)
    return out.astype(x_dtype)


def apply_train(
    p: dict,
    cfg: ModelConfig,
    x: Array,
    positions: Array,
    mrope_positions=None,
    *,
    causal: bool = True,
    block_k: int = 1024,
) -> Array:
    """Full-sequence attention (training / prefill without cache return)."""
    q, k, v = _project_qkv(p, cfg, x)
    q, k = _rope(cfg, q, k, positions, mrope_positions)
    o = common.attention_chunked(q, k, v, causal=causal, block_k=block_k)
    return _out_proj(p, x.dtype, o)


def apply_prefill(
    p: dict,
    cfg: ModelConfig,
    x: Array,
    positions: Array,
    max_len: int,
    mrope_positions=None,
    block_k: int = 1024,
):
    """As apply_train but also returns the (padded-to-max_len) KV for caching."""
    q, k, v = _project_qkv(p, cfg, x)
    q, k = _rope(cfg, q, k, positions, mrope_positions)
    o = common.attention_chunked(q, k, v, causal=True, block_k=block_k)
    l = x.shape[1]
    if max_len > l:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, max_len - l), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, max_len - l), (0, 0)))
    return _out_proj(p, x.dtype, o), (k, v)


def apply_decode(
    p: dict,
    cfg: ModelConfig,
    x: Array,
    k_cache: Array,
    v_cache: Array,
    lengths: Array,
    mrope_positions=None,
):
    """One-token decode. x: (B, 1, D); caches (B, Hkv, S, hd); lengths (B,).

    Returns (out, k_cache', v_cache'). The new K/V is written at position
    ``lengths`` per sequence; attention sees ``lengths + 1`` valid entries.
    """
    b = x.shape[0]
    q, k, v = _project_qkv(p, cfg, x)
    positions = lengths[:, None].astype(jnp.int32)  # (B, 1)
    q, k = _rope(cfg, q, k, positions, mrope_positions)
    idx = jnp.arange(b)
    k_cache = k_cache.at[idx, :, lengths, :].set(k[:, :, 0, :].astype(k_cache.dtype))
    v_cache = v_cache.at[idx, :, lengths, :].set(v[:, :, 0, :].astype(v_cache.dtype))
    o = common.attention_decode(q, k_cache.astype(q.dtype), v_cache.astype(q.dtype), lengths + 1)
    return _out_proj(p, x.dtype, o), k_cache, v_cache


def init_cache(cfg: ModelConfig, n_layers: int, batch: int, max_len: int, dtype=jnp.bfloat16):
    shape = (n_layers, batch, cfg.n_kv_heads, max_len, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "lengths": jnp.zeros((batch,), jnp.int32),
    }


def cache_specs(cfg: ModelConfig, model_axis: int = 16) -> dict:
    """Sharding for the stacked cache: heads over MODEL when divisible, else seq."""
    if cfg.n_kv_heads % model_axis == 0:
        kv = (None, BATCH, MODEL, None, None)
    else:
        kv = (None, BATCH, None, MODEL, None)
    return {"k": kv, "v": kv, "lengths": (BATCH,)}
