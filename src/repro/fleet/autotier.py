"""Online fleet re-tiering: plan on the aggregate, push to every host.

The paper's tiering decision (§5, Table 5) is made from *fleet* behavior —
"few pages serve most bandwidth" is a property of the service, not of one
host's recent window. The AutoTierer periodically re-runs core/tiering.plan
on the aggregated fleet histogram and pushes the resulting near-tier page
set to every replica (which suppresses their local TPP loops), so placement
is driven by the representative profile instead of each engine's noisy
local view. Under a stationary workload the pushed plan converges: the
Jaccard overlap of successive near-sets approaches 1.

Multi-tenant: the plan is still made from the COMBINED histogram — the near
tier is one physical resource — but each epoch also reports the fraction of
every tenant's accesses the pushed near set would serve. A skew-heavy
tenant crowding the top-k pushes its neighbors' planned near-hit down;
that per-tenant spread is the co-location interference signal the
tenant_interference benchmark measures.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core import tiering
from repro.core.hw import HBM_BW, HOST_LINK_BW, TierSpec
from repro.fleet import aggregator
from repro.fleet.replica import Replica


def _fleet_specs(near_frac: float) -> tuple:
    return (
        TierSpec("hbm", near_frac, HBM_BW, 1.0, 8.0),
        TierSpec("host-dram", 1.0 - near_frac, HOST_LINK_BW, 6.0, 1.0),
    )


@dataclasses.dataclass
class TierEpoch:
    fleet_step: int
    near_ids: np.ndarray
    near_hit_frac: float  # planned fraction of accesses served near
    migrated_pages: int  # placement changes this push cost, fleet-wide
    overlap_prev: float  # Jaccard vs previous epoch's near set
    # planned near-served fraction per tenant under the SAME shared near set
    tenant_near_frac: Dict[str, float] = dataclasses.field(default_factory=dict)


class AutoTierer:
    def __init__(
        self,
        replicas: List[Replica],
        near_frac: float = 0.30,
        epoch_steps: int = 32,
        specs: Optional[tuple] = None,
    ):
        self.replicas = replicas
        self.near_frac = near_frac
        self.epoch_steps = epoch_steps
        self.specs = specs or _fleet_specs(near_frac)
        self.history: List[TierEpoch] = []

    # ------------------------------------------------------------------
    def __call__(self, fleet_step: int):
        """FleetRouter.on_step hook."""
        if fleet_step % self.epoch_steps == 0:
            self.step(fleet_step)

    def step(self, fleet_step: int = 0) -> Optional[TierEpoch]:
        profiles = aggregator.export_all(self.replicas)
        counts = aggregator.aggregate_counts(profiles)
        if counts.sum() == 0:
            return None
        p = tiering.plan(counts, self.specs)
        migrated = sum(r.apply_placement(p.hot_blocks) for r in self.replicas)
        overlap = 0.0
        if self.history:
            prev = set(self.history[-1].near_ids.tolist())
            cur = set(p.hot_blocks.tolist())
            overlap = len(prev & cur) / max(len(prev | cur), 1)
        tenant_frac = {}
        for t, tc in aggregator.aggregate_tenant_counts(profiles).items():
            near = tc[p.hot_blocks[p.hot_blocks < tc.size]].sum()
            tenant_frac[t] = float(near / max(tc.sum(), 1))
        epoch = TierEpoch(
            fleet_step, p.hot_blocks, p.hit_fracs[0], migrated, overlap, tenant_frac
        )
        self.history.append(epoch)
        return epoch

    # ------------------------------------------------------------------
    @property
    def converged(self) -> bool:
        """Plan is stable once consecutive near-sets mostly agree."""
        return len(self.history) >= 2 and self.history[-1].overlap_prev >= 0.8

    def convergence_trace(self) -> List[float]:
        return [e.overlap_prev for e in self.history]
