"""Fault-tolerant checkpointing: atomic, sharded, async, reshard-on-restore.

Layout (one directory per step):
    <dir>/step_000123.tmp/      # written first
        meta.json               # step, tree structure, shapes/dtypes, extras
        arr_00000.npy ...       # one file per leaf (this host's shards)
    <dir>/step_000123/          # atomic rename AFTER all files are fsynced

Crash-safety: a checkpoint either has its final name (complete) or is a
.tmp orphan (ignored + GC'd). ``save_async`` snapshots to host memory
synchronously (cheap) and writes on a background thread so the train loop
overlaps I/O with compute. ``restore`` takes target shardings — restoring
onto a different mesh (elastic shrink/grow) just reshards on device_put.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self.gc_orphans()

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def gc_orphans(self):
        for name in os.listdir(self.dir):
            if name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.dir, name), ignore_errors=True)

    def latest_step(self) -> Optional[int]:
        steps = [
            int(n.split("_")[1])
            for n in os.listdir(self.dir)
            if n.startswith("step_") and not n.endswith(".tmp")
        ]
        return max(steps) if steps else None

    # ------------------------------------------------------------------
    def _write(self, step: int, leaves: list, treedef_str: str, extras: dict):
        tmp = self._step_dir(step) + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        meta = {
            "step": step,
            "treedef": treedef_str,
            "n_leaves": len(leaves),
            "dtypes": [str(l.dtype) for l in leaves],
            "shapes": [list(l.shape) for l in leaves],
            "extras": extras,
        }
        for i, leaf in enumerate(leaves):
            np.save(os.path.join(tmp, f"arr_{i:05d}.npy"), leaf)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        final = self._step_dir(step)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.dir)
            if n.startswith("step_") and not n.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any, extras: Optional[dict] = None):
        """Synchronous atomic save (state: any pytree of arrays)."""
        self.wait()
        leaves, treedef = _flatten(state)
        host = [np.asarray(l) for l in leaves]
        self._write(step, host, str(treedef), extras or {})

    def save_async(self, step: int, state: Any, extras: Optional[dict] = None):
        """Snapshot synchronously, write in the background."""
        self.wait()
        leaves, treedef = _flatten(state)
        host = [np.asarray(l) for l in leaves]  # device->host copy (the snapshot)
        td = str(treedef)
        ex = extras or {}

        def _worker():
            try:
                self._write(step, host, td, ex)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_worker, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # ------------------------------------------------------------------
    def restore(self, template: Any, step: Optional[int] = None, shardings: Any = None):
        """Restore into ``template``'s tree structure.

        ``shardings``: optional pytree of Shardings (same structure) — this is
        the elastic-reshard path: arrays are device_put onto the NEW mesh no
        matter what mesh wrote them.
        Returns (state, extras).
        """
        self.wait()
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = self._step_dir(step)
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        leaves = [np.load(os.path.join(d, f"arr_{i:05d}.npy")) for i in range(meta["n_leaves"])]
        t_leaves, treedef = _flatten(template)
        assert len(t_leaves) == len(leaves), "checkpoint/template leaf mismatch"
        if shardings is not None:
            s_leaves = jax.tree.leaves(
                shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
            )
            leaves = [jax.device_put(l, s) for l, s in zip(leaves, s_leaves)]
        else:
            leaves = [jax.numpy.asarray(l) for l in leaves]
        return jax.tree.unflatten(treedef, leaves), meta["extras"]
