"""Fleet subsystem: router policies, fleet-wide MemProf aggregation
(Table 6's <=5% stitched-trace validation, at fleet scale), online
re-tiering convergence, admission control, and the event-driven scheduler's
lockstep-equivalence + straggler-tolerance guarantees.

The whole module runs under whichever stepping mode REPRO_FLEET_LOCKSTEP
selects (CI runs both), except the tests that pin ``lockstep=`` explicitly
to compare the two schedules.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs.workloads import get_profile
from repro.core.memtrace import TraceWindow
from repro.data.requests import Request, RequestGenerator
from repro.fleet import (
    AdmissionController,
    SLOModel,
    aggregate_counts,
    build_fleet,
    export_all,
    fleet_vocab,
    live_fleet_counters,
    stitch_fleet,
    validate_fleet,
)
from repro.fleet.replica import ReplicaProfile


def web_profile(**kw):
    base = dict(prompt_mean=24, decode_mean=6, prefix_share=0.9, n_prefixes=3)
    base.update(kw)
    return dataclasses.replace(get_profile("Web1"), **base)


def run_fleet(
    policy, n_replicas=4, n_requests=16, profile=None, seed=0, lockstep=None,
    submit_per_step=2, **fleet_kw,
):
    kw = dict(trace_window=16, trace_period=32)
    kw.update(fleet_kw)
    fleet = build_fleet(n_replicas, policy=policy, seed=seed, **kw)
    prof = profile or web_profile()
    gen = RequestGenerator(prof, vocab_size=fleet_vocab(), seed=seed)
    stats = fleet.run(
        gen, n_requests=n_requests, max_steps=800,
        submit_per_step=submit_per_step, lockstep=lockstep,
    )
    return fleet, stats


# ---------------------------------------------------------------------------
# router policies


@pytest.mark.slow
def test_prefix_affinity_colocates_shared_prefixes():
    fleet, stats = run_fleet("prefix-affinity")
    # every template has exactly one home replica
    homes = fleet.policy.home
    assert homes and all(0 <= i < 4 for i in homes.values())
    assert fleet.policy.affinity_hits > 0
    # co-location means the page table actually dedups across requests
    _, rr_stats = run_fleet("round-robin")
    assert stats["shared_mappings"] > rr_stats["shared_mappings"]
    assert stats["prefill_tokens_saved"] > rr_stats["prefill_tokens_saved"]


@pytest.mark.slow
def test_affinity_beats_round_robin_throughput():
    """Acceptance: fleet-level value of the shared-TLB observation."""
    _, aff = run_fleet("prefix-affinity")
    _, rr = run_fleet("round-robin")
    assert aff["simulated_throughput"] > rr["simulated_throughput"]
    assert aff["requests_finished"] == rr["requests_finished"] == 16


@pytest.mark.slow
def test_least_loaded_spreads_work():
    fleet, stats = run_fleet("least-loaded", profile=web_profile(prefix_share=0.0))
    per = stats["per_replica"]
    finished = [s["requests_finished"] for s in per]
    assert sum(finished) == 16
    assert min(finished) > 0  # nobody idle while others queue


# ---------------------------------------------------------------------------
# aggregator (fleet MemProf)


def _synthetic_profiles():
    rng = np.random.default_rng(0)
    profs = []
    for rid in range(3):
        blocks = rng.integers(0, 64, 200).astype(np.int64)
        counts = np.bincount(blocks, minlength=64)
        w = TraceWindow(rid, blocks, np.zeros(200, bool))
        profs.append(
            ReplicaProfile(
                rid=rid, counts=counts, windows=[w], reads=150, writes=50,
                live_hit_ratio=0.5, live_accesses=200, live_capacity=32,
                near_hit_rate=0.9,
            )
        )
    return profs


def test_aggregate_counts_sums_logical_pages():
    profs = _synthetic_profiles()
    agg = aggregate_counts(profs)
    assert agg.sum() == sum(p.counts.sum() for p in profs)
    np.testing.assert_array_equal(agg, sum(p.counts for p in profs))


def test_stitch_namespaces_physical_pages():
    profs = _synthetic_profiles()
    trace = stitch_fleet(profs, n_pages=64)
    assert trace.blocks.size == 600
    # host r's pages live in [r*64, (r+1)*64): no cross-host aliasing
    assert trace.blocks.max() < 3 * 64
    owners = trace.blocks // 64
    assert set(owners.tolist()) == {0, 1, 2}
    live = live_fleet_counters(profs)
    assert live["rw_ratio"] == pytest.approx(3.0)


@pytest.mark.slow
@pytest.mark.parametrize("policy", ["round-robin", "least-loaded", "prefix-affinity"])
def test_fleet_trace_validates_within_5pct(policy):
    """Acceptance: stitched fleet trace vs live fleet counters (Table 6).

    Seeded regression across ALL router policies — the routing decision
    changes which host's windows dominate the stitched trace, so the <=5%
    aggregator tolerance must hold per policy or it can silently rot.
    """
    fleet, stats = run_fleet(policy, n_requests=20, seed=0)
    val = validate_fleet(export_all(fleet.replicas))
    assert val["trace_len"] > 0
    assert val["hit_ratio_error"] <= 0.05, (policy, val)
    assert abs(val["rw_ratio_error_pct"]) <= 5.0, (policy, val)


# ---------------------------------------------------------------------------
# event-driven scheduler: lockstep equivalence + straggler tolerance


EQUIV_FIELDS = (
    "tokens_decoded",
    "requests_finished",
    "prefill_tokens",
    "prefill_tokens_saved",
    "routed",
    "shed",
    "near_hit_rate",
    "shared_mappings",
    "fleet_steps",
    "virtual_time",
)


def _equiv_run(lockstep):
    """Overloaded enough that admission sheds — shed equality is the
    subtlest part of the equivalence claim (same decisions at the door)."""
    return run_fleet(
        "prefix-affinity",
        n_requests=40,
        submit_per_step=6,
        lockstep=lockstep,
        admission=AdmissionController(SLOModel(max_delay_steps=6.0)),
        autotier=dict(near_frac=0.30, epoch_steps=8),
    )


@pytest.mark.slow
def test_event_driven_reproduces_lockstep_exactly():
    """Acceptance: homogeneous speeds + no scaling => identical fleet_stats.

    The event schedule must degenerate to the lockstep schedule batch for
    batch: same decode counts, same finishes, same sheds, same epochs —
    not approximately, exactly.
    """
    fl, ls = _equiv_run(lockstep=True)
    fe, ev = _equiv_run(lockstep=False)
    assert ls["mode"] == "lockstep" and ev["mode"] == "event"
    for k in EQUIV_FIELDS:
        assert ls[k] == ev[k], (k, ls[k], ev[k])
    assert ls["shed"] > 0  # the interesting regime was actually exercised
    # per-tenant routing books and queue-wait percentiles agree too (the
    # wait keys are OMITTED for a tenant with no queued request — e.g.
    # 100% shed — so compare via .get: present-vs-absent must match too)
    for t, lt in ls["tenants"].items():
        for k in ("routed", "shed", "wait_p50", "wait_p99"):
            assert lt.get(k) == ev["tenants"][t].get(k), (t, k)
    # autotier epochs land on the same virtual times with identical plans
    hl, he = fl.autotierer.history, fe.autotierer.history
    assert [e.vtime for e in hl] == [e.vtime for e in he]
    assert all(np.array_equal(a.near_ids, b.near_ids) for a, b in zip(hl, he))


@pytest.mark.slow
def test_straggler_event_driven_beats_lockstep():
    """Acceptance: a 4x straggler gates the lockstep barrier (every fleet
    step costs max(step_cost)) but only its own host under the event
    scheduler — decode throughput per virtual time must show it."""
    tput = {}
    for lockstep in (True, False):
        fleet = build_fleet(
            4, policy="least-loaded", speeds=(1, 1, 1, 4),
            trace_window=16, trace_period=32, seed=0,
        )
        gen = RequestGenerator(
            web_profile(prefix_share=0.0), vocab_size=fleet_vocab(), seed=1
        )
        # same horizon AND same offered load per unit virtual time (a
        # lockstep iteration spans 4 units, so it gets 4 ticks' arrivals)
        stats = fleet.run(
            gen, n_requests=60, max_steps=10 if lockstep else 40,
            submit_per_step=8 if lockstep else 2, lockstep=lockstep,
        )
        assert stats["virtual_time"] == pytest.approx(40.0)
        tput[lockstep] = stats["tokens_decoded"] / stats["virtual_time"]
    assert tput[False] > 1.5 * tput[True], tput


@pytest.mark.slow
def test_truncated_run_offer_books_match_lockstep():
    """Horizon truncation must not desync the modes' arrival schedules:
    lockstep offers at iteration starts 0..max_steps-1, so event mode must
    not sneak in an extra arrival batch at t == horizon."""
    books = {}
    for mode in (True, False):
        fleet = build_fleet(2, policy="round-robin", trace_window=16, trace_period=32)
        gen = RequestGenerator(web_profile(), vocab_size=fleet_vocab(), seed=3)
        stats = fleet.run(
            gen, n_requests=40, max_steps=5, submit_per_step=2, lockstep=mode
        )
        books[mode] = (stats["routed"], stats["shed"], fleet.queued())
    assert books[True] == books[False]
    assert books[True][0] + books[True][2] == 10  # 5 ticks x 2 offered


@pytest.mark.slow
def test_truncated_event_run_resumes_cleanly():
    """Regression: a horizon-truncated event run discards un-executed
    completion events; the in-flight markers must be cleared with them or
    the replicas stay busy forever and a follow-up run serves nothing."""
    fleet = build_fleet(2, policy="round-robin", trace_window=16, trace_period=32)
    gen = RequestGenerator(web_profile(), vocab_size=fleet_vocab(), seed=2)
    fleet.run(gen, n_requests=12, max_steps=3, submit_per_step=4, lockstep=False)
    assert all(not r.busy for r in fleet.replicas)
    assert not fleet.drained  # work genuinely survived the truncation
    stats = fleet.run(gen, n_requests=2, max_steps=400, submit_per_step=2, lockstep=False)
    assert fleet.drained
    assert stats["requests_finished"] == stats["routed"]


@pytest.mark.slow
def test_replica_step_cost_hook():
    fleet, _ = run_fleet("round-robin", n_requests=4)
    r = fleet.replicas[0]
    assert r.step_cost == 1.0
    r.speed = 4.0
    assert r.step_cost == 4.0
    r.engine.step_cost_fn = lambda eng: 0.5
    assert r.step_cost == 2.0
    r.engine.step_cost_fn = lambda eng: 0.0
    with pytest.raises(ValueError):
        r.engine.step_cost()


# ---------------------------------------------------------------------------
# autotier (online fleet re-tiering)


@pytest.mark.slow
def test_autotier_converges_on_stationary_workload():
    prof = web_profile(prefix_share=0.6, decode_mean=10)
    fleet, stats = run_fleet(
        "prefix-affinity",
        n_requests=24,
        profile=prof,
        autotier=dict(near_frac=0.30, epoch_steps=8),
    )
    at = fleet.autotierer
    assert len(at.history) >= 3
    # fleet plan stabilizes: successive near-sets converge to high overlap
    assert at.history[-1].overlap_prev >= 0.8
    assert at.converged
    # pushes took ownership of placement on every host
    assert all(r.engine.external_placement for r in fleet.replicas)
    # pushed near set respects each replica's near capacity
    for r in fleet.replicas:
        assert (r.engine.placement.tier == 0).sum() <= r.engine.placement.near_capacity


def test_autotier_zero_count_tenant_reports_zero_not_nan():
    """Regression: a freshly added replica can register a tenant stream
    before any traffic lands (elastic warm-up). The epoch must report an
    explicit 0.0 for that tenant, not divide into a zero histogram."""
    fleet, _ = run_fleet(
        "round-robin", n_requests=8, autotier=dict(near_frac=0.30, epoch_steps=8)
    )
    fleet.replicas[0].engine.profiler.record("kv.idle", np.zeros(0, np.int64))
    epoch = fleet.autotierer.step(fleet.fleet_steps)
    assert epoch is not None
    assert epoch.tenant_near_frac["idle"] == 0.0
    assert all(np.isfinite(v) for v in epoch.tenant_near_frac.values())
    # the zero-traffic tenant never perturbs the combined plan
    assert epoch.near_ids.size > 0


def test_apply_placement_counts_migrations():
    fleet, _ = run_fleet("round-robin", n_requests=8)
    eng = fleet.replicas[0].engine
    near = eng.placement.near_capacity
    before = eng.placement.stats.promotions + eng.placement.stats.demotions
    flipped = np.flatnonzero(eng.placement.tier == 1)[:near]  # all-far -> near
    changed = eng.apply_placement(flipped)
    assert changed > 0
    assert (eng.placement.tier[flipped] == 0).all()
    after = eng.placement.stats.promotions + eng.placement.stats.demotions
    assert after - before == changed


# ---------------------------------------------------------------------------
# admission control


def test_admission_sheds_overload():
    adm = AdmissionController(SLOModel(max_delay_steps=10.0))
    fleet = build_fleet(2, policy="least-loaded", admission=adm)
    prof = web_profile(prompt_mean=32, decode_mean=12, prefix_share=0.0)
    gen = RequestGenerator(prof, vocab_size=fleet_vocab(), seed=3)
    stats = fleet.run(gen, n_requests=40, max_steps=2000)  # all offered at once
    assert stats["shed"] > 0  # overload sheds at the door...
    assert stats["shed"] == adm.shed
    assert 0.0 < adm.shed_rate < 1.0
    # ...and everything admitted is actually served within the run
    assert stats["requests_finished"] == stats["routed"] == adm.admitted


def test_admission_admits_everything_under_light_load():
    adm = AdmissionController(SLOModel(max_delay_steps=1e6))
    fleet = build_fleet(2, policy="round-robin", admission=adm)
    gen = RequestGenerator(web_profile(), vocab_size=fleet_vocab(), seed=4)
    stats = fleet.run(gen, n_requests=6, max_steps=800)
    assert stats["shed"] == 0 and stats["requests_finished"] == 6


# ---------------------------------------------------------------------------
# admission edge cases (no real engines needed: admission only reads
# engine.slots and engine.backlog_tokens)


class _FakeEngine:
    def __init__(self, n_slots, backlog=0.0):
        self.slots = [object()] * n_slots
        self.backlog = backlog

    def backlog_tokens(self, prefill_weight=1.0):
        return self.backlog


class _FakeReplica:
    def __init__(self, n_slots, backlog=0.0):
        self.engine = _FakeEngine(n_slots, backlog)


def _req(rid=0, n_tokens=8, decode=4, tenant="default"):
    return Request(rid, np.zeros(n_tokens, np.int32), decode, -1, 0.0, tenant)


def test_admission_zero_replicas_sheds_without_crashing():
    adm = AdmissionController(SLOModel())
    assert adm.admit(_req(), []) is False
    assert adm.offered == 1 and adm.shed == 1 and adm.shed_rate == 1.0


def test_admission_zero_slot_replicas_shed_everything():
    adm = AdmissionController(SLOModel(max_delay_steps=1e9))
    replicas = [_FakeReplica(0), _FakeReplica(0)]
    assert adm.admit(_req(), replicas) is False  # rate 0: unservable
    assert adm.backlog_steps(replicas) == 0.0  # and no divide-by-zero


def test_admission_shed_rate_before_any_arrivals():
    adm = AdmissionController(SLOModel())
    assert adm.shed_rate == 0.0 and adm.shed == 0
    assert adm.tenant_stats() == {}


def test_admission_burst_must_shed():
    """Backlog growth pushes the projection over the SLO mid-burst."""
    adm = AdmissionController(SLOModel(max_delay_steps=8.0, prefill_weight=0.25))
    replica = _FakeReplica(4)
    decisions = []
    for i in range(12):
        ok = adm.admit(_req(rid=i, n_tokens=8, decode=6), [replica])
        if ok:  # model the admitted request's work entering the fleet
            replica.engine.backlog += 0.25 * 8 + 6
        decisions.append(ok)
    assert decisions[0] is True  # empty fleet admits
    assert not all(decisions)  # the burst hits the SLO wall...
    assert decisions.index(False) == decisions.count(True)  # ...and stays shed
    assert adm.shed == decisions.count(False)
    assert 0.0 < adm.shed_rate < 1.0
