"""Shard-correct serving: one logical replica spanning chips.

Two layers of oracle, matching how the sharded path is built:

1. store equivalence — ``ShardedTieredKV`` (page-interleaved per-shard
   ``TieredKVCache`` slices) against ONE unsharded store driven by the
   identical global stream: returned rows, drained counter planes (slot /
   tenant / role), migration books and the dispatch/sync budget must all
   merge by pure summation into the unsharded values. These run on 1 CPU
   device — the facade's shards are host-side slices, no mesh needed.
2. engine equivalence — a 1-shard ``ShardedServingEngine`` is bit-exact
   with ``ServingEngine`` (tokens, counters, tenant books), and an N-shard
   engine's MERGED counters equal the 1-shard totals on the same seeded
   request stream at the unchanged budget of one segmented dispatch per
   shard per step and zero mandatory host syncs. N-shard engine tests need
   a multi-device mesh: run under
   ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (CI's sharded
   job); they skip on a single-device host.
"""
import dataclasses

import jax
import numpy as np
import pytest

import jax.numpy as jnp

import repro.runtime.tiered_kv as tiered_kv_mod
from repro.configs import get_config
from repro.configs.workloads import get_profile
from repro.data.requests import RequestGenerator
from repro.models.api import get_model
from repro.runtime.serving import EngineConfig, ServingEngine
from repro.runtime.sharded import ShardedServingEngine, ShardedTieredKV
from repro.runtime.tiered_kv import TieredKVCache

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count>=2",
)


# ---------------------------------------------------------------------------
# 1. store equivalence (1-device safe)


def _paired_stores(n_pages, n_shards, row_dim=16, capacity=10, slots=6):
    base = TieredKVCache(n_pages, row_dim, capacity, identity_scales=True,
                         counter_slots=slots)
    shrd = ShardedTieredKV(n_pages, row_dim, capacity, n_shards,
                           identity_scales=True, counter_slots=slots)
    rng = np.random.default_rng(0)
    rows = jnp.asarray(
        rng.integers(-127, 128, size=(n_pages, row_dim)), jnp.float32
    )
    for s in (base, shrd):
        s.write(np.arange(n_pages), rows)
    return base, shrd, rows, rng


def _drive(store, rng_seed, n_rounds=5, n_pages=64, capacity=10, slots=6):
    """One deterministic mixed stream: migrations + ragged segmented
    lookups with slot/tenant/role routing. Returns the concatenated rows."""
    rng = np.random.default_rng(rng_seed)
    got = []
    for _ in range(n_rounds):
        near = rng.choice(n_pages, size=rng.integers(0, capacity + 1), replace=False)
        store.migrate(near)
        seg_sizes = rng.integers(1, 9, size=rng.integers(1, slots + 1))
        ids = rng.integers(0, n_pages, size=seg_sizes.sum())
        seg_of = np.repeat(np.arange(seg_sizes.size), seg_sizes).astype(np.int32)
        got.append(
            np.asarray(
                store.lookup_segments(
                    ids, seg_of, slots + 1,
                    slot_idx=list(range(seg_sizes.size)),
                    tenant_idx=list(rng.integers(0, 3, size=seg_sizes.size)),
                    role_idx=list(rng.integers(0, 2, size=seg_sizes.size)),
                )
            )
        )
    return np.concatenate(got)


def test_sharded_store_rejects_non_divisor():
    with pytest.raises(ValueError, match="divide"):
        ShardedTieredKV(10, 8, 4, 3)


@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_sharded_store_counters_match_unsharded(n_shards):
    """The core merge algebra: same global stream, summed per-shard books
    == unsharded books — rows, totals, and every drained plane."""
    n_pages, cap, slots = 64, 10, 6
    base, shrd, _, _ = _paired_stores(n_pages, n_shards, capacity=cap, slots=slots)
    r_base = _drive(base, 7, n_pages=n_pages, capacity=cap, slots=slots)
    r_shrd = _drive(shrd, 7, n_pages=n_pages, capacity=cap, slots=slots)
    np.testing.assert_array_equal(r_base, r_shrd)
    for attr in ("near_hits", "far_hits", "writes", "moved_rows",
                 "moved_bytes", "near_count"):
        assert getattr(base, attr) == getattr(shrd, attr), attr
    db, ds = base.drain_counters(), shrd.drain_counters()
    assert (db["near"], db["far"]) == (ds["near"], ds["far"])
    np.testing.assert_array_equal(db["role"], ds["role"])
    np.testing.assert_array_equal(db["slot"], ds["slot"][: db["slot"].shape[0]])
    np.testing.assert_array_equal(db["tenant"], ds["tenant"][: db["tenant"].shape[0]])
    # per-shard deltas partition the totals exactly
    stats = shrd.stats()
    assert sum(stats["shard_near_hits"]) == stats["near_hits"]
    assert sum(stats["shard_far_hits"]) == stats["far_hits"]
    assert stats["shards"] == n_shards


def test_sharded_store_drain_cadence_invariance():
    """Draining each shard's plane after every lookup vs once at the end
    charges identical merged totals AND identical per-shard deltas — the
    PR-5 pure-sum invariant holds per shard."""
    n_pages, cap, slots = 64, 10, 6
    eager_tot = {"near": 0, "far": 0}
    eager_shards = None
    _, eager, _, _ = _paired_stores(n_pages, 2, capacity=cap, slots=slots)
    rng = np.random.default_rng(3)
    for _ in range(4):
        ids = rng.integers(0, n_pages, size=12)
        eager.lookup_segments(ids, np.zeros(12, np.int32), 2,
                              slot_idx=[0], tenant_idx=[0], role_idx=[0])
        d = eager.drain_counters()
        eager_tot["near"] += d["near"]
        eager_tot["far"] += d["far"]
    eager_shards = [dict(d) for d in eager.take_shard_drains()]

    _, lazy, _, _ = _paired_stores(n_pages, 2, capacity=cap, slots=slots)
    rng = np.random.default_rng(3)
    for _ in range(4):
        ids = rng.integers(0, n_pages, size=12)
        lazy.lookup_segments(ids, np.zeros(12, np.int32), 2,
                             slot_idx=[0], tenant_idx=[0], role_idx=[0])
    d = lazy.drain_counters()
    assert (d["near"], d["far"]) == (eager_tot["near"], eager_tot["far"])
    assert lazy.take_shard_drains() == eager_shards
    # and the take itself resets the pending deltas
    assert all(t == {"near": 0, "far": 0} for t in lazy.take_shard_drains())


def test_sharded_store_idle_shard_pays_zero():
    """A step whose page walk never touches a shard costs that shard
    nothing: no dispatch, and its clean plane drains without a host sync."""
    shrd = ShardedTieredKV(16, 8, 6, 2, identity_scales=True, counter_slots=2)
    shrd.write(np.arange(16), jnp.zeros((16, 8), jnp.float32))
    even = np.arange(0, 16, 2)  # all owned by shard 0
    shrd.lookup_segments(even, np.zeros(even.size, np.int32), 2,
                         slot_idx=[0], tenant_idx=[0], role_idx=[0])
    s = shrd.stats()
    assert s["shard_dispatches"] == [1, 0]
    shrd.drain_counters()
    assert shrd.shards[0].host_syncs == 1
    assert shrd.shards[1].host_syncs == 0


@pytest.mark.parametrize("n_shards", [2, 4])
def test_sharded_store_restricted_plan_never_cut(n_shards):
    """Any sanitized global near set restricted to a shard fits that
    shard's capacity (min(pages_owned, global_cap)), so the per-shard tier
    maps are exact restrictions of the unsharded map — sanitize's silent
    capacity cut can never fire shard-side."""
    n_pages, cap = 64, 10
    base, shrd, _, _ = _paired_stores(n_pages, n_shards, capacity=cap)
    rng = np.random.default_rng(11)
    for _ in range(10):
        near = rng.choice(n_pages, size=rng.integers(0, cap + 1), replace=False)
        mb, ms = base.migrate(near), shrd.migrate(near)
        assert mb == ms
        tier = np.concatenate(
            [np.flatnonzero(sh.tier_host == 0) * n_shards + s
             for s, sh in enumerate(shrd.shards)]
        )
        np.testing.assert_array_equal(
            np.sort(tier), np.flatnonzero(base.tier_host == 0)
        )
        assert shrd.near_count == base.near_count == near.size


# ---------------------------------------------------------------------------
# 2. engine equivalence


def _mk_base(**ekw):
    cfg = get_config("smollm-360m").reduced()
    api = get_model(cfg)
    if not hasattr(_mk_base, "_params"):
        _mk_base._params = api.init(jax.random.PRNGKey(0))
    kw = dict(
        max_batch=4, max_len=64, n_pages=256, near_frac=0.02, placement_window=4,
        device_tiering=True, tiered_identity_scales=True,
    )
    kw.update(ekw)
    return cfg, api, _mk_base._params, EngineConfig(**kw)


def _run_collect(eng, cfg, n_requests=6, seed=0):
    prof = dataclasses.replace(
        get_profile("Web1"), prompt_mean=24, decode_mean=8, prefix_share=0.5,
        n_prefixes=2,
    )
    gen = RequestGenerator(prof, vocab_size=cfg.vocab_size, seed=seed)
    for _ in range(n_requests):
        eng.submit(next(gen))
    tokens, steps = [], 0
    while (eng.queue or any(s.active for s in eng.slots)) and steps < 400:
        eng.step()
        tokens.append(np.asarray(eng.next_tokens))
        steps += 1
    return np.array(tokens)


def test_sharded_engine_validates_config():
    cfg, api, params, _ = _mk_base()
    with pytest.raises(ValueError, match="divide"):
        ShardedServingEngine(
            api, params, EngineConfig(max_batch=4, max_len=64, n_pages=256,
                                      device_tiering=True, model_shards=3)
        )
    with pytest.raises(ValueError):
        ShardedServingEngine(
            api, params,
            EngineConfig(max_batch=4, max_len=64, n_pages=256,
                         device_tiering=True,
                         model_shards=2 * len(jax.devices())),
        )


@pytest.mark.slow
def test_one_shard_engine_bit_exact():
    """The correctness anchor: a 1-shard mesh IS today's engine — same
    tokens, same drained counters, same tenant books, bit for bit."""
    cfg, api, params, ecfg = _mk_base(tiered_verify=True)
    base = ServingEngine(api, params, ecfg, seed=0)
    t_base = _run_collect(base, cfg)
    cfg, api, params, ecfg1 = _mk_base(tiered_verify=True, model_shards=1)
    shrd = ShardedServingEngine(api, params, ecfg1, seed=0)
    t_shrd = _run_collect(shrd, cfg)
    np.testing.assert_array_equal(t_base, t_shrd)
    assert base.live_counters() == shrd.live_counters()
    sb, ss = base.stats(), shrd.stats()
    for key in ("tokens_decoded", "requests_finished", "near_hit_rate",
                "migrations", "prefill_tokens", "prefetch_accuracy", "tenants"):
        assert sb[key] == ss[key], key
    db, dsh = sb["device_tiering"], ss["device_tiering"]
    for key in ("near_hits", "far_hits", "writes", "moved_rows", "moved_bytes",
                "dispatches", "decode_near_hits", "decode_far_hits",
                "prefill_near_hits", "prefill_far_hits", "max_read_error"):
        assert db[key] == dsh[key], key
    np.testing.assert_array_equal(base.role_hits, shrd.role_hits)
    assert dsh["shards"] == 1


@pytest.mark.slow
def test_one_shard_engine_bit_exact_across_degraded_toggle():
    """The 1-shard == unsharded anchor must survive a mid-run failure-mode
    transition: both engines enter degraded (near tier capacity-zeroed,
    far-tier-only serving) at the same step, keep serving, and exit at the
    same step — tokens and every merged counter stay bit-identical, and
    the store-level degraded flag fans out to the shard facade."""

    def run_toggled(eng, cfg):
        prof = dataclasses.replace(
            get_profile("Web1"), prompt_mean=24, decode_mean=8, prefix_share=0.5,
            n_prefixes=2,
        )
        gen = RequestGenerator(prof, vocab_size=cfg.vocab_size, seed=0)
        for _ in range(6):
            eng.submit(next(gen))
        tokens = []
        while (eng.queue or any(s.active for s in eng.slots)) and eng.engine_steps < 400:
            if eng.engine_steps == 6:
                eng.enter_degraded()
                assert eng.degraded and eng.tiered.degraded
            if eng.engine_steps == 14:
                eng.exit_degraded()
            eng.step()
            tokens.append(np.asarray(eng.next_tokens))
        return np.array(tokens)

    cfg, api, params, ecfg = _mk_base(tiered_verify=True)
    base = ServingEngine(api, params, ecfg, seed=0)
    t_base = run_toggled(base, cfg)
    cfg, api, params, ecfg1 = _mk_base(tiered_verify=True, model_shards=1)
    shrd = ShardedServingEngine(api, params, ecfg1, seed=0)
    t_shrd = run_toggled(shrd, cfg)
    np.testing.assert_array_equal(t_base, t_shrd)
    assert not base.degraded and not shrd.degraded
    assert base.live_counters() == shrd.live_counters()
    sb, ss = base.stats(), shrd.stats()
    for key in ("tokens_decoded", "requests_finished", "near_hit_rate",
                "prefill_tokens", "tenants"):
        assert sb[key] == ss[key], key
    db, dsh = sb["device_tiering"], ss["device_tiering"]
    for key in ("near_hits", "far_hits", "writes", "moved_rows", "moved_bytes",
                "dispatches"):
        assert db[key] == dsh[key], key
    # the toggle really bit: the window served far-only on both engines
    assert base.metrics.total("degraded_entries") == 1
    assert shrd.metrics.total("degraded_entries") == 1


def test_sharded_store_degraded_flag_and_discard_drain():
    """Store-facade contracts the failover path relies on: ``set_degraded``
    fans out to every shard (``degraded`` is the AND over them), a degraded
    ``migrate`` demotes and never promotes, and a quarantine drain
    (``discard=True``) returns the merged deltas without charging any
    shard's books."""
    n_pages, cap, slots = 64, 10, 6
    _, shrd, _, _ = _paired_stores(n_pages, 2, capacity=cap, slots=slots)
    shrd.migrate(np.arange(8))
    assert shrd.near_count == 8
    shrd.set_degraded(True)
    assert shrd.degraded and all(sh.degraded for sh in shrd.shards)
    shrd.migrate(np.arange(16))  # a promote plan while degraded...
    assert shrd.near_count == 0  # ...demotes everything instead
    ids = np.arange(12)
    shrd.lookup_segments(
        ids, np.zeros(ids.size, np.int32), 2, slot_idx=[0], tenant_idx=[0]
    )
    before = (shrd.near_hits, shrd.far_hits, shrd.drains)
    q = shrd.drain_counters(discard=True)
    assert q["near"] == 0 and q["far"] == ids.size  # far-tier-only serving
    assert (shrd.near_hits, shrd.far_hits, shrd.drains) == before  # uncharged
    # plane is clean after the quarantine: a real drain charges nothing
    d = shrd.drain_counters()
    assert d["near"] == 0 and d["far"] == 0
    shrd.set_degraded(False)
    assert not shrd.degraded


@multi_device
@pytest.mark.slow
@pytest.mark.parametrize("n_shards", [2, 4])
def test_n_shard_counter_merge_equals_one_shard(n_shards):
    """N-shard merged counters == 1-shard totals on the same request
    stream. Token VALUES may drift across shard counts (cross-device float
    reassociation in the model math); the page walks, and therefore every
    counter plane, cannot."""
    cfg, api, params, e1 = _mk_base(model_shards=1)
    one = ShardedServingEngine(api, params, e1, seed=0)
    _run_collect(one, cfg)
    cfg, api, params, en = _mk_base(model_shards=n_shards)
    many = ShardedServingEngine(api, params, en, seed=0)
    _run_collect(many, cfg)
    s1, sn = one.stats(), many.stats()
    assert s1["tenants"] == sn["tenants"]
    assert s1["tokens_decoded"] == sn["tokens_decoded"]
    assert s1["requests_finished"] == sn["requests_finished"]
    d1, dn = s1["device_tiering"], sn["device_tiering"]
    for key in ("near_hits", "far_hits", "writes", "moved_rows",
                "decode_near_hits", "decode_far_hits",
                "prefill_near_hits", "prefill_far_hits"):
        assert d1[key] == dn[key], key
    np.testing.assert_array_equal(one.role_hits, many.role_hits)
    # the merge really is a sum over shard-disjoint planes
    assert sum(dn["shard_near_hits"]) == dn["near_hits"]
    assert sum(dn["shard_far_hits"]) == dn["far_hits"]
    assert dn["shards"] == n_shards
    # shard-labeled flight-recorder rows carry the same partition: summing
    # them reproduces the replica totals (they merge as pure sums upstream)
    assert many.metrics.total("shard_near_hits") == dn["near_hits"]
    assert many.metrics.total("shard_far_hits") == dn["far_hits"]


@multi_device
@pytest.mark.slow
def test_sharded_dispatch_and_sync_budget(monkeypatch):
    """Budget at N shards: at most one segmented dispatch per shard per
    step (idle shards pay zero), and host syncs happen ONLY at drain
    boundaries — never per step."""
    calls = []
    orig_seg = tiered_kv_mod.tiered_lookup_segments

    def seg(*a, **k):
        calls.append("seg")
        return orig_seg(*a, **k)

    monkeypatch.setattr(tiered_kv_mod, "tiered_lookup_segments", seg)
    n_shards = 2
    cfg, api, params, ecfg = _mk_base(model_shards=n_shards)
    eng = ShardedServingEngine(api, params, ecfg, seed=0)
    prof = dataclasses.replace(
        get_profile("Web1"), prompt_mean=24, decode_mean=8, prefix_share=0.5,
        n_prefixes=2,
    )
    gen = RequestGenerator(prof, vocab_size=cfg.vocab_size, seed=0)
    for _ in range(6):
        eng.submit(next(gen))
    while (eng.queue or any(s.active for s in eng.slots)) and eng.engine_steps < 200:
        before = len(calls)
        eng.step()
        assert 1 <= len(calls) - before <= n_shards, (len(calls) - before)
    st = eng.stats()["device_tiering"]
    assert eng.tiered.dispatches == len(calls)
    assert all(d <= eng.engine_steps for d in st["shard_dispatches"])
    # zero mandatory per-step syncs: every sync is a (windowed) drain
    assert eng.tiered.host_syncs == eng.tiered.drains
    assert st["host_syncs_per_step"] < 1.0


@multi_device
@pytest.mark.slow
def test_sharded_per_shard_drain_cadence_invariance():
    """Per-step drains vs windowed drains on an N-shard engine: merged
    books AND the shard-labeled counter rows are identical — each shard's
    plane is a pure sum, so cadence is invisible per shard too."""
    engines = []
    for _ in range(2):
        cfg, api, params, ecfg = _mk_base(model_shards=2)
        e = ShardedServingEngine(api, params, ecfg, seed=0)
        prof = dataclasses.replace(
            get_profile("Web1"), prompt_mean=24, decode_mean=8,
            prefix_share=0.5, n_prefixes=2,
        )
        gen = RequestGenerator(prof, vocab_size=cfg.vocab_size, seed=5)
        for _ in range(6):
            e.submit(next(gen))
        engines.append(e)
    windowed, every_step = engines
    while (windowed.queue or any(s.active for s in windowed.slots)) and windowed.engine_steps < 200:
        windowed.step()
        every_step.step()
        every_step.drain_tier_counters()
    sw, se = windowed.stats(), every_step.stats()
    assert sw["tenants"] == se["tenants"]
    dw, de = sw["device_tiering"], se["device_tiering"]
    assert (dw["near_hits"], dw["far_hits"]) == (de["near_hits"], de["far_hits"])
    assert de["drains"] > dw["drains"]

    def shard_rows(eng):
        return {
            k: v
            for k, v in eng.metrics.snapshot().counters.items()
            if k[0] in ("shard_near_hits", "shard_far_hits")
        }

    assert shard_rows(windowed) == shard_rows(every_step)
