"""Trainer (fault tolerance, stragglers), checkpointing, data, serving."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.configs.workloads import get_profile
from repro.data.loader import ShardedLoader
from repro.data.requests import RequestGenerator
from repro.data.synthetic import SyntheticCorpus, token_batches
from repro.models.api import get_model
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.compression import (
    compress_int8,
    decompress_int8,
    ef_compress_tree,
    ef_decompress_tree,
    init_residuals,
)
from repro.runtime.serving import EngineConfig, ServingEngine
from repro.runtime.trainer import SimulatedFailure, StragglerMonitor, Trainer, TrainerConfig


def _mk_trainer(tmp, arch="smollm-360m", **tkw):
    cfg = get_config(arch).reduced()
    api = get_model(cfg)
    tr = Trainer(api, AdamWConfig(lr=1e-3), TrainerConfig(ckpt_dir=str(tmp), ckpt_every=3, **tkw))
    return cfg, api, tr


# ---------------------------------------------------------------------------
# trainer


@pytest.mark.slow
def test_loss_decreases(tmp_path):
    cfg, api, tr = _mk_trainer(tmp_path)
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seq_len=16)
    tr.init_state()
    log = tr.run(token_batches(corpus, 8), 20)
    first = np.mean([m["loss"] for m in log[:4]])
    last = np.mean([m["loss"] for m in log[-4:]])
    assert last < first, (first, last)


@pytest.mark.slow
def test_crash_resume_bitwise(tmp_path):
    """Crash at step 5, restart -> identical params at step 9 as a clean run."""
    cfg, api, tr = _mk_trainer(tmp_path / "a")
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seq_len=16)
    tr.init_state()
    with pytest.raises(SimulatedFailure):
        tr.run(token_batches(corpus, 8), 9, fail_at=5)
    tr.ckpt.wait()
    # restart from disk
    cfg2, api2, tr2 = _mk_trainer(tmp_path / "a")
    assert tr2.try_restore()
    assert tr2.step == 3  # last checkpoint (ckpt_every=3)
    tr2.run(token_batches(corpus, 8, start_step=tr2.step), 9 - tr2.step)
    # clean run, no crash
    cfg3, api3, tr3 = _mk_trainer(tmp_path / "b")
    tr3.init_state()
    tr3.run(token_batches(corpus, 8), 9)
    for a, b in zip(jax.tree.leaves(tr2.params), jax.tree.leaves(tr3.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(z=3.0, min_steps=4)
    for i in range(20):
        mon.observe(i, 0.1 + 0.001 * (i % 3))
    assert not mon.flagged
    assert mon.observe(20, 2.0)  # 20x step time -> straggler
    assert mon.flagged and mon.flagged[-1][0] == 20


# ---------------------------------------------------------------------------
# checkpoint manager


def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"w": jnp.arange(12.0).reshape(3, 4), "n": jnp.int32(7)}
    for step in (1, 2, 3):
        mgr.save(step, state)
    assert mgr.latest_step() == 3
    restored, extras = mgr.restore(state)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(state["w"]))
    steps = sorted(int(d.split("_")[-1]) for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == [2, 3]  # keep=2 garbage-collected step 1


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"w": jnp.ones((128, 128))}
    mgr.save_async(10, state)
    mgr.wait()
    assert mgr.latest_step() == 10


# ---------------------------------------------------------------------------
# optimizer + gradient compression


def test_adamw_reference_step():
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0, clip_norm=1e9)
    params = {"w": jnp.array([1.0, -2.0])}
    grads = {"w": jnp.array([0.5, 0.5])}
    state = adamw_init(params)
    new_p, state, _ = adamw_update(cfg, params, grads, state)
    m = 0.1 * 0.5
    v = 0.001 * 0.25
    mhat, vhat = m / 0.1, v / 0.001
    want = 1.0 - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(float(new_p["w"][0]), want, rtol=1e-5)


def test_int8_compression_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 3.0
    codes, scale, shape = compress_int8(x)
    assert codes.dtype == jnp.int8
    y = decompress_int8(codes, scale, shape)
    err = float(jnp.abs(x - y).max()) / float(jnp.abs(x).max())
    assert err < 0.02  # ~1/127


def test_error_feedback_accumulates():
    """EF: compressing the same grad repeatedly converges (residual shrinks)."""
    grads = {"w": jax.random.normal(jax.random.PRNGKey(1), (64,))}
    residuals = init_residuals(grads)
    total = jnp.zeros((64,))
    for _ in range(8):
        payload, residuals = ef_compress_tree(grads, residuals)
        total = total + ef_decompress_tree(payload)["w"]
    np.testing.assert_allclose(np.asarray(total / 8), np.asarray(grads["w"]), atol=0.02)


# ---------------------------------------------------------------------------
# data


def test_loader_determinism_and_restore():
    corpus = SyntheticCorpus(vocab_size=128, seq_len=8)
    l1 = ShardedLoader(corpus, global_batch=4, host_id=0, n_hosts=1)
    batches = [next(l1) for _ in range(6)]
    state = l1.state()
    nxt = next(l1)
    l1.close()
    l2 = ShardedLoader.restore(corpus, 4, state, host_id=0, n_hosts=1)
    nxt2 = next(l2)
    l2.close()
    np.testing.assert_array_equal(nxt[1]["tokens"], nxt2[1]["tokens"])


def test_loader_host_sharding_disjoint():
    corpus = SyntheticCorpus(vocab_size=128, seq_len=8)
    l0 = ShardedLoader(corpus, global_batch=8, host_id=0, n_hosts=2)
    l1 = ShardedLoader(corpus, global_batch=8, host_id=1, n_hosts=2)
    _, b0 = next(l0)
    _, b1 = next(l1)
    l0.close()
    l1.close()
    assert b0["tokens"].shape == (4, 8)  # half the global batch each
    assert not np.array_equal(b0["tokens"], b1["tokens"])


# ---------------------------------------------------------------------------
# serving engine (tiering + prefix sharing + prefetch live)


def _engine(arch="smollm-360m", **ekw):
    cfg = get_config(arch).reduced()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    ecfg = EngineConfig(max_batch=4, max_len=64, n_pages=512, **ekw)
    return cfg, ServingEngine(api, params, ecfg)


def test_engine_serves_requests():
    cfg, eng = _engine()
    prof = dataclasses.replace(get_profile("Web1"), prompt_mean=20, decode_mean=6)
    gen = RequestGenerator(prof, vocab_size=cfg.vocab_size, seed=0)
    stats = eng.run(gen, n_requests=8, max_steps=400)
    assert stats["requests_finished"] == 8
    assert stats["tokens_decoded"] > 0
    assert 0.0 <= stats["prefetch_accuracy"] <= 1.0


def test_engine_prefix_sharing_saves_prefill():
    """High prefix-share profile must dedupe prefill pages (paper §4 sharing)."""
    cfg, eng = _engine()
    prof = dataclasses.replace(
        get_profile("Web1"), prompt_mean=32, decode_mean=4, prefix_share=1.0, n_prefixes=1
    )
    gen = RequestGenerator(prof, vocab_size=cfg.vocab_size, seed=1)
    stats = eng.run(gen, n_requests=10, max_steps=500)
    assert stats["prefill_tokens_saved"] > 0
    assert eng.pagetable.stats()["shared_mappings"] > 0


def test_engine_tiering_hit_rate():
    cfg, eng = _engine(near_frac=0.5)
    prof = dataclasses.replace(get_profile("Cache1"), prompt_mean=16, decode_mean=8)
    gen = RequestGenerator(prof, vocab_size=cfg.vocab_size, seed=2)
    stats = eng.run(gen, n_requests=8, max_steps=400)
    assert 0.0 <= stats["near_hit_rate"] <= 1.0
