"""rwkv6-7b [ssm] — Finch, attention-free, data-dependent decay.
[arXiv:2404.05892; hf]

Attention-free: KV tiering / prefix sharing inapplicable (O(1) state);
parameter pooling + embedding-row tiering apply. Runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,  # wkv heads = d_model / ssm_head_dim
    n_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    grad_accum=8,
    pooling_cluster=4,  # §Perf: pooled (ZeRO) storage pins grads/opt math
    # to the sharded layout — without it GSPMD replicates the (L,D,D) f32
    # AdamW pipeline (30 GiB/chip); with it the cell fits at 15.7 GiB.
    ssm_head_dim=64,
    rope_theta=0.0,  # no RoPE (attention-free)
    source="arXiv:2404.05892; hf",
)
