"""Mamba2 (SSD) block — state-space core used by the zamba2 hybrid.

Selective state-space recurrence per head (P = head dim, N = state dim):

    S_t = exp(dt_t * A_h) * S_{t-1} + dt_t * x_t B_t^T        S: (P, N)
    y_t = S_t C_t + D_h x_t

with a causal depthwise conv in front of (x, B, C) and a gated RMSNorm after.
jnp path scans over time; kernels/mamba2_scan holds the chunked Pallas kernel
with this as oracle. Decode state is O(1): (conv tail, SSM state).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.mesh import BATCH, MODEL, shard
from repro.models import common

Array = jax.Array


def dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    n_heads = d_in // cfg.ssm_head_dim
    return d_in, n_heads, cfg.ssm_state


def init_block(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    d_in, nh, ns = dims(cfg)
    conv_dim = d_in + 2 * ns
    ks = jax.random.split(key, 4)
    return {
        "ln": jnp.ones((d,), dtype),
        "w_in": common.dense_init(ks[0], (d, 2 * d_in + 2 * ns + nh), dtype=dtype),
        "conv_w": common.dense_init(ks[1], (cfg.ssm_conv, conv_dim), dtype=jnp.float32, scale=1.0),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.zeros((nh,), jnp.float32),  # A = -exp(A_log) = -1
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.full((nh,), -2.0, jnp.float32),  # softplus(-2) ~ 0.13
        "norm_w": jnp.ones((d_in,), dtype),
        "w_out": common.dense_init(
            ks[2], (d_in, d), scale=1.0 / (2 * max(cfg.n_layers, 1)) ** 0.5, dtype=dtype
        ),
    }


def block_specs(cfg: ModelConfig) -> dict:
    return {
        "ln": (None,),
        "w_in": (None, MODEL),
        "conv_w": (None, MODEL),
        "conv_b": (MODEL,),
        "A_log": (MODEL,),
        "D": (MODEL,),
        "dt_bias": (MODEL,),
        "norm_w": (MODEL,),
        "w_out": (MODEL, None),
    }


def _causal_conv(x: Array, w: Array, b: Array, tail: Optional[Array] = None):
    """Depthwise causal conv. x: (B,T,C); w: (K,C); tail: (B,K-1,C) carry-in.

    Returns (y (B,T,C), new_tail (B,K-1,C)).
    """
    k = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)  # (B, T+K-1, C)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k)) + b
    return y, xp[:, -(k - 1) :, :] if k > 1 else jnp.zeros_like(tail)


def _ssd_seq(state, x, dt, A, B, C):
    """Per-token SSD over (b,T,...) inputs from ``state``."""

    def step(s, inp):
        x_t, dt_t, b_t, c_t = inp  # (b,H,P), (b,H), (b,N), (b,N)
        da = jnp.exp(dt_t * A)  # (b,H), A<0 so da in (0,1)
        s = s * da[..., None, None] + (dt_t[..., None] * x_t)[..., None] * b_t[:, None, None, :]
        y = jnp.einsum("bhpn,bn->bhp", s, c_t)
        return s, y

    xs = (
        x.transpose(1, 0, 2, 3),
        dt.transpose(1, 0, 2),
        B.transpose(1, 0, 2),
        C.transpose(1, 0, 2),
    )
    state, ys = jax.lax.scan(step, state, xs)
    return state, ys.transpose(1, 0, 2, 3)


def ssd_scan(x, dt, A, B, C, D, state=None, chunk: int = 128):
    """Chunked SSD. x: (b,T,H,P); dt: (b,T,H); A,D: (H,); B,C: (b,T,N).

    Returns (y (b,T,H,P), final_state (b,H,P,N)). All f32. Chunking +
    checkpointed chunk bodies bound the backward pass to per-chunk state
    saves (a plain per-token scan saves the (b,H,P,N) state at every step —
    see models/rwkv6.wkv6 for the same fix, and kernels/mamba2_scan for the
    Pallas dataflow this mirrors).
    """
    b, t, h, p = x.shape
    n = B.shape[-1]
    if state is None:
        state = jnp.zeros((b, h, p, n), jnp.float32)
    if t <= chunk or t % chunk != 0:
        state, ys = _ssd_seq(state, x, dt, A, B, C)
        return ys + x * D[None, None, :, None], state

    nc = t // chunk

    def chunk_body(s, xs):
        xc, dtc, bc, cc = xs
        s, yc = _ssd_seq(s, xc, dtc, A, bc, cc)
        return s, yc

    chunk_body = jax.checkpoint(chunk_body, prevent_cse=False)
    xs = (
        x.reshape(b, nc, chunk, h, p).transpose(1, 0, 2, 3, 4),
        dt.reshape(b, nc, chunk, h).transpose(1, 0, 2, 3),
        B.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3),
        C.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3),
    )
    state, ys = jax.lax.scan(chunk_body, state, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, t, h, p)
    return y + x * D[None, None, :, None], state


def init_state(cfg: ModelConfig, batch: int):
    d_in, nh, ns = dims(cfg)
    conv_dim = d_in + 2 * ns
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), jnp.float32),
        "ssm": jnp.zeros((batch, nh, cfg.ssm_head_dim, ns), jnp.float32),
    }


def apply(p: dict, cfg: ModelConfig, x: Array, state: Optional[dict] = None):
    p = common.constrain_tree(p, block_specs(cfg), common.dt(cfg.compute_dtype))
    """Full mamba2 block (pre-norm, residual outside). x: (B,T,D).

    Returns (out (B,T,D), new_state).
    """
    b, t, d = x.shape
    d_in, nh, ns = dims(cfg)
    hd = cfg.ssm_head_dim
    if state is None:
        state = init_state(cfg, b)

    xn = common.rms_norm(x, p["ln"], cfg.norm_eps)
    proj = jnp.einsum("btd,de->bte", xn, p["w_in"], preferred_element_type=jnp.float32)
    z, xbc, dt_raw = jnp.split(proj, [d_in, 2 * d_in + 2 * ns], axis=-1)
    conv_out, conv_tail = _causal_conv(xbc, p["conv_w"], p["conv_b"], state["conv"])
    conv_out = jax.nn.silu(conv_out)
    xs, B, C = jnp.split(conv_out, [d_in, d_in + ns], axis=-1)
    xs = shard(xs.reshape(b, t, nh, hd), BATCH, None, MODEL, None)
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])  # (B,T,H)
    A = -jnp.exp(p["A_log"])
    y, ssm_state = ssd_scan(xs, dt, A, B, C, p["D"], state["ssm"])
    y = y.reshape(b, t, d_in)
    # gated RMSNorm (mamba2 style): norm(y * silu(z))
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    y = (yf * jax.lax.rsqrt(var + cfg.norm_eps) * p["norm_w"].astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bte,ed->btd", y, p["w_out"], preferred_element_type=jnp.float32).astype(x.dtype)
    return out, {"conv": conv_tail, "ssm": ssm_state}
