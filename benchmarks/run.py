"""Benchmark suite driver: one benchmark per paper table/figure.

PYTHONPATH=src python -m benchmarks.run            # all
PYTHONPATH=src python -m benchmarks.run table5     # one

``--trace out.json`` attaches the fleet flight recorder (repro.obs) for the
whole run: every engine/fleet the selected benchmarks build emits
request-lifecycle spans and registry metrics through one process-global
recorder, exported on exit as Perfetto/Chrome trace-event JSON (open at
https://ui.perfetto.dev) plus ``out.json.metrics.jsonl``.
"""
import importlib
import os
import sys
import time
import traceback

sys.path.insert(0, os.path.dirname(__file__))  # allow intra-package helpers

MODULES = [
    "fig9_code_bw",
    "table2_correlation",
    "fig13_pooling",
    "fig17_pagetable",
    "fig18_membw_dist",
    "table5_tiering",
    "fig21_prefetch_bw",
    "fig22_prefetch_acc",
    "table6_trace",
    "fleet_bench",
    "chaos_bench",
    "straggler_bench",
    "tenant_interference",
    "tiered_decode_bench",
    "decode_dispatch_bench",
    "kernels_bench",
]


def parse_trace_flag(argv):
    """Split ``--trace PATH`` out of argv; returns (path_or_None, rest)."""
    argv = list(argv)
    if "--trace" not in argv:
        return None, argv
    i = argv.index("--trace")
    if i + 1 >= len(argv):
        raise SystemExit("--trace requires an output path")
    path = argv[i + 1]
    return path, argv[:i] + argv[i + 2 :]


def main(argv):
    trace_path, argv = parse_trace_flag(argv)
    recorder = None
    if trace_path is not None:
        from repro.obs import FlightRecorder, set_default_recorder

        recorder = FlightRecorder()
        set_default_recorder(recorder)
    sel = [m for m in MODULES if not argv or any(a in m for a in argv)]
    if argv and not sel:
        print(f"no benchmark matches {argv}; available: {MODULES}")
        return 2
    failures = []
    for name in sel:
        print("\n" + "=" * 78)
        t0 = time.time()
        try:
            mod = importlib.import_module(name)
            rc = mod.main()
            # benchmarks return result dicts on success; an int is a
            # process-style return code (fleet_bench's self-check)
            if isinstance(rc, int) and rc != 0:
                failures.append(name)
                print(f"[{name}] FAILED: main() returned {rc}")
            else:
                print(f"[{name}] ok in {time.time()-t0:.1f}s")
        except Exception:  # noqa: BLE001
            failures.append(name)
            print(f"[{name}] FAILED:\n{traceback.format_exc(limit=6)}")
    print("\n" + "=" * 78)
    print(f"benchmarks: {len(sel) - len(failures)}/{len(sel)} ok" + (f"; failed: {failures}" if failures else ""))
    if recorder is not None:
        # one timeline over everything that ran; the schema gate only holds
        # within a single scenario (benchmarks rebuild fleets, reusing rids
        # on one timeline), so the suite export skips validation — the CI
        # smoke job validates a single-scenario trace instead
        summary = recorder.write(trace_path, validate=False)
        print(f"flight recorder: {summary['events']} trace events -> {trace_path} "
              f"(+ {trace_path}.metrics.jsonl)")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
