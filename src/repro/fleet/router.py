"""Request routing over N replicas, with prefix-affinity as the headline.

The shared KV page table dedups prompt prefixes *within one host* — sharing
only materializes if requests carrying the same template land on the same
replica while its pages are resident. Prefix-affinity routing is therefore
the fleet-level counterpart of the paper's multi-ASID TLB sharing: it steers
same-code (same-template) requests to the host already holding those
translations, so the per-host dedup the paper measures actually happens at
fleet scale. Round-robin and least-loaded are the controls.

Multi-tenant dispatch: requests are offered into per-tenant queues and a
weighted-fair pick (virtual-time, deterministic tie-break on tenant name)
decides which tenant's head request is routed next — *before* replica
selection. A burst tenant therefore waits behind its own queue while other
tenants keep dispatching at their weighted share; its overload is charged
to its own SLO by the admission controller, never to its neighbors'.

Fleet stepping is event-driven (fleet/scheduler.py): each replica posts a
step-completion event when its ``step_cost`` of virtual time elapses, and
the router dispatches from the tenant queues at every completion batch —
a 4x straggler slows ONE host, not the fleet barrier. The legacy lockstep
path is kept as a compatibility mode (``run(..., lockstep=True)``); with
homogeneous speeds and no scaling events the two schedules are identical
batch for batch, so lockstep-vs-event equivalence is testable bit-exactly.

``simulated_throughput`` scores a fleet run with a simple cost model in
token-equivalents: prefill work not recovered by sharing, plus decode work
inflated by far-tier latency (hw.TPU_TIERED's relative latencies) — the same
three levers as core/tiering's roofline, in request-serving units.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.core.hw import TPU_TIERED
from repro.data.requests import Request, RequestGenerator
from repro.env import env_flag
from repro.fleet.admission import AdmissionController, SLOModel
from repro.fleet.replica import Replica, ReplicaProfile
from repro.fleet.scheduler import ARRIVAL, TIMEOUT, VirtualScheduler
from repro.obs import (
    Histogram,
    MetricSnapshot,
    MetricsRegistry,
    default_recorder,
    merge_snapshots,
)

FAR_LATENCY_REL = TPU_TIERED[1].latency_rel  # host-DRAM far tier vs HBM

_FALLBACK_SLO = SLOModel()  # cost model for fairness when no admission is set

# default fleet-stepping mode when run() isn't told explicitly; CI flips
# this to exercise the legacy path against the same test suite
_LOCKSTEP_ENV = "REPRO_FLEET_LOCKSTEP"


class RoundRobinPolicy:
    name = "round-robin"

    def __init__(self):
        self._next = 0

    def choose(self, req: Request, replicas: List[Replica]) -> int:
        i = self._next % len(replicas)
        self._next += 1
        return i


class LeastLoadedPolicy:
    name = "least-loaded"

    def choose(self, req: Request, replicas: List[Replica]) -> int:
        return int(np.argmin([r.load for r in replicas]))


class PrefixAffinityPolicy:
    """Route shared-template requests to the replica holding the prefix.

    Unique prompts (prefix_id == -1) fall back to least-loaded. A sticky
    mapping overloaded past ``spill_factor``x the mean load spills to the
    least-loaded replica instead (a hot template must not melt one host).
    Homes are keyed by replica ``rid``, not list position — the elastic
    fleet adds and retires replicas, so positions are not stable. A home
    whose host has been retired is reassigned to the least-loaded replica.
    """

    name = "prefix-affinity"

    def __init__(self, spill_factor: float = 3.0):
        self.spill_factor = spill_factor
        self.home: Dict[int, int] = {}  # prefix_id -> replica rid
        self.affinity_hits = 0
        self.spills = 0

    def choose(self, req: Request, replicas: List[Replica]) -> int:
        loads = [r.load for r in replicas]
        least = int(np.argmin(loads))
        if req.prefix_id < 0:
            return least
        by_rid = {r.rid: idx for idx, r in enumerate(replicas)}
        i = by_rid.get(self.home.get(req.prefix_id, -1))
        if i is None:
            self.home[req.prefix_id] = replicas[least].rid
            return least
        mean = max(sum(loads) / len(loads), 1.0)
        if loads[i] > self.spill_factor * mean and loads[i] > loads[least]:
            self.spills += 1
            return least
        self.affinity_hits += 1
        return i


POLICIES = {
    "round-robin": RoundRobinPolicy,
    "least-loaded": LeastLoadedPolicy,
    "prefix-affinity": PrefixAffinityPolicy,
}


class FleetRouter:
    """Per-tenant queueing + dispatch + stepping of the replica set.

    ``admission`` (optional) gates every offer; ``tenant_weights`` sets the
    weighted-fair dispatch shares (default: equal weights); ``on_step``
    hooks (the AutoTierer, the ElasticFleet) run after every completion
    batch with the current virtual time. In lockstep mode virtual time
    advances by the *max* replica step cost per fleet step — the barrier
    the event-driven scheduler removes.
    """

    def __init__(
        self,
        replicas: List[Replica],
        policy,
        admission: Optional[AdmissionController] = None,
        tenant_weights: Optional[Dict[str, float]] = None,
    ):
        assert replicas
        self.replicas = replicas
        self.policy = policy
        self.admission = admission
        self.tenant_weights = dict(tenant_weights or {})
        # deques: dispatch pops the head of a tenant queue on every
        # completion batch, and list.pop(0) is O(queue) — O(n^2) under a
        # burst-tenant backlog
        self.tenant_queues: Dict[str, Deque[Request]] = {}
        self._vtime: Dict[str, float] = {}  # weighted-fair virtual time
        self.on_step: List = []
        self.fleet_steps = 0
        self.routed = 0
        self.shed = 0
        self.routed_by: Dict[str, int] = {}
        self.shed_by: Dict[str, int] = {}
        # fleet virtual time + queue-wait accounting (virtual-time units)
        self._now = 0.0
        self._enqueue_time: Dict[int, float] = {}  # id(req) -> offer time
        self.wait_samples: Dict[str, List[float]] = {}
        self.scheduler: Optional[VirtualScheduler] = None
        self.mode = "idle"
        self.elastic = None  # ElasticFleet, attached by build_fleet
        self.autotierer = None  # AutoTierer, attached by build_fleet
        self.chaos = None  # ChaosEngine, attached by fleet/faults.py
        # callbacks invoked with each run's fresh scheduler before any
        # event executes — the chaos engine posts its fault events here
        self.on_run_start: List = []
        # ---- failure machinery (fleet/faults.py forces these into use) --
        # per-dispatch watchdog: a started step that hasn't completed
        # within this much virtual time is declared hung and failed over.
        # None (default) disables the watchdog — zero scheduling overhead
        # and bit-identical event books either way (cancelled timeouts
        # leave no trace; see scheduler.py).
        self.dispatch_timeout: Optional[float] = None
        self.max_retries = 3
        self.retry_backoff = 1.0  # re-queue delay: backoff * attempt number
        # in-flight step dedup guard: replica rid -> (step seq, timeout
        # Event). A completion or timeout whose seq no longer matches is
        # stale — its step was failed over — and must be a no-op, which is
        # what stops a slow-but-alive host's late completion from double-
        # counting tokens its retry already re-decoded elsewhere.
        self._pending: Dict[int, tuple] = {}
        self._step_seq = 0
        # terminal outcome ledger: every rid that enters the fleet ends as
        # "completed", "shed", or "failed:<reason>" — outcome_report()
        # flags anything still pending (the no-silent-drops invariant)
        self.admitted_rids: set = set()
        self.outcomes: Dict[int, str] = {}
        self.attempts: Dict[int, int] = {}
        self.owner: Dict[int, int] = {}  # rid -> replica rid serving it
        self._fin_seen: Dict[int, int] = {}  # replica rid -> finished[] index
        # crash-retirement books (salvaged host stats + quantified loss)
        self.crashed_stats: List[dict] = []
        self.crashed_profiles: List[ReplicaProfile] = []
        self.lost_windows: List[dict] = []
        # unified metrics plane: the router's registry carries the fleet-
        # scoped series (routed/shed counters, queue-wait histograms); the
        # fleet metric view is merge_snapshots over this + every replica
        # engine registry + retired profiles (metric_snapshots below)
        self.metrics = MetricsRegistry()
        self.recorder = None  # FlightRecorder, via attach_recorder
        if default_recorder() is not None:
            self.attach_recorder(default_recorder())

    # ------------------------------------------------------------------
    # flight recorder

    def attach_recorder(self, rec):
        """Wire a FlightRecorder into the fleet: it reads this router's
        virtual clock, snapshots on every completion batch, and every
        replica's engine (present and future — see ElasticFleet.scale_up)
        emits spans/metrics through it."""
        self.recorder = rec
        rec.now_fn = lambda: self._now
        rec.register(self.metrics)
        for r in self.replicas:
            self._attach_engine(r)
        if rec.on_step not in self.on_step:
            self.on_step.append(rec.on_step)

    def _attach_engine(self, replica: Replica):
        """Point one replica's engine at the fleet clock + recorder."""
        eng = replica.engine
        eng.now_fn = lambda: self._now
        if self.recorder is not None:
            eng.recorder = self.recorder
            self.recorder.register(eng.metrics)

    # ------------------------------------------------------------------
    # tenant bookkeeping

    def _weight(self, tenant: str) -> float:
        return max(self.tenant_weights.get(tenant, 1.0), 1e-9)

    def _weight_share(self, tenant: str) -> float:
        """This tenant's fair share among tenants the router knows about."""
        known = set(self.tenant_queues) | set(self.tenant_weights) | {tenant}
        total = sum(self._weight(t) for t in known)
        return self._weight(tenant) / max(total, 1e-9)

    def _tenant_backlog_tokens(self, tenant: str) -> float:
        slo = self.admission.slo_for(tenant) if self.admission else _FALLBACK_SLO
        return sum(slo.request_cost(r) for r in self.tenant_queues.get(tenant, ()))

    def queued(self, tenant: Optional[str] = None) -> int:
        if tenant is not None:
            return len(self.tenant_queues.get(tenant, ()))
        return sum(len(q) for q in self.tenant_queues.values())

    @property
    def active_replicas(self) -> List[Replica]:
        """Replicas eligible for new work (draining, dead and quarantined-
        hung hosts excluded)."""
        return [
            r for r in self.replicas if not r.draining and r.alive and not r.hung
        ]

    # ------------------------------------------------------------------
    # offer / dispatch

    def offer(self, req: Request) -> bool:
        """Admission-gate one request into its tenant queue (no routing yet)."""
        tenant = req.tenant
        if self.admission is not None and not self.admission.admit(
            req,
            self.active_replicas,
            tenant_backlog_tokens=self._tenant_backlog_tokens(tenant),
            weight_share=self._weight_share(tenant),
        ):
            self.shed += 1
            self.shed_by[tenant] = self.shed_by.get(tenant, 0) + 1
            self.outcomes[req.rid] = "shed"
            self.metrics.counter("shed", tenant=tenant).inc()
            if self.recorder is not None:
                self.recorder.instant("shed", req.rid, self._now, tenant=tenant)
            return False
        self.tenant_queues.setdefault(tenant, deque()).append(req)
        self._enqueue_time[id(req)] = self._now
        self.admitted_rids.add(req.rid)
        self.metrics.counter("admitted", tenant=tenant).inc()
        if self.recorder is not None:
            self.recorder.instant("admit", req.rid, self._now, tenant=tenant)
            self.recorder.begin("queue", req.rid, self._now, tenant=tenant)
        return True

    def _pick_tenant(self) -> Optional[str]:
        ready = [t for t, q in self.tenant_queues.items() if q]
        if not ready:
            return None
        return min(ready, key=lambda t: (self._vtime.get(t, 0.0), t))

    def dispatch(self, budget: Optional[int] = None) -> int:
        """Route up to ``budget`` queued requests (all, if None) in
        weighted-fair tenant order; returns number routed."""
        n = 0
        while budget is None or n < budget:
            targets = self.active_replicas
            if not targets:
                break
            tenant = self._pick_tenant()
            if tenant is None:
                break
            req = self.tenant_queues[tenant].popleft()
            chosen = targets[self.policy.choose(req, targets)]
            chosen.submit(req)
            self.owner[req.rid] = chosen.rid
            wait = self._now - self._enqueue_time.pop(id(req), self._now)
            self.wait_samples.setdefault(tenant, []).append(wait)
            self.metrics.histogram("queue_wait", tenant=tenant).record(wait)
            self.routed += 1
            self.routed_by[tenant] = self.routed_by.get(tenant, 0) + 1
            self.metrics.counter("routed", tenant=tenant).inc()
            if self.recorder is not None:
                self.recorder.end("queue", req.rid, self._now, wait=wait)
                self.recorder.instant(
                    "dispatch", req.rid, self._now, tenant=tenant, replica=chosen.rid
                )
            # virtual time advances by inverse weight: a weight-2 tenant is
            # picked twice as often as a weight-1 tenant under contention
            self._vtime[tenant] = self._vtime.get(tenant, 0.0) + 1.0 / self._weight(tenant)
            n += 1
        return n

    def submit(self, req: Request) -> bool:
        """Offer + immediately drain the queues; returns False if shed.

        The one-call path used when arrivals are not rate-limited — with a
        single tenant this is exactly direct routing.
        """
        admitted = self.offer(req)
        self.dispatch()
        return admitted

    # ------------------------------------------------------------------
    # lockstep stepping (compatibility mode)

    def step(self) -> int:
        """One barrier step: every replica advances once, the fleet clock
        advances by the SLOWEST replica's cost — the straggler tax."""
        decoded = 0
        for r in self.replicas:
            decoded += r.step()
            self._note_finished(r)
        self.fleet_steps += 1
        self._now += max(r.step_cost for r in self.replicas)
        for r in self.replicas:
            r.clock = self._now
        for hook in self.on_step:
            hook(self._now)
        return decoded

    @property
    def free_slots(self) -> int:
        return sum(
            sum(1 for s in r.engine.slots if not s.active)
            for r in self.active_replicas
        )

    @property
    def drained(self) -> bool:
        """No queued work anywhere — valid under out-of-order completion:
        an in-flight event step holds engine state (busy slots or queue), so
        it keeps this False until its completion retires the work."""
        return self.queued() == 0 and all(r.idle and not r.busy for r in self.replicas)

    def run(
        self,
        gen,
        n_requests: int,
        max_steps: int = 10_000,
        submit_per_step: Optional[int] = None,
        lockstep: Optional[bool] = None,
    ) -> dict:
        """Serve ``n_requests``: all up-front, or ``submit_per_step`` per
        unit of virtual time (open-loop arrivals, what admission acts on).

        ``gen`` is a RequestGenerator or any iterator of Requests (e.g. a
        multi-tenant ``data.requests.interleave`` merge). Offered requests
        wait in per-tenant queues; dispatch into free decode slots happens
        in weighted-fair tenant order at every completion batch (event
        mode) or once per barrier step (``lockstep=True``). ``max_steps``
        bounds virtual time (event) / fleet iterations (lockstep) — the
        same number when speeds are homogeneous.
        """
        if lockstep is None:
            lockstep = env_flag(_LOCKSTEP_ENV, default=False)
        it = iter(gen)
        pending = deque(next(it) for _ in range(n_requests))
        if lockstep:
            self._run_lockstep(pending, max_steps, submit_per_step)
        else:
            self._run_events(pending, max_steps, submit_per_step)
        return self.fleet_stats()

    def _run_lockstep(self, pending, max_steps, submit_per_step):
        if self.chaos is not None and getattr(self.chaos, "events", ()):
            raise ValueError(
                "fault injection requires the event-driven mode: faults are "
                "scheduler events, and lockstep has no scheduler"
            )
        self.mode = "lockstep"
        if submit_per_step is None:
            for req in pending:
                self.submit(req)
            pending = []
        steps = 0
        while (pending or not self.drained) and steps < max_steps:
            for _ in range(min(submit_per_step or 0, len(pending))):
                self.offer(pending.popleft())
            self.dispatch(max(self.free_slots, 0))
            self.step()
            steps += 1

    def _run_events(self, pending, max_steps, submit_per_step):
        """Event-driven serve: completions free capacity, capacity pulls
        from the tenant queues, idle hosts consume no virtual time."""
        self.mode = "event"
        sched = VirtualScheduler()
        sched.now = self._now
        self.scheduler = sched
        horizon = self._now + float(max_steps)
        # chaos engines (and any other fault source) post their events into
        # the fresh scheduler here, before anything executes
        for hook in list(self.on_run_start):
            hook(sched)

        def quiescent(now: float):
            self._now = now
            for hook in list(self.on_step):
                hook(now)
            self.dispatch(max(self.free_slots, 0))
            self._start_steps(sched)

        if submit_per_step is None:
            for req in pending:
                self.submit(req)
            pending.clear()
            quiescent(sched.now)  # start the first steps (no events yet)
        else:

            def arrive():
                self._now = sched.now  # offers stamp enqueue at batch time
                for _ in range(min(submit_per_step, len(pending))):
                    self.offer(pending.popleft())
                # lockstep offers at iteration starts 0..max_steps-1, so
                # arrivals stop strictly before the horizon — an extra
                # batch at t == horizon would break truncated-run equality
                if pending and sched.now + 1.0 < horizon:
                    sched.post(sched.now + 1.0, arrive, prio=ARRIVAL)

            sched.post(sched.now, arrive, prio=ARRIVAL)

        sched.run(until=horizon, quiescent=quiescent)
        # scheduler activity enters the registry once per run (pure sums,
        # so cadence-independent like every other mirrored series)
        self.metrics.counter("sched_events").inc(sched.events_run)
        self.metrics.counter("sched_batches").inc(sched.batches)
        # a horizon-truncated run leaves completion events unexecuted in
        # the discarded scheduler; those steps never happened (no engine
        # mutation), so clear the in-flight markers or the replicas would
        # be stuck busy forever and a follow-up run() could never step them
        for r in self.replicas:
            r.busy = False
        self._pending.clear()  # in-flight dedup entries die with the heap
        self._now = sched.now
        # event mode has no barrier iterations; report virtual-time ticks
        # elapsed — the lockstep-equivalent step count at nominal speeds
        # (per-replica true step counts are in per_replica["steps_done"])
        self.fleet_steps = int(round(self._now))

    def _start_steps(self, sched: VirtualScheduler):
        """Begin a step on every replica that has work and no step in
        flight (draining hosts keep stepping to empty their backlog; dead
        and hung hosts never restart one).

        Each started step registers a dedup entry (rid -> (seq, timeout
        event)). The completion consumes the entry and cancels its timeout
        — a cancelled timeout is swept without advancing the clock or
        forming a batch, so with no faults the event books are bit-exact
        with the watchdog-free path. A completion that finds its entry
        gone (or superseded) is stale: the step was failed over, and
        running it would double-count tokens the retry re-decoded — it
        no-ops instead."""
        for r in list(self.replicas):
            if r.busy or r.load <= 0 or not r.alive or r.hung:
                continue
            r.busy = True
            t_begin = sched.now
            self._step_seq += 1
            seq = self._step_seq

            def complete(r=r, t_begin=t_begin, seq=seq):
                ent = self._pending.get(r.rid)
                if ent is None or ent[0] != seq or not r.alive or r.hung:
                    return  # stale: this step was failed over (dedup guard)
                self._pending.pop(r.rid)
                sched.cancel(ent[1])
                self._now = sched.now
                r.busy = False
                r.clock = sched.now
                decoded = r.step()
                self._note_finished(r)
                rec = self.recorder
                if rec is not None and rec.step_spans:
                    rec.span(
                        "step", -1, t_begin, sched.now, replica=r.rid, decoded=decoded
                    )

            sched.post(sched.now + r.step_cost, complete)
            timeout_ev = None
            if self.dispatch_timeout is not None:

                def expire(r=r, seq=seq):
                    self._on_step_timeout(r, seq)

                timeout_ev = sched.post(
                    t_begin + self.dispatch_timeout, expire, prio=TIMEOUT
                )
            self._pending[r.rid] = (seq, timeout_ev)

    # ------------------------------------------------------------------
    # failure machinery: watchdog, failover, crash retirement, retry

    def _note_finished(self, r: Replica):
        """Fold a replica's newly finished seq ids (engine seq id == request
        rid) into the terminal-outcome ledger. Runs after every engine step
        in both stepping modes, so completions are recorded at the batch
        they happen — a later failover of the same host cannot retro-lose
        them."""
        fin = r.engine.finished
        seen = self._fin_seen.get(r.rid, 0)
        if len(fin) > seen:
            for rid in fin[seen:]:
                self.outcomes[rid] = "completed"
                self.owner.pop(rid, None)
            self._fin_seen[r.rid] = len(fin)

    def _on_step_timeout(self, r: Replica, seq: int):
        """Watchdog expiry for one dispatched step. A consumed or
        superseded dedup entry means the step completed (its completion
        cancelled this event — we only get here through a race the
        scheduler's ordering actually forbids) or was already failed over;
        a live entry past the deadline is a hung host."""
        ent = self._pending.get(r.rid)
        if ent is None or ent[0] != seq or not r.alive:
            return
        self._fail_replica(r, self.scheduler.now, reason="timeout", crash=False)

    def _fail_replica(self, r: Replica, now: float, reason: str, crash: bool):
        """Fail one host over: quarantine (hang) or retire (crash) it,
        abort its engine, and re-dispatch every stranded request.

        The dedup entry is removed FIRST, so a slow-but-alive host's late
        completion event finds nothing to match and no-ops — the retry's
        re-decoded tokens are the only ones that count. Aborted requests'
        discarded decode progress is charged to per-tenant ``lost_tokens``
        (the work the retry redoes); a crash additionally quarantines the
        host's undrained device counter plane as a ``lost_window`` (see
        Replica.crash_salvage)."""
        ent = self._pending.pop(r.rid, None)
        if ent is not None and self.scheduler is not None:
            self.scheduler.cancel(ent[1])
        self._now = now
        # completions already in the engine's books stay counted
        self._note_finished(r)
        if crash:
            r.alive = False
            r.busy = False
            stranded = self._retire_crashed(r, now, reason)
        else:
            r.hung = True  # quarantined until a recovery event clears it
            stranded = r.engine.abort_all()
        self.metrics.counter("replica_failures", reason=reason).inc()
        if self.recorder is not None:
            self.recorder.instant(
                "failover",
                -1,
                now,
                replica=r.rid,
                reason=reason,
                crash=crash,
                inflight=len(stranded),
            )
        for req, discarded in stranded:
            if discarded:
                self.metrics.counter("lost_tokens", tenant=req.tenant).inc(discarded)
            self._retry(req, now, reason)

    def _retire_crashed(self, r: Replica, now: float, reason: str) -> list:
        """Crash-path retirement: salvage the dead host's last-drain books,
        quantify what the crash destroyed, remove it from the fleet.

        Ordering matters: the salvage (read-only inventory + discard drain)
        runs before the profile export, so the export's own drain sees a
        clean plane and charges nothing — the host-visible history that
        survives is exactly what the last real drain boundary folded in.
        Returns the aborted (request, discarded_tokens) pairs for retry."""
        lost = r.crash_salvage(now)
        lost["reason"] = reason
        self.lost_windows.append(lost)
        prof = r.export_profile()
        self.crashed_profiles.append(prof)
        if self.autotierer is not None:
            # a dead host's traffic still shaped the service's histogram
            self.autotierer.extra_profiles.append(prof)
        st = r.stats()
        st["placement_near_hits"] = r.engine.placement.stats.near_hits
        st["placement_far_hits"] = r.engine.placement.stats.far_hits
        st["crashed"] = True
        st["crash_reason"] = reason
        self.crashed_stats.append(st)
        stranded = r.engine.abort_all()
        if r in self.replicas:
            self.replicas.remove(r)
        if self.elastic is not None:
            self.elastic.retire_crashed(r, now, reason)
        return stranded

    def _retry(self, req: Request, now: float, reason: str):
        """Re-dispatch one stranded request: re-queue (re-prefill from the
        retained prompt — its KV pages died with the slot) after a linear
        backoff, or declare it failed once retries are exhausted."""
        tenant = req.tenant
        self.metrics.counter("failovers", tenant=tenant).inc()
        n = self.attempts.get(req.rid, 0) + 1
        self.attempts[req.rid] = n
        self.owner.pop(req.rid, None)
        if n > self.max_retries:
            self.outcomes[req.rid] = f"failed:{reason}"
            self.metrics.counter("failed", tenant=tenant).inc()
            if self.recorder is not None:
                self.recorder.instant(
                    "failed", req.rid, now, tenant=tenant, reason=reason, attempts=n - 1
                )
            return
        self.metrics.counter("retries", tenant=tenant).inc()
        if self.recorder is not None:
            self.recorder.instant(
                "retry", req.rid, now, tenant=tenant, reason=reason, attempt=n
            )
        delay = self.retry_backoff * n
        sched = self.scheduler
        if sched is not None and delay > 0:
            sched.post(now + delay, lambda req=req: self._requeue(req), prio=ARRIVAL)
        else:
            self._requeue(req)

    def _requeue(self, req: Request):
        """Put a failed-over request back at the tail of its tenant queue
        (dispatch pulls it at the next completion batch)."""
        if self.scheduler is not None:
            self._now = self.scheduler.now
        self.tenant_queues.setdefault(req.tenant, deque()).append(req)
        self._enqueue_time[id(req)] = self._now
        if self.recorder is not None:
            self.recorder.begin("queue", req.rid, self._now, tenant=req.tenant, retry=True)

    def outcome_report(self) -> dict:
        """Terminal-outcome ledger: every request that entered the fleet
        must end ``completed``, ``shed``, or ``failed:<reason>``. Anything
        admitted but unresolved is listed in ``pending`` — the no-silent-
        drops invariant chaos tests assert empty (a truncated horizon or an
        unrecovered last host legitimately leaves work pending; a completed
        run must not)."""
        counts: Dict[str, int] = {}
        for o in self.outcomes.values():
            key = "failed" if o.startswith("failed") else o
            counts[key] = counts.get(key, 0) + 1
        pending = sorted(r for r in self.admitted_rids if r not in self.outcomes)
        return {
            "offered": len(self.outcomes) + len(pending),
            "admitted": len(self.admitted_rids),
            "outcomes": counts,
            "pending": pending,
            "failed": {
                r: o for r, o in sorted(self.outcomes.items()) if o.startswith("failed")
            },
            "complete": not pending,
        }

    def _tenant_count(self, name: str, tenant: str) -> int:
        """Non-creating per-tenant counter read (no empty series growth)."""
        c = self.metrics._counters.get((name, (("tenant", tenant),)))
        return 0 if c is None else c.value

    # ------------------------------------------------------------------
    def export_profiles(self) -> List[ReplicaProfile]:
        """Live replicas' profiles + retired hosts folded in by the
        elastic layer — the full fleet history the aggregator stitches."""
        profs = [r.export_profile() for r in self.replicas]
        if self.elastic is not None:
            profs += list(self.elastic.retired_profiles)
        profs += list(self.crashed_profiles)
        return profs

    def fleet_stats(self) -> dict:
        per = [r.stats() for r in self.replicas]
        retired = list(self.elastic.retired_stats) if self.elastic is not None else []
        # retired AND crashed hosts' service history stays in the fleet
        # totals — neither a scale-down nor a failure makes served traffic
        # disappear from the books (what a crash destroys is quantified
        # separately in lost_windows, never silently)
        gone = retired + list(self.crashed_stats)
        both = per + gone
        agg = {
            k: sum(s[k] for s in both)
            for k in (
                "tokens_decoded",
                "requests_finished",
                "prefill_tokens",
                "prefill_tokens_saved",
            )
        }
        hits = sum(r.engine.placement.stats.near_hits for r in self.replicas)
        hits += sum(s["placement_near_hits"] for s in gone)
        tot = hits + sum(r.engine.placement.stats.far_hits for r in self.replicas)
        tot += sum(s["placement_far_hits"] for s in gone)
        agg["near_hit_rate"] = hits / max(tot, 1)
        agg["shared_mappings"] = sum(s["pagetable"]["shared_mappings"] for s in both)
        agg["fleet_steps"] = self.fleet_steps
        agg["virtual_time"] = self._now
        agg["mode"] = self.mode
        agg["n_replicas"] = len(self.replicas)
        agg["routed"] = self.routed
        agg["shed"] = self.shed
        agg["policy"] = getattr(self.policy, "name", type(self.policy).__name__)
        # fault/failover books (all zero/empty on a fault-free run, and
        # present in BOTH stepping modes so chaos reports diff cleanly)
        agg["requests_failed"] = sum(
            1 for o in self.outcomes.values() if o.startswith("failed")
        )
        agg["requests_retried"] = int(self.metrics.total("retries"))
        agg["failovers"] = int(self.metrics.total("replica_failures"))
        agg["lost_tokens"] = int(self.metrics.total("lost_tokens"))
        agg["crashed_replicas"] = [s["rid"] for s in self.crashed_stats]
        agg["lost_windows"] = [dict(w) for w in self.lost_windows]
        agg["fault_events"] = list(self.chaos.log) if self.chaos is not None else []
        agg["simulated_throughput"] = simulated_throughput(agg)
        agg["tenants"] = self.tenant_report(both)
        agg["per_replica"] = per
        if self.elastic is not None:
            agg["retired_replicas"] = retired
            agg["scale_events"] = [
                (e.vtime, e.action, e.rid) for e in self.elastic.events
            ]
        return agg

    def tenant_report(self, per_replica_stats: Optional[List[dict]] = None) -> dict:
        """Fleet-wide per-tenant view: service counts, tier hits, routing,
        and queue-wait latency percentiles in virtual time (p50/p99 of the
        offer->dispatch wait — the fairness surface a burst tenant stresses)."""
        per = per_replica_stats or [r.stats() for r in self.replicas]
        out: Dict[str, dict] = {}
        for s in per:
            for t, ts in s.get("tenants", {}).items():
                o = out.setdefault(
                    t,
                    {"tokens_decoded": 0, "requests_finished": 0, "near_hits": 0, "far_hits": 0},
                )
                for k in ("tokens_decoded", "requests_finished", "near_hits", "far_hits"):
                    o[k] += ts[k]
        for t in set(out) | set(self.routed_by) | set(self.shed_by):
            o = out.setdefault(
                t,
                {"tokens_decoded": 0, "requests_finished": 0, "near_hits": 0, "far_hits": 0},
            )
            o["near_hit_rate"] = o["near_hits"] / max(o["near_hits"] + o["far_hits"], 1)
            o["routed"] = self.routed_by.get(t, 0)
            o["shed"] = self.shed_by.get(t, 0)
            o["shed_rate"] = o["shed"] / max(o["routed"] + o["shed"], 1)
            o["queued"] = self.queued(t)
            # fault columns only appear once a tenant was actually touched
            # by a failure — a fault-free run's report is byte-identical to
            # the pre-chaos one (the lockstep/event equivalence surface)
            for k in ("retries", "failovers", "failed", "lost_tokens"):
                v = self._tenant_count(k, t)
                if v:
                    o[k] = v
            # queue-wait percentiles come from the mergeable exponential
            # histogram (deterministic bucket upper bounds, ~9% relative
            # error at the default growth) — NOT np.percentile over the raw
            # sample list, which cannot merge across routers/windows.
            # wait_samples keeps the raw list for exact-replay comparisons.
            # A tenant with NO samples gets no percentile keys at all:
            # Histogram.quantile returns None on an empty series, and
            # zero-filling here used to make "never waited" and "no data"
            # indistinguishable in the report.
            h = self.metrics.histogram("queue_wait", tenant=t)
            if h.count:
                o["wait_p50"] = h.quantile(0.50)
                o["wait_p99"] = h.quantile(0.99)
            # time-to-first-token (submit -> first generated token, virtual
            # time): recorded by each ENGINE — at admit under whole-slot
            # prefill, at the prompt-completing chunk step under chunked
            # prefill — into its registry's per-tenant "ttft" histogram;
            # merged bucket-wise across replicas, same grid as queue_wait.
            # Read without the creating .histogram() accessor so replicas
            # that never served this tenant don't grow empty series.
            th = Histogram()
            for r in self.replicas:
                eh = r.engine.metrics._histograms.get(
                    ("ttft", (("tenant", t),))
                )
                if eh is not None:
                    th.merge(eh)
            if th.count:
                o["ttft_p50"] = th.quantile(0.50)
                o["ttft_p99"] = th.quantile(0.99)
        return out

    # ------------------------------------------------------------------
    # unified metrics plane (fleet view)

    def metric_snapshots(self) -> List[MetricSnapshot]:
        """Every registry's frozen state: router + live replicas + retired
        hosts (whose snapshots ride in their exported profiles)."""
        for r in self.replicas:
            r.engine.drain_tier_counters()  # snapshot at a drain boundary
        snaps = [self.metrics.snapshot()]
        if self.admission is not None:
            snaps.append(self.admission.metrics.snapshot())
        snaps += [r.engine.metrics.snapshot() for r in self.replicas]
        if self.elastic is not None:
            snaps += [
                p.metrics for p in self.elastic.retired_profiles if p.metrics is not None
            ]
        snaps += [p.metrics for p in self.crashed_profiles if p.metrics is not None]
        return snaps

    def fleet_metrics(self) -> MetricSnapshot:
        """Exact fleet merge of every per-host registry — same totals as
        ``fleet_stats`` bit-for-bit (counters are plain int sums), plus the
        label dimensions and histograms the legacy dicts never had."""
        return merge_snapshots(self.metric_snapshots())


def simulated_throughput(stats: dict) -> float:
    """Useful tokens per modeled unit cost (higher is better).

    cost = unshared prefill work + decode work weighted by the average
    KV-read latency its near/far split implies. Prefix sharing removes
    prefill cost; good placement removes the far-latency multiplier.
    """
    useful = stats["prefill_tokens"] + stats["tokens_decoded"]
    near = stats["near_hit_rate"]
    avg_latency = near + (1.0 - near) * FAR_LATENCY_REL
    cost = (
        stats["prefill_tokens"]
        - stats["prefill_tokens_saved"]
        + stats["tokens_decoded"] * avg_latency
    )
    return useful / max(cost, 1e-9)
