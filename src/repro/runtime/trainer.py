"""Distributed trainer: checkpoint/restart, straggler detection, metrics.

Fault tolerance model (designed for 1000+ nodes, exercised in tests on 1):
  * atomic async checkpoints every ``ckpt_every`` steps (CheckpointManager);
  * crash at any point -> restart resumes from the last complete checkpoint
    with a bitwise-identical trajectory (data cursor is part of the state);
  * ``SimulatedFailure`` hook injects crashes in tests;
  * straggler detection: per-step wall times -> EWMA z-score; flagged steps
    are logged (at fleet scale the controller would re-shard around the slow
    host — here surfaced via metrics, consumed by runtime/elastic.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.models.api import ModelAPI, make_train_step
from repro.optim import AdamWConfig, adamw_init


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    straggler_z: float = 3.0
    straggler_min_steps: int = 8


class StragglerMonitor:
    """EWMA + z-score step-time anomaly detector (per host stream)."""

    def __init__(self, alpha: float = 0.1, z: float = 3.0, min_steps: int = 8):
        self.alpha = alpha
        self.z = z
        self.min_steps = min_steps
        self.mean = 0.0
        self.var = 0.0
        self.n = 0
        self.flagged: list[tuple[int, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        self.n += 1
        if self.n == 1:
            self.mean = dt
            return False
        is_straggler = False
        std = max(self.var, 1e-12) ** 0.5
        if self.n > self.min_steps and dt > self.mean + self.z * std and dt > 1.5 * self.mean:
            is_straggler = True
            self.flagged.append((step, dt))
        # update EWMA only with non-outlier samples so one hiccup doesn't
        # poison the baseline
        if not is_straggler:
            d = dt - self.mean
            self.mean += self.alpha * d
            self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        return is_straggler


class Trainer:
    def __init__(
        self,
        api: ModelAPI,
        opt_cfg: AdamWConfig,
        tcfg: TrainerConfig,
        *,
        compute_specs: Optional[dict] = None,
        donate: bool = True,
    ):
        self.api = api
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep)
        step_fn = make_train_step(api, opt_cfg, compute_specs=compute_specs)
        self.train_step = jax.jit(step_fn, donate_argnums=(0, 1) if donate else ())
        self.monitor = StragglerMonitor(z=tcfg.straggler_z, min_steps=tcfg.straggler_min_steps)
        self.metrics_log: list[dict] = []
        self.step = 0
        self.params = None
        self.opt_state = None

    # ------------------------------------------------------------------
    def init_state(self, seed: int = 0):
        self.params = self.api.init(jax.random.PRNGKey(seed))
        self.opt_state = adamw_init(self.params)
        self.step = 0

    def try_restore(self) -> bool:
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        if self.params is None:
            self.init_state()
        (self.params, self.opt_state), extras = self.ckpt.restore(
            (self.params, self.opt_state)
        )
        self.step = int(extras["step"])
        return True

    def save(self, sync: bool = False):
        extras = {"step": self.step}
        if sync:
            self.ckpt.save(self.step, (self.params, self.opt_state), extras)
        else:
            self.ckpt.save_async(self.step, (self.params, self.opt_state), extras)

    # ------------------------------------------------------------------
    def run(
        self,
        batches: Iterator,
        n_steps: int,
        *,
        fail_at: Optional[int] = None,
        on_step: Optional[Callable[[int, dict], None]] = None,
    ) -> list[dict]:
        """Train for n_steps from the iterator of (step, host_batch) pairs.

        ``fail_at``: raise SimulatedFailure after completing that step count
        (tests crash-recovery). Returns the metrics log.
        """
        assert self.params is not None, "call init_state() or try_restore() first"
        done = 0
        for data_step, batch in batches:
            if done >= n_steps:
                break
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self.train_step(
                self.params, self.opt_state, batch
            )
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            self.step += 1
            done += 1
            straggler = self.monitor.observe(self.step, dt)
            metrics.update(step=self.step, dt=dt, straggler=straggler)
            self.metrics_log.append(metrics)
            if on_step:
                on_step(self.step, metrics)
            if self.step % self.tcfg.ckpt_every == 0:
                self.save()
            if fail_at is not None and done >= fail_at:
                raise SimulatedFailure(f"injected failure after step {self.step}")
        self.ckpt.wait()
        return self.metrics_log
