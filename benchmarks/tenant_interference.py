"""Cross-tenant interference study: the paper's co-location experiment in
miniature (arXiv 2303.08396 §2/§5; cloud-tenant interference per 1611.10316).

Two contrasting services share one fleet:

* **web**   — Web1-like: high shared-template rate, longer prompts, steady
  arrivals. Its near-tier value comes from prefix sharing + template-hot
  KV pages.
* **cache** — Cache1-like: Zipf point lookups, tiny prompts, bursty
  arrivals (4x the web arrival rate). Its hot set is narrow and deep.

Each tenant is first served SOLO (whole fleet, whole near tier to itself),
then CO-LOCATED through the same-sized fleet with per-tenant SLOs and
weighted-fair dispatch. Reported per tenant:

* hot-fraction — share of its traffic its top-10% pages carry (per-tenant
  fleet histogram, aggregator.aggregate_tenant_counts);
* shed rate — per-tenant admission sheds (one tenant's burst must land in
  its own shed rate, not its neighbor's);
* near-hit solo vs co-located — the degradation is the interference: the
  shared near tier is planned from the COMBINED histogram, so each
  tenant's realized near-hit drops when the other's hot pages crowd it.

Deterministic under a fixed seed; tests/test_tenancy.py pins that.

PYTHONPATH=src python -m benchmarks.run tenant_interference
"""
import dataclasses

import numpy as np

from repro.configs.workloads import get_profile
from repro.data.requests import RequestGenerator, interleave
from repro.fleet import (
    AdmissionController,
    SLOModel,
    aggregate_counts,
    aggregate_tenant_counts,
    build_fleet,
    export_all,
    fleet_report,
    fleet_vocab,
)

from _common import fmt_table

N_REPLICAS = 2
# a deliberately tight near tier: each replica's live KV footprint
# (max_batch x ~3 pages/seq) exceeds its near capacity, so tenants actually
# contend for it (no contention, no study)
N_PAGES = 64
NEAR_FRAC = 0.125
MAX_BATCH = 6

# tenant -> (profile overrides, arrival rate, SLO, fair-share weight)
TENANTS = {
    "web": dict(
        base="Web1",
        overrides=dict(prompt_mean=24, decode_mean=16, prefix_share=0.9, n_prefixes=3),
        rate=8.0,
        slo=SLOModel(max_delay_steps=96.0),
        weight=1.0,
    ),
    "cache": dict(
        base="Cache1",
        overrides=dict(prompt_mean=8, decode_mean=6, prefix_share=0.0, n_prefixes=4),
        rate=32.0,
        slo=SLOModel(max_delay_steps=12.0),
        weight=1.0,
    ),
}


def _generator(tenant: str, seed: int) -> RequestGenerator:
    spec = TENANTS[tenant]
    prof = dataclasses.replace(get_profile(spec["base"]), **spec["overrides"])
    return RequestGenerator(
        prof, vocab_size=fleet_vocab(), seed=seed, rate=spec["rate"], tenant=tenant
    )


def _build(tenants) -> "FleetRouter":
    """Fleet for the given tenant subset — a solo run must carry ONLY its
    own tenant's weight, or its admission fair-share is not actually 1.0."""
    return build_fleet(
        N_REPLICAS,
        policy="prefix-affinity",
        n_pages=N_PAGES,
        near_frac=NEAR_FRAC,
        max_batch=MAX_BATCH,
        trace_window=16,
        trace_period=32,
        admission=AdmissionController(
            SLOModel(max_delay_steps=64.0),
            tenant_slos={t: TENANTS[t]["slo"] for t in tenants},
        ),
        autotier=dict(near_frac=NEAR_FRAC, epoch_steps=8),
        tenant_weights={t: TENANTS[t]["weight"] for t in tenants},
    )


def _tenant_metrics(fleet, stats) -> dict:
    rep = fleet_report(export_all(fleet.replicas))
    out = {}
    for t, ts in stats["tenants"].items():
        out[t] = {
            "near_hit_rate": ts["near_hit_rate"],
            "shed_rate": ts["shed_rate"],
            "requests_finished": ts["requests_finished"],
            "hot_frac_10pct": rep["tenants"].get(t, {}).get("hot", {}).get(0.1, 0.0),
        }
    return out


def run_solo(tenant: str, seed: int = 0, n_requests: int = 16) -> dict:
    fleet = _build([tenant])
    gen = _generator(tenant, seed)
    stats = fleet.run(gen, n_requests=n_requests, max_steps=600, submit_per_step=2)
    return _tenant_metrics(fleet, stats)[tenant]


def run_colocated(seed: int = 0, n_requests: int = 32) -> dict:
    fleet = _build(sorted(TENANTS))
    gens = [_generator(t, seed + i) for i, t in enumerate(sorted(TENANTS))]
    reqs = interleave(gens, n_requests)
    stats = fleet.run(iter(reqs), n_requests=n_requests, max_steps=600, submit_per_step=2)
    metrics = _tenant_metrics(fleet, stats)
    # sanity: per-tenant fleet histograms must partition the combined one
    profiles = export_all(fleet.replicas)
    combined = aggregate_counts(profiles)
    by_tenant = aggregate_tenant_counts(profiles)
    if by_tenant:
        summed = np.sum([c for c in by_tenant.values()], axis=0)
        if not np.array_equal(summed, combined):
            raise AssertionError("tenant histograms do not sum to combined histogram")
    return metrics


def run_study(seed: int = 0, n_requests_solo: int = 16, n_requests_colo: int = 32) -> dict:
    solo = {t: run_solo(t, seed=seed, n_requests=n_requests_solo) for t in sorted(TENANTS)}
    colo = run_colocated(seed=seed, n_requests=n_requests_colo)
    degradation = {
        t: solo[t]["near_hit_rate"] - colo.get(t, {}).get("near_hit_rate", 0.0)
        for t in sorted(TENANTS)
    }
    return {"solo": solo, "colocated": colo, "near_hit_degradation": degradation}


def main():
    res = run_study()
    rows = []
    for t in sorted(TENANTS):
        s, c = res["solo"][t], res["colocated"].get(t, {})
        rows.append(
            (
                t,
                f"{s['hot_frac_10pct']:.3f}",
                f"{s['near_hit_rate']:.3f}",
                f"{c.get('near_hit_rate', float('nan')):.3f}",
                f"{res['near_hit_degradation'][t]:+.3f}",
                f"{s['shed_rate']:.3f}",
                f"{c.get('shed_rate', float('nan')):.3f}",
            )
        )
    print("tenant interference: solo vs co-located on one fleet "
          f"({N_REPLICAS} replicas, shared near tier)")
    print(
        fmt_table(
            rows,
            ("tenant", "hot-10%", "near-hit-solo", "near-hit-colo",
             "degradation", "shed-solo", "shed-colo"),
        )
    )
    if any(not np.isfinite(v) for v in res["near_hit_degradation"].values()):
        print("tenant_interference: FAIL (non-finite degradation)")
        return 1
    if set(res["colocated"]) != set(TENANTS):
        print("tenant_interference: FAIL (a tenant was starved out of the co-located run)")
        return 1
    print("tenant_interference ok")
    return res


if __name__ == "__main__":
    rc = main()
    raise SystemExit(rc if isinstance(rc, int) else 0)
