"""Tiered row-gather Pallas TPU kernel.

Row ids are SCALAR-PREFETCHED; the source BlockSpec's index map is
data-dependent (block i = row ids[i]), so each grid step DMAs exactly one
(1, D) row HBM->VMEM — a pure-bandwidth op placed exactly where the paper
puts its hot pages: the gather stream for embedding rows / expert blocks is
the measured "few hot pages" stream, and this kernel is the near-tier fast
path. The int8 variant fuses the far-tier dequant (per-row scale) into the
same pass so promoted-but-compressed rows cost no extra memory round-trip.

D is padded to 128 lanes by ops.py; rows are independent so the grid is
embarrassingly parallel (no scratch carry).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(ids_ref, src_ref, out_ref):
    out_ref[...] = src_ref[...].astype(out_ref.dtype)


def _gather_dequant_kernel(ids_ref, src_ref, scale_ref, out_ref):
    out_ref[...] = src_ref[...].astype(jnp.float32) * scale_ref[0, 0]


def gather_rows_kernel(src, ids, scales=None, *, interpret: bool = False):
    """src: (M, D) — D a lane multiple; ids: (N,) int32; scales: (M, 1) or None.

    Returns (N, D) f32.
    """
    m, d = src.shape
    n = ids.shape[0]

    def src_map(i, ids_ref):
        return (ids_ref[i], 0)

    def out_map(i, ids_ref):
        return (i, 0)

    if scales is None:
        return pl.pallas_call(
            _gather_kernel,
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=1,
                grid=(n,),
                in_specs=[pl.BlockSpec((1, d), src_map)],
                out_specs=pl.BlockSpec((1, d), out_map),
            ),
            out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
            interpret=interpret,
        )(ids, src)
    return pl.pallas_call(
        _gather_dequant_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n,),
            in_specs=[
                pl.BlockSpec((1, d), src_map),
                pl.BlockSpec((1, 1), src_map, memory_space=pltpu.SMEM),
            ],
            out_specs=pl.BlockSpec((1, d), out_map),
        ),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=interpret,
    )(ids, src, scales)
