"""Oracle: sequential SSD recurrence (same math as models/mamba2.ssd_scan)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, B, C, D, state=None):
    """x: (b,T,H,P); dt: (b,T,H); A,D: (H,); B,C: (b,T,N).

    Returns (y (b,T,H,P), final_state (b,H,P,N)). All f32.
    S_t = exp(dt_t A) S_{t-1} + dt_t x_t B_t^T ;  y_t = S_t C_t + D x_t
    """
    b, t, h, p = x.shape
    n = B.shape[-1]
    if state is None:
        state = jnp.zeros((b, h, p, n), jnp.float32)

    def step(s, inp):
        x_t, dt_t, b_t, c_t = inp
        da = jnp.exp(dt_t * A)
        s = s * da[..., None, None] + (dt_t[..., None] * x_t)[..., None] * b_t[:, None, None, :]
        y = jnp.einsum("bhpn,bn->bhp", s, c_t)
        return s, y

    xs = (
        x.transpose(1, 0, 2, 3).astype(jnp.float32),
        dt.transpose(1, 0, 2).astype(jnp.float32),
        B.transpose(1, 0, 2).astype(jnp.float32),
        C.transpose(1, 0, 2).astype(jnp.float32),
    )
    state, ys = jax.lax.scan(step, state, xs)
    y = ys.transpose(1, 0, 2, 3) + x.astype(jnp.float32) * D[None, None, :, None]
    return y, state
