"""Paper Fig. 17: I-TLB MPKI with 1x vs 2x entries -> far-tier faults per 1k
decoded tokens with 1x vs 2x near-tier page capacity, +/- prefix sharing
(the multi-ASID shared-entry analogue)."""
from _common import fmt_table, run_workload


def _faults_per_kilo(eng, stats):
    far = eng.placement.stats.far_hits
    toks = max(stats["tokens_decoded"], 1)
    return 1000.0 * far / toks


def main():
    rows = []
    out = {}
    for wl in ("Web1", "Web2", "Feed", "Reader"):
        vals = []
        for near in (0.15, 0.30):
            eng, stats = run_workload(wl, n_requests=10, near_frac=near, seed=3)
            vals.append(_faults_per_kilo(eng, stats))
        rows.append((wl, f"{vals[0]:8.1f}", f"{vals[1]:8.1f}", f"{vals[0]/max(vals[1],1e-9):5.2f}x"))
        out[wl] = vals
    print("[fig17] far-tier faults per 1k decoded tokens (1x vs 2x near capacity)")
    print(fmt_table(rows, ["workload", "1x near", "2x near", "improvement"]))
    print("paper: L1 I-TLB MPKI drops materially with 2x entries -> larger shared L2 I-TLB pays")
    return out


if __name__ == "__main__":
    main()
