"""Unified model API: one object per architecture family exposing

  init / param_specs / loss / prefill / decode / init_cache / cache_specs /
  input_specs (ShapeDtypeStruct stand-ins per assigned shape) / batch_specs

plus step builders (train / prefill / serve) shared by the trainer, the
serving engine, and launch/dryrun.py.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig, ShapeSpec
from repro.core import pooling
from repro.launch.mesh import BATCH, MODEL
from repro.models import common, moe, rwkv6, transformer, vlm, whisper, zamba2
from repro.optim import AdamWConfig, adamw_update

Array = jax.Array
_I32 = jnp.int32
_BF16 = jnp.bfloat16


@dataclasses.dataclass
class ModelAPI:
    cfg: ModelConfig

    # ------------------------------------------------------------------
    @property
    def family(self) -> str:
        return self.cfg.family

    def init(self, key) -> dict:
        return _MODULES[self.family].init(key, self.cfg)

    def abstract_params(self) -> dict:
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    def param_specs(self) -> dict:
        mod = _MODULES[self.family]
        return mod.param_specs(self.cfg)

    def cache_specs(self) -> dict:
        return _MODULES[self.family].cache_specs(self.cfg)

    def init_cache(self, batch: int, max_len: int) -> dict:
        return _MODULES[self.family].init_cache(self.cfg, batch, max_len)

    def abstract_cache(self, batch: int, max_len: int) -> dict:
        return jax.eval_shape(lambda: self.init_cache(batch, max_len))

    # ------------------------------------------------------------------
    def loss(self, params: dict, batch: dict, *, remat: Optional[bool] = None):
        """Trunk + fused seq-chunked lm_head/CE (+ MoE aux).

        Full (B, S, Vp) logits are never materialized — the head matmul and
        the CE run chunk-by-chunk (common.fused_ce_loss), which is what lets
        the 150k-vocab train cells fit per-chip HBM. Returns (loss, metrics).
        """
        cfg = self.cfg
        ce = functools.partial(common.fused_ce_loss, labels=batch["labels"], vocab_size=cfg.vocab_size)
        if self.family == "dense":
            h, w = transformer.features(params, cfg, batch["tokens"], remat=remat)
            return ce(h, w)
        if self.family == "moe":
            h, w, aux = moe.features(params, cfg, batch["tokens"], remat=remat)
            loss, metrics = ce(h, w)
            metrics["aux_loss"] = aux
            return loss + aux, metrics
        if self.family == "ssm":
            h, w = rwkv6.features(params, cfg, batch["tokens"], remat=remat)
            return ce(h, w)
        if self.family == "hybrid":
            h, w = zamba2.features(params, cfg, batch["tokens"], remat=remat)
            return ce(h, w)
        if self.family == "vlm":
            h, w = vlm.features(
                params, cfg, batch["embeds"], batch["mrope_positions"], remat=remat
            )
            return ce(h, w)
        if self.family == "audio":
            h, w = whisper.features(params, cfg, batch["tokens"], batch["frames"], remat=remat)
            return ce(h, w)
        raise ValueError(self.family)

    def prefill(self, params: dict, batch: dict, *, max_len: int):
        cfg = self.cfg
        if self.family == "dense":
            return transformer.prefill(params, cfg, batch["tokens"], max_len=max_len)
        if self.family == "moe":
            return moe.prefill(params, cfg, batch["tokens"], max_len=max_len)
        if self.family == "ssm":
            return rwkv6.prefill(params, cfg, batch["tokens"], max_len=max_len)
        if self.family == "hybrid":
            return zamba2.prefill(params, cfg, batch["tokens"], max_len=max_len)
        if self.family == "vlm":
            return vlm.prefill(
                params, cfg, batch["embeds"], batch["mrope_positions"], max_len=max_len
            )
        if self.family == "audio":
            return whisper.prefill(params, cfg, batch["tokens"], batch["frames"], max_len=max_len)
        raise ValueError(self.family)

    def decode(self, params: dict, cache: dict, tokens: Array):
        return _MODULES[self.family].decode_step(params, self.cfg, cache, tokens)

    # ------------------------------------------------------------------
    # assigned-shape input stand-ins (global shapes; no allocation)

    def input_specs(self, shape_name: str) -> dict:
        """ShapeDtypeStruct tree for the step function of this shape cell."""
        cfg, sh = self.cfg, SHAPES[shape_name]
        b, s = sh.global_batch, sh.seq_len
        tok = lambda shape: jax.ShapeDtypeStruct(shape, _I32)
        emb = lambda shape: jax.ShapeDtypeStruct(shape, _BF16)
        if sh.kind in ("train", "prefill"):
            if self.family == "vlm":
                batch = {"embeds": emb((b, s, cfg.d_model)), "mrope_positions": tok((3, b, s))}
            elif self.family == "audio":
                batch = {"tokens": tok((b, s)), "frames": emb((b, cfg.n_audio_frames, cfg.d_model))}
            else:
                batch = {"tokens": tok((b, s))}
            if sh.kind == "train":
                batch["labels"] = tok((b, s))
            return batch
        # decode: one new token against a cache filled to s
        return {"tokens": tok((b, 1)), "cache": self.abstract_cache(b, s)}

    def batch_specs(self, shape_name: str) -> dict:
        """PartitionSpec tuples matching input_specs(shape_name)."""
        sh = SHAPES[shape_name]
        specs: dict[str, Any] = {}
        if sh.kind in ("train", "prefill"):
            if self.family == "vlm":
                specs["embeds"] = (BATCH, None, None)
                specs["mrope_positions"] = (None, BATCH, None)
            elif self.family == "audio":
                specs["tokens"] = (BATCH, None)
                specs["frames"] = (BATCH, None, None)
            else:
                specs["tokens"] = (BATCH, None)
            if sh.kind == "train":
                specs["labels"] = (BATCH, None)
            return specs
        return {"tokens": (BATCH, None), "cache": self.cache_specs()}


def get_model(cfg: ModelConfig) -> ModelAPI:
    return ModelAPI(cfg)


_MODULES = {
    "dense": transformer,
    "moe": moe,
    "ssm": rwkv6,
    "hybrid": zamba2,
    "vlm": vlm,
    "audio": whisper,
}


# ---------------------------------------------------------------------------
# step builders


def make_train_step(
    api: ModelAPI,
    opt_cfg: AdamWConfig,
    *,
    compute_specs: Optional[dict] = None,
    donate: bool = True,
    grad_accum: Optional[int] = None,
    storage_specs: Optional[dict] = None,
):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``compute_specs``: when weight pooling is on, params arrive POOL-sharded;
    the step gathers them to the compute (TP) layout inside loss_fn — the
    backward transpose reduce-scatters grads back to the pooled layout.

    ``grad_accum`` (default cfg.grad_accum): microbatched gradient
    accumulation via lax.scan. Remat/activation stacks scale as 1/A while
    collectives and the optimizer run once per step — the standard lever
    that fits long-stack (many-layer x 4k-seq) train cells into per-chip
    HBM without resharding the model.

    ``storage_specs``: PartitionSpec tuples for the parameter tree. The
    grad-accumulation buffer is constrained to this layout — without it
    GSPMD materializes REPLICATED f32 accumulators (full per-layer weight
    stacks on every chip).
    """
    ga = grad_accum if grad_accum is not None else api.cfg.grad_accum

    def loss_fn(p, batch):
        if compute_specs is not None:
            p = pooling.gather(p, compute_specs)
        return api.loss(p, batch)

    def train_step(params, opt_state, batch):
        if ga > 1:
            from repro.launch import mesh as meshlib
            from repro.launch.mesh import BATCH

            def split(x):
                b = x.shape[0]
                assert b % ga == 0, (b, ga)
                x = x.reshape(ga, b // ga, *x.shape[1:])
                return meshlib.shard(x, None, BATCH)

            # vlm mrope positions carry batch on dim 1: split on the right axis
            def split_leaf(k, x):
                if k == "mrope_positions":
                    t, b = x.shape[0], x.shape[1]
                    x = x.reshape(t, ga, b // ga, *x.shape[2:]).transpose(1, 0, 2, *range(3, x.ndim + 1))
                    return meshlib.shard(x, None, None, BATCH)
                return split(x)

            micro_batches = {k: split_leaf(k, v) for k, v in batch.items()}
            if storage_specs is not None:
                gzero = jax.tree.map(
                    lambda p, s: meshlib.shard(jnp.zeros(p.shape, jnp.float32), *s),
                    params,
                    storage_specs,
                    is_leaf=lambda x: isinstance(x, jax.Array),
                )
            else:
                gzero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def micro(carry, mb):
                gsum, msum = carry
                (_, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                g = _constrain_grads(g, storage_specs)
                gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gsum, g)
                msum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), msum, metrics)
                return (gsum, msum), None

            m0 = jax.eval_shape(lambda: loss_fn(params, jax.tree.map(lambda x: x[0], micro_batches))[1])
            mzero = jax.tree.map(lambda s: jnp.zeros((), jnp.float32), m0)
            (grads, msum), _ = jax.lax.scan(micro, (gzero, mzero), micro_batches)
            grads = jax.tree.map(lambda g: g / ga, grads)
            metrics = jax.tree.map(lambda m: m / ga, msum)
        else:
            (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
            grads = _constrain_grads(grads, storage_specs)
        params_new, opt_state, om = adamw_update(opt_cfg, params, grads, opt_state)
        return params_new, opt_state, {**metrics, **om}

    return train_step


def _constrain_grads(grads, storage_specs):
    """Pin gradients to the parameter storage layout.

    Without this GSPMD can leave scan-transposed per-layer grads replicated
    (a full all-reduce instead of a reduce-scatter), which then replicates
    the whole grad-accum + AdamW elementwise pipeline — full (L, D, D) f32
    stacks on every chip.
    """
    if storage_specs is None:
        return grads
    from repro.launch import mesh as meshlib

    return jax.tree.map(
        lambda g, s: meshlib.shard(g, *s),
        grads,
        storage_specs,
        is_leaf=lambda x: isinstance(x, jax.Array),
    )


def make_prefill_step(api: ModelAPI, max_len: int):
    """(params, batch) -> (next_token_logits (B, Vp), cache)."""

    def prefill_step(params, batch):
        logits, cache = api.prefill(params, batch, max_len=max_len)
        return logits[:, -1, :], cache

    return prefill_step


def make_serve_step(api: ModelAPI, *, sample: str = "greedy",
                    vocab: Optional[int] = None):
    """(params, cache, tokens (B,1)) -> (next_tokens (B,1), cache').

    ``vocab`` restricts the argmax to the first ``vocab`` logits — models
    pad their output head to a lane multiple, and a serving caller must
    never sample a padding id. The serving engine's jitted decode is this
    step (with the vocab slice and donated cache), so decode + fused
    argmax has exactly one implementation.
    """

    def serve_step(params, cache, tokens):
        logits, cache = api.decode(params, cache, tokens)
        v = logits.shape[-1] if vocab is None else vocab
        nxt = jnp.argmax(logits[:, -1, :v], axis=-1).astype(jnp.int32)[:, None]
        return nxt, cache

    return serve_step
