"""Shared benchmark harness: run the serving engine under a paper-workload
profile and return measured access statistics (MemProf-in-the-loop)."""
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.workloads import PROFILES, get_profile
from repro.data.requests import RequestGenerator
from repro.models.api import get_model
from repro.runtime.serving import EngineConfig, ServingEngine

_MODEL_CACHE = {}  # arch -> (cfg, api, params): one jitted decode per arch


def engine_for(arch="smollm-360m", seed=0, **ekw):
    if arch not in _MODEL_CACHE:
        cfg = get_config(arch).reduced()
        api = get_model(cfg)
        _MODEL_CACHE[arch] = (cfg, api, api.init(jax.random.PRNGKey(0)))
    cfg, api, params = _MODEL_CACHE[arch]
    kw = dict(max_batch=4, max_len=64, n_pages=512)
    kw.update(ekw)
    return cfg, ServingEngine(api, params, EngineConfig(**kw), seed=seed)


def run_workload(name, n_requests=10, seed=0, arch="smollm-360m", prompt=24, decode=8, **ekw):
    cfg, eng = engine_for(arch, seed=seed, **ekw)
    prof = dataclasses.replace(get_profile(name), prompt_mean=prompt, decode_mean=decode)
    gen = RequestGenerator(prof, vocab_size=cfg.vocab_size, seed=seed)
    stats = eng.run(gen, n_requests=n_requests, max_steps=2000)
    return eng, stats


def stream_for(name, n=20_000, n_blocks=4096, seed=0):
    """Raw block-access stream for a workload profile (fast path)."""
    prof = get_profile(name)
    gen = RequestGenerator(prof, vocab_size=1024, seed=seed)
    return gen.block_stream(n, n_blocks=n_blocks), prof


def template_stream_for(name, n=16_000, n_blocks=4096, seed=0, phases=1, **tkw):
    """Stream-tagged template-walk stream (blocks, lanes, profile) — the
    paged-KV access shape the trace-driven prefetcher is scored on."""
    prof = get_profile(name)
    gen = RequestGenerator(prof, vocab_size=1024, seed=seed)
    blocks, lanes = gen.template_stream(n, n_blocks=n_blocks, phases=phases, **tkw)
    return blocks, lanes, prof


def score_prefetcher(blocks, lanes, predictor, table=None, buffer_blocks=256, degree=1):
    """Replay a stream-tagged block stream through a PrefetchEngine and
    return FINALIZED stats (resident-but-unused charged as waste)."""
    from repro.core.prefetch import PrefetchEngine

    eng = PrefetchEngine(predictor=predictor, buffer_blocks=buffer_blocks, degree=degree)
    if table:
        eng.load_successors(table)
    for b, l in zip(blocks.tolist(), lanes.tolist()):
        eng.access(b, is_far=True, stream=l)
    return eng.finalized_stats()


def fmt_table(rows, headers):
    w = [max(len(str(r[i])) for r in rows + [headers]) for i in range(len(headers))]
    out = ["  ".join(str(h).ljust(w[i]) for i, h in enumerate(headers))]
    out.append("  ".join("-" * w[i] for i in range(len(headers))))
    for r in rows:
        out.append("  ".join(str(c).ljust(w[i]) for i, c in enumerate(r)))
    return "\n".join(out)


ALL_WORKLOADS = list(PROFILES)
