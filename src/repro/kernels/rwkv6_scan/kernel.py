"""Chunked WKV6 Pallas TPU kernel (RWKV6 linear attention, per-channel decay).

Per (batch, head) the sequence is processed in chunks of C tokens with the
cross-chunk state S (hd x hd) carried in VMEM scratch. Within a chunk the
recurrence is closed-form:

  ce[t]  = sum_{i<t} lw[i]          (exclusive log-decay cumsum, per channel)
  cwi[s] = sum_{i<=s} lw[i]         (inclusive)
  A[t,s] = sum_k r[t,k] k[s,k] exp(ce[t,k] - cwi[s,k])      (s < t, intra)
  A[t,t] = sum_k r[t,k] u[k] k[t,k]                          (bonus diag)
  y      = A @ v + (r * exp(ce)) @ S_in
  S_out  = diag(exp(cwi[C-1])) S_in + (k * exp(cwi[C-1] - cwi))^T @ v

Every exponent is <= 0 (lw <= 0), so no overflow for arbitrarily strong
data-dependent decay — this is why the kernel materializes the (C, C, hd)
decay tensor instead of the r~/k~ factorization, trading VMEM (C^2*hd f32;
1 MiB at C=64, hd=64) for unconditional numerical safety. Grid
(B, H, T/C) with the chunk axis innermost (sequential state carry).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._interpret import resolve_interpret


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, y_ref, sout_ref, s_ref):
    t_idx = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(t_idx == 0)
    def _init():
        s_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)  # (C, hd)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)  # (hd,)
    c, hd = r.shape

    cwi = jnp.cumsum(lw, axis=0)  # inclusive (C, hd)
    ce = cwi - lw  # exclusive

    # intra-chunk: (C, C, hd) decay tensor, all exponents <= 0
    e = jnp.exp(ce[:, None, :] - cwi[None, :, :])  # (t, s, k)
    p = jnp.sum(r[:, None, :] * k[None, :, :] * e, axis=-1)  # (t, s)
    ti = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    p = jnp.where(si < ti, p, 0.0)
    diag = jnp.sum(r * u[None, :] * k, axis=-1)  # (C,)
    a = p + jnp.where(si == ti, diag[:, None], 0.0)
    y = jax.lax.dot_general(a, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    # carry-in contribution + state update
    s_in = s_ref[...]  # (hd_k, hd_v)
    y = y + jax.lax.dot_general(
        r * jnp.exp(ce), s_in, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    decay_tail = jnp.exp(cwi[-1][None, :] - cwi)  # (C, hd)
    s_new = jnp.exp(cwi[-1])[:, None] * s_in + jax.lax.dot_general(
        (k * decay_tail), v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    s_ref[...] = s_new
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(t_idx == nt - 1)
    def _final():
        sout_ref[0, 0] = s_new.astype(sout_ref.dtype)


def wkv6_chunked_kernel(r, k, v, lw, u, s0, *, chunk: int = 64, interpret=None):
    """r/k/v/lw: (B, H, T, hd); u: (H, hd); s0: (B, H, hd, hd).

    Returns (y (B,H,T,hd) f32, s_out (B,H,hd,hd) f32). T % chunk == 0.
    """
    b, h, t, hd = r.shape
    assert t % chunk == 0, (t, chunk)
    grid = (b, h, t // chunk)

    chunk_spec = pl.BlockSpec((1, 1, chunk, hd), lambda bb, hh, tt: (bb, hh, tt, 0))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            chunk_spec,
            chunk_spec,
            chunk_spec,
            chunk_spec,
            pl.BlockSpec((1, hd), lambda bb, hh, tt: (hh, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda bb, hh, tt: (bb, hh, 0, 0)),
        ],
        out_specs=[
            chunk_spec,
            pl.BlockSpec((1, 1, hd, hd), lambda bb, hh, tt: (bb, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, t, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, h, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(r, k, v, lw, u, s0)
