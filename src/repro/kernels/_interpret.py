"""One interpret-mode default shared by all five kernel packages.

Historically each ops.py picked its own default (``interpret=True``, CPU
container assumption) while the kernel.py entry points defaulted to
``interpret=False`` — calling a kernel directly on CPU crashed, and running
ops on a real TPU silently interpreted. The single source of truth is now:

  * ``REPRO_KERNEL_INTERPRET`` env var, when set: "1"/"true" forces
    interpret mode (CI's CPU kernel job), "0"/"false" forces compiled
    Mosaic lowering;
  * otherwise auto-detect: compiled on TPU backends, interpreted elsewhere.

Public ops take ``interpret: bool | None = None`` and resolve ``None``
through :func:`resolve_interpret` *outside* their ``jax.jit`` wrapper, so an
env flip mid-process is honored (the jit cache is keyed on the resolved
bool, never on ``None``).
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.env import env_flag

ENV_VAR = "REPRO_KERNEL_INTERPRET"


def default_interpret() -> bool:
    """Interpret-mode default for this process: env override, else backend."""
    env = env_flag(ENV_VAR)
    if env is not None:
        return env
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Resolve a caller's ``interpret`` argument (None -> shared default)."""
    return default_interpret() if interpret is None else bool(interpret)
