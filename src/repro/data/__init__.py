from repro.data.synthetic import SyntheticCorpus, token_batches  # noqa: F401
from repro.data.loader import ShardedLoader  # noqa: F401
