"""Dense decoder-only LM (qwen2.5 / internlm2 / smollm / qwen1.5-110b) and the
qwen2-vl text backbone (same block; inputs may be precomputed embeddings with
M-RoPE position ids).

Layer params are stacked (leading L axis) and the block is applied with
``lax.scan`` so the HLO stays compact for 80-layer configs.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.mesh import BATCH, MODEL, shard
from repro.models import attention, common

Array = jax.Array


# ---------------------------------------------------------------------------
# init


def _init_layer(key, cfg: ModelConfig, dtype) -> dict:
    ka, kg, ku, kd = jax.random.split(key, 4)
    d, f = cfg.d_model, cfg.d_ff
    return {
        "ln1": jnp.ones((d,), dtype),
        "ln2": jnp.ones((d,), dtype),
        "attn": attention.init(ka, cfg, dtype),
        "mlp": {
            "w_gate": common.dense_init(kg, (d, f), dtype=dtype),
            "w_up": common.dense_init(ku, (d, f), dtype=dtype),
            "w_down": common.dense_init(kd, (f, d), scale=1.0 / (2 * cfg.n_layers) ** 0.5, dtype=dtype),
        },
    }


def init(key, cfg: ModelConfig) -> dict:
    dtype = common.dt(cfg.param_dtype)
    ke, kl, kh = jax.random.split(key, 3)
    layers = jax.vmap(lambda k: _init_layer(k, cfg, dtype))(jax.random.split(kl, cfg.n_layers))
    params = {
        "embed": common.embed_init(ke, (cfg.padded_vocab, cfg.d_model), dtype),
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = common.dense_init(kh, (cfg.d_model, cfg.padded_vocab), dtype=dtype)
    return params


def layer_specs(cfg: ModelConfig) -> dict:
    """Compute-time (TP) specs for ONE layer slice (no stacked L axis)."""
    return {
        "ln1": (None,),
        "ln2": (None,),
        "attn": attention.param_specs(cfg),
        "mlp": {"w_gate": (None, MODEL), "w_up": (None, MODEL), "w_down": (MODEL, None)},
    }


def param_specs(cfg: ModelConfig) -> dict:
    """Compute-time (TP) PartitionSpecs, matching the ``init`` tree.

    Layer leaves get a leading ``None`` for the stacked L axis.
    """
    lyr = jax.tree.map(lambda s: (None,) + tuple(s), layer_specs(cfg), is_leaf=lambda s: isinstance(s, tuple))
    specs = {
        "embed": (MODEL, None),
        "layers": lyr,
        "final_norm": (None,),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = (None, MODEL)
    return specs


# ---------------------------------------------------------------------------
# blocks


def _res(cfg: ModelConfig, h):
    # residual-stream constraint; sp_activations shards the seq dim over the
    # TP axis (Megatron sequence parallelism) so per-layer saved residuals
    # scale as 1/TP — required for the 80-layer 110B cell to fit HBM.
    return shard(h, BATCH, MODEL if cfg.sp_activations else None, None)


def _sp_gather(cfg: ModelConfig, x):
    # explicit Megatron-SP boundary: all-gather the seq-sharded residual
    # before the TP-sharded matmuls. Without this GSPMD resolves the
    # seq<->head sharding clash inside attention by "involuntary full
    # rematerialization" (replicate + repartition) — the dominant collective
    # cost of the 110B baseline.
    if cfg.sp_activations:
        return shard(x, BATCH, None, None)
    return x


@jax.custom_vjp
def _grad_barrier(h):
    return jax.lax.optimization_barrier(h)


def _grad_barrier_fwd(h):
    return jax.lax.optimization_barrier(h), None


def _grad_barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


# jax 0.4.x has no differentiation rule for optimization_barrier; the barrier
# is identity-valued, so route gradients through a barrier of their own
# (keeps the hoisting protection on the backward pass too).
_grad_barrier.defvjp(_grad_barrier_fwd, _grad_barrier_bwd)


def _block_train(cfg: ModelConfig, h, layer, positions, mrope_positions, block_k):
    layer = common.constrain_tree(layer, layer_specs(cfg), common.dt(cfg.compute_dtype))  # cast + JIT per-layer gather
    # barrier: stops XLA hoisting the bf16->f32 norm upcast of the saved
    # residual out of the backward loop (which would materialize the WHOLE
    # (L, B, S, D) remat stack in f32 — 2x the largest train buffer)
    h = _grad_barrier(h)
    x = common.rms_norm(h, layer["ln1"], cfg.norm_eps)  # attention is SP-native
    h = h + attention.apply_train(layer["attn"], cfg, x, positions, mrope_positions, block_k=block_k)
    x = _sp_gather(cfg, common.rms_norm(h, layer["ln2"], cfg.norm_eps))
    m = layer["mlp"]
    h = h + common.swiglu(x, m["w_gate"], m["w_up"], m["w_down"])
    return _res(cfg, h)


def _embed_in(params, cfg: ModelConfig, tokens=None, embeds=None):
    if embeds is None:
        w = shard(params["embed"], MODEL, None)  # gather-at-use (pool axis)
        embeds = jnp.take(w, tokens, axis=0)
    h = embeds.astype(common.dt(cfg.compute_dtype))
    return _res(cfg, h)


def _head_w(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return shard(params["embed"], MODEL, None).T
    return shard(params["lm_head"], None, MODEL)


def _logits_out(params, cfg: ModelConfig, h):
    h = common.rms_norm(h, params["final_norm"], cfg.norm_eps)
    w = _head_w(params, cfg)
    logits = jnp.einsum("bsd,dv->bsv", h, w.astype(h.dtype), preferred_element_type=jnp.float32)
    return shard(logits, BATCH, None, MODEL)


def features(
    params: dict,
    cfg: ModelConfig,
    tokens: Optional[Array] = None,
    embeds: Optional[Array] = None,
    positions: Optional[Array] = None,
    mrope_positions: Optional[Array] = None,
    *,
    remat: Optional[bool] = None,
    block_k: Optional[int] = None,
):
    """Trunk -> (post-final-norm h (B,S,D), head weight (D,Vp)).

    The loss path pairs this with ``common.fused_ce_loss`` so the full
    logits tensor is never materialized; ``forward`` keeps the logits API
    for serving and tests.
    """
    block_k = block_k or cfg.attn_block_k
    h = _embed_in(params, cfg, tokens, embeds)
    b, l, _ = h.shape
    if positions is None:
        positions = common.causal_positions(b, l)

    use_remat = cfg.remat if remat is None else remat
    k = max(cfg.remat_every, 1)
    layers = params["layers"]
    if k > 1:
        nl = cfg.n_layers
        assert nl % k == 0, (nl, k)
        layers = jax.tree.map(lambda x: x.reshape(nl // k, k, *x.shape[1:]), layers)

        def block(h, lp):
            # k layers per checkpoint: saved residual stack scales as 1/k,
            # backward recomputes k layers per segment (same total flops
            # as remat_every=1 up to scheduling).
            for i in range(k):
                layer = jax.tree.map(lambda x: x[i], lp)
                h = _block_train(cfg, h, layer, positions, mrope_positions, block_k)
            return h

    else:

        def block(h, layer):
            return _block_train(cfg, h, layer, positions, mrope_positions, block_k)

    block = common.maybe_remat(block, use_remat, cfg.remat_policy)
    h, _ = jax.lax.scan(lambda c, lp: (block(c, lp), None), h, layers)
    h = common.rms_norm(h, params["final_norm"], cfg.norm_eps)
    return h, _head_w(params, cfg)


def forward(
    params: dict,
    cfg: ModelConfig,
    tokens: Optional[Array] = None,
    embeds: Optional[Array] = None,
    positions: Optional[Array] = None,
    mrope_positions: Optional[Array] = None,
    *,
    remat: Optional[bool] = None,
    block_k: Optional[int] = None,
) -> Array:
    """Full-sequence forward -> logits (B, S, Vp)."""
    h, w = features(
        params, cfg, tokens, embeds, positions, mrope_positions,
        remat=remat, block_k=block_k,
    )
    logits = jnp.einsum("bsd,dv->bsv", h, w.astype(h.dtype), preferred_element_type=jnp.float32)
    return shard(logits, BATCH, None, MODEL)


# ---------------------------------------------------------------------------
# serving


def prefill(
    params: dict,
    cfg: ModelConfig,
    tokens: Optional[Array] = None,
    embeds: Optional[Array] = None,
    mrope_positions: Optional[Array] = None,
    *,
    max_len: int,
    block_k: Optional[int] = None,
):
    """Forward + KV cache construction. Returns (logits, cache)."""
    block_k = block_k or cfg.attn_block_k
    h = _embed_in(params, cfg, tokens, embeds)
    b, l, _ = h.shape
    positions = common.causal_positions(b, l)

    def block(h, layer):
        layer = common.constrain_tree(layer, layer_specs(cfg), common.dt(cfg.compute_dtype))
        x = common.rms_norm(h, layer["ln1"], cfg.norm_eps)
        a, (k, v) = attention.apply_prefill(
            layer["attn"], cfg, x, positions, max_len, mrope_positions, block_k=block_k
        )
        h = h + a
        x = common.rms_norm(h, layer["ln2"], cfg.norm_eps)
        m = layer["mlp"]
        h = h + common.swiglu(x, m["w_gate"], m["w_up"], m["w_down"])
        return _res(cfg, h), (k, v)

    h, (ks, vs) = jax.lax.scan(lambda c, lp: block(c, lp), h, params["layers"])
    cache = {
        "k": ks.astype(jnp.bfloat16),
        "v": vs.astype(jnp.bfloat16),
        "lengths": jnp.full((b,), l, jnp.int32),
    }
    return _logits_out(params, cfg, h), cache


def decode_step(params: dict, cfg: ModelConfig, cache: dict, tokens: Array, mrope_positions=None):
    """One decode step. tokens: (B, 1). Returns (logits, cache')."""
    h = _embed_in(params, cfg, tokens)
    lengths = cache["lengths"]

    def step(h, xs):
        layer, kc, vc = xs
        layer = common.constrain_tree(layer, layer_specs(cfg), common.dt(cfg.compute_dtype))
        x = common.rms_norm(h, layer["ln1"], cfg.norm_eps)
        a, kc, vc = attention.apply_decode(layer["attn"], cfg, x, kc, vc, lengths, mrope_positions)
        h = h + a
        x = common.rms_norm(h, layer["ln2"], cfg.norm_eps)
        m = layer["mlp"]
        h = h + common.swiglu(x, m["w_gate"], m["w_up"], m["w_down"])
        return h, (kc, vc)

    h, (ks, vs) = jax.lax.scan(step, h, (params["layers"], cache["k"], cache["v"]))
    logits = _logits_out(params, cfg, h)
    new_cache = {"k": ks, "v": vs, "lengths": lengths + 1}
    return logits, new_cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    return attention.init_cache(cfg, cfg.n_layers, batch, max_len, dtype)


def cache_specs(cfg: ModelConfig, model_axis: int = 16) -> dict:
    return attention.cache_specs(cfg, model_axis)
