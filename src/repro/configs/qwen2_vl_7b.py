"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution. [arXiv:2409.12191; hf]

Backbone only: the vision tower is a stub; input_specs() provides precomputed
patch embeddings + (3, B, S) M-RoPE position ids, per the assignment.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    grad_accum=8,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),
    source="arXiv:2409.12191; hf",
)
