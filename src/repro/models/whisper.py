"""Whisper-base backbone: encoder-decoder transformer.

The conv1d mel frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, n_audio_frames, D). Sinusoidal
positions, LayerNorm + GELU MLP, bidirectional encoder self-attention,
causal decoder self-attention + cross-attention. The cross-attention KV is
computed once per request at prefill — in tiering terms it is a read-only
hot page class for the whole decode (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.mesh import BATCH, MODEL, shard
from repro.models import attention, common

Array = jax.Array


def _init_ln(d, dtype):
    return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def _init_mlp(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_in": common.dense_init(k1, (d, f), dtype=dtype),
        "b_in": jnp.zeros((f,), dtype),
        "w_out": common.dense_init(k2, (f, d), scale=1.0 / (2 * cfg.n_layers) ** 0.5, dtype=dtype),
        "b_out": jnp.zeros((d,), dtype),
    }


def _init_enc_layer(key, cfg, dtype):
    ka, km = jax.random.split(key)
    return {
        "ln1": _init_ln(cfg.d_model, dtype),
        "attn": attention.init(ka, cfg, dtype),
        "ln2": _init_ln(cfg.d_model, dtype),
        "mlp": _init_mlp(km, cfg, dtype),
    }


def _init_dec_layer(key, cfg, dtype):
    ka, kc, km = jax.random.split(key, 3)
    return {
        "ln1": _init_ln(cfg.d_model, dtype),
        "self_attn": attention.init(ka, cfg, dtype),
        "ln2": _init_ln(cfg.d_model, dtype),
        "cross_attn": attention.init(kc, cfg, dtype),
        "ln3": _init_ln(cfg.d_model, dtype),
        "mlp": _init_mlp(km, cfg, dtype),
    }


def init(key, cfg: ModelConfig) -> dict:
    dtype = common.dt(cfg.param_dtype)
    ke, kenc, kdec = jax.random.split(key, 3)
    enc = jax.vmap(lambda k: _init_enc_layer(k, cfg, dtype))(
        jax.random.split(kenc, cfg.n_encoder_layers)
    )
    dec = jax.vmap(lambda k: _init_dec_layer(k, cfg, dtype))(jax.random.split(kdec, cfg.n_layers))
    return {
        "embed": common.embed_init(ke, (cfg.padded_vocab, cfg.d_model), dtype),  # tied lm head
        "enc_layers": enc,
        "dec_layers": dec,
        "enc_norm": _init_ln(cfg.d_model, dtype),
        "dec_norm": _init_ln(cfg.d_model, dtype),
    }


def param_specs(cfg: ModelConfig) -> dict:
    mlp = {"w_in": (None, MODEL), "b_in": (MODEL,), "w_out": (MODEL, None), "b_out": (None,)}
    ln = {"w": (None,), "b": (None,)}
    enc = {"ln1": ln, "attn": attention.param_specs(cfg), "ln2": ln, "mlp": mlp}
    dec = {
        "ln1": ln,
        "self_attn": attention.param_specs(cfg),
        "ln2": ln,
        "cross_attn": attention.param_specs(cfg),
        "ln3": ln,
        "mlp": mlp,
    }
    stack = lambda t: jax.tree.map(lambda s: (None,) + tuple(s), t, is_leaf=lambda s: isinstance(s, tuple))
    return {
        "embed": (MODEL, None),
        "enc_layers": stack(enc),
        "dec_layers": stack(dec),
        "enc_norm": ln,
        "dec_norm": ln,
    }


def enc_layer_specs(cfg: ModelConfig) -> dict:
    mlp = {"w_in": (None, MODEL), "b_in": (MODEL,), "w_out": (MODEL, None), "b_out": (None,)}
    ln = {"w": (None,), "b": (None,)}
    return {"ln1": ln, "attn": attention.param_specs(cfg), "ln2": ln, "mlp": mlp}


def dec_layer_specs(cfg: ModelConfig) -> dict:
    mlp = {"w_in": (None, MODEL), "b_in": (MODEL,), "w_out": (MODEL, None), "b_out": (None,)}
    ln = {"w": (None,), "b": (None,)}
    return {
        "ln1": ln,
        "self_attn": attention.param_specs(cfg),
        "ln2": ln,
        "cross_attn": attention.param_specs(cfg),
        "ln3": ln,
        "mlp": mlp,
    }


def _ln(x, p, eps):
    return common.layer_norm(x, p["w"], p["b"], eps)


def _mlp(x, p):
    return common.gelu_mlp(x, p["w_in"], p["b_in"], p["w_out"], p["b_out"])


def encode(params, cfg: ModelConfig, frames: Array) -> Array:
    """frames: (B, T_enc, D) precomputed embeddings (conv frontend stub)."""
    dtype = common.dt(cfg.compute_dtype)
    h = frames.astype(dtype) + common.sinusoidal_positions(frames.shape[1], cfg.d_model).astype(dtype)
    h = shard(h, BATCH, None, None)
    b, t, _ = h.shape
    positions = common.causal_positions(b, t)

    def block(h, layer):
        layer = common.constrain_tree(layer, enc_layer_specs(cfg), common.dt(cfg.compute_dtype))
        x = _ln(h, layer["ln1"], cfg.norm_eps)
        q, k, v = attention._project_qkv(layer["attn"], cfg, x)
        o = common.attention_chunked(q, k, v, causal=False, block_k=1024, bidirectional=True)
        h = h + attention._out_proj(layer["attn"], h.dtype, o)
        h = h + _mlp(_ln(h, layer["ln2"], cfg.norm_eps), layer["mlp"])
        return shard(h, BATCH, None, None), None

    h, _ = jax.lax.scan(block, h, params["enc_layers"])
    return _ln(h, params["enc_norm"], cfg.norm_eps)


def _cross_kv(layer, cfg, enc_out):
    """Precompute cross-attention K/V from encoder output: (B, Hkv, T_enc, hd)."""
    b, t, _ = enc_out.shape
    hd = cfg.head_dim
    k = (enc_out @ layer["cross_attn"]["wk"]).reshape(b, t, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = (enc_out @ layer["cross_attn"]["wv"]).reshape(b, t, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    return k, v


def _cross_attend(layer, cfg, x, ck, cv):
    b, t, _ = x.shape
    hd = cfg.head_dim
    q = (x @ layer["cross_attn"]["wq"]).reshape(b, t, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    o = common.attention_chunked(q, ck.astype(q.dtype), cv.astype(q.dtype), causal=False, bidirectional=True, block_k=1024)
    return attention._out_proj(layer["cross_attn"], x.dtype, o)


def forward(params, cfg: ModelConfig, tokens: Array, frames: Array, *, remat=None, **_):
    """Teacher-forced decoder over encoder(frames). Returns logits (B,S,Vp)."""
    enc_out = encode(params, cfg, frames)
    dtype = common.dt(cfg.compute_dtype)
    b, s = tokens.shape
    h = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    h = h + common.sinusoidal_positions(s, cfg.d_model).astype(dtype)
    h = shard(h, BATCH, None, None)
    positions = common.causal_positions(b, s)

    def block(h, layer):
        layer = common.constrain_tree(layer, dec_layer_specs(cfg), common.dt(cfg.compute_dtype))
        x = _ln(h, layer["ln1"], cfg.norm_eps)
        q, k, v = attention._project_qkv(layer["self_attn"], cfg, x)
        o = common.attention_chunked(q, k, v, causal=True, block_k=1024)
        h = h + attention._out_proj(layer["self_attn"], h.dtype, o)
        ck, cv = _cross_kv(layer, cfg, enc_out)
        h = h + _cross_attend(layer, cfg, _ln(h, layer["ln2"], cfg.norm_eps), ck, cv)
        h = h + _mlp(_ln(h, layer["ln3"], cfg.norm_eps), layer["mlp"])
        return shard(h, BATCH, None, None)

    use_remat = cfg.remat if remat is None else remat
    blk = common.maybe_remat(block, use_remat, cfg.remat_policy)
    h, _ = jax.lax.scan(lambda c, lp: (blk(c, lp), None), h, params["dec_layers"])
    h = _ln(h, params["dec_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(h.dtype), preferred_element_type=jnp.float32)
    return shard(logits, BATCH, None, MODEL)


def features(params, cfg: ModelConfig, tokens: Array, frames: Array, *, remat=None, **_):
    """Trunk -> (post-norm h, tied lm_head weight (D,Vp)) for the fused CE."""
    enc_out = encode(params, cfg, frames)
    dtype = common.dt(cfg.compute_dtype)
    b, s = tokens.shape
    h = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    h = h + common.sinusoidal_positions(s, cfg.d_model).astype(dtype)
    h = shard(h, BATCH, None, None)
    positions = common.causal_positions(b, s)

    def block(h, layer):
        layer = common.constrain_tree(layer, dec_layer_specs(cfg), common.dt(cfg.compute_dtype))
        x = _ln(h, layer["ln1"], cfg.norm_eps)
        q, k, v = attention._project_qkv(layer["self_attn"], cfg, x)
        o = common.attention_chunked(q, k, v, causal=True, block_k=1024)
        h = h + attention._out_proj(layer["self_attn"], h.dtype, o)
        ck, cv = _cross_kv(layer, cfg, enc_out)
        h = h + _cross_attend(layer, cfg, _ln(h, layer["ln2"], cfg.norm_eps), ck, cv)
        h = h + _mlp(_ln(h, layer["ln3"], cfg.norm_eps), layer["mlp"])
        return shard(h, BATCH, None, None)

    use_remat = cfg.remat if remat is None else remat
    blk = common.maybe_remat(block, use_remat, cfg.remat_policy)
    h, _ = jax.lax.scan(lambda c, lp: (blk(c, lp), None), h, params["dec_layers"])
    h = _ln(h, params["dec_norm"], cfg.norm_eps)
    return h, params["embed"].T


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    hd = cfg.head_dim
    return {
        "k": jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, max_len, hd), dtype),
        "v": jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, max_len, hd), dtype),
        "cross_k": jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, cfg.n_audio_frames, hd), dtype),
        "cross_v": jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, cfg.n_audio_frames, hd), dtype),
        "lengths": jnp.zeros((batch,), jnp.int32),
    }


def cache_specs(cfg: ModelConfig, model_axis: int = 16) -> dict:
    kv = (None, BATCH, MODEL, None, None) if cfg.n_kv_heads % model_axis == 0 else (None, BATCH, None, MODEL, None)
    return {"k": kv, "v": kv, "cross_k": kv, "cross_v": kv, "lengths": (BATCH,)}


def prefill(params, cfg: ModelConfig, tokens: Array, frames: Array, *, max_len: int, **_):
    """Encode audio + teacher-force the prompt tokens; build decoder caches."""
    enc_out = encode(params, cfg, frames)
    dtype = common.dt(cfg.compute_dtype)
    b, s = tokens.shape
    h = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    h = h + common.sinusoidal_positions(s, cfg.d_model).astype(dtype)
    positions = common.causal_positions(b, s)

    def block(h, layer):
        layer = common.constrain_tree(layer, dec_layer_specs(cfg), common.dt(cfg.compute_dtype))
        x = _ln(h, layer["ln1"], cfg.norm_eps)
        a, (k, v) = attention.apply_prefill(layer["self_attn"], cfg, x, positions, max_len)
        h = h + a
        ck, cv = _cross_kv(layer, cfg, enc_out)
        h = h + _cross_attend(layer, cfg, _ln(h, layer["ln2"], cfg.norm_eps), ck, cv)
        h = h + _mlp(_ln(h, layer["ln3"], cfg.norm_eps), layer["mlp"])
        return shard(h, BATCH, None, None), (k, v, ck, cv)

    h, (ks, vs, cks, cvs) = jax.lax.scan(block, h, params["dec_layers"])
    h = _ln(h, params["dec_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(h.dtype), preferred_element_type=jnp.float32)
    cache = {
        "k": ks.astype(jnp.bfloat16),
        "v": vs.astype(jnp.bfloat16),
        "cross_k": cks.astype(jnp.bfloat16),
        "cross_v": cvs.astype(jnp.bfloat16),
        "lengths": jnp.full((b,), s, jnp.int32),
    }
    return shard(logits, BATCH, None, MODEL), cache


def decode_step(params, cfg: ModelConfig, cache: dict, tokens: Array):
    dtype = common.dt(cfg.compute_dtype)
    b = tokens.shape[0]
    lengths = cache["lengths"]
    h = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    pe = common.sinusoidal_positions(cache["k"].shape[3], cfg.d_model).astype(dtype)
    h = h + pe[lengths][:, None, :]

    def step(h, xs):
        layer, kc, vc, ck, cv = xs
        x = _ln(h, layer["ln1"], cfg.norm_eps)
        a, kc, vc = attention.apply_decode(layer["self_attn"], cfg, x, kc, vc, lengths)
        h = h + a
        h = h + _cross_attend(layer, cfg, _ln(h, layer["ln2"], cfg.norm_eps), ck, cv)
        h = h + _mlp(_ln(h, layer["ln3"], cfg.norm_eps), layer["mlp"])
        return h, (kc, vc)

    h, (ks, vs) = jax.lax.scan(
        step, h, (params["dec_layers"], cache["k"], cache["v"], cache["cross_k"], cache["cross_v"])
    )
    h = _ln(h, params["dec_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(h.dtype), preferred_element_type=jnp.float32)
    new_cache = dict(cache, k=ks, v=vs, lengths=lengths + 1)
    return shard(logits, BATCH, None, MODEL), new_cache
