"""Elastic replica set: scale on door pressure, warm from the fleet plan.

Hyperscale services don't run a fixed host count — they trade hosts against
time-varying load. This layer closes that loop over the event-driven fleet:

* **scale-up** fires when the admission controller's door pressure rises
  (recent shed rate, or projected queueing delay near the SLO budget). The
  new replica does NOT cold-start its tiering: its near tier is warmed from
  the AutoTierer's latest fleet plan, because the plan is a property of the
  *service* (the aggregated fleet histogram), not of the host — the paper's
  "same code on many hosts" premise is exactly what makes the handoff valid.
* **scale-down** drains before removal: the victim stops receiving new work
  (``Replica.start_drain``) but keeps stepping its backlog; once idle its
  MemProf profile is exported and folded into the fleet aggregate
  (``retired_profiles`` + the AutoTierer's ``extra_profiles``), so the
  stitched fleet trace and the tiering histogram keep the full service
  history across topology changes.

Attach as a ``FleetRouter.on_step`` hook: it re-evaluates after every
completion batch with the fleet's virtual clock, entirely deterministic.

Params for new hosts default to the fleet's shared (cached) weights; a
production fleet hands ``params_source`` a closure over
``runtime/elastic.elastic_restore`` (see ``restored_params_source``) so a
joining host restores the serving checkpoint onto its own device topology —
the same resize/recovery path the trainer uses.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

from repro.fleet.admission import AdmissionController, SLOModel
from repro.fleet.replica import Replica, ReplicaProfile


@dataclasses.dataclass
class ScaleEvent:
    vtime: float
    action: str  # "up" | "drain" | "retire"
    rid: int
    n_active: int  # non-draining replicas after the action
    reason: str = ""


def restored_params_source(manager, template, mesh=None, specs=None, step=None):
    """Params source for scaled-up replicas via the trainer's elastic-restore
    path: a joining host restores the latest serving checkpoint onto its own
    (possibly different) mesh — reshard-on-restore, not weight transfer."""
    from repro.runtime.elastic import elastic_restore

    def source():
        state, _extras = elastic_restore(manager, template, mesh, specs=specs, step=step)
        return state

    return source


class ElasticFleet:
    """Scales ``router.replicas`` (the list shared with the AutoTierer,
    mutated in place) between ``min_replicas`` and ``max_replicas``.

    Decisions use two signals sampled at most once per ``cooldown`` of
    virtual time: the shed rate over the interval since the last decision
    (time-local, so it decays when the burst ends — a cumulative rate never
    would) and the admission controller's projected backlog as a fraction
    of the SLO budget. Without an admission controller, backlog pressure is
    computed directly from engine queues against slot capacity.
    """

    def __init__(
        self,
        router,
        replica_factory: Callable[[int], Replica],
        autotierer=None,
        min_replicas: int = 1,
        max_replicas: int = 8,
        up_shed_rate: float = 0.05,
        up_backlog_frac: float = 0.75,
        down_backlog_frac: float = 0.10,
        cooldown: float = 8.0,
    ):
        assert min_replicas >= 1 and max_replicas >= min_replicas
        self.router = router
        self.factory = replica_factory
        self.autotierer = autotierer
        self.min_replicas = min_replicas
        self.max_replicas = max_replicas
        self.up_shed_rate = up_shed_rate
        self.up_backlog_frac = up_backlog_frac
        self.down_backlog_frac = down_backlog_frac
        self.cooldown = cooldown
        self.retired_profiles: List[ReplicaProfile] = []
        self.retired_stats: List[dict] = []  # folded into fleet_stats
        self.events: List[ScaleEvent] = []
        self._next_rid = max((r.rid for r in router.replicas), default=-1) + 1
        self._last_decision = float("-inf")
        self._prev_offered = 0
        self._prev_shed = 0

    def _record_event(self, ev: ScaleEvent):
        """Every scale event is a fleet-track instant + a labeled counter."""
        self.events.append(ev)
        self.router.metrics.counter("scale_events", action=ev.action).inc()
        rec = self.router.recorder
        if rec is not None:
            rec.instant(
                f"scale_{ev.action}",
                -1,
                ev.vtime,
                n_active=ev.n_active,
                rid=ev.rid,
                reason=ev.reason,
            )

    # ------------------------------------------------------------------
    # pressure signals

    def _interval_shed_rate(self) -> float:
        """Shed fraction of offers since the previous scaling decision."""
        adm = self.router.admission
        if adm is None:
            return 0.0
        d_off = adm.offered - self._prev_offered
        d_shed = adm.shed - self._prev_shed
        self._prev_offered, self._prev_shed = adm.offered, adm.shed
        return d_shed / d_off if d_off > 0 else 0.0

    def pressure(self) -> dict:
        active = self.router.active_replicas
        # no admission controller at the door: read the same pressure math
        # through a default-SLO controller so both paths share one cost
        # model (its empty decision window reports shed_rate 0.0)
        adm = self.router.admission or AdmissionController(SLOModel())
        p = adm.pressure(active)
        p["queued"] = self.router.queued()
        p["n_active"] = len(active)
        return p

    # ------------------------------------------------------------------
    def __call__(self, now: float):
        """Router hook: retire finished drains, then maybe scale."""
        self._retire_drained(now)
        if now - self._last_decision < self.cooldown:
            return
        p = self.pressure()
        shed = self._interval_shed_rate()
        self._last_decision = now
        if (shed > self.up_shed_rate or p["backlog_frac"] > self.up_backlog_frac) and p[
            "n_active"
        ] < self.max_replicas:
            reason = f"shed={shed:.2f} backlog={p['backlog_frac']:.2f}"
            self.scale_up(now, reason=reason)
        elif (
            shed == 0.0
            and p["queued"] == 0
            and p["backlog_frac"] < self.down_backlog_frac
            and p["n_active"] > self.min_replicas
        ):
            self.scale_down(now, reason=f"backlog={p['backlog_frac']:.2f}")

    # ------------------------------------------------------------------
    def scale_up(self, now: float, reason: str = "manual") -> Replica:
        """Add one replica, near tier pre-warmed from the fleet plan."""
        r = self.factory(self._next_rid)
        self._next_rid += 1
        r.clock = now
        r.created_at = now  # stitched windows key off the join time
        # a joining host reports through the fleet's clock and recorder
        # from its first step (before the warm placement push, which emits
        # a migrate span of its own)
        self.router._attach_engine(r)
        warm = self.autotierer.warm_near_ids() if self.autotierer is not None else None
        if warm is not None:
            # the fleet plan is the service's hotness, valid on any host
            r.apply_placement(warm)
        if self.autotierer is not None:
            table = self.autotierer.warm_successors()
            if table:
                # the prefetch plane warms with the tier plane: learned
                # sequences are a service property too
                r.load_successors(table)
        self.router.replicas.append(r)
        self._last_decision = now
        self._record_event(
            ScaleEvent(now, "up", r.rid, len(self.router.active_replicas), reason)
        )
        return r

    def scale_down(self, now: float, reason: str = "manual") -> Optional[Replica]:
        """Start draining one replica (youngest host first, deterministic)."""
        active = self.router.active_replicas
        if len(active) <= self.min_replicas:
            return None
        victim = max(active, key=lambda r: r.rid)
        victim.start_drain()
        self._last_decision = now
        self._record_event(
            ScaleEvent(now, "drain", victim.rid, len(self.router.active_replicas), reason)
        )
        return victim

    def retire_crashed(self, replica: Replica, now: float, reason: str = "crash"):
        """Record a crash retirement in the scaling history.

        The router's fault machinery already salvaged the host's books
        (``router.crashed_stats`` / ``crashed_profiles`` / ``lost_windows``
        — crash books are quarantined there, NOT folded into
        ``retired_stats``, so drained and crashed history stay separately
        attributable) and removed it from the shared replica list. This
        hook records the topology event and resets the decision clock so
        the autoscaler doesn't immediately react to its own casualty. A
        host that was already draining when it crashed is retired exactly
        once, here: it is gone from the shared list, so a pending
        ``_retire_drained`` can never see it again."""
        self._last_decision = now
        self._record_event(
            ScaleEvent(
                now, "crash", replica.rid, len(self.router.active_replicas), reason
            )
        )

    def _retire_drained(self, now: float):
        """Remove fully drained hosts, folding their profile into the
        fleet aggregate so their history survives them."""
        for r in [r for r in self.router.replicas if r.drained]:
            prof = r.export_profile()
            self.retired_profiles.append(prof)
            if self.autotierer is not None:
                self.autotierer.extra_profiles.append(prof)
            st = r.stats()
            # tier-hit counters live on the placement object, not in
            # engine.stats(); snapshot them so fleet near-hit stays exact
            st["placement_near_hits"] = r.engine.placement.stats.near_hits
            st["placement_far_hits"] = r.engine.placement.stats.far_hits
            self.retired_stats.append(st)
            self.router.replicas.remove(r)
            self._record_event(
                ScaleEvent(now, "retire", r.rid, len(self.router.active_replicas))
            )
