"""Fleet subsystem: multi-replica serving with fleet-wide MemProf.

The paper's observations are fleet-level — the same code runs on many
hosts, and both its profiler and its tracer only become *representative*
when aggregated across them. Module -> paper-section map:

* ``replica.py``  — one profiled host: engine + live hardware-counter
  analogue (§3's per-host collection; Table 6's "live" column), with its
  own clock/speed factor and a drain protocol for elastic scale-down.
* ``scheduler.py`` — deterministic virtual-time event loop: per-replica
  completion events instead of a global barrier, so a straggler slows one
  host, not the fleet step (per-host heterogeneity is first-order at
  hyperscale).
* ``router.py``   — request placement across hosts; prefix-affinity is the
  fleet form of the multi-ASID shared-TLB idea (§4 / Fig. 17): same-template
  requests land where those KV translations already live. Dispatch runs
  from weighted-fair tenant queues at every completion batch (lockstep kept
  as a compatibility mode).
* ``aggregator.py`` — fleet MemProf: sums per-page counts over hosts
  (§4, Fig. 6/9/18) and stitches short attach/detach trace windows from
  multiple hosts into one representative trace, validated by cache-sim
  replay against live counters (§6.2-§6.3, Table 6).
* ``autotier.py`` — online re-tiering from the aggregated histogram
  (§5, Table 4/5): plan on fleet behavior, push placement to every host;
  epochs keyed on virtual time over the (possibly changing) replica set.
* ``admission.py`` — overload sheds at the door instead of pushing the
  far tier past its latency knee (§2, Fig. 4); exports the door-pressure
  signal elasticity scales on.
* ``elastic.py``  — replica set scales with load: scale-up warms its near
  tier from the fleet plan, scale-down drains and folds the host's profile
  into the aggregate.
* ``faults.py``   — deterministic chaos: seeded crash/hang/slowdown/degrade
  faults as first-class scheduler events, replica failover with retry and
  dedup-guarded re-dispatch, crash salvage with quantified loss windows —
  same seed, same run, bit for bit.

``build_fleet`` wires it together; examples/serve_fleet.py is the demo,
benchmarks/fleet_bench.py the scaling study, and
benchmarks/straggler_bench.py the straggler/elasticity study.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax

from repro.fleet.admission import AdmissionController, SLOModel
from repro.fleet.aggregator import (
    aggregate_counts,
    aggregate_metrics,
    aggregate_tenant_counts,
    export_all,
    fleet_report,
    live_fleet_counters,
    stitch_fleet,
    validate_fleet,
)
from repro.fleet.autotier import AutoTierer, TierEpoch
from repro.fleet.elastic import ElasticFleet, ScaleEvent, restored_params_source
from repro.fleet.faults import ChaosEngine, FaultEvent
from repro.fleet.replica import Replica, ReplicaProfile
from repro.fleet.router import (
    POLICIES,
    FleetRouter,
    LeastLoadedPolicy,
    PrefixAffinityPolicy,
    RoundRobinPolicy,
    simulated_throughput,
)
from repro.fleet.scheduler import VirtualScheduler

__all__ = [
    "AdmissionController",
    "SLOModel",
    "AutoTierer",
    "TierEpoch",
    "ElasticFleet",
    "ScaleEvent",
    "restored_params_source",
    "ChaosEngine",
    "FaultEvent",
    "Replica",
    "ReplicaProfile",
    "FleetRouter",
    "RoundRobinPolicy",
    "LeastLoadedPolicy",
    "PrefixAffinityPolicy",
    "POLICIES",
    "VirtualScheduler",
    "simulated_throughput",
    "aggregate_counts",
    "aggregate_metrics",
    "aggregate_tenant_counts",
    "export_all",
    "fleet_report",
    "live_fleet_counters",
    "stitch_fleet",
    "validate_fleet",
    "build_fleet",
]

_MODEL_CACHE: dict = {}


def build_fleet(
    n_replicas: int,
    policy: str = "prefix-affinity",
    arch: str = "smollm-360m",
    admission: Optional[AdmissionController] = None,
    autotier: Optional[dict] = None,
    elastic: Optional[dict] = None,
    live_cache_blocks: int = 128,
    seed: int = 0,
    tenant_weights: Optional[dict] = None,
    speeds: Optional[Sequence[float]] = None,
    recorder=None,
    **engine_kwargs,
) -> FleetRouter:
    """Construct N replicas sharing one model (params + jitted decode),
    a router with the named policy, and optionally admission/autotiering/
    elasticity.

    ``autotier`` kwargs (near_frac, epoch_steps) attach an AutoTierer as an
    on_step hook and return it as ``router.autotierer``. ``elastic`` kwargs
    (min_replicas, max_replicas, thresholds, cooldown; optional
    ``params_source`` for checkpoint-restored weights) attach an
    ElasticFleet as ``router.elastic`` — scaled-up replicas are built by
    the same factory as the initial set and warm their near tier from the
    AutoTierer's latest plan. ``speeds`` gives per-replica step-cost
    multipliers (e.g. ``(1, 1, 1, 4)`` for a 4x straggler on host 3).
    ``tenant_weights`` sets the router's weighted-fair dispatch shares for
    multi-tenant traffic (see fleet/router.py); per-tenant SLOs live on the
    AdmissionController (``tenant_slos``).

    ``recorder`` attaches an ``obs.FlightRecorder`` (request-lifecycle
    spans + unified metrics, exportable to Perfetto): every replica —
    including elastically added ones — emits through it on the fleet's
    virtual clock. Defaults to the process-global recorder, if one is
    installed (``obs.set_default_recorder`` / ``REPRO_FLIGHT_RECORDER=1``).
    """
    from repro.configs import get_config
    from repro.models.api import get_model
    from repro.runtime.serving import EngineConfig, ServingEngine

    if arch not in _MODEL_CACHE:
        cfg = get_config(arch).reduced()
        api = get_model(cfg)
        _MODEL_CACHE[arch] = (cfg, api, api.init(jax.random.PRNGKey(0)))
    cfg, api, params = _MODEL_CACHE[arch]
    if policy not in POLICIES:
        raise ValueError(f"unknown policy {policy!r}; choose from {sorted(POLICIES)}")
    if speeds is not None and len(speeds) != n_replicas:
        raise ValueError(f"speeds must have one entry per replica ({n_replicas})")
    kw = dict(max_batch=4, max_len=64, n_pages=512)
    kw.update(engine_kwargs)
    ekw = dict(elastic or {})
    params_source = ekw.pop("params_source", None)

    def make_replica(rid: int, speed: float = 1.0) -> Replica:
        p = params_source() if params_source is not None else params
        ecfg = EngineConfig(**kw)
        if ecfg.model_shards > 1:
            # one LOGICAL replica spanning chips: still one routing target,
            # one profile export, one tenant book — the shards are invisible
            # to the router and merge by summation everywhere above this
            from repro.runtime.sharded import ShardedServingEngine

            eng = ShardedServingEngine(api, p, ecfg, seed=seed + rid)
        else:
            eng = ServingEngine(api, p, ecfg, seed=seed + rid)
        return Replica(rid, eng, live_cache_blocks, speed=speed)

    replicas = [
        make_replica(i, 1.0 if speeds is None else float(speeds[i]))
        for i in range(n_replicas)
    ]
    router = FleetRouter(
        replicas, POLICIES[policy](), admission=admission, tenant_weights=tenant_weights
    )
    if recorder is not None:
        router.attach_recorder(recorder)
    if autotier is not None:
        router.autotierer = AutoTierer(replicas, **autotier)
        router.on_step.append(router.autotierer)
    if elastic is not None:
        router.elastic = ElasticFleet(
            router, make_replica, autotierer=router.autotierer, **ekw
        )
        router.on_step.append(router.elastic)
    return router


def fleet_vocab(arch: str = "smollm-360m") -> int:
    """Vocab size of the (cached) reduced model — for RequestGenerators."""
    from repro.configs import get_config

    if arch in _MODEL_CACHE:
        return _MODEL_CACHE[arch][0].vocab_size
    return get_config(arch).reduced().vocab_size
