"""Benchmark registry smoke: every module benchmarks/run.py lists must
import cleanly and expose a callable ``main`` — a typo'd registration or an
import-time crash should fail here, not in CI's benchmark stage."""
import importlib
import pathlib
import sys

import pytest

BENCH_DIR = pathlib.Path(__file__).resolve().parents[1] / "benchmarks"
sys.path.insert(0, str(BENCH_DIR))

import run as bench_run  # noqa: E402


def test_registry_names_resolve_to_files():
    for name in bench_run.MODULES:
        assert (BENCH_DIR / f"{name}.py").is_file(), name


def test_tenant_interference_is_registered():
    assert "tenant_interference" in bench_run.MODULES


def test_tiered_decode_bench_is_registered():
    assert "tiered_decode_bench" in bench_run.MODULES


@pytest.mark.parametrize("name", bench_run.MODULES)
def test_registered_benchmark_importable_and_callable(name):
    mod = importlib.import_module(name)
    assert hasattr(mod, "main"), f"{name} has no main()"
    assert callable(mod.main)


def test_selector_rejects_unknown_benchmark():
    assert bench_run.main(["no-such-benchmark"]) == 2


# ---------------------------------------------------------------------------
# --trace flag (fleet flight recorder export)


def test_trace_flag_round_trips():
    path, rest = bench_run.parse_trace_flag(["--trace", "out.json", "table5"])
    assert (path, rest) == ("out.json", ["table5"])
    path, rest = bench_run.parse_trace_flag(["table5"])
    assert (path, rest) == (None, ["table5"])
    # the flag composes with the selector in either order
    path, rest = bench_run.parse_trace_flag(["fleet", "--trace", "t.json"])
    assert (path, rest) == ("t.json", ["fleet"])
    with pytest.raises(SystemExit):
        bench_run.parse_trace_flag(["--trace"])


def test_trace_flag_writes_export(tmp_path, monkeypatch):
    """main() with --trace installs a recorder and writes the trace +
    metrics files on exit (exercised against a stub benchmark so the smoke
    stays cheap)."""
    import types

    from repro.obs import default_recorder, set_default_recorder

    stub = types.ModuleType("stub_bench")

    def stub_main():
        rec = default_recorder()
        assert rec is not None, "--trace must install the global recorder"
        rec.instant("tick", 1, 0.5, tenant="t")
        return {"ok": True}

    stub.main = stub_main
    monkeypatch.setitem(sys.modules, "stub_bench", stub)
    monkeypatch.setattr(bench_run, "MODULES", ["stub_bench"])
    out = tmp_path / "trace.json"
    try:
        assert bench_run.main(["stub_bench", "--trace", str(out)]) == 0
    finally:
        set_default_recorder(None)
    assert out.is_file()
    assert (tmp_path / "trace.json.metrics.jsonl").is_file()
    import json

    events = json.loads(out.read_text())["traceEvents"]
    assert any(e.get("ph") == "i" and e["name"] == "tick" for e in events)
