"""Core (paper-technique) invariants: profiler, distribution, tiering,
placement, prefetch, page table, memtrace — with hypothesis property tests."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import distribution as dist
from repro.core import hw
from repro.core.memtrace import CacheSim, MemTracer, validate_trace
from repro.core.pagetable import FAR, NEAR, SharedKVPageTable
from repro.core.placement import TieredPlacement
from repro.core.prefetch import PrefetchEngine
from repro.core.profiler import AccessProfiler
from repro.core.tiering import ThroughputModel, evaluate_configs, plan


# ---------------------------------------------------------------------------
# distribution / profiler


def test_bandwidth_cdf_monotone():
    rng = np.random.default_rng(0)
    counts = np.bincount(rng.zipf(1.2, 50_000) % 1024, minlength=1024)
    xs, ys = dist.bandwidth_cdf(counts)
    assert ys[0] >= 0 and abs(ys[-1] - 1.0) < 1e-9
    assert np.all(np.diff(ys) >= -1e-12)


@given(st.floats(0.05, 0.9))
@settings(max_examples=20, deadline=None)
def test_hot_fraction_dominates_capacity(frac):
    rng = np.random.default_rng(1)
    counts = np.bincount(rng.zipf(1.3, 20_000) % 512, minlength=512)
    hf = dist.hot_fraction(counts, frac)
    # hottest X% of blocks must serve at least X% of traffic
    assert hf >= frac - 1e-6


def test_profiler_correlation_identical_streams():
    prof = AccessProfiler(n_blocks=256)
    rng = np.random.default_rng(2)
    ids = rng.zipf(1.4, 5000) % 256
    prof.record("a", ids)
    prof.record("b", ids)
    prof.record("c", rng.permutation(256)[rng.integers(0, 256, 5000)])
    assert prof.correlation("a", "b") > 0.999  # Table 2 analogue
    assert prof.correlation("a", "c") < 0.9


def test_profiler_rw_ratio():
    prof = AccessProfiler(n_blocks=64)
    prof.record("s", np.arange(64), rw="r")
    prof.record("s", np.arange(32), rw="w")
    assert abs(prof.rw_ratio("s") - 2.0) < 1e-6


# ---------------------------------------------------------------------------
# tiering (paper Table 4/5)


def test_plan_places_hottest_near():
    counts = np.array([1, 100, 5, 50, 2, 80, 3, 60], float)
    p = plan(counts, hw.TIERED)
    hot = set(p.hot_blocks.tolist())
    assert {1, 5, 7} <= hot  # top blocks by count
    assert abs(sum(p.hit_fracs) - 1.0) < 1e-9
    assert p.hit_fracs[0] >= p.hit_fracs[1]


def test_table5_reproduction_band():
    """Measured-skew streams must land Tiered in the paper's band:
    >=1.3x throughput vs Baseline and better perf/cost than both."""
    rng = np.random.default_rng(3)
    counts = np.bincount(rng.zipf(1.2, 200_000) % 4096, minlength=4096)
    res = evaluate_configs(
        counts,
        {"Baseline": hw.BASELINE, "Ideal": hw.IDEAL, "Tiered": hw.TIERED},
        ThroughputModel(),
    )
    t, i, b = (res[k]["relative_throughput"] for k in ("Tiered", "Ideal", "Baseline"))
    assert b == pytest.approx(1.0, rel=1e-6)
    assert 1.30 <= t <= 1.55 and t <= i
    assert res["Tiered"]["throughput_per_cost"] > res["Baseline"]["throughput_per_cost"]
    assert res["Tiered"]["throughput_per_cost"] > res["Ideal"]["throughput_per_cost"]


# ---------------------------------------------------------------------------
# placement (TPP analogue)


def test_placement_migrates_hot_up():
    n = 128
    pl = TieredPlacement(n_blocks=n, near_capacity=32)
    rng = np.random.default_rng(4)
    hot_ids = np.arange(16)  # blocks 0..15 are hot
    for _ in range(8):
        window = np.bincount(
            np.concatenate([np.repeat(hot_ids, 20), rng.integers(0, n, 64)]), minlength=n
        )
        pl.step(window)
    near = set(pl.near_blocks().tolist())
    assert set(hot_ids.tolist()) <= near


# ---------------------------------------------------------------------------
# prefetch (paper §6 accounting)


def test_nextline_perfect_on_sequential():
    eng = PrefetchEngine(predictor="nextline", buffer_blocks=32, degree=2)
    far = np.ones(512, bool)
    for b in range(512):
        eng.access(b, is_far=True)
    assert eng.stats.accuracy > 0.9
    assert eng.stats.coverage > 0.9


def test_random_stream_low_coverage():
    rng = np.random.default_rng(5)
    eng = PrefetchEngine(predictor="nextline", buffer_blocks=32, degree=2)
    for b in rng.integers(0, 4096, 2000):
        eng.access(int(b), is_far=True)
    assert eng.stats.coverage < 0.5  # paper Fig. 22: low coverage
    assert eng.stats.bw_overhead > 0.0  # and real bandwidth cost (Fig. 21)


@given(st.lists(st.integers(0, 63), min_size=1, max_size=300))
@settings(max_examples=25, deadline=None)
def test_prefetch_stats_bounded(stream):
    eng = PrefetchEngine(predictor="stride", buffer_blocks=16, degree=2)
    for b in stream:
        eng.access(b, is_far=True)
    s = eng.stats
    assert 0.0 <= s.accuracy <= 1.0
    assert 0.0 <= s.coverage <= 1.0
    assert s.bw_overhead >= 0.0


# ---------------------------------------------------------------------------
# shared KV page table (multi-ASID analogue)


def test_prefix_sharing_dedups():
    pt = SharedKVPageTable(n_pages=64, page_size=4)
    prefix = list(range(8))
    pt.add_sequence(0, prefix + [100, 101])
    st1 = pt.add_sequence(1, prefix + [200])
    assert st1["shared"] == 2  # both full prefix pages shared
    assert pt.pages[pt.seqs[0][0]].ref == 2
    pt.free_sequence(0)
    assert pt.pages[pt.seqs[1][0]].ref == 1
    pt.free_sequence(1)
    assert pt.used_pages == 0


def test_append_token_cow():
    pt = SharedKVPageTable(n_pages=64, page_size=4)
    pt.add_sequence(0, [1, 2, 3, 4, 5, 6])  # page0 full, page1 fill=2
    pt.add_sequence(1, [1, 2, 3, 4, 5, 6])  # shares page0 only (tail private)
    tail0 = pt.seqs[0][-1]
    pt.append_token(0)
    assert pt.seqs[0][-1] == tail0  # private tail appended in place
    # force sharing of a full tail then COW on append
    pt2 = SharedKVPageTable(n_pages=64, page_size=4)
    pt2.add_sequence(0, [1, 2, 3, 4])
    pt2.add_sequence(1, [1, 2, 3, 4])
    assert pt2.seqs[0][-1] == pt2.seqs[1][-1]
    pid = pt2.append_token(0)  # page full -> new page, no COW needed
    assert pid != pt2.seqs[1][-1]


@given(
    st.lists(
        st.lists(st.integers(0, 3), min_size=1, max_size=24),
        min_size=1,
        max_size=12,
    )
)
@settings(max_examples=30, deadline=None)
def test_pagetable_refcount_invariants(seqs):
    pt = SharedKVPageTable(n_pages=512, page_size=4)
    for i, toks in enumerate(seqs):
        pt.add_sequence(i, toks)
    # refcount of every used page equals the number of sequences mapping it
    from collections import Counter

    mapped = Counter()
    for pages in pt.seqs.values():
        for pid in set(pages):  # a seq maps a page at most once here
            mapped[pid] += pages.count(pid)
    for pid, pg in enumerate(pt.pages):
        assert pg.ref == mapped.get(pid, 0)
    # free everything -> pool fully recovered
    for i in range(len(seqs)):
        pt.free_sequence(i)
    assert pt.used_pages == 0
    assert len(pt.free) == 512


def test_tier_bits():
    pt = SharedKVPageTable(n_pages=8, page_size=2)
    pt.add_sequence(0, [1, 2, 3, 4])
    pid = pt.seqs[0][0]
    assert pt.tier_of([pid])[0] == NEAR
    pt.set_tier(pid, FAR)
    assert pt.tier_of([pid])[0] == FAR


# ---------------------------------------------------------------------------
# memtrace (PIN-tool analogue, Table 6)


def test_trace_stitch_and_validate():
    tracer = MemTracer(window_len=16, period=64)
    rng = np.random.default_rng(6)
    blocks = rng.zipf(1.3, 20_000) % 512
    sim_full = CacheSim(capacity_blocks=64)
    for i, b in enumerate(blocks):
        tracer.tick()
        tracer.record([int(b)], is_write=(i % 3 == 0))
        sim_full.access(int(b))
    trace = tracer.stitch()
    assert tracer.overhead_frac() < 0.5  # windowed: traces a minority of time
    live_hits = sim_full.hits / max(sim_full.hits + sim_full.misses, 1)
    res = validate_trace(trace, live_hits, live_rw_ratio=2.0, capacity_blocks=64)
    assert abs(res["hit_ratio_error"]) < 0.15  # Table 6 band (<=5% in paper)
    assert len(trace.blocks) > 0
