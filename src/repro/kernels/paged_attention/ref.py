"""Pure-jnp oracle for paged decode attention: materialize pages densely,
then run masked single-token attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def gather_pages(pages: jax.Array, page_table: jax.Array) -> jax.Array:
    """pages: (Hkv, P, ps, d); page_table: (B, pp) -> dense (B, Hkv, pp*ps, d)."""
    hkv, _, ps, d = pages.shape
    b, pp = page_table.shape
    g = pages[:, page_table]  # (Hkv, B, pp, ps, d)
    return g.transpose(1, 0, 2, 3, 4).reshape(b, hkv, pp * ps, d)


def paged_attention_ref(q, k_pages, v_pages, page_table, lengths):
    """q: (B, Hq, d); pages: (Hkv, P, ps, d); page_table: (B, pp); lengths: (B,).

    Returns (B, Hq, d) f32-accurate decode attention over the first
    ``lengths[b]`` tokens of each sequence.
    """
    b, hq, d = q.shape
    hkv = k_pages.shape[0]
    g = hq // hkv
    k = gather_pages(k_pages, page_table).astype(jnp.float32)
    v = gather_pages(v_pages, page_table).astype(jnp.float32)
    qf = q.astype(jnp.float32).reshape(b, hkv, g, d)
    s = jnp.einsum("bhgd,bhkd->bhgk", qf, k) / math.sqrt(d)
    mask = jnp.arange(k.shape[2])[None, :] < lengths[:, None]  # (B, S)
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", p, v)
    return o.reshape(b, hq, d).astype(q.dtype)
