"""Paper Fig. 22: prefetcher accuracy/coverage per workload — and the fix.

Part 1 reproduces the paper's finding with the hardware-style software
prefetcher on each workload profile's block stream: high accuracy (>75%)
but LOW coverage (<50%) on irregular cloud workloads, near-perfect on
predictable streams (Ads1 / CPU inference).

Part 2 is the paper's §6 payoff measured: on template-walk streams (hot
prompt templates whose page chains are SCATTERED in the id space — the
paged-KV reality), a successor table trained on stream-tagged trace
windows from a disjoint training segment beats both hardware-style
baselines on coverage while wasting no more bandwidth. All stats are
FINALIZED: blocks resident-but-unused at the end of the run are charged
as waste, so accuracy is not inflated by run-end residency.

Self-checks (the PR's acceptance bar) assert the trace predictor's
coverage strictly beats nextline and markov on every workload at
equal-or-lower bandwidth overhead.
"""
import numpy as np

from repro.core.memtrace import TraceWindow
from repro.core.prefetch import PrefetchEngine, train_successors

from _common import (
    ALL_WORKLOADS,
    fmt_table,
    score_prefetcher,
    stream_for,
    template_stream_for,
)

BW_EPS = 0.02  # slack on the bandwidth-overhead comparison (tail effects)


def _trained_table(blocks, lanes):
    w = TraceWindow(0, blocks, np.zeros(blocks.size, bool), lanes)
    return train_successors([w])


def template_comparison(workloads=("Web1", "Ads1", "Cache1", "Feed", "Reader"), n=24_000):
    """Train on the leading 3/4 of each template stream (the fleet's
    accumulated trace history), score every predictor on the trailing 1/4
    (markov/nextline train online during evaluation, exactly like the
    hardware they model). The wide template set means an online table
    keeps paying its two-sightings-per-transition cold start on the tail
    templates inside the scoring window, while trained successors cover
    a chain's first evaluation appearance — the fleet-history advantage.
    """
    out = {}
    for name in workloads:
        blocks, lanes, _ = template_stream_for(name, n=n, n_templates=48)
        split = 3 * n // 4
        table = _trained_table(blocks[:split], lanes[:split])
        ev_b, ev_l = blocks[split:], lanes[split:]
        out[name] = {
            p: score_prefetcher(ev_b, ev_l, p) for p in ("nextline", "markov")
        }
        out[name]["trace"] = score_prefetcher(ev_b, ev_l, "trace", table=table)
    return out


def main(predictor="nextline"):
    rows = []
    out = {}
    for name in ALL_WORKLOADS:
        stream, prof = stream_for(name, n=12_000)
        eng = PrefetchEngine(predictor=predictor, buffer_blocks=256, degree=1)
        for b in stream:
            eng.access(int(b), is_far=True)
        s = eng.finalized_stats()
        rows.append((name, f"{s.accuracy*100:5.1f}%", f"{s.coverage*100:5.1f}%", f"{s.bw_overhead*100:5.1f}%"))
        out[name] = (s.accuracy, s.coverage)
    # the predictable sequential stream (Ads1-like CPU inference analogue)
    eng = PrefetchEngine(predictor="nextline", buffer_blocks=128, degree=4)
    for b in np.tile(np.arange(512), 8):
        eng.access(int(b), is_far=True)
    s = eng.finalized_stats()
    rows.append(("sequential(KV walk)", f"{s.accuracy*100:5.1f}%", f"{s.coverage*100:5.1f}%", f"{s.bw_overhead*100:5.1f}%"))
    print(f"[fig22] far-tier prefetcher accuracy/coverage (predictor={predictor})")
    print(fmt_table(rows, ["workload", "accuracy", "coverage", "bw overhead"]))
    print("paper: accuracy >75%, coverage <50% for most services; regular streams prefetch well")

    # -- part 2: trace-trained successor table vs the hardware baselines
    comp = template_comparison()
    rows = []
    for name, res in comp.items():
        for p in ("nextline", "markov", "trace"):
            s = res[p]
            rows.append(
                (
                    name if p == "nextline" else "",
                    p,
                    f"{s.accuracy*100:5.1f}%",
                    f"{s.coverage*100:5.1f}%",
                    f"{s.bw_overhead*100:5.1f}%",
                    s.unused_evicted,
                )
            )
        tr, nl, mk = res["trace"], res["nextline"], res["markov"]
        assert tr.coverage > nl.coverage, (name, tr.coverage, nl.coverage)
        assert tr.coverage > mk.coverage, (name, tr.coverage, mk.coverage)
        assert tr.bw_overhead <= nl.bw_overhead + BW_EPS, (name, tr.bw_overhead, nl.bw_overhead)
        assert tr.bw_overhead <= mk.bw_overhead + BW_EPS, (name, tr.bw_overhead, mk.bw_overhead)
        out[f"template:{name}"] = {p: (res[p].accuracy, res[p].coverage) for p in res}
    print("\n[fig22b] template-walk streams: trace-trained table vs hardware baselines")
    print(fmt_table(rows, ["workload", "predictor", "accuracy", "coverage", "bw overhead", "wasted pages"]))
    print("trace training closes the coverage gap at equal-or-lower waste (self-checked)")
    return out


if __name__ == "__main__":
    main()
