"""Virtual-time event scheduler: the fleet's clock without the barrier.

Lockstep stepping (``FleetRouter.step`` calling every replica once per
global tick) encodes a hidden assumption the paper's fleet data refutes:
that all hosts are equally fast. Per-host heterogeneity is first-order at
hyperscale — one 4x-slow host must cost the fleet one slow *replica*, not a
4x-slow *barrier*. This module provides the discrete-event core that makes
stragglers a scenario instead of a bug: each replica runs on its own clock,
posts a completion event when its step's virtual-time cost elapses, and the
router dispatches queued work the moment capacity frees.

Determinism is the design constraint: events execute in
``(time, priority, seq)`` order, where ``seq`` is posting order — there is
no wall clock, no thread, no hash-order anywhere, so a seeded run replays
exactly. With homogeneous step costs the event schedule degenerates to the
lockstep schedule (completions for all busy replicas land on the same
timestamp, in replica order), which is what lets the router guarantee
bit-exact equivalence with the legacy lockstep mode.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, List, Optional

# Priorities order same-timestamp events the way one lockstep iteration
# orders its phases: step completions retire work and free slots first,
# then open-loop arrivals are offered to admission. Dispatch is not an
# event — it runs in the quiescent hook after every batch.
COMPLETION = 0
ARRIVAL = 1


@dataclasses.dataclass(order=True)
class Event:
    time: float
    prio: int
    seq: int
    action: Callable[[], None] = dataclasses.field(compare=False)


class VirtualScheduler:
    """Ordered event heap over virtual time.

    ``run`` drains events in (time, prio, seq) order. All events sharing a
    timestamp form one *batch*; after each batch the ``quiescent`` callback
    runs once — that is where the fleet router fires its hooks, dispatches
    from the weighted-fair tenant queues into freed slots, and starts new
    replica steps (posting their completion events). Actions may post
    further events, including at the current timestamp.
    """

    def __init__(self):
        self._heap: List[Event] = []
        self._seq = itertools.count()
        self.now = 0.0
        self.events_run = 0
        self.batches = 0  # quiescent batches (same-timestamp event groups)

    def post(self, time: float, action: Callable[[], None], prio: int = COMPLETION):
        if time < self.now:
            raise ValueError(f"event scheduled in the past: {time} < {self.now}")
        heapq.heappush(self._heap, Event(float(time), prio, next(self._seq), action))

    @property
    def pending(self) -> int:
        return len(self._heap)

    def run(
        self,
        until: float = float("inf"),
        quiescent: Optional[Callable[[float], None]] = None,
        max_events: int = 10_000_000,
    ) -> float:
        """Drain events with time <= ``until``; returns final virtual time."""
        while self._heap and self._heap[0].time <= until:
            t = self._heap[0].time
            self.now = t
            while self._heap and self._heap[0].time == t:
                ev = heapq.heappop(self._heap)
                self.events_run += 1
                if self.events_run > max_events:
                    raise RuntimeError("VirtualScheduler runaway: max_events exceeded")
                ev.action()
            self.batches += 1
            if quiescent is not None:
                quiescent(t)
        return self.now
