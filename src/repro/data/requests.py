"""Serving request generator driven by the nine workload profiles.

Web-like profiles draw most prompts from a shared prefix pool (the paper's
"cores run the same code" in request form: many requests, same template),
cache-like profiles are Zipf-skewed point lookups, Reader is long-prompt
backend-bound. Deterministic per (profile, seed, index).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Sequence

import numpy as np

from repro.configs.workloads import WorkloadProfile


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray  # prompt token ids (int32)
    decode_len: int
    prefix_id: int  # -1 if unique prompt
    arrival: float
    tenant: str = "default"  # service identity for multi-tenant fleets


@dataclasses.dataclass
class ChunkState:
    """Prefill progress of one admitted request under chunked prefill.

    The continuous-batching engine splits a prompt into fixed-token-budget
    chunks interleaved with decode inside the same segmented dispatch; this
    tracks how far the prompt has been fed. ``pos`` counts tokens already
    written into the slot's KV cache; the request leaves the prefill phase
    when ``done`` (its first generated token is emitted by the same step
    that consumed the final prompt token).
    """

    tokens: np.ndarray  # the (possibly truncated) prompt being prefilled
    pos: int = 0  # prompt tokens already prefilled into the slot

    @property
    def total(self) -> int:
        return len(self.tokens)

    @property
    def remaining(self) -> int:
        return self.total - self.pos

    @property
    def done(self) -> bool:
        return self.pos >= self.total

    def take(self, budget: int) -> np.ndarray:
        """Next chunk of at most ``budget`` tokens (does NOT advance ``pos``;
        the engine advances only after the dispatch lands)."""
        assert budget > 0, budget
        return self.tokens[self.pos : self.pos + budget]


class RequestGenerator:
    def __init__(
        self,
        profile: WorkloadProfile,
        vocab_size: int,
        seed: int = 0,
        rate: float = 8.0,
        tenant: Optional[str] = None,
    ):
        self.p = profile
        self.vocab = vocab_size
        self.tenant = tenant if tenant is not None else "default"
        self.rng = np.random.default_rng(seed)
        self.rate = rate
        self._prefixes = [
            self.rng.integers(0, vocab_size, size=max(8, int(profile.prompt_mean * 0.75)))
            .astype(np.int32)
            for _ in range(profile.n_prefixes)
        ]
        # Zipf over prefixes too: hot templates dominate (Web1's correlation)
        ranks = np.arange(1, profile.n_prefixes + 1, dtype=np.float64)
        pz = ranks ** -max(profile.zipf_alpha, 0.5)
        self._prefix_probs = pz / pz.sum()
        self._next_id = 0
        self._clock = 0.0

    def __iter__(self) -> Iterator[Request]:
        return self

    def __next__(self) -> Request:
        p = self.p
        self._clock += float(self.rng.exponential(1.0 / self.rate))
        rid = self._next_id
        self._next_id += 1
        if self.rng.random() < p.prefix_share:
            pid = int(self.rng.choice(p.n_prefixes, p=self._prefix_probs))
            suffix_len = max(1, int(self.rng.exponential(p.prompt_mean * 0.25)))
            suffix = self.rng.integers(0, self.vocab, size=suffix_len).astype(np.int32)
            tokens = np.concatenate([self._prefixes[pid], suffix])
        else:
            pid = -1
            n = max(4, int(self.rng.exponential(p.prompt_mean)))
            tokens = self.rng.integers(0, self.vocab, size=n).astype(np.int32)
        decode_len = max(1, int(self.rng.exponential(p.decode_mean)))
        return Request(rid, tokens, decode_len, pid, self._clock, self.tenant)

    def block_stream(
        self,
        n: int,
        n_blocks: Optional[int] = None,
        n_streams: int = 4,
        return_lanes: bool = False,
    ) -> np.ndarray:
        """State-block access stream for this service — MemProf.MemBW's
        sampled miss stream.

        Structure mirrors a serving engine's memory behavior: ``n_streams``
        concurrent sequences each walk blocks SEQUENTIALLY (a KV page walk)
        and re-seed at a Zipf-hot block with probability ``seq_jump`` —
        low-jump services (Ads1, CPU inference) are stream-prefetchable,
        high-jump ones (Cache1/2 key-value lookups) are not (Fig. 21/22).

        ``return_lanes=True`` also returns the per-access lane (stream) id —
        the per-stream tag a trace-driven prefetcher trains on; without it a
        consumer sees the interleaved aggregate, which is exactly the
        mistraining hazard core/prefetch.py documents.
        """
        nb = n_blocks or self.p.n_blocks
        ranks = np.arange(1, nb + 1, dtype=np.float64)
        probs = ranks ** -self.p.zipf_alpha
        probs /= probs.sum()
        perm = np.random.default_rng(hash(self.p.name) % 2**31).permutation(nb)
        seeds = perm[self.rng.choice(nb, size=n, p=probs)]  # zipf-hot restarts
        pos = seeds[: n_streams].astype(np.int64).copy()
        jump = self.rng.random(n) < self.p.seq_jump
        lane = self.rng.integers(0, n_streams, n)
        out = np.empty(n, np.int64)
        for i in range(n):
            s = lane[i]
            if jump[i]:
                pos[s] = seeds[i]
            else:
                pos[s] = (pos[s] + 1) % nb
            out[i] = pos[s]
        if return_lanes:
            return out, lane.astype(np.int64)
        return out

    def template_stream(
        self,
        n: int,
        n_blocks: Optional[int] = None,
        n_templates: int = 8,
        template_len: int = 12,
        suffix_len: int = 4,
        n_streams: int = 4,
        phases: int = 1,
    ):
        """Paged-KV template walk: the stream shape trace-driven prefetch wins.

        Real serving traffic re-walks hot prompt TEMPLATES: a request reads
        its template's page chain, then a short private suffix. Crucially
        the chain's physical page ids are SCATTERED — the pagetable
        allocated them whenever the template first appeared, so consecutive
        chain pages are not consecutive ids. A nextline/stride prefetcher
        gets ~nothing from the chain (the successor of page 731 is page 88),
        an online markov table must re-learn every chain per run under its
        confidence gates, but a successor table trained on stream-tagged
        trace windows covers every repeat of a chain seen anywhere in the
        fleet. Suffix pages are private and unpredictable for everyone —
        they keep accuracy honest.

        ``phases > 1`` re-draws template popularity every ``n/phases``
        accesses (the phase-shifting workload of the tiered-decode bench):
        hotness moves but the CHAINS persist, so trained successors stay
        valid across phases while pure-hotness placement lags each shift.

        Returns ``(blocks, lanes)`` — int64 arrays; ``lanes`` tags each
        access with its stream (decode slot analogue).
        """
        nb = n_blocks or self.p.n_blocks
        need = n_templates * template_len
        assert need < nb, "template chains must fit the block space"
        perm = self.rng.permutation(nb)
        chains = perm[:need].reshape(n_templates, template_len)
        pool = perm[need:]
        ranks = np.arange(1, n_templates + 1, dtype=np.float64)
        pz = ranks ** -max(self.p.zipf_alpha, 0.8)
        pz /= pz.sum()
        order = np.arange(n_templates)
        phase_len = max(1, n // max(1, phases))
        out = np.empty(n, np.int64)
        lanes = np.empty(n, np.int64)
        cur = [np.empty(0, np.int64) for _ in range(n_streams)]
        pos = [0] * n_streams
        for i in range(n):
            if phases > 1 and i > 0 and i % phase_len == 0:
                # popularity rotates; the chains themselves persist
                order = self.rng.permutation(n_templates)
            lane = int(self.rng.integers(0, n_streams))
            if pos[lane] >= cur[lane].size:
                t = int(order[self.rng.choice(n_templates, p=pz)])
                sfx = self.rng.choice(pool, size=suffix_len, replace=False)
                cur[lane] = np.concatenate([chains[t], sfx.astype(np.int64)])
                pos[lane] = 0
            out[i] = cur[lane][pos[lane]]
            lanes[i] = lane
            pos[lane] += 1
        return out, lanes


def interleave(gens: Sequence[RequestGenerator], n: int) -> List[Request]:
    """Merge ``n`` requests from several tenant generators by arrival time.

    The co-location traffic model: each tenant keeps its own Poisson clock
    and the fleet sees the time-ordered merge. Request ids are reassigned so
    sequence ids stay unique fleet-wide, and shared-prefix ids are namespaced
    per tenant so one tenant's hot template can't alias another's in
    prefix-affinity routing. Deterministic given the generators' seeds.
    """
    heads = [next(g) for g in gens]
    out: List[Request] = []
    for rid in range(n):
        g = min(range(len(gens)), key=lambda i: (heads[i].arrival, i))
        req = heads[g]
        pid = req.prefix_id if req.prefix_id < 0 else req.prefix_id * len(gens) + g
        out.append(dataclasses.replace(req, rid=rid, prefix_id=pid))
        heads[g] = next(gens[g])
    return out
