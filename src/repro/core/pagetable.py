"""Shared KV page table — the paper's multi-ASID shared L2 I-TLB, in KV form.

The paper lets one TLB entry carry multiple ASIDs so processes running the
same code share translations. Here one PHYSICAL KV page can be mapped by
multiple SEQUENCES (the entry's "ASID list" is its refcount + owner set):
common prompt prefixes are detected by a chunk-hash chain and mapped to the
same physical page, deduplicating both capacity and the prefill bandwidth of
recomputing shared prefixes.

Pages also carry a tier bit (near=HBM / far=host), making this table the
single source of truth for the serving engine's placement + the dense
page-table array consumed by kernels/paged_attention.

Copy-on-write: appending into a partially-filled SHARED page forks it first
(same rule as a TLB entry split on ASID divergence).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

NEAR, FAR = 0, 1


@dataclasses.dataclass
class PhysPage:
    pid: int
    ref: int = 0
    tier: int = NEAR
    chain_hash: Optional[int] = None  # prefix-identity of a FULL page
    fill: int = 0  # tokens written (== page_size when full)


class SharedKVPageTable:
    def __init__(self, n_pages: int, page_size: int):
        self.page_size = page_size
        self.n_pages = n_pages
        self.pages: List[PhysPage] = [PhysPage(i) for i in range(n_pages)]
        self.free: List[int] = list(range(n_pages - 1, -1, -1))
        self.seqs: Dict[int, List[int]] = {}  # seq id -> [phys page ids]
        self.seq_len: Dict[int, int] = {}
        self.chains: Dict[int, int] = {}  # chain_hash -> phys id (full pages)
        # counters
        self.shared_mappings = 0  # pages shared instead of allocated (TLB "hits")
        self.cow_copies = 0
        self.alloc_count = 0

    # ------------------------------------------------------------------
    def _alloc(self) -> int:
        if not self.free:
            raise MemoryError("KV page pool exhausted")
        pid = self.free.pop()
        pg = self.pages[pid]
        pg.ref = 1
        pg.chain_hash = None
        pg.fill = 0
        pg.tier = NEAR
        self.alloc_count += 1
        return pid

    def _decref(self, pid: int):
        pg = self.pages[pid]
        pg.ref -= 1
        if pg.ref == 0:
            if pg.chain_hash is not None:
                self.chains.pop(pg.chain_hash, None)
            pg.chain_hash = None
            self.free.append(pid)

    @staticmethod
    def _chain(prev: int, tokens: Sequence[int]) -> int:
        return hash((prev,) + tuple(int(t) for t in tokens))

    # ------------------------------------------------------------------
    def add_sequence(self, seq_id: int, tokens: Sequence[int]) -> dict:
        """Map a new sequence; share full prefix pages when the chunk-hash
        chain matches an existing resident page. Returns sharing stats.

        Only fully-filled pages are sharable (a partial tail page is private).
        """
        assert seq_id not in self.seqs
        ps = self.page_size
        pages: List[int] = []
        shared = 0
        chain = 0
        n_full = len(tokens) // ps
        for i in range(n_full):
            chunk = tokens[i * ps : (i + 1) * ps]
            chain = self._chain(chain, chunk)
            pid = self.chains.get(chain)
            if pid is not None and self.pages[pid].ref > 0:
                self.pages[pid].ref += 1
                shared += 1
                self.shared_mappings += 1
            else:
                pid = self._alloc()
                self.pages[pid].fill = ps
                self.pages[pid].chain_hash = chain
                self.chains[chain] = pid
            pages.append(pid)
        rem = len(tokens) - n_full * ps
        if rem:
            pid = self._alloc()
            self.pages[pid].fill = rem
            pages.append(pid)
        self.seqs[seq_id] = pages
        self.seq_len[seq_id] = len(tokens)
        return {"pages": len(pages), "shared": shared, "new": len(pages) - shared}

    def append_token(self, seq_id: int) -> int:
        """Advance a sequence by one decoded token; returns the physical page
        written (with copy-on-write if the tail page is shared)."""
        pages = self.seqs[seq_id]
        pos = self.seq_len[seq_id]
        if pos % self.page_size == 0:  # need a fresh page
            pid = self._alloc()
            pages.append(pid)
        else:
            pid = pages[-1]
            pg = self.pages[pid]
            if pg.ref > 1:  # COW fork
                new = self._alloc()
                self.pages[new].fill = pg.fill
                self._decref(pid)
                pages[-1] = new
                pid = new
                self.cow_copies += 1
        self.pages[pid].fill = pos % self.page_size + 1
        self.seq_len[seq_id] = pos + 1
        return pid

    def free_sequence(self, seq_id: int):
        for pid in self.seqs.pop(seq_id):
            self._decref(pid)
        self.seq_len.pop(seq_id)

    # ------------------------------------------------------------------
    def dense_table(self, seq_ids: Sequence[int], pages_per_seq: int) -> np.ndarray:
        """(B, pages_per_seq) int32 physical-page table for the kernel."""
        out = np.zeros((len(seq_ids), pages_per_seq), np.int32)
        for i, sid in enumerate(seq_ids):
            pl = self.seqs[sid][:pages_per_seq]
            out[i, : len(pl)] = pl
        return out

    def lengths(self, seq_ids: Sequence[int]) -> np.ndarray:
        return np.array([self.seq_len[s] for s in seq_ids], np.int32)

    # ------------------------------------------------------------------
    def set_tier(self, pid: int, tier: int):
        self.pages[pid].tier = tier

    def tier_of(self, pids) -> np.ndarray:
        return np.array([self.pages[p].tier for p in np.asarray(pids).reshape(-1)], np.int8)

    @property
    def used_pages(self) -> int:
        return self.n_pages - len(self.free)

    def stats(self) -> dict:
        refs = [p.ref for p in self.pages if p.ref > 0]
        return {
            "used_pages": self.used_pages,
            "free_pages": len(self.free),
            "shared_mappings": self.shared_mappings,
            "cow_copies": self.cow_copies,
            "max_ref": max(refs, default=0),
            "alloc_count": self.alloc_count,
            "dedup_ratio": (self.shared_mappings + self.used_pages) / max(self.used_pages, 1),
        }
