"""Fleet scaling study: throughput vs replica count x router policy.

For each (n_replicas, policy) cell the same Web1-like traffic (high shared-
template rate — the paper's "same code everywhere" in request form) is
served and scored with the fleet cost model. The spread between
prefix-affinity and round-robin at a given width is the fleet-level value
of the shared page table; the stitched-trace validation column is the
Table 6 check run at fleet scale.
"""
import dataclasses

from repro.configs.workloads import get_profile
from repro.data.requests import RequestGenerator
from repro.fleet import build_fleet, export_all, fleet_vocab, validate_fleet

from _common import fmt_table

POLICIES = ("round-robin", "least-loaded", "prefix-affinity")
WIDTHS = (1, 2, 4)


def run_cell(n_replicas: int, policy: str, n_requests: int = 16, seed: int = 0):
    fleet = build_fleet(
        n_replicas,
        policy=policy,
        trace_window=16,
        trace_period=32,
        autotier=dict(near_frac=0.30, epoch_steps=16),
        seed=seed,
    )
    prof = dataclasses.replace(
        get_profile("Web1"), prompt_mean=24, decode_mean=6, prefix_share=0.9, n_prefixes=3
    )
    gen = RequestGenerator(prof, vocab_size=fleet_vocab(), seed=seed)
    stats = fleet.run(gen, n_requests=n_requests, max_steps=600, submit_per_step=2)
    val = validate_fleet(export_all(fleet.replicas))
    return stats, val


def main():
    rows = []
    best = {}
    for width in WIDTHS:
        for policy in POLICIES:
            stats, val = run_cell(width, policy)
            rows.append(
                (
                    width,
                    policy,
                    f"{stats['simulated_throughput']:.3f}",
                    stats["prefill_tokens_saved"],
                    stats["shared_mappings"],
                    f"{stats['near_hit_rate']:.3f}",
                    f"{val['hit_ratio_error']*100:.2f}%",
                    f"{abs(val['rw_ratio_error_pct']):.2f}%",
                )
            )
            best[(width, policy)] = stats["simulated_throughput"]
    print("fleet scaling: simulated throughput by replica count x router policy")
    print(
        fmt_table(
            rows,
            ("replicas", "policy", "sim-tput", "prefill-saved", "shared-maps", "near-hit", "trace-hit-err", "trace-rw-err"),
        )
    )
    w = max(WIDTHS)
    gain = best[(w, "prefix-affinity")] / max(best[(w, "round-robin")], 1e-9)
    print(f"\nprefix-affinity vs round-robin at {w} replicas: {gain:.2f}x")
    if gain <= 1.0:
        print("fleet_bench: FAIL (affinity did not beat round-robin)")
        return 1
    print("fleet_bench ok")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
