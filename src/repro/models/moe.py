"""Mixture-of-Experts LM (granite-moe / qwen2-moe).

Routing is top-k with capacity-bounded dispatch. Two dispatch backends:

* ``einsum`` — GShard-style one-hot dispatch/combine einsums. Partitions
  robustly under GSPMD (the dispatch einsum becomes the all-to-all), but XLA
  counts the one-hot matmuls as real FLOPs, inflating cost_analysis.
* ``sort`` — sort token-slots by expert, scatter into an (E, C, D) buffer,
  run the expert FFNs as one batched einsum, gather back. No fake FLOPs
  (this is the beyond-paper §Perf candidate for compute-bound MoE cells).

Expert sharding: expert-dim EP when n_experts % model_axis == 0, else the
expert FFN hidden dim is sharded over MODEL (TP-for-MoE) — both granite (40)
and qwen2-moe (60) take the TP path on a 16-way model axis. Router decisions
double as the access stream for expert tiering (core/: the paper's hot-page
skew shows up as routing skew).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.mesh import BATCH, MODEL, shard
from repro.models import attention, common

Array = jax.Array


# ---------------------------------------------------------------------------
# init


def _init_layer(key, cfg: ModelConfig, dtype) -> dict:
    ka, kr, ke, ks, kg = jax.random.split(key, 5)
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    k1, k2, k3 = jax.random.split(ke, 3)
    p = {
        "ln1": jnp.ones((d,), dtype),
        "ln2": jnp.ones((d,), dtype),
        "attn": attention.init(ka, cfg, dtype),
        "router": common.dense_init(kr, (d, e), dtype=jnp.float32),
        "experts": {
            "w_gate": common.dense_init(k1, (e, d, f), in_axis=1, dtype=dtype),
            "w_up": common.dense_init(k2, (e, d, f), in_axis=1, dtype=dtype),
            "w_down": common.dense_init(
                k3, (e, f, d), in_axis=1, scale=1.0 / (2 * cfg.n_layers) ** 0.5, dtype=dtype
            ),
        },
    }
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        s1, s2, s3 = jax.random.split(ks, 3)
        p["shared"] = {
            "w_gate": common.dense_init(s1, (d, fs), dtype=dtype),
            "w_up": common.dense_init(s2, (d, fs), dtype=dtype),
            "w_down": common.dense_init(
                s3, (fs, d), scale=1.0 / (2 * cfg.n_layers) ** 0.5, dtype=dtype
            ),
            "gate": common.dense_init(kg, (d, 1), dtype=dtype),
        }
    return p


def init(key, cfg: ModelConfig) -> dict:
    dtype = common.dt(cfg.param_dtype)
    ke, kl, kh = jax.random.split(key, 3)
    layers = jax.vmap(lambda k: _init_layer(k, cfg, dtype))(jax.random.split(kl, cfg.n_layers))
    params = {
        "embed": common.embed_init(ke, (cfg.padded_vocab, cfg.d_model), dtype),
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = common.dense_init(kh, (cfg.d_model, cfg.padded_vocab), dtype=dtype)
    return params


def layer_specs(cfg: ModelConfig, model_axis: int = 16) -> dict:
    ep = cfg.n_experts % model_axis == 0  # expert-parallel when divisible
    if ep:
        experts = {"w_gate": (MODEL, None, None), "w_up": (MODEL, None, None), "w_down": (MODEL, None, None)}
    else:  # TP-for-MoE: shard the expert hidden dim
        experts = {"w_gate": (None, None, MODEL), "w_up": (None, None, MODEL), "w_down": (None, MODEL, None)}
    lyr = {
        "ln1": (None,),
        "ln2": (None,),
        "attn": attention.param_specs(cfg),
        "router": (None, None),
        "experts": experts,
    }
    if cfg.n_shared_experts:
        lyr["shared"] = {
            "w_gate": (None, MODEL),
            "w_up": (None, MODEL),
            "w_down": (MODEL, None),
            "gate": (None, None),
        }
    return lyr


def param_specs(cfg: ModelConfig, model_axis: int = 16) -> dict:
    lyr = jax.tree.map(
        lambda s: (None,) + tuple(s), layer_specs(cfg, model_axis), is_leaf=lambda s: isinstance(s, tuple)
    )
    specs = {"embed": (MODEL, None), "layers": lyr, "final_norm": (None,)}
    if not cfg.tie_embeddings:
        specs["lm_head"] = (None, MODEL)
    return specs


# ---------------------------------------------------------------------------
# routing


def _route(router_w: Array, cfg: ModelConfig, xg: Array):
    """xg: (G, T, D) -> (topv, topi, probs). topv renormalized over top_k."""
    logits = jnp.einsum(
        "gtd,de->gte", xg.astype(jnp.float32), router_w.astype(jnp.float32)
    )  # (G,T,E) f32
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, cfg.top_k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    return topv, topi, probs


def aux_losses(probs: Array, topi: Array, cfg: ModelConfig):
    """GShard load-balance loss + router z-loss. probs (G,T,E), topi (G,T,k)."""
    e = cfg.n_experts
    frac = jnp.mean(jax.nn.one_hot(topi, e, dtype=jnp.float32), axis=(1, 2))  # (G,E)
    imp = jnp.mean(probs, axis=1)  # (G,E)
    lb = e * jnp.mean(jnp.sum(frac * imp, axis=-1))
    return cfg.router_aux_coef * lb


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    c = int(tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(4, -(-c // 4) * 4)


def _expert_ffn(experts: dict, xs: Array) -> Array:
    """xs: (G, E, C, D) -> (G, E, C, D)."""
    g = jnp.einsum("gecd,edf->gecf", xs, experts["w_gate"], preferred_element_type=jnp.float32)
    u = jnp.einsum("gecd,edf->gecf", xs, experts["w_up"], preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(xs.dtype)
    return jnp.einsum("gecf,efd->gecd", h, experts["w_down"], preferred_element_type=jnp.float32).astype(xs.dtype)


def moe_einsum(p: dict, cfg: ModelConfig, xg: Array):
    """GShard dispatch. xg: (G, T, D) -> (out, aux_loss)."""
    gdim, t, d = xg.shape
    e, k = cfg.n_experts, cfg.top_k
    c = _capacity(t, cfg)
    topv, topi, probs = _route(p["router"], cfg, xg)
    oh = jax.nn.one_hot(topi, e, dtype=jnp.float32)  # (G,T,k,E)
    # position of each slot within its expert: cumsum over (T,k) in slot order
    ohf = oh.reshape(gdim, t * k, e)
    pos = jnp.cumsum(ohf, axis=1) - ohf  # (G,T*k,E)
    slot_pos = jnp.sum(pos * ohf, axis=-1).reshape(gdim, t, k)  # (G,T,k)
    keep = (slot_pos < c).astype(jnp.float32)
    cap_oh = jax.nn.one_hot(slot_pos.astype(jnp.int32), c, dtype=jnp.float32)  # (G,T,k,C)
    disp = jnp.einsum("gtke,gtkc->gtec", oh * keep[..., None], cap_oh)  # (G,T,E,C)
    comb = jnp.einsum("gtke,gtkc->gtec", (oh * (topv * keep)[..., None]), cap_oh)
    xs = jnp.einsum(
        "gtec,gtd->gecd", disp.astype(xg.dtype), xg, preferred_element_type=jnp.float32
    ).astype(xg.dtype)
    ys = _expert_ffn(p["experts"], xs)
    out = jnp.einsum(
        "gtec,gecd->gtd", comb.astype(ys.dtype), ys, preferred_element_type=jnp.float32
    ).astype(xg.dtype)
    return out, aux_losses(probs, topi, cfg)


def moe_sort(p: dict, cfg: ModelConfig, xg: Array):
    """Sort-based dispatch — no one-hot matmul FLOPs. xg: (G, T, D)."""
    gdim, t, d = xg.shape
    e, k = cfg.n_experts, cfg.top_k
    c = _capacity(t, cfg)
    topv, topi, probs = _route(p["router"], cfg, xg)

    def one_group(x, ti, tv):
        flat_e = ti.reshape(t * k)
        flat_w = tv.reshape(t * k)
        order = jnp.argsort(flat_e, stable=True)  # slots sorted by expert
        se = flat_e[order]
        counts = jnp.bincount(flat_e, length=e)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(t * k) - starts[se]
        keep = pos < c
        dest = jnp.where(keep, se * c + pos, e * c)  # drop slot -> scratch row
        tok = order // k
        buf = jnp.zeros((e * c + 1, d), x.dtype).at[dest].set(x[tok])
        ys = _expert_ffn(
            {k_: w[None] if w.ndim == 2 else w for k_, w in p["experts"].items()},
            buf[: e * c].reshape(1, e, c, d),
        )[0].reshape(e * c, d)
        y_slot = ys[jnp.minimum(dest, e * c - 1)] * (keep * flat_w[order])[:, None].astype(x.dtype)
        return jnp.zeros((t, d), x.dtype).at[tok].add(y_slot)

    out = jax.vmap(one_group)(xg, topi, topv)
    return out, aux_losses(probs, topi, cfg)


def _shared_ffn(p: dict, x: Array) -> Array:
    s = p["shared"]
    gate = jax.nn.sigmoid(
        jnp.einsum("gtd,do->gto", x.astype(jnp.float32), s["gate"].astype(jnp.float32))
    )
    y = common.swiglu(x, s["w_gate"], s["w_up"], s["w_down"])
    return (y.astype(jnp.float32) * gate).astype(x.dtype)


def moe_ffn(p: dict, cfg: ModelConfig, x: Array, dispatch: Optional[str] = None):
    """x: (B, S, D) -> (out, aux). Routed per batch row (group = row).

    Long sequences are re-grouped to ``cfg.moe_group`` tokens per routing
    group first: GShard capacity state is O(k * t^2) PER GROUP, so a 32k
    prefill in one group is ~16x more dispatch state than 16 groups of 2k.
    """
    dispatch = dispatch or cfg.moe_dispatch
    fn = moe_einsum if dispatch == "einsum" else moe_sort
    g0, t0, d0 = x.shape
    grp = cfg.moe_group
    if grp and t0 > grp and t0 % grp == 0:
        x = x.reshape(g0 * (t0 // grp), grp, d0)
    out, aux = fn(p, cfg, x)
    out = out.reshape(g0, t0, d0)
    if cfg.n_shared_experts:
        out = out + _shared_ffn(p, x.reshape(g0, t0, d0))
    return out, aux


# ---------------------------------------------------------------------------
# full LM (mirrors transformer.py; MLP -> MoE)


def forward(
    params: dict,
    cfg: ModelConfig,
    tokens: Optional[Array] = None,
    embeds: Optional[Array] = None,
    positions: Optional[Array] = None,
    *,
    remat: Optional[bool] = None,
    block_k: int = 1024,
    dispatch: Optional[str] = None,
):
    """Returns (logits, aux_loss_sum)."""
    from repro.models import transformer as _t

    h = _t._embed_in(params, cfg, tokens, embeds)
    b, l, _ = h.shape
    if positions is None:
        positions = common.causal_positions(b, l)

    def block(carry, layer):
        h, aux = carry
        layer = common.constrain_tree(layer, layer_specs(cfg), common.dt(cfg.compute_dtype))
        x = common.rms_norm(h, layer["ln1"], cfg.norm_eps)
        h = h + attention.apply_train(layer["attn"], cfg, x, positions, block_k=block_k)
        x = common.rms_norm(h, layer["ln2"], cfg.norm_eps)
        y, a = moe_ffn(layer, cfg, x, dispatch)
        return (shard(h + y, BATCH, None, None), aux + a), None

    use_remat = cfg.remat if remat is None else remat
    blk = common.maybe_remat(lambda c, lp: block(c, lp)[0], use_remat, cfg.remat_policy)
    (h, aux), _ = jax.lax.scan(lambda c, lp: (blk(c, lp), None), (h, jnp.zeros((), jnp.float32)), params["layers"])
    return _t._logits_out(params, cfg, h), aux


def features(
    params: dict,
    cfg: ModelConfig,
    tokens: Optional[Array] = None,
    embeds: Optional[Array] = None,
    positions: Optional[Array] = None,
    *,
    remat: Optional[bool] = None,
    block_k: int = 1024,
    dispatch: Optional[str] = None,
):
    """Trunk -> (post-norm h, head weight, aux loss) for the fused CE path."""
    from repro.models import transformer as _t

    h = _t._embed_in(params, cfg, tokens, embeds)
    b, l, _ = h.shape
    if positions is None:
        positions = common.causal_positions(b, l)

    def block(carry, layer):
        h, aux = carry
        layer = common.constrain_tree(layer, layer_specs(cfg), common.dt(cfg.compute_dtype))
        x = common.rms_norm(h, layer["ln1"], cfg.norm_eps)
        h = h + attention.apply_train(layer["attn"], cfg, x, positions, block_k=block_k)
        x = common.rms_norm(h, layer["ln2"], cfg.norm_eps)
        y, a = moe_ffn(layer, cfg, x, dispatch)
        return (shard(h + y, BATCH, None, None), aux + a), None

    use_remat = cfg.remat if remat is None else remat
    blk = common.maybe_remat(lambda c, lp: block(c, lp)[0], use_remat, cfg.remat_policy)
    (h, aux), _ = jax.lax.scan(lambda c, lp: (blk(c, lp), None), (h, jnp.zeros((), jnp.float32)), params["layers"])
    h = common.rms_norm(h, params["final_norm"], cfg.norm_eps)
    return h, _t._head_w(params, cfg), aux


def prefill(params, cfg: ModelConfig, tokens=None, embeds=None, *, max_len: int, block_k: int = 1024):
    from repro.models import transformer as _t

    h = _t._embed_in(params, cfg, tokens, embeds)
    b, l, _ = h.shape
    positions = common.causal_positions(b, l)

    def block(h, layer):
        layer = common.constrain_tree(layer, layer_specs(cfg), common.dt(cfg.compute_dtype))
        x = common.rms_norm(h, layer["ln1"], cfg.norm_eps)
        a, (kk, vv) = attention.apply_prefill(layer["attn"], cfg, x, positions, max_len, block_k=block_k)
        h = h + a
        x = common.rms_norm(h, layer["ln2"], cfg.norm_eps)
        y, _ = moe_ffn(layer, cfg, x)
        return shard(h + y, BATCH, None, None), (kk, vv)

    h, (ks, vs) = jax.lax.scan(block, h, params["layers"])
    cache = {"k": ks.astype(jnp.bfloat16), "v": vs.astype(jnp.bfloat16), "lengths": jnp.full((b,), l, jnp.int32)}
    return _t._logits_out(params, cfg, h), cache


def decode_step(params, cfg: ModelConfig, cache: dict, tokens: Array):
    from repro.models import transformer as _t

    h = _t._embed_in(params, cfg, tokens)
    lengths = cache["lengths"]
    b = h.shape[0]

    def step(h, xs):
        layer, kc, vc = xs
        layer = common.constrain_tree(layer, layer_specs(cfg), common.dt(cfg.compute_dtype))
        x = common.rms_norm(h, layer["ln1"], cfg.norm_eps)
        a, kc, vc = attention.apply_decode(layer["attn"], cfg, x, kc, vc, lengths)
        h = h + a
        x = common.rms_norm(h, layer["ln2"], cfg.norm_eps)
        # decode: route the whole batch as one group (G=1, T=B)
        y, _ = moe_ffn(layer, cfg, x.reshape(1, b, -1))
        h = h + y.reshape(b, 1, -1)
        return h, (kc, vc)

    h, (ks, vs) = jax.lax.scan(step, h, (params["layers"], cache["k"], cache["v"]))
    logits = _t._logits_out(params, cfg, h)
    return logits, {"k": ks, "v": vs, "lengths": lengths + 1}


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    return attention.init_cache(cfg, cfg.n_layers, batch, max_len, dtype)


def cache_specs(cfg: ModelConfig, model_axis: int = 16) -> dict:
    return attention.cache_specs(cfg, model_axis)
