"""Launch layer: mesh construction, multi-pod dry-run, roofline, drivers.

``dryrun`` must be executed as ``python -m repro.launch.dryrun`` (it sets
XLA_FLAGS before importing jax); nothing imports it from library code.
"""
