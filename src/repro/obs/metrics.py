"""Typed metrics plane: counters, gauges, mergeable exponential histograms.

The paper's profiler is *always on* and fleet-merged (§3: per-host counters
are only representative once aggregated); the repo's telemetry before this
module was the opposite — ad-hoc ``stats()`` dicts recomputed at read time,
with no labels, no time dimension, and no merge law. This registry is the
unified substrate those dicts migrate onto:

* **Counter / Gauge** — plain host ints/floats. Counters are monotone sums,
  so a fleet ``merge`` over per-replica registries is exact (bit-identical
  to the legacy ``fleet_stats`` sums — the acceptance oracle in
  tests/test_obs.py).
* **Histogram** — exponential buckets (``growth`` per bucket, dict-sparse),
  mergeable by bucket-wise addition. Quantiles are deterministic bucket
  upper bounds, so a merged fleet histogram reports the same p99 as the
  union of its inputs — the property ``np.percentile`` over raw sample
  lists never had, and the reason tenant queue-wait p50/p99 moved here.
* **Labels** — every instrument key is (name, sorted label items); the
  conventional dimensions are ``tenant=`` and ``replica=``. A registry may
  carry ``const_labels`` (e.g. ``replica="3"``) applied to every key at
  snapshot/merge time, so engines created before their host rid is known
  still export fully-labeled series.

Device-side series (near/far hits, moved bytes, dispatches, syncs) enter a
registry ONLY from ``drain_counters()`` deltas at the serving engine's
drain boundaries — the registry never adds a dispatch or a host sync to the
decode hot path, and the PR-5 drain-cadence invariant (books bit-identical
at any cadence) extends to every metric here because deltas are pure sums.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Optional, Tuple

Key = Tuple[str, Tuple[Tuple[str, str], ...]]


def _key(name: str, labels: Dict[str, str]) -> Key:
    return (name, tuple(sorted((str(k), str(v)) for k, v in labels.items())))


class Counter:
    """Monotone sum. ``inc`` is one int add — hot-path safe."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1):
        self.value += n


class Gauge:
    """Last-write-wins level; merged by summing (capacities, queue depths)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float):
        self.value = float(v)


class Histogram:
    """Exponential-bucket histogram: sparse, mergeable, deterministic.

    Bucket ``i`` covers ``(growth**(i-1), growth**i]``; values <= 0 land in
    a dedicated zero bucket. ``quantile`` returns the upper bound of the
    bucket holding the rank-``ceil(q*count)`` sample — a value the true
    quantile never exceeds by more than one bucket width (relative error
    <= growth - 1), identical whether computed before or after ``merge``.
    """

    __slots__ = ("growth", "_log_g", "zero", "buckets", "count", "sum", "max")

    def __init__(self, growth: float = 2.0 ** 0.125):
        assert growth > 1.0
        self.growth = float(growth)
        self._log_g = math.log(self.growth)
        self.zero = 0
        self.buckets: Dict[int, int] = {}
        self.count = 0
        self.sum = 0.0
        self.max = 0.0

    def record(self, v: float, n: int = 1):
        v = float(v)
        self.count += n
        self.sum += v * n
        if v > self.max:
            self.max = v
        if v <= 0.0:
            self.zero += n
            return
        # smallest i with growth**i >= v (guard the exact-power boundary)
        i = math.ceil(math.log(v) / self._log_g - 1e-12)
        self.buckets[i] = self.buckets.get(i, 0) + n

    def merge(self, other: "Histogram"):
        assert abs(other.growth - self.growth) < 1e-12, "bucket grids differ"
        self.zero += other.zero
        self.count += other.count
        self.sum += other.sum
        self.max = max(self.max, other.max)
        for i, n in other.buckets.items():
            self.buckets[i] = self.buckets.get(i, 0) + n

    def quantile(self, q: float) -> Optional[float]:
        """Rank-``ceil(q*count)`` bucket upper bound, or ``None`` when the
        histogram is empty. An empty series has NO quantile — reporting 0.0
        made a tenant with no samples indistinguishable from one with
        genuinely zero latency, so consumers must omit (not zero-fill) the
        statistic when this returns None."""
        if self.count == 0:
            return None
        rank = min(self.count, max(1, math.ceil(q * self.count)))
        if rank <= self.zero:
            return 0.0
        cum = self.zero
        for i in sorted(self.buckets):
            cum += self.buckets[i]
            if cum >= rank:
                return self.growth ** i
        return self.max  # unreachable unless float drift; cap at observed max

    @property
    def mean(self) -> float:
        return self.sum / max(self.count, 1)

    def state(self) -> dict:
        """JSON-serializable snapshot (the metrics-JSONL export format).
        ``p50``/``p99`` appear only when there are samples — an empty
        histogram exports its (zero) count, not a fabricated latency."""
        out = {
            "type": "histogram",
            "growth": self.growth,
            "zero": self.zero,
            "count": self.count,
            "sum": self.sum,
            "max": self.max,
            "buckets": {str(i): n for i, n in sorted(self.buckets.items())},
        }
        if self.count:
            out["p50"] = self.quantile(0.50)
            out["p99"] = self.quantile(0.99)
        return out


@dataclasses.dataclass
class MetricSnapshot:
    """Frozen registry state, detached from live instruments — what a
    ReplicaProfile carries across retirement and what exporters serialize."""

    counters: Dict[Key, int]
    gauges: Dict[Key, float]
    histograms: Dict[Key, Histogram]  # deep copies, safe to merge into

    def flat(self) -> dict:
        """One JSON-ready dict: ``name{k=v,...}`` -> value/state."""

        def fmt(key: Key):
            name, labels = key
            if not labels:
                return name
            return name + "{" + ",".join(f"{k}={v}" for k, v in labels) + "}"

        out: dict = {fmt(k): v for k, v in sorted(self.counters.items())}
        out.update({fmt(k): v for k, v in sorted(self.gauges.items())})
        out.update({fmt(k): h.state() for k, h in sorted(self.histograms.items())})
        return out


class MetricsRegistry:
    """Instrument factory + store. One per engine/replica and one per
    router; the fleet view is ``merge_snapshots`` over all of them (routed
    through the aggregator path like every other per-host export).
    """

    def __init__(self, const_labels: Optional[Dict[str, str]] = None):
        self.const_labels: Dict[str, str] = dict(const_labels or {})
        self._counters: Dict[Key, Counter] = {}
        self._gauges: Dict[Key, Gauge] = {}
        self._histograms: Dict[Key, Histogram] = {}

    # ------------------------------------------------------------------
    def counter(self, name: str, **labels) -> Counter:
        k = _key(name, labels)
        c = self._counters.get(k)
        if c is None:
            c = self._counters[k] = Counter()
        return c

    def gauge(self, name: str, **labels) -> Gauge:
        k = _key(name, labels)
        g = self._gauges.get(k)
        if g is None:
            g = self._gauges[k] = Gauge()
        return g

    def histogram(self, name: str, growth: float = 2.0 ** 0.125, **labels) -> Histogram:
        k = _key(name, labels)
        h = self._histograms.get(k)
        if h is None:
            h = self._histograms[k] = Histogram(growth)
        return h

    # ------------------------------------------------------------------
    def _with_const(self, key: Key) -> Key:
        if not self.const_labels:
            return key
        name, labels = key
        merged = dict(labels)
        for k, v in self.const_labels.items():
            merged.setdefault(str(k), str(v))
        return (name, tuple(sorted(merged.items())))

    def snapshot(self) -> MetricSnapshot:
        """Freeze current state with const labels applied (deep copies)."""
        hists = {}
        for k, h in self._histograms.items():
            c = Histogram(h.growth)
            c.merge(h)
            hists[self._with_const(k)] = c
        return MetricSnapshot(
            counters={self._with_const(k): c.value for k, c in self._counters.items()},
            gauges={self._with_const(k): g.value for k, g in self._gauges.items()},
            histograms=hists,
        )

    def total(self, name: str) -> int:
        """Sum of a counter across all label sets — the legacy-dict view."""
        return sum(c.value for (n, _), c in self._counters.items() if n == name)


def merge_snapshots(snaps: Iterable[MetricSnapshot]) -> MetricSnapshot:
    """Fleet merge: counters/gauges sum, histograms add bucket-wise.

    Exact by construction — every value is an int sum or a bucket-count
    sum, so merging per-replica registries reproduces the legacy
    ``fleet_stats`` totals bit-identically (the acceptance criterion).
    """
    out = MetricSnapshot({}, {}, {})
    for s in snaps:
        for k, v in s.counters.items():
            out.counters[k] = out.counters.get(k, 0) + v
        for k, v in s.gauges.items():
            out.gauges[k] = out.gauges.get(k, 0.0) + v
        for k, h in s.histograms.items():
            dst = out.histograms.get(k)
            if dst is None:
                dst = out.histograms[k] = Histogram(h.growth)
            dst.merge(h)
    return out


def sum_counters(snap: MetricSnapshot, name: str) -> int:
    """Collapse a counter's label dimensions — e.g. fleet tokens_decoded."""
    return sum(v for (n, _), v in snap.counters.items() if n == name)


def merged_histogram(snap: MetricSnapshot, name: str) -> Optional[Histogram]:
    """Collapse a histogram's label dimensions into one distribution."""
    hs: List[Histogram] = [h for (n, _), h in snap.histograms.items() if n == name]
    if not hs:
        return None
    out = Histogram(hs[0].growth)
    for h in hs:
        out.merge(h)
    return out


def prefetch_report(snap: MetricSnapshot) -> dict:
    """Paper-formula prefetcher scores from the registry's prefetch books.

    Derives accuracy / coverage / wasted bytes from the drain-synced
    counters (``prefetch_issued_pages`` etc.) instead of reaching into the
    live engine — so the same report works on a merged fleet snapshot or a
    retired replica's frozen profile, and inherits the drain-cadence
    invariant: identical numbers at any drain schedule. Ratios use the
    exact formulas of ``core.prefetch.PrefetchStats``.
    """
    issued = sum_counters(snap, "prefetch_issued_pages")
    used = sum_counters(snap, "prefetch_used_pages")
    unused = sum_counters(snap, "prefetch_unused_evicted_pages")
    demand = sum_counters(snap, "prefetch_demand_fetches")
    denom = issued + demand - unused
    return {
        "issued_pages": issued,
        "used_pages": used,
        "unused_evicted_pages": unused,
        "demand_fetches": demand,
        "promoted_pages": sum_counters(snap, "prefetch_promoted_pages"),
        "wasted_bytes": sum_counters(snap, "prefetch_wasted_bytes"),
        "accuracy": 1.0 - unused / issued if issued else 1.0,
        "coverage": (issued - unused) / denom if denom > 0 else 0.0,
    }
