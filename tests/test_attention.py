"""Reference attention: chunked online-softmax + flash custom-VJP vs naive."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.common import attention_chunked, attention_decode


def naive(q, k, v, causal=True, q_offset=0):
    b, hq, lq, d = q.shape
    hkv, lk = k.shape[1], k.shape[2]
    g = hq // hkv
    kf = jnp.repeat(k, g, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, g, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf) / np.sqrt(d)
    if causal:
        qpos = q_offset + jnp.arange(lq)
        mask = qpos[:, None] >= jnp.arange(lk)[None, :]
        s = jnp.where(mask, s, -1e30)
    return jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(s, -1), vf)


@pytest.mark.parametrize("hq,hkv", [(4, 4), (6, 2), (5, 5), (8, 1)])
@pytest.mark.parametrize("lq,lk,block", [(64, 64, 16), (33, 33, 16), (16, 80, 32)])
def test_forward_matches_naive(hq, hkv, lq, lk, block, rng):
    if lq != lk:  # decode-extension case: q starts at lk - lq
        off = lk - lq
    else:
        off = 0
    q = jax.random.normal(rng, (2, hq, lq, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, hkv, lk, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, hkv, lk, 32))
    out = attention_chunked(q, k, v, causal=True, q_offset=off, block_k=block)
    ref = naive(q, k, v, causal=True, q_offset=off)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("causal", [True, False])
def test_custom_vjp_grads(causal, rng):
    q = jax.random.normal(rng, (2, 6, 48, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 2, 48, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 2, 48, 16))

    def f1(q, k, v):
        o = attention_chunked(q, k, v, causal=causal, bidirectional=not causal, block_k=16)
        return (o * jnp.arange(16)).sum()

    def f2(q, k, v):
        if causal:
            o = naive(q, k, v, causal=True)
        else:
            o = naive(q, k, v, causal=False)
        return (o * jnp.arange(16)).sum()

    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(g1, g2, "qkv"):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=5e-4, atol=5e-5, err_msg=f"d{name}"
        )


def test_vjp_with_padding_rows(rng):
    """k-length not a block multiple: padded tail must not contribute grads."""
    q = jax.random.normal(rng, (1, 2, 20, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 20, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 20, 16))
    g1 = jax.grad(lambda q: (attention_chunked(q, k, v, block_k=16) ** 2).sum())(q)
    g2 = jax.grad(lambda q: (naive(q, k, v) ** 2).sum())(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=5e-4, atol=5e-5)


def test_decode_matches_naive(rng):
    b, hq, hkv, s, d = 2, 8, 2, 40, 16
    q = jax.random.normal(rng, (b, hq, 1, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, hkv, s, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, hkv, s, d))
    lengths = jnp.array([17, 40])
    out = attention_decode(q, k, v, lengths)
    for i, L in enumerate([17, 40]):
        ref = naive(q[i : i + 1], k[i : i + 1, :, :L], v[i : i + 1, :, :L], causal=False)
        np.testing.assert_allclose(
            np.asarray(out[i : i + 1]), np.asarray(ref), rtol=2e-5, atol=2e-5
        )


def test_bf16_inputs_stay_finite(rng):
    q = jax.random.normal(rng, (1, 4, 32, 16), jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 32, 16), jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 4, 32, 16), jnp.bfloat16)
    out = attention_chunked(q, k, v, block_k=8)
    assert out.dtype == jnp.bfloat16
    assert bool(jnp.isfinite(out.astype(jnp.float32)).all())
