"""Qwen2-VL-7B text backbone (M-RoPE). The vision tower is a stub: inputs are
precomputed patch/token embeddings (B, S, D) + (3, B, S) M-RoPE position ids
(temporal/height/width), per the assignment. Decode continues in text space
(all three M-RoPE channels advance together, equivalent to 1-D RoPE)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer


def init(key, cfg: ModelConfig):
    return transformer.init(key, cfg)


def param_specs(cfg: ModelConfig):
    return transformer.param_specs(cfg)


def forward(params, cfg: ModelConfig, embeds, mrope_positions, **kw):
    return transformer.forward(params, cfg, embeds=embeds, mrope_positions=mrope_positions, **kw)


def features(params, cfg: ModelConfig, embeds, mrope_positions, **kw):
    return transformer.features(params, cfg, embeds=embeds, mrope_positions=mrope_positions, **kw)


def prefill(params, cfg: ModelConfig, embeds, mrope_positions, *, max_len: int, **kw):
    return transformer.prefill(
        params, cfg, embeds=embeds, mrope_positions=mrope_positions, max_len=max_len, **kw
    )


def decode_step(params, cfg: ModelConfig, cache, tokens):
    # text-only continuation: t/h/w positions all equal the sequence index,
    # which reduces M-RoPE to standard RoPE -> reuse the 1-D decode path.
    return transformer.decode_step(params, cfg, cache, tokens)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    return transformer.init_cache(cfg, batch, max_len, dtype)


def cache_specs(cfg: ModelConfig, model_axis: int = 16):
    return transformer.cache_specs(cfg, model_axis)
