from repro.kernels.tiered_gather.ops import (  # noqa: F401
    gather_rows,
    tiered_lookup,
    tiered_lookup_counted,
    tiered_lookup_segments,
)
from repro.kernels.tiered_gather.ref import (  # noqa: F401
    gather_rows_ref,
    tiered_lookup_counted_ref,
    tiered_lookup_ref,
    tiered_lookup_segments_ref,
)
