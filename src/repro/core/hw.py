"""Hardware model: TPU v5e target + memory-tier specs.

Roofline constants come from the assignment: 197 TFLOP/s bf16 per chip,
819 GB/s HBM, ~50 GB/s/link ICI. Tier specs mirror the paper's Table 4
(near = HB-DIMM-like: 2x BW, 2x cost; far = CXL-like: DDR BW, higher
latency) so benchmarks/table5_tiering.py can reproduce Table 5 with the
paper's own constants; the TPU serving tiers (HBM vs host DRAM over PCIe)
are the deployment analogue.
"""
from __future__ import annotations

import dataclasses

# --- TPU v5e (per chip) -----------------------------------------------------
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
HBM_BYTES = 16 * 2**30
ICI_BW_PER_LINK = 50e9  # B/s
VMEM_BYTES = 128 * 2**20
# host link (far tier for serving state): PCIe gen4-ish per chip share
HOST_LINK_BW = 32e9  # B/s
DCI_BW = 25e9  # B/s per chip share, cross-pod (pod axis collectives)


@dataclasses.dataclass(frozen=True)
class TierSpec:
    name: str
    capacity_frac: float  # fraction of total workload memory capacity
    bw: float  # B/s usable peak
    latency_rel: float  # relative load latency (near == 1.0)
    cost_per_unit: float  # relative $ per byte (DDR == 1.0)

    @property
    def cost(self) -> float:
        return self.capacity_frac * self.cost_per_unit


# --- the paper's Table 4 configurations ------------------------------------
GB = 1e9
BASELINE = (TierSpec("ddr", 1.0, 100 * GB, 1.0, 1.0),)
IDEAL = (TierSpec("hb-dimm", 1.0, 200 * GB, 1.0, 2.0),)
TIERED = (
    TierSpec("hb-dimm", 0.375, 200 * GB, 1.0, 2.0),
    TierSpec("cxl", 0.625, 100 * GB, 1.8, 1.0),
)

# --- TPU serving tiers (deployment analogue) --------------------------------
TPU_TIERED = (
    TierSpec("hbm", 0.30, HBM_BW, 1.0, 8.0),
    TierSpec("host-dram", 0.70, HOST_LINK_BW, 6.0, 1.0),
)

# utilization knee: production workloads can't push DDR past ~60-70% without
# the latency blow-up the paper describes (Fig. 4); microbenchmarks can.
BW_KNEE = 0.68
