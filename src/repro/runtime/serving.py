"""Serving engine: continuous batching over paged, tiered, prefix-shared KV.

This is where the paper's three findings operate together at runtime:

  * shared KV page table (core/pagetable): requests with common prompt
    prefixes map the same physical pages (multi-ASID I-TLB analogue) —
    dedups HBM capacity and prefill traffic;
  * tiered placement (core/placement): hot pages stay in the HBM near tier,
    cold pages demote to the host far tier, driven by windowed access counts
    from the profiler (MemProf.MemBW in the loop);
  * software prefetch (core/prefetch): the decode step's sequential page walk
    is predicted and far pages are fetched ahead, overlapping transfer with
    compute; accuracy/coverage accounted with the paper's formulas.

Model math runs through the model's own decode_step (exact for every
family); the page table is the management/accounting plane, as in any
engine where the block manager is host-side (vLLM-style). The Pallas
paged_attention kernel is the device-side fast path for dense archs
(examples/serve_tiered.py wires it directly).

Device-executed tiering (``EngineConfig.device_tiering``, env
``REPRO_DEVICE_TIERING=1``): the decode step's KV page stream is EXECUTED
against a device-resident tiered store (runtime/tiered_kv.TieredKVCache) —
near rows in an f32 "HBM" buffer, far rows int8-quantized with per-row
scales — via the fused kernels/tiered_gather pass. The model's own decode
math stays exact and untouched (it reads its per-family cache as always);
what moves on device is the tier plane: the page gathers, the int8
promote/demote data movement driven by placement pushes (local TPP epochs
and fleet AutoTierer apply_placement), and the near/far hit counters,
which are produced in-kernel at the access point and REPLACE the
host-side tier accounting. With identity scales the device-tiered engine
is bit-identical to the host-accounted one (same tokens, same counters)
and tiered reads never diverge from the flat mirror;
tests/test_tiered_decode.py enforces that equivalence.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.workloads import WorkloadProfile
from repro.core.memtrace import MemTracer
from repro.core.pagetable import FAR, NEAR, SharedKVPageTable
from repro.core.placement import TieredPlacement
from repro.core.prefetch import PrefetchEngine
from repro.core.profiler import AccessProfiler
from repro.data.requests import Request, RequestGenerator
from repro.env import env_flag
from repro.models.api import ModelAPI
from repro.runtime.tiered_kv import TieredKVCache, sanitize_near_ids


def _env_device_tiering() -> bool:
    return env_flag("REPRO_DEVICE_TIERING", default=False)


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 4
    max_len: int = 256
    page_size: int = 16
    n_pages: int = 1024
    near_frac: float = 0.30
    predictor: str = "nextline"
    prefetch_buffer: int = 64
    placement_window: int = 16  # engine steps per TPP epoch
    trace_window: int = 8
    trace_period: int = 64
    # device-executed tiering: route KV page reads through the fused
    # tiered-gather kernel over a device-resident near/far store
    device_tiering: bool = dataclasses.field(default_factory=_env_device_tiering)
    # snap payload rows to the int8 grid so the far tier is lossless —
    # the "quantization error zeroed" mode of the equivalence oracle
    tiered_identity_scales: bool = False
    # differential probe: compare every tiered read against the flat
    # buffer in-line (tracks the max divergence in stats())
    tiered_verify: bool = False


@dataclasses.dataclass
class _Slot:
    seq_id: int = -1
    remaining: int = 0
    request: Optional[Request] = None

    @property
    def active(self) -> bool:
        return self.seq_id >= 0


class ServingEngine:
    def __init__(self, api: ModelAPI, params, ecfg: EngineConfig, seed: int = 0):
        self.api = api
        self.cfg = api.cfg
        self.ecfg = ecfg
        self.params = params
        e = ecfg
        self.pagetable = SharedKVPageTable(e.n_pages, e.page_size)
        self.placement = TieredPlacement(
            e.n_pages,
            near_capacity=max(1, int(e.near_frac * e.n_pages)),
            block_bytes=self._page_bytes(),
        )
        # pages start in the far tier until placement promotes them
        self.placement.tier[:] = 1
        self.placement.tier[: self.placement.near_capacity] = 0
        self.prefetch = PrefetchEngine(e.predictor, e.prefetch_buffer)
        self.profiler = AccessProfiler(e.n_pages, self._page_bytes(), window_len=e.placement_window)
        self.tracer = MemTracer(e.trace_window, e.trace_period)
        self.slots = [_Slot() for _ in range(e.max_batch)]
        self.cache = api.init_cache(e.max_batch, e.max_len)
        self.queue: List[Request] = []
        self.finished: List[int] = []
        self.tokens_decoded = 0
        self.prefill_tokens = 0
        self.prefill_tokens_saved = 0  # shared-prefix pages not recomputed/stored
        self.engine_steps = 0
        # per-tenant accounting: profiler streams are "kv.<tenant>", tier
        # hits split near/far so fleet reports can expose cross-tenant
        # interference on the shared far tier
        self.tenant_stats: Dict[str, Dict[str, int]] = {}
        self.next_tokens = np.zeros((e.max_batch,), np.int32)
        # fleet hooks: called with (page_ids, is_write) for every accounted
        # block access — replicas attach live counters (CacheSim) here
        self.access_hooks: List[Callable] = []
        # when True, a fleet-level planner owns placement (apply_placement);
        # the local TPP epoch is suppressed so the two don't fight
        self.external_placement = False
        # virtual-time cost of one engine step for the fleet's event
        # scheduler; replace to model batch- or far-traffic-dependent step
        # latency. Must stay constant at 1.0 for lockstep-exact replays.
        self.step_cost_fn: Optional[Callable[["ServingEngine"], float]] = None
        # one jitted decode shared by every engine on the same ModelAPI
        # (a replica fleet compiles once, not once per replica)
        if not hasattr(api, "_jit_decode"):
            api._jit_decode = jax.jit(api.decode)
        self._decode = api._jit_decode
        self._rng = np.random.default_rng(seed)
        self._seed = seed
        # device-executed tiering: a device-resident near/far store whose
        # tier map mirrors placement.tier and whose fused-kernel lookups
        # produce the tier-hit counters
        self.tiered: Optional[TieredKVCache] = None
        self.tiered_max_err = 0.0  # max tiered-vs-flat read divergence seen
        self._page_wver = None  # per-page write version (fallback payloads)
        if e.device_tiering:
            self.tiered = TieredKVCache(
                e.n_pages,
                self._payload_dim(),
                self.placement.near_capacity,
                identity_scales=e.tiered_identity_scales,
            )
            self._page_wver = np.zeros(e.n_pages, np.int64)
            # initial fill: position the starting near set without charging
            # it to the migration books (nothing has been written yet)
            self.tiered.migrate(self.placement.near_blocks(), account=False)

    # ------------------------------------------------------------------
    def _page_bytes(self) -> int:
        """Bytes of one logical KV page across all layers (k+v, bf16)."""
        c = self.cfg
        n_layers = getattr(c, "n_layers", 1)
        return self.ecfg.page_size * 2 * c.n_kv_heads * c.head_dim * 2 * n_layers

    # ------------------------------------------------------------------
    # device-tier payload plumbing

    def _dense_kv(self, cache) -> Optional[jnp.ndarray]:
        """The (L, B, H, S, D) k-cache when this family exposes one."""
        k = cache.get("k") if isinstance(cache, dict) else None
        return k if k is not None and getattr(k, "ndim", 0) == 5 else None

    def _payload_dim(self) -> int:
        k = self._dense_kv(self.cache)
        if k is not None:
            n_layers, _, n_heads, _, head_dim = k.shape
            return 2 * n_layers * n_heads * head_dim
        return 128  # recurrent-state families: synthetic payload rows

    def _payload_rows(self, cache, batch_idxs, positions, page_ids) -> jnp.ndarray:
        """Per-page payload rows for the device tier store (one batched
        gather for any number of (slot, position) pairs).

        For KV families the row is the real decode data: the k and v vectors
        of the page's most recently written token, flattened across layers
        and heads. Recurrent-state families (no per-position KV) fall back
        to deterministic rows keyed by (page, write-version) — the memory
        system behavior (gathers, quantization, migration) is identical, only
        the payload values are synthetic.
        """
        k = self._dense_kv(cache)
        if k is not None:
            bi = jnp.asarray(batch_idxs, jnp.int32)
            pos = jnp.asarray(positions, jnp.int32)
            # advanced indices (batch, seq-pos) broadcast together and land
            # in front: (n, L, H, Dh) per store
            kk = k[:, bi, :, pos, :]
            vv = cache["v"][:, bi, :, pos, :]
            kv = jnp.concatenate([kk, vv], axis=1)  # (n, 2L, H, Dh)
            return kv.reshape(len(positions), -1).astype(jnp.float32)
        rows = np.empty((len(page_ids), self.tiered.row_dim), np.float32)
        for i, pid in enumerate(page_ids):
            ver = int(self._page_wver[pid])
            r = np.random.default_rng((self._seed << 40) ^ (int(pid) << 20) ^ ver)
            rows[i] = r.standard_normal(self.tiered.row_dim, dtype=np.float32)
        return jnp.asarray(rows)

    def _tiered_write(self, cache, batch_idxs, positions, page_ids):
        if self.tiered is None or not len(page_ids):
            return
        rows = self._payload_rows(cache, batch_idxs, positions, page_ids)
        self.tiered.write(np.asarray(page_ids, np.int64), rows)
        self._page_wver[np.asarray(page_ids, np.int64)] += 1

    def _sync_device_tiers(self):
        """Mirror placement.tier into the device store (real data movement)."""
        if self.tiered is not None:
            self.tiered.migrate(self.placement.near_blocks())

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for slot_idx, slot in enumerate(self.slots):
            if slot.active or not self.queue:
                continue
            req = self.queue.pop(0)
            budget = max(1, self.ecfg.max_len - 2)
            tokens = req.tokens[:budget]
            decode_len = max(1, min(req.decode_len, self.ecfg.max_len - len(tokens) - 1))
            share = self.pagetable.add_sequence(req.rid, tokens)
            self.prefill_tokens += len(tokens)
            self.prefill_tokens_saved += share["shared"] * self.ecfg.page_size
            # run the model prefill for this request into its slot
            batch = self._prefill_batch(tokens)
            logits1, cache1 = self.api.prefill(self.params, batch, max_len=self.ecfg.max_len)
            self._write_slot(slot_idx, cache1, len(tokens))
            if self.tiered is not None:
                # seed the device tier store with this sequence's page
                # payloads (each page keyed by its last prefilled token)
                pages = self.pagetable.seqs[req.rid]
                ps = self.ecfg.page_size
                positions = [
                    min((i + 1) * ps, len(tokens)) - 1 for i in range(len(pages))
                ]
                self._tiered_write(cache1, [0] * len(pages), positions, pages)
            nxt = int(jnp.argmax(logits1[0, -1, : self.cfg.vocab_size]))
            self.next_tokens[slot_idx] = nxt
            slot.seq_id = req.rid
            slot.remaining = decode_len
            slot.request = req

    def _prefill_batch(self, tokens: np.ndarray) -> dict:
        t = jnp.asarray(tokens, jnp.int32)[None, :]
        fam = self.api.family
        if fam == "vlm":
            emb = jnp.take(self.params["embed"], t, axis=0)
            pos = jnp.broadcast_to(jnp.arange(t.shape[1], dtype=jnp.int32), (3, 1, t.shape[1]))
            return {"embeds": emb, "mrope_positions": pos}
        if fam == "audio":
            frames = jnp.zeros((1, self.cfg.n_audio_frames, self.cfg.d_model), jnp.bfloat16)
            return {"tokens": t, "frames": frames}
        return {"tokens": t}

    def _write_slot(self, slot_idx: int, cache1: dict, length: int):
        """Copy a batch-1 prefill cache into slot ``slot_idx`` of the batched
        cache. Works on the cache pytree: batch axis differs per leaf family
        (kv: axis 1; lengths: axis 0)."""

        def put(dst, src):
            if dst.ndim == 1:  # lengths
                return dst.at[slot_idx].set(src[0])
            return dst.at[:, slot_idx].set(src[:, 0])

        self.cache = jax.tree.map(put, self.cache, cache1)

    # ------------------------------------------------------------------
    def _tenant(self, name: str) -> Dict[str, int]:
        if name not in self.tenant_stats:
            self.tenant_stats[name] = {
                "tokens_decoded": 0,
                "requests_finished": 0,
                "near_hits": 0,
                "far_hits": 0,
            }
        return self.tenant_stats[name]

    def _account_decode(self):
        """Per decode step: every active sequence touches all its KV pages
        (attention reads the whole cache) — that stream drives placement,
        prefetch, the profiler and the tracer.

        In device-tiering mode the read is EXECUTED, not modeled: the pages'
        payload rows are gathered through the fused tiered kernel and the
        near/far hit counters come back from the device, produced by the
        same pass that moved the bytes."""
        for slot in self.slots:
            if not slot.active:
                continue
            pages = np.array(self.pagetable.seqs[slot.seq_id], np.int64)
            if pages.size == 0:
                continue
            far = self.placement.tier[pages] == 1
            if self.tiered is not None:
                rows, near_n, far_n = self.tiered.lookup(pages)
                self.placement.stats.near_hits += near_n
                self.placement.stats.far_hits += far_n
                if self.ecfg.tiered_verify:
                    err = float(
                        jnp.max(jnp.abs(rows - self.tiered.lookup_flat(pages)))
                    )
                    self.tiered_max_err = max(self.tiered_max_err, err)
            else:
                self.placement.access(pages)
                near_n = int((~far).sum())
                far_n = int(far.sum())
            self.prefetch.access_many(pages, far)
            self.profiler.record("kv", pages)
            self.tracer.record(pages, is_write=False)
            ts = self._tenant(slot.request.tenant)
            ts["near_hits"] += near_n
            ts["far_hits"] += far_n
            self.profiler.record(f"kv.{slot.request.tenant}", pages)
            for hook in self.access_hooks:
                hook(pages, False)

    def step(self) -> int:
        """One engine iteration: admit -> decode -> account -> retire.

        Returns number of tokens decoded this step.
        """
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s.active]
        if not active:
            return 0
        tokens = jnp.asarray(self.next_tokens[:, None], jnp.int32)
        logits, self.cache = self._decode(self.params, self.cache, tokens)
        self.next_tokens = np.array(
            jnp.argmax(logits[:, -1, : self.cfg.vocab_size], axis=-1), np.int32, copy=True
        )
        self._account_decode()
        decoded = 0
        written: List[int] = []
        written_tenant: List[str] = []
        written_slot: List[int] = []
        written_pos: List[int] = []
        for slot_idx, slot in enumerate(self.slots):
            if not slot.active:
                continue
            written.append(self.pagetable.append_token(slot.seq_id))
            written_tenant.append(slot.request.tenant)
            written_slot.append(slot_idx)
            written_pos.append(self.pagetable.seq_len[slot.seq_id] - 1)
            slot.remaining -= 1
            decoded += 1
            ts = self._tenant(slot.request.tenant)
            ts["tokens_decoded"] += 1
            if slot.remaining <= 0:
                self.pagetable.free_sequence(slot.seq_id)
                self.finished.append(slot.seq_id)
                ts["requests_finished"] += 1
                slot.seq_id = -1
                slot.request = None
        if written:
            # the decoded token's KV write — gives the access stream a real
            # R:W mix (Table 6 validation compares read:write ratios)
            w = np.asarray(written, np.int64)
            if self.tiered is not None:
                # the write is executed on device too: every written page's
                # payload row lands in its current tier (quantized if far),
                # one batched scatter for the whole step
                self._tiered_write(self.cache, written_slot, written_pos, written)
            self.profiler.record("kv", w, rw="w")
            by_tenant: Dict[str, List[int]] = {}
            for page, tenant in zip(written, written_tenant):
                by_tenant.setdefault(tenant, []).append(page)
            for tenant, pages in by_tenant.items():
                self.profiler.record(f"kv.{tenant}", np.asarray(pages, np.int64), rw="w")
            self.tracer.record(w, is_write=True)
            for hook in self.access_hooks:
                hook(w, True)
        self.tokens_decoded += decoded
        self.engine_steps += 1
        self.profiler.tick()
        self.tracer.tick()
        # TPP epoch at window boundaries (skipped when a fleet planner drives)
        if not self.external_placement and self.engine_steps % self.ecfg.placement_window == 0:
            wins = self.profiler.windows("kv")
            if wins:
                self.placement.step(wins[-1])
                self._sync_device_tiers()
        return decoded

    def run(self, gen: RequestGenerator, n_requests: int, max_steps: int = 10_000) -> dict:
        for _ in range(n_requests):
            self.submit(next(gen))
        steps = 0
        while (self.queue or any(s.active for s in self.slots)) and steps < max_steps:
            self.step()
            steps += 1
        return self.stats()

    # ------------------------------------------------------------------
    # fleet interface (fleet/replica.py wraps these)

    @property
    def load(self) -> int:
        """Backlog metric for routing: busy slots + queued requests."""
        return sum(1 for s in self.slots if s.active) + len(self.queue)

    def step_cost(self) -> float:
        """Virtual-time units one call to ``step`` costs (fleet scheduler).

        The default (1.0) makes engine steps the fleet's time unit; a
        ``step_cost_fn`` hook can price steps by live state instead.
        """
        if self.step_cost_fn is None:
            return 1.0
        cost = float(self.step_cost_fn(self))
        if cost <= 0.0:
            raise ValueError(f"step_cost_fn must return > 0, got {cost}")
        return cost

    def backlog_tokens(self, prefill_weight: float = 1.0) -> float:
        """Pending work in token-equivalents (admission's backlog estimate).

        ``prefill_weight`` discounts queued prompt tokens the same way the
        caller's SLO cost model does (prefill is one batched pass, decode
        is one slot-step per token).
        """
        q = sum(prefill_weight * len(r.tokens) + r.decode_len for r in self.queue)
        return q + sum(s.remaining for s in self.slots if s.active)

    def apply_placement(self, near_ids: np.ndarray) -> int:
        """Push an externally-planned near-tier set (fleet autotier).

        Replaces the local TPP view wholesale; returns number of pages whose
        tier changed (the migration traffic this push costs).
        """
        # same sanitize rule as the device store, or the two tier views
        # diverge; dedup must precede the capacity cut so duplicate ids
        # neither double-count promotions nor shrink the near set
        near_ids = sanitize_near_ids(
            near_ids, self.ecfg.n_pages, self.placement.near_capacity
        )
        old = self.placement.tier.copy()
        self.placement.tier[:] = 1
        self.placement.tier[near_ids] = 0
        promoted = int((old[near_ids] == 1).sum())
        demoted = int(((old == 0) & (self.placement.tier == 1)).sum())
        st = self.placement.stats
        st.promotions += promoted
        st.demotions += demoted
        st.migrated_bytes += (promoted + demoted) * self.placement.block_bytes
        # device mode: the push is real data movement — promotions copy
        # far->near with dequantization, demotions quantize near->far
        self._sync_device_tiers()
        return promoted + demoted

    def live_counters(self) -> dict:
        """Ground-truth counters the fleet aggregator validates against."""
        kv = self.profiler._stream("kv")
        return {
            "reads": kv.reads,
            "writes": kv.writes,
            "rw_ratio": self.profiler.rw_ratio("kv"),
            "near_hit_rate": self.placement.stats.hit_rate,
            "accesses": int(kv.counts.sum()),
        }

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        ps = self.prefetch.stats
        device = None
        if self.tiered is not None:
            device = {**self.tiered.stats(), "max_read_error": self.tiered_max_err}
        return {
            "device_tiering": device,
            "tokens_decoded": self.tokens_decoded,
            "requests_finished": len(self.finished),
            "prefill_tokens": self.prefill_tokens,
            "prefill_tokens_saved": self.prefill_tokens_saved,
            "near_hit_rate": self.placement.stats.hit_rate,
            "migrations": self.placement.stats.promotions + self.placement.stats.demotions,
            "prefetch_accuracy": ps.accuracy,
            "prefetch_coverage": ps.coverage,
            "prefetch_bw_overhead": ps.bw_overhead,
            "pagetable": self.pagetable.stats(),
            "tenants": {
                t: {**ts, "near_hit_rate": ts["near_hits"] / max(ts["near_hits"] + ts["far_hits"], 1)}
                for t, ts in self.tenant_stats.items()
            },
        }
