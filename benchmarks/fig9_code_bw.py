"""Paper Fig. 9: code-bandwidth distribution -> parameter/state-block CDF.

X: hottest blocks (MiB, cumulative); Y: fraction of total access bandwidth.
The paper's shape — a small hot set serving most fetches with a very long
infrequent tail — reproduces for every workload profile.
"""
import numpy as np

from repro.core import distribution as dist

from _common import ALL_WORKLOADS, fmt_table, stream_for

BLOCK_BYTES = 4096
MIB = 2**20


def main():
    marks = (0.25, 0.5, 1.0, 2.0, 4.0, 8.0)
    rows = []
    out = {}
    for name in ALL_WORKLOADS:
        stream, prof = stream_for(name, n=60_000)
        counts = np.bincount(stream, minlength=prof.n_blocks)
        order = np.argsort(-counts)
        cum = np.cumsum(counts[order]) / max(counts.sum(), 1)
        mib = np.arange(1, len(cum) + 1) * BLOCK_BYTES / MIB
        row = [name]
        for m in marks:
            idx = np.searchsorted(mib, m)
            row.append(f"{cum[min(idx, len(cum)-1)]*100:5.1f}%")
        footprint = (counts > 0).sum() * BLOCK_BYTES / MIB
        row.append(f"{footprint:.1f}")
        rows.append(tuple(row))
        out[name] = float(cum[min(np.searchsorted(mib, 1.0), len(cum) - 1)])
    print("[fig9] cumulative access-bandwidth share of the hottest X MiB")
    print(fmt_table(rows, ["workload"] + [f"{m}MiB" for m in marks] + ["footprint"]))
    return out


if __name__ == "__main__":
    main()
