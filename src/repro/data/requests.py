"""Serving request generator driven by the nine workload profiles.

Web-like profiles draw most prompts from a shared prefix pool (the paper's
"cores run the same code" in request form: many requests, same template),
cache-like profiles are Zipf-skewed point lookups, Reader is long-prompt
backend-bound. Deterministic per (profile, seed, index).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Sequence

import numpy as np

from repro.configs.workloads import WorkloadProfile


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray  # prompt token ids (int32)
    decode_len: int
    prefix_id: int  # -1 if unique prompt
    arrival: float
    tenant: str = "default"  # service identity for multi-tenant fleets


class RequestGenerator:
    def __init__(
        self,
        profile: WorkloadProfile,
        vocab_size: int,
        seed: int = 0,
        rate: float = 8.0,
        tenant: Optional[str] = None,
    ):
        self.p = profile
        self.vocab = vocab_size
        self.tenant = tenant if tenant is not None else "default"
        self.rng = np.random.default_rng(seed)
        self.rate = rate
        self._prefixes = [
            self.rng.integers(0, vocab_size, size=max(8, int(profile.prompt_mean * 0.75)))
            .astype(np.int32)
            for _ in range(profile.n_prefixes)
        ]
        # Zipf over prefixes too: hot templates dominate (Web1's correlation)
        ranks = np.arange(1, profile.n_prefixes + 1, dtype=np.float64)
        pz = ranks ** -max(profile.zipf_alpha, 0.5)
        self._prefix_probs = pz / pz.sum()
        self._next_id = 0
        self._clock = 0.0

    def __iter__(self) -> Iterator[Request]:
        return self

    def __next__(self) -> Request:
        p = self.p
        self._clock += float(self.rng.exponential(1.0 / self.rate))
        rid = self._next_id
        self._next_id += 1
        if self.rng.random() < p.prefix_share:
            pid = int(self.rng.choice(p.n_prefixes, p=self._prefix_probs))
            suffix_len = max(1, int(self.rng.exponential(p.prompt_mean * 0.25)))
            suffix = self.rng.integers(0, self.vocab, size=suffix_len).astype(np.int32)
            tokens = np.concatenate([self._prefixes[pid], suffix])
        else:
            pid = -1
            n = max(4, int(self.rng.exponential(p.prompt_mean)))
            tokens = self.rng.integers(0, self.vocab, size=n).astype(np.int32)
        decode_len = max(1, int(self.rng.exponential(p.decode_mean)))
        return Request(rid, tokens, decode_len, pid, self._clock, self.tenant)

    def block_stream(self, n: int, n_blocks: Optional[int] = None, n_streams: int = 4) -> np.ndarray:
        """State-block access stream for this service — MemProf.MemBW's
        sampled miss stream.

        Structure mirrors a serving engine's memory behavior: ``n_streams``
        concurrent sequences each walk blocks SEQUENTIALLY (a KV page walk)
        and re-seed at a Zipf-hot block with probability ``seq_jump`` —
        low-jump services (Ads1, CPU inference) are stream-prefetchable,
        high-jump ones (Cache1/2 key-value lookups) are not (Fig. 21/22).
        """
        nb = n_blocks or self.p.n_blocks
        ranks = np.arange(1, nb + 1, dtype=np.float64)
        probs = ranks ** -self.p.zipf_alpha
        probs /= probs.sum()
        perm = np.random.default_rng(hash(self.p.name) % 2**31).permutation(nb)
        seeds = perm[self.rng.choice(nb, size=n, p=probs)]  # zipf-hot restarts
        pos = seeds[: n_streams].astype(np.int64).copy()
        jump = self.rng.random(n) < self.p.seq_jump
        lane = self.rng.integers(0, n_streams, n)
        out = np.empty(n, np.int64)
        for i in range(n):
            s = lane[i]
            if jump[i]:
                pos[s] = seeds[i]
            else:
                pos[s] = (pos[s] + 1) % nb
            out[i] = pos[s]
        return out


def interleave(gens: Sequence[RequestGenerator], n: int) -> List[Request]:
    """Merge ``n`` requests from several tenant generators by arrival time.

    The co-location traffic model: each tenant keeps its own Poisson clock
    and the fleet sees the time-ordered merge. Request ids are reassigned so
    sequence ids stay unique fleet-wide, and shared-prefix ids are namespaced
    per tenant so one tenant's hot template can't alias another's in
    prefix-affinity routing. Deterministic given the generators' seeds.
    """
    heads = [next(g) for g in gens]
    out: List[Request] = []
    for rid in range(n):
        g = min(range(len(gens)), key=lambda i: (heads[i].arrival, i))
        req = heads[g]
        pid = req.prefix_id if req.prefix_id < 0 else req.prefix_id * len(gens) + g
        out.append(dataclasses.replace(req, rid=rid, prefix_id=pid))
        heads[g] = next(gens[g])
    return out
