"""TPP-like page placement: promotion/demotion between near and far tiers.

The paper's Tiered config uses Maruf et al.'s Transparent Page Placement;
this is that loop for framework state blocks: windowed access counts drive
promotions of hot far-tier blocks and demotions of cold near-tier blocks,
under a per-step migration budget (migration traffic competes with demand
traffic — the paper's Fig. 20 warm-up transient is exactly this budget).

Hysteresis: a far block must beat the coldest near block by ``hysteresis``x
to be promoted, so ping-pong migrations don't eat the budget.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class PlacementStats:
    promotions: int = 0
    demotions: int = 0
    near_hits: int = 0
    far_hits: int = 0
    migrated_bytes: int = 0

    @property
    def hit_rate(self) -> float:
        tot = self.near_hits + self.far_hits
        return self.near_hits / max(tot, 1)


class TieredPlacement:
    def __init__(
        self,
        n_blocks: int,
        near_capacity: int,
        block_bytes: int = 4096,
        hysteresis: float = 1.25,
        migrate_budget: int = 64,
    ):
        assert 0 < near_capacity
        self.n_blocks = n_blocks
        self.near_capacity = min(near_capacity, n_blocks)
        self.block_bytes = block_bytes
        self.hysteresis = hysteresis
        self.migrate_budget = migrate_budget
        self.tier = np.ones(n_blocks, np.int8)  # 0 = near, 1 = far
        self.tier[: self.near_capacity] = 0  # initial arbitrary fill
        self.stats = PlacementStats()

    # ------------------------------------------------------------------
    def near_blocks(self) -> np.ndarray:
        return np.flatnonzero(self.tier == 0)

    def access(self, block_ids: np.ndarray):
        """Account demand accesses (near vs far hits)."""
        t = self.tier[np.asarray(block_ids).reshape(-1)]
        near = int((t == 0).sum())
        self.stats.near_hits += near
        self.stats.far_hits += t.size - near

    def plan_initial(self, counts: np.ndarray):
        """Profile-driven cold start: hottest blocks straight to near tier."""
        order = np.argsort(-np.asarray(counts))
        self.tier[:] = 1
        self.tier[order[: self.near_capacity]] = 0

    def step(self, window_counts: np.ndarray) -> dict:
        """One TPP epoch: promote/demote using the last window's counts."""
        counts = np.asarray(window_counts, np.float64)
        near = np.flatnonzero(self.tier == 0)
        far = np.flatnonzero(self.tier == 1)
        if near.size == 0 or far.size == 0:
            return {"promoted": 0, "demoted": 0}
        order_far = far[np.argsort(-counts[far])]
        order_near = near[np.argsort(counts[near])]
        promoted = demoted = 0
        budget = self.migrate_budget
        for cand, victim in zip(order_far, order_near):
            if budget <= 0:
                break
            if counts[cand] > self.hysteresis * counts[victim] and counts[cand] > 0:
                self.tier[cand] = 0
                self.tier[victim] = 1
                promoted += 1
                demoted += 1
                budget -= 2
            else:
                break  # sorted orders: no further pair can qualify
        self.stats.promotions += promoted
        self.stats.demotions += demoted
        self.stats.migrated_bytes += (promoted + demoted) * self.block_bytes
        return {"promoted": promoted, "demoted": demoted}
