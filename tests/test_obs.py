"""Fleet flight recorder: spans, metrics plane, Perfetto export.

The contracts this file pins (ISSUE 6 acceptance):

1. Span ring buffer is bounded: overflow drops the OLDEST spans and counts
   them; the drop count is itself a metric (``spans_dropped``).
2. The metrics plane is exact: counters/histograms merge bit-identically
   (quantiles are deterministic bucket upper bounds, identical before and
   after merge), and fleet metric totals merged from per-replica registries
   equal the legacy ``fleet_stats`` sums bit-for-bit.
3. Observability is free: recorder on/off and drain-every-step vs
   once-per-window produce identical tokens, live_counters, and registry
   totals — the PR-5 drain-cadence invariant extends to every metric — and
   the segmented decode still pays exactly 1 dispatch/step with tracing on.
4. A seeded multi-tenant straggler+autoscale scenario exports a
   Perfetto-loadable trace_event JSON with causally-ordered spans
   (monotone virtual time, balanced B/E pairs, tenant+replica labels on
   every event).
5. ``tenant_report`` queue-wait p50/p99 now come from the mergeable
   histogram and pin against the legacy np.percentile values on a seeded
   run (within one exponential bucket).
"""
import dataclasses
import json
import math

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.workloads import get_profile
from repro.data.requests import RequestGenerator, interleave
from repro.fleet import (
    AdmissionController,
    SLOModel,
    aggregate_metrics,
    build_fleet,
    fleet_vocab,
)
from repro.models.api import get_model
from repro.obs import (
    FlightRecorder,
    Histogram,
    MetricsRegistry,
    SpanRecorder,
    default_recorder,
    merge_snapshots,
    merged_histogram,
    set_default_recorder,
    sum_counters,
)
from repro.obs.export import read_trace, to_trace_events, validate_trace_events
from repro.obs.spans import Span
from repro.runtime.serving import EngineConfig, ServingEngine


# ---------------------------------------------------------------------------
# 1. span recorder: ring cap + drop counter


def test_ring_buffer_caps_and_counts_drops():
    rec = SpanRecorder(capacity=4)
    for i in range(10):
        rec.instant("tick", i, float(i))
    assert len(rec.finished()) == 4
    assert rec.dropped == 6
    assert rec.emitted == 10
    # oldest fell off the ring; newest survived
    assert [s.trace for s in rec.finished()] == [6, 7, 8, 9]


def test_drop_count_is_a_metric():
    fr = FlightRecorder(capacity=2)
    for i in range(5):
        fr.instant("tick", i, t=float(i), tenant="t")
    snap = fr.merged_snapshot()
    assert snap.gauges[("spans_dropped", ())] == 3
    assert snap.gauges[("spans_emitted", ())] == 5


def test_span_lifecycle_and_drain_open():
    rec = SpanRecorder()
    rec.begin("queue", 7, 1.0, tenant="web")
    assert rec.open_count == 1
    s = rec.end("queue", 7, 3.5, wait=2.5)
    assert (s.t0, s.t1, s.dur) == (1.0, 3.5, 2.5)
    assert s.args["wait"] == 2.5
    # unmatched end degrades to a tagged instant, not a crash
    u = rec.end("queue", 99, 4.0)
    assert u.kind == "instant" and u.args["unmatched"] is True
    # open spans flush as truncated at export time (B/E stay balanced)
    rec.begin("decode", 8, 5.0)
    rec.drain_open(9.0)
    assert rec.open_count == 0
    last = rec.finished()[-1]
    assert last.name == "decode" and last.t1 == 9.0 and last.args["truncated"]


def test_double_end_records_one_span_and_is_counted():
    """Failover races can end the same span twice (e.g. a queue span closed
    by dispatch, then again by a stale path): exactly ONE span reaches the
    ring, the duplicate is counted in the ``double_end`` book instead of
    producing a bogus unmatched-instant."""
    rec = SpanRecorder()
    rec.begin("queue", 7, 1.0)
    rec.end("queue", 7, 3.0)
    assert rec.end("queue", 7, 4.0) is None  # duplicate: swallowed
    assert rec.double_end == 1
    spans = [s for s in rec.finished() if s.name == "queue"]
    assert len(spans) == 1 and spans[0].t1 == 3.0
    # a NEVER-begun end still degrades to the tagged instant (distinct case)
    u = rec.end("queue", 99, 5.0)
    assert u.args["unmatched"] is True and rec.double_end == 1
    # re-begin after a close re-arms the pair: next end is legitimate
    rec.begin("queue", 7, 6.0)
    s = rec.end("queue", 7, 8.0)
    assert s.t1 == 8.0 and rec.double_end == 1
    # drain_open flushes re-opened spans; a later duplicate end of a
    # drained key is still just a count, not a span
    rec.begin("decode", 7, 9.0)
    rec.drain_open(10.0)
    assert rec.end("decode", 7, 11.0) is None
    assert rec.double_end == 2
    # the book rides the merged metric snapshot like the drop counter
    fr = FlightRecorder(capacity=8)
    fr.spans.begin("x", 1, 0.0)
    fr.spans.end("x", 1, 1.0)
    fr.spans.end("x", 1, 2.0)
    assert fr.merged_snapshot().gauges[("spans_double_end", ())] == 1


# ---------------------------------------------------------------------------
# 2. metrics plane: exact merge, deterministic quantiles


def test_counter_merge_is_exact():
    regs = [MetricsRegistry(const_labels={"replica": str(i)}) for i in range(3)]
    for i, r in enumerate(regs):
        r.counter("tokens", tenant="web").inc(10 + i)
        r.counter("tokens", tenant="cache").inc(2)
    merged = merge_snapshots([r.snapshot() for r in regs])
    assert sum_counters(merged, "tokens") == (10 + 11 + 12) + 3 * 2
    # replica labels keep the per-host series distinct in the merge
    assert len([k for k in merged.counters if k[0] == "tokens"]) == 6


def test_histogram_quantile_deterministic_and_merge_invariant():
    rng = np.random.default_rng(0)
    values = np.abs(rng.standard_normal(500)) * 10.0
    whole = Histogram()
    parts = [Histogram(), Histogram()]
    for i, v in enumerate(values):
        whole.record(v)
        parts[i % 2].record(v)
    merged = Histogram()
    for p in parts:
        merged.merge(p)
    for q in (0.5, 0.9, 0.99):
        assert whole.quantile(q) == merged.quantile(q)
    assert whole.count == merged.count == 500
    assert whole.sum == pytest.approx(merged.sum)
    # quantile is the bucket upper bound of the rank sample: within one
    # growth factor of the exact rank statistic
    sv = np.sort(values)
    for q in (0.5, 0.99):
        exact = sv[math.ceil(q * len(sv)) - 1]
        assert exact <= whole.quantile(q) <= exact * whole.growth * (1 + 1e-9)


def test_histogram_zero_and_state_roundtrip():
    h = Histogram()
    h.record(0.0, n=5)
    h.record(1.0)
    assert h.quantile(0.5) == 0.0  # rank 3 of 6 sits in the zero bucket
    assert h.quantile(0.99) == 1.0  # exact power lands on its own boundary
    st = h.state()
    assert st["count"] == 6 and st["zero"] == 5
    json.dumps(st)  # JSONL-exportable


def test_registry_snapshot_is_frozen():
    r = MetricsRegistry()
    c = r.counter("x")
    h = r.histogram("h")
    c.inc(3)
    h.record(1.0)
    snap = r.snapshot()
    c.inc(100)
    h.record(50.0)
    assert snap.counters[("x", ())] == 3
    assert snap.histograms[("h", ())].count == 1


# ---------------------------------------------------------------------------
# 3. export schema


def _span(name, trace, t0, t1, **kw):
    return Span(name, trace, t0, t1, **kw)


def test_trace_events_balanced_and_monotone():
    spans = [
        _span("queue", 1, 0.0, 2.0, tenant="web"),
        _span("decode", 1, 2.0, 7.0, tenant="web", replica=0),
        _span("step", -1, 0.0, 1.0, replica=0),
        _span("migrate", -1, 1.0, 1.0, replica=0),
        _span("shed", 2, 0.5, 0.5, tenant="cache", kind="instant"),
    ]
    events = to_trace_events(spans)
    summary = validate_trace_events(events)
    assert summary["spans"] == 4 and summary["instants"] == 1
    ts = [e["ts"] for e in events if e["ph"] != "M"]
    assert ts == sorted(ts)
    for e in events:
        if e["ph"] != "M":
            assert "tenant" in e["args"] and "replica" in e["args"]
    # request tracks live in tenant processes; host spans in host processes
    pids = {e["pid"] for e in events}
    assert 1_000_000 in pids  # host:0


def test_validator_rejects_broken_traces():
    ok = to_trace_events([_span("a", 1, 0.0, 1.0, tenant="t")])
    bad_order = [e.copy() for e in ok]
    bad_order[-1]["ts"] = -5.0
    with pytest.raises(ValueError, match="monotone"):
        validate_trace_events(bad_order)
    unbalanced = [e for e in ok if e["ph"] != "E"]
    with pytest.raises(ValueError, match="unbalanced"):
        validate_trace_events(unbalanced)
    unlabeled = [dict(e, args={}) if e["ph"] != "M" else e for e in ok]
    with pytest.raises(ValueError, match="labels"):
        validate_trace_events(unlabeled)


# ---------------------------------------------------------------------------
# 4. engine-level: observability is free (tokens, books, budget)


def _mk_engine(recorder=None, **ekw):
    cfg = get_config("smollm-360m").reduced()
    if not hasattr(_mk_engine, "_cached"):
        api = get_model(cfg)
        _mk_engine._cached = (api, api.init(jax.random.PRNGKey(0)))
    api, params = _mk_engine._cached
    kw = dict(
        max_batch=4, max_len=64, n_pages=256, near_frac=0.02, placement_window=4,
        device_tiering=True, tiered_identity_scales=True,
    )
    kw.update(ekw)
    return cfg, ServingEngine(api, params, EngineConfig(**kw), seed=0, recorder=recorder)


def _gen(cfg, seed=0):
    prof = dataclasses.replace(
        get_profile("Web1"), prompt_mean=24, decode_mean=8,
        prefix_share=0.5, n_prefixes=2,
    )
    return RequestGenerator(prof, vocab_size=cfg.vocab_size, seed=seed)


# meta-counters that meter the drain operations themselves — they scale
# WITH cadence by design (more drains = more host syncs) and are excluded
# from the cadence-invariance equality below
_SYNC_METERS = ("kv_drains", "kv_host_syncs")


def _counter_totals(engine):
    snap = engine.metrics.snapshot()
    return {k: v for k, v in snap.counters.items() if k[0] not in _SYNC_METERS}


@pytest.mark.slow
def test_recorder_and_drain_cadence_leave_books_identical():
    """Recorder ON + drain every step vs recorder OFF + window drains:
    identical tokens, live_counters, and registry totals — tracing adds no
    dispatches, no syncs, and no accounting drift at any cadence."""
    rec = FlightRecorder()
    cfg, traced = _mk_engine(recorder=rec)
    cfg, plain = _mk_engine(recorder=None)
    assert plain.recorder is None  # no env default leaking in
    g1, g2 = _gen(cfg, seed=5), _gen(cfg, seed=5)
    for _ in range(6):
        traced.submit(next(g1))
        plain.submit(next(g2))
    while (traced.queue or any(s.active for s in traced.slots)) and traced.engine_steps < 200:
        traced.step()
        traced.drain_tier_counters()  # extra per-step drains on the traced one
        plain.step()
    st, sp = traced.stats(), plain.stats()
    assert st["tokens_decoded"] == sp["tokens_decoded"]
    assert st["tenants"] == sp["tenants"]
    assert traced.live_counters() == plain.live_counters()
    assert _counter_totals(traced) == _counter_totals(plain)
    # the sync meters DO see the cadence: per-step drains cost more syncs,
    # and the registry counts them exactly
    assert sum_counters(traced.metrics.snapshot(), "kv_drains") == traced.tiered.drains
    assert traced.tiered.drains > plain.tiered.drains
    # the budget held with tracing on: 1 dispatch/step, syncs only at drains
    assert traced.tiered.dispatches == traced.engine_steps
    # and the recorder actually saw the run
    assert rec.spans.emitted > 0
    assert any(s.name == "decode" for s in rec.spans.finished())


def test_registry_mirrors_legacy_books_exactly():
    cfg, eng = _mk_engine()
    gen = _gen(cfg)
    eng.run(gen, n_requests=6, max_steps=200)
    snap = eng.metrics.snapshot()
    assert sum_counters(snap, "tokens_decoded") == eng.tokens_decoded
    assert sum_counters(snap, "requests_finished") == len(eng.finished)
    assert sum_counters(snap, "prefill_tokens") == eng.prefill_tokens
    assert sum_counters(snap, "near_hits") == eng.placement.stats.near_hits
    assert sum_counters(snap, "far_hits") == eng.placement.stats.far_hits
    assert sum_counters(snap, "kv_dispatches") == eng.tiered.dispatches
    # tenant label dimension partitions the same totals
    assert sum_counters(snap, "tenant_tokens_decoded") == eng.tokens_decoded


# ---------------------------------------------------------------------------
# 5. fleet acceptance: traced straggler+autoscale scenario


@pytest.fixture(scope="module")
def traced_scenario(tmp_path_factory):
    """Seeded multi-tenant straggler+autoscale run with the recorder on."""
    set_default_recorder(None)
    rec = FlightRecorder(metrics_window=8.0)
    fleet = build_fleet(
        2,
        policy="least-loaded",
        n_pages=128,
        trace_window=16,
        trace_period=32,
        speeds=(1.0, 4.0),  # host 1 is a 4x straggler
        admission=AdmissionController(SLOModel(max_delay_steps=16.0)),
        autotier=dict(near_frac=0.3, epoch_steps=4),
        elastic=dict(min_replicas=2, max_replicas=4, cooldown=3.0,
                     up_shed_rate=0.05, up_backlog_frac=0.6,
                     down_backlog_frac=0.15),
        tenant_weights={"web": 2.0, "cache": 1.0},
        recorder=rec,
    )
    prof = dataclasses.replace(
        get_profile("Web1"), prompt_mean=16, decode_mean=6,
        prefix_share=0.8, n_prefixes=3,
    )
    web = RequestGenerator(prof, vocab_size=fleet_vocab(), seed=0, rate=8.0, tenant="web")
    cache = RequestGenerator(
        dataclasses.replace(prof, prefix_share=0.0, prompt_mean=8, decode_mean=4),
        vocab_size=fleet_vocab(), seed=1, rate=24.0, tenant="cache",
    )
    reqs = interleave([cache, web], 48)
    stats = fleet.run(iter(reqs), n_requests=48, max_steps=400, submit_per_step=6)
    out = tmp_path_factory.mktemp("obs") / "fleet_trace.json"
    summary = rec.write(str(out))
    return fleet, rec, stats, summary, out


@pytest.mark.slow
def test_scenario_scaled_and_served(traced_scenario):
    fleet, rec, stats, summary, out = traced_scenario
    assert stats["requests_finished"] > 0
    assert any(e[1] == "up" for e in stats["scale_events"]), stats["scale_events"]


@pytest.mark.slow
def test_scenario_trace_is_perfetto_loadable(traced_scenario):
    fleet, rec, stats, summary, out = traced_scenario
    # write() already ran the schema gate; re-validate the on-disk file
    events = read_trace(str(out))
    s2 = validate_trace_events(events)
    assert s2 == summary
    assert summary["spans"] > 0 and summary["instants"] > 0
    doc = json.loads(out.read_text())
    assert doc["displayTimeUnit"] == "ms"
    names = {e["name"] for e in events}
    # the full request lifecycle + host/fleet story is on the timeline
    for expected in ("admit", "queue", "dispatch", "prefill", "decode",
                     "complete", "step", "scale_up"):
        assert expected in names, f"missing {expected!r} spans"
    # metrics JSONL rode along, one flat row per window + the final row
    rows = [json.loads(l) for l in
            (out.parent / (out.name + ".metrics.jsonl")).read_text().splitlines()]
    assert rows and all("vtime" in r for r in rows)
    assert any(k.startswith("tokens_decoded") for k in rows[-1])


@pytest.mark.slow
def test_scenario_fleet_merge_matches_fleet_stats_bit_exactly(traced_scenario):
    fleet, rec, stats, summary, out = traced_scenario
    merged = fleet.fleet_metrics()
    for key in ("tokens_decoded", "requests_finished", "prefill_tokens",
                "prefill_tokens_saved"):
        assert sum_counters(merged, key) == stats[key], key
    assert sum_counters(merged, "shed") == stats["shed"]
    assert sum_counters(merged, "routed") == stats["routed"]
    near = sum_counters(merged, "near_hits")
    far = sum_counters(merged, "far_hits")
    assert near / max(near + far, 1) == stats["near_hit_rate"]
    # per-tenant partition sums to the fleet totals
    assert sum_counters(merged, "tenant_tokens_decoded") == stats["tokens_decoded"]
    # the aggregator path over exported profiles gives the same engine books
    prof_merge = aggregate_metrics(fleet.export_profiles())
    assert sum_counters(prof_merge, "tokens_decoded") == stats["tokens_decoded"]
    assert sum_counters(prof_merge, "near_hits") == near


@pytest.mark.slow
def test_scenario_wait_percentiles_pin_legacy(traced_scenario):
    """New histogram p50/p99 vs legacy np.percentile over the raw samples:
    within one exponential bucket (and bit-equal on zero waits)."""
    fleet, rec, stats, summary, out = traced_scenario
    rep = fleet.tenant_report()
    growth = 2.0 ** 0.125
    saw_nonzero = False
    for t, waits in fleet.wait_samples.items():
        assert waits, t
        for q, key in ((50, "wait_p50"), (99, "wait_p99")):
            legacy = float(np.percentile(waits, q))
            new = rep[t][key]
            if legacy <= 0.0:
                assert new == 0.0, (t, key)
            else:
                saw_nonzero = True
                # rank statistic the histogram actually answers for
                sv = sorted(waits)
                exact = sv[max(1, math.ceil(q / 100 * len(sv))) - 1]
                if exact <= 0.0:
                    assert new == 0.0, (t, key)
                else:
                    assert exact <= new <= exact * growth * (1 + 1e-9), (t, key, exact, new)
                # and stays within one bucket of the interpolated legacy value
                assert new <= max(legacy, exact) * growth * (1 + 1e-9), (t, key)
    assert saw_nonzero, "scenario produced no queueing — pin is vacuous"


@pytest.mark.slow
def test_scenario_histograms_merge_fleet_wide(traced_scenario):
    fleet, rec, stats, summary, out = traced_scenario
    merged = fleet.fleet_metrics()
    h = merged_histogram(merged, "queue_wait")
    assert h is not None
    assert h.count == sum(len(w) for w in fleet.wait_samples.values())


def test_default_recorder_env_flag(monkeypatch):
    set_default_recorder(None)
    monkeypatch.delenv("REPRO_FLIGHT_RECORDER", raising=False)
    assert default_recorder() is None
    monkeypatch.setenv("REPRO_FLIGHT_RECORDER", "1")
    rec = default_recorder()
    assert rec is not None and default_recorder() is rec
    set_default_recorder(None)
