"""Public SSD op: layout transpose, chunk padding, state threading."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels._interpret import resolve_interpret
from repro.kernels.mamba2_scan.kernel import ssd_chunked_kernel


def ssd_chunked(x, dt, A, B, C, D, state=None, *, chunk: int = 64, interpret: Optional[bool] = None):
    """Model-layout SSD: x (B,T,H,P); dt (B,T,H); A,D (H,); B,C (B,T,N).

    Returns (y (B,T,H,P) f32, final_state (B,H,P,N) f32). Pads T to a chunk
    multiple with identity steps (dt=0: no decay, no input, no output used).
    """
    return _ssd_chunked(
        x, dt, A, B, C, D, state, chunk=chunk, interpret=resolve_interpret(interpret)
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _ssd_chunked(x, dt, A, B, C, D, state, *, chunk, interpret):
    b, t, h, p = x.shape
    n = B.shape[-1]
    if state is None:
        state = jnp.zeros((b, h, p, n), jnp.float32)
    pad = (-t) % chunk
    xk = x.transpose(0, 2, 1, 3).astype(jnp.float32)
    dtk = dt.transpose(0, 2, 1).astype(jnp.float32)
    Bk, Ck = B.astype(jnp.float32), C.astype(jnp.float32)
    if pad:
        xk = jnp.pad(xk, ((0, 0), (0, 0), (0, pad), (0, 0)))
        dtk = jnp.pad(dtk, ((0, 0), (0, 0), (0, pad)))
        Bk = jnp.pad(Bk, ((0, 0), (0, pad), (0, 0)))
        Ck = jnp.pad(Ck, ((0, 0), (0, pad), (0, 0)))
    y, s_out = ssd_chunked_kernel(
        xk, dtk, A.astype(jnp.float32), Bk, Ck, D.astype(jnp.float32),
        state.astype(jnp.float32), chunk=min(chunk, t + pad), interpret=interpret,
    )
    return y[:, :, :t, :].transpose(0, 2, 1, 3), s_out
