"""Deterministic synthetic corpus.

Token stream with (a) a Zipfian unigram marginal — so embedding-row access
skew is realistic for the tiering study (the paper's "few pages serve most
bandwidth" shows up in the embedding table exactly when token frequencies are
Zipf) — and (b) short-range structure (repeated n-grams) so loss actually
falls during the example training runs.

Everything is derived from (seed, shard, index): any host can regenerate any
batch, which is what makes checkpoint/restart and elastic re-sharding exact
(the loader stores only integer cursors).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticCorpus:
    vocab_size: int
    seq_len: int
    seed: int = 0
    zipf_a: float = 1.2
    motif_len: int = 8
    n_motifs: int = 512

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # Zipf-ranked token ids: rank r -> token id perm[r]
        self._perm = rng.permutation(self.vocab_size)
        ranks = np.arange(1, self.vocab_size + 1, dtype=np.float64)
        probs = ranks ** (-self.zipf_a)
        self._probs = probs / probs.sum()
        self._motifs = rng.integers(
            0, self.vocab_size, size=(self.n_motifs, self.motif_len), dtype=np.int64
        )

    def sequence(self, index: int) -> np.ndarray:
        """Deterministic sequence ``index`` -> int32 (seq_len + 1,) tokens."""
        rng = np.random.default_rng((self.seed << 20) ^ (index & 0xFFFFF) ^ (index >> 20))
        n = self.seq_len + 1
        ranks = rng.choice(self.vocab_size, size=n, p=self._probs)
        toks = self._perm[ranks]
        # overwrite ~25% of positions with motifs (predictable structure)
        n_spans = max(1, n // (self.motif_len * 4))
        starts = rng.integers(0, max(1, n - self.motif_len), size=n_spans)
        which = rng.integers(0, self.n_motifs, size=n_spans)
        for s, w in zip(starts, which):
            toks[s : s + self.motif_len] = self._motifs[w][: n - s]
        return toks.astype(np.int32)

    def batch(self, indices: np.ndarray) -> dict:
        seqs = np.stack([self.sequence(int(i)) for i in indices])
        return {"tokens": seqs[:, :-1], "labels": seqs[:, 1:].astype(np.int32)}


def token_batches(corpus: SyntheticCorpus, batch_size: int, start_step: int = 0):
    """Infinite deterministic batch iterator (global indexing)."""
    step = start_step
    while True:
        idx = np.arange(step * batch_size, (step + 1) * batch_size)
        yield step, corpus.batch(idx)
        step += 1
