"""Benchmark suite driver: one benchmark per paper table/figure.

PYTHONPATH=src python -m benchmarks.run            # all
PYTHONPATH=src python -m benchmarks.run table5     # one
"""
import importlib
import os
import sys
import time
import traceback

sys.path.insert(0, os.path.dirname(__file__))  # allow intra-package helpers

MODULES = [
    "fig9_code_bw",
    "table2_correlation",
    "fig13_pooling",
    "fig17_pagetable",
    "fig18_membw_dist",
    "table5_tiering",
    "fig21_prefetch_bw",
    "fig22_prefetch_acc",
    "table6_trace",
    "fleet_bench",
    "straggler_bench",
    "tenant_interference",
    "tiered_decode_bench",
    "decode_dispatch_bench",
    "kernels_bench",
]


def main(argv):
    sel = [m for m in MODULES if not argv or any(a in m for a in argv)]
    if argv and not sel:
        print(f"no benchmark matches {argv}; available: {MODULES}")
        return 2
    failures = []
    for name in sel:
        print("\n" + "=" * 78)
        t0 = time.time()
        try:
            mod = importlib.import_module(name)
            rc = mod.main()
            # benchmarks return result dicts on success; an int is a
            # process-style return code (fleet_bench's self-check)
            if isinstance(rc, int) and rc != 0:
                failures.append(name)
                print(f"[{name}] FAILED: main() returned {rc}")
            else:
                print(f"[{name}] ok in {time.time()-t0:.1f}s")
        except Exception:  # noqa: BLE001
            failures.append(name)
            print(f"[{name}] FAILED:\n{traceback.format_exc(limit=6)}")
    print("\n" + "=" * 78)
    print(f"benchmarks: {len(sel) - len(failures)}/{len(sel)} ok" + (f"; failed: {failures}" if failures else ""))
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
