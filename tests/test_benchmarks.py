"""Benchmark registry smoke: every module benchmarks/run.py lists must
import cleanly and expose a callable ``main`` — a typo'd registration or an
import-time crash should fail here, not in CI's benchmark stage."""
import importlib
import pathlib
import sys

import pytest

BENCH_DIR = pathlib.Path(__file__).resolve().parents[1] / "benchmarks"
sys.path.insert(0, str(BENCH_DIR))

import run as bench_run  # noqa: E402


def test_registry_names_resolve_to_files():
    for name in bench_run.MODULES:
        assert (BENCH_DIR / f"{name}.py").is_file(), name


def test_tenant_interference_is_registered():
    assert "tenant_interference" in bench_run.MODULES


def test_tiered_decode_bench_is_registered():
    assert "tiered_decode_bench" in bench_run.MODULES


@pytest.mark.parametrize("name", bench_run.MODULES)
def test_registered_benchmark_importable_and_callable(name):
    mod = importlib.import_module(name)
    assert hasattr(mod, "main"), f"{name} has no main()"
    assert callable(mod.main)


def test_selector_rejects_unknown_benchmark():
    assert bench_run.main(["no-such-benchmark"]) == 2
