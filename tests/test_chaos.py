"""Chaos-ready fleet: deterministic fault injection, failover, recovery.

The contracts this file pins (ISSUE 10 acceptance):

1. Determinism anchors: two identical-seed chaos runs are bit-identical
   (fault log, fleet books, outcome ledger), and a ZERO-fault chaos config
   — watchdog armed, no events — is bit-exact with the plain event-driven
   path (cancelled timeout events leave no trace in the event order).
2. Crash accounting: after a mid-burst kill the merged fleet counters
   reconcile — the host-visible books survive through the last drain
   boundary, the undrained remainder is quantified as ``lost_window`` +
   per-tenant ``lost_tokens``, never silently dropped — and the split is
   drain-cadence-invariant.
3. Bounded termination: a hung replica cannot wedge ``router.run`` — the
   per-dispatch watchdog fails it over within ``dispatch_timeout`` of
   virtual time (satellite regression: this used to hang forever).
4. No silent drops: every admitted rid ends ``completed``, ``shed`` or
   ``failed:<reason>``; a slow-but-alive host's late completion is dedup-
   guarded so retried work is never double-counted.
5. Degraded mode: a host whose near tier is capacity-zeroed keeps serving
   far-tier-only; epoch-fenced ``apply_placement`` rejects plans staled by
   the transition.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.configs.workloads import get_profile
from repro.data.requests import RequestGenerator
from repro.fleet import (
    AdmissionController,
    ChaosEngine,
    FaultEvent,
    SLOModel,
    build_fleet,
    fleet_vocab,
)


def _profile(**kw):
    base = dict(prompt_mean=24, decode_mean=6, prefix_share=0.9, n_prefixes=3)
    base.update(kw)
    return dataclasses.replace(get_profile("Web1"), **base)


def _fleet(n=2, **kw):
    base = dict(
        policy="least-loaded",
        seed=0,
        trace_window=16,
        trace_period=32,
        autotier=dict(near_frac=0.30, epoch_steps=8),
    )
    base.update(kw)
    return build_fleet(n, **base)


def _run(fleet, n_requests=12, seed=0, max_steps=400, submit_per_step=3):
    gen = RequestGenerator(_profile(), vocab_size=fleet_vocab(), seed=seed)
    return fleet.run(
        gen, n_requests=n_requests, max_steps=max_steps,
        submit_per_step=submit_per_step,
    )


def _norm(stats):
    """Comparable fleet books: everything but the per-host object dumps."""
    d = {k: v for k, v in stats.items() if k not in ("per_replica", "retired_replicas")}
    return json.dumps(d, sort_keys=True, default=str)


# ---------------------------------------------------------------------------
# 1. determinism anchors


def test_zero_fault_chaos_bit_exact_with_plain_event_path():
    """The armed watchdog posts a timeout per dispatched step; every on-time
    completion cancels its own. Cancelled events are swept without running,
    without advancing the clock, without forming a batch — so the chaos
    config with no faults must reproduce the vanilla run bit for bit."""
    plain = _fleet()
    s_plain = _run(plain)
    chaotic = _fleet()
    ChaosEngine(chaotic, [], dispatch_timeout=50.0)
    s_chaos = _run(chaotic)
    assert _norm(s_plain) == _norm(s_chaos)
    assert chaotic.chaos.log == []
    # the cancelled timeouts really existed (and really left no trace)
    assert chaotic.scheduler.events_cancelled > 0
    assert s_plain["sched_events"] == s_chaos["sched_events"] if "sched_events" in s_plain else True
    assert chaotic.metrics.total("sched_events") == plain.metrics.total("sched_events")
    assert chaotic.metrics.total("sched_batches") == plain.metrics.total("sched_batches")


@pytest.mark.slow
def test_identical_seed_chaos_runs_bit_identical():
    def one(seed):
        fleet = _fleet()
        ChaosEngine.seeded(fleet, seed=seed, n_faults=3, horizon=30.0,
                           dispatch_timeout=10.0)
        stats = _run(fleet)
        return _norm(stats), list(fleet.chaos.log), fleet.outcome_report()

    a, b = one(7), one(7)
    assert a == b
    assert a[1], "seeded scenario injected nothing"
    # a different seed is a different run (sanity that the anchor bites)
    c = one(8)
    assert c[1] != a[1]


def test_seeded_scenario_is_a_pure_function_of_seed():
    f1, f2 = _fleet(), _fleet()
    e1 = ChaosEngine.seeded(f1, seed=3).events
    e2 = ChaosEngine.seeded(f2, seed=3).events
    assert e1 == e2 and len(e1) == 3


# ---------------------------------------------------------------------------
# 2. crash accounting


def test_mid_burst_kill_reconciles_books():
    fleet = _fleet()
    ChaosEngine(fleet, [FaultEvent(5.0, "crash", rid=1)], dispatch_timeout=50.0)
    stats = _run(fleet)
    rep = fleet.outcome_report()
    assert rep["complete"], rep
    assert stats["crashed_replicas"] == [1]
    assert stats["failovers"] == 1
    assert stats["requests_retried"] > 0
    # the loss is quantified, not silent: one lost window for the victim,
    # and the discarded in-flight decode progress is on the books
    (lw,) = stats["lost_windows"]
    assert lw["rid"] == 1 and lw["vtime"] == 5.0
    assert lw["lost_decode_tokens"] == stats["lost_tokens"]
    # everything completed despite the kill; the fleet totals count every
    # request exactly once (the dedup guard: no double-completions)
    assert rep["outcomes"] == {"completed": 12}
    assert stats["requests_finished"] == 12
    # the dead host's salvaged history stays in the fleet merge
    assert any(p.rid == 1 for p in fleet.export_profiles())
    assert fleet.crashed_stats[0]["crashed"] is True


@pytest.mark.slow
def test_crash_loss_split_is_drain_cadence_invariant():
    """Same kill under default cadence vs drain-every-batch: the fleet
    books agree, and the every-batch run's lost window is empty — what a
    crash destroys is EXACTLY the undrained remainder."""

    def one(drain_every_batch):
        fleet = _fleet()
        if drain_every_batch:
            fleet.on_step.append(
                lambda now: [r.engine.drain_tier_counters() for r in fleet.replicas]
            )
        ChaosEngine(fleet, [FaultEvent(5.0, "crash", rid=1)], dispatch_timeout=50.0)
        stats = _run(fleet)
        return fleet, stats

    f_win, s_win = one(False)
    f_all, s_all = one(True)
    for k in ("tokens_decoded", "requests_finished", "prefill_tokens",
              "near_hit_rate", "lost_tokens", "virtual_time"):
        assert s_win[k] == s_all[k], k
    # faults strike before the completions of their batch, so nothing ran
    # on the victim since the last quiescent drain: zero undrained steps
    assert s_all["lost_windows"][0]["steps_undrained"] == 0
    assert s_all["lost_windows"][0]["near"] == 0
    assert s_all["lost_windows"][0]["far"] == 0


@pytest.mark.slow
def test_crash_with_replacement_host():
    fleet = _fleet(elastic=dict(min_replicas=1, max_replicas=4, cooldown=1e9))
    ChaosEngine(
        fleet, [FaultEvent(5.0, "crash", rid=0, duration=6.0)], dispatch_timeout=50.0
    )
    stats = _run(fleet)
    actions = [e.action for e in fleet.elastic.events]
    assert "crash" in actions and "up" in actions
    # the replacement is a NEW host (fresh rid), recovery span on the books
    up = [e for e in fleet.elastic.events if e.action == "up"]
    assert up[0].rid == 2 and up[0].vtime == 11.0
    assert [a for (_, a, _, ok) in fleet.chaos.log if ok] == ["crash", "crash_recover"]
    assert fleet.outcome_report()["complete"]
    assert stats["requests_finished"] == 12


# ---------------------------------------------------------------------------
# 3. bounded termination under hangs (satellite regression)


def test_hung_replica_fails_over_within_timeout():
    """Regression: a hang used to leave the router waiting on a completion
    event that never fires. The watchdog converts it into a failover within
    ``dispatch_timeout`` of virtual time and the run terminates."""
    fleet = _fleet()
    ChaosEngine(fleet, [FaultEvent(4.0, "hang", rid=0)], dispatch_timeout=6.0)
    stats = _run(fleet)
    rep = fleet.outcome_report()
    assert stats["failovers"] == 1
    assert rep["complete"], rep
    # the failover happened at hang detection time, not at the horizon
    failures = [t for (t, a, r, ok) in fleet.chaos.log if a == "hang"]
    assert failures == [4.0]
    assert stats["virtual_time"] < 400
    # the hung host is quarantined, still listed, served nothing after
    assert any(r.rid == 0 and r.hung for r in fleet.replicas)


def test_hang_of_last_replica_still_terminates():
    """Even when every host is gone the run must end in bounded virtual
    time — the queue simply stays pending (reported, not dropped)."""
    fleet = _fleet(n=1, autotier=None)
    ChaosEngine(fleet, [FaultEvent(3.0, "hang", rid=0)], dispatch_timeout=4.0,
                max_retries=1, retry_backoff=1.0)
    stats = _run(fleet, n_requests=8)
    rep = fleet.outcome_report()
    assert not rep["complete"]
    # nothing silently vanished: every admitted rid is completed, failed,
    # or explicitly listed pending
    accounted = rep["outcomes"].get("completed", 0) + rep["outcomes"].get(
        "failed", 0
    ) + len(rep["pending"])
    assert accounted == rep["admitted"]


@pytest.mark.slow
def test_transient_stall_recovers_without_failover():
    """A hang whose recovery lands before the watchdog fires is a stall:
    the host resumes with its slots intact, no retry, no lost tokens."""
    fleet = _fleet()
    ChaosEngine(
        fleet, [FaultEvent(4.0, "hang", rid=0, duration=3.0)], dispatch_timeout=20.0
    )
    stats = _run(fleet)
    assert stats["failovers"] == 0
    assert stats["lost_tokens"] == 0 and stats["requests_retried"] == 0
    assert [a for (_, a, _, ok) in fleet.chaos.log if ok] == ["hang", "hang_recover"]
    assert fleet.outcome_report()["outcomes"] == {"completed": 12}
    # the stalled step was dedup-suppressed, then re-run after recovery:
    # total completions still count each request exactly once
    assert stats["requests_finished"] == 12


def test_retry_budget_exhaustion_fails_with_reason():
    fleet = _fleet(n=1, autotier=None)
    ChaosEngine(fleet, [FaultEvent(3.0, "crash", rid=0)], dispatch_timeout=50.0,
                max_retries=0)
    _run(fleet, n_requests=6)
    rep = fleet.outcome_report()
    assert rep["complete"], rep
    assert rep["outcomes"].get("failed", 0) > 0
    assert all(o == "failed:crash" for o in rep["failed"].values())


# ---------------------------------------------------------------------------
# 4. slowdown + correlated faults


@pytest.mark.slow
def test_slowdown_is_transient_and_deterministic():
    fleet = _fleet()
    ChaosEngine(
        fleet,
        [FaultEvent(3.0, "slowdown", rid=1, duration=10.0, factor=4.0)],
        dispatch_timeout=80.0,
    )
    victim = fleet.replicas[1]
    stats = _run(fleet)
    assert victim.speed == 1.0  # restored
    assert fleet.outcome_report()["complete"]
    assert stats["failovers"] == 0  # a straggler is not a failure


@pytest.mark.slow
def test_correlated_faults_share_one_batch():
    """Two faults at the same timestamp strike together, before any
    completion of that batch — a correlated multi-host failure."""
    fleet = _fleet(n=3)
    ChaosEngine(
        fleet,
        [FaultEvent(5.0, "crash", rid=1), FaultEvent(5.0, "crash", rid=2)],
        dispatch_timeout=50.0,
    )
    stats = _run(fleet)
    assert [(t, a, r) for (t, a, r, _) in fleet.chaos.log] == [
        (5.0, "crash", 1), (5.0, "crash", 2)
    ]
    assert sorted(stats["crashed_replicas"]) == [1, 2]
    assert fleet.outcome_report()["complete"]


@pytest.mark.slow
def test_fault_on_already_dead_host_is_logged_not_applied():
    fleet = _fleet()
    ChaosEngine(
        fleet,
        [FaultEvent(5.0, "crash", rid=1), FaultEvent(6.0, "hang", rid=1)],
        dispatch_timeout=50.0,
    )
    _run(fleet)
    assert fleet.chaos.log[0][3] is True  # crash applied
    assert fleet.chaos.log[1][3] is False  # hang found the host gone


# ---------------------------------------------------------------------------
# 5. degraded mode + epoch fencing


@pytest.mark.slow
def test_degrade_serves_far_tier_only_then_recovers():
    fleet = _fleet()
    ChaosEngine(
        fleet,
        [FaultEvent(3.0, "degrade", rid=0, duration=12.0)],
        dispatch_timeout=80.0,
    )
    victim = fleet.replicas[0]
    stats = _run(fleet)
    assert fleet.outcome_report()["complete"]
    # the mode transition is on the books, and the host kept serving
    assert victim.engine.metrics.total("degraded_entries") == 1
    assert not victim.engine.degraded  # recovered
    assert stats["requests_finished"] == 12
    log = [a for (_, a, _, ok) in fleet.chaos.log if ok]
    assert log == ["degrade", "degrade_recover"]


def test_degraded_engine_near_tier_is_empty_and_pushes_rejected():
    fleet = _fleet()
    eng = fleet.replicas[0].engine
    _run(fleet, n_requests=6)
    assert (eng.placement.tier == 0).sum() > 0  # near tier in use
    eng.enter_degraded()
    assert (eng.placement.tier == 0).sum() == 0  # capacity-zeroed
    # external pushes bounce while degraded
    assert fleet.replicas[0].apply_placement(np.arange(4)) == 0
    assert eng.metrics.total("placement_rejected") == 1
    eng.exit_degraded()
    # near set stays empty until the next epoch refills it — recovery is a
    # planning decision, not a blind restore
    assert (eng.placement.tier == 0).sum() == 0


def test_stale_epoch_placement_fenced():
    fleet = _fleet()
    r = fleet.replicas[0]
    _run(fleet, n_requests=6)
    epoch = fleet.autotierer.epoch_seq
    assert epoch > 0
    r.engine.fence_placement(epoch)
    # a plan stamped at (or before) the fence predates the transition
    assert r.apply_placement(np.arange(4), epoch=epoch) == 0
    assert r.engine.metrics.total("placement_rejected") == 1
    # the next epoch clears the fence
    assert r.apply_placement(np.arange(4), epoch=epoch + 1) >= 0
    assert r.engine.metrics.total("placement_rejected") == 1


@pytest.mark.slow
def test_degrade_fences_in_flight_epoch():
    """A degrade mid-run fences the epoch that was current when it struck:
    a push planned from pre-fault profiles can never land post-recovery."""
    fleet = _fleet()
    ChaosEngine(
        fleet,
        [FaultEvent(9.0, "degrade", rid=0, duration=4.0)],
        dispatch_timeout=80.0,
    )
    victim = fleet.replicas[0]
    _run(fleet)
    fence = victim.engine._placement_fence
    assert fence > 0
    # a plan stamped from the pre-fault profile set bounces off the fence;
    # the next planned epoch lands
    rejected_before = victim.engine.metrics.total("placement_rejected")
    assert victim.apply_placement(np.arange(4), epoch=fence) == 0
    assert victim.engine.metrics.total("placement_rejected") == rejected_before + 1
    assert victim.apply_placement(np.arange(4), epoch=fence + 1) >= 0
    assert victim.engine.metrics.total("placement_rejected") == rejected_before + 1


# ---------------------------------------------------------------------------
# 6. per-tenant fault attribution + lockstep guard


@pytest.mark.slow
def test_tenant_report_carries_fault_columns():
    fleet = _fleet()
    ChaosEngine(fleet, [FaultEvent(5.0, "crash", rid=1)], dispatch_timeout=50.0)
    stats = _run(fleet)
    tr = stats["tenants"]["default"]
    assert tr.get("failovers", 0) >= 1
    assert tr.get("retries", 0) >= 1
    assert tr.get("lost_tokens", 0) == stats["lost_tokens"]
    # fault-free tenant reports carry NO fault columns (equivalence surface)
    clean = _fleet()
    s2 = _run(clean)
    assert "failovers" not in s2["tenants"]["default"]


def test_lockstep_mode_rejects_fault_scenarios():
    fleet = _fleet()
    ChaosEngine(fleet, [FaultEvent(5.0, "crash", rid=1)])
    gen = RequestGenerator(_profile(), vocab_size=fleet_vocab(), seed=0)
    with pytest.raises(ValueError, match="lockstep"):
        fleet.run(gen, n_requests=4, max_steps=50, lockstep=True)


@pytest.mark.slow
def test_recorder_sees_fault_retry_failover_spans():
    from repro.obs import FlightRecorder

    fleet = _fleet(recorder=FlightRecorder(capacity=4096, step_spans=False))
    ChaosEngine(fleet, [FaultEvent(5.0, "crash", rid=1)], dispatch_timeout=50.0)
    _run(fleet)
    names = {s.name for s in fleet.recorder.spans.finished()}
    assert {"fault", "failover", "retry"} <= names
    snap = fleet.fleet_metrics()
    assert sum(v for (n, _), v in snap.counters.items() if n == "retries") > 0
    assert sum(v for (n, _), v in snap.counters.items() if n == "faults") > 0
