"""Paper Fig. 21: IPC and memory-BW change with L2 prefetchers on.

Tiered-serving analogue over each workload's measured block stream: far-tier
demand stalls (IPC proxy: every uncovered far access stalls the decode step)
and TOTAL far-tier traffic, prefetcher off vs on. The paper's point — modest
IPC gain, significant extra bandwidth (e.g. Cache1 +31%) — appears whenever
coverage is low but the prefetcher keeps issuing.
"""
import numpy as np

from repro.core.placement import TieredPlacement
from repro.core.prefetch import PrefetchEngine

from _common import fmt_table, stream_for


def _run(stream, n_blocks, predictor):
    pl = TieredPlacement(n_blocks=n_blocks, near_capacity=max(n_blocks // 10, 1))
    pl.plan_initial(np.bincount(stream[:2000], minlength=n_blocks))
    eng = PrefetchEngine(predictor=predictor, buffer_blocks=256, degree=2)
    tier = pl.tier
    for b in stream:
        eng.access(int(b), is_far=bool(tier[b] == 1))
    s = eng.stats
    stalls = s.demand_fetches
    traffic = s.total_prefetched + s.demand_fetches
    return stalls, traffic


def main():
    rows = []
    out = {}
    for wl in ("Web1", "Ads1", "Cache1", "Feed", "Reader"):
        stream, prof = stream_for(wl, n=30_000)
        st0, t0 = _run(stream, prof.n_blocks, "off")
        st1, t1 = _run(stream, prof.n_blocks, "nextline")
        ipc_gain = (st0 - st1) / max(st0, 1) * 100.0
        bw_incr = (t1 - t0) / max(t0, 1) * 100.0
        rows.append((wl, st0, st1, f"{ipc_gain:+6.1f}%", f"{bw_incr:+6.1f}%"))
        out[wl] = (ipc_gain, bw_incr)
    print("[fig21] far-tier demand stalls + total far traffic, prefetch off -> on (nextline)")
    print(fmt_table(rows, ["workload", "stalls(off)", "stalls(on)", "stall reduction", "BW increase"]))
    print("paper Fig.21: small IPC gains, significant BW increase (Cache1 +31%)")
    return out


if __name__ == "__main__":
    main()
