from repro.models.api import ModelAPI, get_model, make_prefill_step, make_serve_step, make_train_step  # noqa: F401
