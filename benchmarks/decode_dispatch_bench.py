"""Dispatch/sync budget of the tiered decode step: per-slot vs segmented.

The serving engine's hot path used to issue one tiered-gather kernel launch
PER ACTIVE SLOT per decode step, each blocking on an `int(near), int(far)`
counter readback — 8-32 dispatches + host syncs where one would do. The
segmented path (EngineConfig.segmented_lookup, the default) concatenates
every active slot's page ids into ONE ragged kernel pass with per-segment
hit counts accumulated in a device counter plane, drained once per profiler
window. This bench runs the SAME workload through both paths at two slot
counts and reports:

  * tokens/s            — end-to-end decode throughput (wall clock);
  * dispatches-per-step — tiered-gather kernel launches per engine step
                          (segmented: exactly 1; per-slot: ~active slots);
  * host-syncs-per-step — counter-plane round-trips per engine step
                          (segmented: 1/placement_window; per-slot: ~slots).

The continuous-batching cell (``continuous_batching`` in the JSON) runs the
SAME sustained open-loop offered load — deep queue, long-prompt mix —
through the whole-slot engine (monolithic ``api.prefill`` per admit) and
the chunked engine (``prefill_chunk`` > 0: prefill chunks interleaved with
decode inside the step's single dispatch) and reports wall tokens/s plus
p99 time-to-first-token. The chunked win is structural, and honest about
its mechanism: the whole-slot path pays one extra blocking model dispatch
per admit (its admit argmax is a host sync) and an XLA compile per
distinct prompt length, while the chunked engine only ever runs two decode
shapes — (B, 1) and (B, C) — and admits with zero host syncs. Chunked
TTFT is stamped when the engine observes the first token's dispatch (its
step pipeline never blocks), whole-slot TTFT at its admit-time sync; both
are the earliest instant each engine design can know the token exists.

Emits ``BENCH_decode.json`` next to this file — the decode dispatch-budget
baseline the next perf PR regresses against. Self-checks: the segmented
path must hold the 1-dispatch budget and beat the per-slot baseline by
>=1.3x tokens/s at the larger slot count, and continuous batching must
beat whole-slot on BOTH tokens/s and p99 TTFT under offered load.
"""
import dataclasses
import json
import pathlib
import time

import jax
import numpy as np

from repro.configs.workloads import get_profile
from repro.data.requests import RequestGenerator

from _common import engine_for, fmt_table

SLOT_COUNTS = (4, 16)
MODES = ("per-slot", "segmented")
# offered-load sweep: requests submitted open-loop per engine step
OFFERED_LOADS = (1, 2)
CHUNK = 16
# acceptance: segmented beats per-slot at the larger slot count. The floor
# dropped from 1.3 when the prefetch accounting both paths pay per step was
# vectorized (access_many): the per-slot baseline is host-bound, so cutting
# shared host time sped IT up disproportionately and compressed the ratio
# (segmented tok/s itself did not regress — see BENCH_decode.json history)
SPEEDUP_FLOOR = 1.15


def _run(mode: str, n_slots: int, n_requests=None, seed=0):
    cfg, eng = engine_for(
        seed=seed,
        max_batch=n_slots,
        max_len=96,
        n_pages=1024,
        near_frac=0.05,
        placement_window=8,
        device_tiering=True,
        segmented_lookup=(mode == "segmented"),
    )
    # long prompts + enough requests to keep every slot busy: the budget
    # gap is per active slot, so the bench must actually fill the batch
    n_requests = n_requests if n_requests is not None else 3 * n_slots
    prof = dataclasses.replace(
        get_profile("Web1"), prompt_mean=64, decode_mean=12,
        prefix_share=0.5, n_prefixes=2,
    )
    gen = RequestGenerator(prof, vocab_size=cfg.vocab_size, seed=seed)
    t0 = time.time()
    stats = eng.run(gen, n_requests=n_requests, max_steps=3000)
    dt = time.time() - t0
    dev = stats["device_tiering"]
    return {
        "tokens": stats["tokens_decoded"],
        "steps": eng.engine_steps,
        "tokens_per_s": stats["tokens_decoded"] / max(dt, 1e-9),
        "dispatches_per_step": dev["dispatches_per_step"],
        "host_syncs_per_step": dev["host_syncs_per_step"],
        "near_hit_rate": stats["near_hit_rate"],
    }


def _access_many_microbench(n_slots=16, n_steps=120, chain=56, n_pages=4096):
    """Host-side prefetch accounting on the decode hot path: the engine
    feeds every active slot's FULL page walk to the prefetcher each step.
    Replays the same growing walks through the vectorized ``access_many``
    and through the retired per-element ``access`` loop it replaced, and
    reports per-step host time for each."""
    from repro.core.prefetch import PrefetchEngine

    rng = np.random.default_rng(0)
    walks = [rng.permutation(n_pages)[:chain].astype(np.int64) for _ in range(n_slots)]
    tier = (rng.random(n_pages) < 0.7).astype(np.int8)  # 70% far

    def drive(vectorized: bool) -> float:
        eng = PrefetchEngine(predictor="trace", buffer_blocks=128, degree=2)
        t0 = time.time()
        for step in range(n_steps):
            ln = 8 + step * (chain - 8) // max(n_steps - 1, 1)
            for s, w in enumerate(walks):
                pages = w[:ln]
                fm = tier[pages] == 1
                if vectorized:
                    eng.access_many(pages, fm, stream=s)
                else:
                    for p, f in zip(pages.tolist(), fm.tolist()):
                        eng.access(p, is_far=f, stream=s)
        return (time.time() - t0) / n_steps

    scalar_s = drive(vectorized=False)
    vec_s = drive(vectorized=True)
    return {
        "scalar_us_per_step": scalar_s * 1e6,
        "vectorized_us_per_step": vec_s * 1e6,
        "speedup": scalar_s / max(vec_s, 1e-12),
        "slots": n_slots,
        "walk_pages": chain,
    }


def _run_offered(mode: str, rate: int, n_requests=48, seed=0):
    """Sustained open-loop offered load: ``rate`` submits per engine step
    from a long-prompt mix, measured wall-clock end to end (final state
    block_until_ready'd so async dispatches are paid inside the window)."""
    cfg, eng = engine_for(
        seed=seed,
        max_batch=16,
        max_len=96,
        n_pages=1024,
        near_frac=0.05,
        placement_window=8,
        device_tiering=True,
        segmented_lookup=True,
        prefill_chunk=(CHUNK if mode == "chunked" else 0),
    )
    prof = dataclasses.replace(
        get_profile("Web1"), prompt_mean=64, decode_mean=12,
        prefix_share=0.5, n_prefixes=2,
    )
    gen = RequestGenerator(prof, vocab_size=cfg.vocab_size, seed=seed)
    reqs = [next(gen) for _ in range(n_requests)]
    t0 = time.time()
    submitted = step = 0
    while submitted < len(reqs) or eng.queue or any(s.active for s in eng.slots):
        while submitted < len(reqs) and submitted < rate * (step + 1):
            eng.submit(reqs[submitted])
            submitted += 1
        eng.step()
        step += 1
        if step > 4000:
            break
    jax.block_until_ready(eng.next_tokens)
    dt = time.time() - t0
    ttft = np.asarray(eng.ttft_wall_samples)
    sv = eng.stats()["serving"]
    return {
        "tokens": eng.tokens_decoded,
        "steps": eng.engine_steps,
        "tokens_per_s": eng.tokens_decoded / max(dt, 1e-9),
        "ttft_p50_ms": float(np.percentile(ttft, 50)) * 1e3 if ttft.size else 0.0,
        "ttft_p99_ms": float(np.percentile(ttft, 99)) * 1e3 if ttft.size else 0.0,
        "ttft_count": int(ttft.size),
        "model_dispatches_per_step": sv["model_dispatches_per_step"],
        "prefill_dispatches": sv["prefill_dispatches"],
    }


def main():
    # untimed warm-up: pay model-decode + kernel compilation for every
    # (batch, path) shape outside the timed cells
    for n_slots in SLOT_COUNTS:
        for mode in MODES:
            _run(mode, n_slots, n_requests=2)
    rows, out = [], {}
    for n_slots in SLOT_COUNTS:
        for mode in MODES:
            r = _run(mode, n_slots)
            out[f"{mode}@{n_slots}"] = r
            rows.append(
                (
                    n_slots,
                    mode,
                    f"{r['tokens_per_s']:8.1f}",
                    f"{r['dispatches_per_step']:.2f}",
                    f"{r['host_syncs_per_step']:.3f}",
                    r["tokens"],
                )
            )
    print("[decode_dispatch] per-slot vs segmented tiered decode")
    print(
        fmt_table(
            rows,
            ["slots", "path", "tok/s", "disp/step", "syncs/step", "tokens"],
        )
    )
    speedups = {
        n: out[f"segmented@{n}"]["tokens_per_s"] / max(out[f"per-slot@{n}"]["tokens_per_s"], 1e-9)
        for n in SLOT_COUNTS
    }
    for n, s in speedups.items():
        print(f"segmented speedup at {n} slots: {s:.2f}x")
    am = _access_many_microbench()
    print(
        f"prefetch accounting ({am['slots']} slots x {am['walk_pages']}-page walks): "
        f"per-element loop {am['scalar_us_per_step']:.0f}us/step vs vectorized "
        f"access_many {am['vectorized_us_per_step']:.0f}us/step "
        f"({am['speedup']:.1f}x)"
    )
    # continuous batching under sustained open-loop offered load: untimed
    # warm-up pays each engine's compile shapes, then the timed sweep
    for cb_mode in ("whole-slot", "chunked"):
        _run_offered(cb_mode, rate=OFFERED_LOADS[0], n_requests=4)
    cb = {}
    cb_rows = []
    for rate in OFFERED_LOADS:
        for cb_mode in ("whole-slot", "chunked"):
            r = _run_offered(cb_mode, rate)
            cb[f"{cb_mode}@load{rate}"] = r
            cb_rows.append(
                (
                    rate,
                    cb_mode,
                    f"{r['tokens_per_s']:8.1f}",
                    f"{r['ttft_p50_ms']:7.1f}",
                    f"{r['ttft_p99_ms']:7.1f}",
                    f"{r['model_dispatches_per_step']:.2f}",
                )
            )
    print("[decode_dispatch] continuous batching under open-loop offered load")
    print(
        fmt_table(
            cb_rows,
            ["req/step", "engine", "tok/s", "ttft_p50_ms", "ttft_p99_ms", "disp/step"],
        )
    )
    baseline = {
        "results": out,
        "speedups": {str(n): s for n, s in speedups.items()},
        "slot_counts": list(SLOT_COUNTS),
        "access_many": am,
        "continuous_batching": cb,
        "offered_loads": list(OFFERED_LOADS),
        "prefill_chunk": CHUNK,
    }
    path = pathlib.Path(__file__).resolve().parent / "BENCH_decode.json"
    path.write_text(json.dumps(baseline, indent=2, sort_keys=True) + "\n")
    print(f"baseline written to {path}")
    # self-checks: the budget and the payoff
    for n in SLOT_COUNTS:
        seg = out[f"segmented@{n}"]
        if not seg["dispatches_per_step"] <= 1.0 + 1e-9:
            print(f"[decode_dispatch] FAILED: segmented path broke the "
                  f"1-dispatch budget at {n} slots ({seg['dispatches_per_step']:.2f})")
            return 1
        if not seg["host_syncs_per_step"] < 1.0:
            print(f"[decode_dispatch] FAILED: segmented path syncs every "
                  f"step at {n} slots ({seg['host_syncs_per_step']:.2f})")
            return 1
    big = SLOT_COUNTS[-1]
    if speedups[big] < SPEEDUP_FLOOR:
        print(f"[decode_dispatch] FAILED: segmented only {speedups[big]:.2f}x "
              f"per-slot at {big} slots (need >= {SPEEDUP_FLOOR}x)")
        return 1
    if not am["speedup"] > 1.0:
        print(f"[decode_dispatch] FAILED: vectorized access_many slower than "
              f"the per-element loop ({am['speedup']:.2f}x)")
        return 1
    # continuous batching must win BOTH axes at the sustained load
    hi = OFFERED_LOADS[-1]
    ws, ch = cb[f"whole-slot@load{hi}"], cb[f"chunked@load{hi}"]
    if not ch["tokens_per_s"] > ws["tokens_per_s"]:
        print(f"[decode_dispatch] FAILED: chunked tokens/s "
              f"{ch['tokens_per_s']:.1f} <= whole-slot {ws['tokens_per_s']:.1f}")
        return 1
    if not ch["ttft_p99_ms"] < ws["ttft_p99_ms"]:
        print(f"[decode_dispatch] FAILED: chunked p99 TTFT "
              f"{ch['ttft_p99_ms']:.1f}ms >= whole-slot {ws['ttft_p99_ms']:.1f}ms")
        return 1
    if ch["model_dispatches_per_step"] > 1.0 + 1e-9 or ch["prefill_dispatches"] != 0:
        print("[decode_dispatch] FAILED: chunked engine broke the "
              "1-model-dispatch/step budget under offered load")
        return 1
    return baseline


if __name__ == "__main__":
    rc = main()
    raise SystemExit(rc if isinstance(rc, int) else 0)
