"""Public WKV6 op: layout transpose, chunk padding, state threading."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels._interpret import resolve_interpret
from repro.kernels.rwkv6_scan.kernel import wkv6_chunked_kernel


def wkv6_chunked(r, k, v, lw, u, state=None, *, chunk: int = 64, interpret: Optional[bool] = None):
    """Model-layout WKV6: r/k/v/lw (B, T, H, hd); u (H, hd); state (B,H,hd,hd).

    Returns (y (B,T,H,hd) f32, final_state). Pads T to a chunk multiple with
    identity steps (w=1, k=v=r=0: no state change, no output contribution).
    """
    return _wkv6_chunked(
        r, k, v, lw, u, state, chunk=chunk, interpret=resolve_interpret(interpret)
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _wkv6_chunked(r, k, v, lw, u, state, *, chunk, interpret):
    b, t, h, hd = r.shape
    if state is None:
        state = jnp.zeros((b, h, hd, hd), jnp.float32)
    pad = (-t) % chunk

    def to_bhtd(x, fill=0.0):
        x = x.transpose(0, 2, 1, 3)  # (B,H,T,hd)
        if pad:
            x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)), constant_values=fill)
        return x.astype(jnp.float32)

    y, s_out = wkv6_chunked_kernel(
        to_bhtd(r), to_bhtd(k), to_bhtd(v), to_bhtd(lw), u.astype(jnp.float32),
        state.astype(jnp.float32), chunk=min(chunk, t + pad), interpret=interpret,
    )
    return y[:, :, :t, :].transpose(0, 2, 1, 3), s_out
