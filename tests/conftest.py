"""Shared fixtures: tiny per-family configs + deterministic batches.

Tests run on 1 CPU device (the dry-run owns the 512-device env var; it must
NOT be set here — smoke tests exercise the un-meshed code path).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.models.api import get_model

ARCHS = list_archs()


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def tiny(arch: str):
    return get_config(arch).reduced()


def make_batch(cfg, key, batch=2, seq=16):
    if cfg.family == "vlm":
        return {
            "embeds": jax.random.normal(key, (batch, seq, cfg.d_model), jnp.float32),
            "mrope_positions": jnp.tile(
                jnp.arange(seq)[None, None], (3, batch, 1)
            ).astype(jnp.int32),
            "labels": jax.random.randint(key, (batch, seq), 0, cfg.vocab_size),
        }
    if cfg.family == "audio":
        return {
            "tokens": jax.random.randint(key, (batch, seq), 0, cfg.vocab_size),
            "frames": jax.random.normal(key, (batch, cfg.n_audio_frames, cfg.d_model), jnp.float32),
            "labels": jax.random.randint(key, (batch, seq), 0, cfg.vocab_size),
        }
    return {
        "tokens": jax.random.randint(key, (batch, seq), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (batch, seq), 0, cfg.vocab_size),
    }


@pytest.fixture(scope="session")
def tiny_dense_api():
    cfg = tiny("qwen2.5-3b")
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(1))
    return api, params
