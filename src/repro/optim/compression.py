"""Int8 error-feedback gradient compression for cross-pod all-reduce.

At 1000+ nodes the pod-level gradient all-reduce crosses DCI (slow links);
compressing the pod-crossing reduction 4x (f32->i8 with per-block scales) cuts
that term. Error feedback keeps the quantization bias out of the trajectory:
the residual (g - dequant(quant(g))) is carried to the next step.

The quantizer is deterministic and shape-preserving; block size 256 along the
flattened axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_len(n: int) -> int:
    return (n + BLOCK - 1) // BLOCK * BLOCK


def compress_int8(x: jax.Array):
    """x: any shape f32/bf16 -> (codes int8 (n/B, B), scales f32 (n/B,), shape)."""
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    padded = jnp.pad(flat, (0, _pad_len(n) - n)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(padded), axis=1) / 127.0  # (nb,)
    safe = jnp.maximum(scale, 1e-12)
    codes = jnp.clip(jnp.round(padded / safe[:, None]), -127, 127).astype(jnp.int8)
    return codes, scale, x.shape


def decompress_int8(codes: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (codes.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def ef_compress_tree(grads, residuals):
    """Error-feedback compress a grad tree. Returns (payload, new_residuals).

    payload leaves are (codes, scale, shape) triples; new_residuals carry the
    quantization error to the next step.
    """

    def one(g, r):
        g = g.astype(jnp.float32) + r
        codes, scale, shape = compress_int8(g)
        deq = decompress_int8(codes, scale, shape)
        return (codes, scale, shape), g - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    pairs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    payload = tdef.unflatten([p[0] for p in pairs])
    new_res = tdef.unflatten([p[1] for p in pairs])
    return payload, new_res


def ef_decompress_tree(payload):
    return jax.tree.map(
        lambda t: decompress_int8(*t),
        payload,
        is_leaf=lambda t: isinstance(t, tuple) and len(t) == 3 and hasattr(t[0], "dtype"),
    )


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
