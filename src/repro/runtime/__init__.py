from repro.runtime.trainer import Trainer, StragglerMonitor, TrainerConfig  # noqa: F401
from repro.runtime.serving import ServingEngine, EngineConfig  # noqa: F401
