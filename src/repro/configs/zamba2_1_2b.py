"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; hf]

The shared attention block (one param set applied at multiple depths) is the
paper's shared-structure idea in model form; KV tiering applies to the shared
attention KV only. Runs long_500k (sub-quadratic backbone).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    grad_accum=4,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    shared_attn_every=6,
    rope_theta=10_000.0,
    source="arXiv:2411.15242; hf",
)
