"""Paper Table 6: windowed production memory traces validated against live.

The MemTracer attaches for short windows, detaches, and stitches a
representative trace; a cache simulator replay must match the live run's
hit ratio and R:W mix (paper: <=5.38% / <=4.34% error).
"""
import numpy as np

from repro.core.memtrace import CacheSim, MemTracer, validate_trace

from _common import fmt_table, stream_for


def main():
    rows = []
    out = {}
    for wl in ("Cache1", "Feed", "Web1"):
        stream, prof = stream_for(wl, n=40_000)
        rng = np.random.default_rng(7)
        writes = rng.random(len(stream)) < 1.0 / (1.0 + prof.rw_ratio)
        tracer = MemTracer(window_len=64, period=512)
        live = CacheSim(capacity_blocks=256)
        for b, w in zip(stream, writes):
            tracer.tick()
            tracer.record([int(b)], is_write=bool(w))
            live.access(int(b))
        live_hit = live.hits / max(live.hits + live.misses, 1)
        live_rw = float((~writes).sum() / max(writes.sum(), 1))
        res = validate_trace(tracer.stitch(), live_hit, live_rw, capacity_blocks=256)
        rows.append(
            (
                wl,
                f"{live_hit:.3f}",
                f"{res['sim_hit_ratio']:.3f}",
                f"{res['hit_ratio_error']*100:.2f}%",
                f"{live_rw:.2f}",
                f"{res['sim_rw_ratio']:.2f}",
                f"{res['rw_ratio_error_pct']:+.2f}%",
                f"{tracer.overhead_frac()*100:.1f}%",
            )
        )
        out[wl] = res["hit_ratio_error"]
    print("[table6] stitched-trace validation vs live run (paper: <=5.38% hit, <=4.34% R:W)")
    print(
        fmt_table(
            rows,
            ["workload", "live hit", "sim hit", "err", "live R:W", "sim R:W", "err", "traced time"],
        )
    )
    return out


if __name__ == "__main__":
    main()
