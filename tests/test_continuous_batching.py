"""Continuous batching + chunked prefill: the equivalence contracts.

What this file pins:

1. Chunk-budget = ∞ oracle: ``prefill_chunk=0`` IS the whole-slot engine —
   same produced tokens and bit-identical live_counters as the default
   config on the same workload (the legacy path is not a near-copy, it is
   the same code).
2. Finite-chunk token equivalence: the chunked engine produces exactly the
   whole-slot engine's token stream for every request — the prompt-
   completing chunk emits the same first token ``api.prefill``'s argmax
   would have, and every subsequent decode token matches.
3. Chunk-boundary properties: prompt length vs chunk budget edge cases
   (L == C, L = C ± 1, L < C, L = kC, L = kC + 1) take exactly
   ceil(L / C) prefill steps, then decode to completion.
4. Slot reuse after early completion: a request admitted into a recycled
   slot (jitted zero-reset, donated buffers) decodes the same stream as on
   a fresh engine.
5. TTFT histogram pinning: the per-tenant exponential histogram's p50/p99
   bracket np.percentile of the raw virtual-time samples within one bucket
   width (relative error <= growth - 1).
"""
import dataclasses
from collections import defaultdict

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.workloads import get_profile
from repro.data.requests import Request, RequestGenerator
from repro.models.api import get_model
from repro.runtime.serving import EngineConfig, ServingEngine

_CFG = get_config("smollm-360m").reduced()
_API = get_model(_CFG)  # one api => engines share the cached jitted steps
_PARAMS = None


def _mk(**ekw):
    global _PARAMS
    if _PARAMS is None:
        _PARAMS = _API.init(jax.random.PRNGKey(0))
    kw = dict(
        max_batch=4, max_len=64, n_pages=256, near_frac=0.02,
        placement_window=4, device_tiering=True, tiered_identity_scales=True,
    )
    kw.update(ekw)
    return ServingEngine(_API, _PARAMS, EngineConfig(**kw), seed=0)


def _gen(seed=0, **pkw):
    prof = dataclasses.replace(
        get_profile("Web1"), prompt_mean=24, decode_mean=8,
        prefix_share=0.5, n_prefixes=2, **pkw,
    )
    return RequestGenerator(prof, vocab_size=_CFG.vocab_size, seed=seed)


def _run_streams(eng, reqs, max_steps=300):
    """Drive the engine and capture each request's produced-token stream.

    The slot -> seq map is snapshotted right after ``_admit`` (retirement
    clears seq_id before the step returns) and ``next_tokens`` is read
    after the step. Mid-prefill steps produce no token and are skipped; the
    prompt-completing chunk step contributes the request's FIRST generated
    token (under whole-slot prefill that token is overwritten inside the
    admit step, so a whole-slot stream starts at the second token).
    """
    for r in reqs:
        eng.submit(r)
    snap = {}
    orig_admit = eng._admit

    def admit_and_snapshot():
        orig_admit()
        snap.clear()
        for i, s in enumerate(eng.slots):
            if s.active:
                snap[i] = s.seq_id

    eng._admit = admit_and_snapshot
    streams = defaultdict(list)
    steps = 0
    while (eng.queue or any(s.active for s in eng.slots)) and steps < max_steps:
        eng.step()
        nt = np.asarray(eng.next_tokens)
        for i, sid in snap.items():
            s = eng.slots[i]
            if s.active and s.seq_id == sid and s.prefilling:
                continue  # mid-prefill: no token produced for this slot yet
            streams[sid].append(int(nt[i]))
        steps += 1
    assert not eng.queue and not any(s.active for s in eng.slots), "run truncated"
    return dict(streams)


def _first_token(eng, tokens):
    """The whole-slot admit argmax for ``tokens`` (the reference t1)."""
    budget = max(1, eng.ecfg.max_len - 2)
    t = tokens[:budget]
    logits1, _ = eng.api.prefill(
        eng.params, eng._prefill_batch(t), max_len=eng.ecfg.max_len
    )
    return int(jnp.argmax(logits1[0, -1, : eng.cfg.vocab_size]))


# ---------------------------------------------------------------------------
# 1. chunk budget = ∞ oracle


def test_infinite_budget_is_whole_slot_bit_exact():
    runs = []
    for ekw in ({}, {"prefill_chunk": 0}):
        eng = _mk(**ekw)
        assert not eng.chunking
        gen = _gen(seed=7)
        streams = _run_streams(eng, [next(gen) for _ in range(8)])
        runs.append((streams, eng.live_counters(), eng.stats()))
    (st_a, lc_a, s_a), (st_b, lc_b, s_b) = runs
    assert st_a == st_b
    assert lc_a == lc_b
    assert s_a["tenants"] == s_b["tenants"]
    assert s_a["serving"]["prefill_dispatches"] == 8
    assert (
        s_a["serving"]["model_dispatches"]
        == s_b["serving"]["model_dispatches"]
    )


# ---------------------------------------------------------------------------
# 2. finite-chunk token equivalence


def test_chunked_tokens_match_whole_slot():
    gen = _gen(seed=3)
    reqs = [next(gen) for _ in range(8)]
    mono = _run_streams(_mk(), [dataclasses.replace(r) for r in reqs])
    eng_c = _mk(prefill_chunk=8)
    assert eng_c.chunking
    chunked = _run_streams(eng_c, [dataclasses.replace(r) for r in reqs])
    assert set(mono) == set(chunked)
    ref = _mk()  # for the t1 reference prefill passes only
    by_rid = {r.rid: r for r in reqs}
    for rid, m in mono.items():
        c = chunked[rid]
        # chunked stream = [t1(emit), t2, ...]; whole-slot capture starts
        # at t2 (t1 is consumed inside the admit step) — see _run_streams
        assert len(c) == len(m) + 1, (rid, len(c), len(m))
        assert c[1:] == m, rid
        assert c[0] == _first_token(ref, by_rid[rid].tokens), rid
    # the chunked run paid zero monolithic prefill dispatches and exactly
    # one model executable per step
    sv = eng_c.stats()["serving"]
    assert sv["prefill_dispatches"] == 0
    assert sv["model_dispatches"] == eng_c.engine_steps


# ---------------------------------------------------------------------------
# 3. chunk-boundary properties


@pytest.mark.parametrize(
    "L", [1, 3, 7, 8, 9, 15, 16, 17, 24, 25], ids=lambda v: f"L{v}"
)
def test_chunk_boundaries(L):
    C = 8
    eng = _mk(max_batch=2, prefill_chunk=C)
    rng = np.random.default_rng(L)
    tokens = rng.integers(0, _CFG.vocab_size, size=L).astype(np.int32)
    eng.submit(Request(0, tokens, 3, -1, 0.0))
    prefill_steps = 0
    steps = 0
    while (eng.queue or any(s.active for s in eng.slots)) and steps < 60:
        eng.step()
        steps += 1
        if any(s.prefilling for s in eng.slots):
            prefill_steps += 1
    assert not any(s.active for s in eng.slots)
    # the prompt-completing chunk is not counted by the post-step probe
    # (chunk is already cleared), so mid-prefill steps = ceil(L/C) - 1
    expect = -(-L // C)
    assert prefill_steps == expect - 1, (L, C, prefill_steps)
    assert steps == expect + 3, (L, C, steps)  # + decode_len
    assert eng.stats()["serving"]["prefill_dispatches"] == 0


def test_slot_reuse_after_early_completion():
    """A request admitted into a recycled slot (zero-reset, donated
    buffers) must decode exactly the stream it gets on a fresh engine."""
    rng = np.random.default_rng(11)
    early = Request(0, rng.integers(0, _CFG.vocab_size, 10).astype(np.int32), 2, -1, 0.0)
    stayer = Request(1, rng.integers(0, _CFG.vocab_size, 20).astype(np.int32), 12, -1, 0.0)
    late = Request(2, rng.integers(0, _CFG.vocab_size, 12).astype(np.int32), 4, -1, 0.0)
    # batch of 2: `late` queues until `early` retires, then reuses its slot
    shared = _run_streams(_mk(max_batch=2, prefill_chunk=4),
                          [dataclasses.replace(r) for r in (early, stayer, late)])
    alone = _run_streams(_mk(max_batch=2, prefill_chunk=4),
                         [dataclasses.replace(late)])
    assert shared[late.rid] == alone[late.rid]
    assert len(shared) == 3


# ---------------------------------------------------------------------------
# 4. TTFT histogram pinning


def test_ttft_histogram_pins_percentiles():
    eng = _mk(prefill_chunk=8)
    gen = _gen(seed=9)
    reqs = [next(gen) for _ in range(12)]
    _run_streams(eng, reqs)
    samples = np.asarray(eng.ttft_vt_samples)
    assert len(samples) == len(reqs)
    assert (samples >= 0).all()
    h = eng.metrics.histogram("ttft", tenant="default")
    assert h.count == len(samples)
    ordered = np.sort(samples)
    for q in (0.50, 0.99):
        # the histogram's rank convention (rank-ceil(q*count) sample); the
        # np.percentile cross-check below uses the matching method
        rank = min(len(ordered), max(1, int(np.ceil(q * len(ordered)))))
        exact = float(ordered[rank - 1])
        assert exact <= float(np.percentile(samples, 100 * q, method="higher")) + 1e-9
        got = h.quantile(q)
        # bucket upper bound: never below the true quantile, within one
        # bucket width (growth factor) above it
        assert got >= exact - 1e-9, (q, got, exact)
        assert got <= max(exact, 1e-12) * h.growth + 1e-9, (q, got, exact)
