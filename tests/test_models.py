"""Per-architecture smoke tests: loss/grad finiteness, output shapes,
prefill+decode vs full-forward consistency, fused-CE equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, applicable_shapes, get_config, list_archs, skipped_shapes
from repro.models import common
from repro.models.api import get_model, make_serve_step

from conftest import make_batch, tiny


# grad compiles dominate tier-1 wall time; the expensive archs' grad tests
# run in the full-suite CI job, the cheap dense representatives stay in the
# default run (every arch still gets prefill/decode/serve coverage below)
_GRAD_HEAVY = {
    "granite-moe-3b-a800m", "qwen1.5-110b", "qwen2-moe-a2.7b", "qwen2-vl-7b",
    "qwen2.5-3b", "rwkv6-7b", "whisper-base", "zamba2-1.2b",
}


@pytest.mark.parametrize(
    "arch",
    [
        pytest.param(a, marks=pytest.mark.slow) if a in _GRAD_HEAVY else a
        for a in list_archs()
    ],
)
def test_loss_and_grads_finite(arch, rng):
    cfg = tiny(arch)
    api = get_model(cfg)
    params = api.init(rng)
    batch = make_batch(cfg, rng)
    loss, metrics = api.loss(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(metrics["tokens"]) == batch["labels"].size
    grads = jax.grad(lambda p: api.loss(p, batch)[0])(params)
    gsq = sum(float(jnp.sum(g.astype(jnp.float32) ** 2)) for g in jax.tree.leaves(grads))
    assert np.isfinite(gsq) and gsq > 0.0, arch


@pytest.mark.parametrize("arch", list_archs())
def test_prefill_decode_shapes(arch, rng):
    cfg = tiny(arch)
    api = get_model(cfg)
    params = api.init(rng)
    B, S = 2, 16
    batch = {k: v for k, v in make_batch(cfg, rng, B, S).items() if k != "labels"}
    logits, cache = api.prefill(params, batch, max_len=S + 4)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    nxt, cache2 = api.decode(params, cache, jnp.ones((B, 1), jnp.int32))
    assert nxt.shape == (B, 1, cfg.padded_vocab)
    assert bool(jnp.isfinite(nxt.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "internlm2-1.8b", "smollm-360m", "rwkv6-7b"])
def test_decode_matches_forward(arch, rng):
    """prefill(t[:S]) + decode(t[S]) must equal forward(t[:S+1]) at the last
    position — the KV-cache/recurrent-state path is exact, not approximate."""
    cfg = tiny(arch)
    api = get_model(cfg)
    params = api.init(rng)
    B, S = 2, 12
    toks = jax.random.randint(rng, (B, S + 1), 0, cfg.vocab_size)
    from repro.models import rwkv6, transformer

    mod = transformer if cfg.family == "dense" else rwkv6
    full = mod.forward(params, cfg, toks)  # (B, S+1, Vp)
    _, cache = api.prefill(params, {"tokens": toks[:, :S]}, max_len=S + 4)
    step_logits, _ = api.decode(params, cache, toks[:, S:])
    a = np.asarray(full[:, S, :], np.float32)
    b = np.asarray(step_logits[:, 0, :], np.float32)
    np.testing.assert_allclose(a, b, rtol=2e-2, atol=2e-2)


def test_fused_ce_matches_plain(rng):
    B, S, D, V = 2, 16, 8, 50
    Vp = 64
    h = jax.random.normal(rng, (B, S, D))
    w = jax.random.normal(jax.random.PRNGKey(3), (D, Vp))
    labels = jax.random.randint(rng, (B, S), 0, V).at[0, 0].set(-1)
    logits = jnp.einsum("bsd,dv->bsv", h, w, preferred_element_type=jnp.float32)
    l_ref, m_ref = common.cross_entropy(logits, labels, V)
    l_fused, m_fused = common.fused_ce_loss(h, w, labels, V, chunk=4)
    np.testing.assert_allclose(float(l_ref), float(l_fused), rtol=1e-5)
    for k in ("loss", "zloss", "tokens", "accuracy"):
        np.testing.assert_allclose(float(m_ref[k]), float(m_fused[k]), rtol=1e-5, err_msg=k)


def test_fused_ce_grads_match(rng):
    B, S, D, V = 2, 8, 8, 30
    h = jax.random.normal(rng, (B, S, D))
    w = jax.random.normal(jax.random.PRNGKey(3), (D, 32))
    labels = jax.random.randint(rng, (B, S), 0, V)

    def f_plain(h, w):
        logits = jnp.einsum("bsd,dv->bsv", h, w, preferred_element_type=jnp.float32)
        return common.cross_entropy(logits, labels, V)[0]

    def f_fused(h, w):
        return common.fused_ce_loss(h, w, labels, V, chunk=4)[0]

    g1 = jax.grad(f_plain, argnums=(0, 1))(h, w)
    g2 = jax.grad(f_fused, argnums=(0, 1))(h, w)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_features_matches_forward_logits(rng):
    """forward() must equal einsum(features()) — serving and loss agree."""
    cfg = tiny("qwen2.5-3b")
    api = get_model(cfg)
    params = api.init(rng)
    toks = jax.random.randint(rng, (2, 12), 0, cfg.vocab_size)
    from repro.models import transformer

    logits = transformer.forward(params, cfg, toks)
    h, w = transformer.features(params, cfg, toks)
    logits2 = jnp.einsum("bsd,dv->bsv", h, w.astype(h.dtype), preferred_element_type=jnp.float32)
    np.testing.assert_allclose(
        np.asarray(logits, np.float32), np.asarray(logits2, np.float32), rtol=1e-5, atol=1e-5
    )


def test_serve_step_greedy(tiny_dense_api, rng):
    api, params = tiny_dense_api
    B, S = 2, 8
    toks = jax.random.randint(rng, (B, S), 0, api.cfg.vocab_size)
    _, cache = api.prefill(params, {"tokens": toks}, max_len=S + 4)
    step = make_serve_step(api)
    nxt, cache2 = step(params, cache, toks[:, -1:])
    assert nxt.shape == (B, 1) and nxt.dtype == jnp.int32
    assert int(cache2["lengths"][0]) == S + 1


def test_shape_assignment_covers_40_cells():
    cells = [(a, s) for a in list_archs() for s in applicable_shapes(get_config(a))]
    # 10 archs x (train, prefill, decode) + long_500k for the 2 sub-quadratic
    assert len(cells) == 32
    skips = {a: skipped_shapes(get_config(a)) for a in list_archs()}
    n_skipped = sum(len(v) for v in skips.values())
    assert len(cells) + n_skipped == 40
    for a in ("rwkv6-7b", "zamba2-1.2b"):
        assert "long_500k" in applicable_shapes(get_config(a))


@pytest.mark.parametrize("arch", list_archs())
def test_input_specs_match_shapes(arch):
    cfg = get_config(arch)
    api = get_model(cfg)
    for shape in applicable_shapes(cfg):
        sh = SHAPES[shape]
        specs = api.input_specs(shape)
        bspecs = api.batch_specs(shape)
        assert set(specs) == set(bspecs)
        if sh.kind == "train":
            assert specs["labels"].shape == (sh.global_batch, sh.seq_len)
        if sh.kind == "decode":
            assert specs["tokens"].shape == (sh.global_batch, 1)
            assert "cache" in specs


@pytest.mark.slow
def test_grad_accum_matches_single_batch(rng):
    """grad_accum=A must produce the same update as one big batch (same data)."""
    import dataclasses

    from repro.models.api import make_train_step
    from repro.optim import AdamWConfig, adamw_init

    cfg = tiny("smollm-360m")
    api1 = get_model(dataclasses.replace(cfg, grad_accum=1))
    api2 = get_model(dataclasses.replace(cfg, grad_accum=2))
    params = api1.init(rng)
    opt = adamw_init(params)
    batch = make_batch(cfg, rng, batch=4, seq=8)
    s1 = make_train_step(api1, AdamWConfig())
    s2 = make_train_step(api2, AdamWConfig())
    p1, _, m1 = jax.jit(s1)(params, opt, batch)
    p2, _, m2 = jax.jit(s2)(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=2e-4, atol=2e-5
        )
