"""Chunked SSD (Mamba2) Pallas TPU kernel.

Per (batch, head) the time axis is processed in chunks of C steps with the
cross-chunk state S (P x N) in VMEM scratch. Within a chunk (decay is a
SCALAR per head per step — simpler than RWKV6's per-channel decay):

  la[t]  = dt[t] * A                  (<= 0)
  cwi    = cumsum(la)                  (inclusive)
  G[t,s] = exp(cwi[t] - cwi[s]) dt[s]  for s <= t else 0
  y      = ((C_mat @ B^T) * G) @ x  +  exp(cwi)[:,None] * (C_mat @ S_in^T)  +  D*x
  S_out  = exp(cwi[-1]) S_in + (x * (exp(cwi[-1]-cwi) dt)[:,None])^T @ B

All exponents <= 0: unconditionally overflow-safe. Grid (B, H, T/C), chunk
axis innermost. The (C_mat @ B^T) Gram matrix is shared across heads in
principle (B/C are per-group); this kernel recomputes it per head — an
acceptable FLOP trade at N=64 vs. the extra VMEM residency (noted as a
future optimization in EXPERIMENTS.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._interpret import resolve_interpret


def _kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, s0_ref, y_ref, sout_ref, s_ref):
    t_idx = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(t_idx == 0)
    def _init():
        s_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, 0].astype(jnp.float32)  # (C, P)
    dt = dt_ref[0, 0].astype(jnp.float32)  # (C,)
    bmat = b_ref[0].astype(jnp.float32)  # (C, N)
    cmat = c_ref[0].astype(jnp.float32)  # (C, N)
    a = a_ref[0]  # scalar
    d = d_ref[0]
    c, p = x.shape

    la = dt * a  # (C,) <= 0
    cwi = jnp.cumsum(la)
    gram = jax.lax.dot_general(
        cmat, bmat, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (C, C): C_t . B_s
    g = jnp.exp(cwi[:, None] - cwi[None, :]) * dt[None, :]
    ti = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    g = jnp.where(si <= ti, g, 0.0)
    y = jax.lax.dot_general(
        gram * g, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (C, P)

    s_in = s_ref[...]  # (P, N)
    carry = jax.lax.dot_general(
        cmat, s_in, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (C, P)
    y = y + jnp.exp(cwi)[:, None] * carry + d * x

    wtail = jnp.exp(cwi[-1] - cwi) * dt  # (C,)
    s_new = jnp.exp(cwi[-1]) * s_in + jax.lax.dot_general(
        x * wtail[:, None], bmat, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (P, N)
    s_ref[...] = s_new
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(t_idx == nt - 1)
    def _final():
        sout_ref[0, 0] = s_new.astype(sout_ref.dtype)


def ssd_chunked_kernel(x, dt, a, b, c, d, s0, *, chunk: int = 64, interpret=None):
    """x: (B,H,T,P); dt: (B,H,T); a,d: (H,); b,c: (B,T,N); s0: (B,H,P,N).

    Returns (y (B,H,T,P) f32, s_out (B,H,P,N) f32). T % chunk == 0.
    """
    bb, h, t, p = x.shape
    n = b.shape[-1]
    assert t % chunk == 0
    grid = (bb, h, t // chunk)

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda i, j, k: (i, j, k, 0)),
            pl.BlockSpec((1, 1, chunk), lambda i, j, k: (i, j, k)),
            pl.BlockSpec((1, chunk, n), lambda i, j, k: (i, k, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j, k: (i, k, 0)),
            pl.BlockSpec((1,), lambda i, j, k: (j,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda i, j, k: (j,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, p, n), lambda i, j, k: (i, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda i, j, k: (i, j, k, 0)),
            pl.BlockSpec((1, 1, p, n), lambda i, j, k: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bb, h, t, p), jnp.float32),
            jax.ShapeDtypeStruct((bb, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(x, dt, b, c, a, d, s0)
