from repro.kernels.rwkv6_scan.ops import wkv6_chunked  # noqa: F401
from repro.kernels.rwkv6_scan.ref import wkv6_ref  # noqa: F401
