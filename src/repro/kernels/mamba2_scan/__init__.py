from repro.kernels.mamba2_scan.ops import ssd_chunked  # noqa: F401
from repro.kernels.mamba2_scan.ref import ssd_ref  # noqa: F401
