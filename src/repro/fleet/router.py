"""Request routing over N replicas, with prefix-affinity as the headline.

The shared KV page table dedups prompt prefixes *within one host* — sharing
only materializes if requests carrying the same template land on the same
replica while its pages are resident. Prefix-affinity routing is therefore
the fleet-level counterpart of the paper's multi-ASID TLB sharing: it steers
same-code (same-template) requests to the host already holding those
translations, so the per-host dedup the paper measures actually happens at
fleet scale. Round-robin and least-loaded are the controls.

Multi-tenant dispatch: requests are offered into per-tenant queues and a
weighted-fair pick (virtual-time, deterministic tie-break on tenant name)
decides which tenant's head request is routed next — *before* replica
selection. A burst tenant therefore waits behind its own queue while other
tenants keep dispatching at their weighted share; its overload is charged
to its own SLO by the admission controller, never to its neighbors'.

``simulated_throughput`` scores a fleet run with a simple cost model in
token-equivalents: prefill work not recovered by sharing, plus decode work
inflated by far-tier latency (hw.TPU_TIERED's relative latencies) — the same
three levers as core/tiering's roofline, in request-serving units.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.core.hw import TPU_TIERED
from repro.data.requests import Request, RequestGenerator
from repro.fleet.admission import AdmissionController, SLOModel
from repro.fleet.replica import Replica

FAR_LATENCY_REL = TPU_TIERED[1].latency_rel  # host-DRAM far tier vs HBM

_FALLBACK_SLO = SLOModel()  # cost model for fairness when no admission is set


class RoundRobinPolicy:
    name = "round-robin"

    def __init__(self):
        self._next = 0

    def choose(self, req: Request, replicas: List[Replica]) -> int:
        i = self._next % len(replicas)
        self._next += 1
        return i


class LeastLoadedPolicy:
    name = "least-loaded"

    def choose(self, req: Request, replicas: List[Replica]) -> int:
        return int(np.argmin([r.load for r in replicas]))


class PrefixAffinityPolicy:
    """Route shared-template requests to the replica holding the prefix.

    Unique prompts (prefix_id == -1) fall back to least-loaded. A sticky
    mapping overloaded past ``spill_factor``x the mean load spills to the
    least-loaded replica instead (a hot template must not melt one host).
    """

    name = "prefix-affinity"

    def __init__(self, spill_factor: float = 3.0):
        self.spill_factor = spill_factor
        self.home: Dict[int, int] = {}  # prefix_id -> replica index
        self.affinity_hits = 0
        self.spills = 0

    def choose(self, req: Request, replicas: List[Replica]) -> int:
        loads = [r.load for r in replicas]
        least = int(np.argmin(loads))
        if req.prefix_id < 0:
            return least
        i = self.home.get(req.prefix_id)
        if i is None:
            self.home[req.prefix_id] = least
            return least
        mean = max(sum(loads) / len(loads), 1.0)
        if loads[i] > self.spill_factor * mean and loads[i] > loads[least]:
            self.spills += 1
            return least
        self.affinity_hits += 1
        return i


POLICIES = {
    "round-robin": RoundRobinPolicy,
    "least-loaded": LeastLoadedPolicy,
    "prefix-affinity": PrefixAffinityPolicy,
}


class FleetRouter:
    """Per-tenant queueing + dispatch + lockstep stepping of the replica set.

    ``admission`` (optional) gates every offer; ``tenant_weights`` sets the
    weighted-fair dispatch shares (default: equal weights); ``on_step``
    hooks (e.g. the AutoTierer) run after each fleet step with the global
    step index.
    """

    def __init__(
        self,
        replicas: List[Replica],
        policy,
        admission: Optional[AdmissionController] = None,
        tenant_weights: Optional[Dict[str, float]] = None,
    ):
        assert replicas
        self.replicas = replicas
        self.policy = policy
        self.admission = admission
        self.tenant_weights = dict(tenant_weights or {})
        self.tenant_queues: Dict[str, List[Request]] = {}
        self._vtime: Dict[str, float] = {}  # weighted-fair virtual time
        self.on_step: List = []
        self.fleet_steps = 0
        self.routed = 0
        self.shed = 0
        self.routed_by: Dict[str, int] = {}
        self.shed_by: Dict[str, int] = {}

    # ------------------------------------------------------------------
    # tenant bookkeeping

    def _weight(self, tenant: str) -> float:
        return max(self.tenant_weights.get(tenant, 1.0), 1e-9)

    def _weight_share(self, tenant: str) -> float:
        """This tenant's fair share among tenants the router knows about."""
        known = set(self.tenant_queues) | set(self.tenant_weights) | {tenant}
        total = sum(self._weight(t) for t in known)
        return self._weight(tenant) / max(total, 1e-9)

    def _tenant_backlog_tokens(self, tenant: str) -> float:
        slo = self.admission.slo_for(tenant) if self.admission else _FALLBACK_SLO
        return sum(slo.request_cost(r) for r in self.tenant_queues.get(tenant, ()))

    def queued(self, tenant: Optional[str] = None) -> int:
        if tenant is not None:
            return len(self.tenant_queues.get(tenant, ()))
        return sum(len(q) for q in self.tenant_queues.values())

    # ------------------------------------------------------------------
    # offer / dispatch

    def offer(self, req: Request) -> bool:
        """Admission-gate one request into its tenant queue (no routing yet)."""
        tenant = req.tenant
        if self.admission is not None and not self.admission.admit(
            req,
            self.replicas,
            tenant_backlog_tokens=self._tenant_backlog_tokens(tenant),
            weight_share=self._weight_share(tenant),
        ):
            self.shed += 1
            self.shed_by[tenant] = self.shed_by.get(tenant, 0) + 1
            return False
        self.tenant_queues.setdefault(tenant, []).append(req)
        return True

    def _pick_tenant(self) -> Optional[str]:
        ready = [t for t, q in self.tenant_queues.items() if q]
        if not ready:
            return None
        return min(ready, key=lambda t: (self._vtime.get(t, 0.0), t))

    def dispatch(self, budget: Optional[int] = None) -> int:
        """Route up to ``budget`` queued requests (all, if None) in
        weighted-fair tenant order; returns number routed."""
        n = 0
        while budget is None or n < budget:
            tenant = self._pick_tenant()
            if tenant is None:
                break
            req = self.tenant_queues[tenant].pop(0)
            self.replicas[self.policy.choose(req, self.replicas)].submit(req)
            self.routed += 1
            self.routed_by[tenant] = self.routed_by.get(tenant, 0) + 1
            # virtual time advances by inverse weight: a weight-2 tenant is
            # picked twice as often as a weight-1 tenant under contention
            self._vtime[tenant] = self._vtime.get(tenant, 0.0) + 1.0 / self._weight(tenant)
            n += 1
        return n

    def submit(self, req: Request) -> bool:
        """Offer + immediately drain the queues; returns False if shed.

        The one-call path used when arrivals are not rate-limited — with a
        single tenant this is exactly direct routing.
        """
        admitted = self.offer(req)
        self.dispatch()
        return admitted

    # ------------------------------------------------------------------
    def step(self) -> int:
        decoded = sum(r.step() for r in self.replicas)
        self.fleet_steps += 1
        for hook in self.on_step:
            hook(self.fleet_steps)
        return decoded

    @property
    def free_slots(self) -> int:
        return sum(
            sum(1 for s in r.engine.slots if not s.active) for r in self.replicas
        )

    @property
    def drained(self) -> bool:
        return self.queued() == 0 and all(r.idle for r in self.replicas)

    def run(
        self,
        gen,
        n_requests: int,
        max_steps: int = 10_000,
        submit_per_step: Optional[int] = None,
    ) -> dict:
        """Serve ``n_requests``: all up-front, or ``submit_per_step`` per
        fleet step (open-loop arrivals, what admission control acts on).

        ``gen`` is a RequestGenerator or any iterator of Requests (e.g. a
        multi-tenant ``data.requests.interleave`` merge). In the open-loop
        path, offered requests wait in per-tenant queues and each step
        dispatches into the fleet's free decode slots in weighted-fair
        tenant order.
        """
        it = iter(gen)
        pending = [next(it) for _ in range(n_requests)]
        if submit_per_step is None:
            for req in pending:
                self.submit(req)
            pending = []
        steps = 0
        while (pending or not self.drained) and steps < max_steps:
            for _ in range(min(submit_per_step or 0, len(pending))):
                self.offer(pending.pop(0))
            self.dispatch(max(self.free_slots, 0))
            self.step()
            steps += 1
        return self.fleet_stats()

    # ------------------------------------------------------------------
    def fleet_stats(self) -> dict:
        per = [r.stats() for r in self.replicas]
        agg = {
            k: sum(s[k] for s in per)
            for k in (
                "tokens_decoded",
                "requests_finished",
                "prefill_tokens",
                "prefill_tokens_saved",
            )
        }
        hits = sum(r.engine.placement.stats.near_hits for r in self.replicas)
        tot = hits + sum(r.engine.placement.stats.far_hits for r in self.replicas)
        agg["near_hit_rate"] = hits / max(tot, 1)
        agg["shared_mappings"] = sum(s["pagetable"]["shared_mappings"] for s in per)
        agg["fleet_steps"] = self.fleet_steps
        agg["n_replicas"] = len(self.replicas)
        agg["routed"] = self.routed
        agg["shed"] = self.shed
        agg["policy"] = getattr(self.policy, "name", type(self.policy).__name__)
        agg["simulated_throughput"] = simulated_throughput(agg)
        agg["tenants"] = self.tenant_report(per)
        agg["per_replica"] = per
        return agg

    def tenant_report(self, per_replica_stats: Optional[List[dict]] = None) -> dict:
        """Fleet-wide per-tenant view: service counts, tier hits, routing."""
        per = per_replica_stats or [r.stats() for r in self.replicas]
        out: Dict[str, dict] = {}
        for s in per:
            for t, ts in s.get("tenants", {}).items():
                o = out.setdefault(
                    t,
                    {"tokens_decoded": 0, "requests_finished": 0, "near_hits": 0, "far_hits": 0},
                )
                for k in ("tokens_decoded", "requests_finished", "near_hits", "far_hits"):
                    o[k] += ts[k]
        for t in set(out) | set(self.routed_by) | set(self.shed_by):
            o = out.setdefault(
                t,
                {"tokens_decoded": 0, "requests_finished": 0, "near_hits": 0, "far_hits": 0},
            )
            o["near_hit_rate"] = o["near_hits"] / max(o["near_hits"] + o["far_hits"], 1)
            o["routed"] = self.routed_by.get(t, 0)
            o["shed"] = self.shed_by.get(t, 0)
            o["shed_rate"] = o["shed"] / max(o["routed"] + o["shed"], 1)
            o["queued"] = self.queued(t)
        return out


def simulated_throughput(stats: dict) -> float:
    """Useful tokens per modeled unit cost (higher is better).

    cost = unshared prefill work + decode work weighted by the average
    KV-read latency its near/far split implies. Prefix sharing removes
    prefill cost; good placement removes the far-latency multiplier.
    """
    useful = stats["prefill_tokens"] + stats["tokens_decoded"]
    near = stats["near_hit_rate"]
    avg_latency = near + (1.0 - near) * FAR_LATENCY_REL
    cost = (
        stats["prefill_tokens"]
        - stats["prefill_tokens_saved"]
        + stats["tokens_decoded"] * avg_latency
    )
    return useful / max(cost, 1e-9)
