"""Public paged-attention op: GQA reshaping + sublane/lane padding."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels._interpret import resolve_interpret
from repro.kernels.paged_attention.kernel import paged_attention_kernel

LANE = 128
MIN_G = 8  # sublane floor for the q block


def paged_attention(q, k_pages, v_pages, page_table, lengths, *, interpret: Optional[bool] = None):
    """q: (B, Hq, d); k/v_pages: (Hkv, P, ps, d); page_table: (B, pp);
    lengths: (B,). Returns (B, Hq, d)."""
    return _paged_attention(
        q, k_pages, v_pages, page_table, lengths, interpret=resolve_interpret(interpret)
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def _paged_attention(q, k_pages, v_pages, page_table, lengths, *, interpret):
    b, hq, d = q.shape
    hkv = k_pages.shape[0]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d)

    gpad = (-g) % MIN_G
    if gpad:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, gpad), (0, 0)))
    dpad = (-d) % LANE
    if dpad:
        scale_fix = jnp.asarray(((d + dpad) / d) ** 0.5, q.dtype)
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, dpad))) * scale_fix
        k_pages = jnp.pad(k_pages, ((0, 0), (0, 0), (0, 0), (0, dpad)))
        v_pages = jnp.pad(v_pages, ((0, 0), (0, 0), (0, 0), (0, dpad)))

    out = paged_attention_kernel(
        qg, k_pages, v_pages, page_table.astype(jnp.int32), lengths.astype(jnp.int32),
        interpret=interpret,
    )
    return out[:, :, :g, :d].reshape(b, hq, d)
