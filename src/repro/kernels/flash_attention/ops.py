"""Public flash-attention op: padding + dtype policy + jit wrapper.

Pads Lq/Lk to block multiples and head_dim to 128 lanes (e.g. smollm's 64)
before calling the kernel; causal masking of the padded tail happens via the
valid-length mask (padding K rows land beyond lk_valid and score -inf).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels._interpret import resolve_interpret
from repro.kernels.flash_attention.kernel import flash_attention_kernel

LANE = 128


def _pad_to(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def flash_attention(
    q, k, v, *, causal: bool = True, block_q: int = 512, block_k: int = 512,
    interpret: Optional[bool] = None,
):
    """q: (B, Hq, Lq, D); k/v: (B, Hkv, Lk, D) -> (B, Hq, Lq, D).

    ``interpret=None`` resolves via kernels._interpret (env override, else
    compiled on TPU / interpreted elsewhere).
    """
    return _flash_attention(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k,
        interpret=resolve_interpret(interpret),
    )


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def _flash_attention(q, k, v, *, causal, block_q, block_k, interpret):
    b, hq, lq, d = q.shape
    lk = k.shape[2]
    bq = min(block_q, max(lq, 8))
    bk = min(block_k, max(lk, 8))

    q, dpad = _pad_to(q, 3, LANE)
    k, _ = _pad_to(k, 3, LANE)
    v, _ = _pad_to(v, 3, LANE)
    # scale uses the PADDED head dim inside the kernel; compensate so that
    # softmax(q k^T / sqrt(d_orig)) is preserved.
    if dpad:
        q = q * jnp.asarray((d + dpad) ** 0.5 / d**0.5, q.dtype)

    q, qpad = _pad_to(q, 2, bq)
    k, kpad = _pad_to(k, 2, bk)
    v, _ = _pad_to(v, 2, bk)

    # kernel masks kpos >= lk_valid; padded K tail must be masked, so pass
    # the ORIGINAL lk. Padded Q rows compute garbage and are sliced off.
    out = flash_attention_kernel(
        q,
        k,
        v,
        causal=causal,
        block_q=bq,
        block_k=bk,
        lk_valid=lk,
        q_offset=lk - lq,
        interpret=interpret,
    )
    return out[:, :, :lq, :d]
