"""Fleet subsystem: multi-replica serving with fleet-wide MemProf.

The paper's observations are fleet-level — the same code runs on many
hosts, and both its profiler and its tracer only become *representative*
when aggregated across them. Module -> paper-section map:

* ``replica.py``  — one profiled host: engine + live hardware-counter
  analogue (§3's per-host collection; Table 6's "live" column).
* ``router.py``   — request placement across hosts; prefix-affinity is the
  fleet form of the multi-ASID shared-TLB idea (§4 / Fig. 17): same-template
  requests land where those KV translations already live.
* ``aggregator.py`` — fleet MemProf: sums per-page counts over hosts
  (§4, Fig. 6/9/18) and stitches short attach/detach trace windows from
  multiple hosts into one representative trace, validated by cache-sim
  replay against live counters (§6.2-§6.3, Table 6).
* ``autotier.py`` — online re-tiering from the aggregated histogram
  (§5, Table 4/5): plan on fleet behavior, push placement to every host.
* ``admission.py`` — overload sheds at the door instead of pushing the
  far tier past its latency knee (§2, Fig. 4).

``build_fleet`` wires it together; examples/serve_fleet.py is the demo and
benchmarks/fleet_bench.py the scaling study.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.fleet.admission import AdmissionController, SLOModel
from repro.fleet.aggregator import (
    aggregate_counts,
    aggregate_tenant_counts,
    export_all,
    fleet_report,
    live_fleet_counters,
    stitch_fleet,
    validate_fleet,
)
from repro.fleet.autotier import AutoTierer, TierEpoch
from repro.fleet.replica import Replica, ReplicaProfile
from repro.fleet.router import (
    POLICIES,
    FleetRouter,
    LeastLoadedPolicy,
    PrefixAffinityPolicy,
    RoundRobinPolicy,
    simulated_throughput,
)

__all__ = [
    "AdmissionController",
    "SLOModel",
    "AutoTierer",
    "TierEpoch",
    "Replica",
    "ReplicaProfile",
    "FleetRouter",
    "RoundRobinPolicy",
    "LeastLoadedPolicy",
    "PrefixAffinityPolicy",
    "POLICIES",
    "simulated_throughput",
    "aggregate_counts",
    "aggregate_tenant_counts",
    "export_all",
    "fleet_report",
    "live_fleet_counters",
    "stitch_fleet",
    "validate_fleet",
    "build_fleet",
]

_MODEL_CACHE: dict = {}


def build_fleet(
    n_replicas: int,
    policy: str = "prefix-affinity",
    arch: str = "smollm-360m",
    admission: Optional[AdmissionController] = None,
    autotier: Optional[dict] = None,
    live_cache_blocks: int = 128,
    seed: int = 0,
    tenant_weights: Optional[dict] = None,
    **engine_kwargs,
) -> FleetRouter:
    """Construct N replicas sharing one model (params + jitted decode),
    a router with the named policy, and optionally admission/autotiering.

    ``autotier`` kwargs (near_frac, epoch_steps) attach an AutoTierer as an
    on_step hook and return it as ``router.autotierer``. ``tenant_weights``
    sets the router's weighted-fair dispatch shares for multi-tenant
    traffic (see fleet/router.py); per-tenant SLOs live on the
    AdmissionController (``tenant_slos``).
    """
    from repro.configs import get_config
    from repro.models.api import get_model
    from repro.runtime.serving import EngineConfig, ServingEngine

    if arch not in _MODEL_CACHE:
        cfg = get_config(arch).reduced()
        api = get_model(cfg)
        _MODEL_CACHE[arch] = (cfg, api, api.init(jax.random.PRNGKey(0)))
    cfg, api, params = _MODEL_CACHE[arch]
    if policy not in POLICIES:
        raise ValueError(f"unknown policy {policy!r}; choose from {sorted(POLICIES)}")
    kw = dict(max_batch=4, max_len=64, n_pages=512)
    kw.update(engine_kwargs)
    replicas = [
        Replica(i, ServingEngine(api, params, EngineConfig(**kw), seed=seed + i), live_cache_blocks)
        for i in range(n_replicas)
    ]
    router = FleetRouter(
        replicas, POLICIES[policy](), admission=admission, tenant_weights=tenant_weights
    )
    router.autotierer = None
    if autotier is not None:
        router.autotierer = AutoTierer(replicas, **autotier)
        router.on_step.append(router.autotierer)
    return router


def fleet_vocab(arch: str = "smollm-360m") -> int:
    """Vocab size of the (cached) reduced model — for RequestGenerators."""
    from repro.configs import get_config

    if arch in _MODEL_CACHE:
        return _MODEL_CACHE[arch][0].vocab_size
    return get_config(arch).reduced().vocab_size
