"""Software far-tier prefetch engine + the paper's accuracy/coverage accounting.

TPUs have no hardware prefetcher into HBM; the serving engine prefetches
far-tier blocks (KV pages, experts, embedding rows) ahead of the decode step
and overlaps the host->HBM copy with compute. The paper's §6 accounting maps
verbatim (CL -> block):

  Accuracy = 1 - unused_prefetched_evicted / total_prefetched
  Coverage = (total_prefetched - unused_evicted)
           / (total_blocks_brought_in - unused_evicted)

Predictors (selectable, mirroring the L2-prefetcher taxonomy plus the
paper's proposal):
  * nextline — block b -> b+1 (sequential KV walks: near-perfect)
  * stride   — per-stream stride detection
  * markov   — first-order successor table, trained online
  * trace    — successor table TRAINED FROM FLEET TRACES (MemProf §6's
    pitch: the production tracing tool exists to drive better prefetchers).
    ``train_successors`` learns per-stream block transitions from
    ``core.memtrace.TraceWindow``s — the same windows the fleet aggregator
    stitches and validates <=5% against live counters — and the table is
    shipped fleet-wide through ``fleet.autotier.TierEpoch``. The predictor
    issues ONLY trained successors (no heuristic fallback): sequential
    regions of the training traces teach b -> b+1 by themselves, so it
    covers everything the trace evidence supports at a fraction of the
    baselines' wasted bandwidth (fig21/fig22 score all of them).

Predictor state is keyed PER STREAM (decode slot / tenant / trace lane):
``_last``/``_stride`` live on a per-stream record and markov transitions
are only trained within a stream. An earlier revision interleaved every
caller into one global stream and learned transitions that never happen in
any single request stream — exactly the aggregate-stream mistraining
"Memory Controller Design Under Cloud Workloads" warns about.

The paper's headline finding — high accuracy but LOW coverage on irregular
streams, with real bandwidth overhead — reproduces here for the hardware
baselines: a markov table covers only repeated transitions, nextline fails
on scattered page chains, and every wrong prefetch costs a far-tier fetch
(benchmarks/fig21/fig22). The trace-trained table closes that coverage gap;
see ROADMAP "Recent" for the measured numbers.

End-of-run accounting: blocks still resident-but-unused in the prefetch
buffer at teardown are wasted bandwidth like any other unused prefetch.
``finalized_stats()`` (non-destructive) / ``finalize()`` (flushes the
buffer) charge them to ``unused_evicted`` so fig22 accuracy is not
overstated by whatever happened to be resident when the run ended.
"""
from __future__ import annotations

import collections
import dataclasses
import types
from typing import Dict, Hashable, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np

# buffer/table partition for streams with no tenant attached (standalone
# engines, benchmarks, direct scoring runs): everything shares one slice,
# which is exactly the pre-partition behavior
DEFAULT_PARTITION = ""


@dataclasses.dataclass
class PrefetchStats:
    total_prefetched: int = 0
    unused_evicted: int = 0
    used_prefetches: int = 0
    demand_fetches: int = 0  # far-tier fetches NOT covered by a prefetch

    @property
    def accuracy(self) -> float:
        if self.total_prefetched == 0:
            return 1.0
        return 1.0 - self.unused_evicted / self.total_prefetched

    @property
    def coverage(self) -> float:
        brought_in = self.total_prefetched + self.demand_fetches
        denom = brought_in - self.unused_evicted
        if denom <= 0:
            return 0.0
        return (self.total_prefetched - self.unused_evicted) / denom

    @property
    def bw_overhead(self) -> float:
        """Extra blocks moved vs. a perfect (demand-only) fetcher."""
        useful = self.used_prefetches + self.demand_fetches
        return (self.total_prefetched + self.demand_fetches) / max(useful, 1) - 1.0

    def finalized(self, resident_unused: int) -> "PrefetchStats":
        """End-of-run view: prefetches still pending at teardown count as
        unused evictions — the bandwidth was spent and no miss was ever
        covered, the run just ended before the LRU charged them."""
        return dataclasses.replace(
            self, unused_evicted=self.unused_evicted + int(resident_unused)
        )


@dataclasses.dataclass
class _StreamState:
    """Per-stream predictor training state (the contamination fix)."""

    last: Optional[int] = None
    stride: int = 1
    # the last batch this stream passed to access_many: batches that re-read
    # a previously seen walk prefix (a decode step re-reads the whole KV
    # walk) skip straight to the new suffix instead of retraining it
    tail: Optional[np.ndarray] = None


def train_successors(
    windows: Iterable,
    min_count: int = 2,
    min_frac: float = 0.3,
    max_successors: int = 2,
) -> Dict[int, Tuple[int, ...]]:
    """Learn a confidence-gated successor table from trace windows.

    ``windows`` are ``core.memtrace.TraceWindow``s (or anything with
    ``blocks`` and optional per-access ``stream`` arrays). Transitions are
    extracted PER STREAM within each window — a window interleaves many
    decode slots, and adjacent accesses from different slots are not
    transitions (the cross-stream contamination this module exists to
    avoid). Windows never chain into each other. A successor must be seen
    ``min_count`` times and carry ``min_frac`` of its source's transition
    mass to enter the table; at most ``max_successors`` per source, by
    count. Self-transitions are dropped (prefetching the block just
    accessed is a no-op).

    Returns ``{block: (succ, ...)}`` — plain ints, so the table ships
    verbatim inside fleet epochs.
    """
    pair_list: List[np.ndarray] = []
    for w in windows:
        blk = np.asarray(w.blocks, np.int64).reshape(-1)
        if blk.size < 2:
            continue
        sid = getattr(w, "stream", None)
        s = (
            np.zeros(blk.size, np.int64)
            if sid is None
            else np.asarray(sid, np.int64).reshape(-1)
        )
        order = np.argsort(s, kind="stable")  # stable: preserves in-stream order
        bb, ss = blk[order], s[order]
        same = (ss[:-1] == ss[1:]) & (bb[:-1] != bb[1:])
        if same.any():
            pair_list.append(np.stack([bb[:-1][same], bb[1:][same]], axis=1))
    if not pair_list:
        return {}
    pairs = np.concatenate(pair_list)
    uniq, counts = np.unique(pairs, axis=0, return_counts=True)
    srcs = uniq[:, 0]
    starts = np.flatnonzero(np.r_[True, srcs[1:] != srcs[:-1]])
    ends = np.r_[starts[1:], srcs.size]
    table: Dict[int, Tuple[int, ...]] = {}
    for i0, i1 in zip(starts, ends):
        total = int(counts[i0:i1].sum())
        order = np.argsort(-counts[i0:i1], kind="stable")
        succ = tuple(
            int(uniq[i0 + j, 1])
            for j in order[:max_successors]
            if counts[i0 + j] >= min_count and counts[i0 + j] / total >= min_frac
        )
        if succ:
            table[int(srcs[i0])] = succ
    return table


def train_tenant_successors(
    windows: Iterable,
    stream_tenants: Dict[int, str],
    min_count: int = 2,
    min_frac: float = 0.3,
    max_successors: int = 2,
    default: str = DEFAULT_PARTITION,
) -> Dict[str, Dict[int, Tuple[int, ...]]]:
    """Tenant-partitioned successor training: ``{tenant: {block: (succ,)}}``.

    ``stream_tenants`` maps stream ids (engine seq ids, possibly
    rid-namespaced by the fleet aggregator) to tenant names; streams with
    no mapping train the ``default`` partition. Each window's accesses are
    split by their stream's tenant BEFORE training, so one tenant's
    template chains never enter another tenant's table — the table-side
    half of the isolation whose buffer-side half is the PrefetchEngine's
    fair-share partition eviction. Transitions stay per stream inside each
    partition exactly as in :func:`train_successors`; empty partitions are
    dropped.
    """
    by_tenant: Dict[str, list] = {}
    for w in windows:
        blk = np.asarray(w.blocks, np.int64).reshape(-1)
        if blk.size == 0:
            continue
        sid = getattr(w, "stream", None)
        s = (
            np.zeros(blk.size, np.int64)
            if sid is None
            else np.asarray(sid, np.int64).reshape(-1)
        )
        uniq = np.unique(s)
        tenants = np.array([stream_tenants.get(int(u), default) for u in uniq])
        for t in set(tenants.tolist()):
            m = np.isin(s, uniq[tenants == t])
            by_tenant.setdefault(t, []).append(
                types.SimpleNamespace(blocks=blk[m], stream=s[m])
            )
    out: Dict[str, Dict[int, Tuple[int, ...]]] = {}
    for t, ws in by_tenant.items():
        table = train_successors(
            ws, min_count=min_count, min_frac=min_frac, max_successors=max_successors
        )
        if table:
            out[t] = table
    return out


class PrefetchEngine:
    def __init__(self, predictor: str = "nextline", buffer_blocks: int = 64, degree: int = 2):
        assert predictor in ("nextline", "stride", "markov", "trace", "off")
        self.predictor = predictor
        # PENDING prefetches (LRU, insertion-ordered; value = the tenant
        # partition that issued the entry). An entry is consumed by the
        # demand access it covers — one prefetch pays for one miss, as in
        # any hardware stream buffer — or wasted: evicted by its own
        # partition's overflow, evicted with a tier demotion, or still
        # resident at finalize. Overflow eviction is FAIR-SHARE per
        # partition (see _evict_overflow): a tenant under its share is
        # never evicted by another tenant's flood.
        self.buffer: "collections.OrderedDict[int, str]" = collections.OrderedDict()
        self.capacity = buffer_blocks
        self.degree = degree
        self.stats = PrefetchStats()
        self._streams: Dict[Hashable, _StreamState] = {}
        # markov transitions are trained within streams but the table is
        # shared: a transition observed in one request stream is valid
        # evidence for every stream that walks the same blocks (templates)
        self._markov: dict[int, collections.Counter] = collections.defaultdict(
            collections.Counter
        )
        # trace predictor: trained successor tables, one per tenant
        # partition ({partition: {block: (succ, ...)}}). Flat (legacy)
        # tables live in the default partition — the ``_successors``
        # property below — so single-tenant callers see the old shape.
        self._tables: Dict[str, Dict[int, Tuple[int, ...]]] = {}
        # stream id -> tenant partition (set by the serving engine at
        # admit); unmapped streams use DEFAULT_PARTITION
        self._stream_part: Dict[Hashable, str] = {}
        # live pending-entry count per partition (fair-share accounting)
        self._part_sizes: Dict[str, int] = {}
        # cached numpy view of buffer keys for vectorized membership probes;
        # None -> stale (rebuilt lazily after inserts/evictions)
        self._buf_keys: Optional[np.ndarray] = None

    @property
    def _successors(self) -> Dict[int, Tuple[int, ...]]:
        """The default partition's successor table (legacy flat view)."""
        return self._tables.setdefault(DEFAULT_PARTITION, {})

    # ------------------------------------------------------------------
    def _stream(self, sid: Hashable) -> _StreamState:
        st = self._streams.get(sid)
        if st is None:
            st = self._streams[sid] = _StreamState()
        return st

    def drop_stream(self, sid: Hashable):
        """Forget a finished stream's training tail (slot retirement)."""
        self._streams.pop(sid, None)
        self._stream_part.pop(sid, None)

    def set_stream_partition(self, sid: Hashable, partition: str):
        """Bind a stream to a tenant partition: its pending prefetches
        charge that partition's buffer share and its trace predictions
        come from that partition's table."""
        self._stream_part[sid] = str(partition)

    def _partition_of(self, sid: Hashable) -> str:
        return self._stream_part.get(sid, DEFAULT_PARTITION)

    def load_successors(
        self,
        table: Union[Dict[int, Tuple[int, ...]], Dict[str, Dict[int, Tuple[int, ...]]]],
        merge: bool = False,
    ):
        """Install trained successor tables (fleet push or local training).

        ``table`` is either tenant-partitioned (``{tenant: {block:
        (succ,)}}`` — the fleet/TierEpoch shape) or flat (``{block:
        (succ,)}`` — legacy single-tenant callers; installed into the
        default partition). ``merge=False`` replaces wholesale — the fleet
        table is trained on strictly more data than any local one;
        ``merge=True`` keeps local entries the incoming tables lack,
        per partition.
        """
        nested = bool(table) and all(isinstance(v, dict) for v in table.values())
        incoming = (
            {str(t): dict(tb) for t, tb in table.items()}
            if nested
            else {DEFAULT_PARTITION: dict(table)}
        )
        if merge:
            for part, tb in incoming.items():
                self._tables.setdefault(part, {}).update(tb)
        elif nested:
            self._tables = incoming
        else:
            # legacy flat replace touches only the default partition
            self._tables[DEFAULT_PARTITION] = incoming[DEFAULT_PARTITION]

    # ------------------------------------------------------------------
    def _predict(
        self, block: int, st: _StreamState, part: str = DEFAULT_PARTITION
    ) -> list[int]:
        if self.predictor == "off":
            return []
        if self.predictor == "nextline":
            return [block + i + 1 for i in range(self.degree)]
        if self.predictor == "stride":
            return [block + (i + 1) * st.stride for i in range(self.degree)]
        if self.predictor == "trace":
            # pure trained table, NO heuristic fallback: sequential runs in
            # the training traces put b -> b+1 into the table on their own,
            # so nextline behavior emerges exactly where traces support it —
            # and nowhere else, which is what keeps wasted bandwidth at or
            # below the hardware-style baselines (fig21/fig22's criterion).
            # Partitioned: a stream only ever chases ITS tenant's table.
            table = self._tables.get(part, ())
            return list(table.get(block, ())[: self.degree]) if table else []
        succ = self._markov.get(block)
        if not succ:
            return []
        # confidence gate: only prefetch successors seen repeatedly AND
        # dominating the transition mass — this is what makes real L2
        # prefetchers ACCURATE but LOW-COVERAGE on irregular streams
        # (paper Fig. 22): confident predictions are rare.
        total = sum(succ.values())
        return [
            b
            for b, c in succ.most_common(self.degree)
            if c >= 2 and c / total >= 0.5
        ]

    def predict_chain(
        self,
        block: int,
        stream: Hashable = 0,
        lookahead: int = 4,
        partition: Optional[str] = None,
    ) -> list[int]:
        """Walk the predictor ``lookahead`` transitions ahead of ``block``.

        Pure prediction — no training, no buffer effects. This is the
        serving engine's issue window: chase the successor chain (or
        stride/nextline extrapolation) and return candidate blocks in
        predicted-access order, deduplicated, cycles cut. ``partition``
        overrides the stream's tenant partition — used for queued requests
        whose stream does not exist yet but whose tenant is known.
        """
        st = self._streams.get(stream, _StreamState())
        part = self._partition_of(stream) if partition is None else str(partition)
        out: list[int] = []
        seen = {int(block)}
        cur = int(block)
        for _ in range(max(0, int(lookahead))):
            preds = [p for p in self._predict(cur, st, part) if p >= 0]
            if not preds:
                break
            for p in preds:
                if p not in seen:
                    seen.add(p)
                    out.append(p)
            if preds[0] in out or preds[0] == cur:
                nxt = preds[0]
                if nxt == cur:
                    break
                cur = nxt
            else:
                break  # chain head already visited: cycle
            if len(out) >= lookahead * max(1, self.degree):
                break
        return out[: max(0, int(lookahead)) * max(1, self.degree)]

    # ------------------------------------------------------------------
    def _buffer_keys(self) -> np.ndarray:
        if self._buf_keys is None:
            self._buf_keys = np.fromiter(self.buffer.keys(), np.int64, len(self.buffer))
        return self._buf_keys

    def _dec_part(self, part: str):
        n = self._part_sizes.get(part, 0) - 1
        if n > 0:
            self._part_sizes[part] = n
        else:
            self._part_sizes.pop(part, None)

    def _evict_overflow(self, part: str):
        """Fair-share partition eviction on buffer overflow.

        The inserting partition pays when it is over its fair share
        (capacity / live partitions); otherwise the LARGEST over-share
        partition pays. Either way the victim partition loses its OLDEST
        pending entry. The invariant this buys: a tenant at or under its
        fair share is never evicted by another tenant's prediction flood —
        the cross-tenant interference the shared LRU used to allow.
        """
        fair = self.capacity / max(1, len(self._part_sizes))
        victim_part = part
        if self._part_sizes.get(part, 0) <= fair:
            victim_part = max(self._part_sizes, key=lambda p: self._part_sizes[p])
        victim = next(b for b, p in self.buffer.items() if p == victim_part)
        del self.buffer[victim]
        self._dec_part(victim_part)
        self.stats.unused_evicted += 1

    def _insert(self, block: int, part: str = DEFAULT_PARTITION):
        if block in self.buffer:
            return
        self.stats.total_prefetched += 1
        self.buffer[block] = part
        self._part_sizes[part] = self._part_sizes.get(part, 0) + 1
        self._buf_keys = None
        if len(self.buffer) > self.capacity:
            self._evict_overflow(part)

    def _consume(self, block: int):
        """A demand access lands on a pending prefetch: that prefetch is
        spent (covered one miss — the block is resident/near now, and its
        later accesses are the tier books' business, not ours)."""
        self._dec_part(self.buffer.pop(block))
        self.stats.used_prefetches += 1
        self._buf_keys = None

    def mark_prefetched(self, blocks, partitions=None) -> int:
        """Charge externally executed prefetches (the serving engine's
        far->near page promotions) to the books and track their use.
        ``partitions`` is one partition name for all blocks, or a sequence
        aligned with ``blocks``; omitted, entries land in the default
        partition."""
        b = np.asarray(blocks, np.int64).reshape(-1)
        if partitions is None:
            parts: Sequence[str] = [DEFAULT_PARTITION] * b.size
        elif isinstance(partitions, str):
            parts = [partitions] * b.size
        else:
            parts = [str(p) for p in partitions]
            assert len(parts) == b.size, (len(parts), b.size)
        n = 0
        for blk, part in zip(b.tolist(), parts):
            if int(blk) not in self.buffer:
                self._insert(int(blk), part)
                n += 1
        return n

    def evict(self, blocks) -> int:
        """Evict pending prefetches (e.g. pages demoted out of the near
        tier before any access needed them): pure wasted bandwidth."""
        evicted = 0
        for b in np.asarray(blocks, np.int64).reshape(-1):
            part = self.buffer.pop(int(b), None)
            if part is not None:
                self._dec_part(part)
                evicted += 1
                self.stats.unused_evicted += 1
        if evicted:
            self._buf_keys = None
        return evicted

    def resident_unused(self) -> int:
        """Pending prefetches no demand access has consumed yet."""
        return len(self.buffer)

    def finalized_stats(self) -> PrefetchStats:
        """Stats with still-pending prefetches charged as unused — the
        end-of-run view fig21/fig22 and ServingEngine.stats() report.
        Non-destructive: the live engine keeps running."""
        return self.stats.finalized(self.resident_unused())

    def finalize(self) -> PrefetchStats:
        """Teardown: flush the buffer, charging pending entries for real."""
        self.stats.unused_evicted += len(self.buffer)
        self.buffer.clear()
        self._part_sizes.clear()
        self._buf_keys = None
        return self.stats

    # ------------------------------------------------------------------
    def access(self, block: int, *, is_far: bool, stream: Hashable = 0) -> bool:
        """Demand access to ``block`` on ``stream``. Returns True if a
        pending prefetch covered it (consuming that prefetch).

        A block with a pending prefetch counts as covered whichever tier
        it currently maps to — the prefetch is what moved it near — and
        the prefetch is spent by the hit (one prefetch covers one miss;
        the block's later accesses are near hits in the tier books). A far
        access with no pending prefetch is a demand fetch. Near accesses
        outside the buffer only train the predictor.
        """
        covered = False
        if block in self.buffer:
            self._consume(block)
            covered = True
        elif is_far:
            self.stats.demand_fetches += 1
        # train + issue (per-stream: interleaved callers never contaminate)
        st = self._stream(stream)
        if st.last is not None:
            st.stride = block - st.last or st.stride
            if st.last != block:
                self._markov[st.last][block] += 1
        st.last = block
        st.tail = None  # scalar access invalidates the batch-walk cache
        part = self._partition_of(stream)
        for p in self._predict(block, st, part):
            if 0 <= p:
                self._insert(p, part)
        return covered

    def access_many(self, blocks, far_mask, stream: Hashable = 0) -> int:
        """Batched per-stream access — the decode hot path.

        One call is one contiguous run of ``stream``'s accesses (a decode
        step's full KV page walk). Semantics relative to a scalar
        ``access`` loop, pinned by the differential oracle in
        tests/test_prefetch.py:

        * probes run for the WHOLE batch first (vectorized membership
          against the buffer), then training and prediction issue — a
          prefetch issued by this batch can cover the next batch, not a
          later element of the same one;
        * training and issue skip the batch's longest prefix that exactly
          re-reads the stream's previous batch: a decode step re-walks the
          same growing page list every step, and retraining the unchanged
          prefix each step is how this loop used to burn host time (and
          inflate markov counts) on the hot path. Only the new suffix
          trains and issues.
        """
        b = np.asarray(blocks, np.int64).reshape(-1)
        if b.size == 0:
            return 0
        f = np.broadcast_to(np.asarray(far_mask, bool).reshape(-1), b.shape) \
            if np.asarray(far_mask).size != b.size else np.asarray(far_mask, bool).reshape(-1)
        # --- probe (vectorized): buffer hits are covered, far misses demand
        keys = self._buffer_keys()
        hit = np.isin(b, keys) if keys.size else np.zeros(b.shape, bool)
        covered = int(hit.sum())
        self.stats.demand_fetches += int((f & ~hit).sum())
        if covered:
            for blk in np.unique(b[hit]).tolist():
                self._consume(blk)
        # --- train on the new suffix only
        st = self._stream(stream)
        prev = st.tail
        k = 0
        if (
            prev is not None
            and prev.size
            and b.size >= prev.size
            and np.array_equal(b[: prev.size], prev)
        ):
            k = int(prev.size)
        st.tail = b.copy()
        if k == b.size:
            return covered  # pure re-read: nothing new to train or issue
        new = b[k:]
        if k == 0 and st.last is None:
            srcs, dsts = new[:-1], new[1:]
        else:
            last = st.last if k == 0 else int(prev[-1])
            srcs = np.concatenate([np.asarray([last], np.int64), new[:-1]])
            dsts = new
        for a_, b_ in zip(srcs.tolist(), dsts.tolist()):
            if a_ != b_:
                self._markov[a_][b_] += 1
        if srcs.size:
            d = int(dsts[-1]) - int(srcs[-1])
            st.stride = d or st.stride
        st.last = int(new[-1])
        # --- issue for the newly advanced blocks only
        part = self._partition_of(stream)
        for blk in new.tolist():
            for p in self._predict(int(blk), st, part):
                if 0 <= p:
                    self._insert(p, part)
        return covered
