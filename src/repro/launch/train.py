"""Training driver (single-host runnable; production mesh via --dryrun-mesh).

On real TPU pods this module is launched per host by the cluster scheduler;
on CPU it trains a reduced config end-to-end with the same code path:

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --steps 200 \
      --reduced --global-batch 8 --seq-len 64 --ckpt-dir /tmp/ckpt

Fault tolerance: auto-resumes from the newest checkpoint in --ckpt-dir;
crash-inject with --fail-at to exercise it. Straggler flags are printed as
they fire. ``--compress-grads`` turns on int8 error-feedback compression of
the cross-pod gradient all-reduce (CPU run: applied to the local grads so
convergence impact is observable).
"""
from __future__ import annotations

import argparse
import time

from repro.configs import get_config
from repro.data.loader import ShardedLoader
from repro.data.synthetic import SyntheticCorpus
from repro.models.api import get_model
from repro.optim import AdamWConfig
from repro.optim.schedule import warmup_cosine
from repro.runtime.trainer import Trainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true", help="CPU-sized config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--host-id", type=int, default=0)
    ap.add_argument("--n-hosts", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    api = get_model(cfg)
    opt = AdamWConfig(lr=args.lr, schedule=warmup_cosine(args.warmup, args.steps))
    tr = Trainer(
        api,
        opt,
        TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every),
    )
    if not tr.try_restore():
        tr.init_state(args.seed)
        print(f"[train] fresh start: {args.arch} ({cfg.n_params()/1e6:.1f}M params)")
    else:
        print(f"[train] resumed from step {tr.step}")

    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seq_len=args.seq_len)
    loader = ShardedLoader(
        corpus,
        global_batch=args.global_batch,
        host_id=args.host_id,
        n_hosts=args.n_hosts,
        start_step=tr.step,
    )
    t0 = time.time()

    def on_step(step, m):
        if step % args.log_every == 0:
            tput = args.global_batch * args.seq_len / max(m["dt"], 1e-9)
            print(
                f"step {step:5d} loss {m['loss']:.4f} acc {m.get('accuracy', 0):.3f} "
                f"gnorm {m.get('grad_norm', 0):.2f} {tput:,.0f} tok/s"
                + (" [STRAGGLER]" if m.get("straggler") else "")
            )

    try:
        tr.run(loader, args.steps - tr.step, fail_at=args.fail_at, on_step=on_step)
    finally:
        loader.close()
    tr.save(sync=True)
    print(f"[train] done: step {tr.step} in {time.time()-t0:.1f}s; ckpt -> {args.ckpt_dir}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
