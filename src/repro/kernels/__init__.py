"""Pallas TPU kernels for the memory-bandwidth hot spots the paper targets.

Each subpackage: kernel.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jit'd public wrapper: padding, GQA head mapping, dtype policy),
ref.py (pure-jnp oracle used by tests and by the models' default path).

flash_attention — blocked online-softmax attention (prefill/train)
paged_attention — decode attention over paged KV via scalar-prefetch page table
tiered_gather   — near/far tiered row gather: fused tier select + int8
                  far-tier dequant + on-device hit counting; the serving
                  engine's device-executed tiering path
                  (runtime/tiered_kv + EngineConfig.device_tiering)
rwkv6_scan      — chunked WKV6 with per-channel data-dependent decay
mamba2_scan     — chunked SSD state-space scan

Interpret-mode policy is shared by all five packages (_interpret.py):
every public op and kernel entry point takes ``interpret=None``, which
resolves to the ``REPRO_KERNEL_INTERPRET`` env var when set, else to
compiled-on-TPU / interpreted-elsewhere auto-detection.
"""
from repro.kernels._interpret import default_interpret, resolve_interpret  # noqa: F401
