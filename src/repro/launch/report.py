"""Render the dry-run JSON artifacts into the EXPERIMENTS.md tables.

PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
Prints §Dry-run (memory/fit/collective schedule) and §Roofline (three terms,
bound, useful ratio) markdown tables from the per-cell JSONs.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

GIB = 2**30


def load(dirpath):
    cells = []
    for path in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def dryrun_table(cells):
    out = [
        "| arch | shape | pool | lower/compile s | peak GiB | fits | collectives (ops: AG/AR/RS/A2A/CP) |",
        "|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if not c["ok"]:
            out.append(f"| {c['arch']} | {c['shape']} | - | - | - | **FAILED** | {c['error'].splitlines()[0][:60]} |")
            continue
        m = c["memory"]
        ops = c["collectives"]["op_counts"]
        sched = "/".join(
            str(ops.get(k, 0))
            for k in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
        )
        out.append(
            f"| {c['arch']} | {c['shape']} | {c['pooled'] or '-'} "
            f"| {c['seconds_lower']:.1f}/{c['seconds_compile']:.1f} "
            f"| {m['peak_bytes']/GIB:.2f} | {'yes' if m['fits'] else '**NO**'} | {sched} |"
        )
    return "\n".join(out)


def roofline_table(cells):
    out = [
        "| arch | shape | compute ms | memory ms (kernel-adj) | raw mem ms | collective ms | bound | useful | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if not c["ok"] or not c.get("roofline"):
            continue
        r = c["roofline"]
        out.append(
            f"| {c['arch']} | {c['shape']} "
            f"| {r['compute_s']*1e3:.1f} | {r['memory_kernel_adj_s']*1e3:.1f} | {r['memory_s']*1e3:.1f} "
            f"| {r['collective_s']*1e3:.1f} | {r['bound']} | {r['useful_ratio']:.3f} "
            f"| {r['roofline_fraction']:.3f} |"
        )
    return "\n".join(out)


def summary(cells):
    ok = [c for c in cells if c["ok"]]
    fit = [c for c in ok if c["memory"]["fits"]]
    worst = sorted(
        (c for c in ok if c.get("roofline")),
        key=lambda c: c["roofline"]["roofline_fraction"],
    )
    lines = [
        f"cells: {len(cells)}, compiled ok: {len(ok)}, fit HBM: {len(fit)}",
    ]
    if worst:
        lines.append(
            "worst roofline fraction: "
            + ", ".join(f"{c['arch']}x{c['shape']}={c['roofline']['roofline_fraction']:.3f}" for c in worst[:3])
        )
        coll = sorted(ok, key=lambda c: -c["roofline"]["collective_s"])
        lines.append(
            "most collective-bound: "
            + ", ".join(f"{c['arch']}x{c['shape']}={c['roofline']['collective_s']*1e3:.0f}ms" for c in coll[:3])
        )
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args(argv)
    for mesh in ("pod1", "pod2"):
        d = os.path.join(args.dir, mesh)
        if not os.path.isdir(d):
            continue
        cells = load(d)
        print(f"\n## Dry-run — {mesh} ({'16x16=256 chips' if mesh == 'pod1' else '2x16x16=512 chips'})\n")
        print(dryrun_table(cells))
        print(f"\n## Roofline — {mesh}\n")
        print(roofline_table(cells))
        print(f"\n{summary(cells)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
