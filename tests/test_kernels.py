"""Pallas kernels vs. their pure-jnp oracles (interpret mode, shape sweeps)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.mamba2_scan.ops import ssd_chunked
from repro.kernels.mamba2_scan.ref import ssd_ref
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.kernels.rwkv6_scan.ops import wkv6_chunked
from repro.kernels.rwkv6_scan.ref import wkv6_ref
from repro.kernels.tiered_gather.ops import gather_rows, tiered_lookup
from repro.kernels.tiered_gather.ref import gather_rows_ref, tiered_lookup_ref


def keys(n, seed=0):
    return jax.random.split(jax.random.PRNGKey(seed), n)


# ---------------------------------------------------------------------------
# flash attention


@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2), (15, 5)])
@pytest.mark.parametrize("lq,lk", [(128, 128), (96, 160)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(hq, hkv, lq, lk, dtype):
    k1, k2, k3 = keys(3)
    d = 64
    q = jax.random.normal(k1, (2, hq, lq, d), dtype)
    k = jax.random.normal(k2, (2, hkv, lk, d), dtype)
    v = jax.random.normal(k3, (2, hkv, lk, d), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    ref = flash_attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


def test_flash_attention_noncausal():
    k1, k2, k3 = keys(3, 7)
    q = jax.random.normal(k1, (1, 4, 64, 64))
    k = jax.random.normal(k2, (1, 4, 64, 64))
    v = jax.random.normal(k3, (1, 4, 64, 64))
    out = flash_attention(q, k, v, causal=False, block_q=32, block_k=32)
    ref = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# paged attention (decode over paged KV)


@pytest.mark.parametrize("hq,hkv", [(8, 2), (4, 4)])
@pytest.mark.parametrize("ps", [16, 32])
def test_paged_attention_sweep(hq, hkv, ps):
    k0, k1, k2, k3 = keys(4, 1)
    B, d, P, pp = 4, 64, 32, 6
    q = jax.random.normal(k0, (B, hq, d))
    kp = jax.random.normal(k1, (hkv, P, ps, d))
    vp = jax.random.normal(k2, (hkv, P, ps, d))
    pt = jax.random.randint(k3, (B, pp), 0, P)
    lengths = jnp.array([1, ps + 3, 2 * ps, pp * ps], jnp.int32)
    out = paged_attention(q, kp, vp, pt, lengths)
    ref = paged_attention_ref(q, kp, vp, pt, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# rwkv6 scan


@pytest.mark.parametrize("T,chunk", [(64, 16), (96, 32)])
def test_wkv6_kernel_sweep(T, chunk):
    k0, k1, k2, k3, k4 = keys(5, 2)
    B, H, K = 2, 2, 16
    r = jax.random.normal(k0, (B, T, H, K))
    k = jax.random.normal(k1, (B, T, H, K))
    v = jax.random.normal(k2, (B, T, H, K))
    lw = -jnp.exp(jax.random.normal(k3, (B, T, H, K)))
    u = jax.random.normal(k4, (H, K))
    y1, s1 = wkv6_chunked(r, k, v, lw, u, chunk=chunk)
    y2, s2 = wkv6_ref(r, k, v, lw, u)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-4)


def test_wkv6_kernel_state_carry():
    """Two chunked calls with carried state == one long call."""
    k0, k1, k2, k3, k4 = keys(5, 3)
    B, T, H, K = 1, 64, 2, 16
    r = jax.random.normal(k0, (B, T, H, K))
    k = jax.random.normal(k1, (B, T, H, K))
    v = jax.random.normal(k2, (B, T, H, K))
    lw = -jnp.exp(jax.random.normal(k3, (B, T, H, K)))
    u = jax.random.normal(k4, (H, K))
    y_full, s_full = wkv6_ref(r, k, v, lw, u)
    h = T // 2
    y1, s1 = wkv6_chunked(r[:, :h], k[:, :h], v[:, :h], lw[:, :h], u, chunk=16)
    y2, s2 = wkv6_chunked(r[:, h:], k[:, h:], v[:, h:], lw[:, h:], u, state=s1, chunk=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# mamba2 (SSD) scan


@pytest.mark.parametrize("T,chunk", [(64, 16), (128, 64)])
def test_ssd_kernel_sweep(T, chunk):
    k0, k1, k2, k3, k4 = keys(5, 4)
    B, H, P, N = 2, 2, 16, 8
    x = jax.random.normal(k0, (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(k1, (B, T, H)))
    A = -jnp.exp(jax.random.normal(k2, (H,)))
    Bm = jax.random.normal(k3, (B, T, N))
    C = jax.random.normal(k4, (B, T, N))
    D = jnp.ones((H,))
    y1, s1 = ssd_chunked(x, dt, A, Bm, C, D, chunk=chunk)
    y2, s2 = ssd_ref(x, dt, A, Bm, C, D)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# tiered gather


@pytest.mark.parametrize("D", [128, 256])
def test_gather_rows_sweep(D):
    k0, k1 = keys(2, 5)
    src = jax.random.normal(k0, (128, D))
    ids = jax.random.randint(k1, (48,), 0, 128)
    np.testing.assert_allclose(
        np.asarray(gather_rows(src, ids)), np.asarray(gather_rows_ref(src, ids)), rtol=1e-6
    )


def test_tiered_lookup_matches_ref():
    k0, k1, k2, k3 = keys(4, 6)
    Mh, Mc, D, N = 16, 32, 128, 24
    hot = jax.random.normal(k0, (Mh, D))
    cold_q = jax.random.randint(k1, (Mc, D), -127, 127).astype(jnp.int8)
    scales = jnp.abs(jax.random.normal(k2, (Mc,))) + 0.01
    tier = jnp.concatenate([jnp.zeros(Mh, jnp.int32), jnp.ones(Mc, jnp.int32)])
    slot = jnp.concatenate([jnp.arange(Mh, dtype=jnp.int32), jnp.arange(Mc, dtype=jnp.int32)])
    ids = jax.random.randint(k3, (N,), 0, Mh + Mc)
    out = tiered_lookup(hot, cold_q, scales, tier, slot, ids)
    ref = tiered_lookup_ref(hot, cold_q, scales, tier, slot, ids)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# model-level chunked scans vs oracles (the in-model memory-lean paths)


def test_model_wkv6_chunked_equals_seq():
    from repro.models.rwkv6 import _wkv6_seq, wkv6

    k0, k1, k2, k3, k4 = keys(5, 8)
    B, T, H, K = 2, 64, 2, 16
    r = jax.random.normal(k0, (B, T, H, K))
    k = jax.random.normal(k1, (B, T, H, K))
    v = jax.random.normal(k2, (B, T, H, K))
    w = jax.nn.sigmoid(jax.random.normal(k3, (B, T, H, K)))
    u = jax.random.normal(k4, (H, K))
    y_c, s_c = wkv6(r, k, v, w, u, chunk=16)
    s0 = jnp.zeros((B, H, K, K), jnp.float32)
    s_s, y_s = _wkv6_seq(s0, r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_s), rtol=1e-5, atol=1e-5)


def test_model_ssd_chunked_equals_seq():
    from repro.models.mamba2 import _ssd_seq, ssd_scan

    k0, k1, k2, k3, k4 = keys(5, 9)
    B, T, H, P, N = 2, 64, 2, 8, 4
    x = jax.random.normal(k0, (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(k1, (B, T, H)))
    A = -jnp.exp(jax.random.normal(k2, (H,)))
    Bm = jax.random.normal(k3, (B, T, N))
    C = jax.random.normal(k4, (B, T, N))
    D = jnp.ones((H,))
    y_c, s_c = ssd_scan(x, dt, A, Bm, C, D, chunk=16)
    s0 = jnp.zeros((B, H, P, N), jnp.float32)
    s_s, y_s = _ssd_seq(s0, x, dt, A, Bm, C)
    y_s = y_s + x * D[None, None, :, None]
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_s), rtol=1e-5, atol=1e-5)
