"""Fleet serving demo: 4 replicas, fleet MemProf, online re-tiering.

The same high-template-share traffic is served twice — once with requests
sprayed round-robin, once with prefix-affinity routing — while the fleet
aggregator stitches every host's attach/detach trace windows into one
representative trace (paper §6.2) and the AutoTierer re-plans placement
from the aggregated histogram (§5). The affinity run must win on the
simulated-throughput cost model: that delta is the paper's shared-TLB
observation operating at fleet scale. A final co-located run shows the
multi-tenant path.

Tenant config
-------------
A fleet becomes multi-tenant by stamping requests with a tenant name and
(optionally) giving each tenant its own SLO and dispatch weight:

* ``RequestGenerator(profile, ..., tenant="web")`` stamps every request;
  ``data.requests.interleave([gen_a, gen_b], n)`` merges several tenants'
  streams by arrival time (ids re-assigned, prefix ids namespaced).
* ``AdmissionController(default_slo, tenant_slos={"cache": SLOModel(...)})``
  sheds each tenant against ITS OWN delay budget, with per-tenant
  offered/admitted/shed books (``tenant_stats()``).
* ``build_fleet(..., tenant_weights={"web": 3.0, "cache": 1.0})`` sets the
  router's weighted-fair dispatch shares: under contention a weight-3
  tenant is picked 3x as often as a weight-1 tenant, so one tenant's burst
  waits in its own queue instead of starving its neighbors.
* Per-tenant observability: ``fleet_stats()["tenants"]`` (service counts,
  shed rate, realized near-hit, queue-wait p50/p99 in virtual time),
  ``fleet_report()["tenants"]`` (per-tenant fleet histograms), and each
  ``TierEpoch.tenant_near_frac`` (who the shared near tier actually
  serves). benchmarks/tenant_interference.py turns these into the paper's
  co-location study.

Event-driven stepping + elasticity
----------------------------------
Fleet runs are event-driven by default (fleet/scheduler.py): each replica
posts step completions on its own virtual clock, so a slow host is a slow
*host*, not a slow *fleet* (``lockstep=True`` keeps the legacy barrier;
with nominal speeds the two produce identical stats). ``build_fleet`` takes
``speeds=(1, 1, 1, 4)`` to make host 3 a 4x straggler and
``elastic=dict(...)`` to let the replica set scale with admission pressure
— scaled-up hosts warm their near tier from the AutoTierer's current fleet
plan, and drained hosts fold their MemProf profile into the aggregate
before retiring. The straggler/autoscale demo below shows both;
benchmarks/straggler_bench.py is the quantitative study.

Continuous batching: give replicas ``EngineConfig(prefill_chunk=16, ...)``
and each engine refills freed slots every step, feeding prompts in
chunk-budget token slices interleaved with decode inside its single
per-step dispatch (whole-slot monolithic prefill at ``prefill_chunk=0``,
the default). The admission controller's backlog estimate is chunk-aware —
a mid-prefill slot owes only its REMAINING chunk tokens, weighted by the
SLO's ``prefill_weight``, so elastic scaling does not over-shed during
long-prompt admission waves — and ``fleet_stats()["tenants"]`` gains
``ttft_p50``/``ttft_p99`` (submit -> first generated token, virtual time)
merged bucket-wise from the per-engine TTFT histograms.

Failure modes & chaos (fleet/faults.py)
---------------------------------------
``ChaosEngine(fleet, [FaultEvent(...)])`` posts a seeded fault scenario
into the run's virtual-time scheduler as first-class events (FAULT
priority: faults at time t land before t's completions). The taxonomy and
what each fault costs:

* ``crash`` — the host dies. Its books survive only through its last
  counter drain; the undrained remainder is quarantined (never folded into
  fleet books) and reported as a quantified ``lost_window`` (undrained
  steps, near/far deltas, discarded decode tokens). In-flight requests are
  re-dispatched from their retained prompts: each charges its tenant's
  ``failovers``/``lost_tokens`` books and re-enters the queue after
  ``retry_backoff * attempt`` of virtual time, until ``max_retries`` is
  exhausted (then ``failed:crash`` in the outcome ledger — nothing is
  silently dropped). With ``duration > 0`` and an ElasticFleet attached, a
  replacement host scales up after the outage window, near tier pre-warmed.
* ``hang`` — the host stalls without dying. The router's per-dispatch
  watchdog (``dispatch_timeout``, a scheduler-native TIMEOUT event) fires
  in bounded virtual time and fails the host over; a recovery *before* the
  watchdog is a transient stall (slots intact, no failover, no loss). The
  dedup guard makes late completions of a failed-over step no-ops — a
  slow-but-alive host can never double-count tokens.
* ``slowdown`` — a transient speed multiplier; the event scheduler simply
  reorders completions (no failover, no loss).
* ``degrade`` — the host's near tier is evacuated at runtime and the
  engine serves far-tier-only (same 1-dispatch/0-sync step budget), with
  ``apply_placement`` epoch-fenced so a stale TierEpoch planned before the
  failover is rejected instead of resurrecting the near set.

Determinism is the point: the same seed replays the identical fault/retry
event order, token streams and merged books — every chaos scenario is a
regression test, not a flaky one. A zero-fault ChaosEngine is bit-exact
with the plain event path. ``fleet_stats()`` carries the chaos surface
(``failovers``, ``requests_retried``, ``lost_tokens``, ``lost_windows``,
``crashed_replicas``, ``fault_events``); ``outcome_report()`` is the
no-silent-drops ledger; the flight recorder emits ``fault``/``failover``/
``retry`` markers with per-tenant ``recovery_vtime`` histograms. The chaos
demo below kills one of three hosts mid-burst and recovers; see
benchmarks/chaos_bench.py for the quantitative study and tests/
test_chaos.py for the pinned invariants.

Flight recorder (repro.obs)
---------------------------
Pass ``build_fleet(recorder=FlightRecorder())`` (or set
``REPRO_FLIGHT_RECORDER=1``) and the fleet records its whole story on the
scheduler's virtual clock: every request's lifecycle as spans (``admit`` →
``queue`` → ``dispatch`` → ``prefill`` → ``decode`` → ``complete``, or
``shed`` at the door), host-level ``step``/``migrate`` spans, scale events
as instants, and every stats counter as a typed metric with tenant/replica
labels (merged fleet-wide via ``router.fleet_metrics()``, bit-identical to
``fleet_stats``). ``recorder.write(path)`` exports Perfetto/Chrome
trace-event JSON — open it at https://ui.perfetto.dev; requests group into
per-tenant process swimlanes, hosts into ``host:<rid>`` tracks — plus a
``.metrics.jsonl`` timeline of registry snapshots per profiler window.
The autoscale demo below records itself and validates the export schema
(balanced B/E pairs, monotone virtual time, labels on every event).

PYTHONPATH=src python examples/serve_fleet.py [--trace out.json]
"""
import dataclasses
import sys

from repro.configs.workloads import get_profile
from repro.data.requests import RequestGenerator, interleave
from repro.fleet import (
    AdmissionController,
    ChaosEngine,
    FaultEvent,
    SLOModel,
    build_fleet,
    export_all,
    fleet_report,
    fleet_vocab,
    validate_fleet,
)
from repro.obs import FlightRecorder

N_REPLICAS = 4
N_PAGES = 512


def serve(policy: str, n_requests: int = 20):
    fleet = build_fleet(
        N_REPLICAS,
        policy=policy,
        n_pages=N_PAGES,
        trace_window=16,
        trace_period=32,
        admission=AdmissionController(SLOModel(max_delay_steps=96.0)),
        autotier=dict(near_frac=0.30, epoch_steps=16),
    )
    prof = dataclasses.replace(
        get_profile("Web1"), prompt_mean=32, decode_mean=8, prefix_share=0.9, n_prefixes=3
    )
    gen = RequestGenerator(prof, vocab_size=fleet_vocab(), seed=0)
    stats = fleet.run(gen, n_requests=n_requests, max_steps=800, submit_per_step=2)
    profiles = export_all(fleet.replicas)
    val = validate_fleet(profiles)
    print(f"[{policy}] {N_REPLICAS} replicas, {stats['requests_finished']} finished, "
          f"{stats['shed']} shed")
    print(f"  simulated throughput {stats['simulated_throughput']:.3f} "
          f"(prefill saved {stats['prefill_tokens_saved']}, shared mappings {stats['shared_mappings']})")
    hist = fleet.autotierer.history
    overlap = f"{hist[-1].overlap_prev:.2f}" if hist else "n/a"
    print(f"  near-hit {stats['near_hit_rate']:.3f}  "
          f"autotier epochs {len(hist)} (last overlap {overlap})")
    print(f"  fleet trace: {val['trace_len']} accesses stitched from "
          f"{sum(len(p.windows) for p in profiles)} windows x {N_REPLICAS} hosts; "
          f"hit-ratio err {val['hit_ratio_error']*100:.2f}%, R:W err {val['rw_ratio_error_pct']:+.2f}%")
    rep = fleet_report(profiles)
    print(f"  fleet histogram: top-10% of pages serve {rep['hot'][0.1]*100:.1f}% of traffic "
          f"(zipf alpha {rep['zipf_alpha']:.2f})")
    return stats, val


def serve_multi_tenant(n_requests: int = 24):
    """Two tenants, one fleet: per-tenant SLOs + weighted-fair dispatch."""
    fleet = build_fleet(
        N_REPLICAS,
        policy="prefix-affinity",
        n_pages=N_PAGES,
        trace_window=16,
        trace_period=32,
        admission=AdmissionController(
            SLOModel(max_delay_steps=96.0),
            tenant_slos={"cache": SLOModel(max_delay_steps=8.0)},
        ),
        autotier=dict(near_frac=0.30, epoch_steps=16),
        tenant_weights={"web": 2.0, "cache": 1.0},
    )
    web = RequestGenerator(
        dataclasses.replace(get_profile("Web1"), prompt_mean=32, decode_mean=8,
                            prefix_share=0.9, n_prefixes=3),
        vocab_size=fleet_vocab(), seed=0, rate=8.0, tenant="web",
    )
    cache = RequestGenerator(
        dataclasses.replace(get_profile("Cache1"), prompt_mean=8, decode_mean=4,
                            prefix_share=0.0),
        vocab_size=fleet_vocab(), seed=1, rate=32.0, tenant="cache",
    )
    reqs = interleave([cache, web], n_requests)
    stats = fleet.run(iter(reqs), n_requests=n_requests, max_steps=800, submit_per_step=2)
    print(f"[multi-tenant] {stats['requests_finished']} finished, {stats['shed']} shed")
    for t, ts in sorted(stats["tenants"].items()):
        print(f"  {t:>6}: finished {ts['requests_finished']:3d}  "
              f"near-hit {ts['near_hit_rate']:.3f}  shed-rate {ts['shed_rate']:.3f}")
    return stats


def serve_straggler_autoscale(trace_path=None):
    """Host 3 runs 4x slow; a burst then scales an elastic fleet up/down.

    The autoscale scenario runs with the flight recorder attached and
    exports (optionally to ``trace_path``) a Perfetto-loadable trace of the
    whole scale cycle — queue/decode spans per request, migrate spans from
    the warm handoff, scale instants on the fleet track."""
    prof = dataclasses.replace(
        get_profile("Web1"), prompt_mean=24, decode_mean=6, prefix_share=0.9, n_prefixes=3
    )
    # straggler: barrier vs event-driven over a fixed 40-unit horizon, with
    # the same offered load per unit virtual time (a lockstep iteration
    # spans 4 units under the 4x straggler, so it gets 4 ticks' arrivals)
    tput = {}
    for lockstep in (True, False):
        fleet = build_fleet(
            N_REPLICAS, policy="least-loaded", speeds=(1, 1, 1, 4), n_pages=N_PAGES,
            trace_window=16, trace_period=32,
        )
        gen = RequestGenerator(prof, vocab_size=fleet_vocab(), seed=0)
        stats = fleet.run(
            gen, n_requests=60, max_steps=10 if lockstep else 40,
            submit_per_step=8 if lockstep else 2, lockstep=lockstep,
        )
        mode = "lockstep" if lockstep else "event"
        tput[mode] = stats["tokens_decoded"] / max(stats["virtual_time"], 1e-9)
        print(f"[straggler/{mode}] {tput[mode]:.2f} tokens per unit virtual time "
              f"({stats['tokens_decoded']} tokens in {stats['virtual_time']:.0f})")
    print(f"  4x straggler: event-driven wins {tput['event'] / tput['lockstep']:.2f}x "
          f"(the barrier pays max(step_cost) every fleet step)")

    # autoscale: a 6 req/tick burst on 2 replicas, then drain + retire —
    # recorded end to end by the flight recorder
    recorder = FlightRecorder()
    fleet = build_fleet(
        2, policy="least-loaded", n_pages=N_PAGES, trace_window=16, trace_period=32,
        admission=AdmissionController(SLOModel(max_delay_steps=16.0)),
        autotier=dict(near_frac=0.30, epoch_steps=4),
        elastic=dict(min_replicas=2, max_replicas=5, cooldown=3.0,
                     up_shed_rate=0.05, up_backlog_frac=0.6, down_backlog_frac=0.15),
        recorder=recorder,
    )
    gen = RequestGenerator(prof, vocab_size=fleet_vocab(), seed=0)
    stats = fleet.run(gen, n_requests=60, max_steps=400, submit_per_step=6)
    print(f"[autoscale] {stats['requests_finished']} finished, {stats['shed']} shed; "
          f"scale events:")
    for vtime, action, rid in stats["scale_events"]:
        print(f"  t={vtime:5.1f}  {action:>6}  host {rid}")
    val = validate_fleet(fleet.export_profiles())
    print(f"  stitched trace across the scale cycle (incl. retired hosts): "
          f"hit-ratio err {val['hit_ratio_error']*100:.2f}%, "
          f"R:W err {val['rw_ratio_error_pct']:+.2f}%")
    if trace_path is not None:
        summary = recorder.write(trace_path)
    else:
        summary = recorder.validate()
    print(f"  flight recorder: {summary['spans']} spans / {summary['instants']} "
          f"instants on {summary['tracks']} tracks, schema valid"
          + (f" -> {trace_path}" if trace_path else ""))
    return stats, val


def serve_chaos(n_requests: int = 18):
    """Kill one of three hosts mid-burst, recover with a replacement.

    The crash salvages the dead host's drained books, quarantines the
    undrained remainder as a ``lost_window``, and re-dispatches stranded
    requests — the outcome ledger must come back complete (every admitted
    request completed, shed, or failed-with-reason)."""
    fleet = build_fleet(
        3, policy="least-loaded", n_pages=N_PAGES, trace_window=16, trace_period=32,
        autotier=dict(near_frac=0.30, epoch_steps=8),
        elastic=dict(min_replicas=1, max_replicas=4),
    )
    chaos = ChaosEngine(
        fleet,
        [FaultEvent(6.0, "crash", rid=1, duration=6.0)],
        dispatch_timeout=8.0, max_retries=3,
    )
    prof = dataclasses.replace(
        get_profile("Web1"), prompt_mean=24, decode_mean=6, prefix_share=0.9, n_prefixes=3
    )
    gen = RequestGenerator(prof, vocab_size=fleet_vocab(), seed=0)
    stats = fleet.run(gen, n_requests=n_requests, max_steps=400, submit_per_step=3)
    print(f"[chaos] {stats['requests_finished']} finished, "
          f"{stats['failovers']} failovers, {stats['requests_retried']} retried, "
          f"{stats['lost_tokens']} decode tokens lost")
    for vtime, action, rid, applied in chaos.log:
        print(f"  t={vtime:5.1f}  {action:>14}  host {rid}" + ("" if applied else "  (no-op)"))
    for w in stats["lost_windows"]:
        print(f"  host {w['rid']} lost_window: {w['steps_undrained']} undrained steps, "
              f"{w['lost_decode_tokens']} decode tokens discarded")
    rep = fleet.outcome_report()
    print(f"  outcome ledger: {rep['outcomes']} (complete={rep['complete']})")
    return stats, rep


def main(trace_path=None):
    rr, _ = serve("round-robin")
    print()
    aff, val = serve("prefix-affinity")
    gain = aff["simulated_throughput"] / rr["simulated_throughput"]
    print(f"\nprefix-affinity vs round-robin: {gain:.2f}x simulated throughput")
    assert gain > 1.0, "prefix-affinity must beat round-robin on shared-template traffic"
    assert val["hit_ratio_error"] <= 0.05 and abs(val["rw_ratio_error_pct"]) <= 5.0, val
    print()
    mt = serve_multi_tenant()
    assert set(mt["tenants"]) == {"web", "cache"}, mt["tenants"]
    print()
    sa, sval = serve_straggler_autoscale(trace_path)
    assert any(e[1] == "up" for e in sa["scale_events"]), sa["scale_events"]
    assert sval["hit_ratio_error"] <= 0.05 and abs(sval["rw_ratio_error_pct"]) <= 5.0, sval
    print()
    cs, crep = serve_chaos()
    assert cs["failovers"] >= 1 and crep["complete"], (cs["failovers"], crep)
    print("serve_fleet ok")


if __name__ == "__main__":
    path = None
    if "--trace" in sys.argv:
        path = sys.argv[sys.argv.index("--trace") + 1]
    main(path)
