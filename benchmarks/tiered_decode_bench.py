"""Device-tiered decode vs flat decode: near-hit fraction and tokens/s.

Two skew levels (high shared-prefix Web-style traffic vs unshared uniform
traffic) x two decode paths:

  * flat   — the legacy host-accounted engine: decode reads one flat KV
             buffer, the tier split is only modeled;
  * tiered — device-executed tiering (EngineConfig.device_tiering): page
             reads run through the fused kernels/tiered_gather pass over
             the near (f32) / far (int8+scales) device stores, with the
             near/far hit counters produced on device.

Also microbenchmarks the two gathers themselves (flat gather vs fused
tiered gather with dequant) over the id stream the engine actually issued,
so the kernel-level cost of executing the split is visible next to the
engine-level throughput. The paper's claim this instruments: a small near
tier captures most of the bandwidth because few pages are hot — the
near-hit fraction at the SAME capacity split should rise with skew.
"""
import dataclasses
import time

import numpy as np

from repro.configs.workloads import get_profile
from repro.data.requests import Request, RequestGenerator

from _common import engine_for, fmt_table

# vtime price of a fully-far decode step relative to a fully-near one: the
# step_cost_fn hook turns the engine's host-side far fraction into virtual
# time, so "tokens per vtime" rewards keeping live walks in the near tier
FAR_WEIGHT = 4.0

SKEWS = {
    # prefix_share concentrates traffic on the shared template pages (one
    # 4-page template at 0.95 share is the "few hot pages" regime); zero
    # share spreads the stream over every sequence's private pages
    "high-skew": dict(prefix_share=0.95, n_prefixes=1),
    "low-skew": dict(prefix_share=0.0, n_prefixes=1),
}


def _run(mode: str, skew: str, n_requests=20, seed=0):
    device = mode == "tiered"
    # near_frac 0.01 -> 5 near pages: well under the ~16-page concurrent
    # working set, so placement has real promote/demote pressure and the
    # near-hit fraction is a function of skew, not of capacity slack
    cfg, eng = engine_for(
        seed=seed, n_pages=512, near_frac=0.01, max_len=96, placement_window=4,
        device_tiering=device,
    )
    prof = dataclasses.replace(
        get_profile("Web1"), prompt_mean=64, decode_mean=12, **SKEWS[skew]
    )
    gen = RequestGenerator(prof, vocab_size=cfg.vocab_size, seed=seed)
    t0 = time.time()
    stats = eng.run(gen, n_requests=n_requests, max_steps=3000)
    dt = time.time() - t0
    return eng, stats, stats["tokens_decoded"] / max(dt, 1e-9)


def _kernel_microbench(eng, n_iters=20):
    """Flat vs tiered gather over the pages the engine actually holds."""
    store = eng.tiered
    if store is None:
        return None
    rng = np.random.default_rng(0)
    # a decode-step-like id burst biased to the near set (hot pages)
    near = np.flatnonzero(store.tier_host == 0)
    far = np.flatnonzero(store.tier_host == 1)
    ids = np.concatenate([
        rng.choice(near, size=48, replace=True) if near.size else np.empty(0, np.int64),
        rng.choice(far, size=16, replace=True) if far.size else np.empty(0, np.int64),
    ])
    store.lookup(ids)  # warm both jit caches
    store.lookup_flat(ids)
    t0 = time.time()
    for _ in range(n_iters):
        store.lookup_flat(ids).block_until_ready()
    t_flat = (time.time() - t0) / n_iters
    t0 = time.time()
    for _ in range(n_iters):
        store.lookup(ids)[0].block_until_ready()
    t_tiered = (time.time() - t0) / n_iters
    return {"flat_us": t_flat * 1e6, "tiered_us": t_tiered * 1e6, "ids": ids.size}


def _phase_requests(vocab, n_requests=64, n_templates=6, phases=16, prompt=72,
                    decode=8, hot_share=0.7, bg_decode=22, seed=7):
    """Skewed phase-shifting traffic: one hot prompt template dominates each
    phase (70% of arrivals), and the hot template ROTATES every phase — the
    popularity shift that makes count-driven placement lag (a returning
    template's chain has ZERO window counts until its requests are already
    stalling on it; the trace-trained table promotes it straight from the
    queue). Background arrivals decode longer, keeping cold template chains
    resident across their popularity troughs — the fleet's long-tail
    traffic. Template chains are shared prefix pages; suffixes private."""
    rng = np.random.default_rng(seed)
    temps = [rng.integers(0, vocab, size=prompt).astype(np.int32) for _ in range(n_templates)]
    per = max(1, n_requests // phases)
    reqs = []
    for i in range(n_requests):
        hot = min(i // per, phases - 1) % n_templates
        t = hot if rng.random() < hot_share else int(rng.integers(0, n_templates))
        sfx = rng.integers(0, vocab, size=4).astype(np.int32)
        dl = decode if t == hot else bg_decode
        reqs.append(
            Request(i, np.concatenate([temps[t], sfx]), dl, t, float(i))
        )
    return reqs


def _prefetch_run(reqs, promote: bool, seed=0):
    """Drive identical traffic through the device-tiered engine with the
    trace-driven prefetch issue window on or off; virtual time is priced by
    the per-step far fraction through the step_cost_fn hook."""
    cfg, eng = engine_for(
        seed=seed, n_pages=512, near_frac=0.03, max_len=96, placement_window=8,
        device_tiering=True, predictor="trace", prefetch_promote=promote,
        prefetch_buffer=128, prefetch_lookahead=6,
    )
    eng.step_cost_fn = lambda e: 1.0 + FAR_WEIGHT * e.last_step_far_frac
    for r in reqs:
        eng.submit(r)
    vtime, steps = 0.0, 0
    while (eng.queue or any(s.active for s in eng.slots)) and steps < 4000:
        eng.step()
        vtime += eng.step_cost()
        steps += 1
    st = eng.stats()
    return st, st["tokens_decoded"] / max(vtime, 1e-9)


def prefetch_scenario():
    """Acceptance scenario: trace-driven far-tier prefetch under a skewed
    phase-shifting workload — near-hit and tokens-per-vtime uplift at an
    unchanged dispatch/sync budget."""
    cfg, _ = engine_for()  # for vocab only; engine cache is shared
    reqs = _phase_requests(cfg.vocab_size)
    base, base_tpv = _prefetch_run(reqs, promote=False)
    pf, pf_tpv = _prefetch_run(reqs, promote=True)
    rows = []
    for name, st, tpv in (("placement-only", base, base_tpv), ("trace-prefetch", pf, pf_tpv)):
        dev = st["device_tiering"]
        rows.append(
            (
                name,
                f"{st['near_hit_rate']:.3f}",
                f"{tpv:.3f}",
                st["prefetch_promoted_pages"],
                f"{st['prefetch_coverage']:.3f}",
                f"{dev['dispatches_per_step']:.2f}",
                f"{dev['host_syncs_per_step']:.2f}",
            )
        )
    print("\n[tiered_decode:prefetch] skewed phase-shifting workload, promote window off -> on")
    print(
        fmt_table(
            rows,
            ["engine", "near-hit", "tok/vtime", "promoted", "coverage", "disp/step", "sync/step"],
        )
    )
    print(
        f"near-hit {base['near_hit_rate']:.3f} -> {pf['near_hit_rate']:.3f}, "
        f"tokens/vtime {base_tpv:.3f} -> {pf_tpv:.3f} "
        f"(+{(pf_tpv / max(base_tpv, 1e-9) - 1) * 100:.1f}%)"
    )
    # self-checks: the uplift the PR claims, at the budget the PR holds to
    ok = True
    if not pf["near_hit_rate"] > base["near_hit_rate"]:
        print("[tiered_decode:prefetch] FAILED: no near-hit uplift")
        ok = False
    if not pf_tpv > base_tpv:
        print("[tiered_decode:prefetch] FAILED: no tokens-per-vtime uplift")
        ok = False
    bdev, pdev = base["device_tiering"], pf["device_tiering"]
    if pdev["dispatches_per_step"] > 1.0 + 1e-9:
        print("[tiered_decode:prefetch] FAILED: >1 dispatch per step")
        ok = False
    if pdev["host_syncs_per_step"] > bdev["host_syncs_per_step"] + 1e-9:
        print("[tiered_decode:prefetch] FAILED: prefetch window added host syncs")
        ok = False
    return ok, {
        "near_hit": (base["near_hit_rate"], pf["near_hit_rate"]),
        "tokens_per_vtime": (base_tpv, pf_tpv),
        "promoted": pf["prefetch_promoted_pages"],
    }


def main():
    # untimed jit warm-up for BOTH paths, so neither timed cell pays
    # model-decode or tiered-kernel compilation
    _run("flat", "high-skew", n_requests=2)
    _run("tiered", "high-skew", n_requests=2)
    rows = []
    out = {}
    micro = None
    for skew in SKEWS:
        for mode in ("flat", "tiered"):
            eng, stats, tps = _run(mode, skew)
            dev = stats["device_tiering"]
            rows.append(
                (
                    skew,
                    mode,
                    f"{stats['near_hit_rate']:.3f}",
                    f"{tps:8.1f}",
                    stats["tokens_decoded"],
                    "-" if dev is None else dev["moved_rows"],
                    "-" if dev is None else f"{dev['near_hit_rate']:.3f}",
                )
            )
            out[(skew, mode)] = {"near_hit_rate": stats["near_hit_rate"], "tokens_per_s": tps}
            if dev is not None and micro is None:
                micro = _kernel_microbench(eng)
    print("[tiered_decode] flat (host-accounted) vs device-executed tiered decode")
    print(
        fmt_table(
            rows,
            ["skew", "decode", "near-hit", "tok/s", "tokens", "dev-moves", "dev-near"],
        )
    )
    hi = out[("high-skew", "tiered")]["near_hit_rate"]
    lo = out[("low-skew", "tiered")]["near_hit_rate"]
    print(f"near-hit fraction at 1% near capacity: high-skew {hi:.3f} vs low-skew {lo:.3f}")
    if micro:
        print(
            f"kernel gather ({micro['ids']} ids): flat {micro['flat_us']:.0f}us "
            f"vs fused tiered+dequant {micro['tiered_us']:.0f}us per call"
        )
    # self-checks: (a) the device path reproduces the host-accounted hit
    # fraction exactly (the differential invariant this PR tests), and
    # (b) the paper's premise — more skew, more traffic in the same small
    # near tier
    for skew in SKEWS:
        if out[(skew, "flat")]["near_hit_rate"] != out[(skew, "tiered")]["near_hit_rate"]:
            print(f"[tiered_decode] FAILED: flat vs tiered near-hit diverge at {skew}")
            return 1
    if hi + 1e-9 < lo:
        print("[tiered_decode] FAILED: high-skew near-hit below low-skew")
        return 1
    ok, pf = prefetch_scenario()
    if not ok:
        return 1
    return {"near_hit": out, "micro": micro, "prefetch": pf}


if __name__ == "__main__":
    rc = main()
    raise SystemExit(rc if isinstance(rc, int) else 0)
