"""Elastic scaling: restore any checkpoint onto any mesh.

Checkpoints are written as full (unsharded) host arrays per leaf, so a
restore is just device_put with the NEW mesh's shardings — shrink from 512
chips to 256, grow back, or change the pool-axis factorization, and the
training state lands correctly re-sharded. The data pipeline re-slices the
same global cursor (ShardedLoader.restore), so the token trajectory is
unchanged across topology changes.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh

from repro.checkpoint import CheckpointManager
from repro.launch import mesh as meshlib


def shardings_for(mesh: Mesh, specs: Any):
    """Pytree of PartitionSpec-tuples -> NamedShardings on ``mesh`` (axes not
    present in the mesh are dropped; non-divisible dims fall back to
    replicated on that axis via the spec filter)."""
    return jax.tree.map(
        lambda s: meshlib.named(mesh, *s),
        specs,
        is_leaf=lambda s: isinstance(s, tuple) and all(
            x is None or isinstance(x, (str, tuple)) for x in s
        ),
    )


def elastic_restore(
    manager: CheckpointManager,
    template: Any,
    mesh: Optional[Mesh],
    specs: Optional[Any] = None,
    step: Optional[int] = None,
):
    """Restore ``template``-shaped state onto ``mesh`` (None = local devices).

    Returns (state, extras). This is the node-failure / resize recovery path:
    build a fresh mesh from the surviving hosts, call this, continue.
    """
    sh = None
    if mesh is not None and specs is not None:
        sh = shardings_for(mesh, specs)
    return manager.restore(template, step=step, shardings=sh)
