"""Sharded, prefetching host data loader.

Each host materializes only its shard of the global batch (``host_id`` /
``n_hosts``), and a background thread keeps ``prefetch`` batches ready —
the input pipeline's analogue of overlapping far-tier fetches with compute.
State is a single integer cursor: checkpointable, elastic-reshardable (a
restore with a different n_hosts re-slices the same global index space).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np

from repro.data.synthetic import SyntheticCorpus


class ShardedLoader:
    def __init__(
        self,
        corpus: SyntheticCorpus,
        global_batch: int,
        host_id: int = 0,
        n_hosts: int = 1,
        prefetch: int = 2,
        start_step: int = 0,
    ):
        assert global_batch % n_hosts == 0, (global_batch, n_hosts)
        self.corpus = corpus
        self.global_batch = global_batch
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.local_batch = global_batch // n_hosts
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=max(1, prefetch))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _indices(self, step: int) -> np.ndarray:
        base = step * self.global_batch
        lo = base + self.host_id * self.local_batch
        return np.arange(lo, lo + self.local_batch)

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.corpus.batch(self._indices(step))
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, batch = self._q.get()
        self.step = step + 1
        return step, batch

    def state(self) -> dict:
        return {"step": self.step, "host_id": self.host_id, "n_hosts": self.n_hosts}

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)

    @classmethod
    def restore(cls, corpus, global_batch, state: dict, host_id: int, n_hosts: int, **kw):
        """Elastic restore: same global cursor, re-sliced for the new topology."""
        return cls(
            corpus, global_batch, host_id=host_id, n_hosts=n_hosts, start_step=state["step"], **kw
        )
