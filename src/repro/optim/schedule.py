"""LR schedules (multiplicative factors; compose with AdamWConfig.lr)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(warmup_steps: int, total_steps: int, min_ratio: float = 0.1):
    def sched(step):
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(1.0, float(warmup_steps))
        t = (step - warmup_steps) / jnp.maximum(1.0, float(total_steps - warmup_steps))
        t = jnp.clip(t, 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup_steps, warm, cos)

    return sched
