from repro.kernels.tiered_gather.ops import gather_rows, tiered_lookup  # noqa: F401
from repro.kernels.tiered_gather.ref import gather_rows_ref, tiered_lookup_ref  # noqa: F401
