"""Device-tiered decode vs flat decode: near-hit fraction and tokens/s.

Two skew levels (high shared-prefix Web-style traffic vs unshared uniform
traffic) x two decode paths:

  * flat   — the legacy host-accounted engine: decode reads one flat KV
             buffer, the tier split is only modeled;
  * tiered — device-executed tiering (EngineConfig.device_tiering): page
             reads run through the fused kernels/tiered_gather pass over
             the near (f32) / far (int8+scales) device stores, with the
             near/far hit counters produced on device.

Also microbenchmarks the two gathers themselves (flat gather vs fused
tiered gather with dequant) over the id stream the engine actually issued,
so the kernel-level cost of executing the split is visible next to the
engine-level throughput. The paper's claim this instruments: a small near
tier captures most of the bandwidth because few pages are hot — the
near-hit fraction at the SAME capacity split should rise with skew.
"""
import dataclasses
import time

import numpy as np

from repro.configs.workloads import get_profile
from repro.data.requests import RequestGenerator

from _common import engine_for, fmt_table

SKEWS = {
    # prefix_share concentrates traffic on the shared template pages (one
    # 4-page template at 0.95 share is the "few hot pages" regime); zero
    # share spreads the stream over every sequence's private pages
    "high-skew": dict(prefix_share=0.95, n_prefixes=1),
    "low-skew": dict(prefix_share=0.0, n_prefixes=1),
}


def _run(mode: str, skew: str, n_requests=20, seed=0):
    device = mode == "tiered"
    # near_frac 0.01 -> 5 near pages: well under the ~16-page concurrent
    # working set, so placement has real promote/demote pressure and the
    # near-hit fraction is a function of skew, not of capacity slack
    cfg, eng = engine_for(
        seed=seed, n_pages=512, near_frac=0.01, max_len=96, placement_window=4,
        device_tiering=device,
    )
    prof = dataclasses.replace(
        get_profile("Web1"), prompt_mean=64, decode_mean=12, **SKEWS[skew]
    )
    gen = RequestGenerator(prof, vocab_size=cfg.vocab_size, seed=seed)
    t0 = time.time()
    stats = eng.run(gen, n_requests=n_requests, max_steps=3000)
    dt = time.time() - t0
    return eng, stats, stats["tokens_decoded"] / max(dt, 1e-9)


def _kernel_microbench(eng, n_iters=20):
    """Flat vs tiered gather over the pages the engine actually holds."""
    store = eng.tiered
    if store is None:
        return None
    rng = np.random.default_rng(0)
    # a decode-step-like id burst biased to the near set (hot pages)
    near = np.flatnonzero(store.tier_host == 0)
    far = np.flatnonzero(store.tier_host == 1)
    ids = np.concatenate([
        rng.choice(near, size=48, replace=True) if near.size else np.empty(0, np.int64),
        rng.choice(far, size=16, replace=True) if far.size else np.empty(0, np.int64),
    ])
    store.lookup(ids)  # warm both jit caches
    store.lookup_flat(ids)
    t0 = time.time()
    for _ in range(n_iters):
        store.lookup_flat(ids).block_until_ready()
    t_flat = (time.time() - t0) / n_iters
    t0 = time.time()
    for _ in range(n_iters):
        store.lookup(ids)[0].block_until_ready()
    t_tiered = (time.time() - t0) / n_iters
    return {"flat_us": t_flat * 1e6, "tiered_us": t_tiered * 1e6, "ids": ids.size}


def main():
    # untimed jit warm-up for BOTH paths, so neither timed cell pays
    # model-decode or tiered-kernel compilation
    _run("flat", "high-skew", n_requests=2)
    _run("tiered", "high-skew", n_requests=2)
    rows = []
    out = {}
    micro = None
    for skew in SKEWS:
        for mode in ("flat", "tiered"):
            eng, stats, tps = _run(mode, skew)
            dev = stats["device_tiering"]
            rows.append(
                (
                    skew,
                    mode,
                    f"{stats['near_hit_rate']:.3f}",
                    f"{tps:8.1f}",
                    stats["tokens_decoded"],
                    "-" if dev is None else dev["moved_rows"],
                    "-" if dev is None else f"{dev['near_hit_rate']:.3f}",
                )
            )
            out[(skew, mode)] = {"near_hit_rate": stats["near_hit_rate"], "tokens_per_s": tps}
            if dev is not None and micro is None:
                micro = _kernel_microbench(eng)
    print("[tiered_decode] flat (host-accounted) vs device-executed tiered decode")
    print(
        fmt_table(
            rows,
            ["skew", "decode", "near-hit", "tok/s", "tokens", "dev-moves", "dev-near"],
        )
    )
    hi = out[("high-skew", "tiered")]["near_hit_rate"]
    lo = out[("low-skew", "tiered")]["near_hit_rate"]
    print(f"near-hit fraction at 1% near capacity: high-skew {hi:.3f} vs low-skew {lo:.3f}")
    if micro:
        print(
            f"kernel gather ({micro['ids']} ids): flat {micro['flat_us']:.0f}us "
            f"vs fused tiered+dequant {micro['tiered_us']:.0f}us per call"
        )
    # self-checks: (a) the device path reproduces the host-accounted hit
    # fraction exactly (the differential invariant this PR tests), and
    # (b) the paper's premise — more skew, more traffic in the same small
    # near tier
    for skew in SKEWS:
        if out[(skew, "flat")]["near_hit_rate"] != out[(skew, "tiered")]["near_hit_rate"]:
            print(f"[tiered_decode] FAILED: flat vs tiered near-hit diverge at {skew}")
            return 1
    if hi + 1e-9 < lo:
        print("[tiered_decode] FAILED: high-skew near-hit below low-skew")
        return 1
    return {"near_hit": out, "micro": micro}


if __name__ == "__main__":
    rc = main()
    raise SystemExit(rc if isinstance(rc, int) else 0)
