"""memtier core: the paper's contribution as a composable library.

profiler    — MemProf analogue (block-access accounting, CDFs, correlation)
distribution— hotness CDF math / Zipf fits / interval stability
tiering     — tier specs, planner, bandwidth-bound throughput model (Table 4/5)
placement   — TPP-like hot/cold placement + migration
prefetch    — software far-tier prefetch engine + accuracy/coverage (Fig 21/22)
pagetable   — ref-counted prefix-shared KV page table (multi-ASID I-TLB analogue)
pooling     — cluster weight pooling (shared-L2 analogue, ZeRO via GSPMD)
memtrace    — windowed trace capture + stitch + cache-sim validation (Table 6)
hw          — TPU v5e + memory-tier hardware constants
"""
