"""Pallas TPU kernels for the memory-bandwidth hot spots the paper targets.

Each subpackage: kernel.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jit'd public wrapper: padding, GQA head mapping, dtype policy),
ref.py (pure-jnp oracle used by tests and by the models' default path).

flash_attention — blocked online-softmax attention (prefill/train)
paged_attention — decode attention over paged KV via scalar-prefetch page table
tiered_gather   — hot-tier row gather (+ int8 far-tier dequant fusion)
rwkv6_scan      — chunked WKV6 with per-channel data-dependent decay
mamba2_scan     — chunked SSD state-space scan
"""
