"""Architecture config registry.

``get_config("qwen2.5-3b")`` returns the exact assigned config;
``list_archs()`` enumerates all ten. Arch ids use the assignment spelling.
"""
from __future__ import annotations

from repro.configs.base import (  # noqa: F401
    SHAPES,
    ModelConfig,
    ShapeSpec,
    applicable_shapes,
    skipped_shapes,
)

from repro.configs.qwen2_5_3b import CONFIG as _qwen2_5_3b
from repro.configs.internlm2_1_8b import CONFIG as _internlm2
from repro.configs.smollm_360m import CONFIG as _smollm
from repro.configs.qwen1_5_110b import CONFIG as _qwen110b
from repro.configs.granite_moe_3b import CONFIG as _granite
from repro.configs.qwen2_moe_a2_7b import CONFIG as _qwen2moe
from repro.configs.rwkv6_7b import CONFIG as _rwkv6
from repro.configs.zamba2_1_2b import CONFIG as _zamba2
from repro.configs.qwen2_vl_7b import CONFIG as _qwen2vl
from repro.configs.whisper_base import CONFIG as _whisper

_REGISTRY: dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _qwen2_5_3b,
        _internlm2,
        _smollm,
        _qwen110b,
        _granite,
        _qwen2moe,
        _rwkv6,
        _zamba2,
        _qwen2vl,
        _whisper,
    )
}


def list_archs() -> list[str]:
    return sorted(_REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {list_archs()}")
    return _REGISTRY[name]
