"""Device-executed tiered KV decode: the differential harness as the oracle.

Three layers of oracle, matching how the path is built:

1. kernel vs pure-jnp ref — ``tiered_lookup_counted`` against
   ``tiered_lookup_counted_ref`` across dtypes (f32/bf16 near, int8 far),
   ragged/duplicate id sets, empty-near / all-near / all-far edge cases,
   and int8 scale round-trip error bounds. Property-style via the
   ``_hypothesis_compat`` shim so the sweep runs with and without
   hypothesis installed.
2. engine equivalence — a seeded ``ServingEngine.run`` with device tiering
   (identity scales: quantization error zeroed) must emit the SAME tokens
   and the SAME tier-hit counters as the host-accounted path; the
   host-side accounting is the bit-exact regression oracle for the device
   path.
3. migration properties — any ``apply_placement`` push conserves pages,
   never exceeds near capacity, accounts migrated bytes exactly, and keeps
   the device tier map in lockstep with placement; a fleet AutoTierer
   epoch drives consistent device migrations on every host.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.configs.workloads import get_profile
from repro.data.requests import RequestGenerator
from repro.fleet import build_fleet, export_all, fleet_vocab, validate_fleet
from repro.kernels.tiered_gather.ops import (
    gather_rows,
    tiered_lookup,
    tiered_lookup_counted,
    tiered_lookup_segments,
)
from repro.kernels.tiered_gather.ref import (
    gather_rows_ref,
    tiered_lookup_counted_ref,
    tiered_lookup_ref,
    tiered_lookup_segments_ref,
)
from repro.models.api import get_model
from repro.runtime.serving import EngineConfig, ServingEngine
from repro.runtime.tiered_kv import TieredKVCache

# ---------------------------------------------------------------------------
# 1. kernel vs ref (differential tests)


def _tier_setup(rng, mh, mc, d, n):
    """Random two-tier layout over a page-id space of mh+mc pages."""
    m = mh + mc
    tier = np.ones(m, np.int32)
    near_ids = rng.choice(m, size=mh, replace=False) if mh else np.empty(0, np.int64)
    tier[near_ids] = 0
    slot = np.zeros(m, np.int32)
    slot[tier == 0] = np.arange(mh)
    slot[tier == 1] = np.arange(mc)
    hot = jnp.asarray(rng.standard_normal((mh, d)), jnp.float32)
    cold_q = jnp.asarray(rng.integers(-127, 128, size=(mc, d)), jnp.int8)
    scales = jnp.asarray(np.abs(rng.standard_normal(mc)) + 0.01, jnp.float32)
    ids = jnp.asarray(rng.integers(0, m, size=n), jnp.int32)
    return hot, cold_q, scales, jnp.asarray(tier), jnp.asarray(slot), ids


def _assert_counted_matches(hot, cold_q, scales, tier, slot, ids):
    rows, near, far = tiered_lookup_counted(hot, cold_q, scales, tier, slot, ids)
    r_rows, r_near, r_far = tiered_lookup_counted_ref(hot, cold_q, scales, tier, slot, ids)
    np.testing.assert_allclose(np.asarray(rows), np.asarray(r_rows), rtol=1e-6, atol=1e-6)
    assert int(near) == int(r_near)
    assert int(far) == int(r_far)
    assert int(near) + int(far) == int(ids.shape[0])


@given(
    st.integers(0, 12),      # near rows
    st.integers(1, 24),      # far rows
    st.integers(1, 40),      # gather width (ragged, may exceed page count)
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_counted_lookup_matches_ref_property(mh, mc, n, seed):
    rng = np.random.default_rng(seed)
    _assert_counted_matches(*_tier_setup(rng, mh, mc, 64, n))


@pytest.mark.parametrize("near_dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("d", [32, 128, 200])
def test_counted_lookup_dtypes(near_dtype, d):
    rng = np.random.default_rng(3)
    hot, cold_q, scales, tier, slot, ids = _tier_setup(rng, 8, 16, d, 30)
    _assert_counted_matches(hot.astype(near_dtype), cold_q, scales, tier, slot, ids)


def test_counted_lookup_duplicate_and_repeated_ids():
    rng = np.random.default_rng(4)
    hot, cold_q, scales, tier, slot, _ = _tier_setup(rng, 4, 4, 64, 1)
    ids = jnp.asarray([0, 0, 7, 7, 7, 3, 0], jnp.int32)
    _assert_counted_matches(hot, cold_q, scales, tier, slot, ids)


def test_counted_lookup_empty_near_tier():
    rng = np.random.default_rng(5)
    hot, cold_q, scales, tier, slot, ids = _tier_setup(rng, 0, 16, 64, 20)
    rows, near, far = tiered_lookup_counted(hot, cold_q, scales, tier, slot, ids)
    assert int(near) == 0 and int(far) == 20
    _assert_counted_matches(hot, cold_q, scales, tier, slot, ids)


def test_counted_lookup_all_near_all_far():
    rng = np.random.default_rng(6)
    m, d = 12, 64
    hot = jnp.asarray(rng.standard_normal((m, d)), jnp.float32)
    cold_q = jnp.asarray(rng.integers(-127, 128, size=(m, d)), jnp.int8)
    scales = jnp.ones((m,), jnp.float32)
    ids = jnp.arange(m, dtype=jnp.int32)
    slot = jnp.arange(m, dtype=jnp.int32)
    rows, near, far = tiered_lookup_counted(
        hot, cold_q, scales, jnp.zeros(m, jnp.int32), slot, ids
    )
    assert (int(near), int(far)) == (m, 0)
    np.testing.assert_allclose(np.asarray(rows), np.asarray(hot), rtol=1e-6)
    rows, near, far = tiered_lookup_counted(
        hot, cold_q, scales, jnp.ones(m, jnp.int32), slot, ids
    )
    assert (int(near), int(far)) == (0, m)
    np.testing.assert_allclose(np.asarray(rows), np.asarray(cold_q, np.float32), rtol=1e-6)


def test_counted_lookup_empty_ids():
    rng = np.random.default_rng(7)
    hot, cold_q, scales, tier, slot, _ = _tier_setup(rng, 4, 4, 64, 1)
    rows, near, far = tiered_lookup_counted(
        hot, cold_q, scales, tier, slot, jnp.zeros((0,), jnp.int32)
    )
    assert rows.shape == (0, 64) and int(near) == 0 and int(far) == 0


def _assert_segmented_matches(hot, cold_q, scales, tier, slot, ids, seg_of, n_seg):
    rows, hits = tiered_lookup_segments(hot, cold_q, scales, tier, slot, ids, seg_of, n_seg)
    r_rows, r_hits = tiered_lookup_segments_ref(
        hot, cold_q, scales, tier, slot, ids, seg_of, n_seg
    )
    np.testing.assert_allclose(np.asarray(rows), np.asarray(r_rows), rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(hits), np.asarray(r_hits))
    # per-segment counts must sum to the single-segment counted lookup —
    # segmentation refines the counters, it never changes the totals
    _, near, far = tiered_lookup_counted(hot, cold_q, scales, tier, slot, ids)
    assert int(np.asarray(hits)[:, 0].sum()) == int(near)
    assert int(np.asarray(hits)[:, 1].sum()) == int(far)


@given(
    st.integers(0, 12),      # near rows
    st.integers(1, 24),      # far rows
    st.integers(1, 40),      # total gather width across segments
    st.integers(1, 6),       # segments actually populated
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_segmented_lookup_matches_ref_property(mh, mc, n, n_seg, seed):
    rng = np.random.default_rng(seed)
    hot, cold_q, scales, tier, slot, ids = _tier_setup(rng, mh, mc, 64, n)
    # unsorted segment assignment: the kernel must not assume contiguity
    seg_of = jnp.asarray(rng.integers(0, n_seg, size=n), jnp.int32)
    # n_seg + 2 leaves trailing segments empty — they must count (0, 0)
    _assert_segmented_matches(hot, cold_q, scales, tier, slot, ids, seg_of, n_seg + 2)


def test_segmented_lookup_empty_ids_and_duplicates():
    rng = np.random.default_rng(9)
    hot, cold_q, scales, tier, slot, _ = _tier_setup(rng, 4, 4, 64, 1)
    rows, hits = tiered_lookup_segments(
        hot, cold_q, scales, tier, slot,
        jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.int32), 3,
    )
    assert rows.shape == (0, 64)
    np.testing.assert_array_equal(np.asarray(hits), np.zeros((3, 2), np.int32))
    ids = jnp.asarray([0, 0, 7, 7, 7, 3, 0], jnp.int32)
    seg_of = jnp.asarray([0, 1, 1, 0, 2, 2, 2], jnp.int32)
    _assert_segmented_matches(hot, cold_q, scales, tier, slot, ids, seg_of, 3)


def test_rows_only_wrappers_agree():
    rng = np.random.default_rng(8)
    hot, cold_q, scales, tier, slot, ids = _tier_setup(rng, 6, 10, 96, 17)
    np.testing.assert_allclose(
        np.asarray(tiered_lookup(hot, cold_q, scales, tier, slot, ids)),
        np.asarray(tiered_lookup_ref(hot, cold_q, scales, tier, slot, ids)),
        rtol=1e-6, atol=1e-6,
    )
    ids2 = jnp.asarray([1, 5, 2], jnp.int32)
    np.testing.assert_allclose(
        np.asarray(gather_rows(hot, ids2)), np.asarray(gather_rows_ref(hot, ids2)), rtol=1e-6
    )


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_int8_scale_round_trip_bound(seed):
    """|x - dq(q(x))| <= scale/2 per element, scale = absmax/127."""
    rng = np.random.default_rng(seed)
    store = TieredKVCache(n_pages=8, row_dim=32, near_capacity=2)
    rows = jnp.asarray(rng.standard_normal((8, 32)) * (10.0 ** rng.uniform(-2, 2)), jnp.float32)
    store.write(np.arange(8), rows)  # all pages start far -> quantized
    got, near, far = store.lookup(np.arange(8))
    assert near == 0 and far == 8
    absmax = np.abs(np.asarray(rows)).max(axis=1)
    bound = absmax / 127.0 / 2.0 + 1e-7
    err = np.abs(np.asarray(got) - np.asarray(rows)).max(axis=1)
    assert (err <= bound).all(), (err, bound)


def test_identity_scales_round_trip_is_exact():
    """Snapped rows survive write -> promote -> demote -> read bit-exactly."""
    rng = np.random.default_rng(11)
    store = TieredKVCache(n_pages=16, row_dim=32, near_capacity=4, identity_scales=True)
    rows = jnp.asarray(rng.integers(-127, 128, size=(16, 32)), jnp.float32)
    store.write(np.arange(16), rows)
    for near_set in ([0, 1, 2, 3], [3, 4, 5], [12, 13, 14, 15], []):
        store.migrate(np.asarray(near_set, np.int64))
        got, _, _ = store.lookup(np.arange(16))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(rows))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(store.lookup_flat(np.arange(16))))
        # diagnostic probe agrees and never perturbs the hit counters
        hits = (store.near_hits, store.far_hits, store.lookups)
        assert store.max_abs_error(np.arange(16)) == 0.0
        assert (store.near_hits, store.far_hits, store.lookups) == hits


def test_migrate_dedups_near_ids_before_capacity_cut():
    store = TieredKVCache(n_pages=32, row_dim=16, near_capacity=5)
    store.migrate([5, 5, 1, 2, 3, 4])
    assert store.near_count == 5
    assert set(np.flatnonzero(store.tier_host == 0)) == {5, 1, 2, 3, 4}


# ---------------------------------------------------------------------------
# 2. engine equivalence: device-tiered decode vs host-accounted decode


def _mk_engine(device, **ekw):
    cfg = get_config("smollm-360m").reduced()
    api = get_model(cfg)
    if not hasattr(_mk_engine, "_params"):
        _mk_engine._params = api.init(jax.random.PRNGKey(0))
    kw = dict(
        # near_frac 0.02 -> 5 near pages of 256: the seeded workload maps
        # more pages than that, so both tiers see real traffic
        max_batch=4, max_len=64, n_pages=256, near_frac=0.02, placement_window=4,
        device_tiering=device, tiered_identity_scales=device, tiered_verify=device,
    )
    kw.update(ekw)
    return cfg, ServingEngine(api, _mk_engine._params, EngineConfig(**kw), seed=0)


def _run_collect(eng, cfg, n_requests=6, seed=0):
    prof = dataclasses.replace(
        get_profile("Web1"), prompt_mean=24, decode_mean=8, prefix_share=0.5, n_prefixes=2
    )
    gen = RequestGenerator(prof, vocab_size=cfg.vocab_size, seed=seed)
    for _ in range(n_requests):
        eng.submit(next(gen))
    tokens, steps = [], 0
    while (eng.queue or any(s.active for s in eng.slots)) and steps < 400:
        eng.step()
        tokens.append(eng.next_tokens.copy())
        steps += 1
    return np.array(tokens)


@pytest.mark.slow
def test_device_decode_bit_identical_to_host_accounting():
    """The acceptance oracle: identity scales => same tokens, same counters."""
    cfg, host = _mk_engine(False)
    t_host = _run_collect(host, cfg)
    cfg, dev = _mk_engine(True)
    t_dev = _run_collect(dev, cfg)
    np.testing.assert_array_equal(t_host, t_dev)
    assert host.live_counters() == dev.live_counters()
    sh, sd = host.stats(), dev.stats()
    for key in (
        "tokens_decoded", "requests_finished", "near_hit_rate", "migrations",
        "prefill_tokens", "prefetch_accuracy", "prefetch_coverage", "tenants",
    ):
        assert sh[key] == sd[key], key
    # the run actually exercised both tiers and the device store agrees
    # with the fleet-facing counters
    devstats = sd["device_tiering"]
    assert devstats["far_hits"] > 0 and devstats["near_hits"] > 0
    assert devstats["near_hits"] == dev.placement.stats.near_hits
    assert devstats["far_hits"] == dev.placement.stats.far_hits
    # differential probe: tiered reads never diverged from the flat buffer
    assert devstats["max_read_error"] == 0.0


@pytest.mark.slow
def test_device_mode_quantized_counters_still_match():
    """Real (absmax) scales perturb VALUES only — the control plane (tokens
    come from the model cache, counters from the tier map) stays exact."""
    cfg, host = _mk_engine(False)
    t_host = _run_collect(host, cfg, seed=3)
    cfg, dev = _mk_engine(True, tiered_identity_scales=False, tiered_verify=True)
    t_dev = _run_collect(dev, cfg, seed=3)
    np.testing.assert_array_equal(t_host, t_dev)
    assert host.live_counters() == dev.live_counters()
    # quantized far tier: reads diverge from flat, boundedly
    assert dev.stats()["device_tiering"]["far_hits"] > 0


@pytest.mark.slow
def test_fleet_trace_validation_with_device_counters():
    """Stitched fleet-trace validation stays <=5% when every host feeds the
    aggregator from device-counted tiering."""
    fleet = build_fleet(
        3, policy="prefix-affinity", seed=0, trace_window=16, trace_period=32,
        n_pages=256, near_frac=0.10, device_tiering=True, tiered_identity_scales=True,
    )
    prof = dataclasses.replace(
        get_profile("Web1"), prompt_mean=24, decode_mean=6, prefix_share=0.9, n_prefixes=3
    )
    gen = RequestGenerator(prof, vocab_size=fleet_vocab(), seed=0)
    fleet.run(gen, n_requests=12, max_steps=600, submit_per_step=2)
    profiles = export_all(fleet.replicas)
    assert all(p.device_tiering is not None for p in profiles)
    assert sum(p.device_tiering["near_hits"] + p.device_tiering["far_hits"] for p in profiles) > 0
    res = validate_fleet(profiles)
    assert res["trace_len"] > 0
    assert res["hit_ratio_error"] <= 0.05, res
    assert abs(res["rw_ratio_error_pct"]) <= 5.0, res


# ---------------------------------------------------------------------------
# 3. migration properties


@given(st.lists(st.integers(0, 255), min_size=0, max_size=64))
@settings(max_examples=25, deadline=None)
def test_apply_placement_properties(near_ids):
    if not hasattr(test_apply_placement_properties, "_eng"):
        test_apply_placement_properties._eng = _mk_engine(True)
    cfg, eng = test_apply_placement_properties._eng
    near_ids = np.asarray(near_ids, np.int64)
    st0 = dataclasses.replace(eng.placement.stats)
    changed = eng.apply_placement(near_ids)
    stats = eng.placement.stats
    promoted = stats.promotions - st0.promotions
    demoted = stats.demotions - st0.demotions
    # pages conserved: the tier map is total, near + far == n_pages
    near_n = int((eng.placement.tier == 0).sum())
    assert near_n + int((eng.placement.tier == 1).sum()) == eng.ecfg.n_pages
    # near capacity never exceeded
    assert near_n <= eng.placement.near_capacity
    # reported migration traffic is exactly (promoted + demoted) * page_bytes
    assert changed == promoted + demoted
    assert stats.migrated_bytes - st0.migrated_bytes == changed * eng.placement.block_bytes
    # device store is in lockstep with placement
    np.testing.assert_array_equal(eng.tiered.tier_host, eng.placement.tier.astype(np.int32))
    assert eng.tiered.near_count == near_n
    # near slots are a valid, duplicate-free subset of the near buffer
    slots = eng.tiered.slot_host[eng.tiered.tier_host == 0]
    assert np.unique(slots).size == slots.size
    assert ((slots >= 0) & (slots < eng.tiered.near_capacity)).all()


def test_migrate_free_slot_bookkeeping():
    store = TieredKVCache(n_pages=32, row_dim=16, near_capacity=8)
    rng = np.random.default_rng(0)
    store.write(np.arange(32), jnp.asarray(rng.standard_normal((32, 16)), jnp.float32))
    for trial in range(20):
        near = rng.choice(32, size=rng.integers(0, 9), replace=False)
        store.migrate(near)
        used = store.slot_host[store.tier_host == 0]
        assert sorted(list(used) + store._free_near) == list(range(8))
        assert store.near_count == near.size


@pytest.mark.slow
def test_autotier_epoch_migrates_consistently_on_every_host():
    """An AutoTierer epoch over 3 replicas pushes ONE fleet plan: every
    host's placement AND device tier map converge to the same near set,
    and the epoch records the device bytes the push actually moved."""
    fleet = build_fleet(
        3, policy="round-robin", seed=1, autotier=dict(near_frac=0.10, epoch_steps=8),
        n_pages=256, near_frac=0.10, device_tiering=True, tiered_identity_scales=True,
    )
    prof = dataclasses.replace(get_profile("Web1"), prompt_mean=24, decode_mean=6)
    gen = RequestGenerator(prof, vocab_size=fleet_vocab(), seed=1)
    fleet.run(gen, n_requests=12, max_steps=600, submit_per_step=2)
    at = fleet.autotierer
    assert at.history, "no tier epoch ran"
    # an explicit extra epoch, bracketed so the device-bytes attribution is
    # exact (earlier epochs interleave with initial fills / local TPP moves)
    moved_before = sum(r.engine.tiered.moved_bytes for r in fleet.replicas)
    ep = at.step(now=10_000.0)
    assert ep is not None
    assert ep.device_moved_bytes == (
        sum(r.engine.tiered.moved_bytes for r in fleet.replicas) - moved_before
    )
    # one fleet plan: every host's placement AND device map agree
    ref_tier = fleet.replicas[0].engine.placement.tier
    for r in fleet.replicas:
        np.testing.assert_array_equal(r.engine.placement.tier, ref_tier)
        np.testing.assert_array_equal(
            r.engine.tiered.tier_host, r.engine.placement.tier.astype(np.int32)
        )
        assert r.engine.tiered.near_count <= r.engine.placement.near_capacity
