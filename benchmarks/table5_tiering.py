"""Paper Table 4/5 + Fig. 20: Baseline / Ideal / Tiered memory-BW tiering.

Reproduces the headline result with the paper's own constants (near tier =
2x BW at 2x cost, 37.5/62.5 capacity split, DDR knee calibrated to the
measured 67.8 GB/s on a 100 GB/s part) driven by the MEASURED Reader-profile
access distribution. Paper: Tiered = 1.46x throughput, 1.13x tput/cost,
within 6.32% of Ideal.
"""
import numpy as np

from repro.core import hw
from repro.core.tiering import ThroughputModel, evaluate_configs

from _common import fmt_table, run_workload, stream_for

PAPER = {"Baseline": (1.0, 1.0), "Ideal": (1.55, 0.73), "Tiered": (1.46, 1.13)}


def main(live_engine=True):
    if live_engine:  # measured KV-page stream from the serving engine
        eng, _ = run_workload("Reader", n_requests=12, prompt=32, decode=12)
        counts = eng.profiler.counts("kv").astype(float)
        src = "engine-measured KV pages (Reader)"
    if not live_engine or counts.sum() < 1000:
        stream, _ = stream_for("Reader", n=200_000)
        counts = np.bincount(stream, minlength=4096).astype(float)
        src = "Reader profile stream"
    res = evaluate_configs(
        counts,
        {"Baseline": hw.BASELINE, "Ideal": hw.IDEAL, "Tiered": hw.TIERED},
        ThroughputModel(),
    )
    rows = []
    for name, r in res.items():
        pt, pc = PAPER[name]
        rows.append(
            (
                name,
                f"{r['relative_throughput']:.3f}",
                f"{pt:.2f}",
                f"{r['throughput_per_cost']:.3f}",
                f"{pc:.2f}",
                r["bound"],
                f"{r['plan'].hit_fracs[0]:.3f}",
            )
        )
    print(f"[table5] source: {src}")
    print(fmt_table(rows, ["config", "tput(x)", "paper", "tput/cost", "paper", "bound", "near-hit"]))
    gap = abs(res["Tiered"]["relative_throughput"] - res["Ideal"]["relative_throughput"]) / res[
        "Ideal"
    ]["relative_throughput"]
    print(f"Tiered within {gap*100:.2f}% of Ideal (paper: 6.32%)")
    return {name: r["relative_throughput"] for name, r in res.items()}


if __name__ == "__main__":
    main()
