"""Device-resident tiered KV page store — the paper's near/far split, executed.

Before this module the serving engine only *accounted* the near/far tier
split host-side (core/placement keeps a tier byte per page) while the
decode math read one flat KV buffer. Here the split is real device state:

  * ``near``  — (near_capacity, D) f32/bf16 rows, the small high-bandwidth
    "HBM" tier that captures most of the bandwidth because few pages are hot;
  * ``far_q`` + ``far_scale`` — (n_pages, D) int8 rows with per-row scales,
    the capacity tier (every page has a reserved far slot, so demotion never
    allocates);
  * ``tier`` / ``slot`` — device int32 maps consumed by the fused Pallas
    kernel (kernels/tiered_gather): tier bit selects the store, slot the row.

Reads go through :meth:`lookup_segments` → ONE fused ragged kernel pass per
engine step (near gather + far gather with dequant + per-segment near/far
hit counting), with the counts accumulated into a device-resident counter
plane (per-slot, per-tenant-index, and total accumulators) instead of
synced to host ints. :meth:`drain_counters` is the only host sync: it
materializes and zeroes the plane, and the serving engine calls it once
per profiler window — the books it charges are bit-identical to charging
every call, because the plane is a pure sum. :meth:`lookup` keeps the
legacy per-call signature (counters returned as host ints, one sync per
call) for direct callers and the dispatch-budget benchmark's baseline.
Placement pushes go through :meth:`migrate` → real data movement:
promotions dequantize far rows into freed near slots, demotions quantize
near rows back into their far slots. ``flat`` mirrors every write into the legacy flat f32 buffer;
it is the differential-test oracle (and the "flat decode" baseline the
benchmark times) — with ``identity_scales=True`` rows are snapped to the
int8 grid at write time, so tiered reads are bit-identical to flat reads
through any promote/demote history.

The flat mirror is kept unconditionally: at repro scale it costs one extra
scatter per write and an (n_pages, D) f32 buffer, and in exchange every
store — not just verify-mode engines — can be differentially probed
(``lookup_flat`` / ``max_abs_error``) by tests and the benchmark's
baseline. A memory-constrained deployment would gate it behind a flag.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

import functools

import jax

from repro.kernels.tiered_gather.ops import (
    gather_rows,
    tiered_lookup_counted,
    tiered_lookup_segments,
)

NEAR, FAR = 0, 1
_QMAX = 127.0

# segment roles for mixed prefill/decode dispatches (continuous batching):
# the engine tags every segment with the phase of work it carries, and the
# counter plane keeps a (role, tier) accumulator next to slot/tenant ones
ROLE_DECODE, ROLE_PREFILL = 0, 1
N_ROLES = 2


@functools.partial(jax.jit, static_argnames=())
def _plane_add(ctr_slot, ctr_tenant, ctr_role, ctr_total, hits, slot_vec,
               tenant_vec, role_vec):
    """Fold one dispatch's per-segment hit pairs into the counter plane —
    pure device arithmetic, no host sync. Padded segments carry zero hits,
    so scatter-adding them anywhere is a no-op."""
    return (
        ctr_slot.at[slot_vec].add(hits),
        ctr_tenant.at[tenant_vec].add(hits),
        ctr_role.at[role_vec].add(hits),
        ctr_total + hits.sum(axis=0),
    )


def _bucket(n: int, floor: int = 32) -> int:
    """Next power-of-two padding bucket: keeps the ragged concat's jitted
    shapes to O(log N) variants instead of one per distinct step size."""
    return max(floor, 1 << (int(n) - 1).bit_length())


def sanitize_near_ids(near_ids, n_pages: int, capacity: int) -> np.ndarray:
    """Canonical near-set sanitizer shared by the engine's apply_placement
    and TieredKVCache.migrate — the two views MUST apply the same rule or
    placement.tier and the device tier map silently diverge: drop
    out-of-range ids, dedup keeping first-seen order, then cut to capacity."""
    ids = np.asarray(near_ids, np.int64).reshape(-1)
    ids = ids[(ids >= 0) & (ids < n_pages)]
    ids = ids[np.sort(np.unique(ids, return_index=True)[1])]
    return ids[:capacity]


class TieredKVCache:
    def __init__(
        self,
        n_pages: int,
        row_dim: int,
        near_capacity: int,
        *,
        near_dtype=jnp.float32,
        identity_scales: bool = False,
        interpret: Optional[bool] = None,
        counter_slots: int = 0,
    ):
        assert 0 < near_capacity <= n_pages
        self.n_pages = n_pages
        self.row_dim = row_dim
        self.near_capacity = near_capacity
        self.identity_scales = identity_scales
        self.interpret = interpret
        # device stores
        self.near = jnp.zeros((near_capacity, row_dim), near_dtype)
        self.far_q = jnp.zeros((n_pages, row_dim), jnp.int8)
        self.far_scale = jnp.ones((n_pages,), jnp.float32)
        self.flat = jnp.zeros((n_pages, row_dim), jnp.float32)
        # host mirrors of the device maps (slot allocation is host-side
        # bookkeeping, exactly like the page table itself)
        self.tier_host = np.full(n_pages, FAR, np.int32)
        self.slot_host = np.arange(n_pages, dtype=np.int32)  # far slot == pid
        self._free_near = list(range(near_capacity - 1, -1, -1))
        self._maps_dirty = True
        self._tier_dev = None
        self._slot_dev = None
        # counters (host books: drained totals plus legacy per-call sums)
        self.near_hits = 0
        self.far_hits = 0
        self.lookups = 0
        self.moved_rows = 0
        self.moved_bytes = 0
        self.writes = 0
        # dispatch/sync budget: kernel launches issued and host round-trips
        # paid — the two quantities the single-dispatch decode step minimizes
        self.dispatches = 0
        self.host_syncs = 0
        self.drains = 0
        # device-resident counter plane: (k, 2) int32 accumulators of
        # (near, far) hit pairs. The slot plane is indexed by engine decode
        # slot, the tenant plane by a caller-assigned tenant index; both
        # grow on demand and are only read by drain_counters().
        self.ctr_slot = jnp.zeros((int(counter_slots), 2), jnp.int32)
        self.ctr_tenant = jnp.zeros((0, 2), jnp.int32)
        # per-ROLE accumulator: row 0 = decode segments, row 1 = prefill
        # chunks — the continuous-batching step carries a role alongside
        # each segment index so mixed prefill/decode dispatches stay
        # attributable without a second kernel pass
        self.ctr_role = jnp.zeros((N_ROLES, 2), jnp.int32)
        self.ctr_total = jnp.zeros((2,), jnp.int32)
        self._plane_dirty = False
        # degraded far-tier-only mode: the near tier is capacity-zeroed at
        # runtime (host poisoned / HBM partition lost). While set, every
        # migrate resolves to the EMPTY near set — demote-only — so no
        # placement push can land rows in a tier the failover declared dead.
        self.degraded = False

    # ------------------------------------------------------------------
    @property
    def near_row_bytes(self) -> int:
        """Bytes a promotion writes into the near tier (f32/bf16 row)."""
        return self.row_dim * self.near.dtype.itemsize

    @property
    def far_row_bytes(self) -> int:
        """Bytes a demotion writes into the far tier (int8 row + scale)."""
        return self.row_dim + 4

    @property
    def near_count(self) -> int:
        return int((self.tier_host == NEAR).sum())

    def _device_maps(self):
        if self._maps_dirty:
            self._tier_dev = jnp.asarray(self.tier_host)
            self._slot_dev = jnp.asarray(self.slot_host)
            self._maps_dirty = False
        return self._tier_dev, self._slot_dev

    def _quantize(self, rows: jnp.ndarray):
        """Per-row symmetric int8 quantization (identity scales: scale=1)."""
        rows = rows.astype(jnp.float32)
        if self.identity_scales:
            scale = jnp.ones((rows.shape[0],), jnp.float32)
        else:
            absmax = jnp.max(jnp.abs(rows), axis=1)
            scale = jnp.maximum(absmax, 1e-30) / _QMAX
        q = jnp.clip(jnp.round(rows / scale[:, None]), -_QMAX, _QMAX).astype(jnp.int8)
        return q, scale

    def snap(self, rows: jnp.ndarray) -> jnp.ndarray:
        """Snap payload rows onto the representable grid.

        Under identity scales that is the int8 integer grid — the
        "quantization error zeroed" mode the equivalence oracle runs in;
        otherwise rows pass through unchanged (far-tier storage is lossy
        and the round-trip error is bounded by scale/2 per element).
        """
        rows = rows.astype(jnp.float32)
        if self.identity_scales:
            rows = jnp.clip(jnp.round(rows), -_QMAX, _QMAX)
        return rows

    # ------------------------------------------------------------------
    def write(self, page_ids, rows):
        """Write payload rows for ``page_ids`` into their CURRENT tier.

        Near pages land in their near slot at full precision; far pages are
        quantized into their reserved far slot. ``flat`` (the legacy flat
        buffer / differential oracle) always receives the full-precision row.
        Duplicate ids keep the last row (page-table writes are ordered).
        """
        pids = np.asarray(page_ids, np.int64).reshape(-1)
        rows = self.snap(jnp.asarray(rows).reshape(pids.size, self.row_dim))
        if pids.size == 0:
            return
        # keep the LAST write per page id
        _, last = np.unique(pids[::-1], return_index=True)
        keep = (pids.size - 1) - last
        pids, rows = pids[keep], rows[jnp.asarray(keep)]
        self.flat = self.flat.at[pids].set(rows)
        near_mask = self.tier_host[pids] == NEAR
        if near_mask.any():
            np_ids = pids[near_mask]
            nrows = rows[jnp.asarray(np.flatnonzero(near_mask))]
            self.near = self.near.at[self.slot_host[np_ids]].set(
                nrows.astype(self.near.dtype)
            )
        if (~near_mask).any():
            fp_ids = pids[~near_mask]
            frows = rows[jnp.asarray(np.flatnonzero(~near_mask))]
            q, scale = self._quantize(frows)
            self.far_q = self.far_q.at[fp_ids].set(q)
            self.far_scale = self.far_scale.at[fp_ids].set(scale)
        self.writes += int(pids.size)

    # ------------------------------------------------------------------
    def lookup(self, page_ids):
        """Gather payload rows for ``page_ids`` through the fused tiered
        kernel. Returns (rows (N, D) f32, near_hits int, far_hits int) —
        the hit split counted on device, at the access point.

        The counters are synced to host ints per call because the engine
        charges them to per-slot tenant books immediately; a
        latency-critical deployment would keep them on device and drain
        once per step."""
        ids = jnp.asarray(np.asarray(page_ids, np.int64).reshape(-1), jnp.int32)
        tier, slot = self._device_maps()
        rows, near, far = tiered_lookup_counted(
            self.near, self.far_q, self.far_scale, tier, slot, ids,
            interpret=self.interpret,
        )
        n, f = int(near), int(far)
        self.near_hits += n
        self.far_hits += f
        self.lookups += 1
        self.dispatches += 1
        self.host_syncs += 1
        return rows, n, f

    # ------------------------------------------------------------------
    def ensure_counter_plane(self, n_slots: int, n_tenants: int):
        """Grow the counter plane to at least (n_slots, n_tenants) rows,
        preserving any undrained counts."""

        def grow(buf, k):
            if buf.shape[0] >= k:
                return buf
            return jnp.concatenate(
                [buf, jnp.zeros((k - buf.shape[0], 2), jnp.int32)]
            )

        self.ctr_slot = grow(self.ctr_slot, int(n_slots))
        self.ctr_tenant = grow(self.ctr_tenant, int(n_tenants))

    def lookup_segments(self, page_ids, seg_of, n_segments: int,
                        slot_idx=None, tenant_idx=None, role_idx=None):
        """Step-wide ragged gather: ONE kernel dispatch, ZERO host syncs.

        ``page_ids`` concatenates every segment's pages; ``seg_of`` assigns
        each gather to a segment in [0, n_segments - 1) — the last segment
        index is reserved for shape-bucketing padding and its counts are
        discarded. ``slot_idx``/``tenant_idx``/``role_idx`` (one index per
        real segment) route the per-segment (near, far) hit pairs into the
        device counter plane, where they accumulate until
        :meth:`drain_counters`. ``role_idx`` carries the segment's phase
        (ROLE_DECODE / ROLE_PREFILL) so a continuous-batching step that
        mixes decode walks with prefill-chunk reads in the SAME dispatch
        stays attributable per phase; omitted, every segment charges the
        decode row.

        Returns the gathered rows (N, D) f32 — a device array; the hit
        counters never touch the host here.
        """
        ids = np.asarray(page_ids, np.int64).reshape(-1)
        seg = np.asarray(seg_of, np.int32).reshape(-1)
        assert seg.size == ids.size
        n_segments = int(n_segments)
        # the last segment is the padding sink: real gathers assigned there
        # would be silently dropped from the books, so fail loudly instead
        assert int(seg.max(initial=-1)) < n_segments - 1, (
            f"seg_of uses segment {int(seg.max(initial=-1))} but n_segments="
            f"{n_segments} reserves the last index for padding"
        )
        if ids.size == 0:
            return jnp.zeros((0, self.row_dim), jnp.float32)
        # pad the ragged concat to a power-of-two bucket; padding gathers
        # page 0 into the sacrificial last segment, whose counts are dropped
        pad = _bucket(ids.size) - ids.size
        if pad:
            ids = np.concatenate([ids, np.zeros(pad, np.int64)])
            seg = np.concatenate([seg, np.full(pad, n_segments - 1, np.int32)])
        tier, slot = self._device_maps()
        rows, seg_hits = tiered_lookup_segments(
            self.near, self.far_q, self.far_scale, tier, slot,
            jnp.asarray(ids, jnp.int32), jnp.asarray(seg), n_segments,
            interpret=self.interpret,
        )
        live = seg_hits[: n_segments - 1]
        k = live.shape[0]
        slot_vec = np.zeros(k, np.int32)
        tenant_vec = np.zeros(k, np.int32)
        role_vec = np.zeros(k, np.int32)  # default: everything is decode
        if slot_idx is not None:
            slot_vec[: len(slot_idx)] = np.asarray(slot_idx, np.int32)
        if tenant_idx is not None:
            tenant_vec[: len(tenant_idx)] = np.asarray(tenant_idx, np.int32)
        if role_idx is not None:
            role_vec[: len(role_idx)] = np.asarray(role_idx, np.int32)
            assert role_vec.min() >= 0 and role_vec.max() < N_ROLES, role_vec
        self.ensure_counter_plane(int(slot_vec.max(initial=-1)) + 1,
                                  int(tenant_vec.max(initial=-1)) + 1)
        self.ctr_slot, self.ctr_tenant, self.ctr_role, self.ctr_total = _plane_add(
            self.ctr_slot, self.ctr_tenant, self.ctr_role, self.ctr_total,
            live, jnp.asarray(slot_vec), jnp.asarray(tenant_vec),
            jnp.asarray(role_vec),
        )
        self._plane_dirty = True
        self.lookups += 1
        self.dispatches += 1
        return rows[: ids.size - pad] if pad else rows

    def drain_counters(self, discard: bool = False) -> dict:
        """The ONE host sync of the counter plane: materialize the per-slot
        / per-tenant / total accumulators, zero them, and fold the totals
        into the host hit books. Draining every step or once per window
        charges identical books — the plane is a pure sum — which is the
        invariant the drain-equivalence test pins.

        Idempotent: a clean (never-accumulated or already-drained) plane
        returns all-zero deltas and charges NOTHING — no host sync, no
        drain tick, no recharge — so crash/teardown paths may drain
        defensively without corrupting the books. Safe on a partially-
        initialized store (constructor interrupted before the plane
        existed): treated as clean.

        ``discard=True`` is the crash path: the deltas are materialized
        and the plane zeroed, but the totals are QUARANTINED — not folded
        into the host hit books and not charged as a host sync — because
        they describe work a dead host never reported. The caller owns
        them as the ``lost_window``; a subsequent normal drain sees a
        clean plane and returns zeros, so the lost counts can never leak
        back into the fleet merge.
        """
        if not getattr(self, "_plane_dirty", False):
            n_slots = self.ctr_slot.shape[0] if hasattr(self, "ctr_slot") else 0
            n_tenants = self.ctr_tenant.shape[0] if hasattr(self, "ctr_tenant") else 0
            return {
                "near": 0,
                "far": 0,
                "slot": np.zeros((n_slots, 2), np.int64),
                "tenant": np.zeros((n_tenants, 2), np.int64),
                "role": np.zeros((N_ROLES, 2), np.int64),
            }
        slot_c, tenant_c, role_c, total = (
            np.asarray(x, np.int64)
            for x in jax.device_get(
                (self.ctr_slot, self.ctr_tenant, self.ctr_role, self.ctr_total)
            )
        )
        self.ctr_slot = jnp.zeros_like(self.ctr_slot)
        self.ctr_tenant = jnp.zeros_like(self.ctr_tenant)
        self.ctr_role = jnp.zeros_like(self.ctr_role)
        self.ctr_total = jnp.zeros_like(self.ctr_total)
        self._plane_dirty = False
        n, f = int(total[0]), int(total[1])
        if not discard:
            self.near_hits += n
            self.far_hits += f
            self.host_syncs += 1
            self.drains += 1
        return {"near": n, "far": f, "slot": slot_c, "tenant": tenant_c,
                "role": role_c}

    def lookup_flat(self, page_ids):
        """The legacy flat-buffer gather (baseline + differential oracle)."""
        ids = jnp.asarray(np.asarray(page_ids, np.int64).reshape(-1), jnp.int32)
        return gather_rows(self.flat, ids, interpret=self.interpret)

    def max_abs_error(self, page_ids) -> float:
        """Tiered-vs-flat read divergence for ``page_ids`` (0.0 under
        identity scales). Diagnostic only: bypasses the hit counters so a
        probe never perturbs the ground-truth accounting."""
        ids = np.asarray(page_ids, np.int64).reshape(-1)
        if ids.size == 0:
            return 0.0
        tier, slot = self._device_maps()
        rows, _, _ = tiered_lookup_counted(
            self.near, self.far_q, self.far_scale, tier, slot,
            jnp.asarray(ids, jnp.int32), interpret=self.interpret,
        )
        return float(jnp.max(jnp.abs(rows - self.lookup_flat(ids))))

    # ------------------------------------------------------------------
    def set_degraded(self, flag: bool):
        """Flip far-tier-only mode. Entering does not move data by itself —
        callers follow with ``migrate(())`` to demote the resident near rows
        (ServingEngine.enter_degraded does both under one accounting
        boundary)."""
        self.degraded = bool(flag)

    # ------------------------------------------------------------------
    def migrate(self, near_ids, account: bool = True) -> dict:
        """Reconcile the device tiers with a planned near set — REAL moves.

        Demotions run first (quantize near row -> its reserved far slot,
        freeing the near slot), then promotions (dequantize far row -> a
        free near slot). Total pages are conserved by construction (tier is
        a total map) and the near tier never exceeds ``near_capacity``.
        Returns {"promoted", "demoted", "moved_rows", "moved_bytes"}.

        ``account=False`` skips the moved_rows/moved_bytes accumulators:
        the constructor-time initial fill loads empty rows into position,
        it is not migration traffic.

        While ``degraded`` the planned near set is forced EMPTY: resident
        near rows demote (data preserved through the quantize path — the
        capacity is what died, not the bits already read out) and no
        promotion can land, whatever the caller planned.
        """
        want = np.zeros(self.n_pages, bool)
        if not self.degraded:
            want[sanitize_near_ids(near_ids, self.n_pages, self.near_capacity)] = True
        cur = self.tier_host == NEAR
        demote = np.flatnonzero(cur & ~want)
        promote = np.flatnonzero(~cur & want)
        if demote.size:
            d_slots = self.slot_host[demote].copy()
            rows = self.near[jnp.asarray(d_slots)].astype(jnp.float32)
            q, scale = self._quantize(rows)
            self.far_q = self.far_q.at[demote].set(q)
            self.far_scale = self.far_scale.at[demote].set(scale)
            self.tier_host[demote] = FAR
            self.slot_host[demote] = demote  # far slot == page id
            self._free_near.extend(int(s) for s in d_slots)
        if promote.size:
            assert len(self._free_near) >= promote.size, "near tier overflow"
            slots = np.array([self._free_near.pop() for _ in range(promote.size)], np.int32)
            rows = self.far_q[jnp.asarray(promote)].astype(jnp.float32) * self.far_scale[
                jnp.asarray(promote)
            ][:, None]
            self.near = self.near.at[jnp.asarray(slots)].set(rows.astype(self.near.dtype))
            self.tier_host[promote] = NEAR
            self.slot_host[promote] = slots
        if demote.size or promote.size:
            self._maps_dirty = True
        moved = int(promote.size + demote.size)
        # bytes written into the destination tier: promotions land full-
        # precision rows in near, demotions land int8 rows + a scale in far
        moved_bytes = int(
            promote.size * self.near_row_bytes + demote.size * self.far_row_bytes
        )
        if account:
            self.moved_rows += moved
            self.moved_bytes += moved_bytes
        return {
            "promoted": int(promote.size),
            "demoted": int(demote.size),
            "moved_rows": moved,
            "moved_bytes": moved_bytes,
        }

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        """Host-book snapshot. ``near_hits``/``far_hits`` report DRAINED
        counts only — callers owning undrained segmented lookups (the
        serving engine) drain before reading."""
        tot = self.near_hits + self.far_hits
        return {
            "near_count": self.near_count,
            "near_capacity": self.near_capacity,
            "near_hits": self.near_hits,
            "far_hits": self.far_hits,
            "near_hit_rate": self.near_hits / max(tot, 1),
            "lookups": self.lookups,
            "writes": self.writes,
            "moved_rows": self.moved_rows,
            "moved_bytes": self.moved_bytes,
            "dispatches": self.dispatches,
            "host_syncs": self.host_syncs,
            "drains": self.drains,
        }
