"""Sharded serving: ONE logical replica spanning chips.

``ShardedServingEngine`` tensor-shards a replica over the ``model`` axis of
a serving mesh (``launch.mesh.make_serving_mesh``; CPU-testable under
``XLA_FLAGS=--xla_force_host_platform_device_count=N``):

* **parameters** are placed once via ``shard_model_params`` — each leaf's
  last axis partitioned over ``model`` when divisible (NamedSharding),
  replicated otherwise — so every jitted step computes on sharded operands
  with no per-call constraint traffic;
* **KV pages** are partitioned PAGE-INTERLEAVED across per-shard
  ``TieredKVCache`` slices: shard ``s`` owns every page with
  ``pid % n_shards == s`` (local id ``pid // n_shards``). Interleaving —
  not feature-dim splitting — is what makes the counter algebra work: each
  page's near/far hit is counted by EXACTLY ONE shard, so summing the
  shards' drained planes reproduces the unsharded engine's counters
  bit-for-bit (feature-sharding the rows would have every shard count
  every hit N times over).

The step budget is unchanged in shape: ONE segmented tiered-gather
dispatch per shard per step (a shard with no pages in the step's walk pays
zero — ``TieredKVCache.lookup_segments`` never launches on an empty id
set) and ZERO mandatory host syncs — each shard keeps its own device
counter plane and drains it independently once per profiler window; a
clean plane's drain early-returns without a sync, so idle shards do not
even pay the window sync.

Drain/merge contract (the PR-5 invariant, per shard): every shard's plane
is a pure sum, so the facade's ``drain_counters`` merges the per-shard
drains by summation into ONE dict with the unsharded shape — placement
stats, tenant books, role accumulators and the MemProf export all see a
single logical store, and the books are bit-identical at any drain
cadence. Per-shard (near, far) deltas are additionally accumulated for the
flight recorder: the engine charges them to ``shard_near_hits{shard=s}`` /
``shard_far_hits{shard=s}`` registry counters, which merge bit-exactly
across replicas like every other counter (sums of sums).

Per-shard near capacity is ``min(pages_owned, global_near_capacity)``:
the planner's global near set restricted to shard ``s`` can never exceed
either bound, so ``sanitize_near_ids``'s silent capacity cut can never
fire on a shard and the per-shard tier maps stay exact restrictions of
``placement.tier``.

Equivalence anchors (tests/test_sharded.py): a 1-shard mesh is bit-exact
with ``ServingEngine`` — same tokens, same drained counters, same tenant
books — and N-shard merged counters equal the 1-shard totals on the same
seeded request stream (the counter path depends on page walks, never on
generated token VALUES, so the equality survives cross-shard float
reassociation in the model math).
"""
from __future__ import annotations

from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.launch.mesh import activate, make_serving_mesh, shard_model_params
from repro.runtime.serving import EngineConfig, ServingEngine
from repro.runtime.tiered_kv import (
    N_ROLES,
    TieredKVCache,
    sanitize_near_ids,
)


def _padded_sum(arrays: List[np.ndarray]) -> np.ndarray:
    """Sum (k_i, 2) int64 arrays of unequal first dims (planes grow on
    demand per shard) into one (max k_i, 2) array."""
    k = max((a.shape[0] for a in arrays), default=0)
    out = np.zeros((k, 2), np.int64)
    for a in arrays:
        out[: a.shape[0]] += a
    return out


class ShardedTieredKV:
    """Per-shard ``TieredKVCache`` slices behind the unsharded interface.

    The serving engine talks to this exactly as it talks to one
    ``TieredKVCache``: global page ids in, merged counters out. Every
    method splits ids by ``pid % n_shards``, forwards local ids
    (``pid // n_shards``) to the owning shard, and merges results by pure
    summation — the decomposition the PR-5 counter-plane invariant makes
    exact at any drain cadence.
    """

    def __init__(
        self,
        n_pages: int,
        row_dim: int,
        near_capacity: int,
        n_shards: int,
        *,
        near_dtype=jnp.float32,
        identity_scales: bool = False,
        interpret: Optional[bool] = None,
        counter_slots: int = 0,
    ):
        if n_shards < 1 or n_pages % n_shards != 0:
            raise ValueError(
                f"n_shards={n_shards} must divide n_pages={n_pages}: the "
                "page-interleaved partition owns pages by pid % n_shards"
            )
        self.n_pages = n_pages
        self.row_dim = row_dim
        self.near_capacity = near_capacity  # the GLOBAL planner capacity
        self.n_shards = n_shards
        self.identity_scales = identity_scales
        self.interpret = interpret
        n_local = n_pages // n_shards
        self.shards = [
            TieredKVCache(
                n_local,
                row_dim,
                min(n_local, near_capacity),
                near_dtype=near_dtype,
                identity_scales=identity_scales,
                interpret=interpret,
                counter_slots=counter_slots,
            )
            for _ in range(n_shards)
        ]
        # per-shard drained (near, far) deltas pending consumption by the
        # engine's shard-labeled metric rows (take_shard_drains)
        self._shard_drained = [{"near": 0, "far": 0} for _ in range(n_shards)]

    # ------------------------------------------------------------------
    # summed host books (the unsharded attribute surface)

    def _sum(self, attr: str) -> int:
        return sum(getattr(sh, attr) for sh in self.shards)

    @property
    def near_hits(self) -> int:
        return self._sum("near_hits")

    @property
    def far_hits(self) -> int:
        return self._sum("far_hits")

    @property
    def lookups(self) -> int:
        return self._sum("lookups")

    @property
    def writes(self) -> int:
        return self._sum("writes")

    @property
    def moved_rows(self) -> int:
        return self._sum("moved_rows")

    @property
    def moved_bytes(self) -> int:
        return self._sum("moved_bytes")

    @property
    def dispatches(self) -> int:
        return self._sum("dispatches")

    @property
    def host_syncs(self) -> int:
        return self._sum("host_syncs")

    @property
    def drains(self) -> int:
        return self._sum("drains")

    @property
    def near_count(self) -> int:
        return self._sum("near_count")

    # ------------------------------------------------------------------
    def _owner(self, ids: np.ndarray) -> np.ndarray:
        return ids % self.n_shards

    def snap(self, rows):
        return self.shards[0].snap(rows)

    def write(self, page_ids, rows):
        ids = np.asarray(page_ids, np.int64).reshape(-1)
        if ids.size == 0:
            return
        rows = jnp.asarray(rows).reshape(ids.size, self.row_dim)
        owner = self._owner(ids)
        for s, sh in enumerate(self.shards):
            idx = np.flatnonzero(owner == s)
            if idx.size:
                sh.write(ids[idx] // self.n_shards, rows[jnp.asarray(idx)])

    def ensure_counter_plane(self, n_slots: int, n_tenants: int):
        for sh in self.shards:
            sh.ensure_counter_plane(n_slots, n_tenants)

    def lookup_segments(self, page_ids, seg_of, n_segments: int,
                        slot_idx=None, tenant_idx=None, role_idx=None):
        """Step-wide ragged gather, ONE dispatch per NON-EMPTY shard.

        Each shard receives its own pages with the ORIGINAL segment
        indices and the same slot/tenant/role routing vectors, pads its
        own ragged concat, and accumulates its own device counter plane —
        no cross-shard sync anywhere. Because every page id lands in
        exactly one shard, the per-segment hit pairs across shards are a
        disjoint partition of the unsharded pairs: their drained sum is
        bit-identical to one store's counts.
        """
        ids = np.asarray(page_ids, np.int64).reshape(-1)
        seg = np.asarray(seg_of, np.int32).reshape(-1)
        if ids.size == 0:
            return jnp.zeros((0, self.row_dim), jnp.float32)
        out = jnp.zeros((ids.size, self.row_dim), jnp.float32)
        owner = self._owner(ids)
        for s, sh in enumerate(self.shards):
            idx = np.flatnonzero(owner == s)
            if idx.size == 0:
                continue  # idle shard: zero dispatches this step
            rows = sh.lookup_segments(
                ids[idx] // self.n_shards, seg[idx], n_segments,
                slot_idx=slot_idx, tenant_idx=tenant_idx, role_idx=role_idx,
            )
            out = out.at[jnp.asarray(idx)].set(rows)
        return out

    def lookup(self, page_ids):
        """Per-call (baseline) path: fan out, merge rows + host-int hits."""
        ids = np.asarray(page_ids, np.int64).reshape(-1)
        rows = jnp.zeros((ids.size, self.row_dim), jnp.float32)
        near = far = 0
        owner = self._owner(ids)
        for s, sh in enumerate(self.shards):
            idx = np.flatnonzero(owner == s)
            if idx.size == 0:
                continue
            r, n, f = sh.lookup(ids[idx] // self.n_shards)
            rows = rows.at[jnp.asarray(idx)].set(r)
            near += n
            far += f
        return rows, near, far

    def lookup_flat(self, page_ids):
        ids = np.asarray(page_ids, np.int64).reshape(-1)
        rows = jnp.zeros((ids.size, self.row_dim), jnp.float32)
        owner = self._owner(ids)
        for s, sh in enumerate(self.shards):
            idx = np.flatnonzero(owner == s)
            if idx.size:
                rows = rows.at[jnp.asarray(idx)].set(
                    sh.lookup_flat(ids[idx] // self.n_shards)
                )
        return rows

    def max_abs_error(self, page_ids) -> float:
        ids = np.asarray(page_ids, np.int64).reshape(-1)
        owner = self._owner(ids)
        err = 0.0
        for s, sh in enumerate(self.shards):
            idx = np.flatnonzero(owner == s)
            if idx.size:
                err = max(err, sh.max_abs_error(ids[idx] // self.n_shards))
        return err

    # ------------------------------------------------------------------
    def drain_counters(self, discard: bool = False) -> dict:
        """Drain every shard's plane independently and merge by summation.

        One host sync per DIRTY shard (a clean shard's drain early-returns
        sync-free), once per profiler window — never per step. The merged
        dict has the unsharded shape, so placement stats, tenant books and
        the role accumulator charge exactly as before; per-shard (near,
        far) deltas accumulate for ``take_shard_drains``.

        ``discard=True`` quarantines every shard's deltas (the crash-path
        ``lost_window`` semantics of TieredKVCache.drain_counters): no
        shard books or shard-drain feed are charged.
        """
        drains = [sh.drain_counters(discard=discard) for sh in self.shards]
        if discard:
            role = np.zeros((N_ROLES, 2), np.int64)
            for d in drains:
                role += np.asarray(d["role"], np.int64)
            return {
                "near": sum(d["near"] for d in drains),
                "far": sum(d["far"] for d in drains),
                "slot": _padded_sum([np.asarray(d["slot"], np.int64) for d in drains]),
                "tenant": _padded_sum([np.asarray(d["tenant"], np.int64) for d in drains]),
                "role": role,
            }
        for s, d in enumerate(drains):
            self._shard_drained[s]["near"] += d["near"]
            self._shard_drained[s]["far"] += d["far"]
        role = np.zeros((N_ROLES, 2), np.int64)
        for d in drains:
            role += np.asarray(d["role"], np.int64)
        return {
            "near": sum(d["near"] for d in drains),
            "far": sum(d["far"] for d in drains),
            "slot": _padded_sum([np.asarray(d["slot"], np.int64) for d in drains]),
            "tenant": _padded_sum([np.asarray(d["tenant"], np.int64) for d in drains]),
            "role": role,
        }

    def take_shard_drains(self) -> List[dict]:
        """Per-shard drained (near, far) deltas since the last take — the
        feed for shard-labeled flight-recorder counters (pure sums, so the
        labeled rows merge bit-exactly at any cadence)."""
        out = self._shard_drained
        self._shard_drained = [{"near": 0, "far": 0} for _ in self.shards]
        return out

    # ------------------------------------------------------------------
    @property
    def degraded(self) -> bool:
        return all(sh.degraded for sh in self.shards)

    def set_degraded(self, flag: bool):
        """Fan far-tier-only mode out to every shard: one logical replica
        degrades as a unit (the mesh that lost its near capacity is shared
        by all shards of the replica)."""
        for sh in self.shards:
            sh.set_degraded(flag)

    # ------------------------------------------------------------------
    def migrate(self, near_ids, account: bool = True) -> dict:
        """Reconcile every shard with the GLOBAL planned near set: shard
        ``s`` receives the set restricted to its own pages (guaranteed to
        fit its capacity — see the module header). Results sum."""
        ids = sanitize_near_ids(near_ids, self.n_pages, self.near_capacity)
        owner = self._owner(ids)
        out = {"promoted": 0, "demoted": 0, "moved_rows": 0, "moved_bytes": 0}
        for s, sh in enumerate(self.shards):
            res = sh.migrate(ids[owner == s] // self.n_shards, account=account)
            for k in out:
                out[k] += res[k]
        return out

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        tot = self.near_hits + self.far_hits
        return {
            "near_count": self.near_count,
            "near_capacity": self.near_capacity,
            "near_hits": self.near_hits,
            "far_hits": self.far_hits,
            "near_hit_rate": self.near_hits / max(tot, 1),
            "lookups": self.lookups,
            "writes": self.writes,
            "moved_rows": self.moved_rows,
            "moved_bytes": self.moved_bytes,
            "dispatches": self.dispatches,
            "host_syncs": self.host_syncs,
            "drains": self.drains,
            # sharding surface: per-shard near ceilings feed the
            # AutoTierer's TierEpoch.shard_near_capacity
            "shards": self.n_shards,
            "shard_near_capacity": [sh.near_capacity for sh in self.shards],
            "shard_dispatches": [sh.dispatches for sh in self.shards],
            "shard_near_hits": [sh.near_hits for sh in self.shards],
            "shard_far_hits": [sh.far_hits for sh in self.shards],
        }


class ShardedServingEngine(ServingEngine):
    """A ``ServingEngine`` whose params and KV pages span a device mesh.

    One logical replica, one routing target: the fleet wraps it in a
    ``Replica`` like any other engine — its profile export, tenant books
    and metrics are the merged (summed) view of its shards. Construction
    places the parameters on the mesh (``shard_model_params``); the tiered
    store comes from the ``_make_tiered_store`` seam as a
    ``ShardedTieredKV``; every step runs under the activated mesh so model
    code's ``shard()`` constraints bind.
    """

    def __init__(
        self,
        api,
        params,
        ecfg: EngineConfig,
        seed: int = 0,
        recorder=None,
        mesh=None,
    ):
        n = max(1, int(ecfg.model_shards))
        if ecfg.n_pages % n != 0:
            raise ValueError(
                f"model_shards={n} must divide n_pages={ecfg.n_pages}"
            )
        self.mesh = mesh if mesh is not None else make_serving_mesh(n)
        if int(self.mesh.shape["model"]) != n:
            raise ValueError(
                f"mesh model axis {self.mesh.shape['model']} != "
                f"model_shards={n}"
            )
        with activate(self.mesh):
            params = shard_model_params(params, self.mesh)
            super().__init__(api, params, ecfg, seed=seed, recorder=recorder)

    def _make_tiered_store(self):
        e = self.ecfg
        return ShardedTieredKV(
            e.n_pages,
            self._payload_dim(),
            self.placement.near_capacity,
            max(1, int(e.model_shards)),
            identity_scales=e.tiered_identity_scales,
            counter_slots=e.max_batch,
        )

    def step(self) -> int:
        # the whole step — admit, chunk/decode dispatch, segmented gather,
        # boundary drain — runs under the mesh so sharding constraints in
        # model code resolve against it; nothing else changes
        with activate(self.mesh):
            return super().step()

    def drain_tier_counters(self):
        d = super().drain_tier_counters()
        if isinstance(self.tiered, ShardedTieredKV):
            # shard-labeled metric rows: drained deltas are pure sums, so
            # these counters merge bit-exactly across cadences and replicas
            for s, delta in enumerate(self.tiered.take_shard_drains()):
                if delta["near"]:
                    self.metrics.counter("shard_near_hits", shard=str(s)).inc(
                        delta["near"]
                    )
                if delta["far"]:
                    self.metrics.counter("shard_far_hits", shard=str(s)).inc(
                        delta["far"]
                    )
        return d
