"""Config system: architecture configs + input-shape specs.

Every assigned architecture is a ``ModelConfig`` (one module per arch under
``repro.configs``). The four assigned input shapes are ``ShapeSpec`` entries in
``SHAPES``. ``applicable_shapes(cfg)`` encodes the per-family skip rules from
the assignment (see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input shape (seq_len x global_batch)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description. Covers dense / moe / ssm / hybrid / vlm / audio.

    Only the fields relevant to ``family`` are honored by the model builders.
    """

    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    # attention details
    qkv_bias: bool = False
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    router_aux_coef: float = 0.001
    capacity_factor: float = 1.25

    # SSM (rwkv6 / mamba2 / zamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_expand: int = 2
    # hybrid (zamba2): a shared attention block applied every k ssm layers
    shared_attn_every: int = 0

    # vlm (qwen2-vl): M-RoPE section split of head_dim/2
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)

    # audio (whisper): encoder-decoder
    n_encoder_layers: int = 0
    n_audio_frames: int = 1_500

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # runtime feature flags (the paper's technique; see core/)
    pooling_cluster: int = 1  # shared-L2 analogue: ZeRO-style weight pooling over k
    kv_page_size: int = 128  # tokens per KV page (pagetable/tiering granularity)
    remat: bool = True
    remat_policy: str = "nothing"  # "nothing" | "dots" (see common.maybe_remat)
    sp_activations: bool = False  # shard the residual stream's seq dim over MODEL
    attn_block_k: int = 256  # k-block for the online-softmax reference attention
    grad_accum: int = 1  # microbatches per step: remat stacks scale as 1/A
    moe_dispatch: str = "einsum"  # "einsum" (GShard one-hot) | "sort" (no one-hot)
    remat_every: int = 1  # checkpoint every k layers: saved stack scales 1/k
    moe_group: int = 2048  # max tokens per routing group: dispatch/combine
    # state is O(1.25*k*t^2/1) per group, so long-sequence cells re-group

    source: str = ""  # provenance tag from the assignment table

    # ------------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        # padded for TP divisibility + lane alignment; CE masks the padding.
        return _round_up(self.vocab_size, 256)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs autoregress (whisper via its decoder)

    @property
    def sub_quadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def n_params(self) -> int:
        """Approximate parameter count (embedding + blocks), for roofline."""
        d, f, v = self.d_model, self.d_ff, self.padded_vocab
        kv_dim = self.n_kv_heads * self.head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family in ("dense", "vlm"):
            per = d * (d + 2 * kv_dim) + d * d + 3 * d * f + 2 * d
            return emb + self.n_layers * per
        if self.family == "moe":
            attn = d * (d + 2 * kv_dim) + d * d
            routed = self.n_experts * 3 * d * self.moe_d_ff
            shared = 3 * d * self.moe_d_ff * self.n_shared_experts
            router = d * self.n_experts
            return emb + self.n_layers * (attn + routed + shared + router + 2 * d)
        if self.family == "ssm":  # rwkv6
            att = 4 * d * d + 6 * d * 32 + d  # r,k,v,o + lora-ish mixers
            ffn = 2 * d * f
            return emb + self.n_layers * (att + ffn + 2 * d)
        if self.family == "hybrid":  # zamba2
            d_in = self.ssm_expand * d
            per = d * (2 * d_in + 2 * self.ssm_state) + d_in * d
            n_shared = 1
            shared_attn = n_shared * (4 * (2 * d) * (2 * d))
            return emb + self.n_layers * per + shared_attn
        if self.family == "audio":
            dec = self.n_layers * (4 * d * d + 2 * d * f + 4 * d * d)
            enc = self.n_encoder_layers * (4 * d * d + 2 * d * f)
            return emb + dec + enc
        raise ValueError(self.family)

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed top_k active)."""
        if self.family != "moe":
            return self.n_params()
        d = self.d_model
        kv_dim = self.n_kv_heads * self.head_dim
        attn = d * (d + 2 * kv_dim) + d * d
        act = (self.top_k + self.n_shared_experts) * 3 * d * self.moe_d_ff
        router = d * self.n_experts
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        return emb + self.n_layers * (attn + act + router + 2 * d)

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests.

        compute_dtype falls back to float32: the XLA CPU runtime cannot
        EXECUTE bf16xbf16 dots (it can compile them — the dry-run keeps
        bf16, which is what the TPU target runs).
        """
        heads = min(self.n_heads, 4)
        kv = max(1, min(self.n_kv_heads, heads))
        while heads % kv:
            kv -= 1
        return dataclasses.replace(
            self,
            compute_dtype="float32",
            n_layers=2,
            d_model=64,
            n_heads=heads,
            n_kv_heads=kv,
            d_ff=128,
            vocab_size=512,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            top_k=min(self.top_k, 2) if self.top_k else 0,
            moe_d_ff=64 if self.moe_d_ff else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state or self.family == "ssm" else self.ssm_head_dim,
            shared_attn_every=2 if self.shared_attn_every else 0,
            n_encoder_layers=2 if self.n_encoder_layers else 0,
            n_audio_frames=16 if self.n_encoder_layers else self.n_audio_frames,
            mrope_sections=(4, 2, 2),
            kv_page_size=16,
        )


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    """Assigned-shape cells for this arch, with the assignment's skip rules."""
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        shapes.append("long_500k")  # full-attention archs skip long_500k
    return shapes


def skipped_shapes(cfg: ModelConfig) -> dict[str, str]:
    out = {}
    if not cfg.sub_quadratic:
        out["long_500k"] = (
            "full-attention arch: 500k context requires sub-quadratic attention "
            "(assignment: run long_500k only for SSM/hybrid/linear-attn)"
        )
    return out
