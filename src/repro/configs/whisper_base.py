"""whisper-base [audio] — enc-dec, conv frontend (stubbed).
[arXiv:2212.04356; unverified]

Backbone only: input_specs() provides precomputed mel-frame embeddings
(the conv1d frontend is a stub per the assignment).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,  # decoder layers
    n_encoder_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    n_audio_frames=1500,
    rope_theta=0.0,  # learned/sinusoidal positions, no RoPE
    source="arXiv:2212.04356; unverified",
)
