"""Per-kernel microbench: wall time (interpret mode on CPU — correctness
path), analytic FLOPs/bytes, and arithmetic intensity vs the v5e ridge.

On TPU the same entry points run compiled (interpret=False); the analytic
intensity column tells where each kernel sits against the 197TF/819GB/s
ridge (240 FLOP/B): attention prefill is compute-side, decode/gather are
memory-side — matching each cell's roofline bound in EXPERIMENTS.md.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hw

from _common import fmt_table

RIDGE = hw.PEAK_FLOPS_BF16 / hw.HBM_BW


def timed(fn, *args, n=3, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def main():
    rows = []
    k0, k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 4)

    # flash attention (prefill tile)
    from repro.kernels.flash_attention.ops import flash_attention

    B, H, L, D = 1, 4, 256, 64
    q = jax.random.normal(k0, (B, H, L, D), jnp.float32)
    k = jax.random.normal(k1, (B, H, L, D), jnp.float32)
    v = jax.random.normal(k2, (B, H, L, D), jnp.float32)
    dt = timed(flash_attention, q, k, v, n=2)
    flops = 4 * B * H * L * L * D
    bts = (3 * B * H * L * D + B * H * L * D) * 2
    rows.append(("flash_attention", f"{dt*1e3:8.1f}", f"{flops/1e9:7.2f}", f"{bts/1e6:7.2f}", f"{flops/bts:7.1f}", "compute" if flops / bts > RIDGE else "memory"))

    # paged attention (decode)
    from repro.kernels.paged_attention.ops import paged_attention

    Bq, Hq, Hkv, d, P, ps, pp = 8, 8, 2, 64, 64, 16, 16
    qd = jax.random.normal(k0, (Bq, Hq, d))
    kp = jax.random.normal(k1, (Hkv, P, ps, d))
    vp = jax.random.normal(k2, (Hkv, P, ps, d))
    pt = jax.random.randint(k3, (Bq, pp), 0, P)
    lens = jnp.full((Bq,), pp * ps, jnp.int32)
    dt = timed(paged_attention, qd, kp, vp, pt, lens, n=2)
    S = pp * ps
    flops = 4 * Bq * Hq * S * d
    bts = Bq * 2 * Hkv * S * d * 2  # stream K+V once
    rows.append(("paged_attention", f"{dt*1e3:8.1f}", f"{flops/1e9:7.2f}", f"{bts/1e6:7.2f}", f"{flops/bts:7.1f}", "memory"))

    # rwkv6 scan
    from repro.kernels.rwkv6_scan.ops import wkv6_chunked

    B2, T, H2, K2 = 1, 128, 4, 32
    r = jax.random.normal(k0, (B2, T, H2, K2))
    kk = jax.random.normal(k1, (B2, T, H2, K2))
    vv = jax.random.normal(k2, (B2, T, H2, K2))
    lw = -jnp.exp(jax.random.normal(k3, (B2, T, H2, K2)))
    u = jax.random.normal(k0, (H2, K2))
    dt = timed(wkv6_chunked, r, kk, vv, lw, u, n=1)
    flops = 4 * B2 * T * H2 * K2 * K2
    bts = 4 * B2 * T * H2 * K2 * 4
    rows.append(("rwkv6_scan", f"{dt*1e3:8.1f}", f"{flops/1e9:7.2f}", f"{bts/1e6:7.2f}", f"{flops/bts:7.1f}", "compute" if flops / bts > RIDGE else "memory"))

    # mamba2 scan
    from repro.kernels.mamba2_scan.ops import ssd_chunked

    Hm, P2, N = 4, 32, 16
    x = jax.random.normal(k0, (B2, T, Hm, P2))
    dts = jax.nn.softplus(jax.random.normal(k1, (B2, T, Hm)))
    A = -jnp.exp(jax.random.normal(k2, (Hm,)))
    Bm = jax.random.normal(k3, (B2, T, N))
    C = jax.random.normal(k0, (B2, T, N))
    Dv = jnp.ones((Hm,))
    dt = timed(ssd_chunked, x, dts, A, Bm, C, Dv, n=1)
    flops = 4 * B2 * T * Hm * P2 * N
    bts = B2 * T * (Hm * P2 * 2 + 2 * N) * 4
    rows.append(("mamba2_scan", f"{dt*1e3:8.1f}", f"{flops/1e9:7.2f}", f"{bts/1e6:7.2f}", f"{flops/bts:7.1f}", "memory"))

    # tiered gather
    from repro.kernels.tiered_gather.ops import gather_rows

    src = jax.random.normal(k0, (4096, 512))
    ids = jax.random.randint(k1, (256,), 0, 4096)
    dt = timed(gather_rows, src, ids, n=2)
    bts = 256 * 512 * 4 * 2
    rows.append(("tiered_gather", f"{dt*1e3:8.1f}", f"{0.0:7.2f}", f"{bts/1e6:7.2f}", f"{0.0:7.1f}", "memory"))

    print(f"[kernels] interpret-mode timing (CPU correctness path) + analytic v5e roofline position (ridge={RIDGE:.0f} FLOP/B)")
    print(fmt_table(rows, ["kernel", "ms(interp)", "GFLOP", "MB", "FLOP/B", "v5e side"]))
    return {r[0]: r[4] for r in rows}


if __name__ == "__main__":
    main()
