"""Hotness-distribution math (Fig. 9 / Fig. 18 analytics).

Everything operates on a per-block access-count vector (the profiler's
output): CDFs, hot-set extraction, Zipf fits, and the interval-stability
check that justifies tiering (paper: "a similar memory bandwidth profile for
different measurement intervals ... supports memory bandwidth tiering").
"""
from __future__ import annotations

import numpy as np


def bandwidth_cdf(counts: np.ndarray):
    """counts: (n_blocks,) access counts.

    Returns (capacity_frac, traffic_frac): traffic_frac[i] = fraction of all
    accesses served by the hottest capacity_frac[i] of blocks.
    """
    counts = np.asarray(counts, dtype=np.float64)
    n = counts.size
    order = np.argsort(-counts)
    sorted_c = counts[order]
    total = max(sorted_c.sum(), 1.0)
    traffic = np.cumsum(sorted_c) / total
    capacity = np.arange(1, n + 1) / n
    return capacity, traffic


def hot_fraction(counts: np.ndarray, capacity_frac: float) -> float:
    """Traffic fraction served by the hottest ``capacity_frac`` of blocks."""
    cap, tra = bandwidth_cdf(counts)
    k = max(1, int(np.ceil(capacity_frac * counts.size)))
    return float(tra[k - 1])


def capacity_for_traffic(counts: np.ndarray, traffic_frac: float) -> float:
    """Smallest capacity fraction serving >= ``traffic_frac`` of accesses
    (the paper's '90%-tile bandwidth is contributed by <10% of capacity')."""
    cap, tra = bandwidth_cdf(counts)
    idx = int(np.searchsorted(tra, traffic_frac))
    idx = min(idx, counts.size - 1)
    return float(cap[idx])


def hot_set(counts: np.ndarray, capacity_frac: float) -> np.ndarray:
    """Block ids of the hottest ``capacity_frac`` of blocks."""
    k = max(1, int(np.ceil(capacity_frac * counts.size)))
    return np.argsort(-np.asarray(counts))[:k]


def zipf_alpha(counts: np.ndarray) -> float:
    """Least-squares Zipf exponent over the non-zero ranked counts."""
    c = np.sort(np.asarray(counts, dtype=np.float64))[::-1]
    c = c[c > 0]
    if c.size < 3:
        return 0.0
    ranks = np.arange(1, c.size + 1)
    slope, _ = np.polyfit(np.log(ranks), np.log(c), 1)
    return float(-slope)


def interval_stability(window_counts: list[np.ndarray], capacity_frac: float = 0.1) -> dict:
    """Max deviation of hot_fraction across measurement windows (Fig. 18).

    Small deviation == the bandwidth distribution is stable over time ==
    tiering placement decisions stay valid between migrations.
    """
    fracs = [hot_fraction(w, capacity_frac) for w in window_counts if np.sum(w) > 0]
    if not fracs:
        return {"mean": 0.0, "max_dev": 0.0, "fracs": []}
    mean = float(np.mean(fracs))
    return {"mean": mean, "max_dev": float(np.max(np.abs(np.array(fracs) - mean))), "fracs": fracs}


def pearson(a: np.ndarray, b: np.ndarray) -> float:
    """Pearson correlation between two access-count vectors (Table 2)."""
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    sa, sb = a.std(), b.std()
    if sa == 0 or sb == 0:
        return 0.0
    return float(((a - a.mean()) * (b - b.mean())).mean() / (sa * sb))
