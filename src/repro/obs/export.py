"""Exporters: Perfetto/Chrome ``trace_event`` JSON + metrics JSON lines.

A span timeline is only useful if a human can open it. This module renders
the flight recorder's spans in the Chrome trace-event format (load the file
at https://ui.perfetto.dev or chrome://tracing):

* **request tracks** — every request is one thread (tid = rid) inside its
  tenant's process (pid = tenant index), so a request's ``queue`` →
  ``prefill`` → ``decode`` story reads left-to-right on one line and a
  tenant's requests stack into one swimlane group;
* **host tracks** — replica-level spans (``step``, ``migrate``) and scale
  events render under per-host processes (pid = HOST_PID_BASE + rid);
* **fleet track** — pid 0 carries fleet-scoped instants.

Timestamps are *virtual time* scaled by ``TS_SCALE`` (1 vtime unit = 1 ms
of trace time) — the causal order of the deterministic scheduler, not wall
clock. Spans become balanced B/E pairs (every ``B`` has its ``E``), instants
become ``i`` events, and every event's args carry ``tenant`` and ``replica``
labels; :func:`validate_trace_events` enforces exactly that schema plus
global ts monotonicity, and is what the CI smoke job runs against a real
recorded fleet scenario.

Metrics snapshots export as JSON lines — one object per profiler window
with a ``vtime`` stamp — so a scenario yields a timeline of every registry
series, not just final totals.
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional

from repro.obs.spans import INSTANT, Span

HOST_PID_BASE = 1_000_000  # host tracks live far above any tenant pid
TS_SCALE = 1000.0  # trace-event ts is in us; 1 vtime unit -> 1 ms


def _tenant_pids(spans: Iterable[Span]) -> Dict[str, int]:
    names = sorted({s.tenant for s in spans if s.trace >= 0})
    return {t: i + 1 for i, t in enumerate(names)}  # pid 0 is the fleet


def _track(span: Span, tenant_pids: Dict[str, int]):
    if span.trace >= 0:
        return tenant_pids.get(span.tenant, 0), span.trace
    if span.replica >= 0:
        return HOST_PID_BASE + span.replica, 0
    return 0, 0


def to_trace_events(spans: List[Span]) -> List[dict]:
    """Render finished spans as a ts-sorted trace-event list.

    Per track, spans are emitted in (t0, t1) order as adjacent B/E pairs;
    the final stable sort by ts interleaves tracks while preserving each
    track's B-before-E order at equal timestamps — so the output is both
    globally monotone in virtual time and balanced per track.
    """
    tenant_pids = _tenant_pids(spans)
    tracks: Dict[tuple, List[tuple]] = {}
    for idx, s in enumerate(spans):
        tracks.setdefault(_track(s, tenant_pids), []).append((s.t0, s.t1, idx, s))
    events: List[dict] = []
    for (pid, tid), items in sorted(tracks.items()):
        items.sort(key=lambda it: (it[0], it[1], it[2]))
        for t0, t1, _, s in items:
            args = {"tenant": s.tenant, "replica": s.replica, **s.args}
            common = {"name": s.name, "pid": pid, "tid": tid, "cat": "repro", "args": args}
            if s.kind == INSTANT:
                events.append({**common, "ph": "i", "s": "t", "ts": t0 * TS_SCALE})
            else:
                events.append({**common, "ph": "B", "ts": t0 * TS_SCALE})
                events.append({**common, "ph": "E", "ts": max(t1, t0) * TS_SCALE})
    events.sort(key=lambda e: e["ts"])  # stable: per-track order survives ties
    meta = [
        {"name": "process_name", "ph": "M", "pid": 0, "tid": 0, "ts": 0,
         "args": {"name": "fleet"}},
    ]
    for t, pid in sorted(tenant_pids.items()):
        meta.append({"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                     "ts": 0, "args": {"name": f"tenant:{t or 'default'}"}})
    for pid in sorted({e["pid"] for e in events if e["pid"] >= HOST_PID_BASE}):
        meta.append({"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                     "ts": 0, "args": {"name": f"host:{pid - HOST_PID_BASE}"}})
    return meta + events


def validate_trace_events(events: List[dict]) -> dict:
    """Schema gate for exported traces (the CI smoke contract).

    Raises ``ValueError`` on: non-monotone ts, unbalanced or misnested B/E
    on any (pid, tid) track, or a span/instant event missing the tenant or
    replica label. Returns summary counts on success.
    """
    stacks: Dict[tuple, List[str]] = {}
    last_ts = float("-inf")
    n_spans = n_instants = 0
    for i, e in enumerate(events):
        for field in ("name", "ph", "pid", "tid"):
            if field not in e:
                raise ValueError(f"event {i} missing {field!r}: {e}")
        if e["ph"] == "M":
            continue
        ts = e.get("ts")
        if ts is None:
            raise ValueError(f"event {i} missing ts: {e}")
        if ts < last_ts:
            raise ValueError(
                f"event {i} ts {ts} < previous {last_ts}: vtime not monotone"
            )
        last_ts = ts
        args = e.get("args", {})
        if "tenant" not in args or "replica" not in args:
            raise ValueError(f"event {i} lacks tenant/replica labels: {e}")
        key = (e["pid"], e["tid"])
        if e["ph"] == "B":
            stacks.setdefault(key, []).append(e["name"])
        elif e["ph"] == "E":
            stack = stacks.get(key, [])
            if not stack:
                raise ValueError(f"event {i}: E {e['name']!r} with empty stack on {key}")
            top = stack.pop()
            if top != e["name"]:
                raise ValueError(
                    f"event {i}: E {e['name']!r} closes B {top!r} on {key} (misnested)"
                )
            n_spans += 1
        elif e["ph"] == "i":
            n_instants += 1
        else:
            raise ValueError(f"event {i}: unexpected phase {e['ph']!r}")
    unbalanced = {k: v for k, v in stacks.items() if v}
    if unbalanced:
        raise ValueError(f"unbalanced B events at end of trace: {unbalanced}")
    return {
        "events": len(events),
        "spans": n_spans,
        "instants": n_instants,
        "tracks": len(stacks),
    }


def write_trace(path: str, events: List[dict]):
    """Chrome/Perfetto JSON object form (loadable as-is in the Perfetto UI)."""
    with open(path, "w") as f:
        json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f)


def write_metrics(path: str, rows: List[dict]):
    """Metrics snapshots as JSON lines: one flat object per profiler window."""
    with open(path, "w") as f:
        for row in rows:
            f.write(json.dumps(row, sort_keys=True) + "\n")


def read_trace(path: str) -> List[dict]:
    with open(path) as f:
        doc = json.load(f)
    return doc["traceEvents"] if isinstance(doc, dict) else doc
