"""Admission control: shed overload instead of thrashing the far tier.

The paper's Fig. 4 point is that pushing DDR past its utilization knee
explodes latency — the serving analogue is a backlog so deep that decode
steps queue behind far-tier migration traffic. The controller models each
request as (prefill + decode) token-equivalents of work, estimates the
fleet's service rate from its slot capacity, and admits only while the
projected queueing delay stays inside the SLO. Shed requests are counted,
not errored: an overloaded fleet degrades by rejecting at the door.

Multi-tenant: each tenant may carry its own ``SLOModel`` (a latency-tight
cache tenant sheds earlier than a throughput web tenant), and offered /
admitted are accounted per tenant so one tenant's burst shows up in *its*
shed rate, not its neighbors'. A tenant's own queued-but-undispatched work
is charged against its fair share of the fleet rate (``weight_share``), so
the projection a burst tenant sees inflates with its own backlog while
other tenants keep admitting against the shared engine backlog only.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

from repro.data.requests import Request
from repro.obs import MetricsRegistry


@dataclasses.dataclass
class SLOModel:
    """Delay budget in engine steps + how request tokens map to steps.

    A decode token costs one slot-step; prefill is amortized (one batched
    pass) so it is discounted by ``prefill_weight``.
    """

    max_delay_steps: float = 64.0
    prefill_weight: float = 0.25

    def request_cost(self, req: Request) -> float:
        return self.prefill_weight * len(req.tokens) + req.decode_len


class AdmissionController:
    def __init__(
        self,
        slo: SLOModel,
        tenant_slos: Optional[Dict[str, SLOModel]] = None,
        pressure_window: int = 64,
    ):
        self.slo = slo
        self.tenant_slos = dict(tenant_slos or {})
        self.offered = 0
        self.admitted = 0
        self.offered_by: Dict[str, int] = {}
        self.admitted_by: Dict[str, int] = {}
        # door books on the unified metrics plane (same ints as the dicts
        # above; the router folds this registry into the fleet merge)
        self.metrics = MetricsRegistry()
        # sliding window of recent admit/shed decisions, exported via
        # ``pressure()`` for observability. Note it only decays as NEW
        # offers arrive — the elastic fleet's scale decisions therefore use
        # interval deltas of offered/shed sampled at decision times
        # (fleet/elastic.py), which read zero once a burst ends.
        self._recent: deque = deque(maxlen=pressure_window)

    def slo_for(self, tenant: str) -> SLOModel:
        return self.tenant_slos.get(tenant, self.slo)

    @property
    def shed(self) -> int:
        return self.offered - self.admitted

    @property
    def shed_rate(self) -> float:
        return self.shed / max(self.offered, 1)

    def tenant_stats(self) -> Dict[str, dict]:
        out = {}
        for t, off in self.offered_by.items():
            adm = self.admitted_by.get(t, 0)
            out[t] = {
                "offered": off,
                "admitted": adm,
                "shed": off - adm,
                "shed_rate": (off - adm) / max(off, 1),
            }
        return out

    def fleet_rate(self, replicas: List) -> int:
        """Ideal service rate in tokens/step: total decode slots."""
        return sum(len(r.engine.slots) for r in replicas)

    @property
    def recent_shed_rate(self) -> float:
        """Shed fraction over the last ``pressure_window`` offers."""
        if not self._recent:
            return 0.0
        return 1.0 - sum(self._recent) / len(self._recent)

    def pressure(self, replicas: List) -> dict:
        """Scaling signal for fleet/elastic.py: how close the fleet is to
        shedding at the door. ``backlog_frac`` is projected queueing delay
        as a fraction of the default SLO budget — >1 means new arrivals are
        already over budget; ``shed_rate`` is the recent-window door rate.
        """
        backlog = self.backlog_steps(replicas)
        return {
            "shed_rate": self.recent_shed_rate,
            "backlog_steps": backlog,
            "backlog_frac": backlog / max(self.slo.max_delay_steps, 1e-9),
        }

    def backlog_steps(self, replicas: List) -> float:
        """Projected steps to drain the fleet's queued work at full rate.

        Queued prompts are discounted by the same ``prefill_weight`` as
        ``request_cost`` so admission and its SLO share one cost model.
        Chunk-aware via ``ServingEngine.backlog_tokens``: under chunked
        prefill a mid-prefill slot owes only its REMAINING chunk tokens,
        so pressure (and the elastic controller reading it) does not
        over-shed during long-prompt admission waves.
        """
        work = sum(r.engine.backlog_tokens(self.slo.prefill_weight) for r in replicas)
        return work / max(self.fleet_rate(replicas), 1)

    def admit(
        self,
        req: Request,
        replicas: List,
        tenant_backlog_tokens: float = 0.0,
        weight_share: float = 1.0,
    ) -> bool:
        """Admit/shed one request against its tenant's SLO.

        ``tenant_backlog_tokens`` is work the tenant has offered but the
        router has not yet dispatched; it drains at the tenant's weighted
        fair share of the fleet rate, not the whole rate.
        """
        tenant = getattr(req, "tenant", "default")
        self.offered += 1
        self.offered_by[tenant] = self.offered_by.get(tenant, 0) + 1
        self.metrics.counter("offered", tenant=tenant).inc()
        rate = self.fleet_rate(replicas)
        if rate <= 0:
            # no replicas / no decode slots: nothing can ever be served, so
            # everything sheds at the door (and no divide-by-zero below)
            self._recent.append(False)
            return False
        slo = self.slo_for(tenant)
        share_rate = rate * min(max(weight_share, 1e-9), 1.0)
        projected = (
            self.backlog_steps(replicas)
            + (tenant_backlog_tokens + slo.request_cost(req)) / share_rate
        )
        if projected > slo.max_delay_steps:
            self._recent.append(False)
            return False
        self.admitted += 1
        self.admitted_by[tenant] = self.admitted_by.get(tenant, 0) + 1
        self.metrics.counter("door_admitted", tenant=tenant).inc()
        self._recent.append(True)
        return True
