"""Deterministic fault injection for the event-driven fleet.

Hyperscale behavior includes the failures: hosts crash mid-burst, hang
without dying, run slow for a while, or lose their near tier and keep
serving from host DRAM. The chaos engine makes those first-class scheduler
events on the fleet's virtual clock — same heap, same ``(time, prio, seq)``
order, FAULT priority so an injected failure at ``t`` strikes before the
completions of ``t`` (the adversarial and deterministic choice). There is
no wall clock and no randomness at injection time; a seeded scenario is a
plain list of ``FaultEvent``s, so the same seed replays the same run
bit-for-bit: identical event order, identical token streams, identical
merged fleet books. ``ChaosEngine.log`` is that anchor in recorded form.

Fault taxonomy (and what each one costs):

* ``crash`` — the host dies instantly. Its host-visible books survive (the
  router salvages them through the last drain boundary); the undrained
  device counter window and all in-flight decode progress are destroyed and
  quantified (``lost_window``, per-tenant ``lost_tokens``); stranded
  requests re-prefill elsewhere. ``duration > 0`` schedules a replacement
  host through the elastic layer.
* ``hang`` — the host stalls: its in-flight step never completes. The
  router's per-dispatch watchdog (``dispatch_timeout``) declares it hung
  and fails it over; a recovery *before* the watchdog fires is a transient
  stall — the host resumes with its slots intact and nothing is lost but
  the stalled step's virtual time.
* ``slowdown`` — the host's step cost is multiplied by ``factor`` for
  ``duration``: a straggler, not a failure. No work is lost; the event
  scheduler charges the slowness to this host alone.
* ``degrade`` — the host's near tier is capacity-zeroed at runtime
  (``ServingEngine.enter_degraded``): it keeps serving far-tier-only until
  the recovery event restores placement. Placement pushes planned before
  the fault are fenced out by epoch.

Correlated multi-host failure is just several events sharing a timestamp —
they land in one scheduler batch, before any completion of that batch.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.fleet.replica import Replica
from repro.fleet.scheduler import FAULT, VirtualScheduler

KINDS = ("crash", "hang", "slowdown", "degrade")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: strike ``rid`` at virtual time ``time``.

    ``duration`` schedules the matching recovery (0 = permanent):
    replacement host for a crash, un-hang for a hang, speed restore for a
    slowdown, ``exit_degraded`` for a degrade. ``factor`` is the slowdown
    multiplier (ignored by other kinds).
    """

    time: float
    kind: str
    rid: int
    duration: float = 0.0
    factor: float = 1.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; one of {KINDS}")


class ChaosEngine:
    """Schedules a fault scenario into every ``FleetRouter.run``.

    Attaching arms the router's failure machinery (watchdog timeout, retry
    budget, backoff) and registers an ``on_run_start`` hook that posts the
    scenario into each run's fresh scheduler exactly once. An empty
    scenario is the control: the armed watchdog posts timeout events that
    every on-time completion cancels, and cancelled events are swept
    without a trace — so a zero-fault chaos run is bit-exact with the
    plain event-driven path.

    ``log`` records ``(vtime, action, rid, applied)`` tuples in execution
    order — the replay-determinism anchor two identical-seed runs must
    match exactly. ``applied=False`` marks a fault that found its target
    already gone (e.g. crashed by an earlier correlated event).
    """

    def __init__(
        self,
        router,
        events: Sequence[FaultEvent],
        dispatch_timeout: Optional[float] = 8.0,
        max_retries: int = 3,
        retry_backoff: float = 1.0,
    ):
        self.router = router
        self.events = sorted(events, key=lambda e: (e.time, e.rid, e.kind))
        self.log: List[Tuple[float, str, int, bool]] = []
        self._installed = False
        router.dispatch_timeout = dispatch_timeout
        router.max_retries = max_retries
        router.retry_backoff = retry_backoff
        router.chaos = self
        router.on_run_start.append(self._install)

    # ------------------------------------------------------------------
    def _install(self, sched: VirtualScheduler):
        """Post the whole scenario into a run's fresh scheduler (once —
        a second ``run`` on the same router replays nothing)."""
        if self._installed:
            return
        self._installed = True
        for ev in self.events:
            sched.post(max(ev.time, sched.now), lambda ev=ev: self._fire(ev), prio=FAULT)

    def _replica(self, rid: int) -> Optional[Replica]:
        for r in self.router.replicas:
            if r.rid == rid:
                return r
        return None

    def _note(self, now: float, action: str, rid: int, applied: bool, **args):
        self.log.append((float(now), action, rid, applied))
        self.router.metrics.counter("faults", kind=action).inc()
        if self.router.recorder is not None:
            self.router.recorder.instant(
                "fault", -1, now, kind=action, replica=rid, applied=applied, **args
            )

    # ------------------------------------------------------------------
    def _fire(self, ev: FaultEvent):
        sched = self.router.scheduler
        now = sched.now
        r = self._replica(ev.rid)
        applied = r is not None and r.alive
        if applied:
            getattr(self, f"_do_{ev.kind}")(r, ev, sched)
        self._note(now, ev.kind, ev.rid, applied, duration=ev.duration)

    def _recovered(self, t0: float, now: float, action: str, rid: int, applied: bool):
        self._note(now, action, rid, applied)
        if applied:
            self.router.metrics.histogram("recovery_vtime").record(now - t0)

    # ---- kind handlers -----------------------------------------------
    def _do_crash(self, r: Replica, ev: FaultEvent, sched: VirtualScheduler):
        t0 = sched.now
        self.router._fail_replica(r, t0, reason="crash", crash=True)
        if ev.duration > 0 and self.router.elastic is not None:

            def replace():
                nr = self.router.elastic.scale_up(
                    sched.now, reason=f"crash-recover rid={ev.rid}"
                )
                self._recovered(t0, sched.now, "crash_recover", nr.rid, True)

            sched.post(t0 + ev.duration, replace, prio=FAULT)

    def _do_hang(self, r: Replica, ev: FaultEvent, sched: VirtualScheduler):
        """Stall the host: the dedup entry stays registered so the in-
        flight step's completion no-ops and the watchdog sees it hung."""
        t0 = sched.now
        r.hung = True
        if ev.duration > 0:

            def recover():
                ok = r.alive and r.hung
                if ok:
                    # before the watchdog fired: drop the stalled step's
                    # dedup entry (its completion must not double-run) and
                    # resume with slots intact. After a failover the entry
                    # is already gone and the engine empty — same clears.
                    ent = self.router._pending.pop(r.rid, None)
                    if ent is not None:
                        sched.cancel(ent[1])
                    r.hung = False
                    r.busy = False
                self._recovered(t0, sched.now, "hang_recover", r.rid, ok)

            sched.post(t0 + ev.duration, recover, prio=FAULT)

    def _do_slowdown(self, r: Replica, ev: FaultEvent, sched: VirtualScheduler):
        t0 = sched.now
        old = r.speed
        r.speed = old * ev.factor
        if ev.duration > 0:

            def restore():
                ok = r.alive
                if ok:
                    r.speed = old
                self._recovered(t0, sched.now, "slowdown_recover", r.rid, ok)

            sched.post(t0 + ev.duration, restore, prio=FAULT)

    def _do_degrade(self, r: Replica, ev: FaultEvent, sched: VirtualScheduler):
        t0 = sched.now
        tierer = self.router.autotierer
        fence = tierer.epoch_seq if tierer is not None else None
        r.engine.enter_degraded(fence_epoch=fence)
        if ev.duration > 0:

            def restore():
                ok = r.alive
                if ok:
                    tierer = self.router.autotierer
                    r.engine.exit_degraded(
                        fence_epoch=tierer.epoch_seq if tierer is not None else None
                    )
                self._recovered(t0, sched.now, "degrade_recover", r.rid, ok)

            sched.post(t0 + ev.duration, restore, prio=FAULT)

    # ------------------------------------------------------------------
    @classmethod
    def seeded(
        cls,
        router,
        seed: int,
        n_faults: int = 3,
        horizon: float = 64.0,
        kinds: Sequence[str] = KINDS,
        mean_duration: float = 8.0,
        **kwargs,
    ) -> "ChaosEngine":
        """Deterministic random scenario: same seed, same fleet — same
        ``FaultEvent`` list, hence the same run, bit for bit."""
        rng = np.random.default_rng(seed)
        rids = [r.rid for r in router.replicas]
        events = []
        for _ in range(n_faults):
            kind = kinds[int(rng.integers(len(kinds)))]
            events.append(
                FaultEvent(
                    time=float(rng.uniform(1.0, max(horizon, 2.0))),
                    kind=kind,
                    rid=rids[int(rng.integers(len(rids)))],
                    duration=float(rng.uniform(0.5, 2.0)) * mean_duration,
                    factor=float(rng.uniform(2.0, 6.0)) if kind == "slowdown" else 1.0,
                )
            )
        return cls(router, events, **kwargs)
