"""Paged decode-attention Pallas TPU kernel.

The physical KV pool lives in HBM as (Hkv, n_pages, page_size, d); the
logical sequence -> physical page mapping (the KV "page table" — the
framework's I-TLB analogue, see core/pagetable.py) is SCALAR-PREFETCHED so
the K/V BlockSpec index maps are data-dependent: grid step (b, h, p) pulls
physical page page_table[b, p] into VMEM. This is the TPU-native form of the
paper's insight that translation (page table) and data (pages) are separate
streams: translations ride the scalar core; pages ride the DMA engine.

Grid: (B, Hkv, pages_per_seq) with the page axis innermost — online-softmax
state (m, l, acc) is carried in VMEM scratch across a sequence's pages.
VMEM per step: one K page + one V page (ps x d) + q (G x d) + acc — with
ps=128, d=128, bf16 that's ~130 KiB: tiny; many sequences' streams can be
double-buffered by the pipeline.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._interpret import resolve_interpret

NEG_INF = -1e30
LANES = 128


def _kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, page_size, scale):
    b = pl.program_id(0)
    p = pl.program_id(2)
    npages = pl.num_programs(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]

    @pl.when(p * page_size < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (G, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (ps, d)
        v = v_ref[0, 0].astype(jnp.float32)
        g = q.shape[0]
        ps = k.shape[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (G, ps)
        kpos = p * page_size + jax.lax.broadcasted_iota(jnp.int32, (g, ps), 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        pexp = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = jnp.broadcast_to((l_ref[:, 0] * corr + pexp.sum(axis=1))[:, None], l_ref.shape)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            pexp.astype(v.dtype), v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)

    @pl.when(p == npages - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[:, 0], 1e-30)[:, None]).astype(o_ref.dtype)


def paged_attention_kernel(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    page_table: jax.Array,
    lengths: jax.Array,
    *,
    interpret=None,
) -> jax.Array:
    """q: (B, Hkv, G, d); pages: (Hkv, P, ps, d); page_table: (B, pp) int32;
    lengths: (B,) int32. Returns (B, Hkv, G, d)."""
    b, hkv, g, d = q.shape
    _, nphys, ps, _ = k_pages.shape
    pp = page_table.shape[1]
    grid = (b, hkv, pp)
    flat_pt = page_table.reshape(-1)

    def q_map(bb, h, p, pt, lens):
        return (bb, h, 0, 0)

    def kv_map(bb, h, p, pt, lens):
        return (h, pt[bb * pp + p], 0, 0)

    kernel = functools.partial(_kernel, page_size=ps, scale=1.0 / math.sqrt(d))
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, d), q_map),
                pl.BlockSpec((1, 1, ps, d), kv_map),
                pl.BlockSpec((1, 1, ps, d), kv_map),
            ],
            out_specs=pl.BlockSpec((1, 1, g, d), q_map),
            scratch_shapes=[
                pltpu.VMEM((g, LANES), jnp.float32),
                pltpu.VMEM((g, LANES), jnp.float32),
                pltpu.VMEM((g, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=resolve_interpret(interpret),
    )(flat_pt, lengths, q, k_pages, v_pages)
