"""One serving host in the fleet: a ServingEngine plus its export surface.

The paper profiles the *same code running on many hosts*; the fleet layer's
unit of aggregation is therefore one engine with (a) live ground-truth
counters (a CacheSim fed every block access, the "production counters" of
Table 6) and (b) the windowed MemTracer / AccessProfiler state the
aggregator stitches into one representative fleet view (§6.2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.memtrace import CacheSim, TraceWindow
from repro.core.prefetch import train_successors
from repro.data.requests import Request
from repro.obs import MetricSnapshot
from repro.runtime.serving import EngineConfig, ServingEngine


@dataclasses.dataclass
class ReplicaProfile:
    """Per-host MemProf export consumed by fleet/aggregator.py."""

    rid: int
    counts: np.ndarray  # (n_pages,) total kv accesses per logical page
    windows: List[TraceWindow]  # raw attach/detach trace windows
    reads: int
    writes: int
    live_hit_ratio: float  # live LRU hit ratio (ground truth, not sampled)
    live_accesses: int
    live_capacity: int  # blocks in the live cache (sizes the validation sim)
    near_hit_rate: float
    # per-tenant views of the same host: access counts over the logical
    # page space and realized near-tier hit rate (interference surface)
    tenant_counts: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    tenant_near_hit: Dict[str, float] = dataclasses.field(default_factory=dict)
    # virtual time one engine step costs on this host (speed x engine cost):
    # lets the aggregator order trace windows by when they actually happened
    # on a heterogeneous fleet, not by per-host step indices. Snapshot at
    # export — window ordering assumes the cost was constant over the
    # traced interval (true for per-host speed factors; a step_cost_fn that
    # varies mid-run would misplace earlier windows)
    step_cost: float = 1.0
    # fleet virtual time this host joined (0 for founding replicas): an
    # elastically added host's engine step counter starts at 0, so its
    # windows happened at clock_offset + start_step * step_cost
    clock_offset: float = 0.0
    # device-executed tiering (runtime/tiered_kv): when the host runs the
    # fused tiered-gather decode path this carries the store's counters
    # (near/far hits counted on device and DRAINED at export — the export
    # boundary is a drain boundary, so fleet epochs never read a stale
    # plane — plus the dispatch/host-sync budget and bytes actually moved
    # by placement pushes); None for hosts on the host-accounted path
    device_tiering: Optional[dict] = None
    # frozen metrics-registry state at export (replica label applied): what
    # a retired host contributes to the fleet metrics merge after its live
    # registry is gone
    metrics: Optional[MetricSnapshot] = None
    # successor table trained from THIS host's stream-tagged trace windows
    # ({block: (succ, ...)}): the per-host export surface of the trace-
    # driven prefetcher. The AutoTierer pools the raw windows of every
    # profile and retrains fleet-wide instead of merging these — but a
    # retired host's table (via extra_profiles) is still inspectable.
    successors: Dict[int, tuple] = dataclasses.field(default_factory=dict)
    # stream id (engine seq id) -> tenant name for every request this host
    # admitted: trace-window streams are seq ids, and this map is what lets
    # the fleet aggregator partition successor training per tenant (one
    # tenant's template chains never enter another tenant's table)
    stream_tenants: Dict[int, str] = dataclasses.field(default_factory=dict)

    @property
    def n_pages(self) -> int:
        """Size of this host's physical page-id space."""
        return int(self.counts.size)


class Replica:
    """A ServingEngine with fleet hooks attached.

    ``live_cache_blocks`` sizes the per-host live cache simulator used as
    ground truth when validating the stitched fleet trace — it plays the
    role of the paper's hardware hit-ratio counters.

    ``speed`` is this host's step-cost multiplier in virtual time (1.0 =
    nominal, 4.0 = a 4x straggler). ``clock``/``busy`` are owned by the
    event-driven fleet run; ``draining`` excludes the host from dispatch
    while it finishes its backlog (elastic scale-down).
    """

    def __init__(
        self,
        rid: int,
        engine: ServingEngine,
        live_cache_blocks: int = 128,
        speed: float = 1.0,
    ):
        self.rid = rid
        self.engine = engine
        self.live_cache_blocks = live_cache_blocks
        self.live_sim = CacheSim(live_cache_blocks)
        self.speed = float(speed)
        self.clock = 0.0  # virtual time of this host's last completion
        self.created_at = 0.0  # fleet vtime this host joined (elastic)
        self.busy = False  # a step is in flight on the event scheduler
        self.draining = False
        # fault state (fleet/faults.py): a dead host is removed from the
        # fleet after crash salvage; a hung host stays listed but is
        # quarantined from dispatch until its fault's recovery event clears
        # the flag (its engine was purged at failover — it rejoins empty)
        self.alive = True
        self.hung = False
        self.steps_done = 0
        engine.access_hooks.append(self._on_access)
        # flight-recorder identity: span tracks and metric series from this
        # host carry its rid (const label, applied at snapshot time so the
        # engine's pre-existing instruments are covered too)
        engine.host_rid = rid
        engine.metrics.const_labels.setdefault("replica", str(rid))

    def _on_access(self, pages: np.ndarray, is_write: bool):
        for p in np.asarray(pages).reshape(-1):
            self.live_sim.access(int(p))

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.engine.submit(req)

    def step(self) -> int:
        self.steps_done += 1
        return self.engine.step()

    @property
    def step_cost(self) -> float:
        """Virtual-time cost of this host's next step (straggler = bigger)."""
        return self.speed * self.engine.step_cost()

    @property
    def load(self) -> int:
        return self.engine.load

    @property
    def queue_depth(self) -> int:
        return len(self.engine.queue)

    @property
    def idle(self) -> bool:
        return self.engine.load == 0

    # ------------------------------------------------------------------
    # drain protocol (elastic scale-down): stop receiving, finish backlog

    def start_drain(self):
        self.draining = True

    @property
    def drained(self) -> bool:
        return self.draining and self.idle and not self.busy

    def apply_placement(self, near_ids: np.ndarray, epoch: Optional[int] = None) -> int:
        self.engine.external_placement = True
        return self.engine.apply_placement(near_ids, epoch=epoch)

    # ------------------------------------------------------------------
    # crash protocol (fleet/faults.py): inventory what died, salvage books

    def crash_salvage(self, now: float) -> dict:
        """Inventory a crashed host before retirement.

        The host-visible books — everything the last drain boundary folded
        in, every token already streamed — survive a crash by construction.
        What dies is (a) the device counter plane accumulated since that
        boundary, quarantined here via the discard drain and reported as
        the ``lost_window``, and (b) the in-flight decode progress of
        resident requests, reported as ``lost_decode_tokens`` (the work
        their failover re-dispatch must redo). After this call every
        subsequent drain on the engine sees a clean plane and charges
        nothing — the idempotent-drain guarantee is what makes the
        follow-up ``export_profile``/``stats`` reads crash-safe.
        """
        stranded = self.engine.stranded_requests()
        lost = self.engine.lost_window()
        lost.update(
            rid=self.rid,
            vtime=float(now),
            inflight=len(stranded),
            lost_decode_tokens=int(sum(d for _, d in stranded)),
        )
        return lost

    # ------------------------------------------------------------------
    def export_profile(self) -> ReplicaProfile:
        eng = self.engine
        eng.tracer.stitch()  # flush any open window into tracer.windows
        # drain the device counter plane first: fleet epochs and stitched
        # traces read drained books, never per-step ints (live_counters
        # drains too, but the explicit call keeps tenant_stats — read
        # below — at the same boundary)
        eng.drain_tier_counters()
        live = eng.live_counters()
        sim = self.live_sim
        tenants = {
            name[len("kv."):]: eng.profiler.counts(name).copy()
            for name in eng.profiler.streams("kv.")
        }
        tenant_near = {
            t: ts["near_hits"].value
            / max(ts["near_hits"].value + ts["far_hits"].value, 1)
            for t, ts in eng.tenant_stats.items()
        }
        return ReplicaProfile(
            rid=self.rid,
            counts=eng.profiler.counts("kv").copy(),
            windows=list(eng.tracer.windows),
            reads=live["reads"],
            writes=live["writes"],
            live_hit_ratio=sim.hits / max(sim.hits + sim.misses, 1),
            live_accesses=sim.hits + sim.misses,
            live_capacity=self.live_cache_blocks,
            near_hit_rate=live["near_hit_rate"],
            tenant_counts=tenants,
            tenant_near_hit=tenant_near,
            step_cost=self.step_cost,
            clock_offset=self.created_at,
            device_tiering=None if eng.tiered is None else eng.tiered.stats(),
            metrics=eng.metrics.snapshot(),
            successors=train_successors(eng.tracer.windows[-64:]),
            stream_tenants=dict(eng._seq_tenant),
        )

    def load_successors(self, table: dict):
        """Install a fleet-trained successor table into this host's
        prefetcher (wholesale: the fleet table saw strictly more data)."""
        self.engine.prefetch.load_successors(table)

    @property
    def device_moved_bytes(self) -> int:
        """Bytes the device tier store has actually migrated on this host."""
        return 0 if self.engine.tiered is None else self.engine.tiered.moved_bytes

    def stats(self) -> dict:
        return {
            **self.engine.stats(),
            "rid": self.rid,
            "speed": self.speed,
            "steps_done": self.steps_done,
            "draining": self.draining,
        }
