"""RWKV6 "Finch" (attention-free, data-dependent decay). arXiv:2404.05892.

Time-mix: token-shift with LoRA-modulated per-channel interpolation, then the
WKV6 recurrence per 64-wide head:

    S_t = diag(w_t) S_{t-1} + k_t v_t^T        (data-dependent decay w_t)
    y_t = r_t (S_{t-1} + diag(u) k_t v_t^T)

Channel-mix: token-shift + squared-ReLU FFN with receptance gate.

The jnp path scans over time (this file); kernels/rwkv6_scan holds the
chunked Pallas TPU kernel with this as its oracle. Decode state is O(1):
per layer (wkv state, att shift, cm shift) — which is exactly why this arch
runs the long_500k cell and why the paper's KV tiering is inapplicable to it
(DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.mesh import BATCH, MODEL, shard
from repro.models import common

Array = jax.Array

MIX_RANK = 32
DECAY_RANK = 64


def _n_heads(cfg: ModelConfig) -> int:
    return cfg.d_model // cfg.ssm_head_dim


# ---------------------------------------------------------------------------
# init


def _init_layer(key, cfg: ModelConfig, dtype) -> dict:
    d, f, hd = cfg.d_model, cfg.d_ff, cfg.ssm_head_dim
    h = _n_heads(cfg)
    ks = jax.random.split(key, 12)
    u = jnp.zeros((h, hd), jnp.float32) + 0.5
    return {
        "ln1": {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)},
        "ln2": {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)},
        "att": {
            "maa_x": jnp.zeros((d,), jnp.float32),
            "maa": jnp.zeros((5, d), jnp.float32),  # w,k,v,r,g
            "maa_w1": common.dense_init(ks[0], (d, 5 * MIX_RANK), dtype=jnp.float32, scale=0.1),
            "maa_w2": common.dense_init(ks[1], (5, MIX_RANK, d), in_axis=1, dtype=jnp.float32, scale=0.1),
            "w0": jnp.full((d,), -6.0, jnp.float32),  # decay bias: slow decay default
            "w1": common.dense_init(ks[2], (d, DECAY_RANK), dtype=jnp.float32, scale=0.1),
            "w2": common.dense_init(ks[3], (DECAY_RANK, d), dtype=jnp.float32, scale=0.1),
            "u": u,  # "time_faaaa" bonus
            "wr": common.dense_init(ks[4], (d, d), dtype=dtype),
            "wk": common.dense_init(ks[5], (d, d), dtype=dtype),
            "wv": common.dense_init(ks[6], (d, d), dtype=dtype),
            "wg": common.dense_init(ks[7], (d, d), dtype=dtype),
            "wo": common.dense_init(ks[8], (d, d), scale=1.0 / (2 * cfg.n_layers) ** 0.5, dtype=dtype),
            "ln_x": {"w": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)},
        },
        "ffn": {
            "maa_k": jnp.zeros((d,), jnp.float32),
            "maa_r": jnp.zeros((d,), jnp.float32),
            "wk": common.dense_init(ks[9], (d, f), dtype=dtype),
            "wv": common.dense_init(ks[10], (f, d), scale=1.0 / (2 * cfg.n_layers) ** 0.5, dtype=dtype),
            "wr": common.dense_init(ks[11], (d, d), dtype=dtype),
        },
    }


def init(key, cfg: ModelConfig) -> dict:
    dtype = common.dt(cfg.param_dtype)
    ke, kl, kh = jax.random.split(key, 3)
    layers = jax.vmap(lambda k: _init_layer(k, cfg, dtype))(jax.random.split(kl, cfg.n_layers))
    return {
        "embed": common.embed_init(ke, (cfg.padded_vocab, cfg.d_model), dtype),
        "ln0": {"w": jnp.ones((cfg.d_model,), dtype), "b": jnp.zeros((cfg.d_model,), dtype)},
        "layers": layers,
        "final_norm": {"w": jnp.ones((cfg.d_model,), dtype), "b": jnp.zeros((cfg.d_model,), dtype)},
        "lm_head": common.dense_init(kh, (cfg.d_model, cfg.padded_vocab), dtype=dtype),
    }


def layer_specs(cfg: ModelConfig) -> dict:
    rep1 = (None,)
    return {
        "ln1": {"w": rep1, "b": rep1},
        "ln2": {"w": rep1, "b": rep1},
        "att": {
            "maa_x": rep1,
            "maa": (None, None),
            "maa_w1": (None, None),
            "maa_w2": (None, None, None),
            "w0": rep1,
            "w1": (None, None),
            "w2": (None, None),
            "u": (MODEL, None),
            "wr": (None, MODEL),
            "wk": (None, MODEL),
            "wv": (None, MODEL),
            "wg": (None, MODEL),
            "wo": (MODEL, None),
            "ln_x": {"w": rep1, "b": rep1},
        },
        "ffn": {
            "maa_k": rep1,
            "maa_r": rep1,
            "wk": (None, MODEL),
            "wv": (MODEL, None),
            "wr": (None, None),
        },
    }


def param_specs(cfg: ModelConfig) -> dict:
    rep1 = (None,)
    lyr = jax.tree.map(
        lambda s: (None,) + tuple(s), layer_specs(cfg), is_leaf=lambda s: isinstance(s, tuple)
    )
    return {
        "embed": (MODEL, None),
        "ln0": {"w": rep1, "b": rep1},
        "layers": lyr,
        "final_norm": {"w": rep1, "b": rep1},
        "lm_head": (None, MODEL),
    }


# ---------------------------------------------------------------------------
# wkv6 recurrence (jnp oracle for kernels/rwkv6_scan)


def _wkv6_seq(state, r, k, v, w, u):
    """Per-token WKV6 over (B, T, H, hd) inputs from ``state``."""

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp  # (B,H,hd)
        kv = k_t[..., :, None] * v_t[..., None, :]  # (B,H,hd,hd)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None, :, :, None] * kv)
        s = w_t[..., :, None] * s + kv
        return s, y

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (r, k, v, w))
    state, ys = jax.lax.scan(step, state, xs)
    return state, ys.transpose(1, 0, 2, 3)


def wkv6(
    r: Array, k: Array, v: Array, w: Array, u: Array,
    state: Optional[Array] = None, chunk: int = 128,
):
    """WKV6 as a chunked scan. r/k/v/w: (B, T, H, hd) f32, w in (0,1); u: (H, hd).

    Returns (y (B,T,H,hd), final_state (B,H,hd,hd)). State axes: [k-dim, v-dim].

    Training memory note: differentiating a plain per-token scan saves the
    (B,H,hd,hd) state at EVERY step (T x 8 MB per layer at 4k — tens of GB).
    Chunking + checkpointing the chunk body keeps only per-chunk states and
    recomputes inside a chunk on the backward pass, mirroring the Pallas
    kernel's chunked dataflow (kernels/rwkv6_scan).
    """
    b, t, h, hd = r.shape
    if state is None:
        state = jnp.zeros((b, h, hd, hd), jnp.float32)
    if t <= chunk or t % chunk != 0:
        state, ys = _wkv6_seq(state, r, k, v, w, u)
        return ys, state

    nc = t // chunk

    def chunk_body(s, xs):
        rc, kc, vc, wc = xs  # (B, C, H, hd)
        s, yc = _wkv6_seq(s, rc, kc, vc, wc, u)
        return s, yc

    chunk_body = jax.checkpoint(chunk_body, prevent_cse=False)
    xs = tuple(
        a.reshape(b, nc, chunk, h, hd).transpose(1, 0, 2, 3, 4) for a in (r, k, v, w)
    )
    state, ys = jax.lax.scan(chunk_body, state, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, t, h, hd)
    return y, state


def _token_shift(x: Array, prev: Array) -> Array:
    """x: (B,T,D); prev: (B,D) last token of previous segment -> shifted x."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _time_mix(att: dict, cfg: ModelConfig, x: Array, shift_prev: Array, wkv_state):
    """Returns (out (B,T,D), new_shift (B,D), new_wkv_state)."""
    b, t, d = x.shape
    hd = cfg.ssm_head_dim
    h = d // hd
    xf = x.astype(jnp.float32)
    sx = _token_shift(xf, shift_prev) - xf  # (B,T,D)
    xxx = xf + sx * att["maa_x"]
    mix = jnp.tanh(xxx @ att["maa_w1"]).reshape(b, t, 5, MIX_RANK)  # (B,T,5,R)
    mix = jnp.einsum("btfr,frd->fbtd", mix, att["maa_w2"])  # (5,B,T,D)
    xw, xk, xv, xr, xg = [xf + sx * (att["maa"][i] + mix[i]) for i in range(5)]

    dtype = x.dtype
    r = (xr.astype(dtype) @ att["wr"]).astype(jnp.float32).reshape(b, t, h, hd)
    k = (xk.astype(dtype) @ att["wk"]).astype(jnp.float32).reshape(b, t, h, hd)
    v = (xv.astype(dtype) @ att["wv"]).astype(jnp.float32).reshape(b, t, h, hd)
    g = jax.nn.silu((xg.astype(dtype) @ att["wg"]).astype(jnp.float32))
    w = jnp.exp(-jnp.exp(att["w0"] + xw @ att["w1"] @ att["w2"]))  # (B,T,D) in (0,1)
    w = w.reshape(b, t, h, hd)
    r = shard(r, BATCH, None, MODEL, None)
    k = shard(k, BATCH, None, MODEL, None)
    v = shard(v, BATCH, None, MODEL, None)

    y, wkv_state = wkv6(r, k, v, w, att["u"], wkv_state)  # (B,T,H,hd)
    # per-head groupnorm, then gate and output proj
    yf = y.reshape(b, t, h, hd)
    mu = yf.mean(-1, keepdims=True)
    var = yf.var(-1, keepdims=True)
    yn = ((yf - mu) * jax.lax.rsqrt(var + 64e-5)).reshape(b, t, d)
    yn = yn * att["ln_x"]["w"] + att["ln_x"]["b"]
    out = ((yn * g).astype(dtype) @ att["wo"]).astype(dtype)
    return out, xf[:, -1, :], wkv_state


def _channel_mix(ffn: dict, cfg: ModelConfig, x: Array, shift_prev: Array):
    xf = x.astype(jnp.float32)
    sx = _token_shift(xf, shift_prev) - xf
    xk = (xf + sx * ffn["maa_k"]).astype(x.dtype)
    xr = (xf + sx * ffn["maa_r"]).astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ ffn["wk"]))
    kv = (k @ ffn["wv"].astype(k.dtype)).astype(x.dtype)
    gate = jax.nn.sigmoid((xr @ ffn["wr"]).astype(jnp.float32)).astype(x.dtype)
    return gate * kv, xf[:, -1, :]


def _block(layer, cfg: ModelConfig, h, att_shift, cm_shift, wkv_state):
    # cast + re-pin TP layout per scanned slice: without the constraint GSPMD
    # loses the spec through the scan transpose and replicates d(weights)
    layer = common.constrain_tree(layer, layer_specs(cfg), common.dt(cfg.compute_dtype))
    x = common.layer_norm(h, layer["ln1"]["w"], layer["ln1"]["b"], cfg.norm_eps)
    a, att_shift, wkv_state = _time_mix(layer["att"], cfg, x, att_shift, wkv_state)
    h = h + a
    x = common.layer_norm(h, layer["ln2"]["w"], layer["ln2"]["b"], cfg.norm_eps)
    m, cm_shift = _channel_mix(layer["ffn"], cfg, x, cm_shift)
    h = shard(h + m, BATCH, None, None)
    return h, att_shift, cm_shift, wkv_state


def _embed(params, cfg, tokens):
    h = jnp.take(params["embed"], tokens, axis=0).astype(common.dt(cfg.compute_dtype))
    h = common.layer_norm(h, params["ln0"]["w"], params["ln0"]["b"], cfg.norm_eps)
    return shard(h, BATCH, None, None)


def _logits(params, cfg, h):
    h = common.layer_norm(h, params["final_norm"]["w"], params["final_norm"]["b"], cfg.norm_eps)
    return shard(
        jnp.einsum("bsd,dv->bsv", h, params["lm_head"].astype(h.dtype), preferred_element_type=jnp.float32),
        BATCH, None, MODEL,
    )


# ---------------------------------------------------------------------------
# public API


def forward(params, cfg: ModelConfig, tokens=None, embeds=None, positions=None, *, remat=None, **_):
    h = _embed(params, cfg, tokens) if embeds is None else embeds.astype(common.dt(cfg.compute_dtype))
    b, t, d = h.shape
    hd = cfg.ssm_head_dim

    def block(h, layer):
        z = jnp.zeros((b, d), jnp.float32)
        s0 = jnp.zeros((b, d // hd, hd, hd), jnp.float32)
        h, *_ = _block(layer, cfg, h, z, z, s0)
        return h

    use_remat = cfg.remat if remat is None else remat
    blk = common.maybe_remat(block, use_remat, cfg.remat_policy)
    h, _ = jax.lax.scan(lambda c, lp: (blk(c, lp), None), h, params["layers"])
    return _logits(params, cfg, h)


def features(params, cfg: ModelConfig, tokens=None, embeds=None, positions=None, *, remat=None, **_):
    """Trunk -> (post-norm h, lm_head weight) for the fused CE path."""
    h = _embed(params, cfg, tokens) if embeds is None else embeds.astype(common.dt(cfg.compute_dtype))
    b, t, d = h.shape
    hd = cfg.ssm_head_dim

    def block(h, layer):
        z = jnp.zeros((b, d), jnp.float32)
        s0 = jnp.zeros((b, d // hd, hd, hd), jnp.float32)
        h, *_ = _block(layer, cfg, h, z, z, s0)
        return h

    use_remat = cfg.remat if remat is None else remat
    blk = common.maybe_remat(block, use_remat, cfg.remat_policy)
    h, _ = jax.lax.scan(lambda c, lp: (blk(c, lp), None), h, params["layers"])
    h = common.layer_norm(h, params["final_norm"]["w"], params["final_norm"]["b"], cfg.norm_eps)
    return h, shard(params["lm_head"], None, MODEL)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    d, hd = cfg.d_model, cfg.ssm_head_dim
    h = d // hd
    del max_len  # O(1) state — the whole point
    return {
        "wkv": jnp.zeros((cfg.n_layers, batch, h, hd, hd), jnp.float32),
        "att_shift": jnp.zeros((cfg.n_layers, batch, d), jnp.float32),
        "cm_shift": jnp.zeros((cfg.n_layers, batch, d), jnp.float32),
        "lengths": jnp.zeros((batch,), jnp.int32),
    }


def cache_specs(cfg: ModelConfig, model_axis: int = 16) -> dict:
    return {
        "wkv": (None, BATCH, MODEL, None, None),
        "att_shift": (None, BATCH, None),
        "cm_shift": (None, BATCH, None),
        "lengths": (BATCH,),
    }


def prefill(params, cfg: ModelConfig, tokens=None, embeds=None, *, max_len: int = 0, **_):
    """Forward that also returns the recurrent state as the 'cache'."""
    h = _embed(params, cfg, tokens) if embeds is None else embeds.astype(common.dt(cfg.compute_dtype))
    b, t, d = h.shape
    hd = cfg.ssm_head_dim

    def block(h, layer):
        z = jnp.zeros((b, d), jnp.float32)
        s0 = jnp.zeros((b, d // hd, hd, hd), jnp.float32)
        h, a_s, c_s, s = _block(layer, cfg, h, z, z, s0)
        return h, (a_s, c_s, s)

    h, (a_s, c_s, s) = jax.lax.scan(block, h, params["layers"])
    cache = {
        "wkv": s,
        "att_shift": a_s,
        "cm_shift": c_s,
        "lengths": jnp.full((b,), t, jnp.int32),
    }
    return _logits(params, cfg, h), cache


def decode_step(params, cfg: ModelConfig, cache: dict, tokens: Array):
    h = _embed(params, cfg, tokens)  # (B,1,D)
    b = h.shape[0]

    def step(h, xs):
        layer, a_s, c_s, s = xs
        h, a_s, c_s, s = _block(layer, cfg, h, a_s, c_s, s)
        return h, (a_s, c_s, s)

    h, (a_s, c_s, s) = jax.lax.scan(
        step, h, (params["layers"], cache["att_shift"], cache["cm_shift"], cache["wkv"])
    )
    logits = _logits(params, cfg, h)
    return logits, {
        "wkv": s,
        "att_shift": a_s,
        "cm_shift": c_s,
        "lengths": cache["lengths"] + 1,
    }
