"""Quickstart: build an assigned arch, train a few steps, then serve it.

PYTHONPATH=src python examples/quickstart.py [--arch qwen2.5-3b]
Runs the REDUCED (CPU-sized) config of the chosen architecture end to end:
one jitted train step, a short loss curve, then prefill + greedy decode.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.models.api import get_model, make_serve_step, make_train_step
from repro.optim import AdamWConfig, adamw_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    api = get_model(cfg)
    print(f"arch={args.arch} family={cfg.family} reduced params...")
    params = api.init(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"  {n/1e6:.2f}M params, vocab {cfg.vocab_size}, d_model {cfg.d_model}")

    # --- train a few steps on a synthetic batch
    opt_state = adamw_init(params)
    step = jax.jit(make_train_step(api, AdamWConfig(lr=1e-3)))
    key = jax.random.PRNGKey(1)
    B, S = 4, 32
    if cfg.family == "vlm":
        batch = {
            "embeds": jax.random.normal(key, (B, S, cfg.d_model)),
            "mrope_positions": jnp.tile(jnp.arange(S)[None, None], (3, B, 1)).astype(jnp.int32),
            "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        }
    elif cfg.family == "audio":
        batch = {
            "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
            "frames": jax.random.normal(key, (B, cfg.n_audio_frames, cfg.d_model)),
            "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        }
    else:
        toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    for i in range(args.steps):
        t0 = time.time()
        params, opt_state, metrics = step(params, opt_state, batch)
        if i % 2 == 0:
            print(f"  step {i}: loss {float(metrics['loss']):.4f} ({time.time()-t0:.2f}s)")

    # --- serve: prefill a prompt, decode greedily
    if cfg.family in ("vlm", "audio"):
        print("serving demo uses token prompts; done for modality stubs.")
        return
    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 0, cfg.vocab_size)
    logits, cache = api.prefill(params, {"tokens": prompt}, max_len=24)
    serve = jax.jit(make_serve_step(api))
    out = [int(t) for t in prompt[0]]
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    for _ in range(8):
        out.append(int(tok[0, 0]))
        tok, cache = serve(params, cache, tok)
    print(f"  prompt+decode ids: {out}")
    print("quickstart ok")


if __name__ == "__main__":
    main()
