"""Pure-jnp oracle for the flash attention kernel (GQA, causal optional)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q: (B, Hq, Lq, D); k/v: (B, Hkv, Lk, D); Hq % Hkv == 0.

    Plain softmax attention in f32 — the semantic ground truth.
    """
    b, hq, lq, d = q.shape
    hkv, lk = k.shape[1], k.shape[2]
    g = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, g, lq, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) / math.sqrt(d)
    if causal:
        mask = jnp.arange(lk)[None, :] <= jnp.arange(lq)[:, None] + (lk - lq)
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return o.reshape(b, hq, lq, d).astype(q.dtype)
