"""Dispatch/sync budget of the segmented tiered decode step.

The contracts this file pins:

1. ONE tiered-gather dispatch per engine step, regardless of how many
   decode slots are active (counted by monkeypatching the kernel ops the
   device store calls — the regression that motivated the segmented path
   was one dispatch per slot per step).
2. Drain-cadence equivalence: the books (placement tier hits + per-tenant
   near/far) are bit-identical whether the device counter plane is drained
   after every step or once per profiler window — draining is a pure sum,
   never a semantic boundary.
3. Admission is FIFO over a deque: O(1) head pops, arrival order preserved
   across steps and slot turnover.
4. The counter-based synthetic payload rows (recurrent-family fallback)
   are deterministic, keyed on (seed, page, write-version), and produced
   by one vectorized draw.
"""
import dataclasses
from collections import deque

import jax
import numpy as np
import pytest

import repro.runtime.tiered_kv as tiered_kv_mod
from repro.configs import get_config
from repro.configs.workloads import get_profile
from repro.data.requests import RequestGenerator
from repro.models.api import get_model
from repro.runtime.serving import EngineConfig, ServingEngine, counter_rows
from repro.runtime.tiered_kv import TieredKVCache


def _mk_engine(device, **ekw):
    cfg = get_config("smollm-360m").reduced()
    api = get_model(cfg)
    if not hasattr(_mk_engine, "_params"):
        _mk_engine._params = api.init(jax.random.PRNGKey(0))
    kw = dict(
        max_batch=4, max_len=64, n_pages=256, near_frac=0.02, placement_window=4,
        device_tiering=device, tiered_identity_scales=device,
    )
    kw.update(ekw)
    return cfg, ServingEngine(api, _mk_engine._params, EngineConfig(**kw), seed=0)


def _gen(cfg, seed=0, **pkw):
    prof = dataclasses.replace(
        get_profile("Web1"), prompt_mean=24, decode_mean=8,
        prefix_share=0.5, n_prefixes=2, **pkw,
    )
    return RequestGenerator(prof, vocab_size=cfg.vocab_size, seed=seed)


# ---------------------------------------------------------------------------
# 1. dispatch count


def test_one_tiered_dispatch_per_step(monkeypatch):
    calls = []
    orig_seg = tiered_kv_mod.tiered_lookup_segments
    orig_cnt = tiered_kv_mod.tiered_lookup_counted

    def seg(*a, **k):
        calls.append("seg")
        return orig_seg(*a, **k)

    def cnt(*a, **k):
        calls.append("cnt")
        return orig_cnt(*a, **k)

    monkeypatch.setattr(tiered_kv_mod, "tiered_lookup_segments", seg)
    monkeypatch.setattr(tiered_kv_mod, "tiered_lookup_counted", cnt)
    cfg, eng = _mk_engine(True)
    gen = _gen(cfg)
    for _ in range(6):
        eng.submit(next(gen))
    steps_with_multi = 0
    while (eng.queue or any(s.active for s in eng.slots)) and eng.engine_steps < 200:
        active_before = sum(1 for s in eng.slots if s.active) or len(eng.queue)
        before = len(calls)
        eng.step()
        # exactly ONE lookup dispatch per step, however many slots decoded
        assert len(calls) - before == 1, (len(calls) - before, active_before)
        if sum(1 for s in eng.slots if s.active) > 1:
            steps_with_multi += 1
    assert steps_with_multi > 0, "workload never filled >1 slot"
    assert all(c == "seg" for c in calls), "segmented engine fell back to per-call lookups"
    # the store's own budget books agree with the monkeypatch count
    assert eng.tiered.dispatches == len(calls)
    assert eng.tiered.dispatches == eng.engine_steps


def test_per_slot_baseline_dispatches_scale_with_slots():
    cfg, eng = _mk_engine(True, segmented_lookup=False)
    gen = _gen(cfg)
    stats = eng.run(gen, n_requests=6, max_steps=200)
    dev = stats["device_tiering"]
    # the retired path pays >1 dispatch and >=1 sync per step — the budget
    # gap decode_dispatch_bench measures
    assert dev["dispatches_per_step"] > 1.0
    assert dev["host_syncs_per_step"] >= 1.0


# ---------------------------------------------------------------------------
# 2. drain-cadence equivalence


def test_counter_drain_cadence_equivalence():
    cfg, windowed = _mk_engine(True)
    gen = _gen(cfg, seed=5)
    for _ in range(6):
        windowed.submit(next(gen))
    cfg, every_step = _mk_engine(True)
    gen = _gen(cfg, seed=5)
    for _ in range(6):
        every_step.submit(next(gen))
    while (windowed.queue or any(s.active for s in windowed.slots)) and windowed.engine_steps < 200:
        windowed.step()
        every_step.step()
        every_step.drain_tier_counters()  # extra per-step drains
    sw, se = windowed.stats(), every_step.stats()  # stats() drains the rest
    assert sw["tenants"] == se["tenants"]
    assert sw["near_hit_rate"] == se["near_hit_rate"]
    assert windowed.placement.stats.near_hits == every_step.placement.stats.near_hits
    assert windowed.placement.stats.far_hits == every_step.placement.stats.far_hits
    dw, de = sw["device_tiering"], se["device_tiering"]
    assert (dw["near_hits"], dw["far_hits"]) == (de["near_hits"], de["far_hits"])
    # cadence differed; books did not
    assert de["drains"] > dw["drains"]


def test_store_segments_match_per_call_totals():
    """Store-level check: N per-call lookups and one segmented lookup over
    the same ragged id sets charge identical near/far books after drain."""
    rng = np.random.default_rng(2)
    seg_sets = [rng.integers(0, 32, size=rng.integers(1, 9)) for _ in range(5)]
    payload = rng.standard_normal((32, 16)).astype(np.float32)
    stores = []
    for _ in range(2):
        s = TieredKVCache(n_pages=32, row_dim=16, near_capacity=8, counter_slots=8)
        s.write(np.arange(32), payload)
        s.migrate(np.arange(8))
        stores.append(s)
    per_call, segmented = stores
    for pages in seg_sets:
        per_call.lookup(pages)
    ids = np.concatenate(seg_sets)
    seg_of = np.repeat(np.arange(len(seg_sets)), [s.size for s in seg_sets])
    rows = segmented.lookup_segments(
        ids, seg_of, len(seg_sets) + 1,
        slot_idx=list(range(len(seg_sets))),
        tenant_idx=[0] * len(seg_sets),
    )
    d = segmented.drain_counters()
    assert (segmented.near_hits, segmented.far_hits) == (per_call.near_hits, per_call.far_hits)
    assert d["slot"][: len(seg_sets)].sum() == ids.size
    # rows come back in concat order, identical to the per-call gathers
    np.testing.assert_array_equal(
        np.asarray(rows), np.concatenate([np.asarray(per_call.lookup(s)[0]) for s in seg_sets])
    )
    # budget: segmented store paid 1 dispatch + 1 sync; per-call paid N of each
    assert (segmented.dispatches, segmented.host_syncs) == (1, 1)
    assert per_call.dispatches == 2 * len(seg_sets)  # incl. the re-reads above


def test_padding_segment_never_pollutes_role_or_slot_books():
    """The segmented lookup pads the ragged id concat to the next power of
    two by assigning sacrificial entries to segment ``n_segments - 1``; the
    accumulator must keep that LAST segment out of every book. Differential
    at a non-power-of-two id count (37 -> pads to 64: 27 sacrificial
    entries) and segment count: per-slot rows, per-role totals and the
    near/far sums all pin against a host oracle computed straight from the
    tier map — any padding leak would inflate them."""
    rng = np.random.default_rng(6)
    store = TieredKVCache(n_pages=32, row_dim=16, near_capacity=8, counter_slots=8)
    store.write(np.arange(32), rng.standard_normal((32, 16)).astype(np.float32))
    store.migrate(np.arange(8))
    seg_sizes = [9, 11, 7, 6, 4]  # 37 ids across 5 live segments
    roles = [0, 1, 0, 1, 1]
    ids = rng.integers(0, 32, size=sum(seg_sizes))
    seg_of = np.repeat(np.arange(len(seg_sizes)), seg_sizes).astype(np.int32)
    store.lookup_segments(
        ids, seg_of, len(seg_sizes) + 1,
        slot_idx=list(range(len(seg_sizes))),
        tenant_idx=[0] * len(seg_sizes),
        role_idx=roles,
    )
    d = store.drain_counters()
    tier = store.tier_host
    role_oracle = np.zeros((2, 2), np.int64)
    for s, size in enumerate(seg_sizes):
        seg_ids = ids[seg_of == s]
        n = int((tier[seg_ids] == 0).sum())
        role_oracle[roles[s]] += (n, size - n)
        assert tuple(d["slot"][s]) == (n, size - n), s
    np.testing.assert_array_equal(d["role"], role_oracle)
    # every real id counted exactly once, every padding entry nowhere
    assert d["near"] + d["far"] == ids.size
    assert (store.near_hits, store.far_hits) == (d["near"], d["far"])


def test_prefetch_promote_window_keeps_budget():
    """The trace-driven prefetch issue window (prefetch_promote) batches its
    promotions into the boundary drain: identical traffic with the window on
    must hold the 1-dispatch budget and add ZERO host syncs vs promote-off.
    The window's apply_placement runs right after the boundary drain, when
    the counter plane is clean — migrations never touch the sync books."""
    runs = {}
    for promote in (False, True):
        cfg, eng = _mk_engine(
            True, predictor="trace", prefetch_promote=promote, near_frac=0.05,
        )
        gen = _gen(cfg, seed=3)
        stats = eng.run(gen, n_requests=8, max_steps=300)
        assert eng.tiered.dispatches == eng.engine_steps
        runs[promote] = (stats, eng)
    (s_off, _), (s_on, eng_on) = runs[False], runs[True]
    d_off, d_on = s_off["device_tiering"], s_on["device_tiering"]
    assert d_on["dispatches_per_step"] <= 1.0 + 1e-9
    assert d_on["host_syncs_per_step"] <= d_off["host_syncs_per_step"] + 1e-9
    # the window actually ran: promotions were charged to the prefetch books
    assert s_on["prefetch_promoted_pages"] >= 0
    assert s_off["prefetch_promoted_pages"] == 0
    # promoted pages flow through mark_prefetched into the prefetch books
    st = eng_on.prefetch.finalized_stats()
    assert st.total_prefetched >= s_on["prefetch_promoted_pages"]


def test_drain_cadence_equivalence_with_promote():
    """Per-step drains vs windowed drains with the promote window ON: the
    drain is a pure sum, so the prefetch window's decisions — and the tier
    books — must be identical under either cadence."""
    engines = []
    for _ in range(2):
        cfg, e = _mk_engine(True, predictor="trace", prefetch_promote=True)
        gen = _gen(cfg, seed=5)
        for _ in range(6):
            e.submit(next(gen))
        engines.append(e)
    windowed, every_step = engines
    while (windowed.queue or any(s.active for s in windowed.slots)) and windowed.engine_steps < 200:
        windowed.step()
        every_step.step()
        every_step.drain_tier_counters()
    sw, se = windowed.stats(), every_step.stats()
    assert sw["near_hit_rate"] == se["near_hit_rate"]
    assert sw["prefetch_promoted_pages"] == se["prefetch_promoted_pages"]
    assert np.array_equal(windowed.placement.tier, every_step.placement.tier)
    dw, de = sw["device_tiering"], se["device_tiering"]
    assert (dw["near_hits"], dw["far_hits"]) == (de["near_hits"], de["far_hits"])
    assert de["drains"] > dw["drains"]


def test_drain_counters_idempotent_and_partial_init_safe():
    """Crash-safety contract: a second drain with no traffic in between
    charges NOTHING (the plane was zeroed), a drain on a store whose
    counter plane was never armed is a clean no-op, and a quarantine drain
    (``discard=True``) returns the deltas WITHOUT folding them into the
    books — so a crashed host's follow-up stats/export reads are safe."""
    # partial init: no write/lookup/ensure_counter_plane ever happened
    fresh = TieredKVCache(n_pages=16, row_dim=8, near_capacity=4, counter_slots=4)
    d = fresh.drain_counters()
    assert d["near"] == 0 and d["far"] == 0 and fresh.drains == 0
    # accumulate via the segmented dispatch (the path that feeds the
    # device plane), then double-drain: second is a no-op on every book
    rng = np.random.default_rng(0)
    store = TieredKVCache(n_pages=16, row_dim=8, near_capacity=4, counter_slots=4)
    store.write(np.arange(16), rng.standard_normal((16, 8)).astype(np.float32))
    store.migrate(np.arange(4))
    ids = np.array([0, 1, 8, 9])
    store.lookup_segments(ids, np.zeros(4, np.int32), 2, slot_idx=[0], tenant_idx=[0])
    d1 = store.drain_counters()
    assert d1["near"] == 2 and d1["far"] == 2
    books = (store.near_hits, store.far_hits, store.host_syncs, store.drains)
    assert books[:2] == (2, 2)
    d2 = store.drain_counters()
    assert d2["near"] == 0 and d2["far"] == 0
    assert (store.near_hits, store.far_hits, store.host_syncs, store.drains) == books
    # quarantine drain: deltas come back, books stay untouched
    store.lookup_segments(np.array([0, 8]), np.zeros(2, np.int32), 2,
                          slot_idx=[0], tenant_idx=[0])
    q = store.drain_counters(discard=True)
    assert q["near"] == 1 and q["far"] == 1
    assert (store.near_hits, store.far_hits) == books[:2]
    # and the plane really was zeroed by the quarantine: nothing left over
    d3 = store.drain_counters()
    assert d3["near"] == 0 and d3["far"] == 0


def test_degraded_mode_keeps_one_dispatch_budget(monkeypatch):
    """Far-tier-only serving is a placement change, not a code path change:
    the degraded engine still pays exactly ONE tiered dispatch per step and
    no mandatory per-step host syncs, with every read a far hit."""
    calls = []
    orig_seg = tiered_kv_mod.tiered_lookup_segments

    def seg(*a, **k):
        calls.append("seg")
        return orig_seg(*a, **k)

    monkeypatch.setattr(tiered_kv_mod, "tiered_lookup_segments", seg)
    cfg, eng = _mk_engine(True)
    eng.enter_degraded()
    assert eng.degraded and eng.tiered.degraded
    gen = _gen(cfg)
    for _ in range(6):
        eng.submit(next(gen))
    syncs_before = eng.tiered.host_syncs
    while (eng.queue or any(s.active for s in eng.slots)) and eng.engine_steps < 200:
        before = len(calls)
        eng.step()
        assert len(calls) - before == 1, (len(calls) - before)
    assert eng.tiered.dispatches == eng.engine_steps
    # the only syncs are profiler-window boundary drains, never per-step
    assert eng.tiered.host_syncs - syncs_before < eng.engine_steps
    d = eng.tiered.drain_counters()
    stats = eng.stats()
    dev = stats["device_tiering"]
    assert dev["near_hits"] == 0 and dev["far_hits"] > 0  # far-tier-only
    assert stats["near_hit_rate"] == 0.0


# ---------------------------------------------------------------------------
# 3. deque admission


def test_admission_is_fifo_deque():
    cfg, eng = _mk_engine(False, max_batch=2)
    gen = _gen(cfg)
    reqs = [next(gen) for _ in range(5)]
    for r in reqs:
        eng.submit(r)
    assert isinstance(eng.queue, deque)
    eng.step()
    active = [s.seq_id for s in eng.slots if s.active]
    assert active == [reqs[0].rid, reqs[1].rid]
    assert [r.rid for r in eng.queue] == [r.rid for r in reqs[2:]]
    # drain fully: backfill must admit in arrival order (observe at the
    # admission point — a 1-token request can retire inside its first step)
    admitted = list(active)
    orig_admit = eng._admit

    def recording_admit():
        orig_admit()
        for s in eng.slots:
            if s.active and s.seq_id not in admitted:
                admitted.append(s.seq_id)

    eng._admit = recording_admit
    while eng.queue or any(s.active for s in eng.slots):
        eng.step()
    assert admitted == [r.rid for r in reqs]


# ---------------------------------------------------------------------------
# 4. counter-based payload rows


def test_counter_rows_deterministic_and_keyed():
    a = counter_rows(0, [1, 2, 3], [0, 0, 1], 64)
    assert a.shape == (3, 64) and a.dtype == np.float32
    np.testing.assert_array_equal(a, counter_rows(0, [1, 2, 3], [0, 0, 1], 64))
    # bumping one page's write-version changes only that page's row
    b = counter_rows(0, [1, 2, 3], [1, 0, 1], 64)
    assert not np.array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1:], b[1:])
    # different seed, different rows
    assert not np.array_equal(a, counter_rows(1, [1, 2, 3], [0, 0, 1], 64))
    # sane standard-normal-ish distribution (loose: 3*64 samples)
    big = counter_rows(7, np.arange(64), np.zeros(64), 128)
    assert abs(float(big.mean())) < 0.05
    assert abs(float(big.std()) - 1.0) < 0.05


# ---------------------------------------------------------------------------
# 5. continuous batching keeps (and extends) the budget


def test_chunked_prefill_one_dispatch_per_step(monkeypatch):
    """Chunked prefill folds prompt work into the step's ONE tiered
    dispatch and ONE model executable: mixed prefill/decode steps never
    add kernel launches, and api.prefill is never dispatched at all."""
    calls = []
    orig_seg = tiered_kv_mod.tiered_lookup_segments

    def seg(*a, **k):
        calls.append("seg")
        return orig_seg(*a, **k)

    monkeypatch.setattr(tiered_kv_mod, "tiered_lookup_segments", seg)
    cfg, eng = _mk_engine(True, prefill_chunk=8)
    assert eng.chunking
    gen = _gen(cfg)
    for _ in range(6):
        eng.submit(next(gen))
    mixed_steps = 0
    while (eng.queue or any(s.active for s in eng.slots)) and eng.engine_steps < 200:
        before = len(calls)
        prefilling = any(s.prefilling for s in eng.slots) or bool(eng.queue)
        eng.step()
        assert len(calls) - before == 1, (len(calls) - before)
        if prefilling and sum(1 for s in eng.slots if s.active) > 1:
            mixed_steps += 1
    assert mixed_steps > 0, "workload never mixed prefill with decode"
    sv = eng.stats()["serving"]
    # honest model-dispatch books: prefill rode the step executable
    assert sv["prefill_dispatches"] == 0
    assert sv["model_dispatches"] == eng.engine_steps
    assert eng.tiered.dispatches == eng.engine_steps


def test_whole_slot_prefill_dispatches_counted():
    """The whole-slot path's per-admit api.prefill launches are now on the
    books: one prefill dispatch per admitted request, each a model
    dispatch OUTSIDE the per-step budget."""
    cfg, eng = _mk_engine(True)
    gen = _gen(cfg)
    n = 6
    stats = eng.run(gen, n_requests=n, max_steps=200)
    sv = stats["serving"]
    assert sv["prefill_dispatches"] == n
    assert sv["model_dispatches"] == eng.engine_steps + n
    assert sv["model_dispatches_per_step"] > 1.0


def test_chunked_drain_cadence_equivalence():
    """Drain-cadence bit-exactness extends to chunked prefill AND the new
    per-role (decode/prefill x near/far) books: per-step drains vs
    windowed drains charge identical totals."""
    engines = []
    for _ in range(2):
        cfg, e = _mk_engine(True, prefill_chunk=8)
        gen = _gen(cfg, seed=5)
        for _ in range(6):
            e.submit(next(gen))
        engines.append(e)
    windowed, every_step = engines
    while (windowed.queue or any(s.active for s in windowed.slots)) and windowed.engine_steps < 200:
        windowed.step()
        every_step.step()
        every_step.drain_tier_counters()
    sw, se = windowed.stats(), every_step.stats()
    assert sw["tenants"] == se["tenants"]
    assert sw["near_hit_rate"] == se["near_hit_rate"]
    dw, de = sw["device_tiering"], se["device_tiering"]
    assert (dw["near_hits"], dw["far_hits"]) == (de["near_hits"], de["far_hits"])
    np.testing.assert_array_equal(windowed.role_hits, every_step.role_hits)
    # the role plane split the same hits the totals counted — nothing
    # double-charged, nothing lost — and prefill-role hits actually flowed
    for eng, d in ((windowed, dw), (every_step, de)):
        assert int(eng.role_hits.sum()) == d["near_hits"] + d["far_hits"]
        assert int(eng.role_hits[:, 0].sum()) == d["near_hits"]
        assert d["prefill_near_hits"] + d["prefill_far_hits"] > 0
        assert d["decode_near_hits"] + d["decode_far_hits"] > 0
    assert de["drains"] > dw["drains"]
