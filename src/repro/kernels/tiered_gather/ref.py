"""Oracles for the tiered row-gather kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_rows_ref(src, ids, scales=None):
    """src: (M, D); ids: (N,) int32; scales: optional (M,) row scales.

    Returns (N, D) f32: src[ids] (dequantized by scales if given).
    """
    rows = src[ids].astype(jnp.float32)
    if scales is not None:
        rows = rows * scales[ids].astype(jnp.float32)[:, None]
    return rows


def tiered_lookup_counted_ref(hot, cold_q, cold_scales, tier, slot, ids):
    """Two-tier lookup oracle with host-side hit counting.

    hot: (Mh, D) bf16/f32 near-tier rows; cold_q: (Mc, D) int8 far-tier rows
    with per-row ``cold_scales`` (Mc,); ``tier[id]`` in {0=hot, 1=cold};
    ``slot[id]`` = row within its tier. Returns (rows (N, D) f32,
    near_hits, far_hits) — the counter semantics the device kernel must
    reproduce bit-exactly (the differential harness's oracle).
    """
    d = hot.shape[1]
    if ids.shape[0] == 0:
        z = jnp.zeros((), jnp.int32)
        return jnp.zeros((0, d), jnp.float32), z, z
    s = slot[ids]
    t = tier[ids]
    if hot.shape[0] == 0:
        hot = jnp.zeros((1, d), hot.dtype)
    if cold_q.shape[0] == 0:
        cold_q = jnp.zeros((1, d), cold_q.dtype)
        cold_scales = jnp.ones((1,), jnp.float32)
    h = hot[jnp.where(t == 0, s, 0)].astype(jnp.float32)
    c = cold_q[jnp.where(t == 1, s, 0)].astype(jnp.float32) * cold_scales[
        jnp.where(t == 1, s, 0)
    ].astype(jnp.float32)[:, None]
    rows = jnp.where((t == 0)[:, None], h, c)
    near = (t == 0).sum().astype(jnp.int32)
    return rows, near, jnp.int32(ids.shape[0]) - near


def tiered_lookup_ref(hot, cold_q, cold_scales, tier, slot, ids):
    """Rows-only view of :func:`tiered_lookup_counted_ref`."""
    return tiered_lookup_counted_ref(hot, cold_q, cold_scales, tier, slot, ids)[0]


def tiered_lookup_segments_ref(hot, cold_q, cold_scales, tier, slot, ids,
                               seg_of, n_segments: int):
    """Segmented-lookup oracle: rows as in :func:`tiered_lookup_ref`, and
    per-segment (near, far) hit pairs as a (n_segments, 2) int32 table —
    the counter semantics the ragged device kernel must reproduce
    bit-exactly. Segments with no gathers count (0, 0).
    """
    n_segments = int(n_segments)
    if ids.shape[0] == 0:
        return (
            jnp.zeros((0, hot.shape[1]), jnp.float32),
            jnp.zeros((n_segments, 2), jnp.int32),
        )
    rows = tiered_lookup_ref(hot, cold_q, cold_scales, tier, slot, ids)
    near = (tier[ids] == 0).astype(jnp.int32)
    seg = seg_of.astype(jnp.int32)
    near_seg = jax.ops.segment_sum(near, seg, num_segments=n_segments)
    far_seg = jax.ops.segment_sum(1 - near, seg, num_segments=n_segments)
    return rows, jnp.stack([near_seg, far_seg], axis=1).astype(jnp.int32)
