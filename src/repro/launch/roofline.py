"""Roofline terms from a compiled dry-run artifact (TPU v5e target).

Per (arch x shape x mesh) cell we derive the three terms the assignment
specifies, all in seconds per step, from the compiled module:

  compute term    = HLO_FLOPs        / (peak_FLOP/s per chip)
  memory term     = HLO_bytes        / (HBM_bw per chip)
  collective term = collective_bytes / (link_bw per chip)

``cost_analysis()`` on a post-SPMD module is per-device, so the terms are
per-chip wall-clock lower bounds; the dominant term is the bottleneck.
Collective bytes are NOT in cost_analysis — they come from the HLO text via
``hlo_analysis.analyze`` (while-loop trip counts included, so a collective
inside an 80-layer scan body counts 80 times).

The ICI term models each collective with its step count on a bidirectional
ring over its group: an all-gather/reduce-scatter of B bytes (B = full
gathered size) moves B*(g-1)/g bytes per chip; all-reduce = 2x reduce-scatter;
all-to-all moves B*(g-1)/g but split across links; collective-permute moves B.
Cross-pod ("pod"-axis) collectives ride DCI at DCI_BW instead of ICI.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core import hw
from repro.launch.hlo_analysis import Cost

# v5e: each chip has 4 ICI links in a 2D torus; a 1D-ring collective uses 2
# (one per direction). Keep the per-link figure from the assignment and let
# the ring model use one bidirectional link pair.
ICI_BW = hw.ICI_BW_PER_LINK  # B/s per link, assignment constant


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes: float
    collective_bytes: float
    model_flops: float
    useful_ratio: float  # MODEL_FLOPS / (HLO_FLOPs * chips)
    bound: str
    detail: Dict[str, float]
    # memory term with the reference-attention HBM traffic replaced by the
    # Pallas flash kernel's (scores/probs stay in VMEM on TPU) — the honest
    # deployment number; memory_s is the raw compiled-HLO artifact number.
    memory_kernel_adj_s: float = 0.0

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        return d


def _collective_seconds(cost: Cost, cross_pod_bytes: float = 0.0) -> float:
    """Ring-model seconds for the per-device collective traffic."""
    total_s = 0.0
    for kind, nbytes in cost.collective_bytes.items():
        g = max(cost.group_sizes.get(kind, 2), 2)
        frac = (g - 1) / g
        if kind == "all-reduce":
            wire = 2.0 * nbytes * frac
        elif kind in ("all-gather", "reduce-scatter"):
            wire = nbytes * frac
        elif kind == "all-to-all":
            wire = nbytes * frac
        else:  # collective-permute: point-to-point
            wire = nbytes
        total_s += wire / ICI_BW
    total_s += cross_pod_bytes / hw.DCI_BW
    return total_s


def roofline(
    *,
    flops: float,
    bytes_: float,
    cost: Cost,
    n_params: float,
    n_tokens: float,
    chips: int,
    kind: str = "train",
    cross_pod_bytes: float = 0.0,
    attn_ref_bytes: float = 0.0,
    attn_kernel_bytes: float = 0.0,
) -> RooflineTerms:
    """Three-term roofline for one compiled cell (per-chip quantities in)."""
    compute_s = flops / hw.PEAK_FLOPS_BF16
    memory_s = bytes_ / hw.HBM_BW
    memory_adj_s = max(bytes_ - attn_ref_bytes + attn_kernel_bytes, 0.0) / hw.HBM_BW
    collective_s = _collective_seconds(cost, cross_pod_bytes)
    # MODEL_FLOPS: 6*N*D for a train step (fwd+bwd), 2*N*D forward-only.
    mult = 6.0 if kind == "train" else 2.0
    model_flops = mult * n_params * n_tokens
    hlo_total = flops * chips
    useful = model_flops / hlo_total if hlo_total else 0.0
    terms = {"compute": compute_s, "memory": memory_adj_s, "collective": collective_s}
    bound = max(terms, key=terms.get)
    return RooflineTerms(
        compute_s=compute_s,
        memory_s=memory_s,
        memory_kernel_adj_s=memory_adj_s,
        collective_s=collective_s,
        flops=flops,
        bytes=bytes_,
        collective_bytes=cost.total_collective_bytes,
        model_flops=model_flops,
        useful_ratio=useful,
        bound=bound,
        detail={
            "per_collective_bytes": dict(cost.collective_bytes),
            "per_collective_ops": dict(cost.collective_ops),
            "group_sizes": dict(cost.group_sizes),
            "attn_ref_bytes": attn_ref_bytes,
            "attn_kernel_bytes": attn_kernel_bytes,
        },
    )


def roofline_fraction(t: RooflineTerms) -> float:
    """How close the dominant term says we are to the compute roofline.

    = useful compute time / max(all terms): 1.0 means the step runs at the
    hardware's model-flops peak; lower means redundant compute, memory, or
    collectives dominate. Uses the kernel-adjusted memory term.
    """
    chips_compute_s = t.compute_s * max(t.useful_ratio, 0.0)  # useful-flops time
    m = max(t.compute_s, t.memory_kernel_adj_s, t.collective_s)
    return chips_compute_s / m if m > 0 else 0.0


def format_row(name: str, t: RooflineTerms) -> str:
    return (
        f"{name:42s} comp={t.compute_s*1e3:9.3f}ms mem={t.memory_kernel_adj_s*1e3:9.3f}ms "
        f"(raw {t.memory_s*1e3:9.3f}ms) coll={t.collective_s*1e3:9.3f}ms bound={t.bound:10s} "
        f"useful={t.useful_ratio:6.3f} roofline={roofline_fraction(t):5.3f}"
    )
