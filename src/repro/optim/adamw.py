"""AdamW with f32 state, global-norm clipping, decoupled weight decay.

States mirror the param tree, so whatever sharding the params get (including
the pooled / ZeRO layout from core/pooling.py) applies to m/v for free —
that IS ZeRO-1/2: optimizer state lives only on the pooling shard.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    schedule: Optional[Callable[[jax.Array], jax.Array]] = None


def adamw_init(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = cfg.lr if cfg.schedule is None else cfg.lr * cfg.schedule(step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
