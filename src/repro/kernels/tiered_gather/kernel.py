"""Tiered row-gather Pallas TPU kernels.

Row ids are SCALAR-PREFETCHED; the source BlockSpec's index map is
data-dependent (block i = row ids[i]), so each grid step DMAs exactly one
(1, D) row HBM->VMEM — a pure-bandwidth op placed exactly where the paper
puts its hot pages: the gather stream for KV pages / embedding rows /
expert blocks is the measured "few hot pages" stream, and this kernel is
the near-tier fast path. The int8 variant fuses the far-tier dequant
(per-row scale) into the same pass so promoted-but-compressed rows cost no
extra memory round-trip.

``tiered_gather_kernel`` is the fused serving-path kernel: one pass selects
each row from the near (bf16/f32) or far (int8 + scale) store by a
prefetched tier bit, dequantizes far rows in-register, and accumulates the
near-tier hit count into an SMEM cell (constant output block index ->
the buffer is carried across sequential grid steps, the standard reduction
pattern). The hit counters are therefore produced at the access point — on
device, by the same pass that moves the bytes — and feed the MemProf
profiler streams directly instead of being re-derived host-side.

``tiered_segmented_kernel`` is the step-wide ragged variant: all active
decode slots' page ids are concatenated into ONE id vector with a
prefetched segment index per gather, and the same pass accumulates a
per-segment (near, far) hit pair into an SMEM counter table. One engine
step therefore costs one kernel dispatch regardless of slot count, and the
counters never leave the device — the serving engine drains them in
profiler windows instead of syncing `int(near)` per slot per step.

D is padded to 128 lanes by ops.py; rows are independent so the grid is
embarrassingly parallel (no scratch carry).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._interpret import resolve_interpret


def _gather_kernel(ids_ref, src_ref, out_ref):
    out_ref[...] = src_ref[...].astype(out_ref.dtype)


def _gather_dequant_kernel(ids_ref, src_ref, scale_ref, out_ref):
    out_ref[...] = src_ref[...].astype(jnp.float32) * scale_ref[0, 0]


def gather_rows_kernel(src, ids, scales=None, *, interpret=None):
    """src: (M, D) — D a lane multiple; ids: (N,) int32; scales: (M, 1) or None.

    Returns (N, D) f32.
    """
    interpret = resolve_interpret(interpret)
    m, d = src.shape
    n = ids.shape[0]

    def src_map(i, ids_ref):
        return (ids_ref[i], 0)

    def out_map(i, ids_ref):
        return (i, 0)

    if scales is None:
        return pl.pallas_call(
            _gather_kernel,
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=1,
                grid=(n,),
                in_specs=[pl.BlockSpec((1, d), src_map)],
                out_specs=pl.BlockSpec((1, d), out_map),
            ),
            out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
            interpret=interpret,
        )(ids, src)
    return pl.pallas_call(
        _gather_dequant_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n,),
            in_specs=[
                pl.BlockSpec((1, d), src_map),
                pl.BlockSpec((1, 1), src_map, memory_space=pltpu.SMEM),
            ],
            out_specs=pl.BlockSpec((1, d), out_map),
        ),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=interpret,
    )(ids, src, scales)


def _tiered_kernel(tier_ref, hot_ids_ref, cold_ids_ref, hot_ref, cold_ref,
                   scale_ref, out_ref, hits_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        hits_ref[0, 0] = 0

    near = tier_ref[i] == 0
    hot_row = hot_ref[...].astype(jnp.float32)
    cold_row = cold_ref[...].astype(jnp.float32) * scale_ref[0, 0]
    out_ref[...] = jnp.where(near, hot_row, cold_row)
    hits_ref[0, 0] += jnp.where(near, 1, 0).astype(jnp.int32)


def _tiered_seg_kernel(tier_ref, hot_ids_ref, cold_ids_ref, seg_ref, hot_ref,
                       cold_ref, scale_ref, out_ref, seghits_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        def zero(j, carry):
            seghits_ref[j, 0] = 0
            seghits_ref[j, 1] = 0
            return carry

        jax.lax.fori_loop(0, seghits_ref.shape[0], zero, 0)

    near = tier_ref[i] == 0
    hot_row = hot_ref[...].astype(jnp.float32)
    cold_row = cold_ref[...].astype(jnp.float32) * scale_ref[0, 0]
    out_ref[...] = jnp.where(near, hot_row, cold_row)
    s = seg_ref[i]
    inc = jnp.where(near, 1, 0).astype(jnp.int32)
    seghits_ref[s, 0] += inc
    seghits_ref[s, 1] += 1 - inc


def tiered_segmented_kernel(hot, cold_q, cold_scales, tier_sel, hot_ids,
                            cold_ids, seg_of, n_segments, *, interpret=None):
    """Ragged (segmented) two-tier gather with per-segment hit counting.

    Same stores/selectors as :func:`tiered_gather_kernel`, plus ``seg_of``
    (N,) int32 mapping each gather to a segment in [0, n_segments). The
    SMEM counter table (n_segments, 2) — column 0 near hits, column 1 far
    hits — uses a constant output block index, so it is carried across the
    sequential grid steps and accumulated by the same pass that DMAs the
    rows. Callers batching ragged id sets to a fixed bucket size point the
    padding at a sacrificial segment and slice it off.

    Returns (rows (N, D) f32, seg_hits (n_segments, 2) int32).
    """
    interpret = resolve_interpret(interpret)
    d = hot.shape[1]
    n = tier_sel.shape[0]

    def hot_map(i, tier_ref, hot_ids_ref, cold_ids_ref, seg_ref):
        return (hot_ids_ref[i], 0)

    def cold_map(i, tier_ref, hot_ids_ref, cold_ids_ref, seg_ref):
        return (cold_ids_ref[i], 0)

    def out_map(i, tier_ref, hot_ids_ref, cold_ids_ref, seg_ref):
        return (i, 0)

    def hits_map(i, tier_ref, hot_ids_ref, cold_ids_ref, seg_ref):
        return (0, 0)

    return pl.pallas_call(
        _tiered_seg_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(n,),
            in_specs=[
                pl.BlockSpec((1, d), hot_map),
                pl.BlockSpec((1, d), cold_map),
                pl.BlockSpec((1, 1), cold_map, memory_space=pltpu.SMEM),
            ],
            out_specs=[
                pl.BlockSpec((1, d), out_map),
                pl.BlockSpec((n_segments, 2), hits_map, memory_space=pltpu.SMEM),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            jax.ShapeDtypeStruct((n_segments, 2), jnp.int32),
        ],
        interpret=interpret,
    )(tier_sel, hot_ids, cold_ids, seg_of, hot, cold_q, cold_scales)


def tiered_gather_kernel(hot, cold_q, cold_scales, tier_sel, hot_ids, cold_ids,
                         *, interpret=None):
    """Fused two-tier gather with on-device hit counting.

    hot: (Mh, D) f32/bf16; cold_q: (Mc, D) int8; cold_scales: (Mc, 1) f32;
    tier_sel/hot_ids/cold_ids: (N,) int32 per-gather selectors (tier bit and
    the row to DMA from each store — masked selectors must be in-range, the
    unused row is discarded by the tier select).

    Returns (rows (N, D) f32, near_hits (1, 1) int32).
    """
    interpret = resolve_interpret(interpret)
    d = hot.shape[1]
    n = tier_sel.shape[0]

    def hot_map(i, tier_ref, hot_ids_ref, cold_ids_ref):
        return (hot_ids_ref[i], 0)

    def cold_map(i, tier_ref, hot_ids_ref, cold_ids_ref):
        return (cold_ids_ref[i], 0)

    def out_map(i, tier_ref, hot_ids_ref, cold_ids_ref):
        return (i, 0)

    def hits_map(i, tier_ref, hot_ids_ref, cold_ids_ref):
        return (0, 0)

    return pl.pallas_call(
        _tiered_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(n,),
            in_specs=[
                pl.BlockSpec((1, d), hot_map),
                pl.BlockSpec((1, d), cold_map),
                pl.BlockSpec((1, 1), cold_map, memory_space=pltpu.SMEM),
            ],
            out_specs=[
                pl.BlockSpec((1, d), out_map),
                pl.BlockSpec((1, 1), hits_map, memory_space=pltpu.SMEM),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(tier_sel, hot_ids, cold_ids, hot, cold_q, cold_scales)
