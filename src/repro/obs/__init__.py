"""Fleet flight recorder: spans + metrics + exporters on one substrate.

This package is the reproduction's answer to MemProf's "always-on profiler
+ tracing tool" pairing (paper §3, §6.2): PR 3-5 built the virtual-time
scheduler, the device counter plane, and the dispatch/sync budget books,
but their telemetry was ad-hoc ``stats()`` dicts — totals with no time
dimension, no per-request story, no export format. The flight recorder
threads one instrumentation substrate through admission, routing,
scheduling, elasticity, the serving engine, and the tiered-KV drain path:

* ``spans``   — request-lifecycle spans (admit/queue/dispatch/prefill/
  decode/migrate/shed/complete, plus per-chunk ``prefill_chunk`` spans
  under chunked prefill — the ``prefill`` span then covers admission to
  the prompt-completing chunk, labeled with its chunk count) stamped with
  scheduler virtual time, in a ring buffer with a drop counter (bounded
  under million-request runs);
* ``metrics`` — typed counters/gauges/exponential histograms with tenant +
  replica label dimensions and an exact fleet ``merge``; device-side series
  enter ONLY from ``drain_counters()`` deltas, so the decode hot path stays
  at one dispatch and zero mandatory host syncs per step and the PR-5
  drain-cadence invariant extends to every metric. Engines record a
  per-tenant ``ttft`` histogram (submit -> first generated token, virtual
  time; the prompt-completing chunk step under chunked prefill), merged
  into ``tenant_report``'s ``ttft_p50``/``ttft_p99``;
* ``export``  — Perfetto/Chrome trace_event JSON for the span timeline and
  JSON-lines metric snapshots per profiler window.

:class:`FlightRecorder` is the facade the fleet attaches
(``FleetRouter.attach_recorder`` / ``build_fleet(recorder=...)``); a
process-global default recorder can be installed explicitly
(:func:`set_default_recorder`, what ``benchmarks/run.py --trace`` does) or
via the strict boolean env ``REPRO_FLIGHT_RECORDER=1`` (what CI uses to run
the dispatch-budget suite with tracing on).
"""
from __future__ import annotations

from typing import List, Optional

from repro.env import env_flag
from repro.obs import export as export_mod
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricSnapshot,
    MetricsRegistry,
    merge_snapshots,
    merged_histogram,
    prefetch_report,
    sum_counters,
)
from repro.obs.spans import Span, SpanRecorder

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricSnapshot",
    "MetricsRegistry",
    "merge_snapshots",
    "merged_histogram",
    "prefetch_report",
    "sum_counters",
    "Span",
    "SpanRecorder",
    "FlightRecorder",
    "default_recorder",
    "set_default_recorder",
]

_ENV_FLAG = "REPRO_FLIGHT_RECORDER"


class FlightRecorder:
    """Spans + a fleet-level registry + every attached engine registry.

    ``now_fn`` is set by whatever owns the clock (the FleetRouter points it
    at fleet virtual time; a standalone engine at its step counter), so all
    emission points share one causal timeline. ``metrics_window`` sets the
    vtime cadence of metric snapshots (the JSONL export rows).
    """

    def __init__(
        self,
        capacity: int = 65536,
        metrics_window: float = 16.0,
        step_spans: bool = True,
    ):
        self.spans = SpanRecorder(capacity)
        self.metrics = MetricsRegistry()
        self.extra_registries: List[MetricsRegistry] = []
        self.metrics_window = float(metrics_window)
        self.metric_rows: List[dict] = []
        self.step_spans = bool(step_spans)  # per-replica step spans on host tracks
        self.now_fn = lambda: 0.0
        self._last_window: Optional[float] = None

    # ------------------------------------------------------------------
    def now(self) -> float:
        return float(self.now_fn())

    def register(self, registry: MetricsRegistry):
        """Include an engine/replica registry in snapshots and exports."""
        if registry is not self.metrics and registry not in self.extra_registries:
            self.extra_registries.append(registry)

    # span API (t defaults to the shared virtual clock) ----------------
    def begin(self, name, trace, t=None, **kw):
        self.spans.begin(name, trace, self.now() if t is None else t, **kw)

    def end(self, name, trace, t=None, **kw):
        return self.spans.end(name, trace, self.now() if t is None else t, **kw)

    def instant(self, name, trace, t=None, **kw):
        self.spans.instant(name, trace, self.now() if t is None else t, **kw)

    def span(self, name, trace, t0, t1, **kw):
        self.spans.span(name, trace, t0, t1, **kw)

    # metrics snapshots -------------------------------------------------
    def on_step(self, now: float):
        """FleetRouter hook: snapshot the registries once per window."""
        if self._last_window is None:
            self._last_window = now
            return
        if now - self._last_window >= self.metrics_window:
            self._last_window = now
            self.snapshot_metrics(now)

    def merged_snapshot(self) -> MetricSnapshot:
        self.metrics.gauge("spans_dropped").set(self.spans.dropped)
        self.metrics.gauge("spans_emitted").set(self.spans.emitted)
        self.metrics.gauge("spans_double_end").set(self.spans.double_end)
        return merge_snapshots(
            [self.metrics.snapshot()] + [r.snapshot() for r in self.extra_registries]
        )

    def snapshot_metrics(self, now: float) -> dict:
        row = {"vtime": float(now), **self.merged_snapshot().flat()}
        self.metric_rows.append(row)
        return row

    # export ------------------------------------------------------------
    def trace_events(self, drain_open: bool = True) -> List[dict]:
        if drain_open:
            self.spans.drain_open(self.now())
        return export_mod.to_trace_events(self.spans.finished())

    def validate(self) -> dict:
        return export_mod.validate_trace_events(self.trace_events())

    def write(
        self,
        trace_path: str,
        metrics_path: Optional[str] = None,
        validate: bool = True,
    ) -> dict:
        """Export the span timeline (and final metrics row) to disk.

        ``metrics_path`` defaults to ``<trace_path>.metrics.jsonl``. Returns
        the validator's summary so callers can assert on it.
        ``validate=False`` skips the schema gate — for traces that span
        several independent scenarios (benchmarks/run.py over the whole
        suite), where unrelated fleets reuse rids on one timeline.
        """
        events = self.trace_events()
        if validate:
            summary = export_mod.validate_trace_events(events)
        else:
            summary = {"events": len(events)}
        export_mod.write_trace(trace_path, events)
        self.snapshot_metrics(self.now())
        export_mod.write_metrics(
            metrics_path or f"{trace_path}.metrics.jsonl", self.metric_rows
        )
        return summary


_DEFAULT: Optional[FlightRecorder] = None


def set_default_recorder(rec: Optional[FlightRecorder]):
    """Install (or clear, with None) the process-global recorder that
    engines and routers attach when not given one explicitly."""
    global _DEFAULT
    _DEFAULT = rec


def default_recorder() -> Optional[FlightRecorder]:
    """The global recorder, if any: one installed via
    :func:`set_default_recorder` (``benchmarks/run.py --trace``), else a
    lazily created singleton when ``REPRO_FLIGHT_RECORDER=1``."""
    global _DEFAULT
    if _DEFAULT is None and env_flag(_ENV_FLAG, default=False):
        _DEFAULT = FlightRecorder()
    return _DEFAULT
