"""Shared model primitives (pure JAX).

Everything here is written to lower cleanly under GSPMD on big meshes:
 * attention is chunked (lax.scan over KV blocks, online softmax, f32
   accumulators) so prefill at 32k never materializes an (Lq, Lk) matrix;
 * decode (Lq == 1) uses a direct masked einsum so a sequence-sharded KV
   cache partitions without per-iteration gathers;
 * all matmuls request f32 accumulation via preferred_element_type.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array

# ---------------------------------------------------------------------------
# dtype helpers


def dt(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# initializers


def dense_init(key, shape, in_axis: int = 0, scale: float = 1.0, dtype=jnp.float32):
    """Truncated-normal fan-in init."""
    fan_in = shape[in_axis]
    std = scale / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms


def rms_norm(x: Array, weight: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: Array, weight: Array, bias: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings


def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def _rotate_half(x: Array) -> Array:
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([-x2, x1], axis=-1)


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, H, L, D); positions: (B, L) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    ang = positions[:, None, :, None].astype(jnp.float32) * freqs  # (B,1,L,D/2)
    cos = jnp.concatenate([jnp.cos(ang)] * 2, axis=-1)
    sin = jnp.concatenate([jnp.sin(ang)] * 2, axis=-1)
    return (x.astype(jnp.float32) * cos + _rotate_half(x.astype(jnp.float32)) * sin).astype(x.dtype)


def apply_mrope(x: Array, positions: Array, theta: float, sections) -> Array:
    """Qwen2-VL multimodal RoPE. positions: (3, B, L) [t,h,w]; sections sum to D/2."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    # per-frequency section id: first sections[0] freqs use t, next use h, then w
    sec = jnp.concatenate(
        [jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)]
    )  # (D/2,)
    pos_sel = positions.astype(jnp.float32)[sec].transpose(1, 2, 0)  # (B, L, D/2)
    ang = pos_sel[:, None, :, :] * freqs  # (B,1,L,D/2)
    cos = jnp.concatenate([jnp.cos(ang)] * 2, axis=-1)
    sin = jnp.concatenate([jnp.sin(ang)] * 2, axis=-1)
    return (x.astype(jnp.float32) * cos + _rotate_half(x.astype(jnp.float32)) * sin).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (jnp oracle — also the reference for kernels/flash_attention)

NEG_INF = -1e30


def _expand_gqa(q: Array, n_kv: int) -> Array:
    """(B, Hq, L, D) -> (B, Hkv, G, L, D)."""
    b, hq, l, d = q.shape
    return q.reshape(b, n_kv, hq // n_kv, l, d)


def _kv_blocks(k: Array, v: Array, block_k: int):
    """(B,Hkv,Lk,D) k/v -> (nb,B,Hkv,block,D) stacks, zero-padded."""
    b, hkv, lk, d = k.shape
    nb = max(1, -(-lk // block_k))
    pad = nb * block_k - lk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kb = k.reshape(b, hkv, nb, block_k, d).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, hkv, nb, block_k, d).transpose(2, 0, 1, 3, 4)
    return kb, vb, nb


def _block_scores(qg, kblk, iblk, *, scale, block_k, lk, lq, q_offset, causal, bidirectional):
    """Masked f32 scores for one k-block: (B,Hkv,G,Lq,block).

    Masking is an additive (Lq, block) bias instead of a broadcast ``where``
    over the full score shape: XLA hoists loop-invariant mask tensors out of
    the scan, and a stacked (nb, B, H, G, Lq, block) pred buffer was the
    single largest allocation of the train step. The small bias stack is
    negligible and fuses into the score add.
    """
    kv_pos = iblk * block_k + jnp.arange(block_k)  # (block,)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kblk, preferred_element_type=jnp.float32) * scale
    valid = kv_pos < lk
    if causal and not bidirectional:
        q_pos = q_offset + jnp.arange(lq)
        valid = valid[None, :] & (kv_pos[None, :] <= q_pos[:, None])  # (Lq, block)
        bias = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)
        s = s + bias[None, None, None]
    else:
        bias = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)  # (block,)
        s = s + bias[None, None, None, None]
    return s


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _attention_core(q, k, v, causal, q_offset, block_k, bidirectional):
    out, _ = _attention_fwd_impl(q, k, v, causal, q_offset, block_k, bidirectional)
    return out


def _attention_fwd_impl(q, k, v, causal, q_offset, block_k, bidirectional):
    b, hq, lq, d = q.shape
    hkv, lk = k.shape[1], k.shape[2]
    scale = 1.0 / math.sqrt(d)
    qg = _expand_gqa(q, hkv)  # (B,Hkv,G,Lq,D)
    g = qg.shape[2]
    kb, vb, nb = _kv_blocks(k, v, block_k)

    def body(carry, blk):
        m, l, acc = carry
        kblk, vblk, iblk = blk
        s = _block_scores(
            qg, kblk, iblk, scale=scale, block_k=block_k, lk=lk, lq=lq,
            q_offset=q_offset, causal=causal, bidirectional=bidirectional,
        )
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p.astype(vblk.dtype), vblk, preferred_element_type=jnp.float32
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, lq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, lq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, lq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, jnp.arange(nb)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,Hkv,G,Lq,D) f32
    # flash-style softmax stats: lse = m + log(l); 0 for fully-masked rows so
    # the backward's exp(s - lse) stays 0 (s is NEG_INF there) instead of nan
    lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)), 0.0)
    return out.reshape(b, hq, lq, d).astype(q.dtype), lse


def _attention_fwd(q, k, v, causal, q_offset, block_k, bidirectional):
    out, lse = _attention_fwd_impl(q, k, v, causal, q_offset, block_k, bidirectional)
    return out, (q, k, v, out, lse)


def _attention_bwd(causal, q_offset, block_k, bidirectional, res, dout):
    """Flash-attention backward: recompute p per k-block from (q,k,lse).

    Saves only (q,k,v,out,lse) — no stacked per-block score/prob/acc
    residuals, which is what makes the train cells fit per-chip HBM (and it
    mirrors the Pallas kernel's dataflow, HBM traffic = q/k/v/o + grads).
    """
    q, k, v, out, lse = res
    b, hq, lq, d = q.shape
    hkv, lk = k.shape[1], k.shape[2]
    scale = 1.0 / math.sqrt(d)
    qg = _expand_gqa(q, hkv)
    g = qg.shape[2]
    kb, vb, nb = _kv_blocks(k, v, block_k)
    do = _expand_gqa(dout, hkv)  # (B,Hkv,G,Lq,D), compute dtype
    og = _expand_gqa(out, hkv)
    delta = (do.astype(jnp.float32) * og.astype(jnp.float32)).sum(-1)  # (B,Hkv,G,Lq)

    def body(dq, blk):
        kblk, vblk, iblk = blk
        s = _block_scores(
            qg, kblk, iblk, scale=scale, block_k=block_k, lk=lk, lq=lq,
            q_offset=q_offset, causal=causal, bidirectional=bidirectional,
        )
        p = jnp.exp(s - lse[..., None])  # exact probs (B,Hkv,G,Lq,block)
        # matmul inputs in compute dtype (as the Pallas kernel does on MXU);
        # accumulation stays f32 via preferred_element_type
        pc = p.astype(v.dtype)
        dv_blk = jnp.einsum("bhgqk,bhgqd->bhkd", pc, do, preferred_element_type=jnp.float32)
        dp = jnp.einsum("bhgqd,bhkd->bhgqk", do, vblk, preferred_element_type=jnp.float32)
        ds = (p * (dp - delta[..., None]) * scale).astype(k.dtype)
        dq = dq + jnp.einsum("bhgqk,bhkd->bhgqd", ds, kblk, preferred_element_type=jnp.float32)
        dk_blk = jnp.einsum("bhgqk,bhgqd->bhkd", ds, qg, preferred_element_type=jnp.float32)
        return dq, (dk_blk, dv_blk)

    dq0 = jnp.zeros((b, hkv, g, lq, d), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(body, dq0, (kb, vb, jnp.arange(nb)))
    dk = dks.transpose(1, 2, 0, 3, 4).reshape(b, hkv, nb * block_k, d)[:, :, :lk]
    dv = dvs.transpose(1, 2, 0, 3, 4).reshape(b, hkv, nb * block_k, d)[:, :, :lk]
    return (
        dq.reshape(b, hq, lq, d).astype(q.dtype),
        dk.astype(k.dtype),
        dv.astype(v.dtype),
    )


_attention_core.defvjp(_attention_fwd, _attention_bwd)


def attention_chunked(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    q_offset: int = 0,
    block_k: int = 1024,
    bidirectional: bool = False,
) -> Array:
    """Online-softmax attention, O(L * block_k) memory, flash-style custom
    VJP (backward recomputes per-block probs from the saved LSE).

    q: (B, Hq, Lq, D); k, v: (B, Hkv, Lk, D). GQA via Hq % Hkv == 0.
    Returns (B, Hq, Lq, D) in q.dtype.

    The named_scope tags every HLO op of this region so the dry-run cost
    model can attribute its HBM traffic: on TPU this whole region runs as
    the Pallas flash kernel (scores/probs stay in VMEM), so the roofline
    reports both the raw-HLO memory term and the kernel-adjusted one.
    """
    with jax.named_scope("flash_attention_ref"):
        return _attention_core(q, k, v, causal, q_offset, block_k, bidirectional)


def attention_decode(
    q: Array,
    k: Array,
    v: Array,
    kv_length,
    *,
    sink_cache: bool = False,
) -> Array:
    """Single-position attention over a (possibly partially filled) cache.

    q: (B, Hq, 1, D); k, v: (B, Hkv, S, D); kv_length: scalar or (B,) valid len.
    Direct masked einsum — partitions cleanly when S (or Hkv) is sharded.
    """
    b, hq, lq, d = q.shape
    hkv, s_len = k.shape[1], k.shape[2]
    scale = 1.0 / math.sqrt(d)
    qg = _expand_gqa(q, hkv)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k, preferred_element_type=jnp.float32) * scale
    kv_length = jnp.asarray(kv_length)
    if kv_length.ndim == 0:
        kv_length = jnp.broadcast_to(kv_length, (b,))
    mask = jnp.arange(s_len)[None, :] < kv_length[:, None]  # (B, S)
    s = jnp.where(mask[:, None, None, None, :], s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    o = o / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    return o.reshape(b, hq, lq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLP


def swiglu(x: Array, w_gate: Array, w_up: Array, w_down: Array) -> Array:
    g = jnp.einsum("bsd,df->bsf", x, w_gate, preferred_element_type=jnp.float32)
    u = jnp.einsum("bsd,df->bsf", x, w_up, preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", h, w_down, preferred_element_type=jnp.float32).astype(x.dtype)


def gelu_mlp(x: Array, w_in: Array, b_in: Array, w_out: Array, b_out: Array) -> Array:
    h = jnp.einsum("bsd,df->bsf", x, w_in, preferred_element_type=jnp.float32) + b_in
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return (
        jnp.einsum("bsf,fd->bsd", h, w_out, preferred_element_type=jnp.float32) + b_out
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# losses


def cross_entropy(logits: Array, labels: Array, vocab_size: int, z_coef: float = 1e-4):
    """Mean CE over labels >= 0; logits padding beyond vocab_size is masked.

    logits: (B, S, Vp) any float dtype; labels: (B, S) int32 with -1 = ignore.
    Returns (loss, metrics dict).
    """
    vp = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    if vp > vocab_size:
        pad_mask = jnp.arange(vp) >= vocab_size
        lf = jnp.where(pad_mask[None, None, :], NEG_INF, lf)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - gold
    mask = (labels >= 0).astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    zloss = z_coef * ((lse * mask) ** 2).sum() / denom
    # accuracy via gold==max (an argmax would materialize a vocab-sized iota)
    metrics = {
        "loss": loss,
        "zloss": zloss,
        "tokens": mask.sum(),
        "accuracy": ((gold >= lf.max(-1)) * mask).sum() / denom,
    }
    return loss + zloss, metrics


def fused_ce_loss(
    h: Array,
    w: Array,
    labels: Array,
    vocab_size: int,
    *,
    chunk: int = 1024,
    z_coef: float = 1e-4,
):
    """Sequence-chunked fused lm_head + cross-entropy.

    Never materializes the full (B, S, Vp) logits: the head matmul and the
    CE run one seq-chunk at a time inside a checkpointed scan (backward
    recomputes each chunk's logits). For 150k-vocab configs this removes
    the single largest train-step allocation (f32 logits + softmax +
    dlogits). h: (B, S, D) post-final-norm; w: (D, Vp); labels: (B, S)
    int32 with -1 = ignore. Returns (loss, metrics) like ``cross_entropy``.
    """
    from repro.launch.mesh import BATCH, MODEL, shard  # local: avoid cycle

    b, s, d = h.shape
    vp = w.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = (s + pad) // chunk
    hs = h.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)  # (nc, B, C, D)
    ls = labels.reshape(b, nc, chunk).transpose(1, 0, 2)
    vocab_bias = jnp.where(jnp.arange(vp) < vocab_size, 0.0, NEG_INF).astype(jnp.float32)

    def body(carry, xs):
        nll, zz, ntok, ncorr = carry
        hc, lc = xs
        logits = jnp.einsum(
            "bcd,dv->bcv", hc, w.astype(hc.dtype), preferred_element_type=jnp.float32
        )
        logits = shard(logits + vocab_bias, BATCH, None, MODEL)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        msk = (lc >= 0).astype(jnp.float32)
        nll = nll + ((lse - gold) * msk).sum()
        zz = zz + ((lse * msk) ** 2).sum()
        ntok = ntok + msk.sum()
        ncorr = ncorr + ((gold >= logits.max(-1)) * msk).sum()
        return (nll, zz, ntok, ncorr), None

    body = jax.checkpoint(body, prevent_cse=False)
    zero = jnp.zeros((), jnp.float32)
    (nll, zz, ntok, ncorr), _ = jax.lax.scan(body, (zero, zero, zero, zero), (hs, ls))
    denom = jnp.maximum(ntok, 1.0)
    loss = nll / denom
    zloss = z_coef * zz / denom
    metrics = {"loss": loss, "zloss": zloss, "tokens": ntok, "accuracy": ncorr / denom}
    return loss + zloss, metrics


# ---------------------------------------------------------------------------
# misc


def causal_positions(batch: int, seq: int) -> Array:
    return jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None, :], (batch, seq))


def sinusoidal_positions(length: int, d_model: int) -> Array:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d_model, 2, dtype=jnp.float32) * (-math.log(10000.0) / d_model))
    pe = jnp.zeros((length, d_model), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


def maybe_remat(fn, enabled: bool, policy: str = "nothing"):
    """Per-layer activation checkpointing.

    policy="nothing": save only the inter-layer residual stream (minimum
    memory, ~1/3 more compute in backward) — the default so every assigned
    cell fits per-chip HBM; policy="dots": additionally save matmul outputs
    (less recompute, more memory) — a §Perf lever for compute-bound cells.
    """
    if not enabled:
        return fn
    policies = {
        "nothing": jax.checkpoint_policies.nothing_saveable,
        "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    }
    return jax.checkpoint(fn, policy=policies[policy])


def cast_tree(tree, dtype):
    """Cast float leaves of a weight (sub)tree to the compute dtype.

    Matmuls must see bf16 weights: mixed f32xbf16 einsums promote the
    activations to f32, which silently turns the whole residual stream and
    every saved remat buffer f32 (2x memory) and pushes the MXU off its
    bf16 path (TPU peak is quoted in bf16).
    """
    return jax.tree.map(
        lambda w: w.astype(dtype) if jnp.issubdtype(w.dtype, jnp.floating) else w,
        tree,
    )


def constrain_tree(tree, specs, dtype=None):
    """Constrain a (sub)tree of weights to its compute (TP) layout (+cast).

    No-op when the weights are already in that layout (the non-pooled path)
    or when no mesh is active (CPU tests). With pooled / ZeRO storage this is
    the just-in-time gather of the paper's shared-L2 pooling: called on one
    scanned layer slice at a time, it keeps a single layer's gathered weights
    live instead of the whole tree, and its transpose under jax.grad is the
    per-layer reduce-scatter of the gradients back to the pooled layout.
    The cast happens BEFORE the constraint so the gather moves bf16 bytes.
    """
    from repro.launch import mesh as _meshlib

    def one(w, s):
        if dtype is not None and jnp.issubdtype(w.dtype, jnp.floating):
            w = w.astype(dtype)
        return _meshlib.shard(w, *s)

    return jax.tree.map(one, tree, specs, is_leaf=lambda x: isinstance(x, jax.Array))
