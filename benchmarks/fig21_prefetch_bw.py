"""Paper Fig. 21: IPC and memory-BW change with L2 prefetchers on.

Tiered-serving analogue over each workload's measured block stream: far-tier
demand stalls (IPC proxy: every uncovered far access stalls the decode step)
and TOTAL far-tier traffic, prefetcher off vs on. The paper's point — modest
IPC gain, significant extra bandwidth (e.g. Cache1 +31%) — appears whenever
coverage is low but the prefetcher keeps issuing.

Part 2 runs the same books on template-walk streams for the trace-trained
successor predictor against the hardware-style baselines: trained on the
stream's leading segment (the fleet trace history), it removes MORE stalls
than nextline or markov while moving LESS total data than nextline — the
trace-driven design the paper's §6 tooling exists to enable. Stats are
finalized (pending prefetches count as waste). Self-checked.
"""
import numpy as np

from repro.core.memtrace import TraceWindow
from repro.core.placement import TieredPlacement
from repro.core.prefetch import PrefetchEngine, train_successors

from _common import fmt_table, score_prefetcher, stream_for, template_stream_for


def _run(stream, n_blocks, predictor):
    pl = TieredPlacement(n_blocks=n_blocks, near_capacity=max(n_blocks // 10, 1))
    pl.plan_initial(np.bincount(stream[:2000], minlength=n_blocks))
    eng = PrefetchEngine(predictor=predictor, buffer_blocks=256, degree=2)
    tier = pl.tier
    for b in stream:
        eng.access(int(b), is_far=bool(tier[b] == 1))
    s = eng.finalized_stats()
    stalls = s.demand_fetches
    traffic = s.total_prefetched + s.demand_fetches
    return stalls, traffic


def _books(stats):
    """(stalls, total far traffic) from finalized prefetch stats."""
    return stats.demand_fetches, stats.total_prefetched + stats.demand_fetches


def main():
    rows = []
    out = {}
    for wl in ("Web1", "Ads1", "Cache1", "Feed", "Reader"):
        stream, prof = stream_for(wl, n=30_000)
        st0, t0 = _run(stream, prof.n_blocks, "off")
        st1, t1 = _run(stream, prof.n_blocks, "nextline")
        ipc_gain = (st0 - st1) / max(st0, 1) * 100.0
        bw_incr = (t1 - t0) / max(t0, 1) * 100.0
        rows.append((wl, st0, st1, f"{ipc_gain:+6.1f}%", f"{bw_incr:+6.1f}%"))
        out[wl] = (ipc_gain, bw_incr)
    print("[fig21] far-tier demand stalls + total far traffic, prefetch off -> on (nextline)")
    print(fmt_table(rows, ["workload", "stalls(off)", "stalls(on)", "stall reduction", "BW increase"]))
    print("paper Fig.21: small IPC gains, significant BW increase (Cache1 +31%)")

    # -- part 2: trace-trained prefetch on template-walk streams
    rows = []
    n = 24_000
    for wl in ("Web1", "Cache1", "Feed"):
        blocks, lanes, _ = template_stream_for(wl, n=n, n_templates=48)
        split = 3 * n // 4
        table = train_successors(
            [TraceWindow(0, blocks[:split], np.zeros(split, bool), lanes[:split])]
        )
        ev_b, ev_l = blocks[split:], lanes[split:]
        res = {p: score_prefetcher(ev_b, ev_l, p, degree=2) for p in ("off", "nextline", "markov")}
        res["trace"] = score_prefetcher(ev_b, ev_l, "trace", table=table, degree=2)
        st_off, t_off = _books(res["off"])
        for p in ("nextline", "markov", "trace"):
            st, t = _books(res[p])
            rows.append(
                (
                    wl if p == "nextline" else "",
                    p,
                    st,
                    f"{(st_off - st) / max(st_off, 1) * 100.0:+6.1f}%",
                    f"{(t - t_off) / max(t_off, 1) * 100.0:+6.1f}%",
                )
            )
        st_tr, t_tr = _books(res["trace"])
        st_nl, t_nl = _books(res["nextline"])
        st_mk, t_mk = _books(res["markov"])
        assert st_tr < st_nl and st_tr < st_mk, (wl, st_tr, st_nl, st_mk)
        assert t_tr < t_nl, (wl, t_tr, t_nl)  # more stalls removed, less data moved
        out[f"template:{wl}"] = {
            p: _books(res[p]) for p in ("off", "nextline", "markov", "trace")
        }
    print("\n[fig21b] template-walk streams: stalls removed vs extra traffic, per predictor")
    print(fmt_table(rows, ["workload", "predictor", "stalls", "stall reduction", "BW increase"]))
    print("trace-trained successors: most stalls removed, least extra traffic (self-checked)")
    return out


if __name__ == "__main__":
    main()
