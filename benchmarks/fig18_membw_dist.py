"""Paper Fig. 18: memory-bandwidth distribution over 30/60/120-step windows.

Key observation 2: few pages serve most bandwidth, and the distribution is
STABLE across measurement intervals (what makes tiering placement work).
"""
import numpy as np

from repro.core import distribution as dist

from _common import ALL_WORKLOADS, fmt_table, stream_for


def main():
    rows = []
    out = {}
    for name in ALL_WORKLOADS:
        stream, prof = stream_for(name, n=90_000)
        thirds = np.array_split(stream, 3)  # 30/60/120-second-window analogue
        windows = [np.bincount(t, minlength=prof.n_blocks) for t in thirds]
        total = np.bincount(stream, minlength=prof.n_blocks)
        cap90 = dist.capacity_for_traffic(total, 0.9)
        active = (total > 0).mean()
        stab = dist.interval_stability(windows, capacity_frac=0.10)
        rows.append(
            (
                name,
                f"{cap90*100:5.1f}%",
                f"{active*100:5.1f}%",
                f"{stab['mean']:.3f}+-{stab['max_dev']:.3f}",
            )
        )
        out[name] = float(cap90)
    print("[fig18] capacity serving 90% of traffic | active footprint | hot-set stability across windows")
    print(fmt_table(rows, ["workload", "cap@90%BW", "active", "stability"]))
    print("paper: <=10% of capacity serves >=90% of bandwidth; stable across 30/60/120s")
    return out


if __name__ == "__main__":
    main()
