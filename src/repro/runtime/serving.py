"""Serving engine: continuous batching over paged, tiered, prefix-shared KV.

This is where the paper's three findings operate together at runtime:

  * shared KV page table (core/pagetable): requests with common prompt
    prefixes map the same physical pages (multi-ASID I-TLB analogue) —
    dedups HBM capacity and prefill traffic;
  * tiered placement (core/placement): hot pages stay in the HBM near tier,
    cold pages demote to the host far tier, driven by windowed access counts
    from the profiler (MemProf.MemBW in the loop);
  * software prefetch (core/prefetch): the decode step's sequential page walk
    is predicted and far pages are fetched ahead, overlapping transfer with
    compute; accuracy/coverage accounted with the paper's formulas.

Model math runs through the model's own decode_step (exact for every
family); the page table is the management/accounting plane, as in any
engine where the block manager is host-side (vLLM-style). The Pallas
paged_attention kernel is the device-side fast path for dense archs
(examples/serve_tiered.py wires it directly).

Device-executed tiering (``EngineConfig.device_tiering``, env
``REPRO_DEVICE_TIERING=1``): the decode step's KV page stream is EXECUTED
against a device-resident tiered store (runtime/tiered_kv.TieredKVCache) —
near rows in an f32 "HBM" buffer, far rows int8-quantized with per-row
scales — via the fused kernels/tiered_gather pass. The model's own decode
math stays exact and untouched (it reads its per-family cache as always);
what moves on device is the tier plane: the page gathers, the int8
promote/demote data movement driven by placement pushes (local TPP epochs
and fleet AutoTierer apply_placement), and the near/far hit counters,
which are produced in-kernel at the access point and REPLACE the
host-side tier accounting. With identity scales the device-tiered engine
is bit-identical to the host-accounted one (same tokens, same counters)
and tiered reads never diverge from the flat mirror;
tests/test_tiered_decode.py enforces that equivalence.

Dispatch/sync budget: one engine step costs ONE tiered-gather dispatch and
ZERO mandatory host syncs. All active slots' page ids are concatenated
into a single ragged (segmented) kernel pass whose per-segment near/far
hit counts accumulate into the store's device counter plane; the engine
drains the plane once per profiler window (``drain_tier_counters``) and
charges placement stats and per-tenant books from the drained deltas —
bit-identically to the retired per-slot path, which is kept as
``EngineConfig.segmented_lookup=False`` for the dispatch-budget
benchmark's baseline. The next-token argmax is fused into the jitted
decode (the step's cache buffers are donated), so the decode feedback loop
stays on device too.

Continuous batching + chunked prefill (``EngineConfig.prefill_chunk``):
with a positive chunk budget, ``step`` is a vLLM-style continuous-batching
step — new requests are admitted into freed slots every step, and their
prompts are fed in fixed-token-budget chunks INTERLEAVED with the decode
tokens of co-resident slots inside the SAME single jitted dispatch (a
masked column scan over the family decode step; every engine step runs
exactly one model executable and one tiered-gather dispatch regardless of
the prefill/decode mix). Prefill-chunk KV page reads ride the segmented
gather as ROLE_PREFILL segments next to the decode walks, prefill chunks
write KV pages through the tiered write path as they complete, and slot
cache buffers are donated/reused across join/leave churn (a jitted
zero-reset at admit; no per-admit batch-1 cache allocation and no
per-prompt-length XLA compiles — the chunked engine only ever runs two
decode shapes, (B, 1) and (B, C)). ``prefill_chunk = 0`` (the default)
means an infinite budget: prompts prefill whole at admit through
``api.prefill``, the legacy whole-slot path — and the chunk-budget=∞
equivalence baseline.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.workloads import WorkloadProfile
from repro.core.memtrace import MemTracer
from repro.core.pagetable import FAR, NEAR, SharedKVPageTable
from repro.core.placement import TieredPlacement
from repro.core.prefetch import PrefetchEngine, train_tenant_successors
from repro.core.profiler import AccessProfiler
from repro.data.requests import ChunkState, Request, RequestGenerator
from repro.env import env_flag
from repro.obs import Counter, MetricsRegistry, default_recorder
from repro.models.api import ModelAPI, make_serve_step
from repro.runtime.tiered_kv import (
    N_ROLES,
    ROLE_DECODE,
    ROLE_PREFILL,
    TieredKVCache,
    sanitize_near_ids,
)

# families whose decode_step can consume prompt tokens incrementally (the
# chunked-prefill substrate). Excluded: "audio" (whisper's cross-attention
# caches exist only after an encode+prefill pass) and "vlm" (prompt embeds
# carry M-RoPE positions the decode path does not reconstruct) — both fall
# back to monolithic prefill at admit regardless of the chunk budget.
CHUNKABLE_FAMILIES = ("dense", "moe", "ssm", "hybrid")


def _env_device_tiering() -> bool:
    return env_flag("REPRO_DEVICE_TIERING", default=False)


def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer — the counter-based hash behind the synthetic
    payload rows (vectorized; uint64 wraparound is the intended ring)."""
    x = x.copy()
    x ^= x >> np.uint64(30)
    x *= np.uint64(0xBF58476D1CE4E5B9)
    x ^= x >> np.uint64(27)
    x *= np.uint64(0x94D049BB133111EB)
    x ^= x >> np.uint64(31)
    return x


def counter_rows(seed: int, page_ids, versions, dim: int) -> np.ndarray:
    """Deterministic standard-normal payload rows keyed on (seed, page,
    write-version), generated by ONE vectorized counter-based draw.

    Replaces a per-page ``np.random.default_rng`` construction loop that
    dominated recurrent-family writes: every output element's uniform bits
    come from splitmix64 over (key, counter), then Box-Muller maps uniform
    pairs to normals — no sequential generator state anywhere.
    """
    pids = np.asarray(page_ids, np.uint64).reshape(-1)
    vers = np.asarray(versions, np.uint64).reshape(-1)
    key = _mix64(
        (np.uint64(seed) << np.uint64(40)) ^ (pids << np.uint64(20)) ^ vers
    )
    ctr = np.arange(2 * dim, dtype=np.uint64) * np.uint64(0x9E3779B97F4A7C15)
    h = _mix64(key[:, None] ^ ctr[None, :])
    u = (h >> np.uint64(11)).astype(np.float64) * (2.0 ** -53)
    u1 = np.maximum(u[:, :dim], 2.0 ** -53)  # log(0) guard
    u2 = u[:, dim:]
    rows = np.sqrt(-2.0 * np.log(u1)) * np.cos(2.0 * np.pi * u2)
    return rows.astype(np.float32)


def _slot_put(dst, src, slot_idx):
    """Write a batch-1 cache leaf into slot ``slot_idx`` of a batched leaf.
    Batch axis differs per leaf family: 1-D leaves (lengths) carry batch on
    axis 0, everything else on axis 1."""
    if dst.ndim == 1:
        return dst.at[slot_idx].set(src[0])
    return dst.at[:, slot_idx].set(src[:, 0])


def _slot_zero(leaf, slot_idx):
    """Zero one slot of a batched cache leaf (same axis rule as _slot_put).
    Chunked admission starts prefill from an empty slot — KV lengths reset
    to 0 and recurrent state cleared — without allocating a fresh cache."""
    if leaf.ndim == 1:
        return leaf.at[slot_idx].set(jnp.zeros((), leaf.dtype))
    return leaf.at[:, slot_idx].set(jnp.zeros((), leaf.dtype))


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 4
    max_len: int = 256
    page_size: int = 16
    n_pages: int = 1024
    near_frac: float = 0.30
    predictor: str = "nextline"
    prefetch_buffer: int = 64
    placement_window: int = 16  # engine steps per TPP epoch
    trace_window: int = 8
    trace_period: int = 64
    # device-executed tiering: route KV page reads through the fused
    # tiered-gather kernel over a device-resident near/far store
    device_tiering: bool = dataclasses.field(default_factory=_env_device_tiering)
    # one segmented dispatch per step (the default) vs the retired
    # one-dispatch-per-slot path, kept as the dispatch-budget baseline
    segmented_lookup: bool = True
    # snap payload rows to the int8 grid so the far tier is lossless —
    # the "quantization error zeroed" mode of the equivalence oracle
    tiered_identity_scales: bool = False
    # differential probe: compare every tiered read against the flat
    # buffer in-line (tracks the max divergence in stats())
    tiered_verify: bool = False
    # trace-driven far-tier prefetch: at every placement-window boundary,
    # chase each active stream's predictor chain and PROMOTE predicted
    # far pages into the near tier (batched dequant migration through the
    # device store, charged to the migration books) ahead of the decode
    # steps that will read them. Off by default: promotion perturbs the
    # near set, and the lockstep/bit-exact equivalence oracles pin the
    # unperturbed baseline.
    prefetch_promote: bool = False
    # how many predicted transitions ahead of each stream's head to chase
    prefetch_lookahead: int = 4
    # cap on promoted pages per issue window (bounds wasted bandwidth)
    prefetch_max_promote: int = 32
    # tensor-sharding degree of one logical replica: parameters and KV
    # pages partition over the `model` axis of a serving mesh, each shard
    # owning a per-shard TieredKVCache slice (runtime/sharded.py). 1 =
    # today's unsharded engine; ShardedServingEngine consumes this.
    model_shards: int = 1
    # continuous batching: prefill-chunk token budget per engine step.
    # 0 = infinite budget (the legacy whole-slot path: the whole prompt
    # prefills at admit through api.prefill). Positive values split every
    # prompt into <=prefill_chunk-token chunks interleaved with decode
    # inside the same single dispatch (CHUNKABLE_FAMILIES only).
    prefill_chunk: int = 0


@dataclasses.dataclass
class _Slot:
    seq_id: int = -1
    remaining: int = 0
    request: Optional[Request] = None
    # flight-recorder bookkeeping: when this request entered the slot
    # (virtual time + engine step), so retirement can emit one decode span
    # labeled with its whole step range
    t_admit: float = 0.0
    start_step: int = 0
    # chunked prefill: non-None while the slot is still feeding its prompt
    # (cleared the step the final prompt token lands and the first
    # generated token is emitted)
    chunk: Optional[ChunkState] = None
    chunks_done: int = 0  # prefill chunks this occupancy has dispatched
    shared_pages: int = 0  # prefix pages shared at admit (span labeling)
    decode_assigned: int = 0  # decode budget granted at admit (abort books)

    @property
    def active(self) -> bool:
        return self.seq_id >= 0

    @property
    def prefilling(self) -> bool:
        return self.active and self.chunk is not None


class ServingEngine:
    def __init__(
        self,
        api: ModelAPI,
        params,
        ecfg: EngineConfig,
        seed: int = 0,
        recorder=None,
    ):
        self.api = api
        self.cfg = api.cfg
        self.ecfg = ecfg
        self.params = params
        e = ecfg
        self.pagetable = SharedKVPageTable(e.n_pages, e.page_size)
        self.placement = TieredPlacement(
            e.n_pages,
            near_capacity=max(1, int(e.near_frac * e.n_pages)),
            block_bytes=self._page_bytes(),
        )
        # pages start in the far tier until placement promotes them
        self.placement.tier[:] = 1
        self.placement.tier[: self.placement.near_capacity] = 0
        self.prefetch = PrefetchEngine(e.predictor, e.prefetch_buffer)
        self.profiler = AccessProfiler(e.n_pages, self._page_bytes(), window_len=e.placement_window)
        self.tracer = MemTracer(e.trace_window, e.trace_period)
        self.slots = [_Slot() for _ in range(e.max_batch)]
        self.cache = api.init_cache(e.max_batch, e.max_len)
        # deque, not list: _admit pops the head every step, and a list's
        # pop(0) makes admission O(n^2) under backlog
        self.queue: Deque[Request] = deque()
        self.finished: List[int] = []
        self.engine_steps = 0
        # unified metrics plane: the legacy totals below are now registry
        # counters (exposed as properties for compatibility), so per-replica
        # registries merge into the fleet view bit-identically to the old
        # fleet_stats sums. A fleet wires host_rid + now_fn (via Replica);
        # standalone engines label replica=-1 and use engine steps as time.
        self.metrics = MetricsRegistry()
        self._m_tokens = self.metrics.counter("tokens_decoded")
        self._m_finished = self.metrics.counter("requests_finished")
        self._m_prefill = self.metrics.counter("prefill_tokens")
        self._m_prefill_saved = self.metrics.counter("prefill_tokens_saved")
        # delta-tracking for books owned elsewhere (placement stats, device
        # store host books): synced at drain boundaries ONLY, so the decode
        # hot path never touches the registry and the drain-cadence
        # invariant extends to every mirrored series
        self._book_seen: Dict[str, int] = {}
        self.recorder = recorder if recorder is not None else default_recorder()
        if self.recorder is not None:
            self.recorder.register(self.metrics)
        # set by the fleet: replica id for span tracks, and the shared
        # virtual clock (None -> engine steps stand in for time)
        self.host_rid = -1
        self.now_fn: Optional[Callable[[], float]] = None
        # per-tenant accounting: profiler streams are "kv.<tenant>", tier
        # hits split near/far so fleet reports can expose cross-tenant
        # interference on the shared far tier. Values are registry Counter
        # objects labeled tenant=<name> (read with .value).
        self.tenant_stats: Dict[str, Dict[str, "Counter"]] = {}
        # tenant name -> dense index into the device counter plane; stable
        # for the engine's lifetime so drained rows always map back
        self._tenant_index: Dict[str, int] = {}
        # seq id (rid) -> tenant name for every request this engine ever
        # admitted: trace-window streams ARE seq ids, so this map is what
        # lets successor training partition transitions per tenant (and is
        # exported in ReplicaProfile.stream_tenants for the fleet pool)
        self._seq_tenant: Dict[int, str] = {}
        # device-resident decode feedback: the fused decode writes the next
        # tokens here and reads them back next step without a host round-trip
        self.next_tokens = jnp.zeros((e.max_batch,), jnp.int32)
        # fleet hooks: called with (page_ids, is_write) for every accounted
        # block access — replicas attach live counters (CacheSim) here
        self.access_hooks: List[Callable] = []
        # when True, a fleet-level planner owns placement (apply_placement);
        # the local TPP epoch is suppressed so the two don't fight
        self.external_placement = False
        # degraded far-tier-only mode: the near tier is capacity-zeroed at
        # runtime (enter_degraded). Placement planning, prefetch promotion
        # and external pushes are all suspended; lookups keep flowing
        # through the same single segmented dispatch, every read a far hit.
        self.degraded = False
        # epoch fence for apply_placement: plans stamped with an epoch at
        # or below the fence predate a failover/degrade transition and are
        # rejected as stale instead of resurrecting a dead tier view
        self._placement_fence = 0
        # engine step of the last counter-plane drain — what lost_window()
        # uses to size the undrained remainder a crash leaves behind
        self._last_drain_step = 0
        # virtual-time cost of one engine step for the fleet's event
        # scheduler; replace to model batch- or far-traffic-dependent step
        # latency. Must stay constant at 1.0 for lockstep-exact replays.
        self.step_cost_fn: Optional[Callable[["ServingEngine"], float]] = None
        # host-visible fraction of this step's KV page reads that hit the
        # far tier (computed from the host tier map — no device sync).
        # step_cost_fn hooks price steps with it: far reads stall the step.
        self.last_step_far_frac = 0.0
        self._m_pf_promoted = self.metrics.counter("prefetch_promoted_pages")
        # model-dispatch books (satellite of the 1-dispatch/step budget):
        # model_dispatches counts every model executable launched — the
        # fused decode/chunk step AND any monolithic api.prefill pass the
        # whole-slot path pays per admit; prefill_dispatches counts just
        # the latter, so test_dispatch_budget can pin "chunked = exactly
        # one model dispatch per step, prefill folded in".
        self.model_dispatches = 0
        self.prefill_dispatches = 0
        # time-to-first-token: stamped at submit(), recorded the moment a
        # request's first generated token exists (admit-time under the
        # whole-slot path; the prompt-completing chunk step under chunked
        # prefill). Virtual-time samples feed the per-tenant "ttft"
        # histogram + the pinning test; wall-clock samples feed the
        # offered-load benchmark cells.
        self._enq_vt: Dict[int, float] = {}
        self._enq_wall: Dict[int, float] = {}
        self.ttft_vt_samples: List[float] = []
        self.ttft_wall_samples: List[float] = []
        # per-role (decode, prefill) x (near, far) tier hits drained from
        # the device counter plane's role accumulator
        self.role_hits = np.zeros((N_ROLES, 2), np.int64)
        # per-slot (start, end) prompt intervals of the chunk step in
        # flight, set by step() before the dispatch and consumed by
        # _account_decode + the post-step bookkeeping
        self._step_chunks: Dict[int, Tuple[int, int]] = {}
        # chunked prefill is gated per family (see CHUNKABLE_FAMILIES)
        self.chunking = e.prefill_chunk > 0 and api.family in CHUNKABLE_FAMILIES
        # one jitted decode shared by every engine on the same ModelAPI
        # (a replica fleet compiles once, not once per replica). The
        # next-token argmax is fused in and the cache buffers are donated,
        # so a steady-state step launches one executable and allocates
        # nothing new for the cache.
        if not hasattr(api, "_jit_decode"):
            serve = make_serve_step(api, vocab=self.cfg.vocab_size)

            def _decode_step(params, cache, tokens):
                nxt, cache = serve(params, cache, tokens)
                return nxt[:, 0], cache

            api._jit_decode = jax.jit(_decode_step, donate_argnums=(1,))
        self._decode = api._jit_decode
        # the continuous-batching step: a masked scan over the chunk's
        # token columns through the same family decode step — ONE jitted
        # dispatch covers every prefill chunk and decode token of the step.
        # Per column, prompt rows take their chunk token, decode rows take
        # the fed-back next token; inactive rows keep their cache via a
        # per-leaf where (batch axis 0 for 1-D leaves, else axis 1 — the
        # same convention _write_slot relies on). ``emit`` marks the column
        # whose argmax is a row's next fed token: column 0 for decode rows,
        # the final-prompt-token column for a prompt that completes this
        # step (its first generated token).
        if not hasattr(api, "_jit_chunk_decode"):
            chunk_serve = make_serve_step(api, vocab=self.cfg.vocab_size)

            def _chunk_step(params, cache, nxt, tok, use_prompt, active, emit):
                def col(carry, xs):
                    cache, nxt = carry
                    tok_c, up_c, act_c, em_c = xs
                    t = jnp.where(up_c, tok_c, nxt)
                    out, new_cache = chunk_serve(params, cache, t[:, None])

                    def gate(new, old):
                        if new.ndim == 1:
                            return jnp.where(act_c, new, old)
                        m = act_c.reshape((1, -1) + (1,) * (new.ndim - 2))
                        return jnp.where(m, new, old)

                    cache = jax.tree.map(gate, new_cache, cache)
                    nxt = jnp.where(em_c, out[:, 0], nxt)
                    return (cache, nxt), None

                (cache, nxt), _ = jax.lax.scan(
                    col, (cache, nxt), (tok.T, use_prompt.T, active.T, emit.T)
                )
                return nxt, cache

            api._jit_chunk_decode = jax.jit(_chunk_step, donate_argnums=(1,))
        self._chunk_decode = api._jit_chunk_decode
        # slot-buffer donation across join/leave churn: the batched cache
        # is threaded through jitted, donated updates — the whole-slot
        # path's prefill copy-in and the chunked path's zero-reset both
        # reuse the existing buffers instead of allocating per admit.
        if not hasattr(api, "_jit_write_slot"):

            def _write_slot_fn(dst, src, slot_idx):
                return jax.tree.map(
                    lambda d, s: _slot_put(d, s, slot_idx), dst, src
                )

            api._jit_write_slot = jax.jit(_write_slot_fn, donate_argnums=(0,))
        self._write_slot_jit = api._jit_write_slot
        if not hasattr(api, "_jit_reset_slot"):

            def _reset_slot_fn(cache, slot_idx):
                return jax.tree.map(lambda c: _slot_zero(c, slot_idx), cache)

            api._jit_reset_slot = jax.jit(_reset_slot_fn, donate_argnums=(0,))
        self._reset_slot_jit = api._jit_reset_slot
        self._rng = np.random.default_rng(seed)
        self._seed = seed
        # device-executed tiering: a device-resident near/far store whose
        # tier map mirrors placement.tier and whose fused-kernel lookups
        # produce the tier-hit counters
        self.tiered: Optional[TieredKVCache] = None
        self.tiered_max_err = 0.0  # max tiered-vs-flat read divergence seen
        self._page_wver = None  # per-page write version (fallback payloads)
        if e.device_tiering:
            self.tiered = self._make_tiered_store()
            self._page_wver = np.zeros(e.n_pages, np.int64)
            # initial fill: position the starting near set without charging
            # it to the migration books (nothing has been written yet)
            self.tiered.migrate(self.placement.near_blocks(), account=False)

    def _make_tiered_store(self):
        """Build the device-resident tiered store. Overridable seam: the
        sharded engine returns a per-shard facade here; everything else in
        the engine talks to the store through the same interface."""
        e = self.ecfg
        return TieredKVCache(
            e.n_pages,
            self._payload_dim(),
            self.placement.near_capacity,
            identity_scales=e.tiered_identity_scales,
            counter_slots=e.max_batch,
        )

    # ------------------------------------------------------------------
    # legacy counter facade over the metrics registry (same ints, one store)

    @property
    def tokens_decoded(self) -> int:
        return self._m_tokens.value

    @property
    def prefill_tokens(self) -> int:
        return self._m_prefill.value

    @property
    def prefill_tokens_saved(self) -> int:
        return self._m_prefill_saved.value

    def now(self) -> float:
        """Virtual time if a fleet clock is attached, else engine steps."""
        return float(self.now_fn()) if self.now_fn is not None else float(self.engine_steps)

    # ------------------------------------------------------------------
    def _page_bytes(self) -> int:
        """Bytes of one logical KV page across all layers (k+v, bf16)."""
        c = self.cfg
        n_layers = getattr(c, "n_layers", 1)
        return self.ecfg.page_size * 2 * c.n_kv_heads * c.head_dim * 2 * n_layers

    # ------------------------------------------------------------------
    # device-tier payload plumbing

    def _dense_kv(self, cache) -> Optional[jnp.ndarray]:
        """The (L, B, H, S, D) k-cache when this family exposes one."""
        k = cache.get("k") if isinstance(cache, dict) else None
        return k if k is not None and getattr(k, "ndim", 0) == 5 else None

    def _payload_dim(self) -> int:
        k = self._dense_kv(self.cache)
        if k is not None:
            n_layers, _, n_heads, _, head_dim = k.shape
            return 2 * n_layers * n_heads * head_dim
        return 128  # recurrent-state families: synthetic payload rows

    def _payload_rows(self, cache, batch_idxs, positions, page_ids) -> jnp.ndarray:
        """Per-page payload rows for the device tier store (one batched
        gather for any number of (slot, position) pairs).

        For KV families the row is the real decode data: the k and v vectors
        of the page's most recently written token, flattened across layers
        and heads. Recurrent-state families (no per-position KV) fall back
        to deterministic rows keyed by (page, write-version) — the memory
        system behavior (gathers, quantization, migration) is identical, only
        the payload values are synthetic.
        """
        k = self._dense_kv(cache)
        if k is not None:
            bi = jnp.asarray(batch_idxs, jnp.int32)
            pos = jnp.asarray(positions, jnp.int32)
            # advanced indices (batch, seq-pos) broadcast together and land
            # in front: (n, L, H, Dh) per store
            kk = k[:, bi, :, pos, :]
            vv = cache["v"][:, bi, :, pos, :]
            kv = jnp.concatenate([kk, vv], axis=1)  # (n, 2L, H, Dh)
            return kv.reshape(len(positions), -1).astype(jnp.float32)
        pids = np.asarray(page_ids, np.int64)
        return jnp.asarray(
            counter_rows(self._seed, pids, self._page_wver[pids], self.tiered.row_dim)
        )

    def _tiered_write(self, cache, batch_idxs, positions, page_ids):
        if self.tiered is None or not len(page_ids):
            return
        rows = self._payload_rows(cache, batch_idxs, positions, page_ids)
        self.tiered.write(np.asarray(page_ids, np.int64), rows)
        self._page_wver[np.asarray(page_ids, np.int64)] += 1

    def _sync_device_tiers(self):
        """Mirror placement.tier into the device store (real data movement)."""
        if self.tiered is not None:
            self.tiered.migrate(self.placement.near_blocks())

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        # stamp arrival so TTFT covers queue wait, not just slot residency
        self._enq_vt[req.rid] = self.now()
        self._enq_wall[req.rid] = time.perf_counter()
        self.queue.append(req)

    def _record_ttft(self, req: Request):
        """First generated token exists for ``req`` — close its TTFT."""
        t = self.now()
        vt = t - self._enq_vt.pop(req.rid, t)
        self.ttft_vt_samples.append(vt)
        self.metrics.histogram("ttft", tenant=req.tenant).record(vt)
        wall = self._enq_wall.pop(req.rid, None)
        if wall is not None:
            self.ttft_wall_samples.append(time.perf_counter() - wall)

    def _admit_common(self, slot_idx: int, slot: _Slot, req: Request):
        """Slot bookkeeping shared by both admission paths. Returns the
        (truncated) prompt and the pagetable share record."""
        budget = max(1, self.ecfg.max_len - 2)
        tokens = req.tokens[:budget]
        decode_len = max(1, min(req.decode_len, self.ecfg.max_len - len(tokens) - 1))
        share = self.pagetable.add_sequence(req.rid, tokens)
        self._m_prefill.inc(len(tokens))
        self._m_prefill_saved.inc(share["shared"] * self.ecfg.page_size)
        slot.seq_id = req.rid
        slot.remaining = decode_len
        slot.decode_assigned = decode_len
        slot.request = req
        slot.t_admit = self.now()
        slot.start_step = self.engine_steps
        slot.chunk = None
        slot.chunks_done = 0
        self._tenant(req.tenant)  # register the tenant counter index
        self._seq_tenant[req.rid] = req.tenant
        # the prefetch buffer is partitioned per tenant: this stream's
        # pending prefetches charge (and evict within) its tenant's share
        self.prefetch.set_stream_partition(req.rid, req.tenant)
        return tokens, share

    def _admit(self):
        """Fill freed slots from the queue — called at the top of EVERY
        step, so admission is continuous, not between-generations.

        Whole-slot path (``prefill_chunk == 0`` or a non-chunkable family):
        the prompt prefills monolithically through ``api.prefill`` — one
        extra model dispatch per admit, charged to ``prefill_dispatches``.
        Chunked path: admission only maps pages, zero-resets the slot's
        cache rows (jitted, donated — no allocation), and arms a
        ChunkState; the prompt tokens flow through the shared chunk-scan
        dispatch of subsequent steps.
        """
        for slot_idx, slot in enumerate(self.slots):
            if slot.active or not self.queue:
                continue
            req = self.queue.popleft()
            if self.chunking:
                tokens, share = self._admit_common(slot_idx, slot, req)
                self.cache = self._reset_slot_jit(
                    self.cache, jnp.int32(slot_idx)
                )
                slot.chunk = ChunkState(tokens=tokens)
                slot.shared_pages = share["shared"]
                continue
            tokens, share = self._admit_common(slot_idx, slot, req)
            # run the model prefill for this request into its slot — a
            # whole extra model dispatch outside the step's fused decode
            # (what the chunked path folds away), counted honestly
            batch = self._prefill_batch(tokens)
            logits1, cache1 = self.api.prefill(self.params, batch, max_len=self.ecfg.max_len)
            self.model_dispatches += 1
            self.prefill_dispatches += 1
            self._write_slot(slot_idx, cache1, len(tokens))
            if self.tiered is not None:
                # seed the device tier store with this sequence's page
                # payloads (each page keyed by its last prefilled token)
                pages = self.pagetable.seqs[req.rid]
                ps = self.ecfg.page_size
                positions = [
                    min((i + 1) * ps, len(tokens)) - 1 for i in range(len(pages))
                ]
                self._tiered_write(self.cache, [slot_idx] * len(pages), positions, pages)
            nxt = int(jnp.argmax(logits1[0, -1, : self.cfg.vocab_size]))
            self.next_tokens = self.next_tokens.at[slot_idx].set(nxt)
            self._record_ttft(req)
            if self.recorder is not None:
                # prefill is one batched pass at admit time: a zero-length
                # span on the request's track, sized by its args
                self.recorder.span(
                    "prefill",
                    req.rid,
                    slot.t_admit,
                    slot.t_admit,
                    tenant=req.tenant,
                    replica=self.host_rid,
                    prompt_tokens=len(tokens),
                    shared_pages=share["shared"],
                )

    def _prefill_batch(self, tokens: np.ndarray) -> dict:
        t = jnp.asarray(tokens, jnp.int32)[None, :]
        fam = self.api.family
        if fam == "vlm":
            emb = jnp.take(self.params["embed"], t, axis=0)
            pos = jnp.broadcast_to(jnp.arange(t.shape[1], dtype=jnp.int32), (3, 1, t.shape[1]))
            return {"embeds": emb, "mrope_positions": pos}
        if fam == "audio":
            frames = jnp.zeros((1, self.cfg.n_audio_frames, self.cfg.d_model), jnp.bfloat16)
            return {"tokens": t, "frames": frames}
        return {"tokens": t}

    def _write_slot(self, slot_idx: int, cache1: dict, length: int):
        """Copy a batch-1 prefill cache into slot ``slot_idx`` of the batched
        cache. Batch axis differs per leaf family (kv: axis 1; lengths:
        axis 0 — the _slot_put convention). Runs through the jitted,
        donated slot writer: the batched cache buffers are reused in place
        across join/leave churn, and because ``api.prefill`` pads to
        ``max_len`` the source shapes are fixed, so this compiles once per
        family rather than once per prompt length."""
        self.cache = self._write_slot_jit(self.cache, cache1, jnp.int32(slot_idx))

    def _chunk_plan(self):
        """Column plan for one continuous-batching step: (B, C) token ids
        plus the use-prompt / active / emit masks the chunk scan consumes,
        and the per-slot ``(start, end)`` prompt intervals this dispatch
        advances. Decode slots occupy column 0 only; each prefilling slot
        takes up to ``prefill_chunk`` prompt tokens and emits (captures its
        first generated token) only in the column that consumes its final
        prompt token."""
        e = self.ecfg
        C = e.prefill_chunk
        B = e.max_batch
        tok = np.zeros((B, C), np.int32)
        use_prompt = np.zeros((B, C), bool)
        active = np.zeros((B, C), bool)
        emit = np.zeros((B, C), bool)
        spans: Dict[int, Tuple[int, int]] = {}
        for i, s in enumerate(self.slots):
            if not s.active:
                continue
            if s.prefilling:
                c = s.chunk.take(C)
                n = len(c)
                tok[i, :n] = c
                use_prompt[i, :n] = True
                active[i, :n] = True
                emit[i, n - 1] = s.chunk.pos + n >= s.chunk.total
                spans[i] = (s.chunk.pos, s.chunk.pos + n)
            else:
                active[i, 0] = True
                emit[i, 0] = True
        return tok, use_prompt, active, emit, spans

    # ------------------------------------------------------------------
    def _tenant(self, name: str) -> Dict[str, Counter]:
        if name not in self.tenant_stats:
            self.tenant_stats[name] = {
                "tokens_decoded": self.metrics.counter("tenant_tokens_decoded", tenant=name),
                "requests_finished": self.metrics.counter("tenant_requests_finished", tenant=name),
                "near_hits": self.metrics.counter("tenant_near_hits", tenant=name),
                "far_hits": self.metrics.counter("tenant_far_hits", tenant=name),
            }
        self._tenant_index.setdefault(name, len(self._tenant_index))
        return self.tenant_stats[name]

    def _sync_registry_books(self):
        """Mirror externally-owned books into the registry by delta.

        Placement stats (near/far hits, promotions, demotions, migrated
        bytes) and the device store's host books (moved rows/bytes, writes,
        dispatches, host syncs, drains) are charged by code that predates
        the registry; rather than instrument every charge site — and risk a
        hot-path cost — this syncs their *deltas* at drain boundaries. Pure
        int sums, so registry totals are bit-identical at any drain cadence.
        """

        def charge(name: str, current: int):
            seen = self._book_seen.get(name, 0)
            if current != seen:
                self.metrics.counter(name).inc(current - seen)
                self._book_seen[name] = current

        st = self.placement.stats
        charge("near_hits", st.near_hits)
        charge("far_hits", st.far_hits)
        charge("promotions", st.promotions)
        charge("demotions", st.demotions)
        charge("migrated_bytes", st.migrated_bytes)
        # prefetch books are monotone counters, so the delta-sync gives the
        # registry the same totals at any drain cadence; wasted bytes =
        # unused evictions priced at the page size the migrations pay
        pf = self.prefetch.stats
        charge("prefetch_issued_pages", pf.total_prefetched)
        charge("prefetch_used_pages", pf.used_prefetches)
        charge("prefetch_unused_evicted_pages", pf.unused_evicted)
        charge("prefetch_demand_fetches", pf.demand_fetches)
        charge(
            "prefetch_wasted_bytes", pf.unused_evicted * self.placement.block_bytes
        )
        if self.tiered is not None:
            tk = self.tiered
            charge("kv_moved_rows", tk.moved_rows)
            charge("kv_moved_bytes", tk.moved_bytes)
            charge("kv_writes", tk.writes)
            charge("kv_dispatches", tk.dispatches)
            charge("kv_host_syncs", tk.host_syncs)
            charge("kv_drains", tk.drains)

    def drain_tier_counters(self) -> Optional[dict]:
        """Drain the device counter plane and charge the host books.

        The ONE host sync of the tiered decode path, called once per
        profiler window (and at stats/export boundaries). Placement stats
        get the totals, tenant books their per-tenant-index rows — sums of
        the same per-page tier bits the per-step path charged, so the books
        are bit-identical at every drain boundary regardless of cadence.
        The metrics registry is synced here too (and ONLY here or at other
        drain boundaries), so every registry series inherits the invariant.
        """
        d = None
        self._last_drain_step = self.engine_steps
        if self.tiered is not None:
            d = self.tiered.drain_counters()
            if d["near"] or d["far"]:
                self.placement.stats.near_hits += d["near"]
                self.placement.stats.far_hits += d["far"]
                # per-role (decode/prefill) x (near/far) split: pure sums
                # of the same hits, so the drain-cadence invariant holds
                self.role_hits += np.asarray(d["role"], np.int64)
                tenant_rows = d["tenant"]
                for name, idx in self._tenant_index.items():
                    if idx < len(tenant_rows):
                        n, f = int(tenant_rows[idx][0]), int(tenant_rows[idx][1])
                        if n or f:
                            ts = self._tenant(name)
                            ts["near_hits"].inc(n)
                            ts["far_hits"].inc(f)
        self._sync_registry_books()
        return d

    def _account_decode(self):
        """Per decode step: every active sequence touches all its KV pages
        (attention reads the whole cache) — that stream drives placement,
        prefetch, the profiler and the tracer.

        In device-tiering mode the read is EXECUTED, not modeled: ALL
        active slots' page ids go through ONE segmented tiered-gather
        dispatch, and the per-slot near/far hit counts accumulate into the
        store's device counter plane — no host sync here; the engine
        drains the plane once per profiler window.

        Under chunked prefill a prefilling slot's walk is truncated to the
        pages whose KV content exists after this step's chunk (attention
        masks the rest), and its segment carries ROLE_PREFILL into the
        counter plane's role accumulator — the mixed prefill/decode
        dispatch stays ONE kernel pass, roles ride alongside the segment
        index exactly like tenant rows do."""
        segs = []
        for slot_idx, slot in enumerate(self.slots):
            if not slot.active:
                continue
            pages_all = self.pagetable.seqs[slot.seq_id]
            role = ROLE_DECODE
            if slot.prefilling and slot_idx in self._step_chunks:
                end = self._step_chunks[slot_idx][1]
                n_pages = -(-end // self.ecfg.page_size)
                pages = np.array(pages_all[:n_pages], np.int64)
                role = ROLE_PREFILL
            else:
                pages = np.array(pages_all, np.int64)
            if pages.size:
                segs.append((slot_idx, slot, pages, role))
        if not segs:
            return
        segmented = self.tiered is not None and self.ecfg.segmented_lookup
        if segmented:
            ids = np.concatenate([p for _, _, p, _ in segs])
            seg_of = np.repeat(
                np.arange(len(segs), dtype=np.int32),
                [p.size for _, _, p, _ in segs],
            )
            rows = self.tiered.lookup_segments(
                ids,
                seg_of,
                self.ecfg.max_batch + 1,  # last segment absorbs the padding
                slot_idx=[i for i, _, _, _ in segs],
                tenant_idx=[
                    self._tenant_index[s.request.tenant] for _, s, _, _ in segs
                ],
                role_idx=[r for _, _, _, r in segs],
            )
            if self.ecfg.tiered_verify:
                err = float(jnp.max(jnp.abs(rows - self.tiered.lookup_flat(ids))))
                self.tiered_max_err = max(self.tiered_max_err, err)
        far_total = n_total = 0
        for slot_idx, slot, pages, _role in segs:
            far = self.placement.tier[pages] == 1
            far_total += int(far.sum())
            n_total += pages.size
            if segmented:
                pass  # hits live in the device plane until the window drain
            else:
                if self.tiered is not None:
                    rows, near_n, far_n = self.tiered.lookup(pages)
                    self.placement.stats.near_hits += near_n
                    self.placement.stats.far_hits += far_n
                    if self.ecfg.tiered_verify:
                        err = float(
                            jnp.max(jnp.abs(rows - self.tiered.lookup_flat(pages)))
                        )
                        self.tiered_max_err = max(self.tiered_max_err, err)
                else:
                    self.placement.access(pages)
                    near_n = int((~far).sum())
                    far_n = int(far.sum())
                ts = self._tenant(slot.request.tenant)
                ts["near_hits"].inc(near_n)
                ts["far_hits"].inc(far_n)
            # stream = the sequence id: each request's page walk is its own
            # stream, so the predictor never learns cross-slot transitions
            self.prefetch.access_many(pages, far, stream=slot.seq_id)
            self.profiler.record("kv", pages)
            self.tracer.record(pages, is_write=False, stream=slot.seq_id)
            self.profiler.record(f"kv.{slot.request.tenant}", pages)
            for hook in self.access_hooks:
                hook(pages, False)
        self.last_step_far_frac = far_total / n_total if n_total else 0.0

    def _finish_chunk(self, slot_idx: int, slot: _Slot):
        """Post-dispatch bookkeeping for one prefilling slot: advance the
        chunk cursor, push the prompt pages this chunk completed through
        the tiered write path (each page keyed by its last prefilled
        token, exactly as the whole-slot admit seeds them), and — when
        the final prompt token just landed — close TTFT: the emit column
        captured the request's first generated token into next_tokens."""
        start, end = self._step_chunks[slot_idx]
        slot.chunk.pos = end
        slot.chunks_done += 1
        if self.tiered is not None:
            pages = self.pagetable.seqs[slot.seq_id]
            ps = self.ecfg.page_size
            total = slot.chunk.total
            w_pages: List[int] = []
            w_pos: List[int] = []
            for i, pid in enumerate(pages):
                endpos = min((i + 1) * ps, total)
                if start < endpos <= end:
                    w_pages.append(pid)
                    w_pos.append(endpos - 1)
            if w_pages:
                self._tiered_write(
                    self.cache, [slot_idx] * len(w_pages), w_pos, w_pages
                )
        t = self.now()
        if self.recorder is not None:
            self.recorder.span(
                "prefill_chunk",
                slot.seq_id,
                t,
                t,
                tenant=slot.request.tenant,
                replica=self.host_rid,
                tokens=end - start,
                chunk=slot.chunks_done,
            )
        if slot.chunk.done:
            prompt_tokens = slot.chunk.total
            slot.chunk = None
            self._record_ttft(slot.request)
            if self.recorder is not None:
                self.recorder.span(
                    "prefill",
                    slot.seq_id,
                    slot.t_admit,
                    t,
                    tenant=slot.request.tenant,
                    replica=self.host_rid,
                    prompt_tokens=prompt_tokens,
                    chunks=slot.chunks_done,
                    shared_pages=slot.shared_pages,
                )

    def step(self) -> int:
        """One engine iteration: admit -> decode -> account -> retire.

        Continuous batching: ``_admit`` runs at the top of EVERY step, so
        freed slots refill immediately. When any slot is mid-prefill the
        step dispatches the chunk scan — prefill chunks and decode tokens
        share ONE jitted executable (and one segmented tiered-gather pass
        in ``_account_decode``); steady-state decode-only steps take the
        plain fused (B, 1) decode. Either way: one model dispatch, zero
        mandatory host syncs.

        Returns number of tokens decoded this step.
        """
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s.active]
        if not active:
            return 0
        if any(s.prefilling for s in self.slots):
            tok, use_prompt, act, emit, spans = self._chunk_plan()
            self._step_chunks = spans
            self.next_tokens, self.cache = self._chunk_decode(
                self.params,
                self.cache,
                self.next_tokens,
                jnp.asarray(tok),
                jnp.asarray(use_prompt),
                jnp.asarray(act),
                jnp.asarray(emit),
            )
        else:
            self._step_chunks = {}
            # one fused dispatch: decode + next-token argmax, cache donated —
            # tokens and cache stay on device, nothing reads back to host
            self.next_tokens, self.cache = self._decode(
                self.params, self.cache, self.next_tokens[:, None]
            )
        self.model_dispatches += 1
        self._account_decode()
        decoded = 0
        written: List[int] = []
        written_tenant: List[str] = []
        written_slot: List[int] = []
        written_pos: List[int] = []
        written_seq: List[int] = []
        for slot_idx, slot in enumerate(self.slots):
            if not slot.active:
                continue
            if slot.prefilling:
                self._finish_chunk(slot_idx, slot)
                continue
            written.append(self.pagetable.append_token(slot.seq_id))
            written_tenant.append(slot.request.tenant)
            written_slot.append(slot_idx)
            written_pos.append(self.pagetable.seq_len[slot.seq_id] - 1)
            written_seq.append(slot.seq_id)
            slot.remaining -= 1
            decoded += 1
            ts = self._tenant(slot.request.tenant)
            ts["tokens_decoded"].inc()
            if slot.remaining <= 0:
                self.pagetable.free_sequence(slot.seq_id)
                self.finished.append(slot.seq_id)
                # retire the stream's predictor tail with it — the seq id
                # never recurs, and stale tails only cost memory
                self.prefetch.drop_stream(slot.seq_id)
                ts["requests_finished"].inc()
                if self.recorder is not None:
                    t1 = self.now()
                    self.recorder.span(
                        "decode",
                        slot.seq_id,
                        slot.t_admit,
                        t1,
                        tenant=slot.request.tenant,
                        replica=self.host_rid,
                        step_range=[slot.start_step, self.engine_steps],
                    )
                    self.recorder.instant(
                        "complete",
                        slot.seq_id,
                        t1,
                        tenant=slot.request.tenant,
                        replica=self.host_rid,
                    )
                self._m_finished.inc()
                slot.seq_id = -1
                slot.request = None
        if written:
            # the decoded token's KV write — gives the access stream a real
            # R:W mix (Table 6 validation compares read:write ratios)
            w = np.asarray(written, np.int64)
            if self.tiered is not None:
                # the write is executed on device too: every written page's
                # payload row lands in its current tier (quantized if far),
                # one batched scatter for the whole step
                self._tiered_write(self.cache, written_slot, written_pos, written)
            self.profiler.record("kv", w, rw="w")
            by_tenant: Dict[str, List[int]] = {}
            for page, tenant in zip(written, written_tenant):
                by_tenant.setdefault(tenant, []).append(page)
            for tenant, pages in by_tenant.items():
                self.profiler.record(f"kv.{tenant}", np.asarray(pages, np.int64), rw="w")
            self.tracer.record(w, is_write=True, stream=np.asarray(written_seq, np.int64))
            for hook in self.access_hooks:
                hook(w, True)
        self._m_tokens.inc(decoded)
        self.engine_steps += 1
        self.profiler.tick()
        self.tracer.tick()
        # profiler-window boundary: the ONE host sync of the tiered path —
        # drain the device counter plane into the host books, then run the
        # TPP epoch (skipped when a fleet planner drives placement)
        if self.engine_steps % self.ecfg.placement_window == 0:
            self.drain_tier_counters()
            # degraded mode suspends placement planning and prefetch
            # promotion — there is no near capacity to plan into — but the
            # boundary drain above still runs: far hits keep charging the
            # books at the same cadence, so degraded books stay exact
            if not self.external_placement and not self.degraded:
                wins = self.profiler.windows("kv")
                if wins:
                    self.placement.step(wins[-1])
                    self._sync_device_tiers()
            # trace-driven prefetch issue window: runs right after the
            # boundary drain, so its apply_placement-style migration sees a
            # clean counter plane and costs ZERO additional host syncs
            # (drain_counters early-returns while the plane is clean)
            if self.ecfg.prefetch_promote and not self.degraded:
                if self.prefetch.predictor == "trace":
                    # local training is tenant-partitioned like the fleet
                    # push: trace streams are seq ids, and _seq_tenant maps
                    # them back to the tenant whose table they train
                    self.prefetch.load_successors(
                        train_tenant_successors(
                            self.tracer.windows[-32:], self._seq_tenant
                        ),
                        merge=True,
                    )
                self._prefetch_window()
        return decoded

    def _prefetch_window(self) -> int:
        """Chase each predicted page chain and promote the predicted FAR
        pages into the near tier ahead of the decode steps that will read
        them (the paper's trace-driven prefetcher, acting).

        Candidates come from two predictions the placement counters cannot
        make: (a) each active walk's chain links and tail successors, and
        (b) the chains of QUEUED requests — a queued request's first full
        prefix page names its template via the pagetable chunk-hash, and
        the trained successor table chases the rest of the chain before a
        single count exists for it.

        Swaps are VALUE-ranked, not count-ranked: a page's value for the
        next window is the number of readers it will serve — active slots
        mapping it (pagetable ref) plus queued requests about to walk it.
        A far chain about to serve three admissions may evict a near page
        serving one; ties never churn. Among zero-value victims, pages
        deepest in the allocator's LIFO free list go first — the tail the
        allocator is about to pop would have started a fresh allocation in
        the near tier "for free".

        The swap goes through ``apply_placement``, so promotions are real
        far->near dequant copies charged to the migration books and the
        device-moved-bytes counters. Returns pages promoted.
        """
        e = self.ecfg
        preds: List[int] = []
        seen = set()
        upcoming: Dict[int, int] = {}  # page -> queued readers about to walk it
        part_of: Dict[int, str] = {}  # page -> tenant partition that predicted it
        for slot in self.slots:
            if not slot.active:
                continue
            tenant = slot.request.tenant
            pages = self.pagetable.seqs.get(slot.seq_id, [])
            if not pages:
                continue
            if slot.prefilling:
                # chunked prefill: the remaining chunk steps will read the
                # not-yet-prefilled tail of the mapped chain — count those
                # pages as upcoming readers so mid-prefill promotion is
                # amortized over the chunks instead of waiting for counts
                done = slot.chunk.pos // e.page_size
                for p in pages[done:]:
                    upcoming[p] = upcoming.get(p, 0) + 1
                    if p not in seen:
                        seen.add(p)
                        preds.append(p)
                        part_of[p] = tenant
            # the decode walk re-reads the WHOLE chain next step: chase one
            # predicted hop from every mapped page (promotes the far links
            # of a newly hot template chain the moment its head is seen),
            # then ``lookahead`` hops past the tail (the pages about to be
            # allocated and written)
            for src in pages:
                for p in self.prefetch.predict_chain(int(src), stream=slot.seq_id, lookahead=1):
                    if 0 <= p < e.n_pages and p not in seen:
                        seen.add(p)
                        preds.append(p)
                        part_of[p] = tenant
            for p in self.prefetch.predict_chain(
                int(pages[-1]), stream=slot.seq_id, lookahead=e.prefetch_lookahead
            ):
                if 0 <= p < e.n_pages and p not in seen:
                    seen.add(p)
                    preds.append(p)
                    part_of[p] = tenant
        ps = e.page_size
        for req in list(self.queue)[: e.max_batch]:
            if len(req.tokens) < ps:
                continue
            pid = self.pagetable.chains.get(
                self.pagetable._chain(0, req.tokens[:ps])
            )
            if pid is None or self.pagetable.pages[pid].ref <= 0:
                continue
            # chase the WHOLE template chain from the successor table, not
            # just prefetch_lookahead hops: a queued request's first full
            # prefix page names its template, and under chunked prefill the
            # promotion cost is amortized over the prefill chunk steps that
            # will read the chain page by page
            chain = [int(pid)] + self.prefetch.predict_chain(
                int(pid),
                stream=-1,
                lookahead=max(e.prefetch_lookahead, e.max_len // e.page_size),
                # a queued request has no live stream yet, but its tenant is
                # known: chase THAT tenant's table, never a neighbor's
                partition=req.tenant,
            )
            for p in chain:
                if not 0 <= p < e.n_pages:
                    continue
                upcoming[p] = upcoming.get(p, 0) + 1
                if p not in seen:
                    seen.add(p)
                    preds.append(p)
                    part_of[p] = req.tenant
        if not preds:
            return 0

        def value(p: int) -> int:
            # readers the page serves next window; pagetable refs are the
            # active mappers (retired sequences already dropped theirs)
            return self.pagetable.pages[p].ref + upcoming.get(p, 0)

        # stale successors may name pages the allocator has reclaimed —
        # promoting those wastes a migration on content about to be replaced
        cand = [p for p in preds if self.pagetable.pages[p].ref > 0]
        cand = [p for p in cand if self.placement.tier[p] == 1]
        if not cand:
            return 0
        cand.sort(key=value, reverse=True)
        near_ids = np.flatnonzero(self.placement.tier == 0)
        free_pos = {int(pid): i for i, pid in enumerate(self.pagetable.free)}
        victims = sorted(
            (int(b) for b in near_ids),
            key=lambda b: (value(b), free_pos.get(b, -1)),
        )
        promote: List[int] = []
        evict: List[int] = []
        for c, v in zip(cand, victims):
            if len(promote) >= e.prefetch_max_promote or value(c) <= value(v):
                break  # sorted both ways: no later pair can be profitable
            promote.append(c)
            evict.append(v)
        if not promote:
            return 0
        promote_a = np.asarray(promote, np.int64)
        evict_a = np.asarray(evict, np.int64)
        keep = np.setdiff1d(near_ids, evict_a, assume_unique=True)
        # demoted pages leave the buffer first (unused ones are waste) ...
        self.prefetch.evict(evict_a)
        self.apply_placement(np.concatenate([keep, promote_a]))
        # ... and promotions enter the books as prefetched-not-yet-used,
        # each charged to the tenant partition whose prediction named it
        self.prefetch.mark_prefetched(
            promote_a, partitions=[part_of.get(p, "") for p in promote]
        )
        self._m_pf_promoted.inc(len(promote))
        return len(promote)

    def run(self, gen: RequestGenerator, n_requests: int, max_steps: int = 10_000) -> dict:
        for _ in range(n_requests):
            self.submit(next(gen))
        steps = 0
        while (self.queue or any(s.active for s in self.slots)) and steps < max_steps:
            self.step()
            steps += 1
        return self.stats()

    # ------------------------------------------------------------------
    # fleet interface (fleet/replica.py wraps these)

    @property
    def load(self) -> int:
        """Backlog metric for routing: busy slots + queued requests."""
        return sum(1 for s in self.slots if s.active) + len(self.queue)

    def step_cost(self) -> float:
        """Virtual-time units one call to ``step`` costs (fleet scheduler).

        The default (1.0) makes engine steps the fleet's time unit; a
        ``step_cost_fn`` hook can price steps by live state instead.
        """
        if self.step_cost_fn is None:
            return 1.0
        cost = float(self.step_cost_fn(self))
        if cost <= 0.0:
            raise ValueError(f"step_cost_fn must return > 0, got {cost}")
        return cost

    def backlog_tokens(self, prefill_weight: float = 1.0) -> float:
        """Pending work in token-equivalents (admission's backlog estimate).

        ``prefill_weight`` discounts queued prompt tokens the same way the
        caller's SLO cost model does. Chunk-aware: a prefilling slot owes
        its REMAINING chunk tokens (weighted like queued prompt work — it
        occupies chunk columns, not admit-time passes), not the whole
        prompt, so AdmissionController.pressure and elastic scaling don't
        over-shed mid-prefill under chunked prefill.
        """
        q = sum(prefill_weight * len(r.tokens) + r.decode_len for r in self.queue)
        a = 0.0
        for s in self.slots:
            if not s.active:
                continue
            a += s.remaining
            if s.prefilling:
                a += prefill_weight * s.chunk.remaining
        return q + a

    def apply_placement(self, near_ids: np.ndarray, epoch: Optional[int] = None) -> int:
        """Push an externally-planned near-tier set (fleet autotier).

        Replaces the local TPP view wholesale; returns number of pages whose
        tier changed (the migration traffic this push costs).

        ``epoch`` is the planner's TierEpoch sequence number. A push whose
        epoch is at or below the engine's placement fence was planned from
        profiles gathered BEFORE a failover/degrade transition on this host
        — applying it would resurrect a tier view the failover invalidated
        — so it is rejected (counted, recorded, zero pages moved). Pushes
        while degraded are rejected the same way: there is no near
        capacity for the plan to land in.
        """
        # drain first: hits observed under the outgoing tier map are charged
        # before the map changes, so every epoch's books are exact
        self.drain_tier_counters()
        if epoch is not None and int(epoch) <= self._placement_fence:
            self.metrics.counter("placement_rejected", reason="stale_epoch").inc()
            if self.recorder is not None:
                self.recorder.instant(
                    "placement_rejected", -1, self.now(), replica=self.host_rid,
                    reason="stale_epoch", epoch=int(epoch), fence=self._placement_fence,
                )
            return 0
        if self.degraded:
            self.metrics.counter("placement_rejected", reason="degraded").inc()
            if self.recorder is not None:
                self.recorder.instant(
                    "placement_rejected", -1, self.now(), replica=self.host_rid,
                    reason="degraded",
                )
            return 0
        return self._apply_near_set(near_ids)

    def _apply_near_set(self, near_ids: np.ndarray) -> int:
        """Unconditional tier rewrite — the body ``apply_placement`` guards.
        ``enter_degraded`` calls this directly with the empty set (the
        demote-all transition must run even while the degraded flag is up).
        """
        # same sanitize rule as the device store, or the two tier views
        # diverge; dedup must precede the capacity cut so duplicate ids
        # neither double-count promotions nor shrink the near set
        near_ids = sanitize_near_ids(
            near_ids, self.ecfg.n_pages, self.placement.near_capacity
        )
        old = self.placement.tier.copy()
        self.placement.tier[:] = 1
        self.placement.tier[near_ids] = 0
        promoted = int((old[near_ids] == 1).sum())
        demoted = int(((old == 0) & (self.placement.tier == 1)).sum())
        st = self.placement.stats
        st.promotions += promoted
        st.demotions += demoted
        st.migrated_bytes += (promoted + demoted) * self.placement.block_bytes
        # device mode: the push is real data movement — promotions copy
        # far->near with dequantization, demotions quantize near->far
        self._sync_device_tiers()
        self._sync_registry_books()
        if self.recorder is not None and (promoted or demoted):
            t = self.now()
            self.recorder.span(
                "migrate",
                -1,
                t,
                t,
                replica=self.host_rid,
                promoted=promoted,
                demoted=demoted,
                bytes=(promoted + demoted) * self.placement.block_bytes,
            )
        return promoted + demoted

    # ------------------------------------------------------------------
    # failure machinery: degraded mode, epoch fencing, abort/strand books

    def fence_placement(self, epoch: int):
        """Raise the placement fence: plans stamped at or below ``epoch``
        predate this failover transition and will be rejected as stale."""
        self._placement_fence = max(self._placement_fence, int(epoch))

    def enter_degraded(self, fence_epoch: Optional[int] = None) -> int:
        """Drop to far-tier-only serving: the near tier is capacity-zeroed
        at runtime (host fault poisoned it or its HBM partition is gone).

        One accounting boundary: drain hits observed under the old map,
        then demote every resident near row through the real migration
        path — demote-first is what preserves the data, since rows in a
        dead near tier would otherwise be lost while the far mirror is
        stale. Placement planning, prefetch promotion and external pushes
        are suspended until ``exit_degraded``; the decode hot path is
        untouched (same single segmented dispatch, every read a far hit),
        so the 1-dispatch/0-mandatory-sync step budget survives the mode.
        Returns pages whose tier changed. Idempotent.
        """
        if self.degraded:
            return 0
        self.drain_tier_counters()
        self.degraded = True
        if self.tiered is not None:
            self.tiered.set_degraded(True)
        if fence_epoch is not None:
            self.fence_placement(fence_epoch)
        changed = self._apply_near_set(np.empty(0, np.int64))
        self.metrics.counter("degraded_entries").inc()
        if self.recorder is not None:
            self.recorder.instant(
                "degraded", -1, self.now(), replica=self.host_rid,
                demoted=changed,
            )
        return changed

    def exit_degraded(self, fence_epoch: Optional[int] = None):
        """Restore near-tier capacity. The near set stays empty until the
        next placement epoch (local TPP or a post-fence fleet push) refills
        it — recovery is a planning decision, not a blind restore of the
        pre-fault set. Idempotent."""
        if not self.degraded:
            return
        self.degraded = False
        if self.tiered is not None:
            self.tiered.set_degraded(False)
        if fence_epoch is not None:
            self.fence_placement(fence_epoch)
        if self.recorder is not None:
            self.recorder.instant("restored", -1, self.now(), replica=self.host_rid)

    def stranded_requests(self) -> List[Tuple[Request, int]]:
        """Read-only view of every request this engine would strand if it
        vanished right now: queued requests plus slot residents, each with
        the decode tokens already produced for it (work a failover must
        redo). Crash paths use this — the dead host's state is never
        mutated, just inventoried."""
        out: List[Tuple[Request, int]] = [(r, 0) for r in self.queue]
        for slot in self.slots:
            if slot.active:
                done = 0 if slot.chunk is not None else slot.decode_assigned - slot.remaining
                out.append((slot.request, max(0, done)))
        return out

    def abort_all(self) -> List[Tuple[Request, int]]:
        """Abort every queued and resident request (hung-host quarantine).

        Frees pagetable mappings, predictor streams and slots so a later
        re-dispatch of the same rid — here or on another replica —
        re-prefills cleanly from the request's retained prompt. Returns
        (request, decode_tokens_discarded) pairs; tokens already decoded
        stay in the books (they were really computed and streamed), the
        discarded count is the progress the retry will redo.
        """
        out: List[Tuple[Request, int]] = []
        for req in self.queue:
            self._enq_vt.pop(req.rid, None)
            self._enq_wall.pop(req.rid, None)
            out.append((req, 0))
        self.queue.clear()
        for slot in self.slots:
            if not slot.active:
                continue
            req = slot.request
            done = 0 if slot.chunk is not None else slot.decode_assigned - slot.remaining
            self.pagetable.free_sequence(slot.seq_id)
            self.prefetch.drop_stream(slot.seq_id)
            self._enq_vt.pop(slot.seq_id, None)
            self._enq_wall.pop(slot.seq_id, None)
            slot.seq_id = -1
            slot.request = None
            slot.chunk = None
            slot.remaining = 0
            out.append((req, max(0, done)))
        if out:
            self.metrics.counter("requests_aborted").inc(len(out))
        return out

    def lost_window(self) -> dict:
        """Quantify the undrained remainder a crash leaves behind.

        The device counter plane since the last drain boundary is the one
        book a dead host cannot report; this materializes it via the
        quarantine drain (``discard=True`` — the deltas are returned but
        never folded into the host books or charged as a sync, so they can
        never leak into the fleet merge) and sizes it in steps. Everything
        already drained — the host-visible books — survives the crash by
        construction; ``salvaged + lost_window`` is therefore invariant
        under drain cadence.
        """
        steps = self.engine_steps - self._last_drain_step
        out = {"steps_undrained": int(steps), "near": 0, "far": 0}
        if self.tiered is not None:
            d = self.tiered.drain_counters(discard=True)
            out["near"] = int(d["near"])
            out["far"] = int(d["far"])
        return out

    def live_counters(self) -> dict:
        """Ground-truth counters the fleet aggregator validates against."""
        self.drain_tier_counters()
        kv = self.profiler._stream("kv")
        return {
            "reads": kv.reads,
            "writes": kv.writes,
            "rw_ratio": self.profiler.rw_ratio("kv"),
            "near_hit_rate": self.placement.stats.hit_rate,
            "accesses": int(kv.counts.sum()),
        }

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        # finalized view: pages sitting unused in the prefetch buffer at
        # report time are wasted bandwidth the LRU never got to charge
        ps = self.prefetch.finalized_stats()
        device = None
        self.drain_tier_counters()
        steps = max(self.engine_steps, 1)
        if self.tiered is not None:
            device = {
                **self.tiered.stats(),
                "max_read_error": self.tiered_max_err,
                # the dispatch/sync budget the segmented step holds to:
                # 1 dispatch and (1/placement_window) syncs per step
                "dispatches_per_step": self.tiered.dispatches / steps,
                "host_syncs_per_step": self.tiered.host_syncs / steps,
                # role split of the same tier hits (drained from the
                # counter plane's role accumulator)
                "decode_near_hits": int(self.role_hits[ROLE_DECODE, 0]),
                "decode_far_hits": int(self.role_hits[ROLE_DECODE, 1]),
                "prefill_near_hits": int(self.role_hits[ROLE_PREFILL, 0]),
                "prefill_far_hits": int(self.role_hits[ROLE_PREFILL, 1]),
            }
        tv = self.ttft_vt_samples
        return {
            "device_tiering": device,
            "serving": {
                # honest model-dispatch books: chunked prefill holds
                # model_dispatches == engine_steps (prefill folded into
                # the step's one executable); the whole-slot path pays
                # prefill_dispatches extra launches on top
                "model_dispatches": self.model_dispatches,
                "prefill_dispatches": self.prefill_dispatches,
                "model_dispatches_per_step": self.model_dispatches / steps,
                "ttft_p50": float(np.percentile(tv, 50)) if tv else 0.0,
                "ttft_p99": float(np.percentile(tv, 99)) if tv else 0.0,
                "ttft_count": len(tv),
            },
            "tokens_decoded": self.tokens_decoded,
            "requests_finished": len(self.finished),
            "prefill_tokens": self.prefill_tokens,
            "prefill_tokens_saved": self.prefill_tokens_saved,
            "near_hit_rate": self.placement.stats.hit_rate,
            "migrations": self.placement.stats.promotions + self.placement.stats.demotions,
            "prefetch_accuracy": ps.accuracy,
            "prefetch_coverage": ps.coverage,
            "prefetch_bw_overhead": ps.bw_overhead,
            "prefetch_promoted_pages": self._m_pf_promoted.value,
            "pagetable": self.pagetable.stats(),
            "tenants": {
                t: {
                    **{k: c.value for k, c in ts.items()},
                    "near_hit_rate": ts["near_hits"].value
                    / max(ts["near_hits"].value + ts["far_hits"].value, 1),
                }
                for t, ts in self.tenant_stats.items()
            },
        }
