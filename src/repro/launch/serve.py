"""Serving driver: tiered paged-KV engine under a paper-workload profile.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
      --workload Reader --requests 16

Prints the engine's MemProf-in-the-loop report: near-tier hit rate, prefix
sharing savings, prefetch accuracy/coverage, and the measured KV bandwidth
distribution (what drives the tier plan).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax

from repro.configs import get_config
from repro.configs.workloads import PROFILES, get_profile
from repro.core import distribution as dist
from repro.data.requests import RequestGenerator
from repro.models.api import get_model
from repro.runtime.serving import EngineConfig, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--workload", default="Reader", choices=sorted(PROFILES))
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--n-pages", type=int, default=1024)
    ap.add_argument("--near-frac", type=float, default=0.30)
    ap.add_argument("--predictor", default="nextline")
    ap.add_argument("--prompt-mean", type=int, default=32)
    ap.add_argument("--decode-mean", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    eng = ServingEngine(
        api,
        params,
        EngineConfig(
            max_batch=args.max_batch,
            max_len=args.max_len,
            n_pages=args.n_pages,
            near_frac=args.near_frac,
            predictor=args.predictor,
        ),
        seed=args.seed,
    )
    prof = dataclasses.replace(
        get_profile(args.workload), prompt_mean=args.prompt_mean, decode_mean=args.decode_mean
    )
    gen = RequestGenerator(prof, vocab_size=cfg.vocab_size, seed=args.seed)
    t0 = time.time()
    stats = eng.run(gen, n_requests=args.requests, max_steps=10_000)
    dt = time.time() - t0

    print(f"[serve] {args.workload} on {args.arch}: {stats['requests_finished']} requests, "
          f"{stats['tokens_decoded']} tokens in {dt:.1f}s ({stats['tokens_decoded']/max(dt,1e-9):.1f} tok/s)")
    for k in ("prefill_tokens", "prefill_tokens_saved", "near_hit_rate", "migrations",
              "prefetch_accuracy", "prefetch_coverage", "prefetch_bw_overhead"):
        v = stats[k]
        print(f"  {k:24s} {v:.3f}" if isinstance(v, float) else f"  {k:24s} {v}")
    counts = eng.profiler.counts("kv")
    if counts.sum():
        cap90 = dist.capacity_for_traffic(counts, 0.9)
        print(f"  kv pages serving 90% BW: {cap90*100:.1f}% of capacity "
              f"(drives the {args.near_frac:.0%} near-tier plan)")
    pt = eng.pagetable.stats()
    print(f"  page table: used={pt['used_pages']} shared={pt['shared_mappings']} "
          f"cow={pt['cow_copies']} dedup={pt['dedup_ratio']:.2f}x")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
