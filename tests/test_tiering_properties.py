"""Property tests for core/tiering.plan invariants (paper §5).

Runs under real hypothesis when installed, else the deterministic replay
shim in tests/_hypothesis_compat.py — CI exercises both paths. The
invariants the fleet AutoTierer leans on:

* the near set never exceeds the near tier's planned capacity;
* the near set is exactly the top-k of the measured histogram (tie-robust:
  compared by served traffic, not by id);
* the plan is invariant under rescaling the counts — hotness is a shape,
  not a magnitude, so doubling the measurement window must not change
  placement.
"""
import numpy as np

from _hypothesis_compat import given, settings, st

from repro.core.hw import TierSpec
from repro.core.tiering import plan

SPECS = (
    TierSpec("hbm", 0.25, 800.0, 1.0, 8.0),
    TierSpec("host-dram", 0.75, 100.0, 6.0, 1.0),
)


def _counts_from(values):
    # at least two blocks so near/far is a real split
    return np.asarray(values + [1, 0], dtype=np.int64)


@given(st.lists(st.integers(0, 10_000), min_size=0, max_size=64))
@settings(max_examples=50, deadline=None)
def test_plan_capacity_never_exceeded(values):
    counts = _counts_from(values)
    p = plan(counts, SPECS)
    cap = int(np.ceil(SPECS[0].capacity_frac * counts.size))
    assert p.hot_blocks.size <= cap
    assert np.unique(p.hot_blocks).size == p.hot_blocks.size  # no dup placements
    assert ((p.hot_blocks >= 0) & (p.hot_blocks < counts.size)).all()


@given(st.lists(st.integers(0, 10_000), min_size=0, max_size=64))
@settings(max_examples=50, deadline=None)
def test_plan_near_set_is_topk(values):
    counts = _counts_from(values)
    p = plan(counts, SPECS)
    k = p.hot_blocks.size
    topk_traffic = np.sort(counts)[::-1][:k].sum()
    # ties make the exact id set ambiguous; the served traffic is not
    assert counts[p.hot_blocks].sum() == topk_traffic
    assert abs(sum(p.hit_fracs) - 1.0) < 1e-9 or counts.sum() == 0


@given(
    st.lists(st.integers(0, 10_000), min_size=0, max_size=64),
    st.integers(2, 1000),
)
@settings(max_examples=50, deadline=None)
def test_plan_stable_under_count_rescaling(values, scale):
    counts = _counts_from(values)
    p1 = plan(counts, SPECS)
    p2 = plan(counts * scale, SPECS)
    # integer rescaling preserves every pairwise comparison, so the argsort
    # (and with it the physical near set) must be bit-identical
    np.testing.assert_array_equal(p1.hot_blocks, p2.hot_blocks)
    np.testing.assert_allclose(p1.hit_fracs, p2.hit_fracs, atol=1e-12)
