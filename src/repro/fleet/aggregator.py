"""Fleet-wide MemProf: stitch per-host windows into one representative view.

Two aggregations, mirroring the paper's two planes:

* **profiling** (§4, Fig. 6): per-page access counts are summed over the
  *logical* page-id space — every replica runs the same engine over the same
  id space, exactly the "same code on many cores/hosts" premise, so the sum
  is the fleet's hotness histogram and drives fleet/autotier.py.

* **tracing** (§6.2, Table 6): each host's short attach/detach MemTracer
  windows are interleaved by time into ONE trace. Physical pages on
  different hosts are different memory, so block ids are namespaced per
  replica before stitching. Validation replays the stitched trace through a
  CacheSim scaled to the fleet's total cache capacity and compares hit ratio
  and R:W mix against the live per-host counters (paper: errors <= ~5%).
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.core import distribution
from repro.core.memtrace import TraceWindow, validate_trace
from repro.core.prefetch import train_tenant_successors
from repro.fleet.replica import Replica, ReplicaProfile
from repro.obs import MetricSnapshot, merge_snapshots

# per-replica stream-id namespace stride for fleet-pooled successor
# training: stream ids are engine seq ids (< 2**32 in any real run), so
# shifting by the rid keeps two hosts' streams from ever chaining together
_STREAM_STRIDE = 1 << 32


def export_all(replicas: List[Replica]) -> List[ReplicaProfile]:
    return [r.export_profile() for r in replicas]


def aggregate_metrics(profiles: List[ReplicaProfile]) -> MetricSnapshot:
    """Fleet metrics merge over exported profiles — same path as the
    hotness histogram: per-host state is only representative aggregated.

    Counters sum exactly (ints), histograms add bucket-wise, so the merged
    totals equal the legacy ``fleet_stats`` sums bit-for-bit while keeping
    tenant/replica label dimensions the legacy dicts flatten away.
    """
    return merge_snapshots([p.metrics for p in profiles if p.metrics is not None])


def aggregate_counts(profiles: List[ReplicaProfile]) -> np.ndarray:
    """Fleet hotness histogram over the shared logical page-id space.

    Robust to an elastic fleet's edge states: no profiles (all hosts
    retired mid-export) and freshly added hosts with all-zero counts.
    """
    n = max((p.counts.size for p in profiles), default=0)
    out = np.zeros(n, np.int64)
    for p in profiles:
        out[: p.counts.size] += p.counts
    return out


def aggregate_tenant_counts(profiles: List[ReplicaProfile]) -> Dict[str, np.ndarray]:
    """Per-tenant fleet histograms over the same logical page-id space.

    Summing the returned histograms over tenants reproduces
    ``aggregate_counts`` exactly: every engine access is recorded once in
    the combined "kv" stream and once in its tenant's "kv.<t>" stream.
    """
    n = max((p.counts.size for p in profiles), default=0)
    out: Dict[str, np.ndarray] = {}
    for p in profiles:
        for t, counts in p.tenant_counts.items():
            dst = out.setdefault(t, np.zeros(n, np.int64))
            dst[: counts.size] += counts
    return out


def stitch_fleet(profiles: List[ReplicaProfile], n_pages: Optional[int] = None) -> TraceWindow:
    """One representative fleet trace from many hosts' windows.

    Windows are ordered by (virtual time, rid), where a window that opened
    at engine step s on a host that joined the fleet at virtual time t0
    with per-step cost c happened at virtual time t0 + s*c — on a
    heterogeneous fleet a straggler's step index advances slower than its
    clock, and an elastically added host's step counter starts at 0 no
    matter when it joined, so interleaving by raw step index would place
    both hosts' windows too early. With nominal speeds and a founding
    (t0=0) replica set this degenerates to the lockstep (start_step, rid)
    round-robin interleave: contemporaneous windows stay contemporaneous,
    and each host's working set stays warm in the fleet-scaled cache just
    as it does in that host's own cache. Known approximation (identical in
    lockstep and event modes): an engine's step counter freezes while the
    host is idle, so windows after an idle gap compress toward the gap's
    start — harmless for replay because idle hosts record no accesses.
    ``n_pages`` (the per-host namespace stride) defaults to the widest
    host's page space.
    """
    if n_pages is None:
        n_pages = max((p.n_pages for p in profiles), default=0)
    tagged = []
    for p in profiles:
        for w in p.windows:
            tagged.append((p.clock_offset + w.start_step * p.step_cost, p.rid, w))
    tagged.sort(key=lambda t: (t[0], t[1]))
    if not tagged:
        return TraceWindow(
            0, np.zeros(0, np.int64), np.zeros(0, bool), np.zeros(0, np.int64)
        )
    blocks = np.concatenate([w.blocks + rid * n_pages for _, rid, w in tagged])
    writes = np.concatenate([w.is_write for _, _, w in tagged])
    streams = np.concatenate(
        [
            (
                w.stream
                if w.stream is not None
                else np.zeros(w.blocks.size, np.int64)
            )
            + rid * _STREAM_STRIDE
            for _, rid, w in tagged
        ]
    )
    return TraceWindow(tagged[0][2].start_step, blocks, writes, streams)


def train_fleet_successors(
    profiles: List[ReplicaProfile],
    min_count: int = 2,
    min_frac: float = 0.3,
    max_successors: int = 2,
) -> Dict[str, Dict[int, tuple]]:
    """Train TENANT-PARTITIONED successor tables from every host's windows:
    ``{tenant: {block: (succ, ...)}}``.

    This is the paper's point in acting form: the fleet tracing tool
    exists to drive better prefetchers. Blocks stay in the shared LOGICAL
    page-id space — the same "same code on many hosts" premise that lets
    ``aggregate_counts`` sum histograms lets transitions observed on any
    host count as evidence for all of them — while stream ids are
    namespaced per replica, so two hosts' request streams never chain into
    each other (that would re-create the interleaving contamination the
    per-stream model exists to kill). Pooling windows and retraining beats
    merging the per-host ``ReplicaProfile.successors`` tables: counts from
    different hosts reinforce each other through the confidence gates.

    Partitioning rides each profile's ``stream_tenants`` map (seq id ->
    tenant, rid-namespaced here to match the pooled streams): one tenant's
    template chains train ONLY that tenant's table, so a pushed fleet table
    can never flood a neighbor tenant's pending prefetches out of the
    partitioned prefetch buffer. Streams with no tenant mapping (legacy
    profiles) train the default ``""`` partition.
    """
    tagged = []
    stream_tenants: Dict[int, str] = {}
    for p in profiles:
        for sid, t in getattr(p, "stream_tenants", {}).items():
            stream_tenants[int(sid) + p.rid * _STREAM_STRIDE] = t
        for w in p.windows:
            s = (
                w.stream
                if w.stream is not None
                else np.zeros(w.blocks.size, np.int64)
            )
            tagged.append(
                TraceWindow(w.start_step, w.blocks, w.is_write, s + p.rid * _STREAM_STRIDE)
            )
    return train_tenant_successors(
        tagged, stream_tenants,
        min_count=min_count, min_frac=min_frac, max_successors=max_successors,
    )


def live_fleet_counters(profiles: List[ReplicaProfile]) -> dict:
    """Ground truth: access-weighted live hit ratio + aggregate R:W."""
    acc = sum(p.live_accesses for p in profiles)
    hit = sum(p.live_hit_ratio * p.live_accesses for p in profiles) / max(acc, 1)
    reads = sum(p.reads for p in profiles)
    writes = sum(p.writes for p in profiles)
    return {"hit_ratio": hit, "rw_ratio": reads / max(writes, 1), "accesses": acc}


def validate_fleet(
    profiles: List[ReplicaProfile],
    n_pages: Optional[int] = None,
    capacity_per_replica: Optional[int] = None,
) -> dict:
    """Table 6 at fleet scale: stitched-trace replay vs live counters.

    The namespace stride and sim capacity default to what the profiles
    themselves report (page-space width, live-cache size), so the
    validation can't silently drift from the fleet's actual geometry.
    ``rw_ratio_error_pct`` is signed, as in core/memtrace.validate_trace.
    """
    trace = stitch_fleet(profiles, n_pages)
    live = live_fleet_counters(profiles)
    if capacity_per_replica is None:
        capacity_per_replica = max((p.live_capacity for p in profiles), default=1)
    res = validate_trace(
        trace, live["hit_ratio"], live["rw_ratio"],
        capacity_blocks=capacity_per_replica * len(profiles),
    )
    res["trace_len"] = int(trace.blocks.size)
    return res


def fleet_report(profiles: List[ReplicaProfile], capacity_fracs=(0.05, 0.1, 0.25)) -> dict:
    """The MemProf report over the aggregated fleet histogram (Fig. 9/18).

    ``tenants`` carries the same hotness profile per tenant plus the
    access-weighted near-tier hit rate each tenant realized — the combined
    view drives tiering, the per-tenant views expose who wins and who pays
    on the shared far tier.
    """
    counts = aggregate_counts(profiles)
    tenants = {}
    for t, tc in aggregate_tenant_counts(profiles).items():
        weights = [
            (p.tenant_near_hit.get(t, 0.0), float(p.tenant_counts.get(t, np.zeros(0)).sum()))
            for p in profiles
        ]
        wsum = sum(w for _, w in weights)
        tenants[t] = {
            "total_accesses": int(tc.sum()),
            "hot": {f: distribution.hot_fraction(tc, f) for f in capacity_fracs},
            "zipf_alpha": distribution.zipf_alpha(tc),
            "near_hit_rate": sum(h * w for h, w in weights) / max(wsum, 1.0),
        }
    return {
        "total_accesses": int(counts.sum()),
        "active_frac": float((counts > 0).mean()),
        "hot": {f: distribution.hot_fraction(counts, f) for f in capacity_fracs},
        "capacity_for_90pct": distribution.capacity_for_traffic(counts, 0.9),
        "zipf_alpha": distribution.zipf_alpha(counts),
        "near_hit_rate": float(
            np.mean([p.near_hit_rate for p in profiles]) if profiles else 0.0
        ),
        "tenants": tenants,
    }
