"""Tiered serving demo: paged KV + prefix sharing + TPP placement + prefetch.

Two engines serve the same Web1-like traffic (high shared-prefix rate):
one with the paper's techniques ON, one with sharing off and a cold-only
placement — the deltas are the paper's Table 5 / Fig. 17 story live.

Device-executed tiering: the ON engine runs with
``EngineConfig.device_tiering=True`` (equivalently env
``REPRO_DEVICE_TIERING=1`` flips the default for every engine), so the
near/far split is EXECUTED on device rather than only accounted host-side:
the decode step's KV page stream runs through the fused
``kernels/tiered_gather`` Pallas pass over a device-resident store (near
rows f32, far rows int8 + per-row scales, dequant fused into the gather),
the near/far hit counters come back from the kernel, and every placement
push moves real rows between the tiers (promote = dequantize far->near,
demote = quantize near->far). The model's decode math itself stays the
exact per-family path — the device store executes the tier plane beside
it, pinned to the flat mirror by the differential harness. With
``tiered_identity_scales=True`` the device path is bit-identical to the
host-accounted engine — same tokens, same counters — which is exactly what
tests/test_tiered_decode.py enforces.

Single-dispatch step + drain cadence: one engine step issues ONE segmented
tiered-gather dispatch — every active slot's page ids concatenated with a
segment-offset vector, per-slot near/far hits accumulated into a
device-resident counter plane in the same kernel pass — and ZERO mandatory
host syncs. The next-token argmax is fused into the jitted decode (cache
buffers donated), so the decode feedback loop never leaves the device
either. The counter plane drains once per profiler window
(``placement_window`` steps; also at stats/export/placement-push
boundaries), and the drained deltas charge placement stats and per-tenant
books bit-identically to per-step charging. benchmarks/decode_dispatch_bench.py
measures the budget: 1 dispatch + ~1/window syncs per step vs ~slots of
each on the retired per-slot path (``EngineConfig.segmented_lookup=False``).

Sharded serving: ``EngineConfig.model_shards=N`` (with N devices visible —
CPU-testable under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``)
swaps in ``runtime/sharded.ShardedServingEngine``: ONE logical replica
spanning chips. Parameters are tensor-sharded over the ``model`` axis of a
serving mesh at placement time (``launch.mesh.shard_model_params``), and
the KV store becomes page-interleaved per-shard ``TieredKVCache`` slices
(shard ``s`` owns pages with ``pid % N == s``), each shard with near
capacity ``min(pages_owned, global_near_capacity)`` so the planner's
global near set always lands intact. The step budget is unchanged in
shape — at most ONE segmented tiered-gather dispatch per shard per step
(a shard outside the step's page walk pays zero) and ZERO mandatory host
syncs. Per-shard drain/merge contract: each shard's counter plane drains
independently once per profiler window and merges by PURE SUMMATION into
the replica's placement stats, tenant books, role split and MemProf
export — every page is counted by exactly one shard, so the merged books
are bit-identical to the unsharded engine's at any drain cadence, and the
shard-labeled ``shard_near_hits{shard=s}`` flight-recorder rows sum back
to the replica totals. A 1-shard mesh IS today's engine, bit for bit
(tokens, counters, tenant books — tests/test_sharded.py).

Continuous batching + chunked prefill: set ``EngineConfig.prefill_chunk``
to a positive token budget (e.g. 16) and the step becomes a vLLM-style
continuous-batching step — freed slots are refilled EVERY step and prompts
are fed in ``prefill_chunk``-token chunks interleaved with the co-resident
decode tokens inside the same single dispatch (prefill-chunk page reads
ride the segmented gather as prefill-role segments; completed prompt pages
go through the tiered write path as they finish). ``prefill_chunk=0`` (the
default) keeps the whole-slot path: the full prompt prefills at admit via
one extra blocking ``api.prefill`` dispatch. The offered-load cells in
decode_dispatch_bench compare the two on tokens/s and p99 TTFT.

PYTHONPATH=src python examples/serve_tiered.py
"""
import dataclasses

import jax

from repro.configs import get_config
from repro.configs.workloads import get_profile
from repro.data.requests import RequestGenerator
from repro.models.api import get_model
from repro.runtime.serving import EngineConfig, ServingEngine


def run(share: float, near_frac: float, label: str, n_requests=12, device=False):
    cfg = get_config("smollm-360m").reduced()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    eng = ServingEngine(
        api, params,
        EngineConfig(
            max_batch=4, max_len=96, n_pages=1024, near_frac=near_frac,
            device_tiering=device, tiered_identity_scales=device,
        ),
    )
    prof = dataclasses.replace(
        get_profile("Web1"), prompt_mean=48, decode_mean=10,
        prefix_share=share, n_prefixes=2,
    )
    gen = RequestGenerator(prof, vocab_size=cfg.vocab_size, seed=0)
    stats = eng.run(gen, n_requests=n_requests, max_steps=5000)
    pt = eng.pagetable.stats()
    print(f"[{label}]")
    print(f"  prefill tokens {stats['prefill_tokens']} (saved {stats['prefill_tokens_saved']} via shared prefixes)")
    print(f"  near-tier hit rate {stats['near_hit_rate']:.3f}  migrations {stats['migrations']}")
    print(f"  page dedup {pt['dedup_ratio']:.2f}x  (shared mappings {pt['shared_mappings']}, COW {pt['cow_copies']})")
    print(f"  prefetch acc {stats['prefetch_accuracy']:.2f} cov {stats['prefetch_coverage']:.2f} "
          f"bw overhead {stats['prefetch_bw_overhead']:.2f}")
    dev = stats["device_tiering"]
    if dev is not None:
        print(f"  device tiering: {dev['near_hits']} near / {dev['far_hits']} far hits counted "
              f"in-kernel, {dev['moved_rows']} rows migrated ({dev['moved_bytes']} B)")
    return stats


def main():
    on = run(share=0.95, near_frac=0.30,
             label="technique ON  (sharing + 30% near tier, device-executed)", device=True)
    off = run(share=0.0, near_frac=0.05, label="technique OFF (no sharing, 5% near tier)")
    saved = on["prefill_tokens_saved"]
    print(f"\nprefix sharing recovered {saved} prefill tokens; "
          f"near-hit {on['near_hit_rate']:.2f} vs {off['near_hit_rate']:.2f}")
    print("serve_tiered ok")


if __name__ == "__main__":
    main()
