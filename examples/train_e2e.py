"""End-to-end training driver: data pipeline -> trainer -> checkpoint ->
crash -> auto-resume -> verify the trajectory continued exactly.

Default is a ~2M-param llama-family model for 200 steps (a few minutes on
CPU). For the full-scale run of this example on a pod:
  python -m repro.launch.train --arch smollm-360m --steps 300 ...

PYTHONPATH=src python examples/train_e2e.py [--steps 200] [--d-model 128]
"""
import argparse
import dataclasses
import shutil
import tempfile

import numpy as np

from repro.configs import get_config
from repro.data.loader import ShardedLoader
from repro.data.synthetic import SyntheticCorpus
from repro.models.api import get_model
from repro.optim import AdamWConfig
from repro.optim.schedule import warmup_cosine
from repro.runtime.trainer import SimulatedFailure, Trainer, TrainerConfig


def build(d_model, n_layers, vocab):
    cfg = get_config("smollm-360m").reduced()
    heads = max(4, d_model // 32)
    return dataclasses.replace(
        cfg, d_model=d_model, n_layers=n_layers, n_heads=heads, n_kv_heads=heads,
        d_ff=4 * d_model, vocab_size=vocab,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--n-layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = build(args.d_model, args.n_layers, args.vocab)
    api = get_model(cfg)
    print(f"model: {cfg.n_params()/1e6:.1f}M params ({cfg.n_layers}L x {cfg.d_model})")
    ckpt = tempfile.mkdtemp(prefix="repro_e2e_")
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seq_len=args.seq)
    opt = AdamWConfig(lr=1e-3, schedule=warmup_cosine(20, args.steps))

    def mk():
        return Trainer(api, opt, TrainerConfig(ckpt_dir=ckpt, ckpt_every=25, log_every=20))

    def loader(start):
        return ShardedLoader(corpus, global_batch=args.batch, host_id=0, n_hosts=1, start_step=start)

    # phase 1: train and CRASH mid-way
    tr = mk()
    tr.init_state()
    half = args.steps // 2
    ld = loader(0)
    try:
        tr.run(ld, args.steps, fail_at=half, on_step=lambda s, m: s % 20 == 0 and print(
            f"  step {s:4d} loss {m['loss']:.4f}"))
    except SimulatedFailure as e:
        print(f"  !! {e} — simulating node failure")
    finally:
        ld.close()
    tr.ckpt.wait()

    # phase 2: a fresh process resumes from the last checkpoint
    tr2 = mk()
    assert tr2.try_restore(), "no checkpoint found"
    print(f"  resumed at step {tr2.step}")
    ld = loader(tr2.step)
    try:
        log = tr2.run(ld, args.steps - tr2.step, on_step=lambda s, m: s % 20 == 0 and print(
            f"  step {s:4d} loss {m['loss']:.4f}"))
    finally:
        ld.close()

    first = np.mean([m["loss"] for m in log[:5]])
    last = np.mean([m["loss"] for m in log[-5:]])
    print(f"loss {first:.4f} -> {last:.4f} over the resumed segment")
    assert last < first
    shutil.rmtree(ckpt, ignore_errors=True)
    print("train_e2e ok (crash -> resume -> loss still falling)")


if __name__ == "__main__":
    main()
